"""Fault-tolerance substrate: checkpoint atomicity/retention/async, exact
pipeline resume, health-monitor policy, elastic scale plans."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (Action, CheckpointManager, HealthMonitor,
                              scale_plan)
from repro.data import TokenPipeline
from repro.models.config import ArchConfig


@pytest.fixture
def tree():
    return {"w": jnp.arange(12.0).reshape(3, 4),
            "opt": {"mu": jnp.ones((5,)), "step": jnp.int32(7)}}


def test_roundtrip_and_retention(tmp_path, tree):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3):
        mgr.save(s, jax.tree_util.tree_map(lambda x: x * s, tree))
    assert mgr.all_steps() == [2, 3]
    restored, _ = mgr.restore(3, tree)
    np.testing.assert_allclose(np.asarray(restored["w"]),
                               np.arange(12.).reshape(3, 4) * 3)
    assert int(restored["opt"]["step"]) == 21


def test_async_save_ordering(tmp_path, tree):
    mgr = CheckpointManager(str(tmp_path), keep=10)
    for s in range(1, 5):
        mgr.save_async(s, tree, extra={"step": s})
    mgr.wait()
    assert mgr.all_steps() == [1, 2, 3, 4]
    _, extra = mgr.restore(mgr.latest_step(), tree)
    assert extra["step"] == 4


def test_crash_mid_write_leaves_no_partial(tmp_path, tree):
    """A stale .tmp dir (simulated crash) must be invisible to restore."""
    mgr = CheckpointManager(str(tmp_path), keep=5)
    mgr.save(1, tree)
    os.makedirs(os.path.join(str(tmp_path), "step_00000002.tmp"))
    assert mgr.latest_step() == 1          # tmp not listed
    mgr.save(2, tree)                      # and does not block a real save
    assert mgr.latest_step() == 2


def test_restore_missing_leaf_errors(tmp_path, tree):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"w": tree["w"]})
    with pytest.raises(KeyError):
        mgr.restore(1, tree)


def test_pipeline_exact_resume():
    cfg = ArchConfig(name="t", family="dense", n_layers=1, d_model=8,
                     n_heads=1, n_kv_heads=1, d_ff=16, vocab=100)
    p1 = TokenPipeline(cfg, batch=2, seq=16, seed=9)
    _ = next(p1)
    state = p1.state()
    want = next(p1)
    p2 = TokenPipeline(cfg, batch=2, seq=16, seed=0)
    p2.restore(state)
    got = next(p2)
    np.testing.assert_array_equal(want["tokens"], got["tokens"])
    np.testing.assert_array_equal(want["labels"], got["labels"])


def test_health_monitor_full_lifecycle():
    hm = HealthMonitor(4, straggler_factor=1.5, patience=2, miss_limit=2)
    assert hm.report_step(0, [1, 1, 1, 1]) == {}
    hm.report_step(1, [1, 1, 1, 4.0])
    a = hm.report_step(2, [1, 1, 1, 4.0])
    assert a == {3: Action.REBALANCE}
    a = hm.report_step(3, [1, 1, 1, None])
    assert a == {3: Action.CHECKPOINT_NOW}
    a = hm.report_step(4, [1, 1, 1, None])
    assert a == {3: Action.EVICT_AND_RESHARD}
    assert hm.survivors() == [0, 1, 2]
    # recovered workers are not resurrected implicitly
    assert hm.report_step(5, [1, 1, 1, 1]) == {}
    assert hm.n_alive() == 3


def test_scale_plan_preserves_model_parallel_degree():
    p = scale_plan(256, model_parallel=16)
    assert p.mesh_shape == (16, 16)
    p = scale_plan(255, model_parallel=16)       # lost one node
    assert p.mesh_shape == (15, 16)
    assert p.n_devices == 240
    p = scale_plan(8, model_parallel=16)         # degrade below MP degree
    assert p.mesh_shape[1] == 8


def test_train_loop_fault_injection(tmp_path):
    """The trainer's failure path: heartbeat miss → checkpoint → eviction."""
    from repro.launch.train import train_lm
    out = train_lm("llama3.2-1b", smoke=True, steps=8, batch=2, seq=32,
                   ckpt_dir=str(tmp_path), fault_at=4, log_every=0)
    assert out["survivors"] == [0, 1, 2]
    mgr = CheckpointManager(str(tmp_path))
    assert mgr.latest_step() is not None  # checkpoint fired on the miss
