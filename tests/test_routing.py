"""Algorithm 1 (parallel multicast routing) — §4.3 invariants + Fig. 9."""
import numpy as np
import pytest
pytest.importorskip("hypothesis",
                    reason="property tests need hypothesis "
                           "(pip install -e .[test])")
from hypothesis import given, settings, strategies as st

from repro.core.routing import (aggregate_bandwidth_model, fuse_experiment,
                                make_fuse_wave, popcount, route_messages,
                                validate_routing, xor_path_set)
from repro.core.schedule import (compare_schedules, dimension_ordered_table,
                                 round_bytes)


def test_xor_path_set_is_single_bit_flips():
    for cur in range(16):
        for dst in range(16):
            ps = xor_path_set(cur, dst, 4)
            assert len(ps) == bin(cur ^ dst).count("1")
            for nxt in ps:
                diff = cur ^ nxt
                assert diff and (diff & (diff - 1)) == 0


def test_single_wave_all_constraints():
    rng = np.random.default_rng(0)
    src, dst = make_fuse_wave(4, rng)
    res = route_messages(src, dst, seed=1)
    validate_routing(res, src, dst)
    # lower bound: longest shortest path
    assert res.cycles >= popcount(src ^ dst).max()


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(1, 4))
def test_routing_invariants_random_waves(seed, n_groups):
    """Property: ANY wave of ≤4 msgs/source routes deadlock-free with all
    §4.3.2 constraints held and every message delivered."""
    rng = np.random.default_rng(seed)
    src, dst = make_fuse_wave(n_groups, rng)
    res = route_messages(src, dst, seed=seed)
    validate_routing(res, src, dst)


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_routing_arbitrary_destinations(seed):
    """Even adversarial (non-permutation) destinations route, as long as the
    per-sender limit holds (4 msgs per source = the paper's start rule)."""
    rng = np.random.default_rng(seed)
    src = np.repeat(np.arange(16), 4)           # 4 msgs per sender
    dst = rng.integers(0, 16, 64)
    res = route_messages(src, dst, seed=seed, max_cycles=512)
    validate_routing(res, src, dst)


def test_fig9_fuse_scaling():
    """Fig. 9: Fuse1→4 average receive cycles grow ≈ +1 cycle per group
    (paper: 'adds only one cycle as messaging increases by one group')."""
    stats = [fuse_experiment(g, n_trials=60, seed=0) for g in (1, 2, 3, 4)]
    avgs = [s["avg_cycles"] for s in stats]
    assert avgs == sorted(avgs)
    # paper's avg period ≈ 20.13 ns @ 250 MHz ⇒ ~5.03 cycles for Fuse4
    assert 4.0 <= avgs[-1] <= 6.5
    for lo, hi in zip(avgs, avgs[1:]):
        assert hi - lo <= 1.5                   # ≈ +1 cycle per group
    # fastest possible full wave = 4 cycles (paper §4.3.3)
    assert min(s["avg_cycles"] for s in stats) >= 3.0


def test_bandwidth_model_matches_paper_magnitude():
    """§5.2: 64B lines, 16 cores, fan-in 4, 16× compression at ~20 ns
    average wave period ⇒ TB/s-scale effective aggregate bandwidth."""
    out = aggregate_bandwidth_model(20.13)
    assert 2.5e12 < out["effective_Bps"] < 3.5e12      # ≈ 2.96 TB/s
    assert 180e9 < out["raw_Bps"] < 210e9              # ≈ 189.4 GB/s raw


def test_dimension_ordered_static_schedule():
    rng = np.random.default_rng(0)
    src, dst = make_fuse_wave(4, rng)
    table = dimension_ordered_table(src, dst)
    assert table.shape == (4, 64)
    assert np.all(table[-1] == dst)
    cmp = compare_schedules(src, dst, seed=0)
    assert cmp["static_cycles"] == 4
    assert cmp["adaptive_cycles"] >= cmp["lower_bound"]


def test_round_bytes_accounting():
    src = np.array([0, 1, 2])
    dst = np.array([15, 1, 3])       # steps: 4, 0, 1
    rb = round_bytes(src, dst, msg_bytes=10)
    assert rb.sum() == (4 + 0 + 1) * 10
