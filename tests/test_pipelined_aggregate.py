"""Pipelined (double-buffered) aggregation + block-layout SpMM.

Contracts:
  * the pipelined hypercube fold is fp32 BIT-EXACT vs the serial fold for
    any wave count, on 2/4/8 simulated devices (same per-element add order,
    only the issue order differs);
  * the full pipelined aggregate (block tiles + fused fold) is bit-exact vs
    the serial aggregate, forward;
  * the block-layout SpMM kernel (per-block row offsets, no global one-hot)
    matches kernels/ref.py on random block graphs;
  * the overlapped train step computes the same loss as the serial one.
"""
import textwrap

import numpy as np
import pytest

from conftest import run_subprocess


# ---------------------------------------------------------------------------
# Block-layout SpMM kernel vs the pure-jnp oracle (single device).
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n_cores,n_dst,n_src,d,e", [
    (4, 64, 64, 32, 500),
    (8, 128, 96, 64, 1000),
    (2, 32, 200, 48, 333),
])
def test_spmm_block_matches_ref(rng, n_cores, n_dst, n_src, d, e):
    import jax.numpy as jnp
    from repro.core.blockmsg import dst_tiles
    from repro.graph.coo import from_edges
    from repro.graph.partition import block_partition
    from repro.kernels.ops import spmm_block
    from repro.kernels.ref import spmm_ref

    coo = from_edges(rng.integers(0, n_dst, e), rng.integers(0, n_src, e),
                     rng.standard_normal(e).astype(np.float32), n_dst, n_src)
    tiles = dst_tiles(block_partition(coo, n_cores))
    x = jnp.asarray(rng.standard_normal((n_src, d)), jnp.float32)
    out = spmm_block(jnp.asarray(tiles.rows), jnp.asarray(tiles.cols),
                     jnp.asarray(tiles.vals), x, tiles.dst_per_core)
    ref = spmm_ref(coo.rows, coo.cols, coo.vals, x, n_dst)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_sender_tiles_partials_match_flat_bit_exact(rng):
    """Per-destination-block partials == flat global segment-sum, bit-exact
    (same per-row add order — the invariant the pipelined fold needs)."""
    import jax.numpy as jnp
    from repro.distributed.aggregate import (
        _local_partials, _local_partials_blocked, shard_edges,
        shard_edges_blocked)
    from repro.graph.coo import from_edges

    P, n_dst, n_src, d, e = 4, 64, 128, 16, 900
    coo = from_edges(rng.integers(0, n_dst, e), rng.integers(0, n_src, e),
                     rng.standard_normal(e).astype(np.float32), n_dst, n_src)
    es = shard_edges(coo, P)
    eb = shard_edges_blocked(coo, P)
    x = jnp.asarray(rng.standard_normal((n_src // P, d)), jnp.float32)
    for j in range(P):
        flat = _local_partials(jnp.asarray(es.rows_global[j]),
                               jnp.asarray(es.cols_local[j]),
                               jnp.asarray(es.vals[j]), x, n_dst)
        blk = _local_partials_blocked(jnp.asarray(eb.rows_local[j]),
                                      jnp.asarray(eb.cols_local[j]),
                                      jnp.asarray(eb.vals[j]), x, n_dst // P)
        assert np.array_equal(np.asarray(flat).reshape(P, n_dst // P, d),
                              np.asarray(blk)), f"core {j} not bit-exact"


def test_feature_waves_cover_and_order():
    from repro.core.schedule import feature_waves

    for d, nc in [(7, 2), (128, 4), (1, 3), (16, 1), (5, 8)]:
        waves = feature_waves(d, nc)
        assert waves[0].start == 0
        assert waves[-1].stop == d
        for a, b in zip(waves, waves[1:]):
            assert a.stop == b.start
        assert max(w.size for w in waves) - min(w.size for w in waves) <= 1


@pytest.mark.parametrize("order", ["coag", "agco"])
@pytest.mark.parametrize("activate", [True, False])
def test_block_engine_layer_matches_reference(rng, order, activate):
    """The block-tile GCN layer (fwd through spmm_block, transpose-free
    tile-walk bwd), reached through the Engine, matches the flat
    transpose-free layer."""
    import jax
    import jax.numpy as jnp
    from repro.core.gcn import gcn_layer
    from repro.engine import Engine, EngineConfig
    from repro.graph.coo import from_edges

    n_dst, n_src, d, h, e = 64, 96, 24, 12, 700
    coo = from_edges(rng.integers(0, n_dst, e), rng.integers(0, n_src, e),
                     rng.standard_normal(e).astype(np.float32), n_dst, n_src)
    eng = Engine(EngineConfig(format="block", block_tiles=4))
    x = jnp.asarray(rng.standard_normal((n_src, d)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((d, h)), jnp.float32)
    y_ref = gcn_layer(coo, x, w, order=order, activate=activate)
    y_blk = eng.layer(coo, x, w, order=order, activate=activate)
    np.testing.assert_allclose(np.asarray(y_blk), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)

    def loss(fn):
        return lambda x, w: jnp.sum(fn(x, w) ** 2)

    g_ref = jax.grad(loss(lambda x, w: gcn_layer(
        coo, x, w, order=order, activate=activate)), argnums=(0, 1))(x, w)
    g_blk = jax.grad(loss(lambda x, w: eng.layer(
        coo, x, w, order=order, activate=activate)), argnums=(0, 1))(x, w)
    for a, b in zip(g_ref, g_blk):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                   rtol=2e-3, atol=2e-3)


# ---------------------------------------------------------------------------
# Multi-device bit-exactness (2/4/8 simulated cores, subprocess backend).
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n_devices", [2, 4, 8])
def test_pipelined_fold_bit_exact(n_devices):
    ndim = int(np.log2(n_devices))
    run_subprocess(textwrap.dedent(f"""
        import jax, numpy as np, jax.numpy as jnp
        from jax.sharding import Mesh, PartitionSpec as P
        from repro.compat import shard_map
        from repro.distributed.aggregate import (
            hypercube_reduce_scatter, hypercube_reduce_scatter_pipelined)

        PC, ndim = {n_devices}, {ndim}
        t, d = 16, 37                       # ragged d: uneven waves
        rng = np.random.default_rng(0)
        part = jnp.asarray(rng.standard_normal((PC, PC, t, d)), jnp.float32)
        mesh = Mesh(np.array(jax.devices()), ('model',))
        ser = shard_map(
            lambda p: hypercube_reduce_scatter(p[0], 'model', ndim)[None],
            mesh=mesh, in_specs=(P('model'),), out_specs=P('model'))
        a = np.asarray(ser(part))
        for nc in (1, 2, 3):
            pip = shard_map(
                lambda p, nc=nc: hypercube_reduce_scatter_pipelined(
                    p[0], 'model', ndim, nc)[None],
                mesh=mesh, in_specs=(P('model'),), out_specs=P('model'))
            b = np.asarray(pip(part))
            assert np.array_equal(a, b), (nc, np.abs(a - b).max())
        print('OK')
    """), n_devices=n_devices)


@pytest.mark.parametrize("n_devices", [4, 8])
def test_pipelined_aggregate_matches_serial(n_devices):
    """Full fused path: forward bit-exact vs serial aggregate; gradients
    match the dense reference (transpose-free mirror backward)."""
    ndim = int(np.log2(n_devices))
    run_subprocess(textwrap.dedent(f"""
        import jax, numpy as np, jax.numpy as jnp
        from jax.sharding import Mesh, PartitionSpec as P
        from repro.compat import shard_map
        from repro.graph.coo import from_edges
        from repro.distributed.aggregate import (
            shard_edges, shard_edges_blocked, hypercube_aggregate,
            hypercube_aggregate_pipelined)

        PC, ndim = {n_devices}, {ndim}
        n_dst, n_src, d, e = 16 * PC, 32 * PC, 20, 2500
        rng = np.random.default_rng(0)
        coo = from_edges(rng.integers(0, n_dst, e),
                         rng.integers(0, n_src, e),
                         rng.standard_normal(e).astype(np.float32),
                         n_dst, n_src)
        x = jnp.asarray(rng.standard_normal((n_src, d)), jnp.float32)
        mesh = Mesh(np.array(jax.devices()), ('model',))
        es = shard_edges(coo, PC)
        eb = shard_edges_blocked(coo, PC)
        ser = shard_map(
            lambda r, c, v, xl: hypercube_aggregate(
                'model', ndim, n_dst, r[0], c[0], v[0], xl),
            mesh=mesh, in_specs=(P('model'),) * 4, out_specs=P('model'))
        ys = np.asarray(ser(jnp.asarray(es.rows_global),
                            jnp.asarray(es.cols_local),
                            jnp.asarray(es.vals), x))
        for nc in (1, 2):
            pip = shard_map(
                lambda r, c, v, xl, nc=nc: hypercube_aggregate_pipelined(
                    'model', ndim, n_dst, r[0], c[0], v[0], xl, nc),
                mesh=mesh, in_specs=(P('model'),) * 4, out_specs=P('model'))
            args = (jnp.asarray(eb.rows_local), jnp.asarray(eb.cols_local),
                    jnp.asarray(eb.vals))
            yp = np.asarray(pip(*args, x))
            assert np.array_equal(ys, yp), (nc, np.abs(ys - yp).max())
            g1 = jax.grad(lambda xx: jnp.sum(pip(*args, xx) ** 2))(x)
            g2 = jax.grad(lambda xx: jnp.sum(coo.matmul(xx) ** 2))(x)
            np.testing.assert_allclose(np.asarray(g1), np.asarray(g2),
                                       rtol=2e-3, atol=2e-3)
        print('OK')
    """), n_devices=n_devices)


def test_overlap_train_step_matches_serial():
    """The block+pipelined engine computes the same loss trajectory as the
    coo+serial one (Weight-Bank sync + transpose-free mirror included)."""
    run_subprocess(textwrap.dedent("""
        import jax, numpy as np, jax.numpy as jnp
        from repro.graph import NeighborSampler, make_dataset
        from repro.distributed.gcn_train import init_params
        from repro.engine import Engine, EngineConfig

        ds = make_dataset('flickr', scale=0.005, feat_dim=32)
        sampler = NeighborSampler(ds.graph, fanouts=(5, 5),
                                  pad_multiple=8, seed=0)
        rng = np.random.default_rng(0)
        seeds = rng.permutation(ds.graph.n_nodes)[:32]
        mb = sampler.sample(seeds, rng=np.random.default_rng(1))
        feats = ds.features[np.minimum(mb.input_nodes,
                                       ds.graph.n_nodes - 1)]
        pad = mb.layers[0].n_dst - len(seeds)
        labels = ds.labels[np.pad(seeds, (0, pad))] % 7

        mesh = jax.make_mesh((8,), ('model',))
        params = init_params(jax.random.PRNGKey(0), [(32, 16), (16, 7)])
        ser = Engine(EngineConfig.from_spec('coo+serial',
                                            lr=0.3)).build(mesh)
        pip = Engine(EngineConfig.from_spec('block+pipelined', lr=0.3,
                                            n_chunks=2)).build(mesh)
        b_ser = ser.shard_batch(mb, feats, labels)
        b_pip = pip.shard_batch(mb, feats, labels)
        p1, p2 = params, params
        for i in range(5):
            p1, l1 = ser.train_step(p1, b_ser)
            p2, l2 = pip.train_step(p2, b_pip)
            assert abs(float(l1) - float(l2)) < 1e-6, (i, float(l1),
                                                       float(l2))
        print('OK', float(l1))
    """), n_devices=8)
