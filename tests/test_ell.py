"""Pre-reduced ELL aggregation engine: plan builder, kernels, custom_vjp,
distributed aggregate, autotuner.

Contracts:
  * the degree-bucketed ELL tables reproduce the COO oracle exactly
    (forward AND the column-major transpose walk), on both the pure-XLA
    path and the Pallas kernel (interpret mode off-TPU);
  * ELL padding is routed to a dedicated zero row / out-of-range fill —
    never to real row 0 — and empty destination blocks produce exact zeros;
  * the ELL engine layer (``Engine("ell+pipelined").layer``) matches the
    serial ``gcn_layer`` forward and grads;
  * EdgePlans are built once per graph and cached on the COO identity;
  * the distributed ELL aggregate matches the serial hypercube aggregate
    to ≤1e-5 abs (fp32) on 2/4/8 simulated devices, and the overlapped ELL
    train step tracks the serial loss trajectory;
  * the autotuner persists a JSON winner that ``get_config`` then serves.
"""
import textwrap

import numpy as np
import pytest

from conftest import run_subprocess


def _skewed_coo(rng, n_dst, n_src, e, hub_extra=60):
    """Random graph with a hub row (degree skew) and isolated dst rows."""
    from repro.graph.coo import from_edges

    rows = np.concatenate([rng.integers(0, n_dst, e),
                           np.full(hub_extra, min(3, n_dst - 1))])
    cols = rng.integers(0, n_src, len(rows))
    vals = rng.standard_normal(len(rows)).astype(np.float32)
    iso = rng.integers(0, n_dst, max(n_dst // 8, 1))   # isolated dst rows
    keep = ~np.isin(rows, iso)
    return from_edges(rows[keep], cols[keep], vals[keep], n_dst, n_src)


# ---------------------------------------------------------------------------
# Plan builder + kernels vs the COO oracles.
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n_dst,n_src,d,e", [
    (64, 64, 32, 500),
    (70, 53, 19, 600),          # non-multiple-of-tile everything
    (8, 200, 33, 777),
    (130, 96, 64, 1),           # near-empty graph
])
@pytest.mark.parametrize("caps", ["pow2", "single", (2, 8)])
def test_ell_walk_matches_oracle(rng, n_dst, n_src, d, e, caps):
    import jax.numpy as jnp
    from repro.kernels import edgeplan
    from repro.kernels.ops import ell_apply
    from repro.kernels.ref import spmm_ref, spmm_t_ref

    coo = _skewed_coo(rng, n_dst, n_src, e)
    plan = edgeplan.build_plan(coo, caps=caps)
    tables = plan.device_tables()
    x = jnp.asarray(rng.standard_normal((n_src, d)), jnp.float32)
    ref = np.asarray(spmm_ref(coo.rows, coo.cols, coo.vals, x, n_dst))
    for use_pallas in (False, True):
        out = np.asarray(ell_apply(tables, x, use_pallas=use_pallas))
        np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)
    err = jnp.asarray(rng.standard_normal((n_dst, d)), jnp.float32)
    tref = np.asarray(spmm_t_ref(coo.rows, coo.cols, coo.vals, err, n_src))
    for use_pallas in (False, True):
        out = np.asarray(ell_apply(tables, err, transpose=True,
                                   use_pallas=use_pallas))
        np.testing.assert_allclose(out, tref, rtol=1e-5, atol=1e-5)


def test_spmm_ell_kernel_direct(rng):
    """The raw bucketed kernel (one bucket at a time) vs a dense gather."""
    import jax.numpy as jnp
    from repro.kernels.ops import spmm_ell, spmm_ell_t

    nb, K, n_src, d = 37, 5, 41, 23
    cols = rng.integers(0, n_src + 1, (nb, K)).astype(np.int32)
    vals = rng.standard_normal((nb, K)).astype(np.float32)
    vals[cols == n_src] = 0.0           # padding entries -> zero row, val 0
    x = rng.standard_normal((n_src, d)).astype(np.float32)
    xz = np.concatenate([x, np.zeros((1, d), np.float32)])
    ref = (xz[cols] * vals[..., None]).sum(axis=1)
    out = np.asarray(spmm_ell(jnp.asarray(cols), jnp.asarray(vals),
                              jnp.asarray(x)))
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)
    # spmm_ell_t is the same kernel by contract
    out_t = np.asarray(spmm_ell_t(jnp.asarray(cols), jnp.asarray(vals),
                                  jnp.asarray(x)))
    np.testing.assert_allclose(out_t, ref, rtol=1e-5, atol=1e-5)


def test_ell_padding_never_touches_real_rows(rng):
    """Poisoned row 0: padding must gather the dedicated zero row, not real
    data — even when every padding val is (wrongly) nonzero."""
    import jax.numpy as jnp
    from repro.kernels.ops import spmm_ell

    nb, K, n_src, d = 8, 3, 16, 7
    cols = np.full((nb, K), n_src, np.int32)      # ALL entries -> zero row
    vals = np.ones((nb, K), np.float32)           # poisoned weights
    x = np.full((n_src, d), 1e9, np.float32)      # poisoned real rows
    out = np.asarray(spmm_ell(jnp.asarray(cols), jnp.asarray(vals),
                              jnp.asarray(x)))
    assert np.all(out == 0.0), "padding gathered real data"


def test_empty_destination_block_is_noop(rng):
    """A destination block with zero edges costs nothing and outputs exact
    zeros (inv_perm routes its rows to the zero output row)."""
    import jax.numpy as jnp
    from repro.graph.coo import from_edges
    from repro.kernels import edgeplan
    from repro.kernels.ops import ell_apply

    n_dst, n_src, d = 64, 64, 16            # 4 blocks of 16 dst rows
    rows = rng.integers(0, 16, 300)         # ALL edges land in block 0
    coo = from_edges(rows, rng.integers(0, n_src, 300),
                     rng.standard_normal(300).astype(np.float32),
                     n_dst, n_src)
    plan = edgeplan.build_plan(coo)
    x = jnp.asarray(np.full((n_src, d), 7.0, np.float32))
    for use_pallas in (False, True):
        out = np.asarray(ell_apply(plan.device_tables(), x,
                                   use_pallas=use_pallas))
        assert np.all(out[16:] == 0.0), "empty blocks must be exact zeros"
        assert np.any(out[:16] != 0.0)


def test_coo_out_of_range_padding_cols_are_noops(rng):
    """The wrappers now route padding cols PAST the source range, so the
    gather one-hot matches nothing: an out-of-range col is a no-op even
    with a NONZERO weight (the old col-0 padding relied entirely on
    val == 0 zeroing a gather of real row 0 after the fact)."""
    import jax.numpy as jnp
    from repro.kernels.ref import spmm_ref
    from repro.kernels.spmm import spmm as spmm_raw

    n_dst, n_src, d, e = 32, 48, 128, 256
    rows = rng.integers(0, n_dst, e).astype(np.int32)
    cols = rng.integers(0, n_src, e).astype(np.int32)
    vals = rng.standard_normal(e).astype(np.float32)
    cols[200:] = n_src                        # out-of-range "padding"
    vals[200:] = 7.0                          # ...with poisoned weights
    x = jnp.asarray(rng.standard_normal((n_src, d)), jnp.float32)
    out = np.asarray(spmm_raw(jnp.asarray(rows), jnp.asarray(cols),
                              jnp.asarray(vals), x, n_dst, interpret=True))
    ref = np.asarray(spmm_ref(rows[:200], cols[:200], vals[:200], x, n_dst))
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Layer-level: the ELL engine layer vs the serial transpose-free layer.
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("order", ["coag", "agco"])
@pytest.mark.parametrize("activate", [True, False])
def test_ell_engine_layer_matches_reference(rng, order, activate):
    import jax
    import jax.numpy as jnp
    from repro.core.gcn import gcn_layer
    from repro.engine import Engine

    n_dst, n_src, d, h, e = 64, 96, 24, 12, 700
    coo = _skewed_coo(rng, n_dst, n_src, e)
    eng = Engine("ell+pipelined")
    x = jnp.asarray(rng.standard_normal((n_src, d)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((d, h)), jnp.float32)
    y_ref = gcn_layer(coo, x, w, order=order, activate=activate)
    y_ell = eng.layer(coo, x, w, order=order, activate=activate)
    np.testing.assert_allclose(np.asarray(y_ell), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)

    def loss(fn):
        return lambda x, w: jnp.sum(fn(x, w) ** 2)

    g_ref = jax.grad(loss(lambda x, w: gcn_layer(
        coo, x, w, order=order, activate=activate)), argnums=(0, 1))(x, w)
    g_ell = jax.grad(loss(lambda x, w: eng.layer(
        coo, x, w, order=order, activate=activate)), argnums=(0, 1))(x, w)
    for a, b in zip(g_ref, g_ell):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                   rtol=2e-3, atol=2e-3)


def test_message_rowlists_is_the_merge_plan(rng):
    """Walking a Block Message with message_rowlists reconstructs exactly
    the per-slot neighbor groups the ELL rows store: one yield per wire
    message, whose (B, D, w) slices rebuild the block's edge set and whose
    lengths are the pre-merge fan-ins."""
    from repro.core.blockmsg import compress_block, message_rowlists

    lr = rng.integers(0, 16, 120)
    lc = rng.integers(0, 16, 120)
    v = rng.standard_normal(120).astype(np.float32)
    bm = compress_block(lr, lc, v, dst_core=2, src_core=5)
    seen = []
    for b, d_slots, w in message_rowlists(bm):
        assert len(d_slots) == len(w) > 0
        seen.extend((b, int(d), float(x)) for d, x in zip(d_slots, w))
    assert sorted(seen) == sorted(
        (int(r), int(c), float(x)) for r, c, x in zip(lr, lc, v))
    assert [b for b, _, _ in message_rowlists(bm)] \
        == sorted(set(int(r) for r in lr))


# ---------------------------------------------------------------------------
# Plan cache: built once per graph, keyed on the COO identity.
# ---------------------------------------------------------------------------
def test_edgeplan_cache_hit(rng):
    from repro.graph.coo import from_edges
    from repro.kernels import edgeplan

    coo = from_edges(rng.integers(0, 32, 100), rng.integers(0, 32, 100),
                     rng.standard_normal(100).astype(np.float32), 32, 32)
    p1 = edgeplan.build_plan(coo, caps="pow2")
    p2 = edgeplan.build_plan(coo, caps="pow2")
    assert p1 is p2, "second build must return the cached object"
    # different caps -> different plan; same arrays -> still cached per key
    p3 = edgeplan.build_plan(coo, caps="single")
    assert p3 is not p1
    assert edgeplan.build_plan(coo, caps="single") is p3
    # a different COO (fresh arrays) must NOT hit the cache
    coo2 = from_edges(np.asarray(coo.rows).copy(),
                      np.asarray(coo.cols).copy(),
                      np.asarray(coo.vals).copy(), 32, 32)
    assert edgeplan.build_plan(coo2, caps="pow2") is not p1


def test_shard_edges_ell_cache_hit(rng):
    from repro.distributed.aggregate import shard_edges_ell
    from repro.graph.coo import from_edges

    coo = from_edges(rng.integers(0, 32, 200), rng.integers(0, 32, 200),
                     rng.standard_normal(200).astype(np.float32), 32, 32)
    assert shard_edges_ell(coo, 4) is shard_edges_ell(coo, 4)
    assert shard_edges_ell(coo, 4) is not shard_edges_ell(coo, 2)


# ---------------------------------------------------------------------------
# Autotuner: sweep -> JSON -> get_config.
# ---------------------------------------------------------------------------
def test_autotune_persists_and_serves(tmp_path, monkeypatch):
    from repro.kernels import tune

    path = str(tmp_path / "autotune.json")
    monkeypatch.setenv(tune.ENV_PATH, path)
    tune.reset()
    rec = tune.autotune(n=64, deg=3, d=8, n_reps=1)
    assert rec["backend"] and "caps" in rec["config"]
    cfg = tune.get_config()
    assert cfg["caps"] == rec["config"]["caps"]
    # idempotent: second call reads the file, no re-sweep
    rec2 = tune.autotune(n=64, deg=3, d=8, n_reps=1)
    assert rec2["config"] == rec["config"]
    tune.reset()


# ---------------------------------------------------------------------------
# Distributed: ≤1e-5 vs the serial path on 2/4/8 simulated devices.
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n_devices", [2, 4, 8])
def test_distributed_ell_matches_serial(n_devices):
    ndim = int(np.log2(n_devices))
    run_subprocess(textwrap.dedent(f"""
        import jax, numpy as np, jax.numpy as jnp
        from jax.sharding import Mesh, PartitionSpec as P
        from repro.compat import shard_map
        from repro.graph.coo import from_edges
        from repro.distributed.aggregate import (
            shard_edges, shard_edges_ell, hypercube_aggregate,
            hypercube_aggregate_ell)

        PC, ndim = {n_devices}, {ndim}
        n_dst, n_src, d, e = 16 * PC, 32 * PC, 20, 2500
        rng = np.random.default_rng(0)
        coo = from_edges(rng.integers(0, n_dst, e),
                         rng.integers(0, n_src, e),
                         rng.standard_normal(e).astype(np.float32),
                         n_dst, n_src)
        x = jnp.asarray(rng.standard_normal((n_src, d)), jnp.float32)
        mesh = Mesh(np.array(jax.devices()), ('model',))
        es = shard_edges(coo, PC)
        ee = shard_edges_ell(coo, PC)
        ser = shard_map(
            lambda r, c, v, xl: hypercube_aggregate(
                'model', ndim, n_dst, r[0], c[0], v[0], xl),
            mesh=mesh, in_specs=(P('model'),) * 4, out_specs=P('model'))
        ys = np.asarray(ser(jnp.asarray(es.rows_global),
                            jnp.asarray(es.cols_local),
                            jnp.asarray(es.vals), x))
        tabs = jax.tree_util.tree_map(jnp.asarray, ee.tables)
        especs = jax.tree_util.tree_map(
            lambda a: P('model', *([None] * (a.ndim - 1))), tabs)
        for nc in (1, 2):
            agg = shard_map(
                lambda t, xl, nc=nc: hypercube_aggregate_ell(
                    'model', ndim, n_dst,
                    jax.tree_util.tree_map(lambda a: a[0], t), xl, nc),
                mesh=mesh, in_specs=(especs, P('model')),
                out_specs=P('model'))
            ye = np.asarray(agg(tabs, x))
            assert np.abs(ys - ye).max() <= 1e-5, (nc, np.abs(ys - ye).max())
            g1 = jax.grad(lambda xx: jnp.sum(agg(tabs, xx) ** 2))(x)
            g2 = jax.grad(lambda xx: jnp.sum(coo.matmul(xx) ** 2))(x)
            np.testing.assert_allclose(np.asarray(g1), np.asarray(g2),
                                       rtol=2e-3, atol=2e-3)
        print('OK')
    """), n_devices=n_devices)


def test_ell_mesh_mismatch_fails_loudly():
    """A batch built for 8 cores on a 4-core mesh must raise, not silently
    drop half the senders' tables (the blocked path's tile-count guard,
    re-established for the ELL layout)."""
    run_subprocess(textwrap.dedent("""
        import jax, numpy as np
        from repro.distributed.gcn_train import init_params
        from repro.engine import Engine
        from repro.graph.coo import from_edges

        rng = np.random.default_rng(0)

        class _MB:
            layers = [from_edges(rng.integers(0, 32, 200),
                                 rng.integers(0, 64, 200),
                                 rng.standard_normal(200).astype(np.float32),
                                 32, 64)]

        feats = rng.standard_normal((64, 8)).astype(np.float32)
        labels = rng.integers(0, 4, 32).astype(np.int32)
        eng = Engine('ell+pipelined')
        batch = eng.build(n_cores=8).shard_batch(_MB(), feats, labels)
        mesh = jax.make_mesh((4,), ('model',))
        step = eng.build(mesh).train_step_fn(batch['dims'])
        params = init_params(jax.random.PRNGKey(0), [(8, 4)])
        try:
            step(params, batch)
        except ValueError as e:
            assert 'different core count' in str(e), e
            print('OK raised')
        else:
            raise AssertionError('mesh/layout mismatch not detected')
    """), n_devices=4)


def test_ell_train_step_matches_serial():
    """The ell+pipelined engine tracks the coo+serial loss trajectory
    (≤1e-5; the merge reorders fp32 adds)."""
    run_subprocess(textwrap.dedent("""
        import jax, numpy as np, jax.numpy as jnp
        from repro.graph import NeighborSampler, make_dataset
        from repro.distributed.gcn_train import init_params
        from repro.engine import Engine, EngineConfig

        ds = make_dataset('flickr', scale=0.005, feat_dim=32)
        sampler = NeighborSampler(ds.graph, fanouts=(5, 5),
                                  pad_multiple=8, seed=0)
        rng = np.random.default_rng(0)
        seeds = rng.permutation(ds.graph.n_nodes)[:32]
        mb = sampler.sample(seeds, rng=np.random.default_rng(1))
        feats = ds.features[np.minimum(mb.input_nodes,
                                       ds.graph.n_nodes - 1)]
        pad = mb.layers[0].n_dst - len(seeds)
        labels = ds.labels[np.pad(seeds, (0, pad))] % 7

        mesh = jax.make_mesh((8,), ('model',))
        params = init_params(jax.random.PRNGKey(0), [(32, 16), (16, 7)])
        ser = Engine(EngineConfig.from_spec('coo+serial',
                                            lr=0.3)).build(mesh)
        ell = Engine(EngineConfig.from_spec('ell+pipelined', lr=0.3,
                                            n_chunks=2)).build(mesh)
        b_ser = ser.shard_batch(mb, feats, labels)
        b_ell = ell.shard_batch(mb, feats, labels)
        p1, p2 = params, params
        for i in range(5):
            p1, l1 = ser.train_step(p1, b_ser)
            p2, l2 = ell.train_step(p2, b_ell)
            assert abs(float(l1) - float(l2)) < 1e-5, (i, float(l1),
                                                       float(l2))
        print('OK', float(l1))
    """), n_devices=8)
