# NOTE: no XLA_FLAGS here on purpose — unit/smoke tests must see the real
# 1-device CPU backend (the dry-run sets its own 512-device flag in its own
# process; multi-device tests in test_distributed.py use subprocesses).
import os
import sys

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")
if SRC not in sys.path:
    sys.path.insert(0, SRC)


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def run_subprocess(code: str, n_devices: int = 16) -> str:
    """Run a snippet under a forced multi-device CPU backend."""
    import subprocess
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    if out.returncode != 0:
        raise AssertionError(f"subprocess failed:\n{out.stdout}\n{out.stderr}")
    return out.stdout
