"""The engine-native Trainer + async input pipeline.

Contracts:
  * the :class:`~repro.data.Prefetcher` preserves the restartable-stream
    contract — ordering, state of the last CONSUMED batch (in-flight work
    excluded), drain-on-close with rewind, producer errors re-raised on
    the consumer;
  * sync and prefetch input pipelines consume bit-identical batch streams
    (same losses, step for step);
  * checkpoint mid-epoch WITH batches in flight + restore replays the
    remaining batch stream and loss trajectory bit-exactly vs an
    uninterrupted run;
  * every registered format×schedule spec trains end-to-end through the
    Trainer on 2 simulated devices, within 1e-4 of the coo+serial oracle
    trajectory (the ISSUE-4 acceptance bar — formats the old train_gcn
    hard-rejected train here via the host-side ``prepare_batch`` hook);
  * multilabel datasets train through the argmax proxy.
"""
import numpy as np
import pytest
import textwrap

from conftest import run_subprocess


# ---------------------------------------------------------------------------
# Prefetcher unit contract (no jax, no devices).
# ---------------------------------------------------------------------------
class _CountSource:
    """Deterministic restartable stream: yields (idx,) tuples."""

    def __init__(self, idx: int = 0, sleep: float = 0.0):
        self.idx = idx
        self.sleep = sleep

    def __next__(self):
        if self.sleep:
            import time
            time.sleep(self.sleep)
        out = (self.idx,)
        self.idx += 1
        return out

    def state(self):
        return {"idx": self.idx}

    def restore(self, st):
        self.idx = int(st["idx"])


def _mk(depth=2, sleep=0.0, prepare=None):
    from repro.data import Prefetcher
    return Prefetcher(_CountSource(sleep=sleep), prepare=prepare,
                      depth=depth)


def test_prefetcher_preserves_order_and_applies_prepare():
    pf = _mk(prepare=lambda i: i * 10)
    got = [next(pf) for _ in range(7)]
    pf.close()
    assert got == [0, 10, 20, 30, 40, 50, 60]
    assert pf.n_consumed == 7
    assert pf.stall_s >= 0.0


def test_prefetcher_state_excludes_in_flight_batches():
    import time
    pf = _mk(depth=2)
    assert pf.state() == {"idx": 0}          # nothing consumed yet
    assert next(pf) == (0,)
    # give the producer time to run ahead (queue depth 2 + one in hand)
    time.sleep(0.2)
    assert pf.source.idx > 1                 # it DID prefetch ahead
    assert pf.state() == {"idx": 1}          # ...but state() doesn't move
    assert next(pf) == (1,)
    assert pf.state() == {"idx": 2}
    pf.close()


def test_prefetcher_close_rewinds_so_nothing_is_skipped():
    import time
    pf = _mk(depth=2)
    assert next(pf) == (0,)
    time.sleep(0.2)                          # let it prefetch 1, 2
    pf.close()                               # drops them, rewinds source
    assert pf.source.idx == 1
    assert next(pf) == (1,)                  # regenerated, not skipped
    pf.close()


def test_prefetcher_restore_is_batch_exact():
    pf = _mk(depth=2)
    want = [next(pf) for _ in range(5)]
    st = pf.state()
    _ = [next(pf) for _ in range(3)]         # wander ahead
    pf.restore(st)
    got = [next(pf) for _ in range(3)]
    pf.close()
    assert st == {"idx": 5}
    assert got == [(5,), (6,), (7,)]
    assert want == [(i,) for i in range(5)]


def test_prefetcher_propagates_producer_errors():
    import time
    from repro.data import Prefetcher

    class _Boom(_CountSource):
        def __next__(self):
            if self.idx == 2:
                raise RuntimeError("sampler exploded")
            return super().__next__()

    pf = Prefetcher(_Boom(), depth=1)
    assert next(pf) == (0,)
    # depth 1 forces the full-queue timing: the producer hits the error
    # while item 1 still occupies the queue, so the DONE sentinel must
    # wait for space — a dropped sentinel here would hang the consumer
    # forever with the error lost
    time.sleep(0.3)
    assert next(pf) == (1,)
    with pytest.raises(RuntimeError, match="sampler exploded"):
        next(pf)
    pf.close()


def test_prefetcher_rejects_bad_depth():
    from repro.data import Prefetcher
    with pytest.raises(ValueError, match="depth"):
        Prefetcher(_CountSource(), depth=0)


def test_prefetcher_close_is_idempotent():
    pf = _mk(depth=2)
    assert next(pf) == (0,)
    pf.close()
    pf.close()                               # double close: a no-op
    pf.close()
    assert pf.source.idx == 1                # still rewound to last consumed
    assert next(pf) == (1,)                  # and still restartable
    pf.close()
    # close before ever starting the producer is also fine
    fresh = _mk()
    fresh.close()
    assert next(fresh) == (0,)
    fresh.close()


def test_prefetcher_close_after_producer_error_discards_it():
    import time
    from repro.data import Prefetcher

    class _Boom(_CountSource):
        def __next__(self):
            if self.idx == 1:
                raise RuntimeError("sampler exploded")
            return super().__next__()

    pf = Prefetcher(_Boom(), depth=1)
    assert next(pf) == (0,)
    time.sleep(0.3)                          # let the producer die
    pf.close()                               # error discarded, queue drained
    pf.close()                               # and still idempotent
    assert pf._error is None
    # the rewound source re-raises on the NEXT consume — the error is
    # regenerated, never silently lost
    with pytest.raises(RuntimeError, match="sampler exploded"):
        next(pf)
    pf.close()


# ---------------------------------------------------------------------------
# Trainer: sync == prefetch, metrics, multilabel.
# ---------------------------------------------------------------------------
def _toy_trainer(pipeline: str, ckpt=None, spec="coo+serial", seed=3,
                 dataset="flickr", **kw):
    from repro.launch.trainer import Trainer
    kw.setdefault("feat_dim", 16)
    kw.setdefault("scale", 0.005)
    return Trainer(spec, dataset, n_cores=1,
                   hidden=16, batch_size=16, lr=0.2, seed=seed,
                   input_pipeline=pipeline, val_batches=1,
                   ckpt_dir=ckpt, ckpt_every=0, **kw)


def test_trainer_sync_and_prefetch_streams_are_identical():
    a = _toy_trainer("prefetch").fit(1, steps_per_epoch=6)
    b = _toy_trainer("sync").fit(1, steps_per_epoch=6)
    assert a["loss_history"] == b["loss_history"]
    assert len(a["loss_history"]) == 6
    assert 0.0 <= a["val_acc"][0] <= 1.0
    for key in ("epoch_s", "steps_per_s", "host_stall_s_per_step"):
        assert len(a[key]) == 1 and a[key][0] >= 0.0
    assert a["input_pipeline"] == "prefetch"
    assert b["input_pipeline"] == "sync"


def test_trainer_multilabel_dataset_trains():
    out = _toy_trainer("prefetch", dataset="yelp", scale=0.0005,
                       seed=0).fit(1, steps_per_epoch=2)
    assert len(out["loss_history"]) == 2
    assert all(np.isfinite(out["loss_history"]))


def test_trainer_rejects_bad_input_pipeline():
    # validated before any dataset/mesh work happens
    with pytest.raises(ValueError, match="input_pipeline"):
        _toy_trainer("turbo")


# ---------------------------------------------------------------------------
# Resume-exactness THROUGH the prefetcher (mid-epoch, batches in flight).
# ---------------------------------------------------------------------------
def test_trainer_resume_through_prefetcher_is_bit_exact(tmp_path):
    """Checkpoint mid-epoch while the producer holds prefetched batches in
    flight; restore must replay the exact remaining batch stream (pipeline
    states step for step) and the exact loss trajectory."""
    full = _toy_trainer("prefetch")
    full_losses, full_states = [], []
    for _ in range(10):
        full_losses.extend(full.train_steps(1))
        full_states.append(full._pipeline_state())
    full.close()

    part = _toy_trainer("prefetch", ckpt=str(tmp_path))
    part.train_steps(4)
    # the producer thread has had time to run ahead; the saved state must
    # nevertheless point at batch 5 (last consumed), not at the queue head
    part.save(sync=True)
    part.close()

    resumed = _toy_trainer("prefetch", ckpt=str(tmp_path))
    assert resumed.resume() is True
    assert resumed.global_step == 4
    assert resumed._pipeline_state() == full_states[3]
    res_losses, res_states = [], []
    for _ in range(6):
        res_losses.extend(resumed.train_steps(1))
        res_states.append(resumed._pipeline_state())
    resumed.close()
    # bit-identical loss trajectory AND batch stream
    assert res_losses == full_losses[4:]
    assert res_states == full_states[4:]


def test_trainer_fit_resume_continues_to_same_horizon(tmp_path):
    full = _toy_trainer("prefetch").fit(1, steps_per_epoch=8)
    part = _toy_trainer("prefetch", ckpt=str(tmp_path))
    part.train_steps(5)
    part.save(sync=True)
    part.close()
    out = _toy_trainer("prefetch", ckpt=str(tmp_path)).fit(
        1, steps_per_epoch=8, max_steps=8, resume=True)
    assert out["loss_history"] == full["loss_history"][5:]
    assert out["global_step"] == 8


# ---------------------------------------------------------------------------
# Every registered spec trains end-to-end on 2 simulated devices (ISSUE-4
# acceptance bar: trajectories within 1e-4 of the coo+serial oracle).
# ---------------------------------------------------------------------------
def test_trainer_every_spec_matches_oracle_on_two_devices():
    run_subprocess(textwrap.dedent("""
        from repro.engine import supported_specs
        from repro.launch.trainer import Trainer

        def run(spec):
            tr = Trainer(spec, 'flickr', n_cores=2, scale=0.005,
                         feat_dim=16, hidden=16, batch_size=16, lr=0.2,
                         seed=0, input_pipeline='prefetch', val_batches=1)
            out = tr.fit(1, steps_per_epoch=4)
            return out['loss_history'], out['val_acc'][0]

        # padding that can't split across the hypercube dies at init
        try:
            Trainer('coo+serial', 'flickr', n_cores=2, scale=0.005,
                    feat_dim=16, pad_multiple=17)
            raise SystemExit('expected ValueError for pad_multiple=17')
        except ValueError as e:
            assert 'multiple of' in str(e), e

        specs = supported_specs()
        assert len(specs) >= 3, specs
        ref, ref_acc = run('coo+serial')
        for spec in specs:
            traj, acc = run(spec)
            drift = max(abs(a - b) for a, b in zip(ref, traj))
            assert drift <= 1e-4, (spec, drift, ref, traj)
            assert abs(acc - ref_acc) <= 0.5, (spec, acc, ref_acc)
        print('OK', specs)
    """), n_devices=2)
