"""Distributed layer — runs under a 16-device CPU backend in subprocesses
(the main pytest process must keep the real 1-device backend)."""
import textwrap

import numpy as np
import pytest

from conftest import run_subprocess


def test_hypercube_aggregate_fwd_bwd_and_uma():
    run_subprocess(textwrap.dedent("""
        import jax, numpy as np, jax.numpy as jnp
        from repro.compat import shard_map, set_mesh
        from jax.sharding import Mesh, PartitionSpec as P
        from repro.graph.coo import from_edges
        from repro.distributed.aggregate import (shard_edges,
            shard_edges_by_dst, hypercube_aggregate, uma_aggregate)

        P_CORES, ndim = 16, 4
        n_dst, n_src, d, e = 256, 512, 32, 3000
        rng = np.random.default_rng(0)
        coo = from_edges(rng.integers(0, n_dst, e),
                         rng.integers(0, n_src, e),
                         rng.standard_normal(e).astype(np.float32),
                         n_dst, n_src)
        x = jnp.asarray(rng.standard_normal((n_src, d)), jnp.float32)
        ref = coo.matmul(x)
        mesh = Mesh(np.array(jax.devices()), ('model',))
        es = shard_edges(coo, P_CORES)
        fn = shard_map(
            lambda r, c, v, xl: hypercube_aggregate(
                'model', ndim, n_dst, r[0], c[0], v[0], xl),
            mesh=mesh, in_specs=(P('model'),) * 4, out_specs=P('model'))
        y = fn(jnp.asarray(es.rows_global), jnp.asarray(es.cols_local),
               jnp.asarray(es.vals), x)
        np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                                   rtol=2e-4, atol=2e-4)

        g1 = jax.grad(lambda xx: jnp.sum(fn(
            jnp.asarray(es.rows_global), jnp.asarray(es.cols_local),
            jnp.asarray(es.vals), xx) ** 2))(x)
        g2 = jax.grad(lambda xx: jnp.sum(coo.matmul(xx) ** 2))(x)
        np.testing.assert_allclose(np.asarray(g1), np.asarray(g2),
                                   rtol=2e-3, atol=2e-3)

        esd = shard_edges_by_dst(coo, P_CORES)
        fn_uma = shard_map(
            lambda r, c, v, xl: uma_aggregate(
                'model', ndim, n_dst, r[0], c[0], v[0], xl),
            mesh=mesh, in_specs=(P('model'),) * 4, out_specs=P('model'))
        yu = fn_uma(jnp.asarray(esd.rows_global),
                    jnp.asarray(esd.cols_local), jnp.asarray(esd.vals), x)
        np.testing.assert_allclose(np.asarray(yu), np.asarray(ref),
                                   rtol=2e-4, atol=2e-4)
        print('OK')
    """))


def test_hypercube_wire_bytes_beat_uma_in_hlo():
    """The NUMA claim, on the compiled artifact: the hypercube schedule's
    collective-permute bytes < the UMA all-gather bytes for a denser-than-
    trivial graph."""
    run_subprocess(textwrap.dedent("""
        import jax, numpy as np, jax.numpy as jnp
        from repro.compat import shard_map, set_mesh
        from jax.sharding import Mesh, PartitionSpec as P
        from repro.graph.coo import from_edges
        from repro.distributed.aggregate import (shard_edges,
            shard_edges_by_dst, hypercube_aggregate, uma_aggregate)
        from repro.launch.hlo_analysis import analyze_hlo

        P_CORES, ndim = 16, 4
        n_dst, n_src, d, e = 512, 2048, 64, 30000
        rng = np.random.default_rng(0)
        coo = from_edges(rng.integers(0, n_dst, e),
                         rng.integers(0, n_src, e),
                         np.abs(rng.standard_normal(e)).astype(np.float32),
                         n_dst, n_src)
        x = jnp.asarray(rng.standard_normal((n_src, d)), jnp.float32)
        mesh = Mesh(np.array(jax.devices()), ('model',))
        es = shard_edges(coo, P_CORES)
        esd = shard_edges_by_dst(coo, P_CORES)
        hyper = jax.jit(shard_map(
            lambda r, c, v, xl: hypercube_aggregate(
                'model', ndim, n_dst, r[0], c[0], v[0], xl),
            mesh=mesh, in_specs=(P('model'),) * 4, out_specs=P('model')))
        uma = jax.jit(shard_map(
            lambda r, c, v, xl: uma_aggregate(
                'model', ndim, n_dst, r[0], c[0], v[0], xl),
            mesh=mesh, in_specs=(P('model'),) * 4, out_specs=P('model')))
        args_h = (jnp.asarray(es.rows_global), jnp.asarray(es.cols_local),
                  jnp.asarray(es.vals), x)
        args_u = (jnp.asarray(esd.rows_global), jnp.asarray(esd.cols_local),
                  jnp.asarray(esd.vals), x)
        wh = analyze_hlo(hyper.lower(*args_h).compile().as_text(),
                         16).collective_wire_bytes
        wu = analyze_hlo(uma.lower(*args_u).compile().as_text(),
                         16).collective_wire_bytes
        assert wh < wu, (wh, wu)
        print('hyper', wh, '< uma', wu)
    """))


def test_compressed_psum_and_error_feedback():
    run_subprocess(textwrap.dedent("""
        import jax, numpy as np, jax.numpy as jnp
        from repro.compat import shard_map, set_mesh
        from jax.sharding import Mesh, PartitionSpec as P
        from repro.distributed.compress import (compressed_psum,
            ef_compress_grads, init_error_state)

        mesh = Mesh(np.array(jax.devices()), ('model',))
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.standard_normal((16, 4096)), jnp.float32)
        fn = shard_map(
            lambda xl: compressed_psum(xl[0], 'model', 4)[None],
            mesh=mesh, in_specs=(P('model'),), out_specs=P('model'))
        out = np.asarray(fn(x))[0]
        ref = np.asarray(x).sum(0)
        rel = np.abs(out - ref).max() / np.abs(ref).max()
        assert rel < 0.05, rel

        # error feedback: average gradient bias vanishes over repeats
        grads = {'w': jnp.asarray(rng.standard_normal((16, 1024)),
                                  jnp.float32)}
        def run(gl, el):
            m, e = ef_compress_grads({'w': gl[0]}, {'w': el[0]},
                                     'model', 4)
            return m['w'][None], e['w'][None]
        step = shard_map(run, mesh=mesh,
                             in_specs=(P('model'), P('model')),
                             out_specs=(P('model'), P('model')))
        err = jnp.zeros((16, 1024), jnp.float32)
        acc = np.zeros(1024, np.float32)
        ref_mean = np.asarray(grads['w']).mean(0)
        for i in range(8):
            mean, err = step(grads['w'], err)
            acc += np.asarray(mean)[0]
        bias = np.abs(acc / 8 - ref_mean).max() / np.abs(ref_mean).max()
        assert bias < 0.02, bias
        print('OK', rel, bias)
    """))


def test_compressed_psum_rejects_non_hypercube_core_counts():
    """Regression: the dimension-ordered hypercube rounds (peer = i ^ 2^b)
    silently mis-routed on non-power-of-two counts; now the ``n_cores=``
    form raises a ValueError naming the topology, and exactly one of
    ``ndim``/``n_cores`` must be given."""
    import jax.numpy as jnp
    from repro.distributed.compress import (_hypercube_ndim,
                                            compressed_psum,
                                            ef_compress_grads)

    assert _hypercube_ndim(1) == 0
    assert _hypercube_ndim(8) == 3
    x = jnp.zeros((16,), jnp.float32)
    for bad in (3, 6, 12):
        with pytest.raises(ValueError, match="power-of-two"):
            compressed_psum(x, "model", n_cores=bad)
        with pytest.raises(ValueError, match="power-of-two"):
            ef_compress_grads({"w": x}, {"w": x}, "model", n_cores=bad)
    with pytest.raises(ValueError, match="exactly one"):
        compressed_psum(x, "model")
    with pytest.raises(ValueError, match="exactly one"):
        compressed_psum(x, "model", 2, n_cores=4)
    with pytest.raises(ValueError, match="exactly one"):
        ef_compress_grads({"w": x}, {"w": x}, "model", 2, n_cores=4)


def test_grad_accum_matches_full_batch():
    run_subprocess(textwrap.dedent("""
        import jax, numpy as np, jax.numpy as jnp
        from repro.compat import shard_map, set_mesh
        from repro.distributed.overlap import grad_accum

        rng = np.random.default_rng(0)
        w = jnp.asarray(rng.standard_normal((8, 4)), jnp.float32)
        xs = jnp.asarray(rng.standard_normal((16, 8)), jnp.float32)
        ys = jnp.asarray(rng.standard_normal((16, 4)), jnp.float32)

        def loss(w, batch):
            x, y = batch
            return jnp.mean((x @ w - y) ** 2)

        full_loss, full_grads = jax.value_and_grad(loss)(w, (xs, ys))
        for n_micro in (2, 4, 8):
            l, g = grad_accum(loss, w, (xs, ys), n_micro=n_micro)
            np.testing.assert_allclose(float(l), float(full_loss),
                                       rtol=1e-5)
            np.testing.assert_allclose(np.asarray(g), np.asarray(full_grads),
                                       rtol=1e-4, atol=1e-5)
        print('OK')
    """), n_devices=1)


def test_elastic_reshard_across_meshes():
    run_subprocess(textwrap.dedent("""
        import jax, numpy as np, jax.numpy as jnp
        from repro.compat import shard_map, set_mesh
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
        from repro.checkpoint import reshard

        devs = np.array(jax.devices())
        mesh_a = Mesh(devs.reshape(4, 4), ('data', 'model'))
        mesh_b = Mesh(devs[:12].reshape(3, 4), ('data', 'model'))
        x = jnp.arange(48.0).reshape(12, 4)
        xa = jax.device_put(x, NamedSharding(mesh_a, P('data', 'model')))
        xb = reshard({'x': xa},
                     {'x': NamedSharding(mesh_b, P('data', 'model'))})['x']
        np.testing.assert_allclose(np.asarray(xb), np.asarray(x))
        assert xb.sharding.mesh.shape['data'] == 3
        print('OK')
    """))


def test_moe_ep_shardmap_matches_reference():
    """The explicit message-passing EP MoE (§Perf iteration A.6) computes
    the same values and gradients as the single-device reference."""
    run_subprocess(textwrap.dedent("""
        import jax, numpy as np, jax.numpy as jnp
        from repro.compat import shard_map, set_mesh
        from jax.sharding import PartitionSpec as P
        from repro.models.config import ArchConfig
        from repro.models.moe import init_moe_params, moe_ffn, moe_ffn_ep

        cfg = ArchConfig(name='m', family='moe', n_layers=2, d_model=64,
                         n_heads=4, n_kv_heads=4, d_ff=128, vocab=61,
                         moe_experts=32, moe_topk=4)
        p = init_moe_params(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.standard_normal((4, 64, 64)), jnp.float32)
        mesh = jax.make_mesh((2, 8), ('data', 'model'))
        ep_spec = P(('data',), 'model', None, None)
        y_ref, _ = moe_ffn(x, p, cfg, capacity_factor=2.0)
        g_ref = jax.grad(lambda x: jnp.sum(
            moe_ffn(x, p, cfg, 2.0)[0] ** 2))(x)
        with set_mesh(mesh):
            y_ep, _ = jax.jit(lambda x, p: moe_ffn_ep(
                x, p, cfg, 2.0, ep_spec))(x, p)
            g_ep = jax.grad(lambda x: jnp.sum(
                moe_ffn_ep(x, p, cfg, 2.0, ep_spec)[0] ** 2))(x)
        np.testing.assert_allclose(np.asarray(y_ep), np.asarray(y_ref),
                                   rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(np.asarray(g_ep), np.asarray(g_ref),
                                   rtol=2e-3, atol=2e-3)
        print('OK')
    """))


def test_distributed_gcn_matches_reference():
    """The paper end-to-end on 16 devices: local combination + hypercube
    aggregation + Weight-Bank grad sync == single-device GCN math."""
    run_subprocess(textwrap.dedent("""
        import jax, numpy as np, jax.numpy as jnp
        from repro.compat import shard_map, set_mesh
        from repro.graph import NeighborSampler, make_dataset
        from repro.distributed.gcn_train import init_params
        from repro.engine import Engine, EngineConfig
        from repro.models.gcn_model import GCNConfig, gcn_loss

        ds = make_dataset('flickr', scale=0.005, feat_dim=32)
        sampler = NeighborSampler(ds.graph, fanouts=(5, 5),
                                  pad_multiple=16, seed=0)
        rng = np.random.default_rng(0)
        seeds = rng.permutation(ds.graph.n_nodes)[:32]
        mb = sampler.sample(seeds, rng=np.random.default_rng(1))
        feats = ds.features[np.minimum(mb.input_nodes,
                                       ds.graph.n_nodes - 1)]
        pad = mb.layers[0].n_dst - len(seeds)
        labels = ds.labels[np.pad(seeds, (0, pad))] % 7

        mesh = jax.make_mesh((16,), ('model',))
        bundle = Engine(EngineConfig.from_spec('coo+serial',
                                               lr=0.3)).build(mesh)
        batch = bundle.shard_batch(mb, feats, labels)
        params = init_params(jax.random.PRNGKey(0), [(32, 16), (16, 7)])
        with set_mesh(mesh):
            p1, first = bundle.train_step(params, batch)
            for _ in range(25):
                p1, loss = bundle.train_step(p1, batch)
        assert float(loss) < float(first)

        cfg = GCNConfig(name='t', feat_dim=32, hidden=16, n_classes=7)
        ref_params = {'layers': [{'w': p['w']} for p in params]}
        ref = gcn_loss(ref_params, mb.layers, jnp.asarray(feats),
                       jnp.asarray(labels), cfg, ('coag', 'coag'))
        np.testing.assert_allclose(float(first), float(ref),
                                   rtol=1e-4, atol=1e-5)
        print('OK', float(first), float(loss))
    """))
