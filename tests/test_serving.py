"""The online inference service: queue/coalescer, incremental aggregation.

Contracts:
  * the request queue is deque-backed FIFO — a micro-batch is always a
    contiguous arrival-order prefix; deadlines accelerate flushing (head
    deadline within slack closes the batch early) but never reorder;
    duplicate nodes coalesce into one computed row with logits scattered
    back to every request;
  * the embedding cache is an LRU with explicit invalidation — entries
    stay servable until an update's frontier walk drops them, the version
    counter only *accounts* for staleness (stale_hits /
    max_staleness_served), and eviction under capacity pressure is
    counted, never silent;
  * the invalidation frontier walk is exact: an edge update dirties its
    dst row at layer 1 and one out-neighbor ring per deeper layer; a
    feature update at ``u`` dirties ``{u} ∪ out(u)`` at layer 1 —
    hand-checked on a small graph, and property-checked on a seeded
    random stream of mixed edge/feature updates where the incremental
    path must stay BIT-equal to a cold full recompute (for the per-row
    deterministic ``coo`` and ``ell`` formats; ``block``'s cross-row
    tiling breaks per-row determinism, so incremental reuse must
    auto-disable there rather than serve almost-right logits);
  * checkpoint loading needs only the directory — the manifest's leaf
    paths rebuild the ``like`` tree.
"""
import numpy as np
import pytest

from repro.graph import make_dataset
from repro.serving import (DynamicGraph, EmbeddingCache, InferenceEngine,
                           InferenceRequest, InferenceService, RequestQueue,
                           load_checkpoint_params, poisson_trace, summarize)


def _req(node, t, deadline=None):
    return InferenceRequest(node=node, t_arrival=t, deadline=deadline)


def _flickr_engine(spec="coo+serial", *, scale=0.004, feat=8, hidden=8,
                   n_classes=5, seed=0, **kw):
    """A small InferenceEngine over flickr with random (untrained) weights
    — correctness properties don't care whether the weights learned."""
    ds = make_dataset("flickr", scale=scale, feat_dim=feat)
    rng = np.random.default_rng(seed)
    params = [
        {"w": (rng.standard_normal((feat, hidden)) * 0.2).astype(np.float32)},
        {"w": (rng.standard_normal((hidden, n_classes)) * 0.2)
         .astype(np.float32)},
    ]
    return InferenceEngine(spec, ds.graph, ds.features, params=params, **kw)


# ---------------------------------------------------------------------------
# Request queue: deque admission, FIFO + deadline contract, coalescing.
# ---------------------------------------------------------------------------
def test_queue_is_deque_backed_fifo_prefix():
    from collections import deque

    q = RequestQueue(max_batch=2, max_wait=0.01)
    assert isinstance(q._q, deque)      # O(1) popleft, not list.pop(0)
    r = [q.submit(_req(n, 0.0)) for n in (7, 3, 9)]
    # size flush fires immediately at max_batch; the batch is the
    # arrival-order prefix, NOT sorted by node id
    assert q.ready(0.0)
    b = q.next_batch(0.0)
    assert [x.rid for x in b.requests] == [r[0].rid, r[1].rid]
    assert list(b.nodes) == [3, 7]      # nodes ARE sorted (engine order)
    assert q.flush_reasons["size"] == 1
    # the leftover request waits out max_wait, then age-flushes
    assert not q.ready(0.005)
    assert q.next_batch(0.005) is None
    assert q.ready(0.011)
    b = q.next_batch(0.011)
    assert [x.rid for x in b.requests] == [r[2].rid]
    assert q.flush_reasons["age"] == 1


def test_queue_deadline_accelerates_but_never_reorders():
    q = RequestQueue(max_batch=8, max_wait=1.0, deadline_slack=0.01)
    first = q.submit(_req(1, 0.0, deadline=0.05))
    second = q.submit(_req(2, 0.001))
    # head deadline within slack closes the batch long before max_wait …
    assert not q.ready(0.02)
    assert q.ready(0.045)
    b = q.next_batch(0.045)
    assert q.flush_reasons["deadline"] == 1
    # … and the batch is still the FIFO prefix, in arrival order
    assert [x.rid for x in b.requests] == [first.rid, second.rid]


def test_queue_coalesces_duplicates():
    q = RequestQueue(max_batch=5, max_wait=1.0)
    for n in (5, 3, 5, 3, 5):
        q.submit(_req(n, 0.0))
    b = q.next_batch(0.0)
    assert list(b.nodes) == [3, 5]
    assert b.coalesce_factor == 2.5
    assert q.coalesce_factor == 2.5     # cumulative mirror
    assert q.stats()["served_unique"] == 2


def test_queue_next_wakeup_and_forced_drain():
    q = RequestQueue(max_batch=8, max_wait=0.5, deadline_slack=0.01)
    assert q.next_wakeup(0.0) is None
    q.submit(_req(1, 0.0, deadline=0.1))
    # the earlier of (head age flush, head deadline flush)
    assert q.next_wakeup(0.0) == pytest.approx(0.09)
    # no flush condition holds, but force drains the shutdown tail
    assert q.next_batch(0.0) is None
    b = q.next_batch(0.0, force=True)
    assert len(b.requests) == 1
    assert q.flush_reasons["drain"] == 1
    assert len(q) == 0


# ---------------------------------------------------------------------------
# Embedding cache: LRU eviction, explicit invalidation, staleness stamps.
# ---------------------------------------------------------------------------
def test_cache_lru_eviction_accounting():
    c = EmbeddingCache(capacity=3)
    for v in range(3):
        c.put(1, v, np.full(4, v, np.float32))
    assert c.get(1, 0) is not None      # refresh 0's recency
    c.put(1, 3, np.zeros(4, np.float32))
    # vertex 1 was least-recently used and is the one evicted
    assert (1, 1) not in c
    assert (1, 0) in c and (1, 2) in c and (1, 3) in c
    assert c.evictions == 1
    assert c.insertions == 4
    assert len(c) == 3
    s = c.stats()
    assert s["evictions"] == 1 and s["entries"] == 3


def test_cache_staleness_versioning():
    c = EmbeddingCache(capacity=8)
    c.put(1, 0, np.zeros(4, np.float32))
    c.bump_version()
    c.bump_version()
    # the entry is STILL valid (nothing invalidated it); the hit is merely
    # accounted as stale by 2 update batches
    assert c.get(1, 0) is not None
    assert c.stale_hits == 1
    assert c.max_staleness_served == 2
    # a fresh insert is stamped with the current version: hitting it adds
    # no staleness
    c.put(1, 1, np.zeros(4, np.float32))
    assert c.get(1, 1) is not None
    assert c.stale_hits == 1
    assert c.stats()["version"] == 2


def test_cache_invalidate_counts_real_drops_only():
    c = EmbeddingCache(capacity=8)
    c.put(1, 0, np.zeros(4, np.float32))
    c.put(1, 1, np.zeros(4, np.float32))
    c.put(2, 0, np.zeros(4, np.float32))
    # vertices 1 and 99 at layer 1: only vertex 1 actually existed
    assert c.invalidate(1, [1, 99]) == 1
    assert c.invalidations == 1
    assert (1, 1) not in c
    assert (1, 0) in c and (2, 0) in c  # other layer/vertex untouched


# ---------------------------------------------------------------------------
# Dynamic graph: sorted adjacency, dirty sets, frontier expansion.
# ---------------------------------------------------------------------------
def test_dynamic_graph_updates_and_dirty_sets():
    g = DynamicGraph(n_nodes=5)
    dirty = g.update_edges(add=[(0, 1), (2, 1), (1, 3)])
    assert dirty == {1, 3}              # dst rows only — mean weights are
    assert list(g.in_neighbors(1)) == [0, 2]        # row-local
    assert list(g.agg_set(1)) == [0, 1, 2]          # ∪ {self}, sorted
    assert list(g.agg_set(4)) == [4]                # isolated: just self
    assert g.out_neighbors(1) == {3}
    assert g.expand_out({1}) == {1, 3}
    # idempotence: re-adding and removing-missing are counted no-ops
    assert g.update_edges(add=[(0, 1)]) == set()
    assert g.update_edges(remove=[(0, 4)]) == set()
    assert g.noop_updates == 2
    assert g.update_edges(remove=[(2, 1)]) == {1}
    assert list(g.in_neighbors(1)) == [0]
    assert g.edges_added == 3 and g.edges_removed == 1


def test_dynamic_graph_matches_csr_construction():
    ds = make_dataset("flickr", scale=0.004, feat_dim=8)
    g = DynamicGraph(ds.graph)
    indptr = np.asarray(ds.graph.indptr)
    indices = np.asarray(ds.graph.indices)
    # CSR is src-major: out-lists match, in-lists are the transpose
    for s in (0, 1, g.n_nodes // 2, g.n_nodes - 1):
        assert g.out_neighbors(s) == set(
            int(t) for t in indices[indptr[s]:indptr[s + 1]])
    v = int(indices[0])
    srcs = {s for s in range(g.n_nodes)
            if v in indices[indptr[s]:indptr[s + 1]]}
    assert set(g.in_neighbors(v)) == srcs


# ---------------------------------------------------------------------------
# Invalidation frontier walk — hand-checked on a 3-layer engine.
# ---------------------------------------------------------------------------
def test_invalidation_frontier_hand_checked():
    g = DynamicGraph(n_nodes=6)
    g.update_edges(add=[(0, 1), (1, 2), (2, 3), (4, 5)])
    rng = np.random.default_rng(0)
    params = [{"w": rng.standard_normal((4, 4)).astype(np.float32)},
              {"w": rng.standard_normal((4, 4)).astype(np.float32)},
              {"w": rng.standard_normal((4, 3)).astype(np.float32)}]
    feats = rng.standard_normal((6, 4)).astype(np.float32)
    eng = InferenceEngine("coo+serial", g, feats, params=params)
    assert eng.incremental_supported
    eng.query(np.arange(6))             # warm every (layer, vertex) entry
    for layer in (1, 2):
        for v in range(6):
            assert (layer, v) in eng.cache
    v0 = eng.cache.version

    # edge add (5 → 0): layer 1 dirties exactly dst {0}; layer 2 dirties
    # one out-ring of it, {0} ∪ out(0) = {0, 1}.  Everything else keeps
    # serving from history.
    eng.update_edges(add=[(5, 0)])
    assert (1, 0) not in eng.cache
    assert (2, 0) not in eng.cache and (2, 1) not in eng.cache
    for v in range(1, 6):
        assert (1, v) in eng.cache
    for v in (2, 3, 4, 5):
        assert (2, v) in eng.cache
    assert eng.cache.version == v0 + 1

    # feature update at 1: layer 1 dirties {1} ∪ out(1) = {1, 2}; layer 2
    # one further ring, {1, 2, 3}
    eng.query(np.arange(6))             # re-warm the dropped entries
    eng.update_features([1], feats[1] + 1.0)
    for v in (1, 2):
        assert (1, v) not in eng.cache
    for v in (0, 3, 4, 5):
        assert (1, v) in eng.cache
    for v in (1, 2, 3):
        assert (2, v) not in eng.cache
    for v in (0, 4, 5):
        assert (2, v) in eng.cache
    assert eng.cache.version == v0 + 2


# ---------------------------------------------------------------------------
# The bit-match property: incremental == cold under a mixed update stream.
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("spec", ["coo+serial", "ell+pipelined"])
def test_incremental_bit_matches_cold_random_stream(spec):
    """Seeded-random property check (the container has no hypothesis):
    after any prefix of mixed edge/feature updates, a cached query must be
    BIT-equal to the same query with the cache bypassed."""
    eng = _flickr_engine(spec)
    n = eng.graph.n_nodes
    rng = np.random.default_rng(7)
    eng.query(rng.integers(0, n, 16))   # warm the cache first
    for rnd in range(9):
        op = rnd % 3
        if op == 0:
            eng.update_edges(add=[(int(rng.integers(n)),
                                   int(rng.integers(n)))
                                  for _ in range(3)])
        elif op == 1:
            v = int(rng.integers(n))
            nbrs = eng.graph.in_neighbors(v)
            if len(nbrs):
                eng.update_edges(remove=[(int(nbrs[0]), v)])
        else:
            nodes = rng.integers(0, n, 2)
            eng.update_features(
                nodes, rng.standard_normal((2, eng.feat_dim))
                .astype(np.float32))
        q = rng.integers(0, n, 8)
        inc = eng.query(q, use_cache=True)
        cold = eng.query(q, use_cache=False)
        assert np.array_equal(inc, cold), f"round {rnd} diverged"
    # the property must not hold vacuously: history was actually reused
    # and updates actually invalidated entries
    assert eng.rows_from_cache > 0
    assert eng.cache.invalidations > 0
    assert eng.cache.stale_hits > 0


def test_bit_match_survives_eviction_pressure():
    """A tiny cache evicts constantly; correctness must not depend on
    capacity (evicted == recomputed, never wrong)."""
    eng = _flickr_engine(cache_capacity=8)
    n = eng.graph.n_nodes
    rng = np.random.default_rng(3)
    for _ in range(6):
        q = rng.integers(0, n, 8)
        assert np.array_equal(eng.query(q, use_cache=True),
                              eng.query(q, use_cache=False))
    assert eng.cache.evictions > 0
    assert len(eng.cache) <= 8


def test_block_format_disables_incremental_reuse():
    """block's cross-row tiling is not per-row bit-deterministic across
    batch compositions: the cache must hard-disable, not serve drift."""
    eng = _flickr_engine("block+pipelined", pad_multiple=8)
    assert not eng.incremental_supported
    n = eng.graph.n_nodes
    rng = np.random.default_rng(1)
    for _ in range(3):
        q = rng.integers(0, n, 8)
        # use_cache=True silently degrades to the cold path
        assert np.array_equal(eng.query(q, use_cache=True),
                              eng.query(q, use_cache=False))
    assert eng.rows_from_cache == 0
    assert len(eng.cache) == 0
    assert eng.stats()["incremental_supported"] is False


# ---------------------------------------------------------------------------
# Checkpoint loading: the manifest alone rebuilds the weight stack.
# ---------------------------------------------------------------------------
def test_checkpoint_load_roundtrip(tmp_path):
    from repro.checkpoint import CheckpointManager

    rng = np.random.default_rng(2)
    params = [{"w": rng.standard_normal((8, 8)).astype(np.float32)},
              {"w": rng.standard_normal((8, 5)).astype(np.float32)}]
    CheckpointManager(str(tmp_path)).save(7, params)
    loaded = load_checkpoint_params(str(tmp_path))
    assert len(loaded) == 2
    for got, want in zip(loaded, params):
        np.testing.assert_array_equal(np.asarray(got["w"]), want["w"])
    # and the InferenceEngine restores through the same door
    ds = make_dataset("flickr", scale=0.004, feat_dim=8)
    eng = InferenceEngine("coo+serial", ds.graph, ds.features,
                          ckpt_dir=str(tmp_path))
    out = eng.query([0, 1, 2])
    assert out.shape == (3, 5)
    with pytest.raises(FileNotFoundError):
        load_checkpoint_params(str(tmp_path / "empty"))


# ---------------------------------------------------------------------------
# The service loop: coalesce, scatter, open-loop replay.
# ---------------------------------------------------------------------------
def test_service_coalesces_and_scatters_back():
    eng = _flickr_engine()
    svc = InferenceService(eng, max_batch=8, max_wait=0.01)
    nodes = [4, 9, 4, 9, 4, 9, 4, 9]    # 8 requests, 2 unique vertices
    reqs = [svc.submit(n, now=0.0) for n in nodes]
    assert svc.step(now=0.001) == 8     # size flush served the lot
    assert svc.queue.coalesce_factor == 4.0
    for r in reqs:
        assert r.result is not None and r.latency is not None
        # every coalesced copy got the SAME row the engine computes for
        # that vertex alone (per-row determinism)
        np.testing.assert_array_equal(
            r.result, eng.query([r.node], use_cache=False)[0])
    assert svc.served == 8
    assert svc.stats()["queue"]["flush_size"] == 1


def test_service_replay_open_loop():
    eng = _flickr_engine()
    n = eng.graph.n_nodes
    # warm the shape buckets off-clock so replay measures serving, not jit
    eng.query(np.arange(min(16, n)))
    trace = poisson_trace(rate=100.0, duration=0.25, n_nodes=n, seed=4)
    svc = InferenceService(eng, max_batch=8, max_wait=0.004)
    out = svc.replay(trace, slo=0.5)
    assert out["completed"] == len(trace) == len(svc.latencies_s)
    assert out["coalesce_factor"] >= 1.0
    assert out["throughput_at_slo"] > 0
    assert np.isfinite(out["p50_ms"]) and np.isfinite(out["p99_ms"])
    assert out["p50_ms"] <= out["p99_ms"]


def test_loadgen_trace_and_summary_pinned():
    trace = poisson_trace(rate=200.0, duration=0.5, n_nodes=50, seed=0)
    assert len(trace) > 0
    ts = [a.t for a in trace]
    assert ts == sorted(ts) and ts[-1] < 0.5
    assert all(0 <= a.node < 50 for a in trace)
    # same seed → same trace (replayable benchmarks)
    again = poisson_trace(rate=200.0, duration=0.5, n_nodes=50, seed=0)
    assert trace == again
    s = summarize([0.01, 0.02, 0.03, 0.2], slo_s=0.05, wall_s=2.0)
    assert s["completed"] == 4
    assert s["within_slo"] == 3
    assert s["throughput_at_slo"] == pytest.approx(1.5)
    assert s["p50_ms"] == pytest.approx(25.0)
