"""Cross-row redundancy elimination + communication-minimizing partitioning.

Covers the two new planner-visible axes end to end:

  * ``merge="redundancy"`` — GraphACT-style (arXiv:2001.02498 §3) pair
    mining into virtual vertices: exact-count oracles on structured
    graphs, dense reconstruction of the rewritten plan, single-device
    forward/backward parity through the custom_vjp, and the full
    multi-device spec sweep on a bit-matching stream.
  * ``partition="mincom"`` — communication-minimizing label propagation:
    capacity balance, measured cut reduction on planted communities, the
    permutation-chain contract (space 0 identity), and the cost-model
    ranking pin (:func:`repro.engine.planner.rank_partitions`).

Property-based versions run only when ``hypothesis`` is installed
(``pip install -e .[test]``); the deterministic oracles always run.
"""
import textwrap

import numpy as np
import pytest

from conftest import run_subprocess


# ---------------------------------------------------------------------------
# Graph builders.
# ---------------------------------------------------------------------------
def _gcn_normalize(rows, cols, n_dst, n_src):
    """Symmetric GCN weights ``1/sqrt(d_dst * d_src)`` — the normalization
    that makes every structurally shared pair's weights proportional across
    rows (ratio ``sqrt(d_v/d_u)``), i.e. the weights real GCN layers feed
    the miner."""
    d_dst = np.bincount(rows, minlength=n_dst).astype(np.float64)
    d_src = np.bincount(cols, minlength=n_src).astype(np.float64)
    return (1.0 / np.sqrt(np.maximum(d_dst[rows] * d_src[cols], 1.0))
            ).astype(np.float32)


def _gcn_random_coo(n_dst, n_src, deg, seed=0):
    """Random graph with zipf-skewed columns + GCN normalization — skewed
    enough that pair mining always finds shared hub pairs."""
    from repro.graph.coo import from_edges

    rng = np.random.default_rng(seed)
    rows = np.repeat(np.arange(n_dst, dtype=np.int64), deg)
    w = 1.0 / np.arange(1.0, n_src + 1.0) ** 1.2
    cols = rng.permutation(n_src)[rng.choice(n_src, rows.size, p=w / w.sum())]
    keep = np.unique(rows * n_src + cols)
    rows, cols = keep // n_src, keep % n_src
    vals = _gcn_normalize(rows, cols, n_dst, n_src)
    return from_edges(rows, cols, vals, n_dst, n_src)


def _dense_from_pairmerge(mine):
    """Rewritten edges + virtual tier → the dense matrix they encode."""
    a = np.zeros((mine.n_rows, mine.n_cols), np.float64)
    for r, c, v in zip(mine.rows, mine.cols, mine.vals):
        if c < mine.n_cols:
            a[r, c] += v
        else:
            z = c - mine.n_cols
            (u, w), (alpha, beta) = mine.vv_src[z], mine.vv_coef[z]
            a[r, u] += v * alpha
            a[r, w] += v * beta
    return a


def _dense_from_coo(coo):
    a = np.zeros((coo.n_dst, coo.n_src), np.float64)
    np.add.at(a, (np.asarray(coo.rows), np.asarray(coo.cols)),
              np.asarray(coo.vals, np.float64))
    return a


# ---------------------------------------------------------------------------
# mine_pair_redundancy: exact-count oracles + dense reconstruction.
# ---------------------------------------------------------------------------
def test_mining_exact_counts_planted_pairs():
    """k groups of m rows, each group sharing one distinct hub pair under
    GCN normalization → exactly k virtual vertices and k*m pair uses (the
    brute-force pair-frequency table has one k-row entry per group and
    nothing else reaching min_uses)."""
    from repro.kernels.edgeplan import mine_pair_redundancy

    k, m = 4, 5
    n_rows = k * m
    n_cols = 2 * k + n_rows
    rows_l, cols_l = [], []
    for g in range(k):
        for i in range(m):
            r = g * m + i
            rows_l += [r, r, r]
            # the group's hub pair (2g, 2g+1) + one private filler column
            cols_l += [2 * g, 2 * g + 1, 2 * k + r]
    rows = np.asarray(rows_l, np.int64)
    cols = np.asarray(cols_l, np.int64)
    vals = _gcn_normalize(rows, cols, n_rows, n_cols)
    mine = mine_pair_redundancy(rows, cols, vals, n_rows, n_cols)
    assert mine.n_virtual == k
    assert mine.stats["pair_uses"] == k * m
    # each use replaces 2 edges with 1 rewritten entry
    assert mine.stats["edges_after"] == mine.stats["edges_before"] - k * m
    assert mine.stats["pair_coverage"] == pytest.approx(
        2.0 * k * m / (3 * k * m))
    eb, ea = mine.stats["edges_before"], mine.stats["edges_after"]
    assert mine.stats["flop_reduction"] == pytest.approx(
        eb / (ea + 2 * mine.n_virtual))
    # the mined pairs are exactly the planted hubs
    assert sorted(map(tuple, mine.vv_src.tolist())) \
        == [(2 * g, 2 * g + 1) for g in range(k)]
    np.testing.assert_allclose(
        _dense_from_pairmerge(mine),
        _dense_from_coo(type("C", (), {
            "rows": rows, "cols": cols, "vals": vals,
            "n_dst": n_rows, "n_src": n_cols})),
        rtol=1e-6, atol=1e-7)


def test_mining_respects_min_uses_and_proportionality():
    """A pair shared by only one row never factors; a shared pair with
    NON-proportional weights never factors (the rewrite must stay exact)."""
    from repro.kernels.edgeplan import mine_pair_redundancy

    # two rows share (0, 1) but with weight pairs in different ratios
    rows = np.array([0, 0, 1, 1], np.int64)
    cols = np.array([0, 1, 0, 1], np.int64)
    vals = np.array([1.0, 2.0, 1.0, 5.0], np.float32)   # 1:2 vs 1:5
    mine = mine_pair_redundancy(rows, cols, vals, 2, 2)
    assert mine.n_virtual == 0
    assert mine.stats["edges_after"] == 4
    # same structure, proportional weights → exactly one virtual vertex
    vals = np.array([1.0, 2.0, 3.0, 6.0], np.float32)   # both 1:2
    mine = mine_pair_redundancy(rows, cols, vals, 2, 2)
    assert mine.n_virtual == 1
    assert mine.stats["pair_uses"] == 2
    np.testing.assert_allclose(
        _dense_from_pairmerge(mine),
        np.array([[1.0, 2.0], [3.0, 6.0]]), rtol=1e-6)


def test_mining_reconstruction_random_gcn_graph():
    """On a zipf/GCN random graph the mining finds virtual vertices and the
    rewritten plan reconstructs the original dense matrix exactly."""
    from repro.kernels.edgeplan import mine_pair_redundancy

    coo = _gcn_random_coo(96, 64, deg=10, seed=3)
    mine = mine_pair_redundancy(coo.rows, coo.cols, coo.vals,
                                coo.n_dst, coo.n_src)
    assert mine.n_virtual > 0
    assert 0.0 < mine.stats["pair_coverage"] <= 1.0
    assert mine.stats["flop_reduction"] > 1.0
    np.testing.assert_allclose(_dense_from_pairmerge(mine),
                               _dense_from_coo(coo), rtol=1e-5, atol=1e-6)


def test_merged_plan_matches_dense_fwd_and_grad():
    """build_plan(merge="redundancy") through the real kernels: forward and
    custom_vjp backward match the dense oracle ≤1e-5 (single device)."""
    import jax
    import jax.numpy as jnp
    from repro.kernels import edgeplan
    from repro.kernels.ops import ell_aggregate

    coo = _gcn_random_coo(96, 64, deg=10, seed=5)
    plan = edgeplan.build_plan(coo, merge="redundancy")
    assert plan.n_virtual > 0
    assert plan.flop_reduction > 1.0
    tables = plan.device_tables()
    assert "vv_cols" in tables
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((coo.n_src, 16)), jnp.float32)
    dense = jnp.asarray(_dense_from_coo(coo), jnp.float32)
    y = ell_aggregate(tables, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(dense @ x),
                               rtol=1e-5, atol=1e-5)
    g = jax.grad(lambda xx: jnp.sum(ell_aggregate(tables, xx) ** 2))(x)
    g_ref = jax.grad(lambda xx: jnp.sum((dense @ xx) ** 2))(x)
    np.testing.assert_allclose(np.asarray(g), np.asarray(g_ref),
                               rtol=1e-4, atol=1e-4)
    # dedup plan of the same graph: identical output, no virtual tier
    base = edgeplan.build_plan(coo, merge="dedup")
    assert base.n_virtual == 0
    y0 = ell_aggregate(base.device_tables(), x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y0),
                               rtol=1e-5, atol=1e-6)


def test_merge_and_partition_validation():
    from repro.engine import EngineConfig
    from repro.graph.partition import validate_partition
    from repro.kernels.edgeplan import validate_merge

    with pytest.raises(ValueError, match="merge"):
        validate_merge("bogus")
    with pytest.raises(ValueError, match="partition"):
        validate_partition("metis")
    with pytest.raises(ValueError):
        EngineConfig(format="ell", merge="bogus")
    with pytest.raises(ValueError):
        EngineConfig(format="ell", partition="bogus")


def test_partition_spec_roundtrip():
    from repro.engine import EngineConfig

    cfg = EngineConfig.from_spec("ell+pipelined+hypercube+mincom")
    assert cfg.partition == "mincom"
    # non-default partition always spells the topology (parts stay
    # positional)
    assert cfg.spec == "ell+pipelined+hypercube+mincom"
    assert EngineConfig.from_spec(cfg.spec) == cfg
    # default partition stays invisible: legacy specs round-trip unchanged
    assert EngineConfig.from_spec("ell+pipelined").partition == "naive"
    assert EngineConfig.from_spec("ell+pipelined").spec == "ell+pipelined"
    assert EngineConfig.from_spec("ell+pipelined+ring").spec \
        == "ell+pipelined+ring"
    # with_spec carries partition AND merge onto the new spec
    cfg = EngineConfig.from_spec("ell+pipelined+hypercube+mincom",
                                 merge="redundancy", lr=0.3)
    re = cfg.with_spec("block+pipelined")
    assert (re.partition, re.merge, re.lr) == ("mincom", "redundancy", 0.3)
    assert re.spec == "block+pipelined+hypercube+mincom"


# ---------------------------------------------------------------------------
# mincom partitioning: balance, cut, permutation-chain contract.
# ---------------------------------------------------------------------------
def _planted_community_coo(n, n_cores, deg=8, p_in=0.9, seed=0):
    """Square graph with SHUFFLED planted communities: naive contiguous
    striping cuts ~uniform cross traffic, the planted structure is
    recoverable."""
    from repro.graph.coo import from_edges

    rng = np.random.default_rng(seed)
    comm = rng.permutation(np.arange(n) % n_cores)
    rows = np.repeat(np.arange(n, dtype=np.int64), deg)
    cols = np.empty(rows.size, np.int64)
    for c in range(n_cores):
        pool = np.flatnonzero(comm == c)
        m = (comm[rows] == c)
        cols[m] = pool[rng.integers(0, pool.size, int(m.sum()))]
    cross = rng.random(rows.size) < (1.0 - p_in)
    cols[cross] = rng.integers(0, n, int(cross.sum()))
    return from_edges(rows, cols, np.ones(rows.size, np.float32), n, n)


def test_mincom_assignment_balanced_and_cuts_planted_graph():
    from repro.graph.partition import exchange_rows, mincom_assignment

    n, n_cores = 256, 4
    coo = _planted_community_coo(n, n_cores)
    rows = np.asarray(coo.rows, np.int64)
    cols = np.asarray(coo.cols, np.int64)
    assign = mincom_assignment(rows, cols, n, n_cores)
    # capacity contract: every core gets exactly n/P nodes (the striped
    # shard shapes downstream formats rely on)
    np.testing.assert_array_equal(np.bincount(assign, minlength=n_cores),
                                  np.full(n_cores, n // n_cores))
    from repro.graph.partition import partition_permutation
    perm = partition_permutation(assign, n_cores)
    # perm is a permutation that sends each node into its core's stripe
    assert np.array_equal(np.sort(perm), np.arange(n))
    wr_naive = exchange_rows(rows, cols, coo.vals, n, n, n_cores)
    wr_mincom = exchange_rows(perm[rows], perm[cols], coo.vals, n, n,
                              n_cores)
    # the planted communities are recoverable: the cut drops hard
    assert wr_mincom < 0.5 * wr_naive, (wr_naive, wr_mincom)


def test_mincom_layer_perms_chain_contract():
    """perms[0] is the identity (labels/logits/batch order never move);
    every perm is a true permutation; the relabeled chain's summed wire
    rows drop vs naive on a planted 2-layer stream."""
    from repro.graph.coo import from_edges
    from repro.graph.partition import exchange_rows, mincom_layer_perms

    n_cores, batch, mid, frontier, deg = 4, 64, 128, 256, 6
    rng = np.random.default_rng(1)
    comm = [np.minimum(np.arange(batch) // (batch // n_cores), n_cores - 1),
            rng.permutation(np.arange(mid) % n_cores),
            rng.permutation(np.arange(frontier) % n_cores)]

    def layer(n_dst, n_src, cd, cs):
        rows = np.repeat(np.arange(n_dst, dtype=np.int64), deg)
        cols = np.empty(rows.size, np.int64)
        for c in range(n_cores):
            pool = np.flatnonzero(cs == c)
            m = cd[rows] == c
            cols[m] = pool[rng.integers(0, pool.size, int(m.sum()))]
        return from_edges(rows, cols, np.ones(rows.size, np.float32),
                          n_dst, n_src)

    layers = [layer(batch, mid, comm[0], comm[1]),
              layer(mid, frontier, comm[1], comm[2])]
    perms = mincom_layer_perms(layers, n_cores)
    assert len(perms) == len(layers) + 1
    np.testing.assert_array_equal(perms[0], np.arange(batch))
    for p, n in zip(perms, (batch, mid, frontier)):
        assert np.array_equal(np.sort(p), np.arange(n))

    def total_wire(ls):
        return sum(exchange_rows(l.rows, l.cols, l.vals, l.n_dst, l.n_src,
                                 n_cores) for l in ls)

    relab = [from_edges(perms[i][np.asarray(l.rows, np.int64)],
                        perms[i + 1][np.asarray(l.cols, np.int64)],
                        np.asarray(l.vals, np.float32), l.n_dst, l.n_src)
             for i, l in enumerate(layers)]
    assert total_wire(relab) < total_wire(layers)


def test_exchange_rows_counts_distinct_crossing_pairs():
    """Hand-checked: wire content = distinct (dst row, source core) pairs
    crossing cores — the post-Block-Message merge accounting."""
    from repro.graph.coo import from_edges
    from repro.graph.partition import exchange_rows

    # P=2 over 4 nodes (cores own {0,1} and {2,3})
    rows = np.array([0, 0, 0, 2, 3, 1], np.int64)
    cols = np.array([2, 3, 1, 0, 3, 0], np.int64)
    vals = np.ones(6, np.float32)
    coo = from_edges(rows, cols, vals, 4, 4)
    # crossing edges: (0,2) (0,3) → one merged message (row 0 from core 1);
    # (2,0) → one; row 3's (3,3) and row 1's (1,0) stay local
    assert exchange_rows(coo.rows, coo.cols, coo.vals, 4, 4, 2) == 2
    # zero-weight edges don't ship
    vals2 = vals.copy()
    vals2[np.flatnonzero((rows == 2) & (cols == 0))] = 0.0
    assert exchange_rows(rows, cols, vals2, 4, 4, 2) == 1


def test_rank_partitions_prefers_measured_lower_bytes():
    """The cost-model pin: with a byte-sensitive model, mincom ranks first
    exactly when its measured wire bytes are lower; ties prefer naive."""
    from repro.engine.planner import CostModel, rank_partitions

    model = CostModel(alpha=0.0, beta=1e-7, const=1e-4, n_cores=4, d=32)
    coo = _planted_community_coo(256, 4)
    ranked = rank_partitions(model, coo, 4, topology="hypercube", d=32)
    assert [r[0] for r in ranked] == ["mincom", "naive"]
    bytes_by_name = {r[0]: r[2] for r in ranked}
    assert bytes_by_name["mincom"] < bytes_by_name["naive"]
    assert ranked[0][1] < ranked[1][1]
    # a bipartite (sampled-layer) graph: mincom's square relabeling does
    # not apply → identical bytes → the tie goes to naive
    bip = _gcn_random_coo(64, 128, deg=6, seed=7)
    ranked = rank_partitions(model, bip, 4, topology="hypercube", d=32)
    assert ranked[0][0] == "naive"
    assert ranked[0][2] == ranked[1][2]


# ---------------------------------------------------------------------------
# Multi-device: every spec × both partitions × merge="redundancy" on one
# bit-matching stream vs the coo+serial oracle.
# ---------------------------------------------------------------------------
_SWEEP = """
    import jax, numpy as np, jax.numpy as jnp
    from repro.distributed.gcn_train import init_params
    from repro.engine import Engine, EngineConfig, supported_specs
    from repro.graph.coo import from_edges

    PC = {n_devices}
    n_cores = PC
    batch, mid, frontier, feat = 16 * PC, 32 * PC, 64 * PC, 12
    deg = 6
    rng = np.random.default_rng(0)
    comm = [np.minimum(np.arange(batch) // (batch // n_cores), n_cores - 1),
            rng.permutation(np.arange(mid) % n_cores),
            rng.permutation(np.arange(frontier) % n_cores)]

    def layer(n_dst, n_src, cd, cs):
        rows = np.repeat(np.arange(n_dst, dtype=np.int64), deg)
        cols = np.empty(rows.size, np.int64)
        for c in range(n_cores):
            pool = rng.permutation(np.flatnonzero(cs == c))
            m = cd[rows] == c
            w = 1.0 / np.arange(1.0, pool.size + 1.0) ** 1.2
            cols[m] = pool[rng.choice(pool.size, int(m.sum()),
                                      p=w / w.sum())]
        keep = np.unique(rows * n_src + cols)
        rows, cols = keep // n_src, keep % n_src
        dd = np.bincount(rows, minlength=n_dst).astype(np.float64)
        ds = np.bincount(cols, minlength=n_src).astype(np.float64)
        vals = (1.0 / np.sqrt(np.maximum(dd[rows] * ds[cols], 1.0))
                ).astype(np.float32)
        return from_edges(rows, cols, vals, n_dst, n_src)

    class _MB:
        layers = [layer(batch, mid, comm[0], comm[1]),
                  layer(mid, frontier, comm[1], comm[2])]

    feats = rng.standard_normal((frontier, feat)).astype(np.float32)
    labels = rng.integers(0, 4, batch).astype(np.int32)
    params0 = init_params(jax.random.PRNGKey(0), [(feat, 8), (8, 4)])
    mesh = jax.make_mesh((PC,), ('model',))

    def trajectory(cfg):
        bundle = Engine(cfg).build(mesh)
        bb = bundle.shard_batch(_MB(), feats, labels)
        p, traj = params0, []
        for _ in range(5):
            p, loss = bundle.train_step(p, bb)
            traj.append(float(loss))
        return traj, bb

    ref, _ = trajectory(EngineConfig.from_spec('coo+serial', lr=0.3))
    n_ran = 0
    reports = {{}}
    for spec in supported_specs(three_part=True):
        for partition in ('naive', 'mincom'):
            cfg = EngineConfig.from_spec(spec, lr=0.3, partition=partition,
                                         merge='redundancy')
            try:
                Engine(cfg).build(mesh)
            except ValueError:
                continue          # topology rejects this core count
            traj, bb = trajectory(cfg)
            for i, (a, b) in enumerate(zip(ref, traj)):
                assert abs(a - b) <= 1e-5, (cfg.spec, i, a, b)
            reports[(spec, partition)] = bb['report']
            n_ran += 1
    assert n_ran >= 12, n_ran
    # the redundancy tier actually engaged on the ELL specs...
    ell = [r for (s, _), r in reports.items() if s.startswith('ell')]
    assert ell and all(r['virtual_vertices'] > 0 for r in ell)
    assert all(r['flop_reduction'] > 1.0 for r in ell)
    # ...and mincom measurably cut the wire bytes vs naive, per spec
    for spec in set(s for s, _ in reports):
        wb_n = reports[(spec, 'naive')]['wire_bytes']
        wb_m = reports[(spec, 'mincom')]['wire_bytes']
        assert wb_m < wb_n, (spec, wb_n, wb_m)
    print('OK', n_ran, 'spec x partition combos')
"""


@pytest.mark.parametrize("n_devices", [2, 4])
def test_redundancy_mincom_spec_sweep_matches_oracle(n_devices):
    run_subprocess(textwrap.dedent(_SWEEP.format(n_devices=n_devices)),
                   n_devices=n_devices)


# ---------------------------------------------------------------------------
# Property-based (hypothesis-gated): the rewrite is exact on ARBITRARY
# graphs — GCN-normalized or adversarially weighted.
# ---------------------------------------------------------------------------
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:        # the deterministic oracles above still run
    HAVE_HYPOTHESIS = False

    class _Stub:           # no-op decorators/strategies so defs parse
        def __call__(self, *a, **kw):
            return lambda f: f

        def __getattr__(self, name):
            return lambda *a, **kw: None

    given = settings = st = _Stub()

needs_hypothesis = pytest.mark.skipif(
    not HAVE_HYPOTHESIS,
    reason="property tests need hypothesis (pip install -e .[test])")


@needs_hypothesis
@settings(max_examples=25, deadline=None)
@given(n_dst=st.integers(4, 48), n_src=st.integers(4, 48),
       deg=st.integers(1, 8), seed=st.integers(0, 10_000),
       gcn=st.booleans())
def test_property_merged_plan_reconstructs_any_graph(n_dst, n_src, deg,
                                                     seed, gcn):
    from repro.graph.coo import from_edges
    from repro.kernels.edgeplan import mine_pair_redundancy

    rng = np.random.default_rng(seed)
    rows = np.repeat(np.arange(n_dst, dtype=np.int64), deg)
    cols = rng.integers(0, n_src, rows.size)
    keep = np.unique(rows * n_src + cols)
    rows, cols = keep // n_src, keep % n_src
    if gcn:
        vals = _gcn_normalize(rows, cols, n_dst, n_src)
    else:
        vals = rng.standard_normal(rows.size).astype(np.float32)
    coo = from_edges(rows, cols, vals, n_dst, n_src)
    mine = mine_pair_redundancy(coo.rows, coo.cols, coo.vals, n_dst, n_src)
    np.testing.assert_allclose(_dense_from_pairmerge(mine),
                               _dense_from_coo(coo), rtol=1e-5, atol=1e-6)
    # each pair use replaces two edges with one rewritten entry
    assert mine.stats["edges_after"] \
        == mine.stats["edges_before"] - mine.stats["pair_uses"]


@needs_hypothesis
@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000), deg=st.integers(2, 10))
def test_property_merged_kernel_matches_dense(seed, deg):
    import jax.numpy as jnp
    from repro.kernels import edgeplan
    from repro.kernels.ops import ell_aggregate

    coo = _gcn_random_coo(48, 32, deg=deg, seed=seed)
    plan = edgeplan.build_plan(coo, merge="redundancy")
    x = jnp.asarray(np.random.default_rng(seed).standard_normal(
        (coo.n_src, 8)), jnp.float32)
    y = np.asarray(ell_aggregate(plan.device_tables(), x))
    ref = _dense_from_coo(coo) @ np.asarray(x, np.float64)
    np.testing.assert_allclose(y, ref, rtol=1e-4, atol=1e-5)
