"""Hypothesis property tests over the graph substrate's invariants."""
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis",
                    reason="property tests need hypothesis "
                           "(pip install -e .[test])")
from hypothesis import given, settings, strategies as st

from repro.graph.coo import COO, from_edges, mean_normalize, pad_coo
from repro.graph.convert import sort_col_major, sort_row_major, to_backward
from repro.graph.sampler import NeighborSampler, csr_from_edges
from repro.models.moe import capacity


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 10_000), st.integers(1, 64), st.integers(1, 64),
       st.integers(0, 400))
def test_spmm_equals_dense(seed, n_dst, n_src, e):
    rng = np.random.default_rng(seed)
    coo = from_edges(rng.integers(0, n_dst, e), rng.integers(0, n_src, e),
                     rng.standard_normal(e).astype(np.float32), n_dst, n_src)
    x = jnp.asarray(rng.standard_normal((n_src, 3)), jnp.float32)
    np.testing.assert_allclose(np.asarray(coo.matmul(x)),
                               np.asarray(coo.todense() @ x),
                               rtol=1e-4, atol=1e-4)
    e_in = jnp.asarray(rng.standard_normal((n_dst, 3)), jnp.float32)
    np.testing.assert_allclose(np.asarray(coo.rmatmul(e_in)),
                               np.asarray(coo.todense().T @ e_in),
                               rtol=1e-4, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000))
def test_graph_converter_preserves_matrix(seed):
    """Row-major ⇄ column-major re-sorting never changes the matrix (the
    transpose-free contract's precondition)."""
    rng = np.random.default_rng(seed)
    coo = from_edges(rng.integers(0, 32, 100), rng.integers(0, 48, 100),
                     rng.standard_normal(100).astype(np.float32), 32, 48)
    for variant in (sort_row_major(coo), sort_col_major(coo),
                    to_backward(coo)):
        np.testing.assert_allclose(np.asarray(variant.todense()),
                                   np.asarray(coo.todense()),
                                   rtol=1e-5, atol=1e-6)
        assert variant.nnz == coo.nnz


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000), st.integers(1, 50))
def test_padding_is_noop(seed, pad):
    rng = np.random.default_rng(seed)
    coo = from_edges(rng.integers(0, 16, 60), rng.integers(0, 16, 60),
                     rng.standard_normal(60).astype(np.float32), 16, 16)
    padded = pad_coo(coo, coo.nnz + pad)
    x = jnp.asarray(rng.standard_normal((16, 4)), jnp.float32)
    np.testing.assert_allclose(np.asarray(padded.matmul(x)),
                               np.asarray(coo.matmul(x)),
                               rtol=1e-5, atol=1e-6)


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10_000), st.integers(1, 8), st.integers(1, 12))
def test_sampler_adjacency_invariants(seed, fanout1, fanout2):
    """Every sampled edge references real nodes; row-normalization sums to 1
    over non-padded rows; frontier contains the seeds (self loops)."""
    rng = np.random.default_rng(seed)
    n = 64
    src = rng.integers(0, n, 400)
    dst = rng.integers(0, n, 400)
    g = csr_from_edges(np.concatenate([src, dst]),
                       np.concatenate([dst, src]), n)
    sampler = NeighborSampler(g, fanouts=(fanout1, fanout2),
                              pad_multiple=16, seed=seed)
    mb = sampler.sample(rng.permutation(n)[:16],
                        rng=np.random.default_rng(seed))
    for coo, n_real_dst, n_real_src in zip(
            mb.layers, mb.n_real[:-1], mb.n_real[1:]):
        rows = np.asarray(coo.rows)
        cols = np.asarray(coo.cols)
        vals = np.asarray(coo.vals)
        live = vals != 0
        assert rows[live].max(initial=0) < coo.n_dst
        assert cols[live].max(initial=0) < coo.n_src
        sums = np.zeros(coo.n_dst)
        np.add.at(sums, rows[live], vals[live])
        np.testing.assert_allclose(sums[:n_real_dst],
                                   np.ones(n_real_dst), rtol=1e-4)


@settings(max_examples=30, deadline=None)
@given(st.integers(8, 8192), st.integers(2, 128), st.integers(1, 8),
       st.floats(1.0, 4.0))
def test_capacity_monotone_and_sufficient(tokens, experts, topk, factor):
    cap = capacity(tokens, experts, topk, factor)
    assert cap >= 8 and cap % 8 == 0
    assert cap * experts >= factor * tokens * topk * 0.9  # covers the load
    assert capacity(tokens * 2, experts, topk, factor) >= cap
