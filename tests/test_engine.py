"""The declarative Engine API: config validation, the pluggable registry,
format×schedule parity against the serial COO oracle, and the deprecation
shims over the old flag entry points.

Contracts:
  * unknown format/schedule names and unsupported combinations raise
    ``ValueError`` listing the registered options;
  * ``EngineConfig.from_spec`` parses ``"fmt+sched"`` and bare ``"fmt"``
    (default schedule) and round-trips through ``.spec``;
  * EVERY registered format×schedule combination matches the ``coo+serial``
    oracle to ≤1e-5 on 2 and 4 simulated devices — aggregate forward,
    aggregate gradient, and the full train-step loss;
  * a new format registers with ``@register_format`` and is immediately
    reachable via ``Engine``/``supported_specs`` (the ~100-line-extension
    contract);
  * the old flag API (``shard_minibatch``/``make_train_step``/
    ``gcn_layer_blocked``/``gcn_layer_ell``) still works but emits
    ``DeprecationWarning`` (which pytest escalates to an error for any
    in-repo caller outside ``pytest.warns``).
"""
import textwrap

import numpy as np
import pytest

from conftest import run_subprocess


def _toy_coo(rng, n_dst=32, n_src=64, e=300):
    from repro.graph.coo import from_edges
    return from_edges(rng.integers(0, n_dst, e), rng.integers(0, n_src, e),
                      rng.standard_normal(e).astype(np.float32),
                      n_dst, n_src)


# ---------------------------------------------------------------------------
# Config + registry validation.
# ---------------------------------------------------------------------------
def test_from_spec_parses_and_roundtrips():
    from repro.engine import Engine, EngineConfig

    cfg = EngineConfig.from_spec("ell+pipelined", lr=0.1, n_chunks=3)
    assert (cfg.format, cfg.schedule, cfg.lr, cfg.n_chunks) == \
        ("ell", "pipelined", 0.1, 3)
    assert cfg.spec == "ell+pipelined"
    # bare format name takes the format's default schedule
    assert EngineConfig.from_spec("coo").spec == "coo+serial"
    assert Engine("block").spec == "block+pipelined"


def test_supported_specs_lists_all_builtin_combos():
    from repro.engine import supported_specs

    assert set(supported_specs()) >= {"coo+serial", "block+pipelined",
                                      "ell+pipelined", "auto"}


def test_available_specs_is_the_canonical_enumeration():
    """``Engine.available_specs`` replaces hand-built format×topology
    products everywhere (test sweeps, benchmark arms)."""
    from repro.engine import (Engine, available_topologies,
                              supported_specs)

    assert Engine.available_specs() == supported_specs()
    full = Engine.available_specs(three_part=True)
    assert full == supported_specs(three_part=True)
    assert "auto" not in full
    # every concrete 2-part spec appears once per topology it supports
    for spec in supported_specs():
        if spec == "auto":
            continue
        carried = [s for s in full if s.startswith(spec + "+")]
        assert len(carried) == len(available_topologies())


def test_auto_spec_parses_and_is_complete():
    from repro.engine import Engine, EngineConfig

    cfg = EngineConfig.from_spec("auto", lr=0.1)
    assert cfg.is_auto and cfg.spec == "auto"
    eng = Engine(cfg)
    assert eng.is_auto and eng.spec == "auto"
    # resolution (hermetic fallback here) yields a registered concrete spec
    resolved = eng.resolve(4)
    assert not resolved.is_auto
    assert resolved.spec in Engine.available_specs() \
        or resolved.spec in Engine.available_specs(three_part=True)
    # knobs survive resolution
    assert resolved.config.lr == 0.1
    # "auto" is complete: pairing it with explicit parts is rejected with
    # the usual ValueError contract
    with pytest.raises(ValueError, match="complete spec"):
        EngineConfig.from_spec("auto+ring")
    with pytest.raises(ValueError, match="complete spec"):
        EngineConfig(format="auto", schedule="pipelined")


@pytest.mark.parametrize("bad,needle", [
    ("csr+serial", "registered formats"),        # unknown format
    ("coo+fast", "registered schedules"),        # unknown schedule
    ("coo+pipelined", "valid combinations"),     # known names, bad combo
    ("block+serial", "valid combinations"),
    ("ell+serial", "valid combinations"),
    # unknown topology must list the registered topology names — the same
    # contract as unknown format/schedule
    ("coo+serial+extra", "registered topologies"),
    ("ell+pipelined+mobius", "registered topologies"),
    # a fourth part is the partition axis: unknown names list the
    # registered partitions, same contract as format/schedule/topology
    ("coo+serial+hypercube+extra", "registered partitions"),
    ("coo+serial+hypercube+mincom+extra", "valid specs"),  # malformed spec
    ("", "valid specs"),
])
def test_invalid_specs_raise_listing_options(bad, needle):
    from repro.engine import EngineConfig

    with pytest.raises(ValueError, match=needle):
        EngineConfig.from_spec(bad)


def test_unknown_format_error_mentions_auto():
    """The spec grammar grew a planner alias: a typo'd format is told both
    the registered formats AND that 'auto' exists."""
    from repro.engine import EngineConfig

    with pytest.raises(ValueError, match="'auto'"):
        EngineConfig.from_spec("csr+serial")


def test_invalid_knobs_raise():
    from repro.engine import EngineConfig

    with pytest.raises(ValueError, match="n_chunks"):
        EngineConfig(format="ell", n_chunks=0)
    with pytest.raises(ValueError, match="precision"):
        EngineConfig(precision="fp8")
    with pytest.raises(ValueError, match="block_tiles"):
        EngineConfig(format="block", block_tiles=0)


def test_engine_build_needs_power_of_two_cores():
    from repro.engine import Engine

    with pytest.raises(ValueError, match="power-of-two"):
        Engine("coo").build(n_cores=3)
    with pytest.raises(ValueError, match="mesh or n_cores"):
        Engine("coo").build()


def test_register_new_format_is_reachable(rng):
    """The extension contract: a fresh registration is immediately usable
    through Engine/EngineConfig with no other code change."""
    import jax.numpy as jnp
    from repro.engine import (Engine, EngineConfig, available_formats,
                              register_format, supported_specs)
    from repro.engine.formats import CooFormat
    from repro.engine.registry import _FORMATS

    @register_format("coo-twin")
    class CooTwin(CooFormat):
        """Same layout/kernels as coo — registered under a new name."""

    try:
        assert "coo-twin" in available_formats()
        assert "coo-twin+serial" in supported_specs()
        coo = _toy_coo(rng)
        x = jnp.asarray(rng.standard_normal((coo.n_src, 8)), jnp.float32)
        w = jnp.asarray(rng.standard_normal((8, 4)), jnp.float32)
        y_twin = Engine("coo-twin").layer(coo, x, w)
        y_ref = Engine("coo").layer(coo, x, w)
        assert np.array_equal(np.asarray(y_twin), np.asarray(y_ref))
        # unsupported schedule on the new format still validates properly
        with pytest.raises(ValueError, match="valid combinations"):
            EngineConfig.from_spec("coo-twin+pipelined")
    finally:
        _FORMATS.pop("coo-twin", None)


# ---------------------------------------------------------------------------
# Parity: every registered combo vs the serial COO oracle, 2/4 devices.
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n_devices", [2, 4])
def test_every_combo_matches_serial_oracle(n_devices):
    run_subprocess(textwrap.dedent(f"""
        import jax, numpy as np, jax.numpy as jnp
        from repro.engine import Engine, supported_specs
        from repro.graph.coo import from_edges

        PC = {n_devices}
        n_dst, n_src, d, e = 16 * PC, 32 * PC, 20, 2500
        rng = np.random.default_rng(0)
        coo = from_edges(rng.integers(0, n_dst, e),
                         rng.integers(0, n_src, e),
                         rng.standard_normal(e).astype(np.float32),
                         n_dst, n_src)
        x = jnp.asarray(rng.standard_normal((n_src, d)), jnp.float32)
        mesh = jax.make_mesh((PC,), ('model',))
        oracle = Engine('coo+serial').build(mesh, graph=coo)
        ref = np.asarray(oracle.aggregate(x))
        np.testing.assert_allclose(ref, np.asarray(coo.matmul(x)),
                                   rtol=2e-4, atol=2e-4)
        g_ref = np.asarray(jax.grad(
            lambda xx: jnp.sum(coo.matmul(xx) ** 2))(x))
        # the canonical enumeration, not a hand-built product ('auto'
        # rides along and must resolve to a matching concrete engine)
        specs = Engine.available_specs()
        assert specs == supported_specs() and len(specs) >= 4, specs
        for spec in specs:
            b = Engine(spec).build(mesh, graph=coo)
            y = np.asarray(b.aggregate(x))
            err = np.abs(y - ref).max()
            assert err <= 1e-5, (spec, err)
            g = np.asarray(jax.grad(
                lambda xx: jnp.sum(b.aggregator()(xx) ** 2))(x))
            np.testing.assert_allclose(g, g_ref, rtol=2e-3, atol=2e-3,
                                       err_msg=spec)
        print('OK', specs)
    """), n_devices=n_devices)


def test_every_combo_train_step_matches_oracle_loss():
    """Full train-step parity: every registered spec's first-step loss and
    5-step trajectory stay within 1e-5 of the coo+serial oracle."""
    run_subprocess(textwrap.dedent("""
        import jax, numpy as np
        from repro.distributed.gcn_train import init_params
        from repro.engine import Engine, EngineConfig
        from repro.graph.coo import from_edges

        PC = 4
        rng = np.random.default_rng(0)
        n_mid, n_src = 32, 128

        class _MB:
            layers = [from_edges(rng.integers(0, n_mid, 400),
                                 rng.integers(0, n_src, 400),
                                 np.abs(rng.standard_normal(400)
                                        ).astype(np.float32) + 0.1,
                                 n_mid, n_src)]

        feats = rng.standard_normal((n_src, 8)).astype(np.float32)
        labels = rng.integers(0, 4, n_mid).astype(np.int32)
        mesh = jax.make_mesh((PC,), ('model',))
        params0 = init_params(jax.random.PRNGKey(0), [(8, 4)])
        losses = {}
        for spec in Engine.available_specs():
            bundle = Engine(EngineConfig.from_spec(spec,
                                                   lr=0.3)).build(mesh)
            b = bundle.shard_batch(_MB(), feats, labels)
            p = params0
            traj = []
            for _ in range(5):
                p, loss = bundle.train_step(p, b)
                traj.append(float(loss))
            losses[spec] = traj
        ref = losses['coo+serial']
        for spec, traj in losses.items():
            for i, (a, b_) in enumerate(zip(ref, traj)):
                assert abs(a - b_) <= 1e-5, (spec, i, a, b_)
        print('OK', {k: round(v[-1], 5) for k, v in losses.items()})
    """), n_devices=4)


# ---------------------------------------------------------------------------
# Deprecation shims: the old flag API still works — and warns.
# ---------------------------------------------------------------------------
def test_flag_shims_work_and_warn(rng):
    import jax
    from repro.distributed.gcn_train import (init_params, make_train_step,
                                             shard_minibatch)
    from repro.engine import Engine, EngineConfig

    coo = _toy_coo(rng)

    class _MB:
        layers = [coo]

    feats = rng.standard_normal((coo.n_src, 8)).astype(np.float32)
    labels = rng.integers(0, 4, coo.n_dst).astype(np.int32)
    mesh = jax.make_mesh((1,), ("model",))
    params = init_params(jax.random.PRNGKey(0), [(8, 4)])
    # engine reference (the supported path)
    bundle = Engine(EngineConfig.from_spec("coo+serial", lr=0.05)) \
        .build(mesh)
    b_ref = bundle.shard_batch(_MB(), feats, labels)
    _, l_ref = bundle.train_step(params, b_ref)
    # legacy flag path: same numbers, plus a DeprecationWarning each
    with pytest.warns(DeprecationWarning, match="Engine API"):
        batch = shard_minibatch(_MB(), feats, labels, 1, mesh=mesh)
    with pytest.warns(DeprecationWarning, match="Engine API"):
        step = make_train_step(mesh, batch["dims"], lr=0.05)
    _, l_old = step(params, batch)
    assert abs(float(l_old) - float(l_ref)) < 1e-6
    # the flag pairs map to the right specs
    with pytest.warns(DeprecationWarning, match="ell\\+pipelined"):
        shard_minibatch(_MB(), feats, labels, 1, layout="ell", mesh=mesh)
    with pytest.warns(DeprecationWarning, match="block\\+pipelined"):
        make_train_step(mesh, batch["dims"], overlap=True)
    with pytest.raises(ValueError, match="unknown layout"):
        shard_minibatch(_MB(), feats, labels, 1, layout="nope")


def test_layer_shims_work_and_warn(rng):
    import jax.numpy as jnp
    from repro.core.blockmsg import dst_tiles
    from repro.core.gcn import gcn_layer, gcn_layer_blocked, gcn_layer_ell
    from repro.graph.partition import block_partition
    from repro.kernels import edgeplan

    coo = _toy_coo(rng)
    x = jnp.asarray(rng.standard_normal((coo.n_src, 8)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((8, 4)), jnp.float32)
    y_ref = np.asarray(gcn_layer(coo, x, w))
    tiles = dst_tiles(block_partition(coo, 4))
    with pytest.warns(DeprecationWarning, match="Engine API"):
        y_blk = gcn_layer_blocked(tiles, x, w)
    np.testing.assert_allclose(np.asarray(y_blk), y_ref, rtol=1e-4,
                               atol=1e-4)
    plan = edgeplan.build_plan(coo)
    with pytest.warns(DeprecationWarning, match="Engine API"):
        y_ell = gcn_layer_ell(plan, x, w)
    np.testing.assert_allclose(np.asarray(y_ell), y_ref, rtol=1e-4,
                               atol=1e-4)


# ---------------------------------------------------------------------------
# Bundle surface: forward + layout cache.
# ---------------------------------------------------------------------------
def test_bundle_forward_returns_global_logits():
    run_subprocess(textwrap.dedent("""
        import jax, numpy as np
        from repro.distributed.gcn_train import init_params
        from repro.engine import Engine
        from repro.graph.coo import from_edges

        rng = np.random.default_rng(0)

        class _MB:
            layers = [from_edges(rng.integers(0, 16, 100),
                                 rng.integers(0, 64, 100),
                                 rng.standard_normal(100).astype(np.float32),
                                 16, 64)]

        feats = rng.standard_normal((64, 8)).astype(np.float32)
        labels = rng.integers(0, 4, 16).astype(np.int32)
        mesh = jax.make_mesh((2,), ('model',))
        bundle = Engine('ell+pipelined').build(mesh)
        b = bundle.shard_batch(_MB(), feats, labels)
        params = init_params(jax.random.PRNGKey(0), [(8, 4)])
        logits = bundle.forward(params, b)
        assert logits.shape == (16, 4), logits.shape
        print('OK')
    """), n_devices=2)


def test_non_traceable_format_rejected_under_jit(rng):
    """block/ell layouts build host-side: a traced graph must raise the
    explanatory error, not a numpy-on-tracer crash."""
    import jax
    import jax.numpy as jnp
    from repro.engine import Engine

    coo = _toy_coo(rng)
    x = jnp.asarray(rng.standard_normal((coo.n_src, 8)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((8, 4)), jnp.float32)
    eng = Engine("ell+pipelined")
    with pytest.raises(ValueError, match="host-side"):
        jax.jit(lambda c, xx, ww: eng.layer(c, xx, ww))(coo, x, w)
    # the coo format is traceable and jits through the same entry point
    y = jax.jit(lambda c, xx, ww: Engine("coo").layer(c, xx, ww))(coo, x, w)
    assert y.shape == (coo.n_dst, 4)


def test_train_gcn_trains_layout_building_engine_specs():
    """train_gcn used to hard-reject block/ell (their layouts can't build
    under jit); the Trainer's host-side input pipeline builds them per
    batch OUTSIDE any trace, so every registered spec trains end-to-end —
    and matches the coo+serial oracle trajectory.  Unknown specs still die
    at validation time, before any data loads."""
    from repro.launch.train import train_gcn

    ref = train_gcn("flickr", engine="coo+serial", steps=3, scale=0.005,
                    batch_size=16, feat_dim=16, hidden=16, log_every=0)
    out = train_gcn("flickr", engine="ell+pipelined", steps=3, scale=0.005,
                    batch_size=16, feat_dim=16, hidden=16, log_every=0)
    assert len(out["loss_history"]) == 3
    np.testing.assert_allclose(out["loss_history"], ref["loss_history"],
                               rtol=0, atol=1e-5)
    with pytest.raises(ValueError, match="registered formats"):
        train_gcn("flickr", engine="csr+serial", steps=1)


def test_shim_n_cores_beats_mesh_core_count(rng):
    """Old shard_minibatch semantics: n_cores drives the shard shapes even
    when a (different-sized) placement mesh is passed — the mismatch then
    fails loudly at step time, exactly like the flag era."""
    import jax
    from repro.distributed.gcn_train import (init_params, make_train_step,
                                             shard_minibatch)

    coo = _toy_coo(rng)

    class _MB:
        layers = [coo]

    feats = rng.standard_normal((coo.n_src, 8)).astype(np.float32)
    labels = rng.integers(0, 4, coo.n_dst).astype(np.int32)
    mesh = jax.make_mesh((1,), ("model",))
    with pytest.warns(DeprecationWarning, match="Engine API"):
        batch = shard_minibatch(_MB(), feats, labels, 2, layout="ell",
                                mesh=mesh)
    # two senders' tables were built, as requested
    lead = batch["edges"][0]["inv"].shape[0]
    assert lead == 2, lead
    with pytest.warns(DeprecationWarning, match="Engine API"):
        step = make_train_step(mesh, batch["dims"], overlap=True, ell=True)
    params = init_params(jax.random.PRNGKey(0), [(8, 4)])
    with pytest.raises(ValueError, match="different core count"):
        step(params, batch)


def test_aggregator_cached_per_graph_identity(rng):
    import jax
    import jax.numpy as jnp
    from repro.engine import Engine

    mesh = jax.make_mesh((1,), ("model",))
    bundle = Engine("coo+serial").build(mesh)
    coo = _toy_coo(rng)
    agg = bundle.aggregator(coo)
    assert bundle.aggregator(coo) is agg
    coo2 = _toy_coo(rng)
    assert bundle.aggregator(coo2) is not agg
    x = jnp.asarray(rng.standard_normal((coo.n_src, 8)), jnp.float32)
    np.testing.assert_allclose(np.asarray(agg(x)),
                               np.asarray(coo.matmul(x)),
                               rtol=2e-4, atol=2e-4)


def test_engine_layout_is_cached_per_graph(rng):
    from repro.engine import Engine

    coo = _toy_coo(rng)
    eng = Engine("ell+pipelined")
    assert eng.layout(coo) is eng.layout(coo)
    # a different engine object shares the process-wide layout cache
    assert Engine("ell+pipelined").layout(coo) is eng.layout(coo)
    # a different format keys separately
    assert Engine("block+pipelined").layout(coo) is not eng.layout(coo)
