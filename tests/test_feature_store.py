"""Out-of-core feature store + hot-vertex cache + staged pipeline.

Contracts:
  * the ``@register_store`` registry mirrors the engine registry —
    unknown names fail loudly listing the options, fresh registrations
    are reachable with no other code change;
  * ``host`` and ``mmap`` backends gather bit-exactly, count their
    traffic, round-trip the chunked writer, and refuse writes after
    ``seal()``;
  * ``make_dataset(features="store"/"mmap")`` generates features (and
    labels) BIT-IDENTICAL to the dense path at the same seed;
  * the :class:`HotVertexCache` is bit-exact with the raw store, its
    hit/miss/eviction accounting is exact, and eviction can never touch
    a pinned row;
  * :class:`StagedPrefetcher` preserves ordering and the batch-exact
    ``(seed, epoch, batch_idx)`` restore contract through a multi-stage
    chain;
  * the Trainer trains from a store (sync == staged prefetch == dense,
    bit-equal losses), enforces the simulated device feature budget, and
    checkpoint/resumes through the staged store pipeline bit-exactly;
  * every registered spec trains from an MmapStore on 2 simulated
    devices within 1e-5 of its in-memory trajectory.
"""
import textwrap

import numpy as np
import pytest

from conftest import run_subprocess


# ---------------------------------------------------------------------------
# Registry contract (mirrors engine/registry.py).
# ---------------------------------------------------------------------------
def test_unknown_store_fails_loudly_listing_options():
    from repro.featurestore import get_store
    with pytest.raises(ValueError, match=r"unknown feature store 'ssd'"):
        get_store("ssd")
    with pytest.raises(ValueError, match="host"):
        get_store("ssd")          # the error names the registered options


def test_fresh_registration_is_reachable():
    from repro.featurestore import (FeatureStore, available_stores,
                                    get_store, register_store)
    from repro.featurestore.store import _STORES

    @register_store("testonly")
    class _TestStore(FeatureStore):
        pass

    try:
        assert get_store("testonly") is _TestStore
        assert _TestStore.name == "testonly"
        assert "testonly" in available_stores()
    finally:
        _STORES.pop("testonly", None)


def test_builtin_backends_registered():
    from repro.featurestore import (HostStore, MmapStore, available_stores,
                                    get_store)
    assert {"host", "mmap"} <= set(available_stores())
    assert get_store("host") is HostStore
    assert get_store("mmap") is MmapStore


# ---------------------------------------------------------------------------
# Backend gather exactness + facade + counters + writer round-trip.
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("backend", ["host", "mmap"])
def test_store_gather_bit_exact_and_counted(backend, rng):
    from repro.featurestore import get_store
    ref = rng.standard_normal((50, 8)).astype(np.float32)
    with get_store(backend).from_array(ref, chunk_rows=16) as store:
        # ndarray facade
        assert store.shape == (50, 8) and store.ndim == 2
        assert len(store) == 50 and store.nbytes == ref.nbytes
        assert store.dtype == np.float32
        idx = np.array([0, 49, 3, 3, 17])
        got = store.gather(idx)
        np.testing.assert_array_equal(got, ref[idx])
        np.testing.assert_array_equal(store[idx], ref[idx])  # __getitem__
        np.testing.assert_array_equal(store.as_array(), ref)
        # gather + __getitem__ are counted traffic; as_array is not
        assert store.gather_calls == 2
        assert store.bytes_gathered == got.nbytes * 2


@pytest.mark.parametrize("backend", ["host", "mmap"])
def test_chunked_writer_roundtrip_and_seal(backend, rng):
    from repro.featurestore import get_store
    ref = rng.standard_normal((40, 4)).astype(np.float32)
    store = get_store(backend).create(40, 4)
    for s in range(0, 40, 13):
        store.write_chunk(s, ref[s:s + 13])
    store.seal()
    try:
        np.testing.assert_array_equal(store.as_array(), ref)
        with pytest.raises(ValueError, match="sealed"):
            store.write_chunk(0, ref[:1])
    finally:
        store.close()


def test_writer_rejects_bad_chunks():
    from repro.featurestore import HostStore
    store = HostStore.create(10, 4)
    with pytest.raises(ValueError, match="feat_dim"):
        store.write_chunk(0, np.zeros((2, 5), np.float32))
    with pytest.raises(ValueError, match="out of range"):
        store.write_chunk(8, np.zeros((3, 4), np.float32))


def test_mmap_store_reopens_from_path(tmp_path, rng):
    from repro.featurestore import MmapStore
    ref = rng.standard_normal((30, 6)).astype(np.float32)
    path = str(tmp_path / "feats.npy")
    MmapStore.from_array(ref, path=path).close()
    store = MmapStore.open(path)          # .npy header carries shape/dtype
    try:
        assert store.shape == (30, 6)
        np.testing.assert_array_equal(store.as_array(), ref)
        with pytest.raises(ValueError, match="sealed"):
            store.write_chunk(0, ref[:1])
    finally:
        store.close()
    assert (tmp_path / "feats.npy").exists()   # non-owned path survives


def test_mmap_tempfile_unlinked_on_close(rng):
    import os
    from repro.featurestore import MmapStore
    store = MmapStore.from_array(
        rng.standard_normal((8, 2)).astype(np.float32))
    path = store.path
    assert os.path.exists(path)
    store.close()
    assert not os.path.exists(path)
    store.close()                          # idempotent


# ---------------------------------------------------------------------------
# make_dataset(features=...): store-backed generation is bit-identical.
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("features", ["store", "mmap"])
def test_make_dataset_store_bit_identical_to_dense(features):
    from repro.featurestore import FeatureStore
    from repro.graph import make_dataset
    dense = make_dataset("flickr", scale=0.003, seed=7, feat_dim=12)
    ds = make_dataset("flickr", scale=0.003, seed=7, feat_dim=12,
                      features=features, chunk_rows=50)  # force many chunks
    try:
        assert isinstance(ds.features, FeatureStore)
        np.testing.assert_array_equal(ds.features.as_array(), dense.features)
        # labels are drawn AFTER features from the same stream — the
        # chunked generation must leave the generator in the same spot
        np.testing.assert_array_equal(ds.labels, dense.labels)
        np.testing.assert_array_equal(ds.graph.indptr, dense.graph.indptr)
    finally:
        ds.features.close()


# ---------------------------------------------------------------------------
# HotVertexCache: exactness, accounting, pinned rows are untouchable.
# ---------------------------------------------------------------------------
def _cache(n=20, d=4, capacity=4, pinned=2, rng=None):
    from repro.featurestore import HostStore, HotVertexCache
    rng = rng or np.random.default_rng(0)
    ref = rng.standard_normal((n, d)).astype(np.float32)
    store = HostStore.from_array(ref)
    degrees = np.arange(n, 0, -1)          # vertex 0 is the hottest
    return HotVertexCache(store, degrees, capacity, pinned=pinned), store, ref


def test_cache_hit_accounting_is_exact():
    cache, store, ref = _cache()
    assert cache.pinned_ids == {0, 1}      # top-degree, deterministic
    got = cache.gather([0, 1, 2, 3])       # 2 pinned hits, 2 misses
    np.testing.assert_array_equal(got, ref[[0, 1, 2, 3]])
    assert (cache.hits, cache.misses, cache.evictions) == (2, 2, 0)
    got = cache.gather([0, 2, 3, 5])       # 3 hits, miss 5 evicts LRU (2)
    np.testing.assert_array_equal(got, ref[[0, 2, 3, 5]])
    assert (cache.hits, cache.misses, cache.evictions) == (5, 3, 1)
    cache.gather([2])                      # evicted above: a miss again
    assert (cache.hits, cache.misses) == (5, 4)
    assert cache.hit_rate == 5 / 9
    # duplicates count as absorbed traffic, one row per repeat
    cache.gather([0, 0, 0])
    assert cache.hits == 8
    stats = cache.stats()
    assert stats["hits"] == 8 and stats["misses"] == 4
    assert stats["bytes_served"] == 12 * 4 * 4
    assert stats["bytes_from_store"] == store.bytes_gathered \
        - cache.warm_bytes


def test_cache_never_evicts_pinned_rows(rng):
    cache, store, ref = _cache(n=64, capacity=6, pinned=3, rng=rng)
    pinned = sorted(cache.pinned_ids)
    assert pinned == [0, 1, 2]
    # churn the dynamic region far past its 3 slots
    for _ in range(20):
        cache.gather(rng.integers(3, 64, size=8))
    assert cache.evictions > 0
    before = store.bytes_gathered
    got = cache.gather(pinned)             # must be pure hits
    np.testing.assert_array_equal(got, ref[pinned])
    assert store.bytes_gathered == before  # zero store traffic
    assert set(pinned) <= set(cache._slot)


def test_cache_gather_bit_exact_on_random_frontiers():
    hypothesis = pytest.importorskip(
        "hypothesis",
        reason="property tests need hypothesis (pip install -e .[test])")
    st = pytest.importorskip("hypothesis.strategies")

    cache, store, ref = _cache(n=32, capacity=8, pinned=4)

    @hypothesis.settings(max_examples=50, deadline=None)
    @hypothesis.given(st.lists(st.integers(min_value=0, max_value=31),
                               min_size=1, max_size=24))
    def prop(ids):
        np.testing.assert_array_equal(cache.gather(ids),
                                      ref[np.asarray(ids)])

    prop()


def test_cache_rejects_bad_shapes():
    from repro.featurestore import HostStore, HotVertexCache
    store = HostStore.from_array(np.zeros((10, 2), np.float32))
    with pytest.raises(ValueError, match="capacity"):
        HotVertexCache(store, np.ones(10), 0)
    with pytest.raises(ValueError, match="degrees"):
        HotVertexCache(store, np.ones(9), 4)


# ---------------------------------------------------------------------------
# StagedPrefetcher: ordering + restore through a multi-stage chain.
# ---------------------------------------------------------------------------
class _CountSource:
    def __init__(self):
        self.idx = 0

    def __next__(self):
        out = (self.idx,)
        self.idx += 1
        return out

    def state(self):
        return {"idx": self.idx}

    def restore(self, st):
        self.idx = int(st["idx"])


def _staged(depth=2):
    from repro.data import StagedPrefetcher
    return StagedPrefetcher(
        _CountSource(),
        [("double", lambda i: (i * 2,)), ("plus1", lambda i: i + 1)],
        depth=depth)


def test_staged_prefetcher_orders_and_composes_stages():
    sp = _staged()
    got = [next(sp) for _ in range(6)]
    sp.close()
    assert got == [1, 3, 5, 7, 9, 11]      # (i*2)+1, in order
    assert sp.n_consumed == 6
    assert set(sp.stage_stalls()) == {"double", "plus1"}


def test_staged_prefetcher_restore_is_batch_exact():
    sp = _staged()
    want = [next(sp) for _ in range(4)]
    st = sp.state()
    assert st == {"idx": 4}                # innermost source, consumed only
    _ = [next(sp) for _ in range(3)]       # wander ahead, stages in flight
    sp.restore(st)
    got = [next(sp) for _ in range(3)]
    sp.close()
    assert want == [1, 3, 5, 7]
    assert got == [9, 11, 13]              # regenerated, never skipped


def test_staged_prefetcher_close_rewinds_all_stages():
    import time
    sp = _staged()
    assert next(sp) == 1
    time.sleep(0.2)                        # let every stage run ahead
    sp.close()
    assert sp.source.idx == 1              # rewound through the chain
    assert next(sp) == 3
    sp.close()


def test_staged_prefetcher_validates_stages():
    from repro.data import StagedPrefetcher
    with pytest.raises(ValueError, match="at least one stage"):
        StagedPrefetcher(_CountSource(), [])
    with pytest.raises(ValueError, match="duplicate"):
        StagedPrefetcher(_CountSource(),
                         [("a", int), ("a", int)])


# ---------------------------------------------------------------------------
# Trainer integration: store == dense, budgets, resume through the chain.
# ---------------------------------------------------------------------------
def _store_trainer(pipeline, feature_store=None, ckpt=None,
                   dataset="flickr", **kw):
    from repro.launch.trainer import Trainer
    if isinstance(dataset, str):
        kw.setdefault("scale", 0.005)
        kw.setdefault("feat_dim", 16)
    return Trainer("coo+serial", dataset, n_cores=1, hidden=16,
                   batch_size=16, lr=0.2, seed=3, input_pipeline=pipeline,
                   val_batches=1, feature_store=feature_store,
                   ckpt_dir=ckpt, ckpt_every=0, **kw)


def test_trainer_store_streams_match_dense_bit_exact():
    ref = _store_trainer("sync").fit(1, steps_per_epoch=5)
    sync = _store_trainer("sync", feature_store="mmap",
                          cache_capacity=32).fit(1, steps_per_epoch=5)
    staged = _store_trainer("prefetch", feature_store="mmap",
                            cache_capacity=32).fit(1, steps_per_epoch=5)
    assert ref["loss_history"] == sync["loss_history"]
    assert ref["loss_history"] == staged["loss_history"]
    assert ref["feature_store"] == "device"
    assert sync["feature_store"] == staged["feature_store"] == "mmap"
    for out in (sync, staged):
        assert out["gather_bytes"] > 0
        assert out["cache"]["hit_rate"] > 0
    # the staged chain reports per-stage stalls; sync has no chain
    assert set(staged["stage_stall_s_per_step"]) \
        == {"gather", "layout", "place"}
    assert "stage_stall_s_per_step" not in sync


def test_trainer_trains_from_store_backed_dataset():
    from repro.featurestore import FeatureStore
    from repro.graph import make_dataset
    ds = make_dataset("flickr", scale=0.005, seed=3, feat_dim=16,
                      features="store")
    assert isinstance(ds.features, FeatureStore)
    out = _store_trainer("prefetch", dataset=ds).fit(1, steps_per_epoch=3)
    assert out["feature_store"] == "host"   # picked up with no flag
    assert out["gather_bytes"] > 0
    assert all(np.isfinite(out["loss_history"]))


def test_trainer_device_budget_rejects_dense_but_not_store():
    # the dense matrix is ~446*16*4 bytes; a 1 KB budget must refuse it
    with pytest.raises(ValueError, match="device_budget_bytes"):
        _store_trainer("sync", device_budget_bytes=1024)
    # the same budget with a store trains: only frontier rows hit devices
    out = _store_trainer("sync", feature_store="mmap",
                         device_budget_bytes=1024).fit(1, steps_per_epoch=2)
    assert len(out["loss_history"]) == 2


def test_trainer_resume_through_staged_store_pipeline_is_bit_exact(tmp_path):
    """Checkpoint with batches in flight across ALL stages of the staged
    store chain; the resumed run must replay the remaining stream and
    losses bit-exactly — the (seed, epoch, batch_idx) contract survives
    the deeper pipeline."""
    def build(ckpt=None):
        return _store_trainer("prefetch", feature_store="mmap",
                              cache_capacity=32, ckpt=ckpt)

    full = build()
    full_losses = full.train_steps(8)
    full.close()

    part = build(ckpt=str(tmp_path))
    part.train_steps(3)
    part.save(sync=True)        # gather/layout/place queues hold work
    part.close()

    resumed = build(ckpt=str(tmp_path))
    assert resumed.resume() is True
    assert resumed.global_step == 3
    res_losses = resumed.train_steps(5)
    resumed.close()
    assert res_losses == full_losses[3:]


# ---------------------------------------------------------------------------
# Acceptance: every registered spec trains out-of-core on 2 devices, with
# features larger than the simulated per-device budget, ≤1e-5 vs in-memory.
# ---------------------------------------------------------------------------
def test_every_spec_trains_from_mmap_store_on_two_devices():
    run_subprocess(textwrap.dedent("""
        from repro.engine import supported_specs
        from repro.featurestore import MmapStore
        from repro.graph import make_dataset
        from repro.launch.trainer import Trainer

        dense = make_dataset('flickr', scale=0.005, seed=0, feat_dim=16)
        ds = make_dataset('flickr', scale=0.005, seed=0, feat_dim=16,
                          features='mmap')
        assert isinstance(ds.features, MmapStore)
        # the feature matrix exceeds the simulated per-device budget: the
        # dense path refuses, the store path streams frontier rows
        budget = ds.features.nbytes // 4

        def run(spec, dataset, **kw):
            tr = Trainer(spec, dataset, n_cores=2, hidden=16,
                         batch_size=16, lr=0.2, seed=0,
                         input_pipeline='prefetch', val_batches=0,
                         cache_capacity=32, **kw)
            return tr.fit(1, steps_per_epoch=3)

        try:
            run('coo+serial', dense, device_budget_bytes=budget)
            raise SystemExit('dense features over budget must refuse')
        except ValueError as e:
            assert 'device_budget_bytes' in str(e), e

        specs = supported_specs()
        assert len(specs) >= 3, specs
        for spec in specs:
            a = run(spec, dense)['loss_history']
            out = run(spec, ds, device_budget_bytes=budget)
            b = out['loss_history']
            assert out['feature_store'] == 'mmap'
            assert out['cache']['hit_rate'] > 0, spec
            drift = max(abs(x - y) for x, y in zip(a, b))
            assert drift <= 1e-5, (spec, drift, a, b)
        ds.features.close()
        print('OK', specs)
    """), n_devices=2)
