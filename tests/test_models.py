"""Per-arch smoke tests (reduced configs, CPU) + decode/prefill consistency.

Each assigned architecture instantiates its SMOKE config, runs one forward
and one train step asserting output shapes + finiteness, and (for the
decoder archs) checks one-token decode against the teacher-forced forward.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, get_smoke
from repro.models import lm
from repro.models.transformer import (FLASH_THRESHOLD, attend, flash_attend)
from repro.optim import adamw


def _batch(cfg, rng, b=2, s=16):
    out = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (b, s)),
                                 jnp.int32),
           "labels": jnp.asarray(rng.integers(0, cfg.vocab, (b, s)),
                                 jnp.int32)}
    if cfg.family == "encdec":
        out["frames"] = jnp.asarray(
            rng.standard_normal((b, s, cfg.d_model)), jnp.float32)
    return out


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_arch_smoke_forward_and_train_step(rng, arch):
    cfg = get_smoke(arch)
    params = lm.init_params(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    batch = _batch(cfg, rng)
    logits, aux = lm.forward(params, batch, cfg, chunk=8)
    b, s = batch["tokens"].shape
    assert logits.shape == (b, s, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    opt = adamw(1e-3)
    step = jax.jit(lm.train_step_fn(cfg, opt, chunk=8, remat=False))
    params2, opt_state, metrics = step(params, opt[0](params), batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually moved
    moved = any(
        not np.allclose(np.asarray(a), np.asarray(b2))
        for a, b2 in zip(jax.tree_util.tree_leaves(params),
                         jax.tree_util.tree_leaves(params2)))
    assert moved


@pytest.mark.parametrize("arch", ["stablelm-3b", "gemma3-27b",
                                  "mamba2-1.3b", "zamba2-1.2b",
                                  "moonshot-v1-16b-a3b"])
def test_decode_matches_prefill(rng, arch):
    cfg = get_smoke(arch)
    params = lm.init_params(jax.random.PRNGKey(1), cfg, dtype=jnp.float32)
    b, s = 2, 16
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32)
    if cfg.family == "moe":
        from repro.models.moe import moe_forward
        logits, _ = moe_forward(params, tokens, cfg, capacity_factor=8.0)
    else:
        logits, _ = lm.forward(params, {"tokens": tokens}, cfg, chunk=8)
    cache = lm.init_cache(cfg, b, s, dtype=jnp.float32)
    decode = lm.decode_fn(cfg)
    outs = []
    for t in range(s):
        if cfg.family == "moe":
            from repro.models.moe import moe_decode_step
            lg, cache = moe_decode_step(params, cache, tokens[:, t:t + 1],
                                        jnp.int32(t), cfg,
                                        capacity_factor=8.0)
        else:
            lg, cache = decode(params, cache, tokens[:, t:t + 1],
                               jnp.int32(t))
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, 1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(logits),
                               rtol=2e-3, atol=2e-3)


def test_encdec_decode_matches_teacher_forcing(rng):
    cfg = get_smoke("seamless-m4t-medium")
    params = lm.init_params(jax.random.PRNGKey(2), cfg, dtype=jnp.float32)
    from repro.models.encdec import (encode, encdec_decode_step,
                                     encdec_forward, prefill_cross)
    b, s_enc, s_dec = 2, 12, 10
    frames = jnp.asarray(rng.standard_normal((b, s_enc, cfg.d_model)),
                         jnp.float32)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (b, s_dec)), jnp.int32)
    logits = encdec_forward(params, frames, tokens, cfg)
    cache = prefill_cross(params, encode(params, frames, cfg), cfg, b, s_dec,
                          dtype=jnp.float32)
    outs = []
    for t in range(s_dec):
        lg, cache = encdec_decode_step(params, cache, tokens[:, t:t + 1],
                                       jnp.int32(t), cfg)
        outs.append(lg[:, 0])
    np.testing.assert_allclose(np.asarray(jnp.stack(outs, 1)),
                               np.asarray(logits), rtol=1e-3, atol=1e-3)


def test_flash_attention_matches_dense(rng):
    """The online-softmax blocked path == materialized attention."""
    b, s, h, hd, kv = 2, 2048, 4, 32, 2
    q = jnp.asarray(rng.standard_normal((b, s, h, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, kv, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, kv, hd)), jnp.float32)
    i = jnp.arange(s)[:, None]
    j = jnp.arange(s)[None, :]
    for window in (None, 384):
        mask = (j <= i)
        if window:
            mask = mask & (i - j < window)
        ref = attend(q, k, v, mask[None, None])
        w_eff = jnp.int32(window) if window else None
        out = flash_attend(q, k, v, causal=True, w_eff=w_eff,
                           q_block=256, k_block=512)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-4, atol=2e-4)


def test_flash_attention_cross_noncausal(rng):
    b, sq, sk, h, hd = 1, 512, 1024, 2, 16
    q = jnp.asarray(rng.standard_normal((b, sq, h, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, sk, h, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, sk, h, hd)), jnp.float32)
    ref = attend(q, k, v, None)
    out = flash_attend(q, k, v, causal=False, q_block=256, k_block=256)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_moe_capacity_drop_fraction(rng):
    """At the default capacity factor the dropped-token fraction stays small
    on near-uniform routing."""
    from repro.models.moe import capacity
    s, e, k = 4096, 64, 6
    cap = capacity(s, e, k, 1.25)
    eidx = rng.integers(0, e, (s, k))
    counts = np.bincount(eidx.reshape(-1), minlength=e)
    dropped = np.maximum(counts - cap, 0).sum()
    assert dropped / (s * k) < 0.02


def test_param_counts_match_published_sizes():
    from repro.configs import get_config
    expected = {"zamba2-1.2b": 1.2e9, "gemma3-27b": 27e9, "yi-6b": 6e9,
                "llama3.2-1b": 1.2e9, "mamba2-1.3b": 1.3e9,
                "chameleon-34b": 34e9}
    for arch, n in expected.items():
        got = get_config(arch).param_count()
        assert 0.7 * n <= got <= 1.35 * n, (arch, got)
