"""End-to-end behaviour: the paper's training loop learns, resumes exactly
after restart, and the serving loop completes requests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.estimator import LayerShape
from repro.graph import NeighborSampler, make_dataset
from repro.models.gcn_model import (GCNConfig, gcn_forward, gcn_loss,
                                    init_gcn_params, pick_orders)
from repro.optim import apply_updates, sgd


def test_gcn_overfits_one_minibatch(rng):
    """Memorization check: repeating one sampled minibatch must drive the
    loss down hard — exercises fwd + transpose-free bwd + SGD end-to-end."""
    ds = make_dataset("flickr", scale=0.005, feat_dim=32)
    sampler = NeighborSampler(ds.graph, fanouts=(5, 5), seed=0)
    cfg = GCNConfig(name="t", feat_dim=32, hidden=32, n_classes=7)
    params = init_gcn_params(jax.random.PRNGKey(0), cfg)
    seeds = rng.permutation(ds.graph.n_nodes)[:32]
    mb = sampler.sample(seeds)
    x = jnp.asarray(ds.features[np.minimum(mb.input_nodes,
                                           ds.graph.n_nodes - 1)])
    pad = mb.layers[0].n_dst - len(seeds)
    labels = jnp.asarray(ds.labels[np.pad(seeds, (0, pad))] % 7)
    shapes = [LayerShape(b=32, n=l.n_dst, nbar=l.n_src, d=32, h=32,
                         e=l.nnz, c=7) for l in mb.layers]
    orders = pick_orders(cfg, shapes)
    init, update = sgd(0.5, momentum=0.9)
    opt = init(params)
    loss_g = jax.jit(jax.value_and_grad(
        lambda p: gcn_loss(p, mb.layers, x, labels, cfg, orders,
                           n_valid=32)))
    first = None
    for i in range(150):
        loss, g = loss_g(params)
        if first is None:
            first = float(loss)
        upd, opt = update(g, opt, params)
        params = apply_updates(params, upd)
    assert float(loss) < 0.5 * first, (first, float(loss))


def test_gcn_ours_and_naive_train_identically(rng):
    """Same seeds ⇒ bit-comparable training trajectories for both dataflows
    (the paper's redesign changes cost, not math)."""
    ds = make_dataset("flickr", scale=0.005, feat_dim=16)
    sampler = NeighborSampler(ds.graph, fanouts=(4, 4), seed=1)
    seeds = rng.permutation(ds.graph.n_nodes)[:16]
    mb = sampler.sample(seeds)
    x = jnp.asarray(ds.features[np.minimum(mb.input_nodes,
                                           ds.graph.n_nodes - 1)])
    pad = mb.layers[0].n_dst - len(seeds)
    labels = jnp.asarray(ds.labels[np.pad(seeds, (0, pad))] % 7)
    losses = {}
    for dataflow in ("ours", "naive"):
        cfg = GCNConfig(name="t", feat_dim=16, hidden=16, n_classes=7,
                        dataflow=dataflow)
        params = init_gcn_params(jax.random.PRNGKey(3), cfg)
        orders = ("coag", "agco")
        init, update = sgd(0.2)
        opt = init(params)
        hist = []
        for i in range(10):
            loss, g = jax.value_and_grad(
                lambda p: gcn_loss(p, mb.layers, x, labels, cfg, orders,
                                   n_valid=16))(params)
            upd, opt = update(g, opt, params)
            params = apply_updates(params, upd)
            hist.append(float(loss))
        losses[dataflow] = hist
    np.testing.assert_allclose(losses["ours"], losses["naive"],
                               rtol=1e-4, atol=1e-5)


def test_trainer_resume_matches_uninterrupted(tmp_path):
    """Checkpoint at step 50, resume, and land on the same trajectory as an
    uninterrupted run (fault-tolerance contract of the train loop)."""
    from repro.launch.train import train_gcn
    full = train_gcn("flickr", scale=0.005, batch_size=16, steps=60,
                     log_every=0, seed=5)
    _ = train_gcn("flickr", scale=0.005, batch_size=16, steps=50,
                  log_every=0, seed=5, ckpt_dir=str(tmp_path))
    resumed = train_gcn("flickr", scale=0.005, batch_size=16, steps=60,
                        log_every=0, seed=5, ckpt_dir=str(tmp_path),
                        resume=True)
    np.testing.assert_allclose(resumed["loss_history"],
                               full["loss_history"][50:60],
                               rtol=1e-3, atol=1e-4)


def test_serve_completes_all_requests():
    from repro.launch.lm_serve import Request, Server
    rng = np.random.default_rng(0)
    srv = Server("llama3.2-1b", slots=3, max_seq=64)
    for i in range(5):
        prompt = rng.integers(0, srv.cfg.vocab, 6).astype(np.int32)
        srv.submit(Request(rid=i, prompt=prompt, max_new=4))
    stats = srv.run()
    assert len(srv.completed) == 5
    assert all(len(r.generated) == 4 for r in srv.completed)
    assert stats["tokens"] >= 20


def test_lm_trainer_loss_decreases():
    from repro.launch.train import train_lm
    out = train_lm("llama3.2-1b", smoke=True, steps=12, batch=2, seq=32,
                   log_every=0)
    assert out["losses"][-1] < out["losses"][0]
