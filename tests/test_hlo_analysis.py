"""The dry-run 'profiler': scan-trip-count-corrected HLO accounting."""
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import run_subprocess
from repro.launch.hlo_analysis import analyze_hlo, roofline_terms


def test_scan_flops_match_unrolled():
    def scanned(x, w):
        def body(h, _):
            return jnp.tanh(h @ w), ()
        h, _ = jax.lax.scan(body, x, None, length=8)
        return h

    def unrolled(x, w):
        h = x
        for _ in range(8):
            h = jnp.tanh(h @ w)
        return h

    x = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    w = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    a = analyze_hlo(jax.jit(scanned).lower(x, w).compile().as_text())
    b = analyze_hlo(jax.jit(unrolled).lower(x, w).compile().as_text())
    expected = 2 * 128 * 256 * 256 * 8
    assert a.flops == b.flops == expected
    # XLA's own cost_analysis demonstrably undercounts the scan version
    xla = jax.jit(scanned).lower(x, w).compile().cost_analysis()
    if isinstance(xla, (list, tuple)):  # older jaxlib: one dict per program
        xla = xla[0]
    assert xla["flops"] < expected


def test_nested_scan_multipliers():
    def f(x, w):
        def outer(h, _):
            def inner(h2, _):
                return h2 @ w, ()
            h, _ = jax.lax.scan(inner, h, None, length=3)
            return h, ()
        h, _ = jax.lax.scan(outer, x, None, length=5)
        return h

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    st = analyze_hlo(jax.jit(f).lower(x, w).compile().as_text())
    assert st.flops == 2 * 64 * 64 * 64 * 15


def test_collective_bytes_parsed_from_psum():
    run_subprocess(textwrap.dedent("""
        import jax, numpy as np, jax.numpy as jnp
        from repro.compat import shard_map, set_mesh
        from jax.sharding import Mesh, PartitionSpec as P
        from repro.launch.hlo_analysis import analyze_hlo

        mesh = Mesh(np.array(jax.devices()), ('x',))
        fn = jax.jit(shard_map(
            lambda v: jax.lax.psum(v, 'x'),
            mesh=mesh, in_specs=P('x'), out_specs=P()))
        arr = jax.ShapeDtypeStruct((16, 1024), jnp.float32)
        st = analyze_hlo(fn.lower(arr).compile().as_text(), world=16)
        # all-reduce of a [1, 1024] f32 shard → ring wire = 2·15/16·4096 B
        assert st.by_kind_count.get('all-reduce', 0) >= 1
        expected = 2 * 15 / 16 * 1024 * 4
        total = st.collective_wire_bytes
        assert 0.5 * expected <= total <= 4 * expected, total
        print('OK', total)
    """))


def test_collective_bytes_scale_with_scan_trips():
    run_subprocess(textwrap.dedent("""
        import jax, numpy as np, jax.numpy as jnp
        from repro.compat import shard_map, set_mesh
        from jax.sharding import Mesh, PartitionSpec as P
        from repro.launch.hlo_analysis import analyze_hlo

        mesh = Mesh(np.array(jax.devices()), ('x',))
        perm = [(i, (i + 1) % 16) for i in range(16)]

        def once(v):
            return jax.lax.ppermute(v, 'x', perm)

        def many(v):
            def body(h, _):
                return jax.lax.ppermute(h, 'x', perm) * 0.5, ()
            h, _ = jax.lax.scan(body, v, None, length=7)
            return h

        arr = jax.ShapeDtypeStruct((16, 512), jnp.float32)
        w1 = analyze_hlo(jax.jit(shard_map(
            once, mesh=mesh, in_specs=P('x'), out_specs=P('x'))).lower(
            arr).compile().as_text(), 16).collective_wire_bytes
        w7 = analyze_hlo(jax.jit(shard_map(
            many, mesh=mesh, in_specs=P('x'), out_specs=P('x'))).lower(
            arr).compile().as_text(), 16).collective_wire_bytes
        assert w1 > 0
        assert 6 * w1 <= w7 <= 8 * w1, (w1, w7)
        print('OK', w1, w7)
    """))


def test_roofline_terms_and_dominance():
    t = roofline_terms(1e15, 1e12, 1e9, 256)
    assert t["dominant"] == "compute"
    t = roofline_terms(1e12, 1e13, 1e9, 256)
    assert t["dominant"] == "memory"
    t = roofline_terms(1e12, 1e9, 1e12, 256)
    assert t["dominant"] == "collective"
