"""The topology axis of the Engine: spec grammar, registry contracts,
full format×schedule×topology parity, and the end-to-end Trainer ride.

Contracts:
  * ``from_spec`` parses ``fmt+sched+topo``; two-part specs default the
    topology to ``hypercube`` and round-trip through ``.spec`` UNCHANGED
    (no legacy BENCH-key or checkpoint-spec churn);
  * unknown topology names raise ``ValueError`` listing the registered
    topology names (same contract as unknown format/schedule), and a
    format's ``topologies`` restriction is enforced with the full
    three-part spec list in the message;
  * a fresh ``@register_topology`` registration is immediately reachable
    through ``Engine``/``supported_topology_specs`` (the ~100-line
    extension contract);
  * EVERY registered format×schedule×topology combo matches the
    ``coo+serial+allpairs`` dense-reference oracle to ≤1e-5 on 2 and 4
    simulated devices — aggregate forward, gradient, and the 5-step
    train-loss trajectory;
  * the differentiable exchange primitives' custom_vjp mirrors hold: the
    backward of ``reduce_scatter`` is the same topology's allgather and
    vice versa, for every registered topology;
  * ``Trainer(engine_spec="ell+pipelined+ring")`` trains end-to-end with
    checkpoint/resume bit-exact.
"""
import textwrap

import numpy as np
import pytest

from conftest import run_subprocess


# ---------------------------------------------------------------------------
# Spec grammar + defaults (the no-churn shim contract).
# ---------------------------------------------------------------------------
def test_two_part_specs_default_hypercube_and_roundtrip():
    from repro.engine import EngineConfig

    for spec in ("coo+serial", "block+pipelined", "ell+pipelined"):
        cfg = EngineConfig.from_spec(spec)
        assert cfg.topology == "hypercube"
        assert cfg.spec == spec          # unchanged: no BENCH key churn
    # bare format: both defaults kick in
    cfg = EngineConfig.from_spec("ell")
    assert (cfg.schedule, cfg.topology) == ("pipelined", "hypercube")
    assert cfg.spec == "ell+pipelined"


def test_three_part_specs_parse_and_roundtrip():
    from repro.engine import Engine, EngineConfig

    cfg = EngineConfig.from_spec("ell+pipelined+ring", lr=0.1)
    assert (cfg.format, cfg.schedule, cfg.topology) == \
        ("ell", "pipelined", "ring")
    assert cfg.spec == "ell+pipelined+ring"
    assert EngineConfig.from_spec(cfg.spec) == EngineConfig.from_spec(
        "ell+pipelined+ring")
    # an EXPLICIT default topology canonicalizes back to the two-part form
    assert EngineConfig.from_spec("ell+pipelined+hypercube").spec == \
        "ell+pipelined"
    assert Engine("coo+serial+torus2d").spec == "coo+serial+torus2d"


def test_registry_lists_builtin_topologies():
    from repro.engine import (available_topologies, format_topologies,
                              supported_specs, supported_topology_specs)

    topos = available_topologies()
    assert set(topos) >= {"hypercube", "allpairs", "ring", "torus2d"}
    # two-part specs (plus "auto") stay the canonical listing; the 3-part
    # product is the full matrix (built-in formats ride every topology)
    assert "ell+pipelined" in supported_specs()
    assert "auto" in supported_specs()
    assert "+hypercube" not in "".join(supported_specs())
    full = supported_topology_specs()
    assert full == supported_specs(three_part=True)
    assert "ell+pipelined+ring" in full and "coo+serial+torus2d" in full
    # the concrete product excludes "auto" — it is a planner alias, not a
    # buildable combination
    concrete = [s for s in supported_specs() if s != "auto"]
    assert len(full) == len(concrete) * len(topos)
    assert all(s.count("+") == 2 for s in full)
    assert format_topologies("coo") == topos


def test_unknown_topology_lists_registered_names():
    from repro.engine import EngineConfig

    with pytest.raises(ValueError, match="registered topologies"):
        EngineConfig(format="coo", topology="mobius")
    with pytest.raises(ValueError, match="registered topologies"):
        EngineConfig.from_spec("ell+pipelined+mesh3d")


def test_format_topology_restriction_enforced(rng):
    """A format that restricts its topologies gets the same loud
    ValueError contract as a bad schedule pair."""
    from repro.engine import EngineConfig, register_format, \
        supported_topology_specs
    from repro.engine.formats import CooFormat
    from repro.engine.registry import _FORMATS

    @register_format("coo-hyperonly")
    class CooHyperOnly(CooFormat):
        topologies = ("hypercube",)

    try:
        assert "coo-hyperonly+serial+allpairs" not in \
            supported_topology_specs()
        assert "coo-hyperonly+serial+hypercube" in \
            supported_topology_specs()
        EngineConfig.from_spec("coo-hyperonly+serial")          # default ok
        with pytest.raises(ValueError, match="does not support topology"):
            EngineConfig.from_spec("coo-hyperonly+serial+ring")
    finally:
        _FORMATS.pop("coo-hyperonly", None)


def test_register_new_topology_is_reachable(rng):
    """The extension contract: a fresh @register_topology subclass is
    immediately usable through Engine specs with no other code change."""
    import jax.numpy as jnp
    from repro.engine import (Engine, available_topologies,
                              register_topology, supported_topology_specs)
    from repro.engine.registry import _TOPOLOGIES
    from repro.graph.coo import from_edges
    from repro.topology import HypercubeTopology

    @register_topology("hypercube-twin")
    class HypercubeTwin(HypercubeTopology):
        """Same wires as hypercube — registered under a new name."""

    try:
        assert "hypercube-twin" in available_topologies()
        assert "ell+pipelined+hypercube-twin" in supported_topology_specs()
        coo = from_edges(rng.integers(0, 32, 300),
                         rng.integers(0, 64, 300),
                         rng.standard_normal(300).astype(np.float32),
                         32, 64)
        x = jnp.asarray(rng.standard_normal((coo.n_src, 8)), jnp.float32)
        w = jnp.asarray(rng.standard_normal((8, 4)), jnp.float32)
        # single-device layer ignores the wires but must resolve the name
        y = Engine("coo+serial+hypercube-twin").layer(coo, x, w)
        y_ref = Engine("coo+serial").layer(coo, x, w)
        assert np.array_equal(np.asarray(y), np.asarray(y_ref))
    finally:
        _TOPOLOGIES.pop("hypercube-twin", None)


# ---------------------------------------------------------------------------
# Exchange plans (the cost model the benchmarks record).
# ---------------------------------------------------------------------------
def test_exchange_plans_steps_and_bytes():
    from repro.engine import get_topology

    P, rows, d = 8, 256, 32
    expected_steps = {"hypercube": 3, "torus2d": 3,
                      "ring": 7, "allpairs": 7}
    for name, steps in expected_steps.items():
        plan = get_topology(name).plan(rows, d, P)
        assert plan.steps == steps, name
        # every built-in ships exactly the owed blocks: n_rows·(1 − 1/P)
        assert plan.bytes_per_core == rows * (P - 1) // P * d * 4, name
    # ring/allpairs move one n/P block per step; the hypercube front-loads
    # half, the torus splits that across two disjoint link classes
    assert get_topology("ring").plan(rows, d, P).max_step_rows == rows // P
    assert get_topology("hypercube").plan(rows, d, P).max_step_rows \
        == rows // 2
    assert get_topology("torus2d").plan(rows, d, P).max_step_rows \
        == rows // 4


def test_topology_validates_core_count():
    from repro.engine import Engine, get_topology
    from repro.launch.mesh import make_topology_mesh

    with pytest.raises(ValueError, match="power-of-two"):
        get_topology("ring").validate_cores(3)
    with pytest.raises(ValueError, match="power-of-two"):
        Engine("ell+pipelined+ring").build(n_cores=6)
    with pytest.raises(ValueError, match="power-of-two"):
        make_topology_mesh(5, "torus2d")
    with pytest.raises(ValueError, match="registered topologies"):
        make_topology_mesh(4, "nope")


# ---------------------------------------------------------------------------
# Parity: EVERY format×schedule×topology combo vs the coo+serial+allpairs
# dense-reference oracle — aggregate fwd, grad, 5-step train trajectory.
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n_devices", [2, 4])
def test_every_topology_combo_matches_allpairs_oracle(n_devices):
    run_subprocess(textwrap.dedent(f"""
        import jax, numpy as np, jax.numpy as jnp
        from repro.distributed.gcn_train import init_params
        from repro.engine import (Engine, EngineConfig,
                                  supported_topology_specs)
        from repro.graph.coo import from_edges

        PC = {n_devices}
        n_dst, n_src, d, e = 16 * PC, 32 * PC, 20, 2000
        rng = np.random.default_rng(0)
        coo = from_edges(rng.integers(0, n_dst, e),
                         rng.integers(0, n_src, e),
                         rng.standard_normal(e).astype(np.float32),
                         n_dst, n_src)
        x = jnp.asarray(rng.standard_normal((n_src, d)), jnp.float32)
        mesh = jax.make_mesh((PC,), ('model',))
        specs = supported_topology_specs()
        assert len(specs) >= 12, specs

        # the dense all-to-all reference is the oracle of this sweep
        oracle = Engine('coo+serial+allpairs').build(mesh, graph=coo)
        ref = np.asarray(oracle.aggregate(x))
        np.testing.assert_allclose(ref, np.asarray(coo.matmul(x)),
                                   rtol=2e-4, atol=2e-4)
        g_ref = np.asarray(jax.grad(
            lambda xx: jnp.sum(coo.matmul(xx) ** 2))(x))
        for spec in specs:
            b = Engine(spec).build(mesh, graph=coo)
            y = np.asarray(b.aggregate(x))
            err = np.abs(y - ref).max()
            assert err <= 1e-5, (spec, err)
            g = np.asarray(jax.grad(
                lambda xx: jnp.sum(b.aggregator()(xx) ** 2))(x))
            np.testing.assert_allclose(g, g_ref, rtol=2e-3, atol=2e-3,
                                       err_msg=spec)

        # 5-step train trajectories: every combo within 1e-5 of the oracle
        n_mid = 8 * PC
        class _MB:
            layers = [from_edges(rng.integers(0, n_mid, 300),
                                 rng.integers(0, n_src, 300),
                                 np.abs(rng.standard_normal(300)
                                        ).astype(np.float32) + 0.1,
                                 n_mid, n_src)]
        feats = rng.standard_normal((n_src, 8)).astype(np.float32)
        labels = rng.integers(0, 4, n_mid).astype(np.int32)
        params0 = init_params(jax.random.PRNGKey(0), [(8, 4)])
        losses = {{}}
        for spec in ['coo+serial+allpairs'] + specs:
            bundle = Engine(EngineConfig.from_spec(spec,
                                                   lr=0.3)).build(mesh)
            bb = bundle.shard_batch(_MB(), feats, labels)
            p, traj = params0, []
            for _ in range(5):
                p, loss = bundle.train_step(p, bb)
                traj.append(float(loss))
            losses[spec] = traj
        ref_traj = losses['coo+serial+allpairs']
        for spec, traj in losses.items():
            for i, (a, b_) in enumerate(zip(ref_traj, traj)):
                assert abs(a - b_) <= 1e-5, (spec, i, a, b_)
        print('OK', len(specs), 'combos')
    """), n_devices=n_devices)


def test_exchange_primitives_custom_vjp_mirrors():
    """grad through base.reduce_scatter == the topology's allgather of the
    upstream cotangent (and vice versa), for every registered topology —
    the transpose-free backward rides any interconnect."""
    run_subprocess(textwrap.dedent("""
        import jax, numpy as np, jax.numpy as jnp
        from jax.sharding import Mesh, PartitionSpec as P
        from repro.compat import shard_map
        from repro.engine import available_topologies, get_topology
        from repro.topology import allgather, exchange, reduce_scatter

        PC, t, d = 4, 6, 10
        rng = np.random.default_rng(0)
        mesh = Mesh(np.array(jax.devices()), ('model',))
        part = jnp.asarray(rng.standard_normal((PC, PC, t, d)), jnp.float32)
        ct = jnp.asarray(rng.standard_normal((PC, t, d)), jnp.float32)
        for name in available_topologies():
            topo = get_topology(name)
            plan = topo.plan(PC * t, d, PC)

            # reduce_scatter vjp == allgather of the cotangent
            def rs_loss(p):
                y = reduce_scatter(name, 'model', PC, p[0])
                return jnp.sum(y * ct[jax.lax.axis_index('model')]), y

            g = shard_map(lambda p: jax.grad(
                              lambda q: rs_loss(q)[0])(p),
                          mesh=mesh, in_specs=(P('model'),),
                          out_specs=P('model'))(part)
            want = shard_map(
                lambda c: topo.allgather(c[0], 'model', PC)[None],
                mesh=mesh, in_specs=(P('model'),),
                out_specs=P('model'))(ct)
            np.testing.assert_allclose(np.asarray(g), np.asarray(want),
                                       rtol=1e-5, atol=1e-5,
                                       err_msg=f'{name} rs-vjp')

            # allgather vjp == reduce_scatter of the cotangent blocks
            ct_full = jnp.asarray(
                rng.standard_normal((PC, PC, t, d)), jnp.float32)
            g2 = shard_map(
                lambda x, c: jax.grad(lambda q: jnp.sum(
                    allgather(name, 'model', PC, q[0]) * c[0]))(x),
                mesh=mesh, in_specs=(P('model'), P('model')),
                out_specs=P('model'))(ct, ct_full)
            want2 = shard_map(
                lambda c: topo.reduce_scatter(c[0], 'model', PC)[None],
                mesh=mesh, in_specs=(P('model'),),
                out_specs=P('model'))(ct_full)
            np.testing.assert_allclose(np.asarray(g2), np.asarray(want2),
                                       rtol=1e-5, atol=1e-5,
                                       err_msg=f'{name} ag-vjp')

            # exchange() is the plan-driven spelling of the same primitives
            y1 = shard_map(
                lambda p: exchange(p[0], plan)[None], mesh=mesh,
                in_specs=(P('model'),), out_specs=P('model'))(part)
            y2 = shard_map(
                lambda p: topo.reduce_scatter(p[0], 'model', PC)[None],
                mesh=mesh, in_specs=(P('model'),),
                out_specs=P('model'))(part)
            assert np.array_equal(np.asarray(y1), np.asarray(y2)), name
        print('OK')
    """), n_devices=4)


# ---------------------------------------------------------------------------
# End to end: the Trainer rides a non-default topology, ckpt/resume exact.
# ---------------------------------------------------------------------------
def test_trainer_rides_ring_topology_ckpt_resume_bit_exact():
    run_subprocess(textwrap.dedent("""
        import tempfile
        import numpy as np
        from repro.launch.trainer import Trainer

        def build(ckpt):
            return Trainer('ell+pipelined+ring', 'flickr', n_cores=2,
                           scale=0.005, feat_dim=16, hidden=16,
                           batch_size=16, lr=0.1, seed=0,
                           pad_multiple=32, val_batches=1,
                           ckpt_dir=ckpt, ckpt_every=0)

        STEPS, MID = 6, 3
        with tempfile.TemporaryDirectory() as ckpt:
            full = build(None)
            assert full.engine.spec == 'ell+pipelined+ring'
            assert full.bundle.topology.name == 'ring'
            ref = full.fit(1, steps_per_epoch=STEPS)
            part = build(ckpt)
            part.train_steps(MID)
            part.save(sync=True)
            part.close()
            resumed = build(ckpt)
            out = resumed.fit(1, steps_per_epoch=STEPS - MID, resume=True)
        drift = max(abs(a - b) for a, b in
                    zip(ref['loss_history'][MID:], out['loss_history']))
        assert drift == 0.0, drift
        assert out['val_acc'], 'no validation ran'
        print('OK ring trainer, drift', drift)
    """), n_devices=2)
