"""Block-Message compression + staged waves (§4.3.3, Fig. 6/7)."""
import numpy as np
import pytest
pytest.importorskip("hypothesis",
                    reason="property tests need hypothesis "
                           "(pip install -e .[test])")
from hypothesis import given, settings, strategies as st

from repro.core.blockmsg import (build_waves, compress_block,
                                 wave_statistics)
from repro.graph.coo import from_edges
from repro.graph.partition import (anti_diagonal_stages, block_partition,
                                   diagonal_storage_mask)


def _random_coo(rng, n_dst=64, n_src=64, e=300):
    return from_edges(rng.integers(0, n_dst, e), rng.integers(0, n_src, e),
                      rng.standard_normal(e).astype(np.float32),
                      n_dst, n_src)


def test_compress_block_preserves_edges(rng):
    r = rng.integers(0, 64, 200).astype(np.int32)
    c = rng.integers(0, 64, 200).astype(np.int32)
    v = rng.standard_normal(200).astype(np.float32)
    bm = compress_block(r, c, v, dst_core=3, src_core=7)
    assert bm.nnz == 200
    assert bm.n_msgs == len(np.unique(r))
    assert bm.compression >= 1.0
    # reconstruction: pre-reduced messages must equal per-row sums
    x = rng.standard_normal((64, 5)).astype(np.float32)
    msgs = np.zeros((bm.n_msgs, 5), np.float32)
    np.add.at(msgs, bm.seg_ids, x[bm.nbr_slots] * bm.weights[:, None])
    ref = np.zeros((64, 5), np.float32)
    np.add.at(ref, r, x[c] * v[:, None])
    np.testing.assert_allclose(msgs, ref[bm.agg_slots], rtol=1e-5, atol=1e-5)


def test_anti_diagonal_groups_are_conflict_free():
    stages = anti_diagonal_stages(16, group_size=4)
    assert len(stages) == 4
    for stage in stages:
        assert len(stage) == 4
        for group in stage:
            assert len(group) == 16
            dsts = [i for i, _ in group]
            srcs = [j for _, j in group]
            assert len(set(dsts)) == 16 and len(set(srcs)) == 16


def test_waves_cover_all_offdiagonal_edges(rng):
    coo = _random_coo(rng, 64, 64, 400)
    blocked = block_partition(coo, 16)
    waves = build_waves(blocked)
    stats = wave_statistics(waves)
    offdiag = sum(len(r) for (i, j), (r, _, _) in blocked.block_edges.items()
                  if i != j)
    assert stats["raw_edges"] == offdiag
    assert stats["compression"] >= 1.0
    # wave start rule: ≤4 messages per sender per wave (4 groups × 1 each)
    for w in waves:
        for s in range(16):
            assert np.sum(w.src == s) <= 4


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000))
def test_block_partition_roundtrip(seed):
    rng = np.random.default_rng(seed)
    coo = _random_coo(rng, 64, 128, 256)
    blocked = block_partition(coo, 16)
    assert blocked.nnz() == coo.nnz
    # reassemble and compare dense forms
    dense = np.zeros((64, 128), np.float32)
    for (i, j), (r, c, v) in blocked.block_edges.items():
        np.add.at(dense, (r + i * 4, c + j * 8), v)
    np.testing.assert_allclose(dense, np.asarray(coo.todense()),
                               rtol=1e-5, atol=1e-5)


def test_diagonal_storage_mask():
    m = diagonal_storage_mask(16)
    assert m.sum() == 16 * 17 // 2
