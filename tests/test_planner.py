"""The profile-guided planner behind ``Engine("auto")``.

Contracts:
  * resolution tiers in order — persisted autotune winner beats the fitted
    cost model beats the static ``DEFAULT_SPEC`` fallback — and every tier
    degrades silently-but-warned: a missing file is no file, a corrupt or
    stale record is a ``RuntimeWarning`` and a fall-through, never a crash;
  * the cost model is monotone by construction (nonnegative α/β: more
    exchange steps or more effective wire bytes never predicts a faster
    step) and recovers planted coefficients from a fabricated sweep record;
  * ``Topology.plan(..., cost_model=)`` stamps ``predicted_seconds``;
  * ``"auto"`` resolves to a registered concrete spec on 2 and 4 simulated
    devices, trains end-to-end through the Trainer, and a mid-run
    checkpoint + resume pins the RESOLVED spec bit-exactly even when the
    planner record changes under the run;
  * :func:`repro.engine.planner.autotune` persists a winner that a fresh
    ``Engine("auto")`` then follows.

Hermeticity: every test points ``$REPRO_PLANNER_PATH`` /
``$REPRO_TOPOLOGY_PATH`` into ``tmp_path`` so a developer's real
``BENCH_*.json`` in the CWD can never leak in (run_subprocess forwards
os.environ, so the monkeypatched paths reach the child processes too).
"""
import json
import textwrap

import numpy as np
import pytest

from conftest import run_subprocess


@pytest.fixture(autouse=True)
def _hermetic_stores(monkeypatch, tmp_path):
    """No test sees a real planner/topology record unless it writes one."""
    monkeypatch.setenv("REPRO_PLANNER_PATH", str(tmp_path / "planner.json"))
    monkeypatch.setenv("REPRO_TOPOLOGY_PATH",
                       str(tmp_path / "topology.json"))
    return tmp_path


# ---------------------------------------------------------------------------
# Fabricated records with planted coefficients.
# ---------------------------------------------------------------------------
ALPHA, BETA, CONST = 2e-3, 4e-9, 1e-3


def _topology_record(n_cores=4, mid=512, feat=128, backend=None,
                     alpha=ALPHA, beta=BETA, const=CONST):
    """A BENCH_topology.json-shaped sweep whose step times follow
    ``t = const + α·steps + β·bytes/link_parallelism`` exactly."""
    from repro.engine import available_topologies, get_topology

    rec = {"n_cores": n_cores, "mid": mid, "feat": feat,
           "base_spec": "ell+pipelined",
           "topologies": sorted(available_topologies())}
    if backend is not None:
        rec["backend"] = backend
    for name in rec["topologies"]:
        topo = get_topology(name)
        plan = topo.plan(mid, feat, n_cores)
        eff = plan.bytes_per_core / plan.link_parallelism
        rec[f"exchange_steps_{name}"] = plan.steps
        rec[f"exchange_bytes_per_core_{name}"] = plan.bytes_per_core
        rec[f"link_parallelism_{name}"] = plan.link_parallelism
        rec[f"s_per_step_{name}"] = const + alpha * plan.steps + beta * eff
    return rec


def _write(path, obj):
    with open(path, "w") as f:
        json.dump(obj, f)


# ---------------------------------------------------------------------------
# Tier 3: no records at all → the static fallback, hermetically.
# ---------------------------------------------------------------------------
def test_no_records_resolves_to_static_default():
    from repro.engine import planner
    from repro.engine.config import EngineConfig

    spec = planner.resolve_spec(n_cores=4)
    assert spec == planner.DEFAULT_SPEC
    cfg = EngineConfig.from_spec(spec)      # the fallback must be concrete
    assert not cfg.is_auto
    # resolution is a pure read: no sweep left a record behind
    assert planner.PLANNER_STORE.load() is None


# ---------------------------------------------------------------------------
# Tier 1: the persisted autotune winner.
# ---------------------------------------------------------------------------
def test_persisted_winner_beats_everything(tmp_path):
    from repro.engine import planner

    backend = "cpu"
    entry = {"spec": "block+pipelined+ring", "backend": backend,
             "n_cores": 4, "bucket": "default"}
    _write(tmp_path / "planner.json",
           {"entries": {planner._entry_key(backend, 4, "default"): entry}})
    # a cost-model record that would pick something else is outranked
    _write(tmp_path / "topology.json", _topology_record(n_cores=4))
    assert planner.resolve_spec(n_cores=4,
                                backend=backend) == "block+pipelined+ring"
    # the winner is keyed per core count: a 4-core entry says nothing at 2
    assert planner.resolve_spec(n_cores=2,
                                backend=backend) == planner.DEFAULT_SPEC


def test_exact_bucket_beats_prefix_match(tmp_path):
    from repro.engine import planner

    stats = planner.GraphStats(n_dst=500, n_src=1000, avg_deg=7.0,
                               feat_dim=100)
    exact = planner._entry_key("cpu", 4, stats.bucket())
    other = planner._entry_key("cpu", 4, "n64_s128_d4_f16")
    _write(tmp_path / "planner.json", {"entries": {
        other: {"spec": "coo+serial+allpairs"},
        exact: {"spec": "ell+pipelined+torus2d"},
    }})
    got = planner.resolve_spec(n_cores=4, graph_stats=stats, backend="cpu")
    assert got == "ell+pipelined+torus2d"
    # without stats the deterministic sorted-prefix fallback still finds
    # SOME measured entry at this (backend, n_cores)
    got = planner.resolve_spec(n_cores=4, backend="cpu")
    assert got in ("coo+serial+allpairs", "ell+pipelined+torus2d")


def test_corrupt_record_warns_and_falls_back(tmp_path):
    from repro.engine import planner

    (tmp_path / "planner.json").write_text("{not json")
    with pytest.warns(RuntimeWarning, match="unreadable"):
        assert planner.resolve_spec(n_cores=4) == planner.DEFAULT_SPEC


def test_stale_spec_warns_and_falls_back(tmp_path):
    from repro.engine import planner

    key = planner._entry_key("cpu", 4, "default")
    _write(tmp_path / "planner.json",
           {"entries": {key: {"spec": "csr+magic+wormhole"}}})
    with pytest.warns(RuntimeWarning, match="stale/unregistered"):
        got = planner.resolve_spec(n_cores=4, backend="cpu")
    assert got == planner.DEFAULT_SPEC


def test_missing_entries_table_warns_and_falls_back(tmp_path):
    from repro.engine import planner

    _write(tmp_path / "planner.json", {"spec": "ell+pipelined"})
    with pytest.warns(RuntimeWarning, match="entries"):
        assert planner.resolve_spec(n_cores=4) == planner.DEFAULT_SPEC


# ---------------------------------------------------------------------------
# Tier 2: the fitted cost model.
# ---------------------------------------------------------------------------
def test_nnls_recovers_and_clamps():
    from repro.engine.planner import _nnls

    rng = np.random.default_rng(0)
    A = rng.uniform(0.5, 2.0, (12, 3))
    true = np.array([0.3, 1.7, 0.0])
    coef = _nnls(A, A @ true)
    assert np.allclose(coef, true, atol=1e-8)
    assert (coef >= 0).all()
    # a column that only helps with a NEGATIVE weight is clamped to zero
    y = A @ np.array([1.0, 0.0, 0.0]) - 0.5 * A[:, 2]
    coef = _nnls(A, y)
    assert coef[2] == 0.0


def test_fit_cost_model_recovers_planted_coefficients(tmp_path):
    from repro.engine import planner

    _write(tmp_path / "topology.json", _topology_record(n_cores=4))
    model = planner.fit_cost_model(n_cores=4)
    assert model is not None
    assert model.alpha == pytest.approx(ALPHA, rel=1e-6)
    assert model.beta == pytest.approx(BETA, rel=1e-6)
    assert model.const == pytest.approx(CONST, rel=1e-6)
    assert model.n_cores == 4


def test_fit_cost_model_rejects_mismatched_records(tmp_path):
    from repro.engine import planner

    _write(tmp_path / "topology.json", _topology_record(n_cores=4,
                                                        backend="tpu"))
    # per-(backend, axis-size) coefficients: a 4-core sweep says nothing
    # about a 2-core mesh, a tpu sweep nothing about cpu
    assert planner.fit_cost_model(n_cores=2) is None
    assert planner.fit_cost_model(n_cores=4, backend="cpu") is None
    assert planner.fit_cost_model(n_cores=4, backend="tpu") is not None
    # fewer than 3 measured arms → underdetermined → None
    rec = _topology_record(n_cores=4)
    rec["topologies"] = rec["topologies"][:2]
    assert planner.fit_cost_model(record=rec) is None
    # corrupt topology store: warn, never raise
    (tmp_path / "topology.json").write_text("][")
    with pytest.warns(RuntimeWarning, match="unreadable"):
        assert planner.fit_cost_model(n_cores=4) is None


def test_cost_model_is_monotone():
    """More steps or more effective bytes never predicts a faster step —
    guaranteed by the nonnegative fit, checked against the fitted model."""
    import dataclasses

    from repro.engine import planner
    from repro.topology.base import ExchangePlan

    model = planner.fit_cost_model(record=_topology_record(n_cores=4))
    base = ExchangePlan(topology="hypercube", n_cores=4, steps=2,
                        bytes_per_core=1 << 20, max_step_rows=256)
    for field, worse in (("steps", 5), ("bytes_per_core", 1 << 24)):
        bigger = dataclasses.replace(base, **{field: worse})
        assert model.predict(bigger) >= model.predict(base)
    # higher link parallelism = fewer effective bytes = never slower
    wide = dataclasses.replace(base, link_parallelism=2.0)
    assert model.predict(wide) <= model.predict(base)


def test_plan_stamps_predicted_seconds():
    from repro.engine import get_topology, planner

    model = planner.fit_cost_model(record=_topology_record(n_cores=4))
    topo = get_topology("hypercube")
    plain = topo.plan(512, 128, 4)
    assert plain.predicted_seconds is None          # opt-in, no model → None
    plan = topo.plan(512, 128, 4, cost_model=model)
    assert plan.predicted_seconds == pytest.approx(model.predict(plain))
    assert plan.predicted_seconds > 0


def test_analytic_tier_ranks_and_resolves(tmp_path):
    """With only a topology sweep on disk the analytic tier picks the
    min-predicted topology — torus2d here, whose orthogonal halves halve
    the effective bytes under a byte-dominated planted model."""
    from repro.engine import planner
    from repro.engine.config import EngineConfig

    _write(tmp_path / "topology.json",
           _topology_record(n_cores=4, alpha=1e-6, beta=1e-7, const=1e-4))
    model = planner.fit_cost_model(n_cores=4)
    ranked = planner.rank_specs(model, 4)
    assert ranked[0][0] == "ell+pipelined+torus2d"
    assert all(a[1] <= b[1] for a, b in zip(ranked, ranked[1:]))
    spec = planner.resolve_spec(n_cores=4)
    assert spec == "ell+pipelined+torus2d"
    assert not EngineConfig.from_spec(spec).is_auto
    # a latency-dominated model (β=0) prefers the fewest-step topology
    _write(tmp_path / "topology.json",
           _topology_record(n_cores=4, alpha=1e-3, beta=0.0, const=1e-4))
    spec = planner.resolve_spec(n_cores=4)
    assert spec in ("ell+pipelined+hypercube", "ell+pipelined+torus2d")


def test_graph_stats_bucketing():
    from repro.engine import planner

    a = planner.GraphStats(n_dst=500, n_src=1000, avg_deg=7.2, feat_dim=100)
    b = planner.GraphStats(n_dst=512, n_src=1024, avg_deg=8.0, feat_dim=128)
    assert a.bucket() == b.bucket() == "n512_s1024_d8_f128"
    c = planner.GraphStats(n_dst=513, n_src=1024, avg_deg=8.0, feat_dim=128)
    assert c.bucket() != a.bucket()


# ---------------------------------------------------------------------------
# Serving mode: latency-weighted ranking over micro-batch sizes.
# ---------------------------------------------------------------------------
def test_serving_mode_scores_are_latency_weighted():
    """The serving objective is pinned: mean predicted latency over
    coalesced micro-batch sizes 1, 2, 4, … max_batch (each micro-batch is
    one user-visible latency, so every size weighs equally)."""
    from repro.engine import get_topology, planner

    model = planner.CostModel(alpha=1e-4, beta=1e-9, const=1e-3, n_cores=4)
    cands = ["ell+pipelined+hypercube", "ell+pipelined+ring"]
    ranked = dict(planner.rank_specs(model, 4, candidates=cands,
                                     mode="serving", max_batch=8))
    for spec in cands:
        topo = get_topology(spec.split("+")[2])
        plans = [topo.plan(b, model.d, 4) for b in (1, 2, 4, 8)]
        want = sum(model.predict(p) for p in plans) / len(plans)
        assert ranked[spec] == pytest.approx(want)
    # max_batch=1 degenerates to the single-request latency
    one = dict(planner.rank_specs(model, 4, candidates=cands,
                                  mode="serving", max_batch=1))
    for spec in cands:
        topo = get_topology(spec.split("+")[2])
        assert one[spec] == pytest.approx(
            model.predict(topo.plan(1, model.d, 4)))
    # train mode scores the fitted workload's row count instead
    train = dict(planner.rank_specs(model, 4, candidates=cands))
    for spec in cands:
        topo = get_topology(spec.split("+")[2])
        assert train[spec] == pytest.approx(
            model.predict(topo.plan(model.n_rows, model.d, 4)))
    with pytest.raises(ValueError, match="rank mode"):
        planner.rank_specs(model, 4, mode="batch")


def test_serving_and_train_rankings_can_invert(monkeypatch):
    """The point of the serving mode: a topology that wins on wire bytes
    at training row counts loses at micro-batch sizes if it takes more
    hops.  The built-in topologies all ship the bandwidth-optimal byte
    count (torus2d dominates outright), so the inversion is demonstrated
    on a synthetic few-hop/fat-message topology — the shape a new
    registration could legally have."""
    from repro.engine import planner, registry
    from repro.topology.base import Topology

    class FatPipe(Topology):
        """One hop, but 6× the wire bytes (redundant wide messages)."""

        def steps(self, n_cores):
            return 1

        def bytes_per_core(self, n_rows, d, n_cores, dtype_bytes=4):
            return 6 * super().bytes_per_core(n_rows, d, n_cores,
                                              dtype_bytes)

    inst = FatPipe()
    inst.name = "fatpipe"
    registry._ensure_topologies()
    monkeypatch.setitem(registry._TOPOLOGIES, "fatpipe", inst)
    model = planner.CostModel(alpha=1e-4, beta=1e-9, const=1e-3, n_cores=4)
    cands = ["ell+pipelined+hypercube", "ell+pipelined+fatpipe"]
    train = planner.rank_specs(model, 4, candidates=cands)
    serving = planner.rank_specs(model, 4, candidates=cands,
                                 mode="serving", max_batch=8)
    # train (512 rows): β·bytes dominates → the lean 2-hop hypercube wins
    assert train[0][0] == "ell+pipelined+hypercube"
    # serving (1..8 rows): bytes are negligible, α·steps dominates → the
    # 1-hop fat pipe wins despite shipping 6× the bytes
    assert serving[0][0] == "ell+pipelined+fatpipe"


def test_serving_mode_skips_persisted_train_winner(tmp_path):
    """Tier 1 records measure training step THROUGHPUT — the wrong
    objective for micro-batch latency — so ``mode="serving"`` must skip
    them and rank through the cost model."""
    from repro.engine import planner

    key = planner._entry_key("cpu", 4, "default")
    _write(tmp_path / "planner.json",
           {"entries": {key: {"spec": "block+pipelined+ring"}}})
    _write(tmp_path / "topology.json",
           _topology_record(n_cores=4, alpha=1e-6, beta=1e-7, const=1e-4))
    # train mode: the persisted winner beats everything …
    assert planner.resolve_spec(n_cores=4,
                                backend="cpu") == "block+pipelined+ring"
    # … serving mode ignores it and takes the analytic tier's pick (the
    # byte-dominated planted model favors torus2d's orthogonal halves)
    got = planner.resolve_spec(n_cores=4, backend="cpu", mode="serving")
    assert got == "ell+pipelined+torus2d"
    # no topology record either → the static fallback, never the tier-1 hit
    (tmp_path / "topology.json").unlink()
    got = planner.resolve_spec(n_cores=4, backend="cpu", mode="serving")
    assert got == planner.DEFAULT_SPEC


# ---------------------------------------------------------------------------
# Engine("auto") end-to-end on simulated devices.
# ---------------------------------------------------------------------------
def test_auto_resolves_and_trains_on_2_and_4_devices():
    run_subprocess(textwrap.dedent("""
        from repro.engine import Engine, supported_specs
        from repro.launch.trainer import Trainer

        concrete = set(supported_specs(three_part=True))
        for n in (2, 4):
            eng = Engine('auto')
            resolved = eng.resolve(n)
            assert not resolved.is_auto
            # .spec canonicalizes the default topology to the 2-part form
            assert resolved.config.with_spec(resolved.spec) \
                .spec == resolved.spec
            tr = Trainer('auto', 'flickr', n_cores=n, scale=0.005,
                         feat_dim=16, hidden=16, batch_size=16, lr=0.2,
                         seed=0, input_pipeline='prefetch', val_batches=1)
            assert tr.requested_spec == 'auto'
            assert not tr.engine.is_auto
            out = tr.fit(1, steps_per_epoch=3)
            assert out['requested_spec'] == 'auto'
            assert out['spec'] != 'auto'
            assert len(out['loss_history']) == 3
        print('OK')
        """), n_devices=4)


def test_auto_resume_pins_resolved_spec_bit_exact(tmp_path):
    """Checkpoint an auto run mid-stream, then CHANGE the planner record
    under it; the resumed run must pin the checkpoint's concrete spec (not
    re-plan) and replay the loss trajectory bit-exactly."""
    run_subprocess(textwrap.dedent(f"""
        import json, os
        from repro.launch.trainer import Trainer

        kw = dict(scale=0.005, feat_dim=16, hidden=16, batch_size=16,
                  lr=0.2, seed=3, input_pipeline='prefetch', val_batches=1)

        full = Trainer('auto', 'flickr', n_cores=2, **kw)
        pinned = full.engine.spec
        full_losses = [full.train_steps(1)[0] for _ in range(8)]
        full.close()

        part = Trainer('auto', 'flickr', n_cores=2,
                       ckpt_dir={str(tmp_path / 'ckpt')!r},
                       ckpt_every=0, **kw)
        assert part.engine.spec == pinned
        part.train_steps(4)
        part.save(sync=True)
        part.close()

        # a new autotune winner lands between save and resume: the resumed
        # run must IGNORE it and continue on the checkpointed wires
        divergent = 'coo+serial+allpairs'
        assert divergent != pinned
        from repro.engine import planner
        key = planner._entry_key('cpu', 2, 'default')
        with open(os.environ['REPRO_PLANNER_PATH'], 'w') as f:
            json.dump({{'entries': {{key: {{'spec': divergent}}}}}}, f)
        fresh = Trainer('auto', 'flickr', n_cores=2,
                        ckpt_dir={str(tmp_path / 'ckpt')!r},
                        ckpt_every=0, **kw)
        assert fresh.engine.spec == divergent      # pre-resume: re-planned
        assert fresh.resume() is True
        assert fresh.engine.spec == pinned         # checkpoint wins
        assert fresh.requested_spec == 'auto'
        res = [fresh.train_steps(1)[0] for _ in range(4)]
        fresh.close()
        assert res == full_losses[4:], (res, full_losses[4:])
        print('OK')
        """), n_devices=2)


def test_autotune_persists_and_auto_follows_winner():
    """The compile-and-replay tier end to end at toy sizes: autotune two
    candidate specs, check the persisted entry, and a fresh
    ``Engine('auto')`` resolves through it."""
    run_subprocess(textwrap.dedent("""
        import json, os
        from repro.engine import Engine, EngineConfig, planner

        stats = planner.GraphStats(n_dst=32, n_src=64, avg_deg=4.0,
                                   feat_dim=16)
        cands = ['ell+pipelined+hypercube', 'coo+serial+allpairs']
        entry = planner.autotune(stats, n_cores=2, candidates=cands,
                                 n_steps=1, n_trials=2)
        assert entry['spec'] in cands
        assert entry['loss_match'] is True
        assert set(entry['s_per_step']) == set(cands)
        # persisted under the env-var path with the composite key
        rec = json.load(open(os.environ['REPRO_PLANNER_PATH']))
        key = planner._entry_key(entry['backend'], 2, stats.bucket())
        assert rec['entries'][key]['spec'] == entry['spec']
        # idempotent: a re-run returns the persisted entry, no re-measure
        again = planner.autotune(stats, n_cores=2, candidates=cands,
                                 n_steps=1, n_trials=2)
        assert again == entry
        # and Engine('auto') follows the winner (canonicalized)
        resolved = Engine('auto').resolve(2, graph_stats=stats)
        assert resolved.spec == EngineConfig.from_spec(entry['spec']).spec
        print('OK')
        """), n_devices=2)
