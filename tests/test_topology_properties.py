"""Hypothesis property tests for topology routing correctness.

For every registered topology, on 2/4/8 simulated devices and randomized
block/feature shapes:

  * **exactly-once delivery** — the allgather must reproduce every
    sender's block verbatim in core order (no drop, no duplicate, no
    reorder), and a reduce-scatter of power-of-two sender tags
    (``partial[j][·] = 2^j``, exactly representable and uniquely
    decomposable in fp32) must equal ``2^P − 1`` everywhere: any dropped
    or duplicated message changes the exact sum;
  * **reduction-order tolerance** — random partials reduce to within
    ≤1e-5 of the float64 dense oracle, whatever per-topology add order.

Shapes deliberately include ``d = 1`` (torus2d's feature split
degenerates to a single fold) and odd ``d`` (uneven halves).
"""
import textwrap

import pytest

pytest.importorskip("hypothesis",
                    reason="property tests need hypothesis "
                           "(pip install -e .[test])")
from hypothesis import given, settings, strategies as st  # noqa: E402

from conftest import run_subprocess  # noqa: E402


@pytest.mark.parametrize("n_devices", [2, 4, 8])
@settings(max_examples=3, deadline=None)
@given(seed=st.integers(0, 2**20), t=st.integers(1, 7),
       d=st.sampled_from([1, 3, 8, 17]))
def test_every_topology_delivers_exactly_once(n_devices, seed, t, d):
    run_subprocess(textwrap.dedent(f"""
        import jax, numpy as np, jax.numpy as jnp
        from jax.sharding import Mesh, PartitionSpec as P
        from repro.compat import shard_map
        from repro.engine import available_topologies, get_topology

        PC, t, d, seed = {n_devices}, {t}, {d}, {seed}
        rng = np.random.default_rng(seed)
        mesh = Mesh(np.array(jax.devices()), ('model',))
        part = jnp.asarray(rng.standard_normal((PC, PC, t, d)), jnp.float32)
        dense = np.asarray(part, np.float64).sum(0)      # [PC, t, d] oracle
        tags = jnp.broadcast_to(
            (2.0 ** jnp.arange(PC, dtype=jnp.float32))[:, None, None, None],
            (PC, PC, t, d))                              # sender j sends 2^j
        xg = jnp.asarray(rng.standard_normal((PC, t, d)), jnp.float32)
        for name in available_topologies():
            topo = get_topology(name)
            rs = shard_map(
                lambda p, tp=topo: tp.reduce_scatter(p[0], 'model',
                                                     PC)[None],
                mesh=mesh, in_specs=(P('model'),), out_specs=P('model'))
            # exactly-once, exact arithmetic: sum of distinct powers of two
            got = np.asarray(rs(tags))
            assert np.all(got == float(2 ** PC - 1)), (
                name, 'tag sum broken: a message was dropped or duplicated')
            # reduction-order tolerance vs the float64 dense oracle
            y = np.asarray(rs(part))
            err = np.abs(y - dense).max()
            assert err <= 1e-5, (name, err)
            # allgather: every device must hold every block verbatim, in
            # core order — delivery is exact, not approximate
            ag = shard_map(
                lambda x, tp=topo: tp.allgather(x[0], 'model', PC)[None],
                mesh=mesh, in_specs=(P('model'),), out_specs=P('model'))
            g = np.asarray(ag(xg))                       # [PC, PC, t, d]
            for i in range(PC):
                assert np.array_equal(g[i], np.asarray(xg)), (
                    name, f'device {{i}} gathered wrong/reordered blocks')
        print('OK', available_topologies())
    """), n_devices=n_devices)
