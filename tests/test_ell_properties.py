"""Hypothesis property tests for the pre-reduced ELL engine: random graphs
with isolated nodes, high-degree skew, and non-multiple-of-tile shapes."""
import numpy as np
import pytest
pytest.importorskip("hypothesis",
                    reason="property tests need hypothesis "
                           "(pip install -e .[test])")
from hypothesis import given, settings, strategies as st


def _random_skewed_coo(seed, n_dst, n_src, e, hub_frac):
    """Graph generator the properties share: a hub row soaks up
    ``hub_frac`` of the edges (degree skew), and some dst rows stay
    isolated because edges only target the lower half of the row range."""
    from repro.graph.coo import from_edges

    rng = np.random.default_rng(seed)
    n_hub = int(e * hub_frac)
    rows = np.concatenate([
        rng.integers(0, max(n_dst // 2, 1), e - n_hub),  # upper half isolated
        np.zeros(n_hub, np.int64),                        # the hub row
    ])
    cols = rng.integers(0, n_src, e)
    vals = rng.standard_normal(e).astype(np.float32)
    return from_edges(rows, cols, vals, n_dst, n_src), rng


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10_000), st.integers(1, 97), st.integers(1, 83),
       st.integers(0, 600), st.floats(0.0, 0.5),
       st.sampled_from(["pow2", "single", (3, 9)]))
def test_ell_walk_matches_oracle(seed, n_dst, n_src, e, hub_frac, caps):
    import jax.numpy as jnp
    from repro.kernels import edgeplan
    from repro.kernels.ops import ell_apply
    from repro.kernels.ref import spmm_ref, spmm_t_ref

    coo, rng = _random_skewed_coo(seed, n_dst, n_src, e, hub_frac)
    plan = edgeplan.build_plan(coo, caps=caps)
    d = int(rng.integers(1, 40))
    x = jnp.asarray(rng.standard_normal((n_src, d)), jnp.float32)
    ref = np.asarray(spmm_ref(coo.rows, coo.cols, coo.vals, x, n_dst))
    out = np.asarray(ell_apply(plan.device_tables(), x, use_pallas=False))
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-5)
    err = jnp.asarray(rng.standard_normal((n_dst, d)), jnp.float32)
    tref = np.asarray(spmm_t_ref(coo.rows, coo.cols, coo.vals, err, n_src))
    tout = np.asarray(ell_apply(plan.device_tables(), err, transpose=True,
                                use_pallas=False))
    np.testing.assert_allclose(tout, tref, rtol=1e-4, atol=1e-5)


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 10_000), st.integers(1, 70), st.integers(1, 50),
       st.integers(0, 300), st.floats(0.0, 0.4))
def test_ell_pallas_kernel_matches_oracle(seed, n_dst, n_src, e, hub_frac):
    """The interpret-mode Pallas kernel (src-tiled body) on ragged shapes."""
    import jax.numpy as jnp
    from repro.kernels import edgeplan
    from repro.kernels.ops import ell_apply
    from repro.kernels.ref import spmm_ref

    coo, rng = _random_skewed_coo(seed, n_dst, n_src, e, hub_frac)
    plan = edgeplan.build_plan(coo, caps="pow2")
    x = jnp.asarray(rng.standard_normal((n_src, 9)), jnp.float32)
    ref = np.asarray(spmm_ref(coo.rows, coo.cols, coo.vals, x, n_dst))
    out = np.asarray(ell_apply(plan.device_tables(), x, use_pallas=True))
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000), st.sampled_from(["coag", "agco"]),
       st.booleans())
def test_ell_engine_layer_grads_match(seed, order, activate):
    import jax
    import jax.numpy as jnp
    from repro.core.gcn import gcn_layer
    from repro.engine import Engine

    coo, rng = _random_skewed_coo(seed, 48, 56, 500, 0.3)
    eng = Engine("ell+pipelined")
    x = jnp.asarray(rng.standard_normal((56, 13)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((13, 7)), jnp.float32)

    def loss(fn):
        return lambda x, w: jnp.sum(fn(x, w) ** 2)

    y_ref = gcn_layer(coo, x, w, order=order, activate=activate)
    y_ell = eng.layer(coo, x, w, order=order, activate=activate)
    np.testing.assert_allclose(np.asarray(y_ell), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)
    g_ref = jax.grad(loss(lambda x, w: gcn_layer(
        coo, x, w, order=order, activate=activate)), argnums=(0, 1))(x, w)
    g_ell = jax.grad(loss(lambda x, w: eng.layer(
        coo, x, w, order=order, activate=activate)), argnums=(0, 1))(x, w)
    for a, b in zip(g_ref, g_ell):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                   rtol=2e-3, atol=2e-3)


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 10_000), st.integers(1, 200), st.integers(0, 64),
       st.sampled_from(["pow2", "single", (1, 4, 16)]))
def test_bucketing_partitions_rows(seed, n_rows, max_deg, caps):
    """Every row with edges lands in exactly one bucket whose capacity fits
    its merged degree; inv_perm is a bijection onto the stored rows."""
    from repro.kernels import edgeplan

    rng = np.random.default_rng(seed)
    e = int(rng.integers(0, n_rows * max(max_deg, 1)))
    rows = rng.integers(0, n_rows, e)
    cols = rng.integers(0, max(max_deg, 1), e)
    vals = rng.standard_normal(e).astype(np.float32)
    t = edgeplan.build_tables(rows, cols, vals, n_rows, max(max_deg, 1),
                              caps=caps)
    deg = edgeplan.merged_degrees(rows, cols, vals, n_rows, max(max_deg, 1))
    total = sum(c.shape[0] for c in t.cols)
    stored = t.inv_perm[deg > 0]
    assert len(np.unique(stored)) == int((deg > 0).sum())   # bijection
    assert np.all(stored < total)
    assert np.all(t.inv_perm[deg == 0] == total)            # zero-row route
    # capacity fits: per-bucket nonzero counts never exceed K, and every
    # stored row's entry count equals its merged degree
    base = 0
    for c, v in zip(t.cols, t.vals):
        nnz_rows = (v != 0).sum(axis=1)
        ids = np.flatnonzero((t.inv_perm >= base)
                             & (t.inv_perm < base + c.shape[0]))
        np.testing.assert_array_equal(
            nnz_rows[t.inv_perm[ids] - base], deg[ids])
        base += c.shape[0]
