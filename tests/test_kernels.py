"""Per-kernel allclose sweeps against the pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import gemm, gemm_ref, spmm, spmm_ref, spmm_t_ref
from repro.kernels.spmm import spmm as spmm_raw
from repro.kernels.gemm import gemm as gemm_raw


@pytest.mark.parametrize("m,k,n", [(128, 128, 128), (256, 384, 128),
                                   (128, 256, 256), (384, 128, 384)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("relu", [False, True])
def test_gemm_aligned_sweep(rng, m, k, n, dtype, relu):
    x = jnp.asarray(rng.standard_normal((m, k)), dtype)
    w = jnp.asarray(rng.standard_normal((k, n)), dtype)
    b = jnp.asarray(rng.standard_normal((n,)), dtype)
    out = gemm_raw(x, w, b, relu=relu, interpret=True)
    ref = gemm_ref(x, w, b, relu=relu)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("m,k,n", [(100, 70, 33), (1, 130, 5), (127, 1, 129)])
def test_gemm_ragged_padding_wrapper(rng, m, k, n):
    x = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)
    out = gemm(x, w, relu=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(gemm_ref(x, w)),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("n_dst,n_src,d,e", [(64, 64, 128, 512),
                                             (64, 96, 256, 1024),
                                             (128, 64, 128, 256),
                                             (8, 200, 128, 777)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_spmm_sweep(rng, n_dst, n_src, d, e, dtype):
    rows = jnp.asarray(rng.integers(0, n_dst, e), jnp.int32)
    cols = jnp.asarray(rng.integers(0, n_src, e), jnp.int32)
    vals = jnp.asarray(rng.standard_normal(e), jnp.float32)
    x = jnp.asarray(rng.standard_normal((n_src, d)), dtype)
    out = spmm(rows, cols, vals, x, n_dst)
    ref = spmm_ref(rows, cols, vals, x, n_dst)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


def test_spmm_padding_edges_are_noops(rng):
    """val == 0 ⇒ edge is a no-op, regardless of its indices (the padding
    contract every layer relies on)."""
    n_dst, n_src, d = 64, 64, 128
    rows = jnp.asarray(rng.integers(0, n_dst, 300), jnp.int32)
    cols = jnp.asarray(rng.integers(0, n_src, 300), jnp.int32)
    vals = jnp.asarray(rng.standard_normal(300), jnp.float32).at[200:].set(0)
    x = jnp.asarray(rng.standard_normal((n_src, d)), jnp.float32)
    full = spmm(rows, cols, vals, x, n_dst)
    trimmed = spmm(rows[:200], cols[:200], vals[:200], x, n_dst)
    np.testing.assert_allclose(np.asarray(full), np.asarray(trimmed),
                               rtol=1e-5, atol=1e-5)


def test_spmm_matches_transpose_oracle(rng):
    """spmm on the swapped index roles == Aᵀe oracle (Graph Converter)."""
    n_dst, n_src, d, e = 64, 80, 128, 400
    rows = jnp.asarray(rng.integers(0, n_dst, e), jnp.int32)
    cols = jnp.asarray(rng.integers(0, n_src, e), jnp.int32)
    vals = jnp.asarray(rng.standard_normal(e), jnp.float32)
    err = jnp.asarray(rng.standard_normal((n_dst, d)), jnp.float32)
    out = spmm(cols, rows, vals, err, n_src)      # roles swapped
    ref = spmm_t_ref(rows, cols, vals, err, n_src)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_gemm_block_shape_invariance(rng):
    """Different VMEM tilings must give the same result (accumulation-order
    tolerance only)."""
    x = jnp.asarray(rng.standard_normal((256, 512)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((512, 256)), jnp.float32)
    a = gemm_raw(x, w, bm=128, bn=128, bk=128, interpret=True)
    b = gemm_raw(x, w, bm=256, bn=256, bk=256, interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("bh,s,hd,qb,kb", [(4, 1024, 64, 128, 256),
                                           (2, 512, 128, 256, 128),
                                           (1, 256, 32, 128, 128)])
def test_flash_mha_sweep(rng, causal, bh, s, hd, qb, kb):
    from repro.kernels.flash import flash_mha
    from repro.kernels.ref import mha_ref
    q = jnp.asarray(rng.standard_normal((bh, s, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((bh, s, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((bh, s, hd)), jnp.float32)
    out = flash_mha(q, k, v, causal=causal, q_block=qb, k_block=kb,
                    interpret=True)
    ref = mha_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=3e-4, atol=3e-4)


def test_flash_mha_bf16(rng):
    from repro.kernels.flash import flash_mha
    from repro.kernels.ref import mha_ref
    q = jnp.asarray(rng.standard_normal((2, 512, 64)), jnp.bfloat16)
    k = jnp.asarray(rng.standard_normal((2, 512, 64)), jnp.bfloat16)
    v = jnp.asarray(rng.standard_normal((2, 512, 64)), jnp.bfloat16)
    out = flash_mha(q, k, v, q_block=128, k_block=128, interpret=True)
    ref = mha_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=5e-2, atol=5e-2)
