"""The paper's C3/C4: transpose-free backward == naive backward, with less
storage and no big transposes in the HLO; estimator reproduces Eqs. 5-8."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis",
                    reason="property tests need hypothesis "
                           "(pip install -e .[test])")
from hypothesis import given, settings, strategies as st

from repro.core.baseline import gcn_layer_baseline, residual_bytes_naive
from repro.core.estimator import (LayerShape, choose_order, storage_naive,
                                  storage_ours, time_naive, time_ours)
from repro.core.gcn import gcn_layer, residual_bytes
from repro.graph.coo import from_edges
from repro.graph.convert import sort_col_major, sort_row_major, to_backward


def _layer_inputs(rng, n_dst=24, n_src=40, d=12, h=8, e=120):
    A = from_edges(rng.integers(0, n_dst, e), rng.integers(0, n_src, e),
                   rng.standard_normal(e).astype(np.float32) * 0.3,
                   n_dst, n_src)
    x = jnp.asarray(rng.standard_normal((n_src, d)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((d, h)) * 0.3, jnp.float32)
    return A, x, w


@pytest.mark.parametrize("order", ["coag", "agco"])
@pytest.mark.parametrize("activate", [True, False])
def test_ours_equals_naive_gradients(rng, order, activate):
    A, x, w = _layer_inputs(rng)
    ct = jnp.asarray(rng.standard_normal((A.n_dst, w.shape[1])), jnp.float32)

    def loss_ours(x, w):
        return jnp.vdot(gcn_layer(A, x, w, order=order, activate=activate),
                        ct)

    def loss_naive(x, w):
        return jnp.vdot(gcn_layer_baseline(A, x, w, order=order,
                                           activate=activate), ct)

    y1 = gcn_layer(A, x, w, order=order, activate=activate)
    y2 = gcn_layer_baseline(A, x, w, order=order, activate=activate)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-5, atol=1e-5)
    g1 = jax.grad(loss_ours, argnums=(0, 1))(x, w)
    g2 = jax.grad(loss_naive, argnums=(0, 1))(x, w)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("order", ["coag", "agco"])
def test_ours_equals_autodiff(rng, order):
    """The hand-written VJP must equal plain autodiff through the math."""
    A, x, w = _layer_inputs(rng)

    def ref(x, w):
        dense = A.todense()
        if order == "coag":
            z = dense @ (x @ w)
        else:
            z = (dense @ x) @ w
        return jnp.sum(jnp.maximum(z, 0.0) ** 2)

    def ours(x, w):
        return jnp.sum(gcn_layer(A, x, w, order=order) ** 2)

    g_ref = jax.grad(ref, argnums=(0, 1))(x, w)
    g_ours = jax.grad(ours, argnums=(0, 1))(x, w)
    for a, b in zip(g_ours, g_ref):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)


def test_backward_hlo_has_no_feature_matrix_transpose(rng):
    """The transpose-free contract, checked on the compiled artifact: the
    backward of 'ours' contains no transpose of an [n, d]-sized operand
    (the baseline does — it materializes Xᵀ)."""
    A, x, w = _layer_inputs(rng, n_dst=32, n_src=64, d=16, h=8)

    def grad_ours(x, w):
        return jax.grad(lambda x, w: jnp.sum(gcn_layer(A, x, w) ** 2),
                        argnums=(0, 1))(x, w)

    def grad_naive(x, w):
        return jax.grad(
            lambda x, w: jnp.sum(gcn_layer_baseline(A, x, w) ** 2),
            argnums=(0, 1))(x, w)

    def big_transposes(fn):
        import re
        txt = jax.jit(fn).lower(x, w).compile().as_text()
        hits = []
        # an actual transpose OP (not autodiff metadata naming): result
        # shape immediately followed by ` transpose(`
        op_re = re.compile(r"f32\[(\d+),(\d+)\]\{[^}]*\}\s+transpose\(")
        for line in txt.splitlines():
            m = op_re.search(line)
            if m and int(m.group(1)) * int(m.group(2)) >= 64 * 16:
                hits.append(line.strip())
        return hits

    assert not big_transposes(grad_ours), big_transposes(grad_ours)


def test_residual_bytes_ours_below_naive():
    for order in ("coag", "agco"):
        ours = residual_bytes(order, n_dst=1024, n_src=4096, d=256, h=256)
        naive = residual_bytes_naive(order, n_dst=1024, n_src=4096, d=256,
                                     h=256, nnz=40_000)
        assert ours < naive
        # paper Eq. 7/8: the gap is ≥ one edge table + one feature transpose
        assert naive - ours >= 40_000 * 12


def test_graph_converter_is_transpose_free(rng):
    A, x, _ = _layer_inputs(rng)
    e = jnp.asarray(rng.standard_normal((A.n_dst, 4)), jnp.float32)
    bwd = to_backward(sort_row_major(A))
    y = bwd.rmatmul(e)                      # Aᵀe via column-major walk
    ref = A.todense().T @ e
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               rtol=1e-4, atol=1e-5)
    # same nnz, same values — no second edge table
    assert bwd.nnz == A.nnz


# ---------------------------------------------------------------------------
# estimator (C4)
# ---------------------------------------------------------------------------
@settings(max_examples=50, deadline=None)
@given(st.integers(8, 2048), st.integers(8, 4096), st.integers(8, 4096),
       st.integers(8, 512), st.integers(8, 512), st.integers(1, 200_000),
       st.integers(2, 100))
def test_eqs_5_to_8_ours_never_worse(b, n, nbar, d, h, e, c):
    """Paper Eqs. 5-8: TC(naive − ours) > 0 and SC(naive − ours) > 0 for any
    admissible shape (nbar ≥ n: the frontier grows)."""
    n, nbar = min(n, nbar), max(n, nbar)
    s = LayerShape(b=min(b, n), n=n, nbar=nbar, d=d, h=h, e=e, c=c)
    for order in ("coag", "agco"):
        assert time_naive(s, order) > time_ours(s, order)
        assert storage_naive(s, order) > storage_ours(s, order)


def test_order_choice_flips_with_shape():
    """The paper's §4.4 point: in training the optimal order depends on the
    (rectangular) batch shape.  CoAg pays e·h, AgCo pays e·d on the edges —
    so wide-input/narrow-output layers (d ≫ h) prefer CoAg and the reverse
    prefer AgCo."""
    skinny = LayerShape(b=512, n=512, nbar=13000, d=602, h=256, e=14_000,
                        c=41)
    assert choose_order(skinny).order == "agco"
    wide_in = LayerShape(b=512, n=512, nbar=2000, d=602, h=41, e=500_000,
                         c=41)
    assert choose_order(wide_in).order == "coag"
