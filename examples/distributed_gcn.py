"""The paper's architecture end-to-end, distributed, through the
engine-native Trainer: 16 virtual devices play the 16 cores — local
combination GEMMs, hypercube message-passing aggregation with sender-side
pre-reduction, transpose-free backward, Weight-Bank gradient sync — while
the async input pipeline (sampling + per-batch layout build on a prefetch
thread, depth-2 double buffering) keeps the device step fed, NUMA-staging
style (paper §4.2–4.3).

    XLA_FLAGS=--xla_force_host_platform_device_count=16 \
        PYTHONPATH=src python examples/distributed_gcn.py [SPEC] \
        [--dataset reddit|flickr|yelp|amazonproducts] [--epochs 2]

SPEC is an engine spec string (default ``ell+pipelined``) — any registered
format+schedule combination trains unchanged: ``coo+serial``,
``block+pipelined``, ``ell+pipelined``.  ``--dataset`` picks the synthetic
stand-in (paper §5.1 stats); the default ``reddit`` scenario and e.g.
``--dataset flickr`` demonstrate the same Trainer on different graph
skews/feature widths with zero code change.  ``--feature-store mmap``
moves the node features out-of-core: they live in a memory-mapped file,
only each batch's frontier rows stream to the devices through the staged
prefetch chain (sample → gather → layout → place), and a degree-keyed
hot-vertex cache absorbs the hub traffic.
"""
import argparse
import os

if "xla_force_host_platform_device_count" not in \
        os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=16")

from repro.launch.trainer import Trainer  # noqa: E402


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("spec", nargs="?", default="ell+pipelined")
    ap.add_argument("--dataset", default="reddit",
                    help="synthetic stand-in to train on (flickr, reddit, "
                         "yelp, amazonproducts)")
    ap.add_argument("--epochs", type=int, default=2)
    ap.add_argument("--steps-per-epoch", type=int, default=10)
    ap.add_argument("--n-cores", type=int, default=16)
    ap.add_argument("--feature-store", default="device",
                    help="'device' (dense in-memory features) or a "
                         "registered featurestore backend ('host', 'mmap')"
                         " to stream frontier rows out-of-core")
    ap.add_argument("--cache-capacity", type=int, default=256,
                    help="hot-vertex cache rows in front of the store")
    args = ap.parse_args()

    fs = None if args.feature_store == "device" else args.feature_store
    trainer = Trainer(args.spec, args.dataset, n_cores=args.n_cores,
                      scale=0.005, feat_dim=64, hidden=64, batch_size=64,
                      fanouts=(5, 10), lr=0.1, seed=0,
                      input_pipeline="prefetch", pad_multiple=64,
                      val_batches=2, feature_store=fs,
                      cache_capacity=args.cache_capacity)
    print(f"mesh: {dict(trainer.mesh.shape)} — each device is one of the "
          f"paper's {trainer.n_cores} hypercube cores; engine spec: "
          f"{trainer.engine.spec}; dataset: {args.dataset}")
    if trainer.store is not None:
        print(f"features: out-of-core via the {trainer.feature_mode} store "
              f"({trainer.store.nbytes / 1e6:.1f} MB backing, "
              f"{args.cache_capacity}-row hot-vertex cache)")
    out = trainer.fit(args.epochs, steps_per_epoch=args.steps_per_epoch)
    if "cache" in out:
        c = out["cache"]
        print(f"store traffic: {out['gather_bytes'] / 1e6:.2f} MB gathered, "
              f"cache hit-rate {c['hit_rate']:.2f} "
              f"({c['hits']} hits / {c['misses']} misses)")
    for ep, (acc, sps, stall) in enumerate(zip(
            out["val_acc"], out["steps_per_s"],
            out["host_stall_s_per_step"]), start=1):
        print(f"epoch {ep}: val_acc {acc:.3f}  {sps:.2f} steps/s  "
              f"host stall/step {stall * 1e3:.1f} ms")
    print(f"done — loss {out['loss_history'][0]:.4f} -> "
          f"{out['loss_history'][-1]:.4f} in {out['wall_s']:.1f}s; "
          "combination stayed core-local, aggregation rode the hypercube "
          f"under the {trainer.engine.spec} engine, weights synced via the "
          "Weight Bank pmean, and the host pipeline prefetched every batch")


if __name__ == "__main__":
    main()
