"""The paper's architecture end-to-end, distributed: 16 virtual devices play
the 16 cores — local combination GEMMs, hypercube message-passing
aggregation with sender-side pre-reduction, transpose-free backward, and
Weight-Bank gradient sync, all through the declarative Engine API.

    XLA_FLAGS=--xla_force_host_platform_device_count=16 \
        PYTHONPATH=src python examples/distributed_gcn.py [SPEC]

SPEC is an engine spec string (default ``ell+pipelined``) — any registered
format+schedule combination works unchanged: ``coo+serial``,
``block+pipelined``, ``ell+pipelined``.
"""
import os
import sys

if "xla_force_host_platform_device_count" not in \
        os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=16")

import jax                      # noqa: E402
from repro.compat import set_mesh  # noqa: E402
import numpy as np              # noqa: E402

from repro.engine import Engine, EngineConfig  # noqa: E402
from repro.distributed.gcn_train import init_params  # noqa: E402
from repro.graph import NeighborSampler, make_dataset  # noqa: E402


def main(spec: str = "ell+pipelined") -> None:
    ds = make_dataset("reddit", scale=0.005, feat_dim=64)
    sampler = NeighborSampler(ds.graph, fanouts=(5, 10), pad_multiple=16,
                              seed=0)
    mesh = jax.make_mesh((16,), ("model",))
    engine = Engine(EngineConfig.from_spec(spec, lr=0.1))
    bundle = engine.build(mesh)
    print(f"mesh: {dict(mesh.shape)} — each device is one of the paper's "
          f"16 hypercube cores; engine spec: {engine.spec}")
    rng = np.random.default_rng(0)
    params = init_params(jax.random.PRNGKey(0),
                         [(64, 64), (64, ds.stats.n_classes)])
    with set_mesh(mesh):
        for i in range(20):
            seeds = rng.permutation(ds.graph.n_nodes)[:64]
            mb = sampler.sample(seeds, nnz_pad=sampler.static_nnz(64),
                                rng=np.random.default_rng(i))
            feats = ds.features[np.minimum(mb.input_nodes,
                                           ds.graph.n_nodes - 1)]
            pad = mb.layers[0].n_dst - len(seeds)
            labels = ds.labels[np.pad(seeds, (0, pad))]
            batch = bundle.shard_batch(mb, feats, labels)
            params, loss = bundle.train_step(params, batch)
            if i % 5 == 0:
                print(f"step {i:3d}  loss {float(loss):.4f}")
    print("done — combination stayed core-local, aggregation rode the "
          f"hypercube under the {engine.spec} engine, weights synced via "
          "the Weight Bank psum")


if __name__ == "__main__":
    main(*sys.argv[1:2])
