"""The paper's architecture end-to-end, distributed: 16 virtual devices play
the 16 cores — local combination GEMMs, hypercube message-passing
aggregation with sender-side pre-reduction, transpose-free backward, and
Weight-Bank gradient sync.

    XLA_FLAGS=--xla_force_host_platform_device_count=16 \
        PYTHONPATH=src python examples/distributed_gcn.py
"""
import os

if "xla_force_host_platform_device_count" not in \
        os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=16")

import jax                      # noqa: E402
from repro.compat import set_mesh  # noqa: E402
import numpy as np              # noqa: E402

from repro.distributed.gcn_train import (init_params, make_train_step,  # noqa: E402
                                         shard_minibatch)
from repro.graph import NeighborSampler, make_dataset  # noqa: E402


def main() -> None:
    ds = make_dataset("reddit", scale=0.005, feat_dim=64)
    sampler = NeighborSampler(ds.graph, fanouts=(5, 10), pad_multiple=16,
                              seed=0)
    mesh = jax.make_mesh((16,), ("model",))
    print(f"mesh: {dict(mesh.shape)} — each device is one of the paper's "
          f"16 hypercube cores")
    rng = np.random.default_rng(0)
    params = init_params(jax.random.PRNGKey(0),
                         [(64, 64), (64, ds.stats.n_classes)])
    step = None
    with set_mesh(mesh):
        for i in range(20):
            seeds = rng.permutation(ds.graph.n_nodes)[:64]
            mb = sampler.sample(seeds, nnz_pad=sampler.static_nnz(64),
                                rng=np.random.default_rng(i))
            feats = ds.features[np.minimum(mb.input_nodes,
                                           ds.graph.n_nodes - 1)]
            pad = mb.layers[0].n_dst - len(seeds)
            labels = ds.labels[np.pad(seeds, (0, pad))]
            batch = shard_minibatch(mb, feats, labels, 16)
            if step is None:
                step = make_train_step(mesh, batch["dims"], lr=0.1)
            params, loss = step(params, batch)
            if i % 5 == 0:
                print(f"step {i:3d}  loss {float(loss):.4f}")
    print("done — combination stayed core-local, aggregation rode the "
          "hypercube, weights synced via the Weight Bank psum")


if __name__ == "__main__":
    main()
