"""The paper's core: route message waves over the 4-D hypercube with
Algorithm 1 and compare against the static dimension-ordered schedule.

    PYTHONPATH=src python examples/routing_playground.py
"""
import numpy as np

from repro.core.routing import (make_fuse_wave, route_messages,
                                validate_routing)
from repro.core.schedule import compare_schedules
from repro.core.blockmsg import build_waves, wave_statistics
from repro.graph.coo import from_edges
from repro.graph.partition import block_partition


def main() -> None:
    rng = np.random.default_rng(0)

    # --- a Fuse4 wave: 64 messages, 4 per source core -----------------
    src, dst = make_fuse_wave(4, rng)
    res = route_messages(src, dst, seed=1)
    validate_routing(res, src, dst)
    print(f"Fuse4 wave: {len(src)} messages in {res.cycles} cycles "
          f"(lower bound 4)")
    print("cycle-by-cycle positions of message 0:",
          list(res.positions[:, 0]))
    print(compare_schedules(src, dst, seed=1))

    # --- Block Messages from a real subgraph ---------------------------
    n = 1024
    e = 8000
    coo = from_edges(rng.integers(0, n, e), rng.integers(0, n, e),
                     rng.standard_normal(e).astype(np.float32), n, n)
    waves = build_waves(block_partition(coo, 16))
    stats = wave_statistics(waves)
    print(f"\n{int(stats['raw_edges'])} edges compressed into "
          f"{int(stats['wire_messages'])} block messages "
          f"({stats['compression']:.2f}x, the paper's Reduced-Register-File "
          f"merge) across {int(stats['waves'])} waves")


if __name__ == "__main__":
    main()
