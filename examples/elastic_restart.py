"""Fault-tolerance walkthrough: train, lose a worker, checkpoint, shrink the
mesh plan, resume from the checkpoint — the full recovery path in one file.

    PYTHONPATH=src python examples/elastic_restart.py
"""
import tempfile

from repro.checkpoint import CheckpointManager, scale_plan
from repro.launch.train import train_lm


def main() -> None:
    with tempfile.TemporaryDirectory() as ckpt:
        print("== phase 1: train with a worker dying at step 5 ==")
        out = train_lm("llama3.2-1b", smoke=True, steps=10, batch=2, seq=32,
                       ckpt_dir=ckpt, fault_at=5, log_every=2)
        print(f"survivors: {out['survivors']} (worker 3 evicted)")

        plan = scale_plan(n_available=255, model_parallel=16)
        print(f"survivor mesh plan: {plan.mesh_shape} "
              f"({plan.n_devices} devices)")

        print("== phase 2: resume from the crash checkpoint ==")
        mgr = CheckpointManager(ckpt)
        print(f"resuming from step {mgr.latest_step()}")
        out2 = train_lm("llama3.2-1b", smoke=True, steps=14, batch=2, seq=32,
                        ckpt_dir=ckpt, resume=True, log_every=2)
        print(f"final loss {out2['losses'][-1]:.4f}")


if __name__ == "__main__":
    main()
