"""Serve a smoke-scale llama3.2-1b with continuous batching.

    PYTHONPATH=src python examples/serve_lm.py
"""
import numpy as np

from repro.launch.lm_serve import Request, Server


def main() -> None:
    rng = np.random.default_rng(0)
    srv = Server("llama3.2-1b", slots=4, max_seq=96)
    for i in range(8):
        prompt = rng.integers(0, srv.cfg.vocab,
                              rng.integers(4, 10)).astype(np.int32)
        srv.submit(Request(rid=i, prompt=prompt, max_new=12))
    stats = srv.run()
    print(f"served {len(srv.completed)} requests / {stats['tokens']} tokens "
          f"in {stats['steps']} steps ({stats['tok_per_s']:.1f} tok/s)")
    for r in srv.completed[:3]:
        print(f"  req {r.rid}: prompt {list(r.prompt)} -> {r.generated}")


if __name__ == "__main__":
    main()
