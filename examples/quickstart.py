"""Quickstart — train the paper's 2-layer GCN on a synthetic Flickr-like
graph with the transpose-free dataflow, the sequence estimator choosing the
execution order, and checkpointing enabled.

    PYTHONPATH=src python examples/quickstart.py

The aggregation engine is declared, not flag-selected: ``engine`` names a
registered format+schedule spec (``repro.engine.supported_specs()`` lists
them all).
"""
import tempfile

from repro.launch.train import train_gcn


def main() -> None:
    with tempfile.TemporaryDirectory() as ckpt:
        out = train_gcn(
            "flickr",                # synthetic stand-in (paper §5.1 stats)
            model="gcn",             # or "sage"
            dataflow="ours",         # the paper's Table-1 redesign
            engine="coo+serial",     # Engine spec: format+schedule
            scale=0.01,              # shrink for CPU
            batch_size=64,
            steps=100,
            lr=0.05,
            ckpt_dir=ckpt,
        )
    print(f"\nestimator chose per-layer orders: {out['orders']}")
    print(f"loss: {out['loss_history'][0]:.4f} -> "
          f"{out['loss_history'][-1]:.4f} in {out['wall_s']:.1f}s")


if __name__ == "__main__":
    main()
