"""Shared persistence for tuned records — one file→env→default contract.

Two tuners persist winners to JSON so later processes just read the file:
the ELL kernel autotuner (:mod:`repro.kernels.tune`,
``BENCH_autotune.json``) and the spec planner
(:mod:`repro.engine.planner`, ``BENCH_planner.json``).  Both resolve their
path the same way — an explicit argument beats the ``$REPRO_*_PATH``
environment override beats the default filename in the CWD — and both
must treat a missing, unreadable or corrupt file as "no record" (library
imports and tests stay hermetic; a broken cache can never crash a
training run).  :class:`RecordStore` is that contract, extracted once.

Stores hold plain JSON dicts; schema and staleness checks (backend match,
registered-spec checks) stay with the consumer — the store only owns
where the record lives and how read/write failures degrade.
"""
from __future__ import annotations

import json
import os
import warnings
from typing import Dict, Optional


class RecordStore:
    """File-backed JSON record with env-var path override.

    ``path()`` resolution: explicit argument → ``$<env_var>`` → the
    default filename in the CWD (benchmarks/CI write and upload it there).
    """

    def __init__(self, default_filename: str, env_var: str):
        self.default_filename = default_filename
        self.env_var = env_var

    def path(self, path: Optional[str] = None) -> str:
        if path is not None:
            return path
        return os.environ.get(self.env_var, self.default_filename)

    def load(self, path: Optional[str] = None, *,
             warn_corrupt: bool = False) -> Optional[Dict]:
        """The record dict, or ``None`` when the file is missing,
        unreadable, corrupt, or not a JSON object.  ``warn_corrupt`` emits
        a ``RuntimeWarning`` for files that exist but cannot be used —
        callers fall back, they never crash on a bad cache."""
        p = self.path(path)
        if not os.path.exists(p):
            return None
        try:
            with open(p) as f:
                rec = json.load(f)
        except (OSError, ValueError) as e:
            if warn_corrupt:
                warnings.warn(f"ignoring unreadable record {p!r}: {e}",
                              RuntimeWarning, stacklevel=2)
            return None
        if not isinstance(rec, dict):
            if warn_corrupt:
                warnings.warn(f"ignoring non-object record {p!r}",
                              RuntimeWarning, stacklevel=2)
            return None
        return rec

    def save(self, rec: Dict, path: Optional[str] = None) -> str:
        p = self.path(path)
        with open(p, "w") as f:
            json.dump(rec, f, indent=1)
        return p
