"""The three built-in formats and two schedules, registered.

Formats declare their interconnect support via ``Format.topologies``
(``None`` = every registered topology): all three built-ins leave it open —
the exchange fold is layout-agnostic, so coo/block/ell ride hypercube,
allpairs, ring or torus2d unchanged, and ``device_aggregate`` simply
forwards the resolved topology name into the aggregation custom_vjps.

Each format wraps the implementation that already owns its kernels and
``custom_vjp`` backward — nothing here re-registers a vjp.  All three
inherit :meth:`Format.prepare_batch` (per-hop ``shard`` over a sampled
``MiniBatch``) — the host-side hook the async input pipeline runs on its
prefetch thread, which is what lets the ``traceable=False`` layouts
(block tiles, ELL plans) train end-to-end on sampled graphs:

  * **coo**   — flat global-row COO (:func:`repro.distributed.aggregate.
    shard_edges` + :func:`hypercube_aggregate`; single-device layer =
    :func:`repro.core.gcn.gcn_layer`).  Serial schedule only: it is the
    fp32 oracle every other combo is tested against.
  * **block** — Block-Message tiles (:func:`shard_edges_blocked` +
    :func:`hypercube_aggregate_pipelined`; Pallas ``spmm_block`` per tile).
    Pipelined only, and fp32 BIT-exact vs the coo oracle by construction.
  * **ell**   — pre-reduced degree-bucketed ELL plans
    (:func:`shard_edges_ell` + :func:`hypercube_aggregate_ell`;
    scatter-free ``spmm_ell`` kernel pair, backward inherited from
    :func:`repro.kernels.ops.ell_aggregate`).  Pipelined only; matches the
    oracle to fp32 roundoff (≤1e-5 — the merge reorders additions).
"""
from __future__ import annotations

import jax

from repro.core import gcn as _gcn
from repro.distributed import aggregate as _agg

from .registry import Format, Schedule, register_format, register_schedule


@register_schedule("serial")
class SerialSchedule(Schedule):
    description = ("log2(P) dimension-ordered hypercube fold, one wave; "
                   "every round's wire transfer completes before its MAC "
                   "work starts")


@register_schedule("pipelined")
class PipelinedSchedule(Schedule):
    description = ("double-buffered fold: feature waves issue their "
                   "ppermute sends before any wave's local add consumes a "
                   "received half (paper §4.2 ping-pong Block-Message "
                   "buffers)")

    def resolve_n_chunks(self, n_chunks):
        if n_chunks is None:
            return _agg.default_n_chunks()
        return int(n_chunks)


@register_format("coo")
class CooFormat(Format):
    schedules = ("serial",)
    traceable = True                 # the layout IS the COO — jits freely
    cache_layouts = False            # identity build: nothing worth caching

    def build_local(self, coo, cfg):
        return coo

    def layer(self, layout, x, w, *, order="coag", activate=True):
        return _gcn.gcn_layer(layout, x, w, order=order, activate=activate)

    def shard(self, coo, n_cores, cfg):
        es = _agg.shard_edges(coo, n_cores)
        return ({"rows": es.rows_global, "cols": es.cols_local,
                 "vals": es.vals}, es.n_dst, es.n_src)

    def device_aggregate(self, schedule, axis_name, ndim, n_dst, leaves,
                         x_local, n_chunks, topology="hypercube"):
        return _agg.hypercube_aggregate(
            axis_name, ndim, n_dst, leaves["rows"][0], leaves["cols"][0],
            leaves["vals"][0], x_local, topology=topology)


@register_format("block")
class BlockFormat(Format):
    schedules = ("pipelined",)

    def build_local(self, coo, cfg):
        from repro.core.blockmsg import dst_tiles
        from repro.graph.partition import block_partition
        return dst_tiles(block_partition(coo, cfg.block_tiles))

    def layer(self, layout, x, w, *, order="coag", activate=True):
        return _gcn._layer_blocked_impl(layout, x, w, order=order,
                                        activate=activate)

    def shard(self, coo, n_cores, cfg):
        eb = _agg.shard_edges_blocked(coo, n_cores)
        return ({"rows": eb.rows_local, "cols": eb.cols_local,
                 "vals": eb.vals}, eb.n_dst, eb.n_src)

    def device_aggregate(self, schedule, axis_name, ndim, n_dst, leaves,
                         x_local, n_chunks, topology="hypercube"):
        return _agg.hypercube_aggregate_pipelined(
            axis_name, ndim, n_dst, leaves["rows"][0], leaves["cols"][0],
            leaves["vals"][0], x_local, n_chunks, topology=topology)


@register_format("ell")
class EllFormat(Format):
    schedules = ("pipelined",)

    def build_local(self, coo, cfg):
        from repro.kernels import edgeplan
        return edgeplan.build_plan(coo, caps=cfg.caps,
                                   merge=getattr(cfg, "merge", "dedup"))

    def layer(self, layout, x, w, *, order="coag", activate=True):
        return _gcn._layer_ell_impl(layout, x, w, order=order,
                                    activate=activate)

    def shard(self, coo, n_cores, cfg):
        ee = _agg.shard_edges_ell(coo, n_cores, caps=cfg.caps,
                                  merge=getattr(cfg, "merge", "dedup"))
        return (ee.tables, ee.n_dst, ee.n_src)

    def device_aggregate(self, schedule, axis_name, ndim, n_dst, leaves,
                         x_local, n_chunks, topology="hypercube"):
        lead = jax.tree_util.tree_leaves(leaves)[0].shape[0]
        if lead != 1:
            # fail loudly: stripping [0] below would silently drop the
            # other senders' tables (the blocked path's tile-count guard,
            # re-established for the ELL layout)
            raise ValueError(
                f"ELL edge tables hold {lead} senders per device; the "
                "batch was built for a different core count than this "
                "mesh — rebuild it with shard_batch on a bundle whose "
                "mesh has the matching core count")
        tables = jax.tree_util.tree_map(lambda a: a[0], leaves)
        return _agg.hypercube_aggregate_ell(axis_name, ndim, n_dst, tables,
                                            x_local, n_chunks,
                                            topology=topology)
