"""The Engine — one declarative entry point for every aggregation path.

``Engine(EngineConfig(...))`` (or ``Engine("ell+pipelined")``) resolves the
registered format and schedule once; ``engine.build(mesh)`` returns an
:class:`EngineBundle` — the compiled surface everything runs through:

    eng = Engine("ell+pipelined")
    bundle = eng.build(mesh)                       # mesh = the core axis
    batch = bundle.shard_batch(mb, feats, labels)  # host prep + placement
    params, loss = bundle.train_step(params, batch)
    y = bundle.aggregate(x, graph=coo)             # y = A @ x, distributed

The bundle owns the jit caches (one compiled step/forward per layer-dims
signature, one aggregator per graph), commits every batch leaf to its
core-axis sharding at build time (placement once per minibatch — the fix
for the measured re-layout-per-step regression), and derives ``shard_map``
specs from the batch pytree itself so any format's leaf structure works.

Single-device use needs no mesh: ``eng.layer(coo, x, w)`` runs the
format's GCN layer (layout built and cached per graph) with its
transpose-free backward.

``Engine("auto")`` defers the triple to :mod:`repro.engine.planner`:
:meth:`Engine.resolve` turns it into a concrete engine for a core count
(persisted autotune winner → fitted cost model → static fallback — pure
reads, no implicit sweep), and :meth:`Engine.build` resolves
automatically from the mesh's core count.  Resolution is cached per
(core count, stats bucket) so one auto engine resolves once.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map

from . import formats as _formats  # noqa: F401  (registers built-ins)
from .config import EngineConfig
from .registry import (Format, Schedule, get_format, get_schedule,
                       get_topology)

Dims = Tuple[Tuple[int, int], ...]


def _layout_cache_key(coo, *extra) -> tuple:
    from repro.kernels import edgeplan
    return edgeplan.coo_key(coo, "engine", *extra)


class Engine:
    """Resolved (format, schedule, topology) triple + the builders around
    them."""

    def __init__(self, config: Union[EngineConfig, str]):
        if isinstance(config, str):
            config = EngineConfig.from_spec(config)
        self.config: EngineConfig = config
        if config.is_auto:
            # deferred: the planner picks the triple at resolve/build time
            self.format = self.schedule = self.topology = None
            self._resolved: Dict[tuple, "Engine"] = {}
        else:
            self.format: Format = get_format(config.format)
            self.schedule: Schedule = get_schedule(config.schedule)
            self.topology = get_topology(config.topology)

    @property
    def spec(self) -> str:
        return self.config.spec

    @property
    def is_auto(self) -> bool:
        return self.config.is_auto

    @classmethod
    def available_specs(cls, *, three_part: bool = False) -> list:
        """Every spec ``Engine(...)`` accepts (the registry's canonical
        enumeration): two-part spellings plus ``"auto"`` by default, the
        concrete three-part product with ``three_part=True``."""
        from .registry import supported_specs
        return supported_specs(three_part=three_part)

    def resolve(self, n_cores: int, graph_stats=None) -> "Engine":
        """This engine with ``"auto"`` made concrete for ``n_cores``.

        Concrete engines return themselves; an auto engine asks the
        planner (:func:`repro.engine.planner.resolve_spec` — persisted
        winner → cost model → static fallback, never a sweep) and caches
        the result per (core count, stats bucket), carrying every knob of
        this config onto the resolved spec.
        """
        if not self.is_auto:
            return self
        from . import planner
        key = (int(n_cores),
               graph_stats.bucket() if graph_stats is not None else None)
        eng = self._resolved.get(key)
        if eng is None:
            spec = planner.resolve_spec(n_cores=int(n_cores),
                                        graph_stats=graph_stats)
            eng = Engine(self.config.with_spec(spec))
            self._resolved[key] = eng
        return eng

    # -- single-device layer ------------------------------------------------
    def layout(self, graph):
        """This format's single-device layout for ``graph`` (cached per COO
        identity when the graph is concrete; tracers build uncached)."""
        if self.is_auto:              # single-device: resolve at P=1
            return self.resolve(1).layout(graph)
        build = lambda: self.format.build_local(graph, self.config)  # noqa: E731
        if isinstance(graph.rows, jax.core.Tracer):
            if not self.format.traceable:
                raise ValueError(
                    f"format {self.config.format!r} builds its layout "
                    "host-side and cannot run on a traced graph (e.g. "
                    "inside jit over sampled COO layers); build the layout "
                    "outside the trace, or use a traceable format such as "
                    '"coo"')
            # inside a trace there is no stable identity to cache on
            return build()
        if not self.format.cache_layouts:
            return build()
        from repro.kernels import edgeplan
        key = _layout_cache_key(graph, self.config.format, self.config.caps,
                                self.config.block_tiles, self.config.merge)
        return edgeplan.cached(key, (graph.rows, graph.cols, graph.vals),
                               build)

    def layer(self, graph, x: jnp.ndarray, w: jnp.ndarray, *,
              order: str = "coag", activate: bool = True) -> jnp.ndarray:
        """Single-device GCN layer through this engine's format: layout
        build (cached), forward kernel, transpose-free backward."""
        if self.is_auto:
            return self.resolve(1).layer(graph, x, w, order=order,
                                         activate=activate)
        return self.format.layer(self.layout(graph), x, w, order=order,
                                 activate=activate)

    # -- distributed bundle --------------------------------------------------
    def build(self, mesh: Optional[Mesh] = None, *, graph=None,
              n_cores: Optional[int] = None) -> "EngineBundle":
        """Compile-ready bundle for ``mesh`` (``None`` + ``n_cores`` builds
        host-side shards without committing placement — single-process
        use).  ``graph`` pre-binds a default COO for ``aggregate``.  An
        explicit ``n_cores`` overrides the mesh-derived core count (shard
        shapes vs placement mesh — a mismatch fails loudly at step time).
        """
        if n_cores is None:
            if mesh is None:
                raise ValueError("Engine.build needs a mesh or n_cores")
            n_cores = int(mesh.shape[self.config.axis])
        if self.is_auto:
            return self.resolve(n_cores).build(mesh, graph=graph,
                                               n_cores=n_cores)
        # the topology owns the core-count contract (every built-in needs a
        # power-of-two count — the block partitioning does too)
        self.topology.validate_cores(n_cores)
        return EngineBundle(engine=self, mesh=mesh, n_cores=n_cores,
                            graph=graph)


class EngineBundle:
    """Everything a training/benchmark loop calls, for one (engine, mesh).

    Public surface (the issue's contract): :meth:`train_step`,
    :meth:`forward`, :meth:`aggregate`, :meth:`shard_batch` — plus the
    explicit builders (:meth:`train_step_fn`, :meth:`forward_fn`,
    :meth:`aggregator`) when a caller wants the jitted callable itself.
    """

    def __init__(self, engine: Engine, mesh: Optional[Mesh],
                 n_cores: int, graph=None):
        self.engine = engine
        self.config = engine.config
        self.format = engine.format
        self.schedule = engine.schedule
        self.topology = engine.topology
        self.mesh = mesh
        self.n_cores = n_cores
        self.ndim = int(np.log2(n_cores))
        self.axis = self.config.axis
        self.graph = graph
        self.n_chunks = self.schedule.resolve_n_chunks(self.config.n_chunks)
        self._steps: Dict[Dims, Any] = {}
        self._forwards: Dict[Dims, Any] = {}

    @property
    def spec(self) -> str:
        """The CONCRETE spec this bundle compiled (auto is resolved by
        build time — a bundle never carries ``"auto"``)."""
        return self.config.spec

    # -- host-side batch prep ------------------------------------------------
    def prepare_batch(self, mb, features, labels: np.ndarray
                      ) -> Dict[str, Any]:
        """Sampled minibatch → HOST-side batch pytree (numpy leaves, no
        device placement).

        This is the expensive per-batch half — the format's layout build
        (``Format.prepare_batch``: edge sharding, block tiling, ELL plan
        construction) — and it is pure host work, safe to run on a prefetch
        thread so it overlaps the previous device step.  Feed the result to
        :meth:`commit_batch`; :meth:`shard_batch` composes the two for
        synchronous callers.

        ``features`` is either the gathered frontier rows (a dense
        ``[n_frontier, d]`` array) or an out-of-core source — a
        :class:`~repro.featurestore.FeatureStore` or
        :class:`~repro.featurestore.HotVertexCache` — in which case the
        frontier gather (``mb.input_nodes``, clamp-indexed like
        :func:`repro.data.gather_features`) happens HERE, store-side, so
        any shard_batch caller trains out-of-core with no other change."""
        if hasattr(features, "gather"):   # FeatureStore / HotVertexCache
            ids = np.minimum(np.asarray(mb.input_nodes, np.int64),
                             features.shape[0] - 1)
            features = features.gather(ids)
        features = np.asarray(features, np.float32)
        mb, features = self._apply_partition(mb, features)
        edges, dims = self.format.prepare_batch(mb, self.n_cores,
                                                self.config)
        labels = np.asarray(labels)
        if labels.ndim == 2:
            # multilabel rows → the dominant class, the single-label proxy
            # every engine train_step shares (BCE is a loss-layer variant,
            # not an aggregation-format concern)
            labels = labels.argmax(-1)
        return {
            "edges": edges,
            "dims": dims,
            "x": features,
            "labels": labels.astype(np.int32),
            "report": self._plan_report(mb, features.shape[-1]),
        }

    def _apply_partition(self, mb, features: np.ndarray):
        """``partition="mincom"``: relabel every non-batch node space with
        the communication-minimizing permutation chain
        (:func:`repro.graph.partition.mincom_layer_perms` — space 0 stays
        identity, so labels, logits and checkpointed batch order never
        move) and permute the frontier feature rows to match.  Cached on
        the layer chain's identity in the shared edge-plan LRU — repeated
        batches (and the aggregator path) pay the greedy passes once.
        ``naive`` (and a single-core mesh) returns the batch untouched."""
        if self.config.partition != "mincom" or self.n_cores <= 1:
            return mb, features
        from repro.graph.coo import from_edges
        from repro.graph.partition import mincom_layer_perms
        from repro.kernels import edgeplan

        layers = list(mb.layers)
        key = tuple(k for coo in layers for k in
                    edgeplan.coo_key(coo, "mincom-perms", self.n_cores))
        pins = tuple(a for coo in layers
                     for a in (coo.rows, coo.cols, coo.vals))
        perms = edgeplan.cached(
            key, pins, lambda: mincom_layer_perms(layers, self.n_cores))
        relabeled = [
            from_edges(perms[i][np.asarray(coo.rows, np.int64)],
                       perms[i + 1][np.asarray(coo.cols, np.int64)],
                       np.asarray(coo.vals, np.float32),
                       coo.n_dst, coo.n_src)
            for i, coo in enumerate(layers)]

        class _RelabeledMB:           # duck-typed: formats read .layers only
            layers = relabeled

        # frontier rows move with their space-L ids: new row perm[v] = old v
        x = features[np.argsort(perms[-1], kind="stable")]
        return _RelabeledMB(), x

    def _plan_report(self, mb, d: int) -> Dict[str, float]:
        """Host-side partition/merge observability for one prepared batch:
        measured exchange ``wire_bytes`` (per-core, summed over hop layers,
        post-merge row accounting through ``Topology.plan(wire_rows=...)``)
        plus the redundancy tier's ``virtual_vertices``/``pair_coverage``
        (ELL format only; the shard build is LRU-cached, so reading the
        stats here costs a cache hit)."""
        from repro.graph.partition import exchange_rows

        wire_bytes = 0
        for coo in mb.layers:
            wr = exchange_rows(np.asarray(coo.rows), np.asarray(coo.cols),
                               np.asarray(coo.vals), coo.n_dst, coo.n_src,
                               self.n_cores)
            wire_bytes += self.topology.plan(
                coo.n_dst, d, self.n_cores, wire_rows=wr).bytes_per_core
        report = {"wire_bytes": float(wire_bytes), "virtual_vertices": 0.0,
                  "pair_coverage": 0.0, "flop_reduction": 1.0}
        if self.config.format == "ell" and self.config.merge == "redundancy":
            from repro.distributed import aggregate as _agg
            nv = pu = eb = ea = 0.0
            for coo in mb.layers:
                ee = _agg.shard_edges_ell(coo, self.n_cores,
                                          caps=self.config.caps,
                                          merge=self.config.merge)
                nv += ee.n_virtual
                pu += ee.pair_coverage
                eb += ee.merge_stats.get("edges_before", 0)
                ea += ee.merge_stats.get("edges_after", 0)
            report["virtual_vertices"] = float(nv)
            report["pair_coverage"] = float(pu / max(len(mb.layers), 1))
            # aggregation MACs: every surviving edge is one, every virtual
            # vertex costs two (its z = alpha*x[u] + beta*x[v] build)
            report["flop_reduction"] = float(eb / max(ea + 2.0 * nv, 1.0))
        return report

    def commit_batch(self, host_batch: Dict[str, Any]) -> Dict[str, Any]:
        """Host batch (from :meth:`prepare_batch`) → device-ready arrays,
        every leaf committed to its core-axis
        :class:`~jax.sharding.NamedSharding` when the bundle has a mesh —
        placement happens once per minibatch, never per step (uncommitted
        arrays get re-laid-out by jit on EVERY step, the measured cause of
        a past ``agg_fwd_speedup < 1`` regression)."""
        if self.mesh is not None:
            from repro.distributed.sharding import leading_axis_put

            def put(a):
                return leading_axis_put(self.mesh, a, self.axis)
        else:
            put = jnp.asarray
        out = {
            "edges": [jax.tree_util.tree_map(put, leaves)
                      for leaves in host_batch["edges"]],
            "dims": host_batch["dims"],
            "x": put(host_batch["x"]),
            "labels": put(host_batch["labels"]),
        }
        if "report" in host_batch:
            # host-side observability floats — not a device leaf, and kept
            # out of the jitted step's pytree (train_step/forward pull
            # edges/x/labels explicitly)
            out["report"] = host_batch["report"]
        return out

    def shard_batch(self, mb, features: np.ndarray, labels: np.ndarray
                    ) -> Dict[str, Any]:
        """Sampled minibatch → device-ready sharded arrays.

        ``mb.layers`` are per-hop COOs deepest-first; ``features`` the
        frontier rows (padded to a multiple of P).  Synchronous composition
        of :meth:`prepare_batch` (host layout build) and
        :meth:`commit_batch` (one-time placement); async pipelines call the
        two halves from their producer thread instead."""
        return self.commit_batch(self.prepare_batch(mb, features, labels))

    # -- per-device forward (inside shard_map) -------------------------------
    def _forward_local(self, params, edges, dims: Dims, x_local):
        """2..L-layer GCN forward, deepest layer first (CoAg order): local
        combination matmul, then this format's aggregation under this
        schedule."""
        h = x_local
        n_layers = len(params)
        for l in range(n_layers - 1, -1, -1):
            n_dst, _ = dims[l]
            h = h @ params[n_layers - 1 - l]["w"]      # local combination
            h = self.format.device_aggregate(
                self.config.schedule, self.axis, self.ndim, n_dst,
                edges[l], h, self.n_chunks, topology=self.config.topology)
            if l != 0:
                h = jnp.maximum(h, 0.0)
        return h                                       # [batch/P, classes]

    def _require_mesh(self, what: str) -> Mesh:
        if self.mesh is None:
            raise ValueError(f"{what} needs a mesh — rebuild with "
                             "Engine.build(mesh)")
        return self.mesh

    def _edge_specs(self, edges):
        from repro.distributed.sharding import leading_axis_spec
        return jax.tree_util.tree_map(
            lambda a: leading_axis_spec(a, self.axis), edges)

    @staticmethod
    def _dims_key(dims) -> Dims:
        return tuple((int(a), int(b)) for a, b in dims)

    # -- training -------------------------------------------------------------
    def train_step_fn(self, dims: Sequence[Tuple[int, int]]):
        """Jitted ``step(params, batch) -> (params, loss)`` for fixed layer
        dims; params replicated, batch leaves sharded on their leading
        (core) axis.  Weight gradients are ``pmean``'d over the hypercube
        (the paper's Weight Bank sync) and applied with SGD at
        ``config.lr``."""
        dims = self._dims_key(dims)
        step = self._steps.get(dims)
        if step is not None:
            return step
        mesh = self._require_mesh("train_step")
        axis, lr = self.axis, self.config.lr

        def body(params, edges, x_local, labels_local):
            def loss_fn(params):
                logits = self._forward_local(params, edges, dims, x_local)
                logp = jax.nn.log_softmax(logits.astype(jnp.float32), -1)
                nll = -jnp.take_along_axis(logp, labels_local[:, None],
                                           axis=-1)[:, 0]
                # mean over the GLOBAL batch (each core owns batch/P rows)
                return jax.lax.pmean(nll.mean(), axis)

            loss, grads = jax.value_and_grad(loss_fn)(params)
            # Weight Bank sync: average weight grads over the hypercube
            grads = jax.lax.pmean(grads, axis)
            params = jax.tree_util.tree_map(lambda p, g: p - lr * g,
                                            params, grads)
            return params, loss

        def step(params, batch):
            fn = shard_map(
                body, mesh=mesh,
                in_specs=(P(), self._edge_specs(batch["edges"]),
                          P(axis, None), P(axis)),
                out_specs=(P(), P()))
            return fn(params, batch["edges"], batch["x"], batch["labels"])

        step = jax.jit(step)
        self._steps[dims] = step
        return step

    def train_step(self, params, batch):
        """``(params, loss) = step(params, batch)`` — compiled per the
        batch's layer-dims signature and cached on the bundle."""
        return self.train_step_fn(batch["dims"])(params, batch)

    # -- inference -------------------------------------------------------------
    def forward_fn(self, dims: Sequence[Tuple[int, int]]):
        """Jitted ``forward(params, batch) -> logits`` (global rows)."""
        dims = self._dims_key(dims)
        fwd = self._forwards.get(dims)
        if fwd is not None:
            return fwd
        mesh = self._require_mesh("forward")
        axis = self.axis

        def body(params, edges, x_local):
            return self._forward_local(params, edges, dims, x_local)

        def fwd(params, batch):
            fn = shard_map(
                body, mesh=mesh,
                in_specs=(P(), self._edge_specs(batch["edges"]),
                          P(axis, None)),
                out_specs=P(axis, None))
            return fn(params, batch["edges"], batch["x"])

        fwd = jax.jit(fwd)
        self._forwards[dims] = fwd
        return fwd

    def forward(self, params, batch):
        return self.forward_fn(batch["dims"])(params, batch)

    # -- raw distributed aggregation -------------------------------------------
    def aggregator(self, graph=None):
        """Jitted ``y = A @ x`` over the mesh for one COO: edge shards built
        host-side, committed to their core-axis sharding once, and closed
        over — the returned callable takes only the global ``x`` and is
        freely differentiable (the format's transpose-free backward).
        Cached per (graph identity, engine spec, mesh) in the shared
        ``edgeplan`` LRU, which pins the graph's arrays (and this mesh)
        alive so id reuse can never alias two graphs."""
        from repro.kernels import edgeplan

        coo = graph if graph is not None else self.graph
        if coo is None:
            raise ValueError("no graph: pass one to aggregator()/aggregate()"
                             " or to Engine.build(graph=...)")
        mesh = self._require_mesh("aggregate")
        key = _layout_cache_key(coo, "agg", self.config.spec, self.n_cores,
                                self.axis, self.config.caps, self.n_chunks,
                                self.config.merge, id(mesh))
        return edgeplan.cached(key, (coo.rows, coo.cols, coo.vals, mesh),
                               lambda: self._build_aggregator(coo, mesh))

    def _build_aggregator(self, coo, mesh: Mesh):
        from repro.distributed.sharding import leading_axis_put

        perm = None
        if self.config.partition == "mincom" and self.n_cores > 1 \
                and coo.n_dst == coo.n_src:
            # square one-space graph: one permutation relabels both sides;
            # x permutes in and y un-permutes out OUTSIDE shard_map (inside
            # the jit), so callers keep the original row order
            from repro.graph.coo import from_edges
            from repro.graph.partition import (mincom_assignment,
                                               partition_permutation)
            assign = mincom_assignment(np.asarray(coo.rows, np.int64),
                                       np.asarray(coo.cols, np.int64),
                                       coo.n_dst, self.n_cores)
            perm = partition_permutation(assign, self.n_cores)
            coo = from_edges(perm[np.asarray(coo.rows, np.int64)],
                             perm[np.asarray(coo.cols, np.int64)],
                             np.asarray(coo.vals, np.float32),
                             coo.n_dst, coo.n_src)

        leaves, n_dst, _ = self.format.shard(coo, self.n_cores, self.config)
        leaves = jax.tree_util.tree_map(
            lambda a: leading_axis_put(mesh, a, self.axis), leaves)

        def body(edge_leaves, x_local):
            return self.format.device_aggregate(
                self.config.schedule, self.axis, self.ndim, n_dst,
                edge_leaves, x_local, self.n_chunks,
                topology=self.config.topology)

        fn = shard_map(
            body, mesh=mesh,
            in_specs=(self._edge_specs(leaves), P(self.axis, None)),
            out_specs=P(self.axis, None))
        if perm is None:
            return jax.jit(lambda x: fn(leaves, x))
        to_new = jnp.asarray(np.argsort(perm, kind="stable"))
        to_old = jnp.asarray(perm)
        return jax.jit(
            lambda x: jnp.take(fn(leaves, jnp.take(x, to_new, axis=0)),
                               to_old, axis=0))

    def aggregate(self, x: jnp.ndarray, graph=None) -> jnp.ndarray:
        """``y = A @ x`` through this engine's format + schedule."""
        return self.aggregator(graph)(x)

    # -- single-device layer (delegates to the engine) --------------------------
    def layer(self, graph, x, w, *, order: str = "coag",
              activate: bool = True):
        return self.engine.layer(graph, x, w, order=order, activate=activate)
