"""Profile-guided spec planner — what ``Engine("auto")`` resolves through.

The paper's claim is that the interconnect schedule, not just the kernel,
decides training throughput; PR 5 made the topology a declarative axis so
specs could be compared, and this module stops hand-picking them.  An
``"auto"`` spec resolves to a concrete ``format+schedule+topology`` before
anything compiles, through three tiers:

1. **Persisted autotune winner** — :func:`autotune` times every candidate
   spec bundle on the actual backend (the paired-median methodology of
   ``benchmarks/epoch_time.py``, re-execing itself under
   ``XLA_FLAGS=--xla_force_host_platform_device_count`` when the process
   has too few devices) and persists the winner per
   ``(backend, n_cores, graph-stats bucket)`` to ``BENCH_planner.json``.
   A matching entry is the strongest evidence and wins outright.
2. **Analytic cost model** — :func:`fit_cost_model` fits nonnegative
   ``t = const + α·steps + β·effective_bytes`` coefficients against the
   per-topology step times recorded in ``BENCH_topology.json``
   (``effective_bytes = bytes_per_core / link_parallelism`` — torus2d's
   orthogonal halves keep two link sets busy).  :func:`rank_specs` scores
   every candidate's :class:`~repro.topology.base.ExchangePlan` with it,
   scaling the compute-side ``const`` term by per-format roofline seconds
   from :mod:`repro.launch.hlo_analysis` when graph stats are given.
3. **Static fallback** — :data:`DEFAULT_SPEC` (``ell+pipelined+hypercube``,
   the measured best).  No file, no fit, no devices → still a valid spec,
   with no implicit sweep at import or test time.

Both stores ride the shared :class:`repro.engine.plans.RecordStore`
contract (explicit path → ``$REPRO_PLANNER_PATH`` / ``$REPRO_TOPOLOGY_PATH``
→ default filename in the CWD); corrupt or stale records warn and fall
through, they never crash a training run.
"""
from __future__ import annotations

import dataclasses
import functools
import json
import os
import subprocess
import sys
import time
import warnings
from typing import Dict, List, Optional, Sequence, Tuple

from .plans import RecordStore
from .registry import supported_specs

#: the measured-best static fallback (tier 3) — the paper's format and NoC
DEFAULT_SPEC = "ell+pipelined+hypercube"

#: autotune winners, keyed ``"{backend}|P{n_cores}|{bucket}"``
PLANNER_STORE = RecordStore("BENCH_planner.json", "REPRO_PLANNER_PATH")
#: the topology sweep record the cost model fits against
TOPOLOGY_STORE = RecordStore("BENCH_topology.json", "REPRO_TOPOLOGY_PATH")


def _pow2(v: float) -> int:
    """Round up to the next power of two (bucket resolution)."""
    n = max(int(-(-v // 1)), 1)              # ceil without math import
    return 1 << (n - 1).bit_length()


@dataclasses.dataclass(frozen=True)
class GraphStats:
    """The workload coordinates a plan is keyed on.

    ``n_dst``/``n_src`` are the deepest sampled layer's destination/source
    row counts (the rows the exchange actually ships), ``avg_deg`` its
    average in-degree, ``feat_dim`` the feature width.  :meth:`bucket`
    rounds each up to a power of two so nearby workloads share one
    autotune record instead of sweeping per batch.
    """

    n_dst: int
    n_src: int
    avg_deg: float
    feat_dim: int

    @classmethod
    def from_layers(cls, layers, feat_dim: int) -> "GraphStats":
        """Stats of the deepest (widest-frontier) COO layer in ``layers``."""
        deepest = max(layers, key=lambda c: c.n_src)
        return cls(n_dst=int(deepest.n_dst), n_src=int(deepest.n_src),
                   avg_deg=float(deepest.nnz) / max(int(deepest.n_dst), 1),
                   feat_dim=int(feat_dim))

    def bucket(self) -> str:
        return (f"n{_pow2(self.n_dst)}_s{_pow2(self.n_src)}"
                f"_d{_pow2(self.avg_deg)}_f{_pow2(self.feat_dim)}")


@dataclasses.dataclass(frozen=True)
class CostModel:
    """Fitted ``t = const + α·steps + β·effective_bytes`` (all ≥ 0).

    Nonnegative coefficients make the prediction monotone by construction:
    more steps or more wire bytes can never predict a faster exchange.
    ``n_rows``/``d``/``base_spec`` record the workload the fit came from so
    :func:`rank_specs` can re-plan candidates at the same coordinates.
    """

    alpha: float                  # seconds per exchange step (latency)
    beta: float                   # seconds per effective wire byte
    const: float                  # exchange-independent step time
    n_cores: int
    backend: Optional[str] = None
    base_spec: str = "ell+pipelined"
    n_rows: int = 512
    d: int = 128
    source: str = "fit"

    def predict(self, plan) -> float:
        """Predicted seconds per train step under ``plan``."""
        eff = plan.bytes_per_core / max(
            getattr(plan, "link_parallelism", 1.0), 1.0)
        return self.const + self.alpha * plan.steps + self.beta * eff


def _nnls(rows: Sequence[Sequence[float]], y: Sequence[float]):
    """Nonnegative least squares via active-set clamping.

    Solve the normalized LS problem, drop the most-negative column, repeat;
    dropped coefficients are exactly zero.  Small (3-column) systems only —
    the clamp is what guarantees the cost model's monotonicity.
    """
    import numpy as np

    A = np.asarray(rows, dtype=float)
    y = np.asarray(y, dtype=float)
    norms = np.linalg.norm(A, axis=0)
    norms[norms == 0] = 1.0
    An = A / norms
    active = list(range(A.shape[1]))
    coef = np.zeros(A.shape[1])
    while active:
        sol, *_ = np.linalg.lstsq(An[:, active], y, rcond=None)
        if (sol >= -1e-12).all():
            for i, c in zip(active, sol):
                coef[i] = max(float(c), 0.0)
            break
        active.pop(int(np.argmin(sol)))
    return coef / norms


def _backend() -> str:
    import jax
    return jax.default_backend()


def _record_link_parallelism(record: Dict, topo: str) -> float:
    """link_parallelism for ``topo``: the record's own column when present
    (new sweeps write it), else the registered topology, else 1.0."""
    v = record.get(f"link_parallelism_{topo}")
    if v is not None:
        return float(v)
    from .registry import get_topology
    try:
        return float(get_topology(topo).link_parallelism)
    except ValueError:
        return 1.0


def fit_cost_model(record: Optional[Dict] = None, *,
                   n_cores: Optional[int] = None,
                   backend: Optional[str] = None,
                   path: Optional[str] = None) -> Optional[CostModel]:
    """Fit α/β/const against a ``BENCH_topology.json`` sweep record.

    ``record=None`` loads the topology store (file → ``$REPRO_TOPOLOGY_PATH``
    → CWD default).  Returns ``None`` — never raises — when there is no
    usable record: missing/corrupt file, an ``n_cores`` or ``backend``
    mismatch (coefficients are per-(backend, axis-size); a 4-core sweep says
    nothing about a 2-core mesh), or fewer than 3 measured arms (the fit
    has 3 unknowns).
    """
    if record is None:
        record = TOPOLOGY_STORE.load(path, warn_corrupt=True)
    if not isinstance(record, dict):
        return None
    if n_cores is not None and record.get("n_cores") != n_cores:
        return None
    rec_backend = record.get("backend")
    if backend is not None and rec_backend is not None \
            and rec_backend != backend:
        return None
    rows, y = [], []
    for topo in record.get("topologies") or []:
        steps = record.get(f"exchange_steps_{topo}")
        nbytes = record.get(f"exchange_bytes_per_core_{topo}")
        t = record.get(f"s_per_step_{topo}")
        if steps is None or nbytes is None or t is None:
            continue
        eff = float(nbytes) / max(_record_link_parallelism(record, topo),
                                  1.0)
        rows.append([1.0, float(steps), eff])
        y.append(float(t))
    if len(rows) < 3:
        return None
    const, alpha, beta = _nnls(rows, y)
    return CostModel(alpha=float(alpha), beta=float(beta),
                     const=float(const),
                     n_cores=int(record.get("n_cores", n_cores or 0)),
                     backend=rec_backend,
                     base_spec=record.get("base_spec", "ell+pipelined"),
                     n_rows=int(record.get("mid", 512)),
                     d=int(record.get("feat", 128)))


# ---------------------------------------------------------------------------
# Format-side compute estimate: roofline seconds of the compiled
# single-device layer, per (backend, format+schedule, size bucket).
# ---------------------------------------------------------------------------
@functools.lru_cache(maxsize=64)
def _format_roofline_seconds(backend: str, fmt_spec: str,
                             dims: Tuple[int, int, int, int]
                             ) -> Optional[float]:
    """t_compute + t_memory of one compiled layer (None on any failure —
    a format that will not compile here just keeps ratio 1.0)."""
    try:
        import jax
        import jax.numpy as jnp
        import numpy as np

        from repro.graph.coo import from_edges
        from repro.launch.hlo_analysis import analyze_hlo, roofline_terms

        from .config import EngineConfig
        from .registry import get_format

        n_dst, n_src, deg, d = dims
        cfg = EngineConfig.from_spec(fmt_spec)
        fmt = get_format(cfg.format)
        rng = np.random.default_rng(0)
        e = n_dst * deg
        coo = from_edges(rng.integers(0, n_dst, e),
                         rng.integers(0, n_src, e),
                         np.abs(rng.standard_normal(e))
                         .astype(np.float32) + 0.1, n_dst, n_src)
        layout = fmt.build_local(coo, cfg)
        x = jnp.zeros((n_src, d), jnp.float32)
        w = jnp.zeros((d, d), jnp.float32)
        txt = jax.jit(lambda x, w: fmt.layer(layout, x, w)) \
            .lower(x, w).compile().as_text()
        stats = analyze_hlo(txt, 1)
        terms = roofline_terms(stats.flops, stats.hbm_bytes,
                               stats.collective_wire_bytes, 1)
        return terms["t_compute"] + terms["t_memory"]
    except Exception as e:                    # noqa: BLE001 — estimate only
        warnings.warn(f"no roofline estimate for {fmt_spec!r}: {e}",
                      RuntimeWarning, stacklevel=2)
        return None


def _roofline_dims(stats: GraphStats) -> Tuple[int, int, int, int]:
    # capped: the ratio between formats stabilizes long before real sizes
    return (min(_pow2(stats.n_dst), 512), min(_pow2(stats.n_src), 1024),
            min(_pow2(stats.avg_deg), 16), min(_pow2(stats.feat_dim), 128))


def rank_specs(model: CostModel, n_cores: int, *,
               graph_stats: Optional[GraphStats] = None,
               backend: Optional[str] = None,
               candidates: Optional[Sequence[str]] = None,
               mode: str = "train", max_batch: int = 8
               ) -> List[Tuple[str, float]]:
    """Candidate three-part specs sorted by predicted seconds.

    The exchange side scores each topology's :class:`ExchangePlan` through
    ``model``; the compute side scales ``model.const`` by the candidate
    format's roofline seconds relative to the fitted base format (only when
    ``graph_stats`` pins a workload — without one every format scores 1.0
    and the ranking is purely the interconnect).  Ties prefer
    ``ell+pipelined`` (the measured-best format arm), then lexicographic —
    deterministic, so resumes re-rank identically.

    ``mode`` picks the objective:

    * ``"train"`` — per-step seconds at the fitted workload's row count
      (throughput: the bytes term dominates at training batch sizes).
    * ``"serving"`` — mean predicted LATENCY over coalesced micro-batch
      sizes ``1, 2, 4, … max_batch``.  Online rows-per-exchange are tiny,
      so the per-step α·steps latency term dominates and the ranking can
      invert relative to train mode — a topology that wins on wire bytes
      at 512 rows loses at 4 rows if it takes more hops.  Every batch size
      weighs equally (each micro-batch is one user-visible latency, not
      one row).
    """
    from .registry import get_topology

    if mode not in ("train", "serving"):
        raise ValueError(f"unknown rank mode {mode!r}; "
                         "expected 'train' or 'serving'")
    specs = list(candidates) if candidates is not None \
        else supported_specs(three_part=True)
    n_rows = graph_stats.n_dst if graph_stats is not None else model.n_rows
    d = graph_stats.feat_dim if graph_stats is not None else model.d
    if mode == "serving":
        batch_sizes = []
        b = 1
        while b < max_batch:
            batch_sizes.append(b)
            b *= 2
        batch_sizes.append(max_batch)
    else:
        batch_sizes = [n_rows]
    base_s = None
    if graph_stats is not None:
        backend = backend or _backend()
        dims = _roofline_dims(graph_stats)
        base_s = _format_roofline_seconds(backend, model.base_spec, dims)
    scored = []
    for spec in specs:
        fmt, sched, topo = spec.split("+")
        try:
            plans = [get_topology(topo).plan(b, d, n_cores,
                                             cost_model=model)
                     for b in batch_sizes]
        except ValueError:            # this topology can't run at n_cores
            continue
        ratio = 1.0
        if base_s:
            s = _format_roofline_seconds(backend, f"{fmt}+{sched}", dims)
            if s:
                ratio = s / base_s
        score = sum(model.const * ratio + model.alpha * plan.steps
                    + model.beta * plan.bytes_per_core
                    / max(plan.link_parallelism, 1.0)
                    for plan in plans) / len(plans)
        scored.append((spec, float(score)))
    scored.sort(key=lambda kv: (kv[1],
                                0 if kv[0].startswith("ell+pipelined")
                                else 1, kv[0]))
    return scored


def rank_partitions(model: CostModel, coo, n_cores: int, *,
                    topology: str = "hypercube", d: Optional[int] = None
                    ) -> List[Tuple[str, float, int]]:
    """Registered partitioners sorted by predicted step seconds on ``coo``.

    For each ``partition`` knob value this relabels the graph
    (``mincom`` → :func:`repro.graph.partition.mincom_assignment`; ``naive``
    → identity), measures the post-merge wire content with
    :func:`repro.graph.partition.exchange_rows`, plans the exchange with
    ``wire_rows`` so ``ExchangePlan.bytes_per_core`` reflects the measured
    cut, and scores it through ``model.predict`` — the partition axis seen
    by the SAME cost model that ranks topologies.  Returns
    ``[(name, predicted_seconds, bytes_per_core), ...]`` best-first; ties
    prefer ``naive`` (no relabeling work for no predicted win).
    """
    import numpy as np

    from repro.graph.partition import (PARTITIONS, exchange_rows,
                                       mincom_assignment,
                                       partition_permutation)

    from .registry import get_topology

    rows = np.asarray(coo.rows, np.int64)
    cols = np.asarray(coo.cols, np.int64)
    vals = np.asarray(coo.vals)
    d = int(d) if d is not None else model.d
    topo = get_topology(topology)
    scored = []
    for name in PARTITIONS:
        if name == "mincom" and n_cores > 1 and coo.n_dst == coo.n_src:
            assign = mincom_assignment(rows, cols, coo.n_dst, n_cores)
            perm = partition_permutation(assign, n_cores)
            r, c = perm[rows], perm[cols]
        else:
            r, c = rows, cols
        wr = exchange_rows(r, c, vals, coo.n_dst, coo.n_src, n_cores)
        plan = topo.plan(coo.n_dst, d, n_cores, cost_model=model,
                         wire_rows=wr)
        scored.append((name, float(plan.predicted_seconds),
                       int(plan.bytes_per_core)))
    scored.sort(key=lambda kv: (kv[1], 0 if kv[0] == "naive" else 1, kv[0]))
    return scored


# ---------------------------------------------------------------------------
# Resolution: the three tiers.
# ---------------------------------------------------------------------------
def _entry_key(backend: str, n_cores: int, bucket: str) -> str:
    return f"{backend}|P{n_cores}|{bucket}"


def _valid_concrete_spec(spec, n_cores: int) -> bool:
    from .config import EngineConfig
    from .registry import get_topology
    if not isinstance(spec, str):
        return False
    try:
        cfg = EngineConfig.from_spec(spec)
        if cfg.is_auto:
            return False
        get_topology(cfg.topology).validate_cores(n_cores)
        return True
    except ValueError:
        return False


def _persisted_spec(backend: str, n_cores: int,
                    graph_stats: Optional[GraphStats],
                    path: Optional[str]) -> Optional[str]:
    rec = PLANNER_STORE.load(path, warn_corrupt=True)
    if rec is None:
        return None
    entries = rec.get("entries")
    if not isinstance(entries, dict):
        warnings.warn(
            f"planner record {PLANNER_STORE.path(path)!r} has no 'entries' "
            "table; falling through", RuntimeWarning, stacklevel=3)
        return None
    prefix = _entry_key(backend, n_cores, "")
    keys = []
    if graph_stats is not None:
        keys.append(_entry_key(backend, n_cores, graph_stats.bucket()))
    # deterministic prefix fallback: any bucket measured at this
    # (backend, n_cores) beats the analytic tier, sorted-first on ties
    keys.extend(k for k in sorted(entries) if k.startswith(prefix)
                and k not in keys)
    for key in keys:
        ent = entries.get(key)
        spec = ent.get("spec") if isinstance(ent, dict) else None
        if _valid_concrete_spec(spec, n_cores):
            return spec
        if ent is not None:
            warnings.warn(
                f"planner entry {key!r} names a stale/unregistered spec "
                f"{spec!r}; falling through", RuntimeWarning, stacklevel=3)
    return None


def resolve_spec(*, n_cores: int,
                 graph_stats: Optional[GraphStats] = None,
                 backend: Optional[str] = None,
                 candidates: Optional[Sequence[str]] = None,
                 path: Optional[str] = None, mode: str = "train",
                 max_batch: int = 8) -> str:
    """The concrete spec ``"auto"`` stands for at ``n_cores``.

    Tier 1: a persisted :func:`autotune` winner for this
    (backend, n_cores, bucket) — measured beats modeled.  Tier 2: the
    analytic cost model fitted from the topology sweep record.  Tier 3:
    :data:`DEFAULT_SPEC`.  Pure reads — never measures, never sweeps —
    and always returns a registered spec.

    ``mode="serving"`` (the :class:`~repro.serving.InferenceEngine` path)
    skips tier 1 — autotune winners measure training step THROUGHPUT,
    the wrong objective for micro-batch latency — and ranks tier 2 with
    the latency-weighted serving objective over batch sizes
    ``1..max_batch`` (see :func:`rank_specs`).
    """
    backend = backend or _backend()
    if mode != "serving":
        spec = _persisted_spec(backend, n_cores, graph_stats, path)
        if spec is not None:
            return spec
    model = fit_cost_model(n_cores=n_cores, backend=backend)
    if model is not None:
        ranked = rank_specs(model, n_cores, graph_stats=graph_stats,
                            backend=backend, candidates=candidates,
                            mode=mode, max_batch=max_batch)
        if ranked:
            return ranked[0][0]
    return DEFAULT_SPEC


# ---------------------------------------------------------------------------
# Tier-1 producer: the compile-and-replay autotune harness.
# ---------------------------------------------------------------------------
def _round_up(v: int, mult: int) -> int:
    return max(((int(v) + mult - 1) // mult) * mult, mult)


def _autotune_measure(stats_kw: Optional[Dict], n_cores: int,
                      candidates: Sequence[str], n_steps: int,
                      n_trials: int, seed: int) -> Dict:
    """Measure every candidate bundle on one shared synthetic stream.

    Same methodology as ``benchmarks/epoch_time.py``: all arms run
    back-to-back inside every trial (host load is common-mode), the
    per-arm time is the median across trials, every arm's first-step loss
    must sit within 1e-5 of the first arm's (reduction-order roundoff
    only).  Needs ``n_cores`` devices — :func:`autotune` re-execs this in
    a child process with forced XLA_FLAGS when the parent has fewer.
    """
    import jax
    import numpy as np

    from repro.distributed.gcn_train import init_params
    from repro.graph.coo import from_edges

    from .engine import Engine

    if len(jax.devices()) < n_cores:
        raise RuntimeError(
            f"need {n_cores} devices, have {len(jax.devices())} — set "
            "XLA_FLAGS=--xla_force_host_platform_device_count")
    if stats_kw:
        mid = _round_up(stats_kw["n_dst"], n_cores)
        frontier = _round_up(stats_kw["n_src"], n_cores)
        deg = max(int(round(stats_kw["avg_deg"])), 1)
        feat = max(int(stats_kw["feat_dim"]), 8)
    else:
        mid, frontier, deg, feat = 256, 512, 8, 64
    batch = _round_up(mid // 2, n_cores)
    hidden = feat
    mesh = jax.make_mesh((n_cores,), ("model",))
    rng = np.random.default_rng(seed)

    def layer(n_dst, n_src):
        e = n_dst * deg
        return from_edges(rng.integers(0, n_dst, e),
                          rng.integers(0, n_src, e),
                          np.abs(rng.standard_normal(e))
                          .astype(np.float32) + 0.1, n_dst, n_src)

    class _MB:                        # duck-typed MiniBatch: layers only
        pass

    _MB.layers = [layer(batch, mid), layer(mid, frontier)]
    x = rng.standard_normal((frontier, feat)).astype(np.float32)
    labels = rng.integers(0, 16, batch).astype(np.int32)
    runs, ref_loss, loss_match = {}, None, True
    for spec in candidates:
        bundle = Engine(spec).build(mesh)
        b = bundle.shard_batch(_MB(), x, labels)
        params = init_params(jax.random.PRNGKey(seed),
                             [(feat, hidden), (hidden, 16)])
        step = bundle.train_step_fn(b["dims"])
        params, loss = step(params, b)        # compile; loss at init params
        first = float(loss)
        params, loss = step(params, b)        # warmup
        jax.block_until_ready(loss)
        if ref_loss is None:
            ref_loss = first
        elif abs(first - ref_loss) > 1e-5:
            loss_match = False
        runs[spec] = {"step": step, "batch": b, "params": params,
                      "times": []}
    for _ in range(n_trials):
        for arm in runs.values():     # back-to-back: load is common-mode
            t0 = time.perf_counter()
            p, loss = arm["params"], None
            for _ in range(n_steps):
                p, loss = arm["step"](p, arm["batch"])
            jax.block_until_ready(loss)
            arm["times"].append((time.perf_counter() - t0) / n_steps)
    s = {spec: sorted(arm["times"])[len(arm["times"]) // 2]
         for spec, arm in runs.items()}
    winner = min(sorted(s), key=lambda k: s[k])
    return {"winner": winner, "s_per_step": s, "loss_match": loss_match,
            "stream": {"batch": batch, "mid": mid, "frontier": frontier,
                       "feat": feat, "deg": deg}}


def _autotune_measure_child(stats_kw: Optional[Dict], n_cores: int,
                            candidates: Sequence[str], n_steps: int,
                            n_trials: int, seed: int) -> Dict:
    """Re-exec :func:`_autotune_measure` under a forced multi-device
    backend (XLA_FLAGS must precede the jax import)."""
    child = (
        "import json;"
        "from repro.engine.planner import _autotune_measure;"
        f"print(json.dumps(_autotune_measure({stats_kw!r}, {n_cores!r}, "
        f"{list(candidates)!r}, {n_steps!r}, {n_trials!r}, {seed!r})))"
    )
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={n_cores} "
                        + env.get("XLA_FLAGS", "")).strip()
    src = os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__))))
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", child], env=env,
                          capture_output=True, text=True, timeout=1800)
    if proc.returncode != 0:
        raise RuntimeError(f"planner autotune child failed:\n{proc.stdout}"
                           f"\n{proc.stderr}")
    return json.loads(proc.stdout.strip().splitlines()[-1])


def autotune(graph_stats: Optional[GraphStats] = None, *,
             n_cores: int = 4,
             candidates: Optional[Sequence[str]] = None,
             n_steps: int = 3, n_trials: int = 8, seed: int = 0,
             path: Optional[str] = None, force: bool = False) -> Dict:
    """Time every candidate spec bundle, persist the winner, return the
    entry.

    Idempotent per (backend, n_cores, bucket) key unless ``force`` — a
    machine autotunes once per workload bucket; training never re-tunes.
    Entries merge into the existing ``BENCH_planner.json`` so different
    core counts and buckets accumulate in one file.
    """
    import jax

    backend = _backend()
    candidates = list(candidates) if candidates is not None \
        else supported_specs(three_part=True)
    bucket = graph_stats.bucket() if graph_stats is not None else "default"
    key = _entry_key(backend, n_cores, bucket)
    rec = PLANNER_STORE.load(path) or {}
    entries = rec.get("entries")
    if not isinstance(entries, dict):
        entries = {}
    if not force:
        ent = entries.get(key)
        if isinstance(ent, dict) and _valid_concrete_spec(ent.get("spec"),
                                                          n_cores):
            return ent
    stats_kw = dataclasses.asdict(graph_stats) \
        if graph_stats is not None else None
    if len(jax.devices()) >= n_cores:
        meas = _autotune_measure(stats_kw, n_cores, candidates, n_steps,
                                 n_trials, seed)
    else:
        meas = _autotune_measure_child(stats_kw, n_cores, candidates,
                                       n_steps, n_trials, seed)
    entry = {
        "spec": meas["winner"], "backend": backend, "n_cores": n_cores,
        "bucket": bucket, "graph_stats": stats_kw,
        "s_per_step": meas["s_per_step"], "loss_match": meas["loss_match"],
        "stream": meas.get("stream"), "candidates": list(candidates),
        "n_steps": n_steps, "n_trials": n_trials, "seed": seed,
    }
    entries[key] = entry
    PLANNER_STORE.save({"entries": entries}, path)
    return entry
