"""Pluggable format/schedule/topology registry — the Engine's extension
point.

A **format** owns one edge layout end to end: how a COO graph becomes that
layout (single-device ``build_local`` and per-sender ``shard``), the kernel
pair that walks it (forward + the transpose-free backward, registered once
as a ``custom_vjp`` inside the implementation it wraps), and the per-device
aggregation body the distributed train step calls inside ``shard_map``.  A
**schedule** names an issue order for the exchange fold (serial vs the
double-buffered pipelined order); each format declares which schedules it
supports.  A **topology** (:class:`repro.topology.Topology`) owns the
interconnect: the per-step exchange plan and the
reduce-scatter/allgather primitives every format's aggregation rides —
``hypercube`` (the paper's 4-D NoC, the default), ``allpairs`` (dense
all-to-all reference), ``ring``, ``torus2d`` (orthogonal row/column
multicast).

Adding a fourth format is a registration, not a cross-cutting flag::

    from repro.engine import Format, register_format

    @register_format("csr")
    class CsrFormat(Format):
        schedules = ("serial",)
        topologies = None            # every registered topology (default)
        def build_local(self, coo, cfg): ...
        def layer(self, layout, x, w, *, order, activate): ...
        def shard(self, coo, n_cores, cfg): ...
        def device_aggregate(self, schedule, axis_name, ndim, n_dst,
                             leaves, x_local, n_chunks,
                             topology="hypercube"): ...

After that, ``EngineConfig(format="csr")`` / ``Engine("csr+serial")``
reaches it everywhere — train step, benchmarks, examples — with no other
code change; a new topology is the same contract through
``@register_topology`` (see :mod:`repro.topology`).
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional, Tuple


class Format:
    """Base class for registered edge formats (see module docstring).

    Subclasses override the four methods below; ``name`` is filled in by
    :func:`register_format`.  ``schedules`` lists the supported schedule
    names (first entry = the format's default).
    """

    name: str = "?"
    schedules: Tuple[str, ...] = ()
    #: topology names this format supports; ``None`` = every registered
    #: topology (all built-in formats ride any interconnect — the fold is
    #: layout-agnostic); a format tied to one wire schedule restricts here
    topologies: Optional[Tuple[str, ...]] = None
    #: True when ``build_local`` works on traced (jit-abstract) COO arrays;
    #: layout-building formats (block tiles, ELL plans) need concrete host
    #: arrays and must be built outside jit
    traceable: bool = False
    #: False when ``build_local`` is (near-)identity — caching it would
    #: only churn the shared layout LRU and pin graph arrays for nothing
    cache_layouts: bool = True

    @property
    def default_schedule(self) -> str:
        return self.schedules[0]

    def build_local(self, coo, cfg):
        """COO → this format's single-device layout (cached by the Engine)."""
        raise NotImplementedError

    def layer(self, layout, x, w, *, order: str = "coag",
              activate: bool = True):
        """Single-device GCN layer over a ``build_local`` layout, with this
        format's transpose-free backward."""
        raise NotImplementedError

    def shard(self, coo, n_cores: int, cfg):
        """COO → ``(leaves, n_dst, n_src)``: a pytree of host arrays whose
        leading axis is the sender core (what ``shard_map`` slices)."""
        raise NotImplementedError

    def prepare_batch(self, mb, n_cores: int, cfg):
        """Sampled :class:`~repro.graph.sampler.MiniBatch` → host-side edge
        leaves: ``(edges, dims)`` with one ``shard`` pytree and one
        ``(n_dst, n_src)`` pair per hop layer (deepest last, matching
        ``mb.layers``).

        This is the per-batch layout-build hook the async input pipeline
        calls OFF the jit path (a prefetch thread, never inside a trace) —
        it is how layout-building formats (block tiles, ELL plans) train on
        sampled graphs despite ``traceable=False``.  The default walks
        ``mb.layers`` through :meth:`shard`; a format may override it to
        fuse work across hops."""
        edges, dims = [], []
        for coo in mb.layers:
            leaves, n_dst, n_src = self.shard(coo, n_cores, cfg)
            edges.append(leaves)
            dims.append((n_dst, n_src))
        return edges, dims

    def device_aggregate(self, schedule: str, axis_name: str, ndim: int,
                         n_dst: int, leaves, x_local, n_chunks,
                         topology: str = "hypercube"):
        """Per-device body: ``y_local = (A @ x)_local`` under ``schedule``,
        exchanging partial rows over ``topology``.

        ``leaves`` is this device's slice of the ``shard`` pytree (leading
        core axis still present, length 1).  Called inside ``shard_map``.
        """
        raise NotImplementedError


class Schedule:
    """A registered issue order for the hypercube fold."""

    name: str = "?"
    description: str = ""

    def resolve_n_chunks(self, n_chunks):
        """Feature-wave count this schedule actually runs (serial: 1)."""
        return 1


_FORMATS: Dict[str, Format] = {}
_SCHEDULES: Dict[str, Schedule] = {}
_TOPOLOGIES: Dict = {}      # name -> repro.topology.Topology instance

#: the topology every spec gets when none is named — the paper's NoC, and
#: the schedule whose fp32 add order is the repo-wide oracle contract
DEFAULT_TOPOLOGY = "hypercube"

#: the profile-guided spec: ``Engine("auto")`` resolves to a concrete
#: format+schedule+topology via :mod:`repro.engine.planner` before build
AUTO_SPEC = "auto"


def _options(plural: str, table: Dict) -> str:
    return f"registered {plural}: {sorted(table)}"


def _ensure_topologies() -> None:
    """Import the built-in topologies on first lookup (registration lives
    in ``repro/topology/__init__.py`` to keep the modules cycle-free)."""
    if not _TOPOLOGIES:
        import repro.topology  # noqa: F401  (registers the built-ins)


def register_format(name: str) -> Callable:
    """Class decorator: instantiate and register a :class:`Format`."""
    def deco(cls):
        inst = cls()
        inst.name = name
        if not inst.schedules:
            raise ValueError(f"format {name!r} declares no schedules")
        _FORMATS[name] = inst
        return cls
    return deco


def register_schedule(name: str) -> Callable:
    """Class decorator: instantiate and register a :class:`Schedule`."""
    def deco(cls):
        inst = cls()
        inst.name = name
        _SCHEDULES[name] = inst
        return cls
    return deco


def register_topology(name: str) -> Callable:
    """Class decorator: instantiate and register a
    :class:`repro.topology.Topology`."""
    def deco(cls):
        inst = cls()
        inst.name = name
        _TOPOLOGIES[name] = inst
        return cls
    return deco


def get_format(name: str) -> Format:
    try:
        return _FORMATS[name]
    except KeyError:
        raise ValueError(f"unknown format {name!r}; "
                         + _options("formats", _FORMATS)
                         + f" (or the {AUTO_SPEC!r} spec)") from None


def get_schedule(name: str) -> Schedule:
    try:
        return _SCHEDULES[name]
    except KeyError:
        raise ValueError(f"unknown schedule {name!r}; "
                         + _options("schedules", _SCHEDULES)) from None


def get_topology(name: str):
    _ensure_topologies()
    try:
        return _TOPOLOGIES[name]
    except KeyError:
        raise ValueError(f"unknown topology {name!r}; "
                         + _options("topologies", _TOPOLOGIES)
                         + f" (or the {AUTO_SPEC!r} spec)") from None


def available_formats() -> List[str]:
    return sorted(_FORMATS)


def available_schedules() -> List[str]:
    return sorted(_SCHEDULES)


def available_topologies() -> List[str]:
    _ensure_topologies()
    return sorted(_TOPOLOGIES)


def available_partitions() -> List[str]:
    """Registered partition-quality names (spec knob 4).  The registry
    lives with the partitioners (:data:`repro.graph.partition.PARTITIONS`);
    this accessor keeps spec tooling on one import."""
    from repro.graph.partition import PARTITIONS
    return sorted(PARTITIONS)


def format_topologies(fmt: str) -> List[str]:
    """Topology names ``fmt`` supports (its restriction, or all)."""
    f = get_format(fmt)
    if f.topologies is None:
        return available_topologies()
    return sorted(f.topologies)


def supported_specs(*, three_part: bool = False) -> List[str]:
    """Every valid spec spelling, sorted.

    Default (``three_part=False``): the canonical two-part
    ``"format+schedule"`` spellings (topology defaults to ``hypercube``) —
    benchmark metric keys and saved-spec round-trips are keyed on them —
    plus ``"auto"``, the profile-guided spec.

    ``three_part=True``: the CONCRETE ``"format+schedule+topology"``
    product (respecting each format's ``topologies`` restriction, no
    ``"auto"``) — the planner's candidate enumeration, and the single
    source arm sweeps and combo tests derive from.
    """
    if three_part:
        return sorted(f"{f}+{s}+{t}" for f, fmt in _FORMATS.items()
                      for s in fmt.schedules for t in format_topologies(f))
    return sorted([f"{f}+{s}" for f, fmt in _FORMATS.items()
                   for s in fmt.schedules] + [AUTO_SPEC])


def supported_topology_specs() -> List[str]:
    """Every valid ``"format+schedule+topology"`` combination, sorted
    (alias of ``supported_specs(three_part=True)``)."""
    return supported_specs(three_part=True)


def validate_combo(fmt: str, schedule: str,
                   topology: Optional[str] = None) -> None:
    """Raise ``ValueError`` (listing the options) on any invalid combo."""
    f = get_format(fmt)
    get_schedule(schedule)          # unknown schedule name raises here
    if schedule not in f.schedules:
        raise ValueError(
            f"format {fmt!r} does not support schedule {schedule!r} "
            f"(it supports {list(f.schedules)}); valid combinations: "
            f"{supported_specs()}")
    if topology is not None:
        get_topology(topology)      # unknown topology name raises here
        if f.topologies is not None and topology not in f.topologies:
            raise ValueError(
                f"format {fmt!r} does not support topology {topology!r} "
                f"(it supports {sorted(f.topologies)}); valid "
                f"combinations: {supported_topology_specs()}")
