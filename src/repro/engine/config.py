"""Declarative Engine configuration — one validated object instead of the
old flag cloud (``overlap=``, ``ell=``, ``blocked=``, ``layout=``).

An :class:`EngineConfig` names a registered format, schedule and topology
plus the knobs every path shares (pipelining waves, ELL autotune caps,
mesh axis, learning rate, precision).  Validation happens at construction:
unknown names and unsupported combinations raise ``ValueError`` listing
the registered options, so a typo dies at config time, not three layers
down inside ``shard_map``.

Spec grammar: ``format[+schedule[+topology[+partition]]]`` — ``"ell"``,
``"ell+pipelined"``, ``"ell+pipelined+ring"``,
``"ell+pipelined+hypercube+mincom"``.  An omitted schedule takes
the format's default; an omitted topology takes ``hypercube`` (the
paper's NoC); an omitted partition takes ``naive`` (contiguous
striping).  ``.spec`` is the canonical spelling and keeps the legacy
two- and three-part forms whenever the trailing knobs are defaults, so
pre-topology/pre-partition spec strings, metric keys and checkpoints
round-trip unchanged.  The ``merge`` knob (``"dedup"`` | ``"redundancy"``,
the edge-plan merge level) is a config FIELD rather than a spec part: it
changes the plan the kernels walk, not which engine path runs.

``"auto"`` is the one spec that is not a format name: it defers the
format/schedule/topology choice to :mod:`repro.engine.planner`, which
resolves it to a concrete registered spec at build time (cost model →
persisted autotune record → static fallback).  An auto config carries the
shared knobs but no concrete parts; combining it with an explicit
schedule or topology is an error.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Union

from . import registry

Caps = Union[str, Sequence[int], None]

#: precisions the kernels implement today (bf16 messages are a future
#: format registration, not a silent cast)
PRECISIONS = ("fp32",)


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Declarative spec of one aggregation engine.

    format:   registered edge layout — ``"coo"`` | ``"block"`` | ``"ell"``
    schedule: registered fold issue order — ``"serial"`` | ``"pipelined"``
              (``None`` → the format's default)
    topology: registered interconnect — ``"hypercube"`` | ``"allpairs"`` |
              ``"ring"`` | ``"torus2d"`` (``None`` → ``hypercube``, the
              paper's NoC and the oracle schedule)
    n_chunks: feature waves for the pipelined schedule (``None`` → the
              backend default, :func:`repro.distributed.aggregate.default_n_chunks`)
    caps:     ELL degree-bucket caps override (``None`` → the autotuned
              scheme from :mod:`repro.kernels.tune`)
    block_tiles: destination tiles for the block format's single-device
              layer (distributed paths always tile per core instead)
    partition: node→core partition quality — ``"naive"`` (contiguous
              striping, the paper's address decode) | ``"mincom"``
              (communication-volume-minimizing relabeling); fourth spec
              part, omitted from ``.spec`` when default
    merge:    edge-plan merge level — ``"dedup"`` (within-block sender
              merge) | ``"redundancy"`` (+ GraphACT cross-row virtual
              vertices); a field, not a spec part
    axis:     mesh axis name that plays the paper's 16-core hypercube
    lr:       SGD learning rate baked into ``train_step``
    precision: accumulation precision (``"fp32"`` only today)
    """

    format: str = "coo"
    schedule: Optional[str] = None
    topology: Optional[str] = None
    partition: str = "naive"
    merge: str = "dedup"
    n_chunks: Optional[int] = None
    caps: Caps = None
    block_tiles: int = 4
    axis: str = "model"
    lr: float = 0.05
    precision: str = "fp32"

    def __post_init__(self):
        from repro.graph.partition import validate_partition
        from repro.kernels.edgeplan import validate_merge
        validate_partition(self.partition)
        validate_merge(self.merge)
        if self.format == registry.AUTO_SPEC:
            if self.schedule is not None or self.topology is not None:
                raise ValueError(
                    f"{registry.AUTO_SPEC!r} is a complete spec — the "
                    f"planner picks the format, schedule AND topology; "
                    f"drop the explicit "
                    f"{'schedule' if self.schedule else 'topology'} or name "
                    f"a concrete spec from "
                    f"{registry.supported_specs(three_part=True)}")
        else:
            fmt = registry.get_format(self.format)
            if self.schedule is None:
                object.__setattr__(self, "schedule", fmt.default_schedule)
            if self.topology is None:
                object.__setattr__(self, "topology",
                                   registry.DEFAULT_TOPOLOGY)
            registry.validate_combo(self.format, self.schedule,
                                    self.topology)
        if self.n_chunks is not None and int(self.n_chunks) < 1:
            raise ValueError(f"n_chunks must be >= 1, got {self.n_chunks}")
        if self.block_tiles < 1:
            raise ValueError(
                f"block_tiles must be >= 1, got {self.block_tiles}")
        if self.precision not in PRECISIONS:
            raise ValueError(f"unknown precision {self.precision!r}; "
                             f"supported: {list(PRECISIONS)}")
        if self.caps is not None and not isinstance(self.caps, str):
            object.__setattr__(self, "caps", tuple(int(c) for c in self.caps))

    @classmethod
    def from_spec(cls, spec: str, **overrides) -> "EngineConfig":
        """Parse ``"ell+pipelined+ring"`` / ``"ell+pipelined"`` / ``"coo"``
        / ``"ell+pipelined+hypercube+mincom"`` into a validated config.

        The spec is ``format[+schedule[+topology[+partition]]]``; a bare
        format takes its default schedule, an omitted topology defaults to
        ``hypercube``, an omitted partition to ``naive``.  ``overrides``
        set the remaining knobs (``n_chunks=4``, ``lr=0.1``, ...).
        """
        parts = [p.strip() for p in spec.split("+")]
        if not 1 <= len(parts) <= 4 or not all(parts):
            raise ValueError(
                f"bad engine spec {spec!r}: expected 'format', "
                f"'format+schedule', 'format+schedule+topology' or "
                f"'format+schedule+topology+partition'; valid "
                f"specs: {registry.supported_specs()} (+ optionally one of "
                f"{registry.available_topologies()}, then one of "
                f"{registry.available_partitions()})")
        kw = dict(overrides)
        kw["format"] = parts[0]
        if len(parts) >= 2:
            kw["schedule"] = parts[1]
        if len(parts) >= 3:
            kw["topology"] = parts[2]
        if len(parts) == 4:
            kw["partition"] = parts[3]
        return cls(**kw)

    @property
    def is_auto(self) -> bool:
        """True for the planner-deferred ``"auto"`` spec (no concrete
        format/schedule/topology until :meth:`Engine.resolve` runs)."""
        return self.format == registry.AUTO_SPEC

    @property
    def spec(self) -> str:
        """The canonical spec string of this config.

        Two-part ``"format+schedule"`` when the topology is the default
        ``hypercube`` (pre-topology specs, metric keys and checkpoints
        round-trip unchanged); ``"format+schedule+topology"`` with a
        non-default topology; a fourth ``+partition`` part only when the
        partition is not ``naive`` (the topology is then always spelled
        out, default or not, so the parts stay positional); ``"auto"``
        for the planner-deferred config.
        """
        if self.is_auto:
            return registry.AUTO_SPEC
        base = f"{self.format}+{self.schedule}"
        if self.partition != "naive":
            return f"{base}+{self.topology}+{self.partition}"
        if self.topology == registry.DEFAULT_TOPOLOGY:
            return base
        return f"{base}+{self.topology}"

    def with_spec(self, spec: str) -> "EngineConfig":
        """This config's knobs (waves, caps, axis, lr, ...) re-bound to a
        different spec — how the planner turns an auto config concrete."""
        return EngineConfig.from_spec(
            spec, partition=self.partition, merge=self.merge,
            n_chunks=self.n_chunks, caps=self.caps,
            block_tiles=self.block_tiles, axis=self.axis, lr=self.lr,
            precision=self.precision)
