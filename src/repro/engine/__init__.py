# The declarative Engine API — the single entry point to every aggregation
# path (format x schedule x topology), with a pluggable registry for new
# formats, schedules and interconnect topologies, plus the profile-guided
# planner behind the "auto" spec (repro.engine.planner — imported lazily
# by Engine.resolve, never at package import).
# See README "Engine API" / "Topology" / "Auto spec" for the grammar.
from .config import EngineConfig
from .engine import Engine, EngineBundle
from .plans import RecordStore
from .registry import (AUTO_SPEC, Format, Schedule, available_formats,
                       available_schedules, available_topologies,
                       format_topologies, get_format, get_schedule,
                       get_topology, register_format, register_schedule,
                       register_topology, supported_specs,
                       supported_topology_specs)
from . import formats  # noqa: F401  (registers the built-in formats)

__all__ = [
    "Engine", "EngineBundle", "EngineConfig", "RecordStore", "AUTO_SPEC",
    "Format", "Schedule", "register_format", "register_schedule",
    "register_topology", "get_format", "get_schedule", "get_topology",
    "available_formats", "available_schedules", "available_topologies",
    "format_topologies", "supported_specs", "supported_topology_specs",
]
