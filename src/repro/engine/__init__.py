# The declarative Engine API — the single entry point to every aggregation
# path (format x schedule), with a pluggable registry for new formats.
# See README "Engine API" for the migration table from the old flag calls.
from .config import EngineConfig
from .engine import Engine, EngineBundle
from .registry import (Format, Schedule, available_formats,
                       available_schedules, get_format, get_schedule,
                       register_format, register_schedule, supported_specs)
from . import formats  # noqa: F401  (registers the built-in formats)

__all__ = [
    "Engine", "EngineBundle", "EngineConfig",
    "Format", "Schedule", "register_format", "register_schedule",
    "get_format", "get_schedule", "available_formats",
    "available_schedules", "supported_specs",
]
