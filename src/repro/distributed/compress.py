"""Gradient compression for the DP all-reduce (distributed-optimization trick).

The paper's Weight Bank synchronizes global weights after every update; at
1000+ nodes that synchronization is the collective-term bottleneck for small
models (roofline: gradient bytes / link bw).  We provide an int8
error-feedback compressed all-reduce built from the same hypercube rounds as
the aggregation layer:

  * reduce-scatter phase: each round quantizes the outgoing half to int8 with
    one f32 scale per round (wire = 1 byte/elem + 4 bytes), dequantizes and
    accumulates in f32 on arrival;
  * all-gather phase: the fully-reduced shard is quantized once and doubled
    around the cube in int8;
  * error feedback: each device keeps the quantization residual of its OWN
    contribution and re-injects it next step — the standard EF-SGD fix that
    keeps compressed SGD convergent (Stich et al.); round-trip quantization
    noise inside the fold is unbiased-ish and dominated by the EF term.

Wire bytes drop 4× vs f32 (the roofline benchmark counts this), at the cost
of int8 noise the tests bound.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp


def _quant(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-30) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequant(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def _dim_perm(n: int, bit: int):
    return [(i, i ^ (1 << bit)) for i in range(n)]


def _hypercube_ndim(n_cores: int) -> int:
    """Hypercube dimensionality for ``n_cores``, or a loud error.

    The ``_dim_perm`` exchange pairs peer ``i`` with ``i ^ (1 << b)`` —
    that wiring only exists when the core count is a power of two.  On any
    other count the permutation would silently mis-route halves (peers
    past the axis end wrap who-knows-where), so this fails at trace time
    naming the topology instead.
    """
    if n_cores < 1 or n_cores & (n_cores - 1):
        raise ValueError(
            f"compressed_psum runs dimension-ordered hypercube rounds "
            f"(peer = i ^ 2^b), which require a power-of-two core count; "
            f"got {n_cores} cores.  Use a topology-registry exchange for "
            f"non-hypercube meshes.")
    return n_cores.bit_length() - 1


def compressed_psum(x: jnp.ndarray, axis_name: str, ndim: int = None, *,
                    n_cores: int = None) -> jnp.ndarray:
    """int8 hypercube all-reduce of a flat f32 vector (call in shard_map).

    ``x``: [n] with n divisible by P = 2**ndim.  Returns the f32 sum over the
    axis, computed with int8 wire traffic.

    Pass EITHER ``ndim`` (the hypercube dimensionality, legacy positional
    form) or ``n_cores=`` (the mesh axis size) — the latter validates that
    the count actually forms a hypercube and raises a ``ValueError`` naming
    the topology on a non-power-of-two count, instead of silently
    mis-permuting.
    """
    if (ndim is None) == (n_cores is None):
        raise ValueError("pass exactly one of ndim= or n_cores=")
    if n_cores is not None:
        ndim = _hypercube_ndim(int(n_cores))
    n_cores = 1 << ndim
    idx = jax.lax.axis_index(axis_name)
    buf = x.reshape(n_cores, -1)
    # --- reduce-scatter fold (int8 wire) ---
    for b in reversed(range(ndim)):
        half = buf.shape[0] // 2
        low, high = buf[:half], buf[half:]
        my_bit = (idx >> b) & 1
        mine = jnp.where(my_bit == 0, low, high)
        send = jnp.where(my_bit == 0, high, low)
        q, s = _quant(send)
        q_r = jax.lax.ppermute(q, axis_name, _dim_perm(n_cores, b))
        s_r = jax.lax.ppermute(s, axis_name, _dim_perm(n_cores, b))
        buf = mine + _dequant(q_r, s_r)
    shard = buf[0]                                  # [n/P] fully reduced
    # --- all-gather double (int8 wire) ---
    q, s = _quant(shard)
    qbuf = q[None]
    sbuf = s[None]
    for b in range(ndim):
        q_r = jax.lax.ppermute(qbuf, axis_name, _dim_perm(n_cores, b))
        s_r = jax.lax.ppermute(sbuf, axis_name, _dim_perm(n_cores, b))
        my_bit = (idx >> b) & 1
        qbuf = jnp.where(my_bit == 0,
                         jnp.concatenate([qbuf, q_r]),
                         jnp.concatenate([q_r, qbuf]))
        sbuf = jnp.where(my_bit == 0,
                         jnp.concatenate([sbuf, s_r]),
                         jnp.concatenate([s_r, sbuf]))
    out = _dequant(qbuf, sbuf[:, None])             # [P, n/P]
    return out.reshape(-1)


def ef_compress_grads(grads, err, axis_name: str, ndim: int = None, *,
                      n_cores: int = None):
    """Error-feedback compressed all-reduce over a gradient pytree.

    Returns (mean_grads, new_err).  Each leaf: inject residual, quantize the
    contribution (that quantized value is what enters the fold), keep the new
    residual locally.  ``ndim`` vs ``n_cores=`` as in
    :func:`compressed_psum` — ``n_cores`` validates the hypercube contract.
    """
    if (ndim is None) == (n_cores is None):
        raise ValueError("pass exactly one of ndim= or n_cores=")
    if n_cores is not None:
        ndim = _hypercube_ndim(int(n_cores))
    n_cores = 1 << ndim

    def one(g, e):
        flat = g.reshape(-1)
        pad = (-flat.shape[0]) % n_cores
        flat = jnp.pad(flat, (0, pad))
        corrected = flat + e
        q, s = _quant(corrected)
        contribution = _dequant(q, s)
        new_e = corrected - contribution
        summed = compressed_psum(contribution, axis_name, ndim)
        return (summed[:g.size] / n_cores).reshape(g.shape), new_e

    flat_g, tree = jax.tree_util.tree_flatten(grads)
    flat_e = jax.tree_util.tree_leaves(err)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    mean = jax.tree_util.tree_unflatten(tree, [o[0] for o in outs])
    new_err = jax.tree_util.tree_unflatten(tree, [o[1] for o in outs])
    return mean, new_err


def init_error_state(params, n_cores: int):
    """Zero EF residuals, one padded flat vector per parameter leaf."""
    def one(p):
        n = p.size + ((-p.size) % n_cores)
        return jnp.zeros((n,), jnp.float32)
    return jax.tree_util.tree_map(one, params)


def compression_ratio(dtype_bytes: int = 4) -> float:
    """Wire-byte ratio vs uncompressed f32 all-reduce (scales amortize out)."""
    return dtype_bytes / 1.0
