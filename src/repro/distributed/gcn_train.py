"""Distributed GCN training — now a thin compatibility layer.

The implementation moved to :mod:`repro.engine`: one ``shard_map`` over the
``model`` axis realizes the paper end to end (local combination GEMMs, the
hypercube message-passing aggregation with sender-side pre-reduction, the
transpose-free mirror backward, and the Weight-Bank ``pmean`` gradient
sync), with the edge format (coo/block/ell) and fold schedule
(serial/pipelined) selected declaratively::

    from repro.engine import Engine, EngineConfig

    bundle = Engine(EngineConfig.from_spec("ell+pipelined", lr=0.05)) \
        .build(mesh)
    batch = bundle.shard_batch(mb, feats, labels)
    params, loss = bundle.train_step(params, batch)

For a full training run (epoch loop, async host pipeline, validation,
checkpoint/resume) use :class:`repro.launch.trainer.Trainer`, which drives
exactly this bundle step — the step function is built once per layer-dims
signature by ``bundle.train_step_fn`` and shared by the Trainer, the
benchmarks, and any hand-rolled loop (no trainer-private step exists).

``shard_minibatch`` / ``make_train_step`` below are the pre-Engine flag
entry points, kept as ``DeprecationWarning`` shims that translate their
flags into an :class:`~repro.engine.EngineConfig`.  ``init_params`` is not
deprecated — it is the Trainer's parameter initializer too.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.deprecation import warn_engine_shim as _warn_shim
from repro.graph.sampler import MiniBatch

Params = List[Dict[str, jnp.ndarray]]

#: flag-era layout names → Engine specs
_LAYOUT_SPECS = {"flat": "coo+serial", "blocked": "block+pipelined",
                 "ell": "ell+pipelined"}


def _flag_spec(overlap: bool, ell: bool) -> str:
    if ell:
        return "ell+pipelined"
    return "block+pipelined" if overlap else "coo+serial"


def shard_minibatch(mb: MiniBatch, features: np.ndarray, labels: np.ndarray,
                    n_cores: int, *, blocked: bool = False,
                    layout: Optional[str] = None,
                    mesh: Optional[Mesh] = None,
                    axis: str = "model") -> Dict[str, Any]:
    """Deprecated shim — ``Engine(spec).build(mesh).shard_batch(...)``.

    The flag-era layout names map to Engine specs: ``"flat"`` →
    ``"coo+serial"``, ``"blocked"`` → ``"block+pipelined"``, ``"ell"`` →
    ``"ell+pipelined"``.
    """
    from repro.engine import Engine, EngineConfig

    if layout is None:
        layout = "blocked" if blocked else "flat"
    if layout not in _LAYOUT_SPECS:
        raise ValueError(f"unknown layout {layout!r}")
    spec = _LAYOUT_SPECS[layout]
    _warn_shim("shard_minibatch",
               f'Engine("{spec}").build(mesh).shard_batch(mb, features, '
               "labels)")
    cfg = EngineConfig.from_spec(spec, axis=axis)
    # old semantics preserved: n_cores drives the shard shapes, mesh only
    # the placement — a mismatch still fails loudly at step time
    bundle = Engine(cfg).build(mesh, n_cores=n_cores)
    return bundle.shard_batch(mb, features, labels)


def make_train_step(mesh: Mesh, dims: Sequence[Tuple[int, int]],
                    lr: float = 0.05, axis: str = "model", *,
                    overlap: bool = False, n_chunks: Optional[int] = None,
                    ell: bool = False):
    """Deprecated shim — ``Engine(spec).build(mesh).train_step_fn(dims)``.

    The old flag pairs collapse into one spec: default → ``"coo+serial"``,
    ``overlap=True`` → ``"block+pipelined"``, ``overlap=True, ell=True`` →
    ``"ell+pipelined"``.
    """
    from repro.engine import Engine, EngineConfig

    spec = _flag_spec(overlap, ell)
    _warn_shim("make_train_step",
               f'Engine(EngineConfig.from_spec("{spec}", lr={lr})).'
               "build(mesh).train_step_fn(dims)")
    cfg = EngineConfig.from_spec(spec, lr=lr, axis=axis, n_chunks=n_chunks)
    return Engine(cfg).build(mesh).train_step_fn(dims)


def init_params(key, dims_io: Sequence[Tuple[int, int]]) -> Params:
    """dims_io: [(d_in, d_out), ...] output layer last."""
    params = []
    for i, (d_in, d_out) in enumerate(dims_io):
        key, k = jax.random.split(key)
        params.append({"w": (jax.random.normal(k, (d_in, d_out))
                             * d_in ** -0.5).astype(jnp.float32)})
    return params
