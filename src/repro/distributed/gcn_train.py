"""Distributed GCN training step — the paper's full architecture, deployed.

One `shard_map` over the ``model`` axis (= the 16-core hypercube) realizes
the paper end to end, per §4.1/§4.2's execution order:

  * **combination** is a LOCAL matmul on each core's feature rows (the NUMA
    claim: dense GEMM reads only core-local HBM at full bandwidth);
  * **aggregation** is the hypercube message-passing layer
    (:func:`repro.distributed.aggregate.hypercube_aggregate`): sender-side
    pre-reduction (Block-Message merge) + log₂P `ppermute` rounds;
  * the backward pass is the transpose-free mirror (custom_vjp inside the
    aggregate: all-gather of the error + column-major walk of the SAME edge
    table — no `Aᵀ`, no `Xᵀ`);
  * **Weight Bank sync**: weights are replicated per core; their gradients
    are `psum`'d over the hypercube after backward — the paper's
    "system controller periodically synchronizes global parameters".

Each sampled minibatch layer ships as sender-side :class:`EdgeShards`
([P, e_max] arrays, leading axis sharded).  Orders are CoAg (combine the
frontier first — the estimator's usual choice for wide-input layers);
AgCo support falls out of calling aggregate before the matmul.

Validated against the single-device reference in
tests/test_distributed.py::test_distributed_gcn_matches_reference and run
end-to-end by examples/distributed_gcn.py.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.graph.coo import COO
from repro.graph.sampler import MiniBatch
from .aggregate import EdgeShards, hypercube_aggregate, shard_edges

Params = List[Dict[str, jnp.ndarray]]


def shard_minibatch(mb: MiniBatch, features: np.ndarray, labels: np.ndarray,
                    n_cores: int) -> Dict[str, Any]:
    """Host-side: sampled minibatch → device-ready sharded arrays.

    Layers come deepest-first (matching forward consumption order); features
    are the frontier rows (already padded to a multiple of P)."""
    shards = [shard_edges(coo, n_cores) for coo in mb.layers]
    return {
        "edges": [
            {"rows": jnp.asarray(es.rows_global),
             "cols": jnp.asarray(es.cols_local),
             "vals": jnp.asarray(es.vals)}
            for es in shards
        ],
        "dims": [(es.n_dst, es.n_src) for es in shards],
        "x": jnp.asarray(features, jnp.float32),
        "labels": jnp.asarray(labels, jnp.int32),
    }


def _forward_local(params, edges, dims, x_local, ndim: int,
                   axis: str = "model"):
    """Per-device 2..L-layer GCN forward, deepest layer first (CoAg)."""
    h = x_local
    n_layers = len(params)
    for l in range(n_layers - 1, -1, -1):
        e = edges[l]
        n_dst, _ = dims[l]
        h = h @ params[n_layers - 1 - l]["w"]          # local combination
        h = hypercube_aggregate(axis, ndim, n_dst,      # routed aggregation
                                e["rows"][0], e["cols"][0], e["vals"][0], h)
        if l != 0:
            h = jnp.maximum(h, 0.0)
    return h                                            # [batch/P, classes]


def make_train_step(mesh: Mesh, dims: Sequence[Tuple[int, int]],
                    lr: float = 0.05, axis: str = "model"):
    """Build the jitted distributed train step for fixed layer dims.

    step(params, batch) -> (params, loss); params replicated, batch arrays
    sharded on their leading (core) axis.
    """
    n_cores = mesh.shape[axis]
    ndim = int(np.log2(n_cores))
    dims = tuple((int(a), int(b)) for a, b in dims)

    def body(params, edges, x_local, labels_local):
        def loss_fn(params):
            logits = _forward_local(params, edges, dims, x_local, ndim,
                                    axis)
            logp = jax.nn.log_softmax(logits.astype(jnp.float32), -1)
            nll = -jnp.take_along_axis(logp, labels_local[:, None],
                                       axis=-1)[:, 0]
            # mean over the GLOBAL batch (each core owns batch/P rows)
            return jax.lax.pmean(nll.mean(), axis)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        # Weight Bank sync: average weight grads over the hypercube
        grads = jax.lax.pmean(grads, axis)
        params = jax.tree_util.tree_map(lambda p, g: p - lr * g, params,
                                        grads)
        return params, loss

    edge_spec = {"rows": P(axis, None), "cols": P(axis, None),
                 "vals": P(axis, None)}

    def step(params, batch):
        n_layers = len(batch["edges"])
        fn = jax.shard_map(
            body,
            mesh=mesh,
            in_specs=(P(), [edge_spec] * n_layers, P(axis, None), P(axis)),
            out_specs=(P(), P()),
        )
        return fn(params, batch["edges"], batch["x"], batch["labels"])

    return jax.jit(step)


def init_params(key, dims_io: Sequence[Tuple[int, int]]) -> Params:
    """dims_io: [(d_in, d_out), ...] output layer last."""
    params = []
    for i, (d_in, d_out) in enumerate(dims_io):
        key, k = jax.random.split(key)
        params.append({"w": (jax.random.normal(k, (d_in, d_out))
                             * d_in ** -0.5).astype(jnp.float32)})
    return params
