"""Distributed GCN training step — the paper's full architecture, deployed.

One `shard_map` over the ``model`` axis (= the 16-core hypercube) realizes
the paper end to end, per §4.1/§4.2's execution order:

  * **combination** is a LOCAL matmul on each core's feature rows (the NUMA
    claim: dense GEMM reads only core-local HBM at full bandwidth);
  * **aggregation** is the hypercube message-passing layer
    (:func:`repro.distributed.aggregate.hypercube_aggregate`): sender-side
    pre-reduction (Block-Message merge) + log₂P `ppermute` rounds;
  * the backward pass is the transpose-free mirror (custom_vjp inside the
    aggregate: all-gather of the error + column-major walk of the SAME edge
    table — no `Aᵀ`, no `Xᵀ`);
  * **Weight Bank sync**: weights are replicated per core; their gradients
    are `psum`'d over the hypercube after backward — the paper's
    "system controller periodically synchronizes global parameters".

Each sampled minibatch layer ships as sender-side :class:`EdgeShards`
([P, e_max] arrays, leading axis sharded).  Orders are CoAg (combine the
frontier first — the estimator's usual choice for wide-input layers);
AgCo support falls out of calling aggregate before the matmul.

Validated against the single-device reference in
tests/test_distributed.py::test_distributed_gcn_matches_reference and run
end-to-end by examples/distributed_gcn.py.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map
from repro.graph.sampler import MiniBatch
from .aggregate import (hypercube_aggregate, hypercube_aggregate_ell,
                        hypercube_aggregate_pipelined, shard_edges,
                        shard_edges_blocked, shard_edges_ell)

Params = List[Dict[str, jnp.ndarray]]


def shard_minibatch(mb: MiniBatch, features: np.ndarray, labels: np.ndarray,
                    n_cores: int, *, blocked: bool = False,
                    layout: Optional[str] = None,
                    mesh: Optional[Mesh] = None,
                    axis: str = "model") -> Dict[str, Any]:
    """Host-side: sampled minibatch → device-ready sharded arrays.

    Layers come deepest-first (matching forward consumption order); features
    are the frontier rows (already padded to a multiple of P).

    ``layout`` selects the edge format per layer:

    * ``"flat"`` (default) — [P, e_max] global-row COO, serial schedule;
    * ``"blocked"`` (or the legacy ``blocked=True``) — Block-Message tiles
      ([P, B, eb], :func:`shard_edges_blocked`) for the bit-exact pipelined
      schedule;
    * ``"ell"`` — pre-reduced degree-bucketed ELL plans
      (:func:`shard_edges_ell`, cached per graph) for the scatter-free
      engine; pair with ``make_train_step(overlap=True, ell=True)``.

    Pass ``mesh`` to commit every batch leaf to its core-axis
    :class:`~jax.sharding.NamedSharding` once, at build time.  Uncommitted
    arrays get re-laid-out by jit on EVERY step — per-step overhead that
    grows with the leaf count and was the measured cause of the blocked
    arm's ``agg_fwd_speedup < 1`` regression.  Host edge prep + placement
    then happen once per minibatch, never per step.
    """
    if layout is None:
        layout = "blocked" if blocked else "flat"
    if mesh is not None:
        # one transfer per leaf: numpy -> its NamedSharding directly (an
        # asarray-then-device_put would copy everything host->device twice)
        from .sharding import leading_axis_put

        def put(a):
            return leading_axis_put(mesh, a, axis)
    else:
        put = jnp.asarray
    if layout == "ell":
        shards = [shard_edges_ell(coo, n_cores) for coo in mb.layers]
        edges = [jax.tree_util.tree_map(put, es.tables) for es in shards]
    elif layout == "blocked":
        shards = [shard_edges_blocked(coo, n_cores) for coo in mb.layers]
        edges = [
            {"rows": put(es.rows_local),
             "cols": put(es.cols_local),
             "vals": put(es.vals)}
            for es in shards
        ]
    elif layout == "flat":
        shards = [shard_edges(coo, n_cores) for coo in mb.layers]
        edges = [
            {"rows": put(es.rows_global),
             "cols": put(es.cols_local),
             "vals": put(es.vals)}
            for es in shards
        ]
    else:
        raise ValueError(f"unknown layout {layout!r}")
    return {
        "edges": edges,
        "dims": [(es.n_dst, es.n_src) for es in shards],
        "x": put(np.asarray(features, np.float32)),
        "labels": put(np.asarray(labels, np.int32)),
    }


def _forward_local(params, edges, dims, x_local, ndim: int,
                   axis: str = "model", overlap: bool = False,
                   n_chunks: Optional[int] = None, ell: bool = False):
    """Per-device 2..L-layer GCN forward, deepest layer first (CoAg).

    ``overlap=True`` expects the Block-Message tile layout per layer and
    runs the double-buffered aggregation (bit-equal values, pipelined
    issue order); ``ell=True`` expects the pre-reduced ELL plan layout and
    runs the scatter-free engine under the same pipelined fold."""
    h = x_local
    n_layers = len(params)
    for l in range(n_layers - 1, -1, -1):
        e = edges[l]
        n_dst, _ = dims[l]
        h = h @ params[n_layers - 1 - l]["w"]          # local combination
        if ell:
            lead = jax.tree_util.tree_leaves(e)[0].shape[0]
            if lead != 1:
                # fail loudly: stripping [0] below would silently drop the
                # other senders' tables (the blocked path's tile-count
                # guard, re-established for the ELL layout)
                raise ValueError(
                    f"ELL edge tables hold {lead} senders per device; the "
                    "batch was built for a different core count than this "
                    "mesh — rebuild with shard_minibatch(..., n_cores="
                    "mesh core count)")
            tables = jax.tree_util.tree_map(lambda a: a[0], e)
            h = hypercube_aggregate_ell(axis, ndim, n_dst, tables, h,
                                        n_chunks)
        elif overlap:
            h = hypercube_aggregate_pipelined(
                axis, ndim, n_dst, e["rows"][0], e["cols"][0], e["vals"][0],
                h, n_chunks)
        else:
            h = hypercube_aggregate(axis, ndim, n_dst,  # routed aggregation
                                    e["rows"][0], e["cols"][0],
                                    e["vals"][0], h)
        if l != 0:
            h = jnp.maximum(h, 0.0)
    return h                                            # [batch/P, classes]


def make_train_step(mesh: Mesh, dims: Sequence[Tuple[int, int]],
                    lr: float = 0.05, axis: str = "model", *,
                    overlap: bool = False, n_chunks: Optional[int] = None,
                    ell: bool = False):
    """Build the jitted distributed train step for fixed layer dims.

    step(params, batch) -> (params, loss); params replicated, batch arrays
    sharded on their leading (core) axis.  ``overlap=True`` selects the
    pipelined aggregation (pass ``blocked=True`` to
    :func:`shard_minibatch`); forward AND backward then run the
    double-buffered schedule (the backward in mirror order).  ``ell=True``
    (pass ``layout="ell"``) runs the pre-reduced scatter-free engine under
    the same pipelined schedule, inheriting its transpose-free backward
    from :func:`repro.kernels.ops.ell_aggregate`'s registration.
    """
    n_cores = mesh.shape[axis]
    ndim = int(np.log2(n_cores))
    dims = tuple((int(a), int(b)) for a, b in dims)

    def body(params, edges, x_local, labels_local):
        def loss_fn(params):
            logits = _forward_local(params, edges, dims, x_local, ndim,
                                    axis, overlap, n_chunks, ell)
            logp = jax.nn.log_softmax(logits.astype(jnp.float32), -1)
            nll = -jnp.take_along_axis(logp, labels_local[:, None],
                                       axis=-1)[:, 0]
            # mean over the GLOBAL batch (each core owns batch/P rows)
            return jax.lax.pmean(nll.mean(), axis)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        # Weight Bank sync: average weight grads over the hypercube
        grads = jax.lax.pmean(grads, axis)
        params = jax.tree_util.tree_map(lambda p, g: p - lr * g, params,
                                        grads)
        return params, loss

    def step(params, batch):
        # every edge leaf is stacked per core on its leading axis — derive
        # the spec tree from the batch itself (works for all three layouts,
        # including the ELL plan's bucketed table pytree)
        from .sharding import leading_axis_spec
        edge_specs = jax.tree_util.tree_map(
            lambda a: leading_axis_spec(a, axis), batch["edges"])
        fn = shard_map(
            body,
            mesh=mesh,
            in_specs=(P(), edge_specs, P(axis, None), P(axis)),
            out_specs=(P(), P()),
        )
        return fn(params, batch["edges"], batch["x"], batch["labels"])

    return jax.jit(step)


def init_params(key, dims_io: Sequence[Tuple[int, int]]) -> Params:
    """dims_io: [(d_in, d_out), ...] output layer last."""
    params = []
    for i, (d_in, d_out) in enumerate(dims_io):
        key, k = jax.random.split(key)
        params.append({"w": (jax.random.normal(k, (d_in, d_out))
                             * d_in ** -0.5).astype(jnp.float32)})
    return params
