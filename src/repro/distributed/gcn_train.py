"""Distributed GCN training step — the paper's full architecture, deployed.

One `shard_map` over the ``model`` axis (= the 16-core hypercube) realizes
the paper end to end, per §4.1/§4.2's execution order:

  * **combination** is a LOCAL matmul on each core's feature rows (the NUMA
    claim: dense GEMM reads only core-local HBM at full bandwidth);
  * **aggregation** is the hypercube message-passing layer
    (:func:`repro.distributed.aggregate.hypercube_aggregate`): sender-side
    pre-reduction (Block-Message merge) + log₂P `ppermute` rounds;
  * the backward pass is the transpose-free mirror (custom_vjp inside the
    aggregate: all-gather of the error + column-major walk of the SAME edge
    table — no `Aᵀ`, no `Xᵀ`);
  * **Weight Bank sync**: weights are replicated per core; their gradients
    are `psum`'d over the hypercube after backward — the paper's
    "system controller periodically synchronizes global parameters".

Each sampled minibatch layer ships as sender-side :class:`EdgeShards`
([P, e_max] arrays, leading axis sharded).  Orders are CoAg (combine the
frontier first — the estimator's usual choice for wide-input layers);
AgCo support falls out of calling aggregate before the matmul.

Validated against the single-device reference in
tests/test_distributed.py::test_distributed_gcn_matches_reference and run
end-to-end by examples/distributed_gcn.py.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map
from repro.graph.sampler import MiniBatch
from .aggregate import (hypercube_aggregate, hypercube_aggregate_pipelined,
                        shard_edges, shard_edges_blocked)

Params = List[Dict[str, jnp.ndarray]]


def shard_minibatch(mb: MiniBatch, features: np.ndarray, labels: np.ndarray,
                    n_cores: int, *, blocked: bool = False) -> Dict[str, Any]:
    """Host-side: sampled minibatch → device-ready sharded arrays.

    Layers come deepest-first (matching forward consumption order); features
    are the frontier rows (already padded to a multiple of P).

    ``blocked=True`` ships the Block-Message tile layout
    ([P, B, eb] per-destination-block arrays, :func:`shard_edges_blocked`)
    that the pipelined/overlapped aggregation consumes; the default flat
    layout feeds the serial schedule."""
    if blocked:
        shards = [shard_edges_blocked(coo, n_cores) for coo in mb.layers]
        edges = [
            {"rows": jnp.asarray(es.rows_local),
             "cols": jnp.asarray(es.cols_local),
             "vals": jnp.asarray(es.vals)}
            for es in shards
        ]
    else:
        shards = [shard_edges(coo, n_cores) for coo in mb.layers]
        edges = [
            {"rows": jnp.asarray(es.rows_global),
             "cols": jnp.asarray(es.cols_local),
             "vals": jnp.asarray(es.vals)}
            for es in shards
        ]
    return {
        "edges": edges,
        "dims": [(es.n_dst, es.n_src) for es in shards],
        "x": jnp.asarray(features, jnp.float32),
        "labels": jnp.asarray(labels, jnp.int32),
    }


def _forward_local(params, edges, dims, x_local, ndim: int,
                   axis: str = "model", overlap: bool = False,
                   n_chunks: Optional[int] = None):
    """Per-device 2..L-layer GCN forward, deepest layer first (CoAg).

    ``overlap=True`` expects the Block-Message tile layout per layer and
    runs the double-buffered aggregation (bit-equal values, pipelined
    issue order)."""
    h = x_local
    n_layers = len(params)
    for l in range(n_layers - 1, -1, -1):
        e = edges[l]
        n_dst, _ = dims[l]
        h = h @ params[n_layers - 1 - l]["w"]          # local combination
        if overlap:
            h = hypercube_aggregate_pipelined(
                axis, ndim, n_dst, e["rows"][0], e["cols"][0], e["vals"][0],
                h, n_chunks)
        else:
            h = hypercube_aggregate(axis, ndim, n_dst,  # routed aggregation
                                    e["rows"][0], e["cols"][0],
                                    e["vals"][0], h)
        if l != 0:
            h = jnp.maximum(h, 0.0)
    return h                                            # [batch/P, classes]


def make_train_step(mesh: Mesh, dims: Sequence[Tuple[int, int]],
                    lr: float = 0.05, axis: str = "model", *,
                    overlap: bool = False, n_chunks: Optional[int] = None):
    """Build the jitted distributed train step for fixed layer dims.

    step(params, batch) -> (params, loss); params replicated, batch arrays
    sharded on their leading (core) axis.  ``overlap=True`` selects the
    pipelined aggregation (pass ``blocked=True`` to
    :func:`shard_minibatch`); forward AND backward then run the
    double-buffered schedule (the backward in mirror order).
    """
    n_cores = mesh.shape[axis]
    ndim = int(np.log2(n_cores))
    dims = tuple((int(a), int(b)) for a, b in dims)

    def body(params, edges, x_local, labels_local):
        def loss_fn(params):
            logits = _forward_local(params, edges, dims, x_local, ndim,
                                    axis, overlap, n_chunks)
            logp = jax.nn.log_softmax(logits.astype(jnp.float32), -1)
            nll = -jnp.take_along_axis(logp, labels_local[:, None],
                                       axis=-1)[:, 0]
            # mean over the GLOBAL batch (each core owns batch/P rows)
            return jax.lax.pmean(nll.mean(), axis)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        # Weight Bank sync: average weight grads over the hypercube
        grads = jax.lax.pmean(grads, axis)
        params = jax.tree_util.tree_map(lambda p, g: p - lr * g, params,
                                        grads)
        return params, loss

    nd = 3 if overlap else 2        # [P, B, eb] tiles vs [P, e_max] flat
    espec = P(axis, *([None] * (nd - 1)))
    edge_spec = {"rows": espec, "cols": espec, "vals": espec}

    def step(params, batch):
        n_layers = len(batch["edges"])
        fn = shard_map(
            body,
            mesh=mesh,
            in_specs=(P(), [edge_spec] * n_layers, P(axis, None), P(axis)),
            out_specs=(P(), P()),
        )
        return fn(params, batch["edges"], batch["x"], batch["labels"])

    return jax.jit(step)


def init_params(key, dims_io: Sequence[Tuple[int, int]]) -> Params:
    """dims_io: [(d_in, d_out), ...] output layer last."""
    params = []
    for i, (d_in, d_out) in enumerate(dims_io):
        key, k = jax.random.split(key)
        params.append({"w": (jax.random.normal(k, (d_in, d_out))
                             * d_in ** -0.5).astype(jnp.float32)})
    return params
