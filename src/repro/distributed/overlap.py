"""Compute/communication overlap: double-buffered collectives + microbatching.

Two layers of overlap live here, both instances of the paper's Eq. 9
criterion ``t = max(t_message_passing, t_comb + t_agg)`` — a layer is judged
by the slower of wire and MAC work, so the win comes from keeping both busy:

1. **Double-buffered exchange** (:func:`double_buffered_exchange`): the
   dataflow form of the paper's ping-pong Block-Message buffers (§4.2/§4.3,
   Fig. 9).  A hypercube round's traffic is split into feature-dimension
   waves (:func:`repro.core.schedule.feature_waves`); every wave's
   ``ppermute`` is issued BEFORE any wave's local combine is consumed, so
   XLA's latency-hiding scheduler can run wave *k*'s add (and the next
   wave's local SpMM) under wave *k+1*'s wire transfer.  The per-element
   add order is untouched — the pipelined fold stays bit-identical to the
   serial one in fp32.  :mod:`repro.distributed.aggregate` builds its
   pipelined reduce-scatter / all-gather out of this primitive.

2. **Microbatched gradient accumulation** (:func:`grad_accum`): split the
   per-device batch into M microbatches, scan compute, and expose the
   gradient all-reduce early enough that XLA overlaps it with the next
   microbatch's backward — the bucketed all-reduce every 1000-node trainer
   runs.  ``bucketed=False`` accumulates locally with one psum at the end
   (min bytes, zero overlap); ``bucketed=True`` psums every microbatch
   (bytes × M, every psum hidden behind compute).  ``jax.remat`` wraps the
   loss for activation checkpointing (the SFBP save-for-backprop buffers
   are the FPGA analogue).
"""
from __future__ import annotations

import functools
from typing import Callable, List, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.compat import axis_size


# ---------------------------------------------------------------------------
# Double-buffered collective exchange (the ping-pong buffer, in dataflow).
# ---------------------------------------------------------------------------
def double_buffered_exchange(chunks: Sequence[jnp.ndarray],
                             split_fn: Callable,
                             permute_fn: Callable) -> List[jnp.ndarray]:
    """One pipelined hypercube round over feature-wave ``chunks``.

    For every chunk, ``split_fn(chunk) -> (mine, send)`` separates the half
    this device keeps from the half it ships; ``permute_fn(send)`` is the
    round's ``ppermute``.  All sends are issued before any ``mine + recv``
    combine consumes a result — the ping-pong structure: while chunk *k*'s
    transfer is on the wire, chunk *k+1*'s split (and, in the fused
    aggregation path, its local SpMM) has independent work to run.

    Returns the combined ``mine + recv`` per chunk.  Addition order per
    element is exactly the serial schedule's, so results are bit-identical.
    """
    mines, recvs = [], []
    for chunk in chunks:
        mine, send = split_fn(chunk)
        recvs.append(permute_fn(send))      # issued before any combine
        mines.append(mine)
    return [m + r for m, r in zip(mines, recvs)]


def double_buffered_rounds(chunks: Sequence[jnp.ndarray],
                           round_fns: Sequence[Callable]
                           ) -> List[jnp.ndarray]:
    """A full pipelined exchange: one double-buffered round per topology
    step.

    The round count is the TOPOLOGY's step count
    (:meth:`repro.topology.Topology.steps`) — ``log₂P`` rounds for the
    hypercube fold, ``P−1`` for a ring — not a hardcoded hypercube loop.
    Each entry of ``round_fns`` is called with the current chunks and
    returns that round's ``(split_fn, permute_fn)`` pair (the buffer halves
    shrink as a fold progresses, so the split is derived per round); the
    round itself runs through :func:`double_buffered_exchange`, keeping the
    all-sends-before-any-combine ping-pong structure — and the per-element
    add order — of the serial schedule.
    """
    for make_round in round_fns:
        split_fn, permute_fn = make_round(chunks)
        chunks = double_buffered_exchange(chunks, split_fn, permute_fn)
    return list(chunks)


# ---------------------------------------------------------------------------
# Microbatched gradient accumulation.
# ---------------------------------------------------------------------------
def grad_accum(loss_fn: Callable, params, batch, *, n_micro: int,
               axis_names: Tuple[str, ...] = (), bucketed: bool = False,
               remat: bool = False):
    """Mean loss + mean grads over ``n_micro`` microbatches.

    ``batch``: pytree with leading dim divisible by n_micro (per-device
    batch).  ``axis_names``: DP axes to psum over (empty = caller handles
    the reduction, e.g. via pjit out-sharding).
    """
    f = jax.remat(loss_fn) if remat else loss_fn

    def micro_slice(i):
        return jax.tree_util.tree_map(
            lambda x: jax.lax.dynamic_slice_in_dim(
                x, i * (x.shape[0] // n_micro), x.shape[0] // n_micro, 0),
            batch)

    def body(carry, i):
        loss_acc, grad_acc = carry
        loss, grads = jax.value_and_grad(f)(params, micro_slice(i))
        if bucketed and axis_names:
            # early reduction: this psum overlaps microbatch i+1's compute
            grads = jax.lax.psum(grads, axis_names)
            loss = jax.lax.psum(loss, axis_names)
        new = (loss_acc + loss,
               jax.tree_util.tree_map(jnp.add, grad_acc, grads))
        return new, ()

    zero_grads = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    (loss_sum, grad_sum), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), zero_grads),
        jnp.arange(n_micro))

    if not bucketed and axis_names:
        grad_sum = jax.lax.psum(grad_sum, axis_names)
        loss_sum = jax.lax.psum(loss_sum, axis_names)
    denom = n_micro * (_axis_prod(axis_names) if axis_names else 1)
    mean = functools.partial(jax.tree_util.tree_map,
                             lambda x: x / denom)
    return loss_sum / denom, mean(grad_sum)


def _axis_prod(axis_names: Tuple[str, ...]):
    size = 1
    for a in axis_names:
        size = size * axis_size(a)
    return size
