"""Compute/communication overlap: microbatched gradient accumulation.

The paper overlaps aggregation messages with MAC compute via ping-pong
buffers (§4.2) and judges a layer by
``t = max(t_message_passing, t_comb + t_agg)`` (Eq. 9).  The framework-level
analogue at scale is microbatching: split the per-device batch into M
microbatches, scan compute, and expose the gradient all-reduce early enough
that XLA's latency-hiding scheduler overlaps it with the next microbatch's
backward — the bucketed all-reduce every 1000-node trainer runs.

Two modes:
  * ``bucketed=False`` — accumulate locally, one psum at the end (min bytes,
    zero overlap: the collective sits on the critical path);
  * ``bucketed=True``  — psum each microbatch's grads inside the scan; bytes
    × M but every psum overlaps the next microbatch's compute.  Eq. 9 says
    this wins whenever compute-per-microbatch ≥ wire-time-per-bucket, which
    the roofline table evaluates per arch.

``jax.remat`` wraps the loss for activation checkpointing (the SFBP buffers
— save-for-backprop — are the FPGA analogue; remat trades their HBM for
recompute, the knob the §Perf hillclimb turns).
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp


def grad_accum(loss_fn: Callable, params, batch, *, n_micro: int,
               axis_names: Tuple[str, ...] = (), bucketed: bool = False,
               remat: bool = False):
    """Mean loss + mean grads over ``n_micro`` microbatches.

    ``batch``: pytree with leading dim divisible by n_micro (per-device
    batch).  ``axis_names``: DP axes to psum over (empty = caller handles
    the reduction, e.g. via pjit out-sharding).
    """
    f = jax.remat(loss_fn) if remat else loss_fn

    def micro_slice(i):
        return jax.tree_util.tree_map(
            lambda x: jax.lax.dynamic_slice_in_dim(
                x, i * (x.shape[0] // n_micro), x.shape[0] // n_micro, 0),
            batch)

    def body(carry, i):
        loss_acc, grad_acc = carry
        loss, grads = jax.value_and_grad(f)(params, micro_slice(i))
        if bucketed and axis_names:
            # early reduction: this psum overlaps microbatch i+1's compute
            grads = jax.lax.psum(grads, axis_names)
            loss = jax.lax.psum(loss, axis_names)
        new = (loss_acc + loss,
               jax.tree_util.tree_map(jnp.add, grad_acc, grads))
        return new, ()

    zero_grads = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    (loss_sum, grad_sum), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), zero_grads),
        jnp.arange(n_micro))

    if not bucketed and axis_names:
        grad_sum = jax.lax.psum(grad_sum, axis_names)
        loss_sum = jax.lax.psum(loss_sum, axis_names)
    denom = n_micro * (_axis_prod(axis_names) if axis_names else 1)
    mean = functools.partial(jax.tree_util.tree_map,
                             lambda x: x / denom)
    return loss_sum / denom, mean(grad_sum)


def _axis_prod(axis_names: Tuple[str, ...]):
    size = 1
    for a in axis_names:
        size = size * jax.lax.axis_size(a)
    return size
