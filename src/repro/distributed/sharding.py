"""PartitionSpec rules — one place that knows where every tensor lives.

Mesh axes (launch/mesh.py):
  * ``pod``   — outer data-parallel axis across pods (multi-pod mesh only)
  * ``data``  — data parallel within a pod
  * ``model`` — the 16-way "core" axis: TP for dense LMs, EP for MoE, and
                the paper's 4-D hypercube for graph aggregation (16 = 2⁴)

The rule of the paper's NUMA layout generalizes: *a tensor is sharded on the
axis that makes its heaviest consumer local.*  Node features and edge blocks
shard over ``model`` (aggregation is the consumer), LM weights shard over
``model`` on their contraction-free dim (megatron TP), activations shard
batch over (``pod``, ``data``) and sequence over ``model`` where the shape
is long (SP for 32k prefill).
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MODEL = "model"
DATA = "data"
POD = "pod"


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    """All data-parallel axes present in this mesh (pod outermost)."""
    return tuple(a for a in (POD, DATA) if a in mesh.axis_names)


def dp_size(mesh: Mesh) -> int:
    out = 1
    for a in batch_axes(mesh):
        out *= mesh.shape[a]
    return out


# --- activation specs -------------------------------------------------------
def act_batch(mesh: Mesh, *trailing: Optional[str]) -> P:
    """[batch, ...] activation: batch over all DP axes."""
    return P(batch_axes(mesh), *trailing)


def act_batch_seq(mesh: Mesh, shard_seq: bool = False) -> P:
    """[batch, seq, d] activation; optionally sequence-sharded over model
    (SP — used for long prefill where seq ≫ heads)."""
    if shard_seq:
        return P(batch_axes(mesh), MODEL, None)
    return P(batch_axes(mesh), None, None)


# --- weight specs (megatron pairing: col-shard then row-shard) --------------
def w_col(mesh: Mesh) -> P:
    """[d_in, d_out] with d_out over model (QKV proj, FFN up/gate)."""
    return P(None, MODEL)


def w_row(mesh: Mesh) -> P:
    """[d_in, d_out] with d_in over model (attn out proj, FFN down)."""
    return P(MODEL, None)


def w_replicated(mesh: Mesh) -> P:
    return P()


def embed_vocab(mesh: Mesh) -> P:
    """[vocab, d] — vocab over model (the big-embedding archs: gemma3 262k,
    seamless 256k, moonshot 164k)."""
    return P(MODEL, None)


def moe_expert(mesh: Mesh) -> P:
    """[experts, d_in, d_out] — experts over model (EP)."""
    return P(MODEL, None, None)


def kv_cache(mesh: Mesh) -> P:
    """[batch, heads_kv, seq, hd] — batch over DP, kv heads over model when
    they divide, else replicated heads (GQA kv=4/8 < 16 ⇒ batch-shard only)."""
    return P(batch_axes(mesh), MODEL, None, None)


# --- graph (paper) specs ----------------------------------------------------
def node_features(mesh: Mesh) -> P:
    """[n_nodes, d] — rows over model: the NUMA placement (core i owns its
    nodes' features in its own HBM)."""
    return P(MODEL, None)


def edge_shards(mesh: Mesh) -> P:
    """[P, e_max] sender-side edge blocks — leading axis over model."""
    return P(MODEL, None)


def gcn_weights(mesh: Mesh) -> P:
    """GCN weights are replicated over model (the paper's Weight Bank keeps a
    synchronized global copy per core) and all-reduced over DP."""
    return P()


def named(mesh: Mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)


def leading_axis_spec(a, axis: str = MODEL) -> P:
    """Spec of a per-core-stacked host artifact: leading axis over ``axis``,
    everything else replicated — the rule every edge layout (flat COO tile,
    Block-Message tile, pre-reduced ELL table/inv leaf) shares."""
    return P(axis, *([None] * (a.ndim - 1)))


def leading_axis_put(mesh: Mesh, a, axis: str = MODEL):
    """Commit one per-core-stacked leaf to its sharding in ONE transfer.

    This placement-at-build-time is load-bearing: jit re-lays-out
    uncommitted operands on EVERY call, which was the measured cause of the
    blocked arm's ``agg_fwd_speedup < 1`` regression.  Train path and
    benchmarks must place leaves through this one helper so they can never
    measure different placements.
    """
    import numpy as np

    a = np.asarray(a)
    return jax.device_put(a, NamedSharding(mesh, leading_axis_spec(a, axis)))
