# Distribution layer: the paper's NUMA placement + hypercube NoC lowered to
# shard_map/ppermute (aggregate.py), PartitionSpec rules (sharding.py), and
# the at-scale tricks the 1000-node deployment needs (compress.py,
# overlap.py).
from .aggregate import (EdgeShards, EllEdgeShards, hypercube_aggregate,
                        hypercube_aggregate_ell, hypercube_allgather,
                        hypercube_reduce_scatter, schedule_bytes, shard_edges,
                        shard_edges_by_dst, shard_edges_ell, uma_aggregate)
from .compress import (compressed_psum, compression_ratio, ef_compress_grads,
                       init_error_state)
from .overlap import grad_accum
from . import sharding

__all__ = [
    "EdgeShards", "EllEdgeShards", "hypercube_aggregate",
    "hypercube_aggregate_ell", "hypercube_allgather",
    "hypercube_reduce_scatter", "schedule_bytes", "shard_edges",
    "shard_edges_by_dst", "shard_edges_ell", "uma_aggregate",
    "compressed_psum", "compression_ratio", "ef_compress_grads",
    "init_error_state", "grad_accum", "sharding",
]
