"""Distributed graph aggregation — the paper's NUMA + hypercube NoC on TPU.

Placement (paper §4.1): node features are row-sharded over the ``model`` mesh
axis — device *i* is core *i* and owns its rows' HBM exclusively (NUMA, no
global addressing).  Each device also owns the edge blocks whose *sources*
live on it (column *i* of the block grid): senders know their outgoing
messages, exactly like the Block-Message buffers sit in the source core.

Aggregation then runs in two stages inside ``shard_map``:

  1. **Local pre-reduction** (the Index Compressor / Reduced Register File):
     each device segment-sums its own sources into partial rows for *every*
     destination core — a single SpMM against the local feature shard.  The
     wire will carry one partial row per (block, aggregate-slot), never raw
     neighbor rows: this is the paper's N ≤ nnz compression.

  2. **Topology exchange** (:mod:`repro.topology`): the partial row-blocks
     fold down to their owner cores over the engine's configured
     interconnect — the ``log₂P`` dimension-ordered hypercube (the default
     and the fp32 oracle schedule), a ring, a dense all-pairs reference, or
     the paper's orthogonal 2-D torus.  The exchange loops that used to
     live inline here are the registered :class:`~repro.topology.Topology`
     objects' ``reduce_scatter``/``allgather``/``fold_pipelined`` plans;
     :func:`hypercube_reduce_scatter` and friends remain as delegating
     shims over :mod:`repro.topology.hypercube`.

The backward pass is the paper's Table-1 redesign, distributed: a
``custom_vjp`` runs the *mirror* schedule — all-gather the error rows over
the SAME topology (the transpose of its reduce-scatter) and walk the SAME
local edge table column-major (``Aᵀ`` without an ``Aᵀ``) — so no
transposed feature matrix, no second edge table, and no transposed
exchange schedule exist on any device, whatever the interconnect.

A UMA/SMP baseline (:func:`uma_aggregate`) does what the paper argues
against: all-gather raw features everywhere, aggregate redundantly, discard.
The roofline benchmark counts both schedules' collective bytes.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.blockmsg import block_tiles
from repro.cotangents import zero_ct
from repro.graph.coo import COO
from repro.graph.partition import block_partition


def _topo(name: str):
    # lazy: aggregate ← engine ← topology all import each other at module
    # level somewhere along the chain; at trace time everything is fully
    # initialized and repro.topology.base owns the one lookup path
    from repro.topology.base import _topo as lookup
    return lookup(name)


# ---------------------------------------------------------------------------
# Hypercube collective shims — canonical implementations moved to
# repro.topology.hypercube (the Topology registry owns the exchange plans);
# these names stay for the callers/tests that predate the topology axis.
# ---------------------------------------------------------------------------
def hypercube_reduce_scatter(partial: jnp.ndarray, axis_name: str,
                             ndim: int) -> jnp.ndarray:
    """Delegates to :func:`repro.topology.hypercube.hypercube_reduce_scatter`."""
    from repro.topology.hypercube import hypercube_reduce_scatter as f
    return f(partial, axis_name, ndim)


def hypercube_allgather(x: jnp.ndarray, axis_name: str, ndim: int
                        ) -> jnp.ndarray:
    """Delegates to :func:`repro.topology.hypercube.hypercube_allgather`."""
    from repro.topology.hypercube import hypercube_allgather as f
    return f(x, axis_name, ndim)


def hypercube_reduce_scatter_pipelined(partial: jnp.ndarray, axis_name: str,
                                       ndim: int, n_chunks: int = 2
                                       ) -> jnp.ndarray:
    """Delegates to
    :func:`repro.topology.hypercube.hypercube_reduce_scatter_pipelined`."""
    from repro.topology.hypercube import (
        hypercube_reduce_scatter_pipelined as f)
    return f(partial, axis_name, ndim, n_chunks)


def hypercube_allgather_pipelined(x: jnp.ndarray, axis_name: str, ndim: int,
                                  n_chunks: int = 2) -> jnp.ndarray:
    """Delegates to
    :func:`repro.topology.hypercube.hypercube_allgather_pipelined`."""
    from repro.topology.hypercube import hypercube_allgather_pipelined as f
    return f(x, axis_name, ndim, n_chunks)


# ---------------------------------------------------------------------------
# Per-device edge shards (host-side build, done once per minibatch).
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class EdgeShards:
    """Sender-side edge blocks, stacked per source core and padded.

    rows_global: [P, e_max] int32 — destination id in GLOBAL row numbering
                 (owner core × tile + slot; Fig. 7's A·64+B).
    cols_local:  [P, e_max] int32 — source slot on the owning device (D).
    vals:        [P, e_max] f32   — Ã weights (0 = padding).
    """

    rows_global: np.ndarray
    cols_local: np.ndarray
    vals: np.ndarray
    n_dst: int
    n_src: int
    n_cores: int

    @property
    def dst_per_core(self) -> int:
        return self.n_dst // self.n_cores

    @property
    def src_per_core(self) -> int:
        return self.n_src // self.n_cores


def shard_edges(coo: COO, n_cores: int,
                e_max: Optional[int] = None) -> EdgeShards:
    """Partition a (padded) COO by SOURCE core — column stripes of the block
    grid — and pad each device's edge list to a common static length."""
    blocked = block_partition(coo, n_cores)
    spc = blocked.src_per_core
    dpc = blocked.dst_per_core
    per_core: list = [[] for _ in range(n_cores)]
    for (i, j), (lr, lc, v) in blocked.block_edges.items():
        per_core[j].append((lr.astype(np.int64) + i * dpc, lc, v))
    if e_max is None:
        e_max = max((sum(len(t[0]) for t in lst) for lst in per_core),
                    default=1)
        e_max = max(int(e_max), 1)
    rows = np.zeros((n_cores, e_max), np.int32)
    cols = np.zeros((n_cores, e_max), np.int32)
    vals = np.zeros((n_cores, e_max), np.float32)
    for j, lst in enumerate(per_core):
        if not lst:
            continue
        r = np.concatenate([t[0] for t in lst])
        c = np.concatenate([t[1] for t in lst])
        v = np.concatenate([t[2] for t in lst])
        if len(r) > e_max:
            raise ValueError(f"core {j} has {len(r)} edges > e_max={e_max}")
        rows[j, :len(r)] = r
        cols[j, :len(c)] = c
        vals[j, :len(v)] = v
    return EdgeShards(rows_global=rows, cols_local=cols, vals=vals,
                      n_dst=coo.n_dst, n_src=coo.n_src, n_cores=n_cores)


# ---------------------------------------------------------------------------
# The distributed aggregate, with the paper's backward dataflow (custom_vjp).
# Shapes inside shard_map (per device): x_local [spc, d] -> y_local [dpc, d].
# ---------------------------------------------------------------------------
def _local_partials(rows_g, cols_l, vals, x_local, n_dst):
    """Stage 1: partial rows for every destination core from local sources."""
    gathered = x_local[cols_l] * vals[:, None]
    return jax.ops.segment_sum(gathered, rows_g, num_segments=n_dst)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2, 3))
def _hypercube_aggregate(axis_name: str, ndim: int, n_dst: int,
                         topology: str, rows_g, cols_l, vals, x_local):
    n_cores = 1 << ndim
    partial = _local_partials(rows_g, cols_l, vals, x_local, n_dst)
    partial = partial.reshape(n_cores, n_dst // n_cores, -1)
    return _topo(topology).reduce_scatter(partial, axis_name, n_cores)


def _hyper_fwd(axis_name, ndim, n_dst, topology, rows_g, cols_l, vals,
               x_local):
    y = _hypercube_aggregate(axis_name, ndim, n_dst, topology, rows_g,
                             cols_l, vals, x_local)
    return y, (rows_g, cols_l, vals, x_local)


def _hyper_bwd(axis_name, ndim, n_dst, topology, res, ct):
    rows_g, cols_l, vals, x_local = res
    # mirror schedule: error rows of ALL cores over the SAME topology
    # (the transpose of its reduce-scatter)
    e_full = _topo(topology).allgather(ct, axis_name, 1 << ndim)
    e_full = e_full.reshape(n_dst, -1)
    # Aᵀ walk of the SAME local edge table (column-major = Graph Converter):
    # dx[c] += v · e[r]  — consumes global rows, produces local cols.
    n_src_local = x_local.shape[0]
    gathered = e_full[rows_g] * vals[:, None]
    dx_local = jax.ops.segment_sum(gathered, cols_l,
                                   num_segments=n_src_local)
    # adjacency is fixed: float0 for the index arrays, zeros for the weights
    return (*zero_ct((rows_g, cols_l, vals)), dx_local)


_hypercube_aggregate.defvjp(_hyper_fwd, _hyper_bwd)


def hypercube_aggregate(axis_name: str, ndim: int, n_dst: int,
                        rows_g: jnp.ndarray, cols_l: jnp.ndarray,
                        vals: jnp.ndarray, x_local: jnp.ndarray,
                        topology: str = "hypercube") -> jnp.ndarray:
    """Per-device body: ``y_local = (A @ x)_local`` via pre-reduce + fold.

    Call inside ``shard_map`` over ``axis_name``; edge arrays are this
    device's :class:`EdgeShards` slice, ``x_local`` its feature rows.
    ``topology`` names the registered interconnect the partial rows fold
    over (default: the paper's hypercube — the historical name of this
    entry point); the backward all-gathers the error rows over the same
    topology's mirror schedule.
    """
    return _hypercube_aggregate(axis_name, ndim, n_dst, topology, rows_g,
                                cols_l, vals, x_local)


# ---------------------------------------------------------------------------
# Block-tile edge shards + the fused, double-buffered aggregate.
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class BlockEdgeShards:
    """Sender-side edges in the Block-Message tile layout, stacked per core.

    Device *j* (= source core *j*) holds ``rows_local[j]``: [B, eb] int32
    block-LOCAL destination slots (Fig. 7's B values) for each of the B
    destination-core tiles, plus matching ``cols_local`` (D values, local
    source slots) and ``vals``.  This is :func:`repro.core.blockmsg.block_tiles`
    per sender, padded to a common static tile size — the layout both the
    block-layout SpMM kernel and the pipelined aggregate consume directly,
    with no global row ids and no one-hot over ``n_dst``.
    """

    rows_local: np.ndarray   # [P, B, eb] int32 — dst slot within dst block
    cols_local: np.ndarray   # [P, B, eb] int32 — source slot on the sender
    vals: np.ndarray         # [P, B, eb] f32   — Ã weights (0 = padding)
    n_dst: int
    n_src: int
    n_cores: int

    @property
    def dst_per_core(self) -> int:
        return self.n_dst // self.n_cores

    @property
    def src_per_core(self) -> int:
        return self.n_src // self.n_cores


def shard_edges_blocked(coo: COO, n_cores: int,
                        eb_max: Optional[int] = None) -> BlockEdgeShards:
    """Partition a (padded) COO into per-sender Block-Message tiles.

    Same source-core striping as :func:`shard_edges`, but each sender's
    edges stay grouped per destination-core block with block-local row
    offsets.  Edge order inside every tile is the block partition's
    (row, col) sort — identical to the flat layout's order per destination
    row, so the blocked and flat aggregation paths are fp32 bit-equal.
    """
    blocked = block_partition(coo, n_cores)
    if eb_max is None:
        eb_max = max((len(r) for (r, _, _) in blocked.block_edges.values()),
                     default=1)
        eb_max = max(int(eb_max), 1)
    tiles = [block_tiles(blocked, j, eb_max=eb_max) for j in range(n_cores)]
    return BlockEdgeShards(
        rows_local=np.stack([t.rows for t in tiles]),
        cols_local=np.stack([t.cols for t in tiles]),
        vals=np.stack([t.vals for t in tiles]),
        n_dst=coo.n_dst, n_src=coo.n_src, n_cores=n_cores)


def _local_partials_blocked(rows_b, cols_b, vals_b, x_local, dpc: int):
    """Per-destination-block partial rows: [B, eb] tiles → [B, dpc, d].

    The block-local offsets are globalized with a trace-time iota
    (tile·dpc + r) and all tiles scatter through ONE segment-sum — same
    per-row add order as both the flat layout and a per-tile walk (tiles
    are concatenated in block order), so results stay fp32 bit-equal, and
    XLA sees a single large scatter instead of a batched small one (a
    vmapped per-tile segment-sum lowers to a serialized scatter loop on
    CPU).  The Pallas twin that scatters per-tile into a [dpc, bd]
    Aggregate Buffer is :func:`repro.kernels.spmm.spmm_block`.
    """
    n_blocks = rows_b.shape[0]
    rows_g = (rows_b
              + (jnp.arange(n_blocks, dtype=rows_b.dtype) * dpc)[:, None])
    gathered = x_local[cols_b.reshape(-1)] * vals_b.reshape(-1)[:, None]
    out = jax.ops.segment_sum(gathered, rows_g.reshape(-1),
                              num_segments=n_blocks * dpc)
    return out.reshape(n_blocks, dpc, -1)


def _pipelined_fwd_impl(axis_name: str, ndim: int, n_dst: int,
                        n_chunks: int, topology: str, rows_b, cols_b,
                        vals_b, x_local):
    """Block-tile partials through the topology's fused pipelined fold.

    ``Topology.fold_pipelined`` owns the exchange: the hypercube runs the
    fused SpMM + ping-pong fold (§4.3, Fig. 9 — the first round's send is
    on the wire while the still-owned half's SpMM computes); other
    topologies default to per-wave reduce-scatters whose sends are
    independent dataflow.
    """
    n_cores = 1 << ndim
    dpc = n_dst // n_cores
    if rows_b.shape[0] != n_cores:
        # fail loudly: dynamic_slice would CLAMP an out-of-range start and
        # silently duplicate blocks into both 'mine' and 'send'
        raise ValueError(
            f"tile count {rows_b.shape[0]} != 2^ndim = {n_cores}; edge "
            "arrays must come from shard_edges_blocked on the same mesh")
    return _topo(topology).fold_pipelined(
        axis_name, n_cores, n_chunks,
        lambda xc: _local_partials_blocked(rows_b, cols_b, vals_b, xc, dpc),
        x_local)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2, 3, 4))
def _hypercube_aggregate_pipelined(axis_name: str, ndim: int, n_dst: int,
                                   n_chunks: int, topology: str, rows_b,
                                   cols_b, vals_b, x_local):
    return _pipelined_fwd_impl(axis_name, ndim, n_dst, n_chunks, topology,
                               rows_b, cols_b, vals_b, x_local)


def _pipe_fwd(axis_name, ndim, n_dst, n_chunks, topology, rows_b, cols_b,
              vals_b, x_local):
    y = _hypercube_aggregate_pipelined(axis_name, ndim, n_dst, n_chunks,
                                       topology, rows_b, cols_b, vals_b,
                                       x_local)
    return y, (rows_b, cols_b, vals_b, x_local)


def _pipe_bwd(axis_name, ndim, n_dst, n_chunks, topology, res, ct):
    from repro.core.gcn import _spmm_t_blocked

    rows_b, cols_b, vals_b, x_local = res
    # mirror schedule, same topology, same waves: all-gather the error rows
    e_full = _topo(topology).allgather_pipelined(ct, axis_name, 1 << ndim,
                                                 n_chunks)
    # Aᵀ walk of the SAME block tiles, column-major: tile b's error rows are
    # the contiguous slab e_full[b] — one shared implementation with the
    # single-device blocked layer.
    dx_local = _spmm_t_blocked(rows_b, cols_b, vals_b,
                               e_full.reshape(n_dst, -1), x_local.shape[0])
    return (*zero_ct((rows_b, cols_b, vals_b)), dx_local)


_hypercube_aggregate_pipelined.defvjp(_pipe_fwd, _pipe_bwd)


def default_n_chunks() -> int:
    """Backend-tuned wave count for the pipelined schedule.

    On accelerators with async collectives (TPU/GPU) two waves let the wire
    hide under MAC work; on the CPU backend collectives are synchronous
    thread barriers, so extra waves only add slice copies — one wave keeps
    the blocked layout + pipelined issue order without the copy tax.
    """
    return 2 if jax.default_backend() in ("tpu", "gpu") else 1


def hypercube_aggregate_pipelined(axis_name: str, ndim: int, n_dst: int,
                                  rows_b: jnp.ndarray, cols_b: jnp.ndarray,
                                  vals_b: jnp.ndarray, x_local: jnp.ndarray,
                                  n_chunks: Optional[int] = None,
                                  topology: str = "hypercube"
                                  ) -> jnp.ndarray:
    """Per-device body: ``y_local = (A @ x)_local`` with the double-buffered
    schedule — block-tile SpMM overlapped with the topology's fold.

    Call inside ``shard_map`` over ``axis_name``; edge arrays are this
    device's :class:`BlockEdgeShards` slice ([B, eb] tiles), ``x_local`` its
    feature rows.  On the default hypercube topology, fp32 results (and the
    custom-vjp backward) are bit-equal to :func:`hypercube_aggregate` for
    ANY wave count — only the issue order differs; other topologies reorder
    the partial-row additions and match to fp32 roundoff (≤1e-5).
    ``n_chunks=None`` picks :func:`default_n_chunks`.
    """
    if n_chunks is None:
        n_chunks = default_n_chunks()
    return _hypercube_aggregate_pipelined(axis_name, ndim, n_dst,
                                          int(n_chunks), topology, rows_b,
                                          cols_b, vals_b, x_local)


# ---------------------------------------------------------------------------
# Pre-reduced ELL edge shards + the scatter-free pipelined aggregate.
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class EllEdgeShards:
    """Per-sender pre-reduced ELL plans, stacked for ``shard_map``.

    ``tables`` mirrors :meth:`repro.kernels.edgeplan.EdgePlan.device_tables`
    with every leaf stacked on a leading core axis: ``cols``/``vals`` are
    per-bucket ``[P, nb, K]`` tables over the GLOBAL partial-row space
    (``dst_core·dpc + B``) with sender-local source slots, ``inv`` is
    ``[P, n_dst]``, and the ``t_*`` leaves are the column-major mirror
    (rows = sender-local source slots, columns = global error rows).
    Bucket capacities and per-bucket row counts are shared across senders so
    every device sees identical shapes.  Built once per graph and cached.

    Redundancy-merged shards (``merge="redundancy"``) additionally carry
    the stacked ``vv_*``/``vvt_*`` pre-pass tables over a shared
    virtual-vertex pad (max across senders), and ``merge_stats`` sums the
    per-sender mining stats.
    """

    tables: Dict
    n_dst: int
    n_src: int
    n_cores: int
    merge_stats: Dict = dataclasses.field(default_factory=dict)

    @property
    def n_virtual(self) -> int:
        return int(self.merge_stats.get("n_virtual", 0))

    @property
    def pair_coverage(self) -> float:
        return float(self.merge_stats.get("pair_coverage", 0.0))

    @property
    def flop_reduction(self) -> float:
        return float(self.merge_stats.get("flop_reduction", 1.0))

    @property
    def dst_per_core(self) -> int:
        return self.n_dst // self.n_cores

    @property
    def src_per_core(self) -> int:
        return self.n_src // self.n_cores


def _stack_sender_tables(flats, n_rows: int, n_cols: int, caps) -> Dict:
    """Per-sender flat edges → shape-aligned stacked ELL tables (one
    direction).  Two passes: degrees fix the shared capacities and the
    per-bucket row pads, then every sender builds against them."""
    from repro.kernels import edgeplan

    degs = [edgeplan.merged_degrees(r, c, v, n_rows, n_cols)
            for (r, c, v) in flats]
    max_deg = max((int(d.max()) for d in degs if d.size), default=0)
    caps_t = edgeplan.resolve_caps(caps, max_deg)
    caps_arr = np.asarray(caps_t, np.int64)
    nb_pad = np.zeros(len(caps_t), np.int64)
    for d in degs:
        listed = d[d > 0]
        counts = np.bincount(np.searchsorted(caps_arr, listed, side="left"),
                             minlength=len(caps_t))
        nb_pad = np.maximum(nb_pad, counts)
    tabs = [edgeplan.build_tables(r, c, v, n_rows, n_cols, caps=caps_t,
                                  nb_pad=nb_pad.tolist())
            for (r, c, v) in flats]
    keep = [b for b in range(len(caps_t)) if nb_pad[b] > 0]
    return {
        "cols": tuple(np.stack([t.cols[b] for t in tabs]) for b in keep),
        "vals": tuple(np.stack([t.vals[b] for t in tabs]) for b in keep),
        "inv": np.stack([t.inv_perm for t in tabs]),
    }


def shard_edges_ell(coo: COO, n_cores: int, caps=None,
                    merge: str = "dedup") -> EllEdgeShards:
    """Partition a (padded) COO into per-sender pre-reduced ELL plans.

    Same source-core striping as :func:`shard_edges`, but each sender's
    edges go through the Index Compressor
    (:func:`repro.core.blockmsg.sender_merge_flat` — ``compress_block`` per
    block) and land as degree-bucketed ELL tables: the local pre-reduction
    becomes a gather + degree-axis reduction with NO segment-sum scatter,
    forward and backward.  Built once per (graph, mesh) and cached on the
    COO's identity — per-step host edge prep disappears.

    ``merge="redundancy"`` runs
    :func:`repro.kernels.edgeplan.mine_pair_redundancy` per sender AFTER
    the within-block merge, so destination rows on every core gather from
    (original ∪ virtual) sender-local sources.  Virtual ids are padded to
    the max across senders so the stacked tables stay shape-aligned;
    senders with fewer virtual vertices leave the pad rows edge-free
    (their ``inv`` fills zeros).  Degrades to the plain shards when no
    sender mines a pair.
    """
    from repro.core.blockmsg import sender_merge_flat
    from repro.kernels import edgeplan

    edgeplan.validate_merge(merge)
    if caps is None:
        from repro.kernels.tune import get_config
        caps = get_config()["caps"]
    caps_key = caps if isinstance(caps, str) else tuple(caps)

    def _build() -> EllEdgeShards:
        blocked = block_partition(coo, n_cores)
        spc = blocked.src_per_core
        fwd_flats = [sender_merge_flat(blocked, j) for j in range(n_cores)]
        merge_stats: Dict = {}
        vv_keys: Dict = {}
        if merge == "redundancy":
            mines = [edgeplan.mine_pair_redundancy(r, c, v, coo.n_dst, spc)
                     for (r, c, v) in fwd_flats]
            n_vv_pad = max(m.n_virtual for m in mines)
            if n_vv_pad:
                ext = spc + n_vv_pad
                fwd_flats = [(m.rows, m.cols, m.vals) for m in mines]
                vv_flats = [m.vv_flat() for m in mines]
                vvt_flats = [(c, r, v) for (r, c, v) in vv_flats]
                vv = _stack_sender_tables(vv_flats, n_vv_pad, spc, caps)
                vvt = _stack_sender_tables(vvt_flats, spc, n_vv_pad, caps)
                vv_keys = {"vv_cols": vv["cols"], "vv_vals": vv["vals"],
                           "vv_inv": vv["inv"], "vvt_cols": vvt["cols"],
                           "vvt_vals": vvt["vals"], "vvt_inv": vvt["inv"]}
                eb = sum(m.stats["edges_before"] for m in mines)
                ea = sum(m.stats["edges_after"] for m in mines)
                nv = sum(m.stats["n_virtual"] for m in mines)
                pu = sum(m.stats["pair_uses"] for m in mines)
                merge_stats = {
                    "edges_before": eb, "edges_after": ea, "n_virtual": nv,
                    "pair_uses": pu,
                    "pair_coverage": 2.0 * pu / max(eb, 1),
                    "flop_reduction": eb / max(ea + 2 * nv, 1),
                }
            else:
                ext = spc
        else:
            ext = spc
        bwd_flats = [(c, r, v) for (r, c, v) in fwd_flats]
        fwd = _stack_sender_tables(fwd_flats, coo.n_dst, ext, caps)
        bwd = _stack_sender_tables(bwd_flats, ext, coo.n_dst, caps)
        tables = dict(fwd)
        tables["t_cols"] = bwd["cols"]
        tables["t_vals"] = bwd["vals"]
        tables["t_inv"] = bwd["inv"]
        tables.update(vv_keys)
        return EllEdgeShards(tables=tables, n_dst=coo.n_dst,
                             n_src=coo.n_src, n_cores=n_cores,
                             merge_stats=merge_stats)

    return edgeplan.cached(
        edgeplan.coo_key(coo, "ell-shards", n_cores, caps_key, merge),
        (coo.rows, coo.cols, coo.vals), _build)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2, 3, 4))
def _hypercube_aggregate_ell(axis_name: str, ndim: int, n_dst: int,
                             n_chunks: int, topology: str, tables, x_local):
    from repro.kernels.ops import ell_apply

    n_cores = 1 << ndim
    dpc = n_dst // n_cores
    return _topo(topology).fold_pipelined(
        axis_name, n_cores, n_chunks,
        lambda xc: ell_apply(tables, xc).reshape(n_cores, dpc, -1),
        x_local)


def _ell_fwd(axis_name, ndim, n_dst, n_chunks, topology, tables, x_local):
    y = _hypercube_aggregate_ell(axis_name, ndim, n_dst, n_chunks, topology,
                                 tables, x_local)
    return y, tables        # aggregation is linear in x: plan-only residual


def _ell_bwd(axis_name, ndim, n_dst, n_chunks, topology, res, ct):
    from repro.kernels.ops import ell_apply

    tables = res
    # mirror schedule, same topology, same waves: all-gather the error rows
    e_full = _topo(topology).allgather_pipelined(ct, axis_name, 1 << ndim,
                                                 n_chunks)
    # then the column-major ELL walk of the SAME plan — scatter-free Aᵀ
    dx_local = ell_apply(tables, e_full.reshape(n_dst, -1), transpose=True)
    return (zero_ct(tables), dx_local)


_hypercube_aggregate_ell.defvjp(_ell_fwd, _ell_bwd)


def hypercube_aggregate_ell(axis_name: str, ndim: int, n_dst: int,
                            tables: Dict, x_local: jnp.ndarray,
                            n_chunks: Optional[int] = None,
                            topology: str = "hypercube") -> jnp.ndarray:
    """Per-device body: ``y_local = (A @ x)_local`` through the pre-reduced
    ELL engine + the double-buffered hypercube fold.

    ``tables`` is this device's :class:`EllEdgeShards` slice (leading core
    axis already stripped).  The local pre-reduction is the sender-side
    Block-Message merge MATERIALIZED: gather + degree-axis reduction, no
    segment-sum scatter — and the backward (registered here, inherited by
    the train step) all-gathers the error in mirror order and walks the
    same plan's column-major tables with the same scatter-free kernel.
    Matches :func:`hypercube_aggregate` to fp32 roundoff (≤1e-5; the merge
    reorders additions, so bit-exactness is not the contract — the blocked
    path keeps that role).
    """
    if n_chunks is None:
        n_chunks = default_n_chunks()
    return _hypercube_aggregate_ell(axis_name, ndim, n_dst, int(n_chunks),
                                    topology, tables, x_local)


def shard_edges_by_dst(coo: COO, n_cores: int,
                       e_max: Optional[int] = None) -> EdgeShards:
    """Receiver-side partition (UMA baseline): device *i* holds the edge
    blocks whose DESTINATIONS live on it (row stripe *i*), with local row
    slots and GLOBAL column ids — it must reach into remote memory for its
    neighbors' features.  Reuses :class:`EdgeShards` with the roles of
    ``rows``/``cols`` mirrored: ``rows_global`` ← local dst slot,
    ``cols_local`` ← global src id."""
    blocked = block_partition(coo, n_cores)
    spc = blocked.src_per_core
    per_core: list = [[] for _ in range(n_cores)]
    for (i, j), (lr, lc, v) in blocked.block_edges.items():
        per_core[i].append((lr, lc.astype(np.int64) + j * spc, v))
    if e_max is None:
        e_max = max((sum(len(t[0]) for t in lst) for lst in per_core),
                    default=1)
        e_max = max(int(e_max), 1)
    rows = np.zeros((n_cores, e_max), np.int32)
    cols = np.zeros((n_cores, e_max), np.int32)
    vals = np.zeros((n_cores, e_max), np.float32)
    for i, lst in enumerate(per_core):
        if not lst:
            continue
        r = np.concatenate([t[0] for t in lst])
        c = np.concatenate([t[1] for t in lst])
        v = np.concatenate([t[2] for t in lst])
        if len(r) > e_max:
            raise ValueError(f"core {i} has {len(r)} edges > e_max={e_max}")
        rows[i, :len(r)] = r
        cols[i, :len(c)] = c
        vals[i, :len(v)] = v
    return EdgeShards(rows_global=rows, cols_local=cols, vals=vals,
                      n_dst=coo.n_dst, n_src=coo.n_src, n_cores=n_cores)


def uma_aggregate(axis_name: str, ndim: int, n_dst: int,
                  rows_l: jnp.ndarray, cols_g: jnp.ndarray,
                  vals: jnp.ndarray, x_local: jnp.ndarray) -> jnp.ndarray:
    """UMA/SMP baseline (what the paper's Fig. 1 motivates AGAINST): every
    device all-gathers the RAW feature shard — bytes ∝ n_src·d with **no
    pre-reduction compression** — then aggregates its own rows from the
    replicated copy (the shared-memory random-access pattern).  Edge arrays
    come from :func:`shard_edges_by_dst`.  Kept for the collective-bytes
    comparison benchmark (roofline's collective term)."""
    n_cores = 1 << ndim
    x_full = hypercube_allgather(x_local, axis_name, ndim)   # [P, spc, d] raw
    x_full = x_full.reshape(-1, x_local.shape[-1])
    gathered = x_full[cols_g] * vals[:, None]
    return jax.ops.segment_sum(gathered, rows_l,
                               num_segments=n_dst // n_cores)


# ---------------------------------------------------------------------------
# Collective-byte accounting (feeds the roofline's collective term).
# ---------------------------------------------------------------------------
def schedule_bytes(n_dst: int, n_src: int, d: int, n_cores: int,
                   dtype_bytes: int = 4) -> dict:
    """Wire bytes per device, both schedules (analytic, matches the HLO).

    hypercube: the reduce-scatter fold sends n_dst/2 + n_dst/4 + … + n_dst/P
    pre-reduced rows = n_dst·(1 − 1/P) — independent of nnz (that is the
    Block-Message compression).  UMA: the raw all-gather ships
    n_src·(1 − 1/P) uncompressed rows and scales with neither schedule's
    reduction — on dense-ish graphs hypercube also wins because partial rows
    replace per-edge traffic."""
    hyper = int(n_dst * (1 - 1 / n_cores)) * d * dtype_bytes
    uma = int(n_src * (1 - 1 / n_cores)) * d * dtype_bytes
    return {"hypercube_bytes_per_device": hyper,
            "uma_bytes_per_device": uma,
            "ratio": uma / max(hyper, 1)}
