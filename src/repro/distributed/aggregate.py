"""Distributed graph aggregation — the paper's NUMA + hypercube NoC on TPU.

Placement (paper §4.1): node features are row-sharded over the ``model`` mesh
axis — device *i* is core *i* and owns its rows' HBM exclusively (NUMA, no
global addressing).  Each device also owns the edge blocks whose *sources*
live on it (column *i* of the block grid): senders know their outgoing
messages, exactly like the Block-Message buffers sit in the source core.

Aggregation then runs in two stages inside ``shard_map``:

  1. **Local pre-reduction** (the Index Compressor / Reduced Register File):
     each device segment-sums its own sources into partial rows for *every*
     destination core — a single SpMM against the local feature shard.  The
     wire will carry one partial row per (block, aggregate-slot), never raw
     neighbor rows: this is the paper's N ≤ nnz compression.

  2. **Hypercube fold** (:func:`hypercube_reduce_scatter`): ``log₂P`` rounds
     of pairwise ``ppermute`` along hypercube dimensions, high bit first.
     Round *b* sends the half of the partial buffer owned by the other
     half-cube and adds the received half — the dimension-ordered schedule of
     :mod:`repro.core.schedule`, which Algorithm 1 degenerates to when every
     wave is full (and which XLA can pipeline).  After the last round each
     device holds exactly its own rows, fully reduced.

The backward pass is the paper's Table-1 redesign, distributed: a
``custom_vjp`` runs the *mirror* schedule — all-gather the error rows
(:func:`hypercube_allgather`, the transpose of reduce-scatter) and walk the
SAME local edge table column-major (``Aᵀ`` without an ``Aᵀ``) — so no
transposed feature matrix and no second edge table exist on any device.

A UMA/SMP baseline (:func:`uma_aggregate`) does what the paper argues
against: all-gather raw features everywhere, aggregate redundantly, discard.
The roofline benchmark counts both schedules' collective bytes.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.graph.coo import COO
from repro.graph.partition import block_partition


# ---------------------------------------------------------------------------
# Collective building blocks (inside shard_map, axis = the "core" axis).
# ---------------------------------------------------------------------------
def _dim_perm(n_cores: int, bit: int) -> list:
    return [(i, i ^ (1 << bit)) for i in range(n_cores)]


def hypercube_reduce_scatter(partial: jnp.ndarray, axis_name: str,
                             ndim: int) -> jnp.ndarray:
    """Fold per-owner partials across the hypercube, high dimension first.

    ``partial``: [P, t, ...] — row-blocks ordered by owner core id.  Returns
    [t, ...]: this device's rows, fully reduced.  Because blocks are in
    ascending core order and we process the top bit first, 'my half' is
    always a contiguous slice — each round halves the buffer (the wire bytes
    form the geometric series t·(1 − 1/P), same as a reduce-scatter).
    """
    idx = jax.lax.axis_index(axis_name)
    n_cores = 1 << ndim
    buf = partial
    for b in reversed(range(ndim)):
        half = buf.shape[0] // 2
        low, high = buf[:half], buf[half:]
        my_bit = (idx >> b) & 1
        mine = jnp.where(my_bit == 0, low, high)
        send = jnp.where(my_bit == 0, high, low)
        recv = jax.lax.ppermute(send, axis_name, _dim_perm(n_cores, b))
        buf = mine + recv
    return buf[0]


def hypercube_allgather(x: jnp.ndarray, axis_name: str, ndim: int
                        ) -> jnp.ndarray:
    """Mirror schedule (transpose of the reduce-scatter): after ``ndim``
    doubling rounds every device holds [P, t, ...] in core order."""
    idx = jax.lax.axis_index(axis_name)
    n_cores = 1 << ndim
    buf = x[None]
    for b in range(ndim):
        other = jax.lax.ppermute(buf, axis_name, _dim_perm(n_cores, b))
        my_bit = (idx >> b) & 1
        lo = jnp.concatenate([buf, other], axis=0)
        hi = jnp.concatenate([other, buf], axis=0)
        buf = jnp.where(my_bit == 0, lo, hi)
    return buf


# ---------------------------------------------------------------------------
# Per-device edge shards (host-side build, done once per minibatch).
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class EdgeShards:
    """Sender-side edge blocks, stacked per source core and padded.

    rows_global: [P, e_max] int32 — destination id in GLOBAL row numbering
                 (owner core × tile + slot; Fig. 7's A·64+B).
    cols_local:  [P, e_max] int32 — source slot on the owning device (D).
    vals:        [P, e_max] f32   — Ã weights (0 = padding).
    """

    rows_global: np.ndarray
    cols_local: np.ndarray
    vals: np.ndarray
    n_dst: int
    n_src: int
    n_cores: int

    @property
    def dst_per_core(self) -> int:
        return self.n_dst // self.n_cores

    @property
    def src_per_core(self) -> int:
        return self.n_src // self.n_cores


def shard_edges(coo: COO, n_cores: int,
                e_max: Optional[int] = None) -> EdgeShards:
    """Partition a (padded) COO by SOURCE core — column stripes of the block
    grid — and pad each device's edge list to a common static length."""
    blocked = block_partition(coo, n_cores)
    spc = blocked.src_per_core
    dpc = blocked.dst_per_core
    per_core: list = [[] for _ in range(n_cores)]
    for (i, j), (lr, lc, v) in blocked.block_edges.items():
        per_core[j].append((lr.astype(np.int64) + i * dpc, lc, v))
    if e_max is None:
        e_max = max((sum(len(t[0]) for t in lst) for lst in per_core),
                    default=1)
        e_max = max(int(e_max), 1)
    rows = np.zeros((n_cores, e_max), np.int32)
    cols = np.zeros((n_cores, e_max), np.int32)
    vals = np.zeros((n_cores, e_max), np.float32)
    for j, lst in enumerate(per_core):
        if not lst:
            continue
        r = np.concatenate([t[0] for t in lst])
        c = np.concatenate([t[1] for t in lst])
        v = np.concatenate([t[2] for t in lst])
        if len(r) > e_max:
            raise ValueError(f"core {j} has {len(r)} edges > e_max={e_max}")
        rows[j, :len(r)] = r
        cols[j, :len(c)] = c
        vals[j, :len(v)] = v
    return EdgeShards(rows_global=rows, cols_local=cols, vals=vals,
                      n_dst=coo.n_dst, n_src=coo.n_src, n_cores=n_cores)


# ---------------------------------------------------------------------------
# The distributed aggregate, with the paper's backward dataflow (custom_vjp).
# Shapes inside shard_map (per device): x_local [spc, d] -> y_local [dpc, d].
# ---------------------------------------------------------------------------
def _local_partials(rows_g, cols_l, vals, x_local, n_dst):
    """Stage 1: partial rows for every destination core from local sources."""
    gathered = x_local[cols_l] * vals[:, None]
    return jax.ops.segment_sum(gathered, rows_g, num_segments=n_dst)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2))
def _hypercube_aggregate(axis_name: str, ndim: int, n_dst: int,
                         rows_g, cols_l, vals, x_local):
    n_cores = 1 << ndim
    partial = _local_partials(rows_g, cols_l, vals, x_local, n_dst)
    partial = partial.reshape(n_cores, n_dst // n_cores, -1)
    return hypercube_reduce_scatter(partial, axis_name, ndim)


def _hyper_fwd(axis_name, ndim, n_dst, rows_g, cols_l, vals, x_local):
    y = _hypercube_aggregate(axis_name, ndim, n_dst, rows_g, cols_l, vals,
                             x_local)
    return y, (rows_g, cols_l, vals, x_local)


def _hyper_bwd(axis_name, ndim, n_dst, res, ct):
    rows_g, cols_l, vals, x_local = res
    # mirror schedule: error rows of ALL cores (transpose of reduce-scatter)
    e_full = hypercube_allgather(ct, axis_name, ndim)        # [P, dpc, d]
    e_full = e_full.reshape(n_dst, -1)
    # Aᵀ walk of the SAME local edge table (column-major = Graph Converter):
    # dx[c] += v · e[r]  — consumes global rows, produces local cols.
    n_src_local = x_local.shape[0]
    gathered = e_full[rows_g] * vals[:, None]
    dx_local = jax.ops.segment_sum(gathered, cols_l,
                                   num_segments=n_src_local)
    dvals = jnp.zeros_like(vals)   # adjacency weights are not trained
    zr = np.zeros(rows_g.shape, dtype=jax.dtypes.float0)
    zc = np.zeros(cols_l.shape, dtype=jax.dtypes.float0)
    return (zr, zc, dvals, dx_local)


_hypercube_aggregate.defvjp(_hyper_fwd, _hyper_bwd)


def hypercube_aggregate(axis_name: str, ndim: int, n_dst: int,
                        rows_g: jnp.ndarray, cols_l: jnp.ndarray,
                        vals: jnp.ndarray, x_local: jnp.ndarray
                        ) -> jnp.ndarray:
    """Per-device body: ``y_local = (A @ x)_local`` via pre-reduce + fold.

    Call inside ``shard_map`` over ``axis_name``; edge arrays are this
    device's :class:`EdgeShards` slice, ``x_local`` its feature rows.
    """
    return _hypercube_aggregate(axis_name, ndim, n_dst, rows_g, cols_l,
                                vals, x_local)


def shard_edges_by_dst(coo: COO, n_cores: int,
                       e_max: Optional[int] = None) -> EdgeShards:
    """Receiver-side partition (UMA baseline): device *i* holds the edge
    blocks whose DESTINATIONS live on it (row stripe *i*), with local row
    slots and GLOBAL column ids — it must reach into remote memory for its
    neighbors' features.  Reuses :class:`EdgeShards` with the roles of
    ``rows``/``cols`` mirrored: ``rows_global`` ← local dst slot,
    ``cols_local`` ← global src id."""
    blocked = block_partition(coo, n_cores)
    spc = blocked.src_per_core
    per_core: list = [[] for _ in range(n_cores)]
    for (i, j), (lr, lc, v) in blocked.block_edges.items():
        per_core[i].append((lr, lc.astype(np.int64) + j * spc, v))
    if e_max is None:
        e_max = max((sum(len(t[0]) for t in lst) for lst in per_core),
                    default=1)
        e_max = max(int(e_max), 1)
    rows = np.zeros((n_cores, e_max), np.int32)
    cols = np.zeros((n_cores, e_max), np.int32)
    vals = np.zeros((n_cores, e_max), np.float32)
    for i, lst in enumerate(per_core):
        if not lst:
            continue
        r = np.concatenate([t[0] for t in lst])
        c = np.concatenate([t[1] for t in lst])
        v = np.concatenate([t[2] for t in lst])
        if len(r) > e_max:
            raise ValueError(f"core {i} has {len(r)} edges > e_max={e_max}")
        rows[i, :len(r)] = r
        cols[i, :len(c)] = c
        vals[i, :len(v)] = v
    return EdgeShards(rows_global=rows, cols_local=cols, vals=vals,
                      n_dst=coo.n_dst, n_src=coo.n_src, n_cores=n_cores)


def uma_aggregate(axis_name: str, ndim: int, n_dst: int,
                  rows_l: jnp.ndarray, cols_g: jnp.ndarray,
                  vals: jnp.ndarray, x_local: jnp.ndarray) -> jnp.ndarray:
    """UMA/SMP baseline (what the paper's Fig. 1 motivates AGAINST): every
    device all-gathers the RAW feature shard — bytes ∝ n_src·d with **no
    pre-reduction compression** — then aggregates its own rows from the
    replicated copy (the shared-memory random-access pattern).  Edge arrays
    come from :func:`shard_edges_by_dst`.  Kept for the collective-bytes
    comparison benchmark (roofline's collective term)."""
    n_cores = 1 << ndim
    x_full = hypercube_allgather(x_local, axis_name, ndim)   # [P, spc, d] raw
    x_full = x_full.reshape(-1, x_local.shape[-1])
    gathered = x_full[cols_g] * vals[:, None]
    return jax.ops.segment_sum(gathered, rows_l,
                               num_segments=n_dst // n_cores)


# ---------------------------------------------------------------------------
# Collective-byte accounting (feeds the roofline's collective term).
# ---------------------------------------------------------------------------
def schedule_bytes(n_dst: int, n_src: int, d: int, n_cores: int,
                   dtype_bytes: int = 4) -> dict:
    """Wire bytes per device, both schedules (analytic, matches the HLO).

    hypercube: the reduce-scatter fold sends n_dst/2 + n_dst/4 + … + n_dst/P
    pre-reduced rows = n_dst·(1 − 1/P) — independent of nnz (that is the
    Block-Message compression).  UMA: the raw all-gather ships
    n_src·(1 − 1/P) uncompressed rows and scales with neither schedule's
    reduction — on dense-ish graphs hypercube also wins because partial rows
    replace per-edge traffic."""
    hyper = int(n_dst * (1 - 1 / n_cores)) * d * dtype_bytes
    uma = int(n_src * (1 - 1 / n_cores)) * d * dtype_bytes
    return {"hypercube_bytes_per_device": hyper,
            "uma_bytes_per_device": uma,
            "ratio": uma / max(hyper, 1)}
