"""Reproduction of the HBM-FPGA message-passing GCN training architecture
on JAX/Pallas — see ROADMAP.md for the north star and PAPER.md for the
source paper."""

__version__ = "0.1.0"
