# Optimizers: the paper trains with SGD (Eq. 4); AdamW serves the LM archs.
from .optimizers import (AdamWState, OptState, SGDState, adamw, apply_updates,
                         clip_by_global_norm, cosine_schedule, sgd)

__all__ = ["AdamWState", "OptState", "SGDState", "adamw", "apply_updates",
           "clip_by_global_norm", "cosine_schedule", "sgd"]
