"""Optimizers — functional, pytree-based (no external deps).

The paper's training uses plain SGD (Eq. 4: W ← W − η∇L); the LM
architectures use AdamW with cosine decay + global-norm clipping.  States
are pytrees so they checkpoint/reshard exactly like params.

AdamW moments are kept in f32 even for bf16 params (mixed-precision
discipline: master math in f32, storage dtype preserved on the params).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

OptState = Any
Params = Any


class SGDState(NamedTuple):
    momentum: Any      # pytree like params (f32), or () if momentum == 0
    step: jnp.ndarray


class AdamWState(NamedTuple):
    mu: Any            # first moment (f32)
    nu: Any            # second moment (f32)
    step: jnp.ndarray


def _f32_like(params):
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)


# ---------------------------------------------------------------------------
def sgd(lr: float, momentum: float = 0.0):
    """Paper Eq. 4.  Returns (init_fn, update_fn)."""

    def init(params):
        mom = _f32_like(params) if momentum else ()
        return SGDState(momentum=mom, step=jnp.zeros((), jnp.int32))

    def update(grads, state: SGDState, params):
        if momentum:
            mom = jax.tree_util.tree_map(
                lambda m, g: momentum * m + g.astype(jnp.float32),
                state.momentum, grads)
            upd = jax.tree_util.tree_map(lambda m: -lr * m, mom)
        else:
            mom = ()
            upd = jax.tree_util.tree_map(
                lambda g: -lr * g.astype(jnp.float32), grads)
        return upd, SGDState(momentum=mom, step=state.step + 1)

    return init, update


def adamw(lr: Callable[[jnp.ndarray], jnp.ndarray] | float,
          b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.0):
    """AdamW; ``lr`` may be a schedule fn of the step."""

    def init(params):
        return AdamWState(mu=_f32_like(params), nu=_f32_like(params),
                          step=jnp.zeros((), jnp.int32))

    def update(grads, state: AdamWState, params):
        step = state.step + 1
        lr_t = lr(step) if callable(lr) else lr
        mu = jax.tree_util.tree_map(
            lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
            state.mu, grads)
        nu = jax.tree_util.tree_map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state.nu, grads)
        mu_hat = jax.tree_util.tree_map(
            lambda m: m / (1 - b1 ** step.astype(jnp.float32)), mu)
        nu_hat = jax.tree_util.tree_map(
            lambda v: v / (1 - b2 ** step.astype(jnp.float32)), nu)
        upd = jax.tree_util.tree_map(
            lambda m, v, p: -lr_t * (m / (jnp.sqrt(v) + eps)
                                     + weight_decay * p.astype(jnp.float32)),
            mu_hat, nu_hat, params)
        return upd, AdamWState(mu=mu, nu=nu, step=step)

    return init, update


def apply_updates(params, updates):
    return jax.tree_util.tree_map(
        lambda p, u: (p.astype(jnp.float32) + u).astype(p.dtype),
        params, updates)


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree_util.tree_leaves(grads)
    norm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale, grads), norm


def cosine_schedule(peak: float, warmup: int, total: int,
                    floor_frac: float = 0.1):
    def fn(step):
        step = step.astype(jnp.float32)
        warm = peak * step / max(warmup, 1)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = peak * (floor_frac + (1 - floor_frac)
                      * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(step < warmup, warm, cos)
    return fn
