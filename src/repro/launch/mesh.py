"""Production meshes.

Single pod: (16, 16) over ("data", "model") — 256 chips; the 16-way model
axis is the paper's 4-D hypercube (16 = 2⁴) for the GCN path and TP/EP for
the LM archs.  Multi-pod: (2, 16, 16) over ("pod", "data", "model") — the
"pod" axis is an outer data-parallel axis whose collectives cross the
inter-pod links (DCN in a real deployment).

A FUNCTION, not a module constant: importing this module must never touch
jax device state (the dry-run sets XLA_FLAGS before first jax init; smoke
tests and benches see the real 1-CPU backend).
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: Sequence[int], axes: Sequence[str]):
    """Arbitrary mesh (tests: small meshes on the 16 host devices)."""
    return jax.make_mesh(tuple(shape), tuple(axes))


def make_topology_mesh(n_cores: int, topology: str = "hypercube",
                       axis: str = "model"):
    """Core-axis mesh validated against a registered topology.

    The topology owns the core-count contract (``Topology.validate_cores``
    — every built-in wants a power of two), so a bad count dies here with
    the topology's own error instead of three layers down inside
    ``shard_map``.  The mesh itself stays one-dimensional: grid structure
    (e.g. the 2-D torus's R×C) lives in the topology's ``ppermute``
    schedules, not in the mesh shape, so every topology shares one mesh
    form and one ``PartitionSpec`` rule.
    """
    from repro.engine.registry import get_topology

    get_topology(topology).validate_cores(n_cores)
    if len(jax.devices()) < n_cores:
        raise RuntimeError(
            f"need {n_cores} devices for n_cores={n_cores}, have "
            f"{len(jax.devices())} — set XLA_FLAGS="
            "--xla_force_host_platform_device_count")
    return jax.make_mesh((n_cores,), (axis,))


# Hardware constants (TPU v5e-like target, per assignment):
PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link
