"""Post-SPMD HLO accounting — the dry-run's 'profiler'.

No real TPU exists here, so the compiled artifact IS the profile.  XLA's
``cost_analysis()`` counts while-loop bodies ONCE, which silently drops
~n_layers× of the compute in scan-over-layers models (verified in
tests/test_hlo_analysis.py), so this module does its own accounting over
``compiled.as_text()``:

  1. parse computations and the call graph (while body/condition with
     ``known_trip_count``, fusion ``calls=``, ``to_apply=``), and propagate a
     *execution multiplier* to every computation;
  2. FLOPs: every ``dot`` op = 2 · |out| · |contracted| (einsums, matmuls —
     elementwise is negligible at roofline granularity), × multiplier;
  3. HBM bytes: per op at fusion boundaries (operands + outputs, skipping
     bookkeeping ops) — the bytes a perfectly-fused executor moves, which is
     the right memory-roofline proxy;
  4. collective wire bytes: all-gather / all-reduce / reduce-scatter /
     all-to-all / collective-permute sizes × ring cost × multiplier:
        all-reduce        2·(g−1)/g · size
        all-gather          (g−1)/g · size     (size = full output)
        reduce-scatter      (g−1)/g · size·g   (size = per-shard output)
        all-to-all          (g−1)/g · size
        collective-permute            size
     with g = replica-group size parsed from the op line.

Roofline terms (seconds) then follow from the hardware constants in
launch/mesh.py.
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(
    r"(f64|f32|f16|bf16|f8e4m3|f8e5m2|s64|s32|s16|s8|u64|u32|u16|u8|pred|"
    r"c64|c128)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.*\{")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_RG_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_RG_LIST_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")

_SKIP_OPS = {"parameter", "get-tuple-element", "tuple", "constant", "copy",
             "bitcast", "after-all", "partition-id", "replica-id"}


def _shape_bytes(type_str: str) -> int:
    """Total bytes of all tensors mentioned in a type string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        elems = 1
        for d in dims.split(","):
            if d:
                elems *= int(d)
        total += elems * _DTYPE_BYTES.get(dt, 4)
    return total


def _first_shape(type_str: str) -> Tuple[str, List[int]]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return "f32", []
    dims = [int(d) for d in m.group(2).split(",") if d]
    return m.group(1), dims


@dataclasses.dataclass
class _Op:
    name: str
    kind: str
    result_types: str         # text before the op kind (shapes of results)
    line: str


@dataclasses.dataclass
class _Computation:
    name: str
    ops: List[_Op]
    symbols: Dict[str, str]   # %name -> result type text


_KIND_RE = re.compile(r"^\s*(?:\(?[a-z0-9_\[\],\s\{\}]*\)?\s*)?([a-z][\w\-]*)\(")


def _parse_op_kind(rhs: str) -> Tuple[str, str]:
    """rhs like 'f32[128,256]{1,0} dot(%a, %b), attrs...' or
    '(s32[], f32[8]{0}) while(%t), ...' → (op kind, result type text)."""
    s = rhs.strip()
    if s.startswith("("):                 # tuple-typed result
        depth = 0
        end = 0
        for i, ch in enumerate(s):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        result = s[:end + 1]
        rest = s[end + 1:].strip()
    else:
        sp = s.find(" ")
        if sp < 0:
            return "", s
        result = s[:sp]
        rest = s[sp + 1:].strip()
    m = re.match(r"([a-z][\w\-]*)\(", rest)
    kind = m.group(1) if m else ""
    return kind, result


def parse_computations(hlo_text: str) -> Dict[str, _Computation]:
    comps: Dict[str, _Computation] = {}
    cur: Optional[_Computation] = None
    entry_name = None
    for raw in hlo_text.splitlines():
        line = raw.rstrip()
        stripped = line.strip()
        if not stripped:
            continue
        m = _COMP_RE.match(stripped)
        if m and stripped.endswith("{"):
            cur = _Computation(name=m.group(1), ops=[], symbols={})
            comps[cur.name] = cur
            if stripped.startswith("ENTRY"):
                entry_name = cur.name
            continue
        if stripped == "}":
            cur = None
            continue
        if cur is None:
            continue
        dm = _DEF_RE.match(stripped)
        if not dm:
            continue
        name, rhs = dm.group(1), dm.group(2)
        kind, result_types = _parse_op_kind(rhs)
        cur.symbols[name] = result_types or rhs
        cur.ops.append(_Op(name=name, kind=kind, result_types=result_types,
                           line=stripped))
    if entry_name is not None:
        comps["__entry__"] = comps[entry_name]
    return comps


def _call_edges(comp: _Computation) -> List[Tuple[str, float]]:
    """(callee, multiplier) edges out of this computation."""
    edges = []
    for op in comp.ops:
        if op.kind == "while":
            m = re.search(r"condition=%?([\w.\-]+)", op.line)
            c = m.group(1) if m else None
            m = re.search(r"body=%?([\w.\-]+)", op.line)
            b = m.group(1) if m else None
            t = _TRIP_RE.search(op.line)
            trips = float(t.group(1)) if t else 1.0
            if b:
                edges.append((b, trips))
            if c:
                edges.append((c, trips + 1))
        elif "calls=" in op.line:
            for callee in re.findall(r"calls=%?([\w.\-]+)", op.line):
                edges.append((callee, 1.0))
        elif "to_apply=" in op.line and op.kind not in (
                "reduce", "all-reduce", "reduce-scatter", "scatter",
                "reduce-window", "sort", "select-and-scatter"):
            m = re.search(r"to_apply=%?([\w.\-]+)", op.line)
            if m:
                edges.append((m.group(1), 1.0))
        elif "branch_computations=" in op.line:
            for callee in re.findall(r"%([\w.\-]+)",
                                     op.line.split("branch_computations=")[1]):
                edges.append((callee, 1.0))
    return edges


def computation_multipliers(comps: Dict[str, _Computation]) -> Dict[str, float]:
    entry = comps.get("__entry__")
    mult: Dict[str, float] = {c: 0.0 for c in comps if c != "__entry__"}
    if entry is None:
        return mult
    mult[entry.name] = 1.0
    # propagate through the DAG (few passes suffice; guard with cap)
    for _ in range(64):
        changed = False
        for cname, comp in comps.items():
            if cname == "__entry__":
                continue
            m = mult.get(cname, 0.0)
            if m <= 0:
                continue
            for callee, k in _call_edges(comp):
                if callee not in mult:
                    continue
                new = 0.0
                # recompute callee multiplier from ALL callers
                for caller2, comp2 in comps.items():
                    if caller2 == "__entry__":
                        continue
                    for c2, k2 in _call_edges(comp2):
                        if c2 == callee:
                            new += mult.get(caller2, 0.0) * k2
                if abs(new - mult[callee]) > 1e-9:
                    mult[callee] = new
                    changed = True
        if not changed:
            break
    return mult


def _dot_flops(op: _Op, symbols: Dict[str, str]) -> float:
    _, out_dims = _first_shape(op.result_types)
    out_elems = 1
    for d in out_dims:
        out_elems *= d
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.line)
    cdims = [int(x) for x in m.group(1).split(",") if x] if m else []
    operands = _OPERAND_RE.findall(op.line.split("(", 1)[1].split(")", 1)[0])
    contract = 1
    if operands:
        lhs_type = symbols.get(operands[0], "")
        _, lhs_dims = _first_shape(lhs_type)
        for cd in cdims:
            if cd < len(lhs_dims):
                contract *= lhs_dims[cd]
    return 2.0 * out_elems * contract


def _group_size(line: str, world: int) -> int:
    m = _RG_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _RG_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return world


def _collective_wire(op: _Op, world: int) -> Tuple[str, float]:
    kind = op.kind.replace("-start", "")
    size = _shape_bytes(op.result_types)
    g = _group_size(op.line, world)
    if g <= 1:
        return kind, 0.0
    if kind == "all-reduce":
        return kind, 2.0 * (g - 1) / g * size
    if kind == "all-gather":
        return kind, (g - 1) / g * size
    if kind == "reduce-scatter":
        return kind, (g - 1) / g * size * g
    if kind == "all-to-all":
        return kind, (g - 1) / g * size
    if kind == "collective-permute":
        return kind, float(size)
    return kind, 0.0


_SLICE_KINDS = {"dynamic-slice", "slice", "gather", "dynamic-update-slice"}


def _op_bytes(op: _Op, symbols: Dict[str, str],
              slice_params: Optional[Dict[str, set]] = None) -> float:
    """HBM traffic of one op: output + operand bytes.

    Slice-like ops read only their window, not the whole operand — charging
    full operand bytes made a loop that block-slices a resident tensor look
    like it re-streams the tensor every iteration (observed 30× overcount
    on flash attention).  ``slice_params``: per-fusion-computation names of
    parameters consumed ONLY by slice ops inside — charged at output size.
    """
    if op.kind in _SKIP_OPS or not op.kind:
        return 0.0
    out = _shape_bytes(op.result_types)
    if op.kind in _SLICE_KINDS:
        return float(2 * out)           # read window + write result
    sliced: set = set()
    if slice_params is not None and "calls=" in op.line:
        m = re.search(r"calls=%?([\w.\-]+)", op.line)
        if m:
            sliced = slice_params.get(m.group(1), set())
    args = 0.0
    arg_str = op.line.split("(", 1)
    if len(arg_str) > 1:
        for idx, operand in enumerate(
                _OPERAND_RE.findall(arg_str[1].split(")", 1)[0])):
            full = _shape_bytes(symbols.get(operand, ""))
            if idx in sliced:
                args += min(full, out)   # windowed read
            else:
                args += full
    return float(out + args)


def _fusion_slice_params(comps: Dict[str, "_Computation"]) -> Dict[str, set]:
    """For each computation: indices of parameters whose ONLY uses inside
    are slice-like ops (the fusion reads a window of that operand)."""
    out: Dict[str, set] = {}
    for comp in comps.values():
        param_of = {}
        for op in comp.ops:
            if op.kind == "parameter":
                m = re.search(r"parameter\((\d+)\)", op.line)
                if m:
                    param_of[op.name] = int(m.group(1))
        if not param_of:
            continue
        uses: Dict[str, List[str]] = {n: [] for n in param_of}
        for op in comp.ops:
            if op.kind == "parameter":
                continue
            arg_str = op.line.split("(", 1)
            if len(arg_str) < 2:
                continue
            for operand in _OPERAND_RE.findall(arg_str[1].split(")", 1)[0]):
                if operand in uses:
                    uses[operand].append(op.kind)
        good = set()
        for name, kinds in uses.items():
            if kinds and all(k in _SLICE_KINDS for k in kinds):
                good.add(param_of[name])
        if good:
            out[comp.name] = good
    return out


@dataclasses.dataclass
class HLOStats:
    flops: float
    hbm_bytes: float
    collective_wire_bytes: float
    by_kind: Dict[str, float]
    by_kind_count: Dict[str, int]


def analyze_hlo(hlo_text: str, world: int = 1) -> HLOStats:
    comps = parse_computations(hlo_text)
    mult = computation_multipliers(comps)
    # fusion-called computations contribute their DOT flops at the caller's
    # multiplier, but their internal op bytes are inside the fusion boundary
    called_by_fusion = set()
    for comp in comps.values():
        if comps.get("__entry__") is comp:
            continue
        for op in comp.ops:
            if "calls=" in op.line:
                for callee in re.findall(r"calls=%?([\w.\-]+)", op.line):
                    called_by_fusion.add(callee)

    slice_params = _fusion_slice_params(comps)
    flops = 0.0
    hbm = 0.0
    wire: Dict[str, float] = {}
    counts: Dict[str, int] = {}
    seen = set()
    for cname, comp in comps.items():
        if cname == "__entry__" or comp.name in seen:
            continue
        seen.add(comp.name)
        m = mult.get(comp.name, 0.0)
        if m <= 0:
            continue
        fused = comp.name in called_by_fusion
        for op in comp.ops:
            if op.kind in ("dot", "convolution"):
                flops += m * _dot_flops(op, comp.symbols)
            base_kind = op.kind.replace("-start", "")
            if base_kind in _COLLECTIVES and "-done" not in op.kind:
                k, w = _collective_wire(op, world)
                wire[k] = wire.get(k, 0.0) + m * w
                counts[k] = counts.get(k, 0) + 1
            if not fused:
                hbm += m * _op_bytes(op, comp.symbols, slice_params)
    return HLOStats(flops=flops, hbm_bytes=hbm,
                    collective_wire_bytes=sum(wire.values()),
                    by_kind=wire, by_kind_count=counts)


def roofline_terms(flops: float, hbm_bytes: float, wire_bytes: float,
                   n_chips: int, *, peak_flops: float = 197e12,
                   hbm_bw: float = 819e9, ici_bw: float = 50e9
                   ) -> Dict[str, float]:
    """Three roofline terms in seconds (inputs are PER-DEVICE: the compiled
    SPMD module is one partition)."""
    t_compute = flops / peak_flops
    t_memory = hbm_bytes / hbm_bw
    t_coll = wire_bytes / ici_bw
    dominant = max((("compute", t_compute), ("memory", t_memory),
                    ("collective", t_coll)), key=lambda kv: kv[1])[0]
    return {"t_compute": t_compute, "t_memory": t_memory,
            "t_collective": t_coll, "dominant": dominant}


# Backwards-compatible helper used by benchmarks: collective bytes only.
@dataclasses.dataclass
class CollectiveStats:
    by_kind: Dict[str, float]
    by_kind_count: Dict[str, int]
    total_wire_bytes: float


def collective_bytes(hlo_text: str, world: int = 1) -> CollectiveStats:
    st = analyze_hlo(hlo_text, world)
    return CollectiveStats(by_kind=st.by_kind, by_kind_count=st.by_kind_count,
                           total_wire_bytes=st.collective_wire_bytes)
