"""End-to-end trainers — the GCN minibatch loop (the paper's workload) and a
causal-LM loop for the assigned archs — with the full fault-tolerance path:
checkpoint/restore, health monitoring, straggler rebalancing and elastic
resharding wired in.

CPU-runnable scales:
    PYTHONPATH=src python -m repro.launch.train gcn --dataset flickr \
        --scale 0.01 --steps 100
    PYTHONPATH=src python -m repro.launch.train lm --arch llama3.2-1b \
        --smoke --steps 20
"""
from __future__ import annotations

import argparse
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import Action, CheckpointManager, HealthMonitor
from repro.configs import get_config, get_smoke
from repro.configs.gcn_paper import FANOUTS, gcn_config
from repro.core.estimator import LayerShape
from repro.data import GraphBatchPipeline, TokenPipeline
from repro.graph import NeighborSampler, make_dataset
from repro.models import lm
from repro.models.gcn_model import (accuracy, gcn_forward, gcn_loss,
                                    init_gcn_params, pick_orders)
from repro.optim import adamw, apply_updates, clip_by_global_norm, sgd


# ---------------------------------------------------------------------------
# GCN minibatch training (paper §5.1 setup)
# ---------------------------------------------------------------------------
def train_gcn(dataset: str = "flickr", *, model: str = "gcn",
              dataflow: str = "ours", engine: Optional[str] = None,
              scale: float = 0.01,
              batch_size: int = 64, steps: int = 100, lr: float = 0.05,
              hidden: Optional[int] = None, feat_dim: Optional[int] = None,
              ckpt_dir: Optional[str] = None, resume: bool = False,
              seed: int = 0, log_every: int = 10) -> Dict[str, Any]:
    """``engine`` is an Engine spec string (``"coo+serial"``, ...) selecting
    the aggregation format/schedule for the 'ours' dataflow — validated
    against the registry up front so a typo dies before the first batch.
    This single-device trainer jits over the sampled COO layers, so only
    trace-capable formats work here; layout-building formats (block/ell)
    are rejected up front — they run through the distributed
    ``Engine.build(mesh)`` path instead."""
    if engine is not None:
        from repro.engine import EngineConfig, get_format
        cfg_spec = EngineConfig.from_spec(engine)  # validate, list options
        if not get_format(cfg_spec.format).traceable:
            raise ValueError(
                f"engine spec {engine!r}: format {cfg_spec.format!r} builds "
                "its layout host-side and cannot be jitted over sampled "
                "graphs in this single-device trainer — use the "
                "distributed path (repro.engine.Engine(spec).build(mesh)) "
                'or a traceable format such as "coo+serial"')
    ds = make_dataset(dataset, scale=scale, feat_dim=feat_dim)
    cfg = gcn_config(dataset, model, dataflow)
    if engine:
        cfg = type(cfg)(**{**cfg.__dict__, "engine": engine})
    if feat_dim:
        cfg = type(cfg)(**{**cfg.__dict__, "feat_dim": feat_dim})
    if hidden:
        cfg = type(cfg)(**{**cfg.__dict__, "hidden": hidden})
    sampler = NeighborSampler(ds.graph, fanouts=FANOUTS, pad_multiple=16,
                              seed=seed)
    pipe = GraphBatchPipeline(ds, sampler, batch_size, seed=seed)
    params = init_gcn_params(jax.random.PRNGKey(seed), cfg)
    init, update = sgd(lr, momentum=0.9)
    opt_state = init(params)
    start_step = 0
    mgr = CheckpointManager(ckpt_dir) if ckpt_dir else None
    if mgr and resume and mgr.latest_step() is not None:
        (params, opt_state), extra = mgr.restore(
            mgr.latest_step(), (params, opt_state))
        pipe.restore(extra["pipeline"])
        start_step = extra["step"]

    # sequence estimator: one order decision per run (paper §4.4)
    avg_deg = ds.graph.n_edges / ds.graph.n_nodes
    shapes = [LayerShape(b=batch_size, n=batch_size,
                         nbar=batch_size * (FANOUTS[0] + 1),
                         d=cfg.feat_dim, h=cfg.hidden, e=0, c=cfg.n_classes)]
    mb0, _, _ = next(GraphBatchPipeline(ds, sampler, batch_size, seed=seed))
    shapes = [LayerShape(b=batch_size, n=l.n_dst, nbar=l.n_src,
                         d=cfg.feat_dim if i == len(mb0.layers) - 1
                         else cfg.hidden,
                         h=cfg.n_classes if i == 0 else cfg.hidden,
                         e=l.nnz, c=cfg.n_classes)
              for i, l in enumerate(mb0.layers)]
    orders = pick_orders(cfg, shapes)

    @jax.jit
    def step_fn(params, opt_state, layers, x, labels):
        loss, grads = jax.value_and_grad(gcn_loss)(
            params, layers, x, labels, cfg, orders, n_valid=batch_size)
        upd, opt_state = update(grads, opt_state, params)
        return apply_updates(params, upd), opt_state, loss

    history = []
    t0 = time.time()
    for i in range(start_step, steps):
        mb, feats, labels = next(pipe)
        params, opt_state, loss = step_fn(
            params, opt_state, mb.layers, jnp.asarray(feats),
            jnp.asarray(labels))
        history.append(float(loss))
        if log_every and i % log_every == 0:
            print(f"step {i:5d}  loss {float(loss):.4f}  orders={orders}")
        if mgr and (i + 1) % 50 == 0:
            mgr.save_async(i + 1, (params, opt_state),
                           extra={"step": i + 1, "pipeline": pipe.state()})
    if mgr:
        mgr.wait()
    return {"params": params, "loss_history": history,
            "orders": orders, "wall_s": time.time() - t0}


# ---------------------------------------------------------------------------
# LM training (assigned archs; smoke-scale on CPU)
# ---------------------------------------------------------------------------
def train_lm(arch: str, *, smoke: bool = True, steps: int = 20,
             batch: int = 2, seq: int = 64, lr: float = 1e-3,
             ckpt_dir: Optional[str] = None, resume: bool = False,
             seed: int = 0, log_every: int = 5,
             fault_at: Optional[int] = None) -> Dict[str, Any]:
    """``fault_at``: inject a simulated worker failure at that step — the
    loop checkpoints, 'evicts' the worker (health monitor), and resumes from
    the checkpoint (single-process simulation of the recovery path)."""
    cfg = get_smoke(arch) if smoke else get_config(arch)
    enc_frames = seq if cfg.family == "encdec" else 0
    pipe = TokenPipeline(cfg, batch=batch, seq=seq, seed=seed,
                         enc_frames=enc_frames)
    params = lm.init_params(jax.random.PRNGKey(seed), cfg,
                            dtype=jnp.float32)
    optimizer = adamw(lr)
    opt_state = optimizer[0](params)
    step_fn = jax.jit(lm.train_step_fn(cfg, optimizer, chunk=16,
                                       remat=False))
    mgr = CheckpointManager(ckpt_dir) if ckpt_dir else None
    monitor = HealthMonitor(n_workers=4)
    start = 0
    if mgr and resume and mgr.latest_step() is not None:
        (params, opt_state), extra = mgr.restore(
            mgr.latest_step(), (params, opt_state))
        pipe.restore(extra["pipeline"])
        start = extra["step"]

    losses = []
    for i in range(start, steps):
        batch_np = next(pipe)
        if cfg.family == "encdec":
            batch_np["tokens"] = batch_np["tokens"][:, :seq // 4]
            batch_np["labels"] = batch_np["labels"][:, :seq // 4]
        batch_dev = {k: jnp.asarray(v) for k, v in batch_np.items()}
        t0 = time.time()
        params, opt_state, metrics = step_fn(params, opt_state, batch_dev)
        dt = time.time() - t0
        losses.append(float(metrics["loss"]))
        # heartbeat: this process plays worker 0; others nominal
        times = [dt, dt, dt, dt]
        if fault_at is not None and i >= fault_at:
            times[3] = None                       # worker 3 is dead for good
        actions = monitor.report_step(i, times)
        if Action.CHECKPOINT_NOW in actions.values() and mgr:
            mgr.save(i + 1, (params, opt_state),
                     extra={"step": i + 1, "pipeline": pipe.state()})
            print(f"step {i}: heartbeat miss → checkpointed")
        if log_every and i % log_every == 0:
            print(f"step {i:4d}  loss {losses[-1]:.4f}  ({dt*1e3:.0f} ms)")
        if mgr and (i + 1) % 10 == 0:
            mgr.save_async(i + 1, (params, opt_state),
                           extra={"step": i + 1, "pipeline": pipe.state()})
    if mgr:
        mgr.wait()
    return {"losses": losses, "survivors": monitor.survivors()}


def main() -> None:
    ap = argparse.ArgumentParser()
    sub = ap.add_subparsers(dest="cmd", required=True)
    g = sub.add_parser("gcn")
    g.add_argument("--dataset", default="flickr")
    g.add_argument("--model", default="gcn", choices=["gcn", "sage"])
    g.add_argument("--dataflow", default="ours", choices=["ours", "naive"])
    g.add_argument("--engine", default=None,
                   help="Engine spec, e.g. coo+serial (default) — see "
                        "repro.engine.supported_specs()")
    g.add_argument("--scale", type=float, default=0.01)
    g.add_argument("--batch-size", type=int, default=64)
    g.add_argument("--steps", type=int, default=100)
    g.add_argument("--lr", type=float, default=0.05)
    g.add_argument("--ckpt-dir", default=None)
    g.add_argument("--resume", action="store_true")
    l = sub.add_parser("lm")
    l.add_argument("--arch", required=True)
    l.add_argument("--smoke", action="store_true", default=True)
    l.add_argument("--steps", type=int, default=20)
    l.add_argument("--batch", type=int, default=2)
    l.add_argument("--seq", type=int, default=64)
    l.add_argument("--ckpt-dir", default=None)
    l.add_argument("--resume", action="store_true")
    l.add_argument("--fault-at", type=int, default=None)
    args = ap.parse_args()
    if args.cmd == "gcn":
        out = train_gcn(args.dataset, model=args.model,
                        dataflow=args.dataflow, engine=args.engine,
                        scale=args.scale,
                        batch_size=args.batch_size, steps=args.steps,
                        lr=args.lr, ckpt_dir=args.ckpt_dir,
                        resume=args.resume)
        print(f"final loss {out['loss_history'][-1]:.4f} "
              f"({out['wall_s']:.1f}s, orders={out['orders']})")
    else:
        out = train_lm(args.arch, smoke=args.smoke, steps=args.steps,
                       batch=args.batch, seq=args.seq,
                       ckpt_dir=args.ckpt_dir, resume=args.resume,
                       fault_at=args.fault_at)
        print(f"final loss {out['losses'][-1]:.4f} "
              f"survivors={out['survivors']}")


if __name__ == "__main__":
    main()
