"""End-to-end trainers — the GCN minibatch loop (the paper's workload) and a
causal-LM loop for the assigned archs — with the full fault-tolerance path:
checkpoint/restore, health monitoring, straggler rebalancing and elastic
resharding wired in.

The GCN path is a thin wrapper over :class:`repro.launch.trainer.Trainer`
(the engine-native loop: every registered format×schedule spec, async host
pipeline, per-epoch validation) — kept for its stable signature and for the
reference dataflows the Trainer does not model (``dataflow="naive"``,
``model="sage"``), which still run the legacy jitted ``gcn_loss`` loop.

CPU-runnable scales:
    PYTHONPATH=src python -m repro.launch.train gcn --dataset flickr \
        --scale 0.01 --steps 100
    PYTHONPATH=src python -m repro.launch.train gcn --engine ell+pipelined \
        --n-cores 1 --steps 50
    PYTHONPATH=src python -m repro.launch.train lm --arch llama3.2-1b \
        --smoke --steps 20
"""
from __future__ import annotations

import argparse
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import Action, CheckpointManager, HealthMonitor
from repro.configs import get_config, get_smoke
from repro.configs.gcn_paper import FANOUTS, HIDDEN, gcn_config
from repro.core.estimator import LayerShape
from repro.data import GraphBatchPipeline, TokenPipeline
from repro.graph import NeighborSampler, make_dataset
from repro.models import lm
from repro.models.gcn_model import (accuracy, gcn_forward, gcn_loss,
                                    init_gcn_params, pick_orders)
from repro.optim import adamw, apply_updates, clip_by_global_norm, sgd


# ---------------------------------------------------------------------------
# GCN minibatch training (paper §5.1 setup)
# ---------------------------------------------------------------------------
def train_gcn(dataset: str = "flickr", *, model: str = "gcn",
              dataflow: str = "ours", engine: Optional[str] = None,
              scale: float = 0.01,
              batch_size: int = 64, steps: int = 100, lr: float = 0.05,
              hidden: Optional[int] = None, feat_dim: Optional[int] = None,
              n_cores: int = 1, input_pipeline: str = "prefetch",
              ckpt_dir: Optional[str] = None, resume: bool = False,
              seed: int = 0, log_every: int = 10) -> Dict[str, Any]:
    """Compatible wrapper over the engine-native Trainer.

    ``engine`` is an Engine spec string (``"coo+serial"``, ... — default
    the serial COO oracle) selecting the aggregation format/schedule for
    the 'ours' dataflow; EVERY registered spec trains end-to-end now,
    including the layout-building ``block``/``ell`` formats (their
    per-batch layouts build on the input-pipeline host thread, outside any
    trace).  ``n_cores`` > 1 distributes over that many simulated/real
    devices.  The reference arms (``dataflow="naive"``, ``model="sage"``)
    keep the legacy single-device jitted loop.

    Returns the legacy dict: ``params``, ``loss_history`` (this
    invocation's steps), ``orders`` (the §4.4 sequence-estimator report),
    ``wall_s``.
    """
    if engine is not None:
        from repro.engine import EngineConfig
        EngineConfig.from_spec(engine)   # validate early, listing options
    if dataflow == "naive" or model == "sage":
        return _train_gcn_reference(
            dataset, model=model, dataflow=dataflow, engine=engine,
            scale=scale,
            batch_size=batch_size, steps=steps, lr=lr, hidden=hidden,
            feat_dim=feat_dim, ckpt_dir=ckpt_dir, resume=resume, seed=seed,
            log_every=log_every)

    from repro.launch.trainer import Trainer

    ds = make_dataset(dataset, scale=scale, feat_dim=feat_dim)
    cfg = gcn_config(dataset, model, dataflow)
    t0 = time.time()
    tr = Trainer(engine or "coo+serial", ds, n_cores=n_cores,
                 hidden=hidden or HIDDEN, batch_size=batch_size,
                 fanouts=FANOUTS, lr=lr, seed=seed,
                 input_pipeline=input_pipeline, ckpt_dir=ckpt_dir,
                 ckpt_every=50, log_every=log_every)
    orders = _estimator_orders(ds, tr.sampler, cfg, batch_size, seed,
                               feat_dim=ds.features.shape[1],
                               hidden=hidden or HIDDEN)
    if resume:
        tr.resume()
    try:
        history = tr.train_steps(max(steps - tr.global_step, 0))
    finally:
        tr.close()
    return {"params": tr.params, "loss_history": history,
            "orders": orders, "wall_s": time.time() - t0,
            "spec": tr.engine.spec, "requested_spec": tr.requested_spec}


def _estimator_orders(ds, sampler, cfg, batch_size: int, seed: int, *,
                      feat_dim: int, hidden: int):
    """Sequence estimator report (paper §4.4): one probe batch gives the
    per-layer shapes, the estimator picks CoAg/AgCo per layer.  The engine
    forward always runs CoAg; the report is kept for the legacy
    ``train_gcn`` contract (and the naive arm, which does obey it)."""
    mb0, _, _ = next(GraphBatchPipeline(ds, sampler, batch_size, seed=seed))
    shapes = [LayerShape(b=batch_size, n=l.n_dst, nbar=l.n_src,
                         d=feat_dim if i == len(mb0.layers) - 1 else hidden,
                         h=cfg.n_classes if i == 0 else hidden,
                         e=l.nnz, c=cfg.n_classes)
              for i, l in enumerate(mb0.layers)]
    return pick_orders(cfg, shapes)


def _train_gcn_reference(dataset: str, *, model: str, dataflow: str,
                         scale: float, batch_size: int, steps: int,
                         lr: float, hidden: Optional[int],
                         feat_dim: Optional[int], ckpt_dir: Optional[str],
                         resume: bool, seed: int, log_every: int,
                         engine: Optional[str] = None) -> Dict[str, Any]:
    """The legacy single-device loop — kept as the reference arm for the
    naive (Table-1 baseline) dataflow and the SAGE root-path model, which
    the engine train step does not implement.  Jits ``gcn_loss`` over the
    sampled COO layers with momentum SGD and the estimator's orders.
    ``engine`` selects the 'ours' layers' spec (sage model); this loop
    traces the sampled graphs, so layout-building formats are rejected up
    front, exactly like the pre-Trainer trainer did."""
    if engine is not None and dataflow == "ours":
        from repro.engine import EngineConfig, get_format
        cfg_spec = EngineConfig.from_spec(engine)
        if cfg_spec.is_auto:
            raise ValueError(
                "engine spec 'auto': the reference loop jits one fixed "
                "single-device layer stack, so there is nothing for the "
                "planner to choose — the engine-native Trainer path "
                "(model='gcn', dataflow='ours') resolves 'auto', or name "
                'a concrete traceable spec such as "coo+serial"')
        if not get_format(cfg_spec.format).traceable:
            raise ValueError(
                f"engine spec {engine!r}: format {cfg_spec.format!r} "
                "builds its layout host-side and cannot be jitted over "
                "sampled graphs in this reference loop — the engine-native "
                "Trainer path (model='gcn', dataflow='ours') supports it, "
                'or use a traceable format such as "coo+serial"')
    ds = make_dataset(dataset, scale=scale, feat_dim=feat_dim)
    cfg = gcn_config(dataset, model, dataflow)
    if engine:
        cfg = type(cfg)(**{**cfg.__dict__, "engine": engine})
    if feat_dim:
        cfg = type(cfg)(**{**cfg.__dict__, "feat_dim": feat_dim})
    if hidden:
        cfg = type(cfg)(**{**cfg.__dict__, "hidden": hidden})
    sampler = NeighborSampler(ds.graph, fanouts=FANOUTS, pad_multiple=16,
                              seed=seed)
    pipe = GraphBatchPipeline(ds, sampler, batch_size, seed=seed)
    params = init_gcn_params(jax.random.PRNGKey(seed), cfg)
    init, update = sgd(lr, momentum=0.9)
    opt_state = init(params)
    start_step = 0
    mgr = CheckpointManager(ckpt_dir) if ckpt_dir else None
    if mgr and resume and mgr.latest_step() is not None:
        (params, opt_state), extra = mgr.restore(
            mgr.latest_step(), (params, opt_state))
        pipe.restore(extra["pipeline"])
        start_step = extra["step"]

    # sequence estimator: one order decision per run (paper §4.4)
    orders = _estimator_orders(ds, sampler, cfg, batch_size, seed,
                               feat_dim=cfg.feat_dim, hidden=cfg.hidden)

    @jax.jit
    def step_fn(params, opt_state, layers, x, labels):
        loss, grads = jax.value_and_grad(gcn_loss)(
            params, layers, x, labels, cfg, orders, n_valid=batch_size)
        upd, opt_state = update(grads, opt_state, params)
        return apply_updates(params, upd), opt_state, loss

    history = []
    t0 = time.time()
    for i in range(start_step, steps):
        mb, feats, labels = next(pipe)
        params, opt_state, loss = step_fn(
            params, opt_state, mb.layers, jnp.asarray(feats),
            jnp.asarray(labels))
        history.append(float(loss))
        if log_every and i % log_every == 0:
            print(f"step {i:5d}  loss {float(loss):.4f}  orders={orders}")
        if mgr and (i + 1) % 50 == 0:
            mgr.save_async(i + 1, (params, opt_state),
                           extra={"step": i + 1, "pipeline": pipe.state()})
    if mgr:
        mgr.wait()
    return {"params": params, "loss_history": history,
            "orders": orders, "wall_s": time.time() - t0}


# ---------------------------------------------------------------------------
# LM training (assigned archs; smoke-scale on CPU)
# ---------------------------------------------------------------------------
def train_lm(arch: str, *, smoke: bool = True, steps: int = 20,
             batch: int = 2, seq: int = 64, lr: float = 1e-3,
             ckpt_dir: Optional[str] = None, resume: bool = False,
             seed: int = 0, log_every: int = 5,
             fault_at: Optional[int] = None) -> Dict[str, Any]:
    """``fault_at``: inject a simulated worker failure at that step — the
    loop checkpoints, 'evicts' the worker (health monitor), and resumes from
    the checkpoint (single-process simulation of the recovery path)."""
    cfg = get_smoke(arch) if smoke else get_config(arch)
    enc_frames = seq if cfg.family == "encdec" else 0
    pipe = TokenPipeline(cfg, batch=batch, seq=seq, seed=seed,
                         enc_frames=enc_frames)
    params = lm.init_params(jax.random.PRNGKey(seed), cfg,
                            dtype=jnp.float32)
    optimizer = adamw(lr)
    opt_state = optimizer[0](params)
    step_fn = jax.jit(lm.train_step_fn(cfg, optimizer, chunk=16,
                                       remat=False))
    mgr = CheckpointManager(ckpt_dir) if ckpt_dir else None
    monitor = HealthMonitor(n_workers=4)
    start = 0
    if mgr and resume and mgr.latest_step() is not None:
        (params, opt_state), extra = mgr.restore(
            mgr.latest_step(), (params, opt_state))
        pipe.restore(extra["pipeline"])
        start = extra["step"]

    losses = []
    for i in range(start, steps):
        batch_np = next(pipe)
        if cfg.family == "encdec":
            batch_np["tokens"] = batch_np["tokens"][:, :seq // 4]
            batch_np["labels"] = batch_np["labels"][:, :seq // 4]
        batch_dev = {k: jnp.asarray(v) for k, v in batch_np.items()}
        t0 = time.time()
        params, opt_state, metrics = step_fn(params, opt_state, batch_dev)
        dt = time.time() - t0
        losses.append(float(metrics["loss"]))
        # heartbeat: this process plays worker 0; others nominal
        times = [dt, dt, dt, dt]
        if fault_at is not None and i >= fault_at:
            times[3] = None                       # worker 3 is dead for good
        actions = monitor.report_step(i, times)
        if Action.CHECKPOINT_NOW in actions.values() and mgr:
            mgr.save(i + 1, (params, opt_state),
                     extra={"step": i + 1, "pipeline": pipe.state()})
            print(f"step {i}: heartbeat miss → checkpointed")
        if log_every and i % log_every == 0:
            print(f"step {i:4d}  loss {losses[-1]:.4f}  ({dt*1e3:.0f} ms)")
        if mgr and (i + 1) % 10 == 0:
            mgr.save_async(i + 1, (params, opt_state),
                           extra={"step": i + 1, "pipeline": pipe.state()})
    if mgr:
        mgr.wait()
    return {"losses": losses, "survivors": monitor.survivors()}


def main() -> None:
    ap = argparse.ArgumentParser()
    sub = ap.add_subparsers(dest="cmd", required=True)
    g = sub.add_parser("gcn")
    g.add_argument("--dataset", default="flickr")
    g.add_argument("--model", default="gcn", choices=["gcn", "sage"])
    g.add_argument("--dataflow", default="ours", choices=["ours", "naive"])
    g.add_argument("--engine", default=None,
                   help="Engine spec, e.g. coo+serial (default) or 'auto' "
                        "(profile-guided: planner picks the spec) — see "
                        "repro.engine.supported_specs(); every registered "
                        "spec trains end-to-end")
    g.add_argument("--n-cores", type=int, default=1,
                   help="hypercube size (needs that many jax devices)")
    g.add_argument("--input-pipeline", default="prefetch",
                   choices=["prefetch", "sync"])
    g.add_argument("--scale", type=float, default=0.01)
    g.add_argument("--batch-size", type=int, default=64)
    g.add_argument("--steps", type=int, default=100)
    g.add_argument("--lr", type=float, default=0.05)
    g.add_argument("--hidden", type=int, default=None)
    g.add_argument("--feat-dim", type=int, default=None)
    g.add_argument("--ckpt-dir", default=None)
    g.add_argument("--resume", action="store_true")
    l = sub.add_parser("lm")
    l.add_argument("--arch", required=True)
    l.add_argument("--smoke", action="store_true", default=True)
    l.add_argument("--steps", type=int, default=20)
    l.add_argument("--batch", type=int, default=2)
    l.add_argument("--seq", type=int, default=64)
    l.add_argument("--ckpt-dir", default=None)
    l.add_argument("--resume", action="store_true")
    l.add_argument("--fault-at", type=int, default=None)
    args = ap.parse_args()
    if args.cmd == "gcn":
        out = train_gcn(args.dataset, model=args.model,
                        dataflow=args.dataflow, engine=args.engine,
                        scale=args.scale, n_cores=args.n_cores,
                        input_pipeline=args.input_pipeline,
                        batch_size=args.batch_size, steps=args.steps,
                        lr=args.lr, hidden=args.hidden,
                        feat_dim=args.feat_dim, ckpt_dir=args.ckpt_dir,
                        resume=args.resume)
        print(f"final loss {out['loss_history'][-1]:.4f} "
              f"({out['wall_s']:.1f}s, orders={out['orders']})")
    else:
        out = train_lm(args.arch, smoke=args.smoke, steps=args.steps,
                       batch=args.batch, seq=args.seq,
                       ckpt_dir=args.ckpt_dir, resume=args.resume,
                       fault_at=args.fault_at)
        print(f"final loss {out['losses'][-1]:.4f} "
              f"survivors={out['survivors']}")


if __name__ == "__main__":
    main()
