"""Step builders + sharding assignment — shared by dryrun/train/serve.

``abstract_inputs(cfg, shape, mesh)`` returns ShapeDtypeStructs (WITH
NamedShardings attached) for every input of the cell's step function;
``build_step(cfg, kind)`` returns the jit-able callable.  The dry-run lowers
``jit(step).lower(*abstract)`` — no array is ever materialized for the full
configs.

Sharding assignment is rule-based (megatron TP pairing, EP on experts,
vocab-sharded embeddings, DP on batch) with a divisibility SANITIZER: any
named axis that does not evenly divide its dim is dropped to None — this is
what makes odd dims (llama4's 40 heads, seamless' 256206 vocab, mamba2's
50280 vocab, long_500k's batch=1) lower cleanly instead of erroring, at the
cost of extra collectives the roofline then exposes.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import lm
from repro.models.config import ArchConfig
from repro.configs import Shape
from repro.optim import adamw

MODEL = "model"

# leaf-name → trailing-dim spec (layer-stack leading dims are prepended)
_COL = (None, MODEL)       # output-dim sharded
_ROW = (MODEL, None)       # input-dim sharded (psum after)
_NAME_RULES: Dict[str, Tuple] = {
    "wq": _COL, "wk": _COL, "wv": _COL, "wo": _ROW,
    "w_gate": _COL, "w_up": _COL, "w_down": _ROW,
    "w_z": _COL, "w_x": _COL, "w_dt": _COL, "out_proj": _ROW,
    "w_B": (), "w_C": (), "router": (),
    "conv_wx": (None, MODEL), "conv_bx": (MODEL,),
    "conv_wB": (), "conv_bB": (), "conv_wC": (), "conv_bC": (),
    "dt_bias": (MODEL,), "A_log": (MODEL,), "D": (MODEL,),
    "norm_g": (MODEL,),
    "embed": (MODEL, None),             # vocab-sharded
    "lm_head": (None, MODEL),
}
# MoE expert stacks [e, d, f]: EP over experts + FSDP over the data axes on
# d — without the data shard a 400B-expert arch (llama4) cannot fit HBM;
# GSPMD all-gathers the shard per layer use (the standard FSDP trade).
_DP = "__dp__"                         # placeholder → dp_axes(mesh)
_EXPERT_RULES: Dict[str, Tuple] = {
    "w_gate": (MODEL, _DP, None), "w_up": (MODEL, _DP, None),
    "w_down": (MODEL, _DP, None),
}
_STACK_KEYS = {"layers", "moe_layers", "dense_layers", "enc_layers",
               "dec_layers", "mamba_layers"}


def _path_names(path) -> Tuple[str, ...]:
    out = []
    for p in path:
        if isinstance(p, jax.tree_util.DictKey):
            out.append(str(p.key))
        elif isinstance(p, jax.tree_util.GetAttrKey):
            out.append(str(p.name))
    return tuple(out)


def sanitize(spec: Tuple, shape: Tuple[int, ...], mesh: Mesh) -> P:
    """Drop axis names that don't divide their dim (or don't exist)."""
    fixed = []
    for dim, ax in zip(shape, tuple(spec) + (None,) * len(shape)):
        if ax is None:
            fixed.append(None)
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        size = 1
        ok = True
        for a in axes:
            if a not in mesh.shape:
                ok = False
                break
            size *= mesh.shape[a]
        fixed.append(ax if ok and dim % size == 0 else None)
    return P(*fixed)


def param_spec(path, leaf_shape: Tuple[int, ...], mesh: Mesh) -> P:
    names = _path_names(path)
    if not names:
        return P()
    name = names[-1]
    if name.startswith("x_"):          # cross-attention clones
        name = name[2:]
    stacked = any(k in _STACK_KEYS for k in names[:-1])
    base_ndim = len(leaf_shape) - (1 if stacked else 0)
    rules = _NAME_RULES
    if name in _EXPERT_RULES and base_ndim == 3:
        rules = _EXPERT_RULES
    trailing = [dp_axes(mesh) if ax == _DP else ax
                for ax in rules.get(name, ())]
    spec = ((None,) if stacked else ()) + tuple(trailing)
    return sanitize(spec, leaf_shape, mesh)


def zero1_spec(pspec: P, leaf_shape: Tuple[int, ...], mesh: Mesh) -> P:
    """ZeRO-1: shard optimizer moments over the data axes too — inject the
    dp axes on the first still-unsharded divisible dim.  The f32 moments are
    the memory bulk at scale; GSPMD turns the grad reduction into
    reduce-scatter + the param update into all-gather automatically."""
    dp = dp_axes(mesh)
    if not dp:
        return pspec
    used = set()
    for ax in pspec:
        for a in (ax if isinstance(ax, tuple) else (ax,)):
            if a:
                used.add(a)
    if any(a in used for a in dp):
        return pspec
    size = 1
    for a in dp:
        size *= mesh.shape[a]
    new = list(pspec) + [None] * (len(leaf_shape) - len(pspec))
    for i, (ax, dim) in enumerate(zip(new, leaf_shape)):
        if ax is None and dim % size == 0 and dim >= size:
            new[i] = dp
            break
    return P(*new)


def tree_shardings(tree_sds: Any, mesh: Mesh, *, zero1: bool = False) -> Any:
    """Shardings for a pytree of ShapeDtypeStructs via the param rules."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree_sds)
    out = []
    for path, leaf in flat:
        spec = param_spec(path, leaf.shape, mesh)
        if zero1:
            spec = zero1_spec(spec, leaf.shape, mesh)
        out.append(NamedSharding(mesh, spec))
    return jax.tree_util.tree_unflatten(treedef, out)


def _with_sharding(tree_sds: Any, shardings: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        tree_sds, shardings)


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


# ---------------------------------------------------------------------------
# abstract inputs per cell
# ---------------------------------------------------------------------------
def _batch_specs(cfg: ArchConfig, shape: Shape, mesh: Mesh) -> Dict[str, Any]:
    dp = dp_axes(mesh)
    gb, s = shape.global_batch, shape.seq_len
    if cfg.family == "encdec":
        # enc side carries seq_len frames; dec side trains on seq_len//4 text
        dec = max(s // 4, 64)
        specs = {
            "frames": jax.ShapeDtypeStruct(
                (gb, s, cfg.d_model), jnp.float32,
                sharding=NamedSharding(mesh, sanitize((dp, None, None),
                                                      (gb, s, cfg.d_model),
                                                      mesh))),
            "tokens": jax.ShapeDtypeStruct(
                (gb, dec), jnp.int32,
                sharding=NamedSharding(mesh, sanitize((dp, None), (gb, dec),
                                                      mesh))),
            "labels": jax.ShapeDtypeStruct(
                (gb, dec), jnp.int32,
                sharding=NamedSharding(mesh, sanitize((dp, None), (gb, dec),
                                                      mesh))),
        }
        return specs
    tok = jax.ShapeDtypeStruct(
        (gb, s), jnp.int32,
        sharding=NamedSharding(mesh, sanitize((dp, None), (gb, s), mesh)))
    return {"tokens": tok, "labels": tok}


def _abstract_params(cfg: ArchConfig, mesh: Mesh) -> Tuple[Any, Any]:
    sds = jax.eval_shape(
        functools.partial(lm.init_params, cfg=cfg), jax.random.PRNGKey(0))
    sh = tree_shardings(sds, mesh)
    return _with_sharding(sds, sh), sh


def _abstract_opt(cfg: ArchConfig, params_sds: Any, mesh: Mesh
                  ) -> Tuple[Any, Any]:
    init, _ = adamw(1e-4)
    sds = jax.eval_shape(init, params_sds)
    sh = tree_shardings(sds, mesh, zero1=True)
    return _with_sharding(sds, sh), sh


def _cache_spec_fn(cfg: ArchConfig, shape: Shape, mesh: Mesh):
    """Spec rules for cache leaves (KV / SSM states), by position."""
    dp = dp_axes(mesh)
    gb = shape.global_batch

    def assign(path, leaf):
        names = _path_names(path)
        nm = names[-1] if names else ""
        shp = leaf.shape
        if nm in ("k", "v"):            # [L, b, S, kv, hd]
            spec = (None, dp, None, MODEL, None)
            s = sanitize(spec, shp, mesh)
            if s[1] is None and len(shp) == 5:
                # batch unshardable (long_500k b=1): context-shard S instead
                s = sanitize((None, None, "data", MODEL, None), shp, mesh)
            return s
        if nm in ("cross_k", "cross_v"):
            return sanitize((None, dp, None, MODEL, None), shp, mesh)
        if nm == "ssm":                 # [L, b, nh, n, p]
            return sanitize((None, dp, MODEL, None, None), shp, mesh)
        if nm in ("conv_x",):           # [L, b, k-1, di]
            return sanitize((None, dp, None, MODEL), shp, mesh)
        if nm in ("conv_B", "conv_C"):
            return sanitize((None, dp, None, None), shp, mesh)
        return sanitize((None, dp), shp, mesh)

    return assign


def _abstract_cache(cfg: ArchConfig, shape: Shape, mesh: Mesh,
                    params_sds: Any) -> Tuple[Any, Any]:
    gb, s = shape.global_batch, shape.seq_len
    if cfg.family == "encdec":
        fn = functools.partial(lm.init_cache, cfg, gb, s,
                               enc_frames=min(s, 4096))
        sds = jax.eval_shape(fn, params=params_sds)
    else:
        sds = jax.eval_shape(
            functools.partial(lm.init_cache, cfg, gb, s))
    assign = _cache_spec_fn(cfg, shape, mesh)
    flat, treedef = jax.tree_util.tree_flatten_with_path(sds)
    sh = [NamedSharding(mesh, assign(path, leaf)) for path, leaf in flat]
    sh_tree = jax.tree_util.tree_unflatten(treedef, sh)
    return _with_sharding(sds, sh_tree), sh_tree


def abstract_inputs(cfg: ArchConfig, shape: Shape, mesh: Mesh, *,
                    chunk: int = 128) -> Tuple[Tuple, Dict[str, Any]]:
    """Returns (args, info) where args are fully-sharded ShapeDtypeStructs
    for the cell's step function."""
    params_sds, params_sh = _abstract_params(cfg, mesh)
    if shape.kind == "train":
        opt_sds, opt_sh = _abstract_opt(cfg, params_sds, mesh)
        batch = _batch_specs(cfg, shape, mesh)
        return (params_sds, opt_sds, batch), {
            "out_shardings": (params_sh, opt_sh, None)}
    if shape.kind == "prefill":
        batch = _batch_specs(cfg, shape, mesh)
        batch.pop("labels")
        return (params_sds, batch), {"out_shardings": None}
    if shape.kind == "decode":
        cache_sds, cache_sh = _abstract_cache(cfg, shape, mesh, params_sds)
        dp = dp_axes(mesh)
        gb = shape.global_batch
        token = jax.ShapeDtypeStruct(
            (gb, 1), jnp.int32,
            sharding=NamedSharding(mesh, sanitize((dp, None), (gb, 1), mesh)))
        pos = jax.ShapeDtypeStruct((), jnp.int32,
                                   sharding=NamedSharding(mesh, P()))
        return (params_sds, cache_sds, token, pos), {
            "out_shardings": (None, cache_sh)}
    raise ValueError(shape.kind)


# ---------------------------------------------------------------------------
# step functions
# ---------------------------------------------------------------------------
def sp_spec_for(cfg: ArchConfig, shape: Shape, mesh: Mesh) -> Optional[P]:
    """Sequence-parallel residual spec [b, s, d]: batch over DP, seq over
    model — dropped per-dim when not divisible."""
    dp = dp_axes(mesh)
    gb, s = shape.global_batch, shape.seq_len
    spec = sanitize((dp, MODEL, None), (gb, s, cfg.d_model), mesh)
    return spec


def ep_spec_for(cfg: ArchConfig, shape: Shape, mesh: Mesh) -> Optional[P]:
    """Expert-parallel pin for the [b, e, cap, ·] MoE intermediates."""
    if cfg.family != "moe":
        return None
    dp = dp_axes(mesh)
    return sanitize((dp, MODEL, None, None),
                    (shape.global_batch, cfg.moe_experts, 8, 8), mesh)


def build_step(cfg: ArchConfig, kind: str, *, chunk: int = 128,
               lr: float = 3e-4, remat: bool = True,
               sp_spec: Optional[P] = None,
               ep_spec: Optional[P] = None) -> Callable:
    if kind == "train":
        opt = adamw(lr)
        return lm.train_step_fn(cfg, opt, chunk=chunk, remat=remat,
                                sp_spec=sp_spec, ep_spec=ep_spec)
    if kind == "prefill":
        return lm.prefill_fn(cfg, chunk=chunk, sp_spec=sp_spec,
                             ep_spec=ep_spec)
    if kind == "decode":
        return lm.decode_fn(cfg)
    raise ValueError(kind)
