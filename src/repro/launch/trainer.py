"""Engine-native distributed Trainer — end-to-end training on EVERY
registered format×schedule spec, with host work off the critical path.

The paper's architecture wins by keeping the accelerator fed: NUMA-aware
host-side staging overlaps message-passing compute (§4.2–4.3).  This is
that split in software.  One :class:`Trainer` owns the whole loop:

  * **Engine-native** — the step is ``EngineBundle.train_step`` (shard_map
    over the hypercube axis, Weight-Bank ``pmean`` sync), so any registered
    spec trains unchanged: ``coo+serial``, ``block+pipelined``,
    ``ell+pipelined``, or a format you registered yesterday.
  * **Async input pipeline** — sampling, the per-batch layout build
    (``bundle.prepare_batch`` — the host-side hook that makes
    ``traceable=False`` formats trainable on sampled graphs) and device
    placement (``commit_batch``) run on a :class:`~repro.data.Prefetcher`
    thread with depth-2 double buffering; the step loop's only input cost
    is a queue pop.  ``input_pipeline="sync"`` runs the same work inline
    for A/B measurement (``benchmarks/epoch_time.py --input-pipeline``).
  * **Epoch metrics** — per-epoch validation accuracy on a held-out seed
    set, wall-clock, steps/s and host-stall time per step.
  * **Checkpoint/resume** — params + progress counters + pipeline state
    via :class:`~repro.checkpoint.CheckpointManager`; the prefetcher drains
    and rewinds to the last consumed batch, so a mid-epoch restore replays
    the in-flight batches bit-exactly (the ``(seed, epoch, batch_idx)``
    contract).

CPU smoke (4 simulated cores)::

    XLA_FLAGS=--xla_force_host_platform_device_count=4 PYTHONPATH=src \\
        python -m repro.launch.trainer --spec ell+pipelined --n-cores 4 \\
        --steps 30 --ckpt-restart
"""
from __future__ import annotations

import argparse
import time
from typing import Any, Dict, List, Optional, Sequence, Union

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs.gcn_paper import FANOUTS
from repro.data import (GraphBatchPipeline, Prefetcher, StagedPrefetcher,
                        gather_features)
from repro.distributed.gcn_train import init_params
from repro.engine import Engine, EngineConfig
from repro.featurestore import FeatureStore, HotVertexCache, get_store
from repro.graph import GraphDataset, NeighborSampler, make_dataset


class Trainer:
    """One engine spec + one dataset → an epoch loop that trains it.

    Parameters
    ----------
    engine: spec string (``"ell+pipelined"``, or with an explicit
        interconnect ``"ell+pipelined+ring"``), :class:`EngineConfig`, or
        :class:`Engine` — every registered format×schedule×topology works.
    dataset: a :class:`GraphDataset` or a dataset name for
        :func:`make_dataset` (with ``scale``/``feat_dim``).
    n_cores: hypercube size; needs ``len(jax.devices()) >= n_cores``
        (``XLA_FLAGS=--xla_force_host_platform_device_count=P`` on CPU).
        ``mesh`` overrides with a prebuilt mesh.
    input_pipeline: ``"prefetch"`` (background thread, depth
        ``prefetch_depth``) or ``"sync"`` (host work inline on the step
        path — the A/B baseline).
    feature_store: where node features live.  ``None``/``"device"`` keeps
        the in-memory path (unless the dataset itself is store-backed); a
        registered backend name (``"host"``, ``"mmap"``, …) wraps the
        dataset's dense features into that out-of-core store; a
        :class:`~repro.featurestore.FeatureStore` instance is used as-is.
        With a store, only each batch's frontier rows stream to the
        device, and ``input_pipeline="prefetch"`` becomes the STAGED
        chain sample → gather → layout → place (each stage on its own
        thread), so the store's gather latency for batch *i+2* hides
        under batch *i+1*'s layout build and batch *i*'s device step.
    cache_capacity: rows in the degree-keyed hot-vertex cache in front of
        the store (0 disables); ``cache_pinned`` of them pin the
        top-degree vertices (default: half), the rest are LRU.
    device_budget_bytes: simulated per-device feature-memory budget — a
        DENSE feature matrix over this size refuses to train (pass a
        ``feature_store`` instead); store-backed features are exempt, as
        only frontier rows ever occupy device memory.
    pad_multiple: sampler node-count padding.  Coarser padding collapses
        the per-batch ``dims`` signatures so the jitted step re-traces
        rarely; must be a multiple of ``n_cores`` (defaults to
        ``max(16, n_cores)``).
    ckpt_every: save (async) every N global steps when ``ckpt_dir`` is set.
    """

    def __init__(self, engine: Union[str, EngineConfig, Engine],
                 dataset: Union[str, GraphDataset] = "flickr", *,
                 n_cores: int = 1, mesh=None, scale: float = 0.01,
                 feat_dim: Optional[int] = None, hidden: int = 64,
                 batch_size: int = 64, fanouts: Sequence[int] = FANOUTS,
                 lr: Optional[float] = None, seed: int = 0,
                 input_pipeline: str = "prefetch", prefetch_depth: int = 2,
                 pad_multiple: Optional[int] = None,
                 val_batches: int = 2,
                 feature_store: Union[None, str, FeatureStore] = None,
                 cache_capacity: int = 0,
                 cache_pinned: Optional[int] = None,
                 device_budget_bytes: Optional[int] = None,
                 ckpt_dir: Optional[str] = None, ckpt_every: int = 50,
                 log_every: int = 0):
        if input_pipeline not in ("prefetch", "sync"):
            raise ValueError(f"unknown input_pipeline {input_pipeline!r}; "
                             "expected 'prefetch' or 'sync'")
        if isinstance(engine, Engine):
            if lr is not None and lr != engine.config.lr:
                raise ValueError(
                    f"lr={lr} conflicts with the prebuilt Engine's "
                    f"config.lr={engine.config.lr} (the step bakes the "
                    "engine's lr in) — pass a spec/EngineConfig, or set "
                    "the lr on the EngineConfig you build the Engine from")
        else:
            if isinstance(engine, str):
                engine = EngineConfig.from_spec(
                    engine, **({} if lr is None else {"lr": lr}))
            elif lr is not None:
                engine = EngineConfig(**{**engine.__dict__, "lr": lr})
            engine = Engine(engine)
        self.requested_spec = engine.spec
        if engine.is_auto:
            # resolve BEFORE any mesh exists: the topology-aware mesh needs
            # a concrete interconnect, and a run plans exactly once —
            # resume pins this resolved spec, never re-plans mid-run
            engine = engine.resolve(
                int(mesh.shape[engine.config.axis]) if mesh is not None
                else n_cores)
        self.engine = engine
        if isinstance(dataset, str):
            dataset = make_dataset(dataset, scale=scale, feat_dim=feat_dim)
        self.dataset = dataset
        # -- feature residency: dense on-device vs out-of-core store ---------
        self._owned_store = False
        feats = dataset.features
        store: Optional[FeatureStore] = None
        if isinstance(feature_store, FeatureStore):
            store = feature_store
        elif isinstance(feats, FeatureStore):
            # the dataset was generated out-of-core — train from its store
            # regardless of the flag (densifying it would defeat the point)
            store = feats
        elif feature_store not in (None, "device"):
            # wrap the dense matrix into the named backend through the
            # chunked writer (mmap streams it to disk chunk by chunk)
            store = get_store(feature_store).from_array(np.asarray(feats))
            self._owned_store = True
        if device_budget_bytes is not None and store is None \
                and feats.nbytes > device_budget_bytes:
            raise ValueError(
                f"dense features are {feats.nbytes} bytes — over the "
                f"device_budget_bytes={device_budget_bytes} budget; pass "
                "feature_store='host' or 'mmap' so only each batch's "
                "frontier rows ever occupy device memory")
        self.store = store
        self.cache: Optional[HotVertexCache] = None
        if store is not None and cache_capacity > 0:
            indptr = dataset.graph.indptr
            self.cache = HotVertexCache(store, indptr[1:] - indptr[:-1],
                                        cache_capacity, pinned=cache_pinned)
        self._gather_src = self.cache if self.cache is not None else store
        self.feature_mode = "device" if store is None \
            else getattr(store, "name", "custom")
        if mesh is None:
            # topology-aware construction: the engine's interconnect
            # validates the core count before any device state is touched
            from repro.launch.mesh import make_topology_mesh
            mesh = make_topology_mesh(n_cores, engine.config.topology,
                                      engine.config.axis)
        self.mesh = mesh
        self.n_cores = int(mesh.shape[engine.config.axis])
        self.bundle = engine.build(mesh)
        self.batch_size = batch_size
        self.seed = seed
        self.input_pipeline = input_pipeline
        self.log_every = log_every
        pad = pad_multiple if pad_multiple is not None \
            else max(16, self.n_cores)
        if pad % self.n_cores:
            raise ValueError(f"pad_multiple={pad} must be a multiple of "
                             f"n_cores={self.n_cores} so every hop splits "
                             "evenly across the hypercube")
        if dataset.graph.n_nodes < batch_size:
            raise ValueError(
                f"batch_size={batch_size} exceeds the dataset's "
                f"{dataset.graph.n_nodes} nodes — an epoch would hold zero "
                "full batches and fit() would train nothing; shrink the "
                "batch or raise the dataset scale")
        self.sampler = NeighborSampler(dataset.graph, fanouts=fanouts,
                                       pad_multiple=pad, seed=seed)
        self.pipeline = GraphBatchPipeline(dataset, self.sampler,
                                           batch_size, seed=seed,
                                           defer_gather=store is not None)
        self._nnz_pad = self.sampler.static_nnz(batch_size)
        if input_pipeline != "prefetch":
            self.fetcher = None
        elif store is not None:
            # staged chain: batch i+2's store gather hides under batch
            # i+1's layout build, which hides under batch i's device step
            self.fetcher = StagedPrefetcher(
                self.pipeline,
                [("gather", self._gather_stage),
                 ("layout", self.bundle.prepare_batch),
                 ("place", self.bundle.commit_batch)],
                depth=prefetch_depth)
        else:
            self.fetcher = Prefetcher(self.pipeline, prepare=self._prepare,
                                      depth=prefetch_depth)
        # model: one GCN layer per sampled hop, hidden width between
        feat = dataset.features.shape[1]
        dims = [feat] + [hidden] * (len(fanouts) - 1) \
            + [dataset.stats.n_classes]
        self.params = init_params(jax.random.PRNGKey(seed),
                                  list(zip(dims[:-1], dims[1:])))
        self.mgr = CheckpointManager(ckpt_dir) if ckpt_dir else None
        self.ckpt_every = ckpt_every
        self.global_step = 0
        self.epochs_done = 0
        # held-out validation seeds: derived from the seed, never from the
        # training stream — identical across resume boundaries
        val_rng = np.random.default_rng(
            np.random.SeedSequence([seed, 9001]))
        self._val_seed_sets = [
            val_rng.permutation(dataset.graph.n_nodes)[:batch_size]
            for _ in range(val_batches)]
        self._val_batches: Optional[List[Any]] = None
        self.history: List[float] = []
        self._sync_stall_s = 0.0
        self._sync_steps = 0

    # -- input pipeline ------------------------------------------------------
    def _prepare(self, mb, feats, labels) -> Dict[str, Any]:
        """sample → host layout build → device placement (producer side)."""
        return self.bundle.commit_batch(
            self.bundle.prepare_batch(mb, feats, labels))

    def _gather_stage(self, mb, labels):
        """The store stage of the staged chain: frontier rows out of the
        feature store, through the hot-vertex cache when one is enabled."""
        feats = gather_features(self._gather_src, mb.input_nodes,
                                self.dataset.graph.n_nodes)
        return mb, feats, labels

    def _next_batch(self) -> Dict[str, Any]:
        if self.fetcher is not None:
            return next(self.fetcher)
        t0 = time.perf_counter()
        item = next(self.pipeline)
        if self.store is not None:     # defer_gather stream: (mb, labels)
            item = self._gather_stage(*item)
        batch = self._prepare(*item)
        self._sync_stall_s += time.perf_counter() - t0
        self._sync_steps += 1
        return batch

    @property
    def stall_per_step(self) -> float:
        """Host time the device step could not hide, per consumed batch."""
        if self.fetcher is not None:
            return self.fetcher.stall_per_step
        return self._sync_stall_s / max(self._sync_steps, 1)

    def reset_stall_stats(self) -> None:
        if self.fetcher is not None:
            self.fetcher.reset_stats()
        self._sync_stall_s = 0.0
        self._sync_steps = 0

    # -- checkpoint/resume ---------------------------------------------------
    def _pipeline_state(self) -> Dict[str, int]:
        return self.fetcher.state() if self.fetcher is not None \
            else self.pipeline.state()

    def _extra(self) -> Dict[str, Any]:
        return {"step": self.global_step, "epochs_done": self.epochs_done,
                "pipeline": self._pipeline_state(),
                "spec": self.engine.spec,
                "requested_spec": self.requested_spec}

    def save(self, *, sync: bool = False) -> None:
        if self.mgr is None:
            return
        fn = self.mgr.save if sync else self.mgr.save_async
        fn(self.global_step, self.params, extra=self._extra())

    def resume(self) -> bool:
        """Restore the newest checkpoint (params + progress + the exact
        next-batch position).  Returns False when none exists."""
        if self.mgr is None:
            return False
        hit = self.mgr.restore_latest(self.params)
        if hit is None:
            return False
        self.params, extra, _ = hit
        saved_spec = extra.get("spec")
        if self.requested_spec == "auto" and saved_spec \
                and saved_spec != self.engine.spec:
            # the checkpoint pins the concrete spec its auto run resolved
            # at launch — a resume must continue bit-exactly on those
            # wires even if the planner record changed since
            self._rebind(saved_spec)
        self.global_step = int(extra["step"])
        self.epochs_done = int(extra.get("epochs_done", 0))
        if self.fetcher is not None:
            self.fetcher.restore(extra["pipeline"])
        else:
            self.pipeline.restore(extra["pipeline"])
        return True

    def _rebind(self, spec: str) -> None:
        """Swap the concrete engine under the existing mesh (the mesh is
        1-D for every topology, so only the bundle rebuilds); cached val
        batches are invalidated — they were placed through the old
        bundle."""
        engine = Engine(self.engine.config.with_spec(spec))
        engine.topology.validate_cores(self.n_cores)
        self.engine = engine
        self.bundle = engine.build(self.mesh)
        self._val_batches = None

    def close(self) -> None:
        if self.fetcher is not None:
            self.fetcher.close()
        if self._owned_store and self.store is not None:
            # only stores the Trainer created (from_array wrapping) are
            # closed here — a dataset-owned or caller-passed store may be
            # shared and outlives this Trainer
            self.store.close()
        if self.mgr is not None:
            self.mgr.wait()

    # -- the loop ------------------------------------------------------------
    def train_steps(self, n_steps: int) -> List[float]:
        """Run ``n_steps`` optimizer steps; returns their losses."""
        losses: List[float] = []
        for _ in range(n_steps):
            batch = self._next_batch()
            if isinstance(batch.get("report"), dict):
                # partition/merge observability from the bundle's host-side
                # batch prep (wire bytes, virtual vertices, pair coverage)
                self.last_plan_report = dict(batch["report"])
            self.params, loss = self.bundle.train_step(self.params, batch)
            losses.append(float(loss))
            self.global_step += 1
            if self.log_every and self.global_step % self.log_every == 0:
                print(f"step {self.global_step:5d}  loss "
                      f"{losses[-1]:.4f}  stall/step "
                      f"{self.stall_per_step * 1e3:.1f} ms")
            if self.mgr and self.ckpt_every \
                    and self.global_step % self.ckpt_every == 0:
                self.save()
        self.history.extend(losses)
        return losses

    def _build_val_batches(self) -> List[Any]:
        """The val batches are deterministic (seed sets + per-batch rngs
        fixed at construction), so they are sampled, laid out, and placed
        ONCE and reused every epoch — re-preparing them would redo the
        layout builds per epoch and churn the shared plan cache for
        byte-identical results."""
        from repro.data import assemble_batch

        batches = []
        for seeds in self._val_seed_sets:
            rng = np.random.default_rng(
                np.random.SeedSequence([self.seed, 7, int(seeds[0])]))
            # the SAME assembly rule as the training pipeline, so the val
            # path can never drift from what the train step consumes
            mb, feats, labels = assemble_batch(self.dataset, self.sampler,
                                               seeds, self._nnz_pad, rng)
            batches.append((len(seeds), self._prepare(mb, feats, labels)))
        return batches

    def evaluate(self) -> float:
        """Validation accuracy on the held-out seed sets (padded rows
        masked host-side; multilabel datasets score the argmax proxy, same
        target the train step optimizes)."""
        if self._val_batches is None:
            self._val_batches = self._build_val_batches()
        hits = total = 0
        for n_seeds, batch in self._val_batches:
            logits = np.asarray(self.bundle.forward(self.params, batch))
            want = np.asarray(batch["labels"])[:n_seeds]
            hits += int((logits[:n_seeds].argmax(-1) == want).sum())
            total += n_seeds
        return hits / max(total, 1)

    def fit(self, epochs: int = 1, *, steps_per_epoch: Optional[int] = None,
            max_steps: Optional[int] = None, resume: bool = False
            ) -> Dict[str, Any]:
        """Epoch loop: train → validate → record metrics (+ checkpoint).

        ``steps_per_epoch`` defaults to the dataset's full epoch;
        ``max_steps`` caps the TOTAL (global) step count, so a resumed run
        continues to the same horizon as an uninterrupted one.
        """
        if resume:
            self.resume()
        spe = steps_per_epoch if steps_per_epoch is not None \
            else self.pipeline.batches_per_epoch
        out: Dict[str, Any] = {"spec": self.engine.spec,
                               "requested_spec": self.requested_spec,
                               "n_cores": self.n_cores,
                               "input_pipeline": self.input_pipeline,
                               "feature_store": self.feature_mode,
                               "loss_history": [], "val_acc": [],
                               "epoch_s": [], "steps_per_s": [],
                               "host_stall_s_per_step": []}
        t_all = time.time()
        try:
            for _ in range(self.epochs_done, epochs):
                budget = spe
                if max_steps is not None:
                    budget = min(budget, max_steps - self.global_step)
                if budget <= 0:
                    break
                self.reset_stall_stats()
                t0 = time.time()
                losses = self.train_steps(budget)
                dt = time.time() - t0
                out["loss_history"].extend(losses)
                out["epoch_s"].append(dt)
                out["steps_per_s"].append(len(losses) / max(dt, 1e-9))
                out["host_stall_s_per_step"].append(self.stall_per_step)
                out["val_acc"].append(self.evaluate())
                self.epochs_done += 1
                if self.log_every:
                    print(f"epoch {self.epochs_done}: loss "
                          f"{losses[-1]:.4f}  val_acc "
                          f"{out['val_acc'][-1]:.3f}  "
                          f"{out['steps_per_s'][-1]:.1f} steps/s  "
                          f"stall/step "
                          f"{out['host_stall_s_per_step'][-1] * 1e3:.1f} ms")
                if self.mgr is not None:
                    self.save()
        finally:
            self.close()
        out["wall_s"] = time.time() - t_all
        out["global_step"] = self.global_step
        out["params"] = self.params
        if self.store is not None:
            out["gather_calls"] = int(self.store.gather_calls)
            out["gather_bytes"] = int(self.store.bytes_gathered)
            if self.cache is not None:
                out["cache"] = self.cache.stats()
        if getattr(self, "last_plan_report", None):
            # last train batch's partition/merge plan metrics, next to the
            # cache stats: measured exchange wire bytes (per core, summed
            # over hop layers), mined virtual vertices, and pair coverage
            out["plan"] = dict(self.last_plan_report)
        if isinstance(self.fetcher, StagedPrefetcher):
            # last epoch's per-stage stalls (stage k's stall = time it
            # waited on stage k-1 — where the chain is bottlenecked)
            out["stage_stall_s_per_step"] = self.fetcher.stage_stalls()
        return out


# ---------------------------------------------------------------------------
# CLI — the CI trainer smoke: train, checkpoint mid-run, restart, resume.
# ---------------------------------------------------------------------------
def main(argv: Optional[Sequence[str]] = None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--spec", default="ell+pipelined",
                    help="engine spec (repro.engine.supported_specs())")
    ap.add_argument("--dataset", default="flickr")
    ap.add_argument("--scale", type=float, default=0.01)
    ap.add_argument("--feat-dim", type=int, default=64)
    ap.add_argument("--hidden", type=int, default=64)
    ap.add_argument("--n-cores", type=int, default=1)
    ap.add_argument("--batch-size", type=int, default=64)
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--lr", type=float, default=0.05)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--input-pipeline", default="prefetch",
                    choices=["prefetch", "sync"])
    ap.add_argument("--feature-store", default="device",
                    help="'device' (dense in-memory features, the default)"
                         " or a registered featurestore backend ('host', "
                         "'mmap') to stream frontier rows out-of-core")
    ap.add_argument("--cache-capacity", type=int, default=0,
                    help="hot-vertex cache rows in front of the store "
                         "(0 disables; needs --feature-store)")
    ap.add_argument("--cache-pinned", type=int, default=None,
                    help="cache rows pinned to the top-degree vertices "
                         "(default: half the capacity)")
    ap.add_argument("--pad-multiple", type=int, default=None,
                    help="coarser sampler padding → fewer distinct dims "
                         "signatures → fewer jit re-traces")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--ckpt-restart", action="store_true",
                    help="smoke the fault path: checkpoint at the midpoint,"
                         " rebuild the Trainer, resume, and assert the "
                         "resumed trajectory matches an uninterrupted run")
    args = ap.parse_args(argv)

    def build(pipeline: str, ckpt: Optional[str]) -> Trainer:
        fs = None if args.feature_store == "device" else args.feature_store
        return Trainer(args.spec, args.dataset, n_cores=args.n_cores,
                       scale=args.scale, feat_dim=args.feat_dim,
                       hidden=args.hidden, batch_size=args.batch_size,
                       lr=args.lr, seed=args.seed, input_pipeline=pipeline,
                       pad_multiple=args.pad_multiple,
                       feature_store=fs, cache_capacity=args.cache_capacity,
                       cache_pinned=args.cache_pinned,
                       ckpt_dir=ckpt, ckpt_every=0, log_every=10)

    if args.ckpt_restart:
        import tempfile
        mid = args.steps // 2
        with tempfile.TemporaryDirectory() as ckpt:
            full = build(args.input_pipeline, None)
            ref = full.fit(1, steps_per_epoch=args.steps)
            part = build(args.input_pipeline, ckpt)
            part.train_steps(mid)
            part.save(sync=True)
            part.close()
            resumed = build(args.input_pipeline, ckpt)
            out = resumed.fit(1, steps_per_epoch=args.steps - mid,
                              resume=True)
        drift = max(abs(a - b) for a, b in
                    zip(ref["loss_history"][mid:], out["loss_history"]))
        print(f"resume drift vs uninterrupted: {drift:.2e}")
        assert drift <= 1e-6, drift
        cache = out.get("cache")
        extra = (f"  store={out['feature_store']} "
                 f"cache_hit_rate={cache['hit_rate']:.2f}"
                 if cache else f"  store={out['feature_store']}")
        print(f"OK spec={args.spec} cores={args.n_cores} "
              f"steps={args.steps} (ckpt@{mid} + resume, batch-exact)  "
              f"val_acc={out['val_acc'][-1]:.3f}{extra}")
        return

    tr = build(args.input_pipeline, args.ckpt_dir)
    out = tr.fit(1, steps_per_epoch=args.steps, resume=args.resume)
    print(f"final loss {out['loss_history'][-1]:.4f}  val_acc "
          f"{out['val_acc'][-1]:.3f}  {out['steps_per_s'][-1]:.1f} steps/s "
          f"({out['wall_s']:.1f}s, stall/step "
          f"{out['host_stall_s_per_step'][-1] * 1e3:.1f} ms)")


if __name__ == "__main__":
    main()
