# Launch-layer entry points: mesh construction, dry-run compile sweep,
# HLO accounting, train/serve drivers — trainer.py is the engine-native
# distributed Trainer (async input pipeline + checkpoint/resume); train.py
# the legacy-signature CLI over it plus the LM loop.  Modules are imported
# directly (e.g. ``repro.launch.mesh``); nothing is re-exported here to
# keep the jax-import side effects (XLA_FLAGS in dryrun.py) explicit.
