# Launch-layer entry points: mesh construction, dry-run compile sweep,
# HLO accounting, train/serve drivers.  Modules are imported directly
# (e.g. ``repro.launch.mesh``); nothing is re-exported here to keep the
# jax-import side effects (XLA_FLAGS in dryrun.py) explicit.
