"""GCN serving CLI — the thin launcher over :mod:`repro.serving`.

Trains (or restores) a checkpoint, builds an
:class:`~repro.serving.InferenceEngine` on it, and drives the
:class:`~repro.serving.InferenceService` under synthetic open-loop traffic,
printing p50/p99 latency, throughput-at-SLO, coalesce factor and cache
hit-rate.  The LM continuous-batching loop that used to live here moved to
:mod:`repro.launch.lm_serve`.

CPU smoke (the CI ``serving-smoke`` job)::

    XLA_FLAGS=--xla_force_host_platform_device_count=4 PYTHONPATH=src \\
        python -m repro.launch.serve --smoke

``--smoke`` hard-asserts the serving contract: logits after a mixed stream
of queries and graph/feature updates bit-match a cold full recompute, and
open-loop p99 stays under ``--p99-budget-ms``; exit 1 on either failure.
"""
from __future__ import annotations

import argparse
import os
import tempfile
from typing import Optional, Sequence

import numpy as np


def _train_checkpoint(args, ckpt_dir: str):
    """Train a few steps on ``--n-cores`` simulated devices and checkpoint
    — the serving engine then loads what a real deployment would: a
    CheckpointManager directory, not in-process weights."""
    from repro.launch.trainer import Trainer

    trainer = Trainer(args.train_spec, "flickr", n_cores=args.n_cores,
                      scale=args.scale, feat_dim=args.feat_dim,
                      hidden=args.hidden, batch_size=args.batch_size,
                      pad_multiple=max(64, args.n_cores),
                      ckpt_dir=ckpt_dir, log_every=0, seed=args.seed)
    trainer.train_steps(args.train_steps)
    trainer.save(sync=True)
    dataset = trainer.dataset
    trainer.close()
    return dataset


def build_engine(args, ckpt_dir: str, dataset=None):
    from repro.graph import make_dataset
    from repro.serving import InferenceEngine

    if dataset is None:
        dataset = make_dataset("flickr", scale=args.scale,
                               feat_dim=args.feat_dim)
    return InferenceEngine(
        args.spec, dataset.graph, dataset.features, ckpt_dir=ckpt_dir,
        cache_capacity=args.cache_capacity,
        feature_cache_capacity=args.feature_cache_capacity,
        max_batch=args.max_batch), dataset


def mixed_stream_bit_match(engine, n_rounds: int, seed: int) -> bool:
    """Interleave queries with edge/feature updates; every query's
    incremental logits must bit-match the cold full recompute."""
    rng = np.random.default_rng(seed)
    n = engine.graph.n_nodes
    ok = True
    for _ in range(n_rounds):
        kind = rng.integers(0, 3)
        if kind == 0:
            ids = rng.integers(0, n, 2)
            engine.update_features(
                ids, rng.standard_normal(
                    (2, engine.feat_dim)).astype(np.float32))
        elif kind == 1:
            engine.update_edges(add=[(int(rng.integers(0, n)),
                                      int(rng.integers(0, n)))
                                     for _ in range(2)])
        else:
            v = int(rng.integers(0, n))
            nbrs = engine.graph.in_neighbors(v)
            if len(nbrs):
                engine.update_edges(remove=[(int(nbrs[0]), v)])
        q = rng.integers(0, n, 4)
        inc = engine.query(q)
        cold = engine.query(q, use_cache=False)
        ok = ok and bool((inc == cold).all())
    return ok


def main(argv: Optional[Sequence[str]] = None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--spec", default="coo+serial",
                    help="serving Engine spec ('auto' uses the planner's "
                    "serving mode)")
    ap.add_argument("--train-spec", default="ell+pipelined",
                    help="spec the checkpoint-producing Trainer runs")
    ap.add_argument("--n-cores", type=int,
                    default=int(os.environ.get("REPRO_SERVE_CORES", 4)))
    ap.add_argument("--scale", type=float, default=0.004)
    ap.add_argument("--feat-dim", type=int, default=32)
    ap.add_argument("--hidden", type=int, default=32)
    ap.add_argument("--batch-size", type=int, default=32)
    ap.add_argument("--train-steps", type=int, default=20)
    ap.add_argument("--ckpt-dir", default=None,
                    help="restore from here when it already holds a "
                    "checkpoint; otherwise train into it")
    ap.add_argument("--rate", type=float, default=150.0,
                    help="open-loop arrivals per second")
    ap.add_argument("--duration", type=float, default=2.0)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--max-wait-ms", type=float, default=2.0)
    ap.add_argument("--slo-ms", type=float, default=50.0)
    ap.add_argument("--cache-capacity", type=int, default=4096)
    ap.add_argument("--feature-cache-capacity", type=int, default=0)
    ap.add_argument("--update-rounds", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="CI gate: assert bit-match + p99 budget")
    # ~50x the warm p50: catches pathological regressions (e.g. a jit
    # recompile per query is an ~800ms floor) without flaking on shared
    # CI host load
    ap.add_argument("--p99-budget-ms", type=float, default=400.0)
    args = ap.parse_args(argv)

    from repro.serving import InferenceService, poisson_trace

    tmp = None
    ckpt_dir = args.ckpt_dir
    dataset = None
    if ckpt_dir is None:
        tmp = tempfile.TemporaryDirectory(prefix="repro_serve_ckpt_")
        ckpt_dir = tmp.name
    if not any(name.startswith("step_") for name in
               (os.listdir(ckpt_dir) if os.path.isdir(ckpt_dir) else [])):
        print(f"training {args.train_steps} steps "
              f"({args.train_spec}, {args.n_cores} cores) -> {ckpt_dir}")
        dataset = _train_checkpoint(args, ckpt_dir)

    engine, dataset = build_engine(args, ckpt_dir, dataset)
    print(f"serving spec: {engine.spec}  "
          f"({engine.n_layers} layers, {engine.graph.n_nodes} nodes)")

    bit_match = mixed_stream_bit_match(engine, args.update_rounds,
                                       args.seed)
    print(f"mixed query/update stream: incremental == cold recompute: "
          f"{bit_match}")

    trace = poisson_trace(args.rate, args.duration, engine.graph.n_nodes,
                          seed=args.seed)
    # rehearsal pass off the clock: replay the identical trace once so
    # every jit shape bucket it will hit is compiled before measurement —
    # compile is deployment warmup, not serving latency (one uncompiled
    # bucket mid-replay shows up as a ~400ms p99 outlier)
    InferenceService(engine, max_batch=args.max_batch,
                     max_wait=args.max_wait_ms * 1e-3) \
        .replay(trace, slo=args.slo_ms * 1e-3)
    service = InferenceService(engine, max_batch=args.max_batch,
                               max_wait=args.max_wait_ms * 1e-3)
    out = service.replay(trace, slo=args.slo_ms * 1e-3)
    hit_rate = engine.cache.hit_rate
    print(f"open loop: {out['completed']} requests  "
          f"p50 {out['p50_ms']:.1f}ms  p99 {out['p99_ms']:.1f}ms  "
          f"throughput@SLO({out['slo_ms']:.0f}ms) "
          f"{out['throughput_at_slo']:.1f}/s  "
          f"coalesce {out['coalesce_factor']:.2f}x  "
          f"embedding-cache hit-rate {hit_rate:.2f}")
    if tmp is not None:
        tmp.cleanup()
    if args.smoke:
        ok = bit_match and out["p99_ms"] < args.p99_budget_ms
        print("SERVING SMOKE", "PASS" if ok else
              f"FAIL (bit_match={bit_match}, p99={out['p99_ms']:.1f}ms, "
              f"budget={args.p99_budget_ms}ms)")
        raise SystemExit(0 if ok else 1)


if __name__ == "__main__":
    main()
