import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

This is the proof that the distribution config is coherent without real
hardware: ``jit(step).lower(*abstract).compile()`` must succeed on the
single-pod (16, 16) mesh AND the 2-pod (2, 16, 16) mesh for every runnable
cell, with ``memory_analysis()`` proving fit and ``cost_analysis()`` +
HLO-parsed collective bytes feeding the §Roofline table.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun                 # all cells
    PYTHONPATH=src python -m repro.launch.dryrun --arch yi-6b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --multi-pod --out dryrun.json
"""
import argparse      # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402
from typing import Any, Dict, Optional  # noqa: E402

import jax           # noqa: E402

from repro.compat import set_mesh  # noqa: E402
from repro.configs import SHAPES, all_cells, applicable, get_config  # noqa: E402
from repro.launch.hlo_analysis import analyze_hlo, roofline_terms  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.steps import (abstract_inputs, build_step,  # noqa: E402
                                ep_spec_for, sp_spec_for)


def dryrun_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
                verbose: bool = True) -> Dict[str, Any]:
    """Lower + compile one cell; return the roofline record."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    t0 = time.time()
    with set_mesh(mesh):   # context mesh: pjit specs + nested shard_map
        args, info = abstract_inputs(cfg, shape, mesh)
        step = build_step(cfg, shape.kind,
                          sp_spec=sp_spec_for(cfg, shape, mesh),
                          ep_spec=ep_spec_for(cfg, shape, mesh))
        # donate the state that the step replaces (params/opt for train, the
        # cache for decode) — in-place updates, halves the peak footprint
        donate = {"train": (0, 1), "decode": (1,), "prefill": ()}[shape.kind]
        kwargs = {"donate_argnums": donate}
        if info["out_shardings"] is not None:
            kwargs["out_shardings"] = info["out_shardings"]
        jitted = jax.jit(step, **kwargs)
        lowered = jitted.lower(*args)
        compiled = lowered.compile()
    t_compile = time.time() - t0

    cost = compiled.cost_analysis() or {}
    try:
        mem = compiled.memory_analysis()
        mem_info = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
        }
    except Exception:  # CPU backend may not implement it
        mem_info = {}
    hlo = compiled.as_text()
    # scan-corrected accounting (XLA's cost_analysis counts while bodies
    # once — see hlo_analysis docstring); raw numbers kept for reference
    st = analyze_hlo(hlo, world=n_chips)
    flops = st.flops
    hbm = st.hbm_bytes
    terms = roofline_terms(flops, hbm, st.collective_wire_bytes, n_chips)

    model_flops = 6 * cfg.active_param_count() \
        * shape.global_batch * (shape.seq_len if shape.kind == "train" else
                                (shape.seq_len if shape.kind == "prefill"
                                 else 1))
    if shape.kind == "train":
        pass  # 6ND: fwd+bwd
    else:
        model_flops = model_flops / 3  # inference: 2ND forward only

    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "kind": shape.kind, "n_chips": int(n_chips),
        "compile_s": round(t_compile, 2),
        "hlo_flops_per_device": flops,
        "hlo_bytes_per_device": hbm,
        "xla_cost_flops_raw": float(cost.get("flops", 0.0)),
        "collective_wire_bytes_per_device": st.collective_wire_bytes,
        "collective_detail": {k: v for k, v in st.by_kind.items()},
        "collective_counts": {k: v for k, v in st.by_kind_count.items()},
        "memory": mem_info,
        "roofline": terms,
        "model_flops_global": model_flops,
        "useful_flops_ratio": (model_flops / max(flops * n_chips, 1.0)),
    }
    if verbose:
        print(f"[{rec['mesh']}] {arch} × {shape_name}: compile {t_compile:.1f}s"
              f"  flops/dev={flops:.3g} bytes/dev={hbm:.3g}"
              f"  wire/dev={st.collective_wire_bytes:.3g}"
              f"  dominant={terms['dominant']}"
              f"  t=({terms['t_compute']*1e3:.2f}, {terms['t_memory']*1e3:.2f},"
              f" {terms['t_collective']*1e3:.2f}) ms")
        if mem_info.get("temp_bytes") is not None:
            print(f"    memory: args={mem_info['argument_bytes']}"
                  f" temp={mem_info['temp_bytes']}"
                  f" peak={mem_info.get('peak_bytes')}")
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true",
                    help="run ONLY the 2x16x16 mesh (default: both)")
    ap.add_argument("--single-pod", action="store_true",
                    help="run ONLY the 16x16 mesh")
    ap.add_argument("--out", default=None, help="write records JSON here")
    args = ap.parse_args()

    meshes = [False, True]
    if args.multi_pod:
        meshes = [True]
    if args.single_pod:
        meshes = [False]

    records = []
    failures = []
    for arch, shape, ok, reason in all_cells(include_skipped=True):
        if args.arch and arch != args.arch:
            continue
        if args.shape and shape != args.shape:
            continue
        if not ok:
            print(f"[skip] {arch} × {shape}: {reason}")
            records.append({"arch": arch, "shape": shape, "skipped": True,
                            "reason": reason})
            continue
        for mp in meshes:
            try:
                records.append(dryrun_cell(arch, shape, multi_pod=mp))
            except Exception as e:  # noqa: BLE001
                traceback.print_exc()
                failures.append((arch, shape, mp, repr(e)))
    if args.out:
        with open(args.out, "w") as f:
            json.dump(records, f, indent=1)
        print(f"wrote {len(records)} records to {args.out}")
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f_ in failures:
            print(" ", f_)
        raise SystemExit(1)
    print(f"\nall {len(records)} cells passed")


if __name__ == "__main__":
    main()
