"""Batched LM serving loop — continuous-batching decode over the LM API.

A minimal production-shaped server: a deque-backed request queue feeds a
fixed-slot batch (continuous batching — a finished request's slot is
refilled immediately), prefill runs per-request, decode steps the whole
batch against the shared cache.  On CPU this runs the smoke configs; the
full configs are exercised shape-level by the dry-run's decode cells.

Slots decode at their OWN positions: ``decode_fn`` takes one scalar ``pos``
and writes the new k/v at that position for every batch row, so the step
groups active slots by position and masks the cache merge per group — only
a group's own rows take the freshly written cache, everyone else keeps
theirs (this fixes the seed's homogeneous-position bug, where
``slot_pos[active[0]]`` was applied to all slots and any slot at another
position read and corrupted the wrong cache column).

    PYTHONPATH=src python -m repro.launch.lm_serve --arch llama3.2-1b \
        --requests 8 --max-new 16
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from collections import deque
from typing import Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke
from repro.models import lm


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray           # [p] int32
    max_new: int
    generated: List[int] = dataclasses.field(default_factory=list)

    @property
    def done(self) -> bool:
        return len(self.generated) >= self.max_new


class Server:
    """Fixed-slot continuous batching server.

    The queue is FIFO (a deque: O(1) admission from the head, unlike the
    seed's ``list.pop(0)``); slots admit strictly in arrival order.
    """

    def __init__(self, arch: str, *, slots: int = 4, max_seq: int = 128,
                 smoke: bool = True, seed: int = 0):
        self.cfg = get_smoke(arch) if smoke else get_config(arch)
        if self.cfg.family == "encdec":
            raise NotImplementedError(
                "serve loop drives decoder-only archs; seamless decode is "
                "covered by the dry-run decode cells")
        self.max_seq = max_seq
        self.slots = slots
        self.params = lm.init_params(jax.random.PRNGKey(seed), self.cfg,
                                     dtype=jnp.float32)
        self.cache = lm.init_cache(self.cfg, slots, max_seq,
                                   dtype=jnp.float32)
        decode = lm.decode_fn(self.cfg)

        def masked_step(params, cache, tokens, pos, mask):
            # decode writes k/v at ``pos`` for EVERY batch row; the merge
            # keeps the new cache only where mask (batch axis 1 on every
            # cache leaf) — other slots' histories stay untouched
            logits, new = decode(params, cache, tokens, pos)

            def merge(n, o):
                m = mask.reshape((1, -1) + (1,) * (n.ndim - 2))
                return jnp.where(m, n, o)

            return logits, jax.tree_util.tree_map(merge, new, cache)

        self.decode = jax.jit(masked_step, donate_argnums=(1,))
        self.slot_req: List[Optional[Request]] = [None] * slots
        self.slot_pos = np.zeros(slots, np.int32)
        self.queue: Deque[Request] = deque()
        self.completed: List[Request] = []

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _admit(self) -> None:
        for s in range(self.slots):
            if self.slot_req[s] is None and self.queue:
                req = self.queue.popleft()
                self.slot_req[s] = req
                # per-request prefill: feed prompt tokens through decode
                # steps (slot-level prefill keeps the batch cache layout;
                # cheap at smoke scale, flash-prefill at production scale)
                for t, tok in enumerate(req.prompt):
                    self._step_slot(s, int(tok), t)
                self.slot_pos[s] = len(req.prompt)

    def _step_slot(self, s: int, token: int, pos: int) -> None:
        # single-slot step: batch with this slot's token, others masked out
        tokens = np.zeros((self.slots, 1), np.int32)
        tokens[s, 0] = token
        mask = np.zeros(self.slots, bool)
        mask[s] = True
        logits, self.cache = self.decode(
            self.params, self.cache, jnp.asarray(tokens), jnp.int32(pos),
            jnp.asarray(mask))
        self._last_logits = np.asarray(logits)

    def step(self) -> int:
        """One decode round over all active slots; returns #active.

        Slots at the same position share one decode call; each distinct
        position gets its own masked call, so heterogeneous prompt lengths
        decode correctly side by side."""
        self._admit()
        active = [s for s in range(self.slots) if self.slot_req[s]]
        if not active:
            return 0
        by_pos: Dict[int, List[int]] = {}
        for s in active:
            by_pos.setdefault(int(self.slot_pos[s]), []).append(s)
        nxt = np.zeros(self.slots, np.int64)
        for pos, group in sorted(by_pos.items()):
            tokens = np.zeros((self.slots, 1), np.int32)
            mask = np.zeros(self.slots, bool)
            for s in group:
                req = self.slot_req[s]
                tokens[s, 0] = req.generated[-1] if req.generated \
                    else int(req.prompt[-1])
                mask[s] = True
            logits, self.cache = self.decode(
                self.params, self.cache, jnp.asarray(tokens),
                jnp.int32(pos), jnp.asarray(mask))
            picks = np.asarray(jnp.argmax(logits[:, 0], -1))
            for s in group:
                nxt[s] = picks[s]
        for s in active:
            req = self.slot_req[s]
            req.generated.append(int(nxt[s]))
            self.slot_pos[s] += 1
            if req.done or self.slot_pos[s] >= self.max_seq - 1:
                self.completed.append(req)
                self.slot_req[s] = None
                self.slot_pos[s] = 0
        return len(active)

    def run(self) -> Dict[str, float]:
        t0 = time.time()
        steps = 0
        tokens = 0
        while self.queue or any(self.slot_req):
            tokens += self.step()
            steps += 1
        dt = time.time() - t0
        return {"steps": steps, "tokens": tokens, "wall_s": dt,
                "tok_per_s": tokens / max(dt, 1e-9)}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    args = ap.parse_args()
    rng = np.random.default_rng(0)
    srv = Server(args.arch, slots=args.slots)
    for i in range(args.requests):
        prompt = rng.integers(0, srv.cfg.vocab,
                              rng.integers(4, 12)).astype(np.int32)
        srv.submit(Request(rid=i, prompt=prompt, max_new=args.max_new))
    stats = srv.run()
    print(f"served {len(srv.completed)} requests, "
          f"{stats['tokens']} tokens in {stats['steps']} steps, "
          f"{stats['tok_per_s']:.1f} tok/s")


if __name__ == "__main__":
    main()
