"""Routing → collective schedule: lowering Algorithm 1 onto TPU ICI.

On the FPGA the routing table programs per-cycle switch states.  A TPU has no
per-cycle channel control — ICI traffic is expressed as collectives — so the
paper's network layer is lowered in two steps:

  1. **Dimension-ordered hypercube schedule** (:func:`reduce_scatter_rounds`):
     the deterministic special case of Algorithm 1 in which every message
     resolves its differing bits in a fixed dimension order.  All messages
     then finish in exactly ``ndim`` rounds, and the traffic of round *r* is
     a single exchange along dimension *r* — which is precisely one
     ``ppermute`` (pairwise ``collective_permute``) per round inside
     ``shard_map``.  Local pre-reduction folds into a segment-sum before each
     send: the wire carries partial sums, never raw neighbor rows — the
     paper's Reduced-Register-File compression, in collective form.

  2. **Equivalence accounting** (:func:`compare_schedules`): Algorithm 1's
     adaptive table and the dimension-ordered schedule deliver the same
     messages; Alg. 1 wins cycles when waves are irregular (it races short
     messages first), dimension-order wins determinism (XLA can overlap it).
     The benchmark quantifies both so EXPERIMENTS.md can show what the
     adaptivity is worth and why the TPU port chooses the static form.

The deadlock-freedom constraints of §4.3.2 translate too: Constraint 1
(≤4 receives) holds because each round uses one dimension (one receive per
device per round); Constraint 2 (distinct senders) because a round's traffic
is a permutation.  What *remains* meaningful on TPU is load balance — bytes
per round — which :func:`round_bytes` exposes for the roofline.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

import numpy as np

from .routing import popcount, route_messages


@dataclasses.dataclass(frozen=True)
class Round:
    """One collective round: every device ``d`` exchanges with ``d ^ mask``."""

    dim: int        # which hypercube dimension this round resolves
    mask: int       # partner XOR mask == 1 << dim

    def partner(self, core: int) -> int:
        return core ^ self.mask


def reduce_scatter_rounds(ndim: int) -> List[Round]:
    """Hypercube reduce-scatter: after round r, partial sums whose destination
    differs from the holder in bit r have moved across dimension r.  After
    ``ndim`` rounds every aggregate row sits fully reduced on its owner."""
    return [Round(dim=r, mask=1 << r) for r in range(ndim)]


def allgather_rounds(ndim: int) -> List[Round]:
    """Mirror schedule (backward pass uses the same edges, reversed)."""
    return [Round(dim=r, mask=1 << r) for r in reversed(range(ndim))]


def dimension_ordered_table(src: Sequence[int], dst: Sequence[int],
                            ndim: int = 4) -> np.ndarray:
    """Static routing table of the dimension-ordered schedule.

    Returns [ndim, p]: position of each message after each round (messages
    whose bit-r matches stay put that round).  Always exactly ``ndim`` rounds
    — the price of determinism is that short messages cannot finish early.
    """
    src = np.asarray(src, np.int64)
    dst = np.asarray(dst, np.int64)
    cur = src.copy()
    out = np.zeros((ndim, len(src)), np.int64)
    for r in range(ndim):
        flip = ((cur ^ dst) >> r) & 1
        cur = cur ^ (flip << r)
        out[r] = cur
    assert np.all(cur == dst)
    return out


def round_bytes(src: Sequence[int], dst: Sequence[int], msg_bytes: int,
                ndim: int = 4) -> np.ndarray:
    """Bytes crossing each dimension under the static schedule ([ndim] array).

    This is the per-round ICI traffic the roofline's collective term reads
    (each round is a bidirectional neighbor exchange on its own link)."""
    src = np.asarray(src, np.int64)
    dst = np.asarray(dst, np.int64)
    moves = np.zeros(ndim, np.int64)
    for r in range(ndim):
        moves[r] = int((((src ^ dst) >> r) & 1).sum())
    return moves * msg_bytes


def compare_schedules(src: Sequence[int], dst: Sequence[int], *, ndim: int = 4,
                      seed: int = 0) -> Dict[str, float]:
    """Adaptive (Alg. 1) vs dimension-ordered cycle counts for one wave."""
    adaptive = route_messages(src, dst, ndim=ndim, seed=seed)
    static_cycles = ndim if len(src) else 0
    shortest = int(popcount(np.asarray(src) ^ np.asarray(dst)).max()) \
        if len(src) else 0
    return {
        "adaptive_cycles": float(adaptive.cycles),
        "static_cycles": float(static_cycles),
        "lower_bound": float(shortest),
        "adaptive_stalls": float(np.sum(adaptive.table == -1)),
    }


@dataclasses.dataclass(frozen=True)
class FeatureWave:
    """One feature-dimension chunk of the pipelined fold (half-open slice)."""

    start: int
    size: int

    @property
    def stop(self) -> int:
        return self.start + self.size


def feature_waves(d: int, n_chunks: int) -> Tuple["FeatureWave", ...]:
    """Chunk a feature dimension into the double-buffer wave schedule.

    The pipelined aggregation issues chunk *k*'s ``ppermute`` before the
    local work of chunk *k+1*, so with ≥2 waves every wire transfer has
    compute to hide behind — the TPU lowering of the paper's ping-pong
    Block-Message buffers (§4.2).  Chunks are contiguous, cover ``[0, d)``
    exactly, and differ in size by at most one column, so the math is
    bit-identical to the unchunked schedule (same per-element add order).
    """
    if d <= 0:
        raise ValueError(f"feature dim must be positive, got {d}")
    n_chunks = max(1, min(int(n_chunks), d))
    base, rem = divmod(d, n_chunks)
    waves = []
    start = 0
    for k in range(n_chunks):
        size = base + (1 if k < rem else 0)
        waves.append(FeatureWave(start=start, size=size))
        start += size
    return tuple(waves)


@dataclasses.dataclass(frozen=True)
class AggregationPlan:
    """Everything the distributed SpMM needs, precomputed at trace time.

    For a P-core partition of an (n_dst × n_src) adjacency:
      * each device computes local partials for ALL destination cores from
        its own source rows (the Index-Compressor pre-reduction),
      * ``rounds`` then fold partials across the hypercube; after the last
        round device i holds the fully-reduced rows it owns.
    """

    ndim: int
    rounds: Tuple[Round, ...]

    @property
    def n_cores(self) -> int:
        return 1 << self.ndim


def make_plan(n_cores: int) -> AggregationPlan:
    ndim = int(np.log2(n_cores))
    if (1 << ndim) != n_cores:
        raise ValueError(f"core count {n_cores} is not a power of two")
    return AggregationPlan(ndim=ndim, rounds=tuple(reduce_scatter_rounds(ndim)))
