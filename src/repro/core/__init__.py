# The paper's primary contribution: transpose-free GCN training dataflow
# (gcn.py vs baseline.py, chosen by estimator.py) + the 4-D hypercube
# parallel-multicast message-passing layer (routing.py, blockmsg.py,
# schedule.py).
from .gcn import gcn_layer, gcn_layer_blocked, gcn_layer_ell, residual_bytes
from .baseline import gcn_layer_baseline, residual_bytes_naive
from .estimator import (CostEstimate, LayerShape, choose_order,
                        layer_shapes_for_batch, storage_naive, storage_ours,
                        time_naive, time_ours)
from .routing import (RoutingResult, aggregate_bandwidth_model,
                      fuse_experiment, make_fuse_wave, route_messages,
                      validate_routing, xor_path_set)
from .blockmsg import (BlockMessage, Wave, build_waves, compress_block,
                       message_rowlists, sender_merge_flat,
                       wave_statistics)
from .schedule import (AggregationPlan, Round, allgather_rounds,
                       compare_schedules, dimension_ordered_table, make_plan,
                       reduce_scatter_rounds, round_bytes)

__all__ = [
    "gcn_layer", "gcn_layer_blocked", "gcn_layer_ell", "residual_bytes",
    "gcn_layer_baseline", "residual_bytes_naive",
    "CostEstimate", "LayerShape", "choose_order", "layer_shapes_for_batch",
    "storage_naive", "storage_ours", "time_naive", "time_ours",
    "RoutingResult", "aggregate_bandwidth_model", "fuse_experiment",
    "make_fuse_wave", "route_messages", "validate_routing", "xor_path_set",
    "BlockMessage", "Wave", "build_waves", "compress_block",
    "message_rowlists", "sender_merge_flat", "wave_statistics",
    "AggregationPlan", "Round", "allgather_rounds", "compare_schedules",
    "dimension_ordered_table", "make_plan", "reduce_scatter_rounds",
    "round_bytes",
]
