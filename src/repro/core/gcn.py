"""GCN layer with the paper's transpose-free backward dataflow (Table 1, "Ours").

Forward (order selectable per layer by the sequence estimator, §4.4):
    CoAg:  Y = σ( A (X W) )          — combine first
    AgCo:  Y = σ( (A X) W )          — aggregate first

Backward — the paper's redesign (Table 1 rows "Ours CoAg" / "Ours AgCo"):
  * never materialize Aᵀ: backward aggregation walks the SAME edge list
    column-major (Graph Converter; here :meth:`COO.rmatmul`),
  * never materialize Xᵀ / (AX)ᵀ as residuals: the weight gradient contracts
    X (resp. AX) directly over the node dimension
    (``einsum('nd,nh->dh')`` = dot_general with contraction on dim 0 — XLA
    never writes a transposed copy to HBM),
  * never materialize Wᵀ: the error propagation contracts W over the hidden
    dimension (``einsum('nh,dh->nd')``),
  * the only true transpose left in the whole training step is the loss-layer
    error (O(b·c), done once in the model's loss, not here).

The measurable contracts (tests + benchmarks assert these):
  * residual storage: CoAg saves {X, mask}, AgCo saves {AX, mask} — no
    transposed duplicates (baseline.py saves Xᵀ/(AX)ᵀ like a naive port),
  * edge storage: one COO table, reused by fwd and bwd (baseline builds Aᵀ),
  * HLO of the backward contains no transpose of an [n, d]-sized operand.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.cotangents import zero_ct
from repro.deprecation import warn_engine_shim as _warn_shim
from repro.graph.coo import COO

Order = str  # 'coag' | 'agco'


def _spmm(rows, cols, vals, x, n_dst):
    """y = A @ x  (row-major edge walk, forward aggregation)."""
    gathered = x[cols] * vals[:, None]
    return jax.ops.segment_sum(gathered, rows, num_segments=n_dst)


def _spmm_t(rows, cols, vals, e, n_src):
    """y = Aᵀ @ e  without an Aᵀ table: same edges, roles swapped
    (column-major walk = the Graph Converter's backward order)."""
    gathered = e[rows] * vals[:, None]
    return jax.ops.segment_sum(gathered, cols, num_segments=n_src)


# ---------------------------------------------------------------------------
# custom_vjp core.  Static args: n_dst, n_src, order, activate.
# ---------------------------------------------------------------------------
@partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2, 3))
def _gcn_layer(n_dst: int, n_src: int, order: Order, activate: bool,
               rows, cols, vals, x, w):
    if order == "coag":
        z = _spmm(rows, cols, vals, x @ w, n_dst)
    elif order == "agco":
        z = _spmm(rows, cols, vals, x, n_dst) @ w
    else:
        raise ValueError(order)
    return jnp.maximum(z, 0.0) if activate else z


def _gcn_layer_fwd(n_dst, n_src, order, activate, rows, cols, vals, x, w):
    if order == "coag":
        z = _spmm(rows, cols, vals, x @ w, n_dst)
        saved_feat = x                       # Table 1 Ours-CoAg: keep X, not Xᵀ
    else:
        ax = _spmm(rows, cols, vals, x, n_dst)
        z = ax @ w
        saved_feat = ax                      # Ours-AgCo: keep AX, not (AX)ᵀ
    y = jnp.maximum(z, 0.0) if activate else z
    mask = (z > 0) if activate else None     # σ' residual: 1 bit/elem, no copy of z
    return y, (rows, cols, vals, saved_feat, w, mask)


def _gcn_layer_bwd(n_dst, n_src, order, activate, res, ct):
    rows, cols, vals, saved_feat, w, mask = res
    dz = jnp.where(mask, ct, 0.0) if activate else ct          # σ'(E^{l+1})
    if order == "coag":
        x = saved_feat
        # S = Aᵀ·dz via column-major walk of the SAME edge list
        s = _spmm_t(rows, cols, vals, dz, n_src)                # [n_src, h]
        # dX = S Wᵀ — contract over h; W consumed untransposed
        dx = jnp.einsum("nh,dh->nd", s, w)
        # dW = Xᵀ S — contract over n; X consumed untransposed
        dw = jnp.einsum("nd,nh->dh", x, s)
    else:
        ax = saved_feat
        # dW = (AX)ᵀ dz — contract over n; AX consumed untransposed
        dw = jnp.einsum("nd,nh->dh", ax, dz)
        # d(AX) = dz Wᵀ — contract over h
        dax = jnp.einsum("nh,dh->nd", dz, w)
        dx = _spmm_t(rows, cols, vals, dax, n_src)
    # fixed normalized adjacency — indices float0, weights plain zeros
    return (*zero_ct((rows, cols, vals)), dx, dw)


_gcn_layer.defvjp(_gcn_layer_fwd, _gcn_layer_bwd)


def gcn_layer(A: COO, x: jnp.ndarray, w: jnp.ndarray, *,
              order: Order = "coag", activate: bool = True) -> jnp.ndarray:
    """Public GCN/SAGE-mean layer: ``σ(A (X W))`` or ``σ((A X) W)`` with the
    paper's transpose-free backward. ``A`` is the (rectangular) row-major COO
    of this hop."""
    if x.shape[0] != A.n_src:
        raise ValueError(f"x rows {x.shape[0]} != A.n_src {A.n_src}")
    return _gcn_layer(A.n_dst, A.n_src, order, activate,
                      A.rows, A.cols, A.vals, x, w)


# ---------------------------------------------------------------------------
# Block-layout variant: aggregation through the Block-Message tile kernel.
# ---------------------------------------------------------------------------
def _spmm_blocked(rows_b, cols_b, vals_b, x, dpc):
    """y = A @ x via the block-layout kernel: per-destination-block tiles
    with block-local row offsets (no global one-hot gathers)."""
    from repro.kernels.ops import spmm_block
    return spmm_block(rows_b, cols_b, vals_b, x, dpc)


def _spmm_t_blocked(rows_b, cols_b, vals_b, e, n_src):
    """y = Aᵀ @ e walking the SAME tiles column-major: tile b's error rows
    are the contiguous slab e[b·dpc : (b+1)·dpc] — the Graph Converter's
    backward order, no Aᵀ table and no transposed error copy.  Block-local
    offsets are globalized with a trace-time iota and all tiles scatter
    through ONE segment-sum (a vmapped per-tile segment-sum lowers to a
    serialized scatter loop on CPU)."""
    n_blocks = rows_b.shape[0]
    dpc = e.shape[0] // n_blocks
    rows_g = (rows_b
              + (jnp.arange(n_blocks, dtype=rows_b.dtype) * dpc)[:, None])
    gathered = e[rows_g.reshape(-1)] * vals_b.reshape(-1)[:, None]
    return jax.ops.segment_sum(gathered, cols_b.reshape(-1),
                               num_segments=n_src)


@partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2, 3))
def _gcn_layer_block(dpc: int, n_src: int, order: Order, activate: bool,
                     rows_b, cols_b, vals_b, x, w):
    if order == "coag":
        z = _spmm_blocked(rows_b, cols_b, vals_b, x @ w, dpc)
    elif order == "agco":
        z = _spmm_blocked(rows_b, cols_b, vals_b, x, dpc) @ w
    else:
        raise ValueError(order)
    return jnp.maximum(z, 0.0) if activate else z


def _gcn_layer_block_fwd(dpc, n_src, order, activate, rows_b, cols_b,
                         vals_b, x, w):
    if order == "coag":
        z = _spmm_blocked(rows_b, cols_b, vals_b, x @ w, dpc)
        saved_feat = x
    else:
        ax = _spmm_blocked(rows_b, cols_b, vals_b, x, dpc)
        z = ax @ w
        saved_feat = ax
    y = jnp.maximum(z, 0.0) if activate else z
    mask = (z > 0) if activate else None
    return y, (rows_b, cols_b, vals_b, saved_feat, w, mask)


def _gcn_layer_block_bwd(dpc, n_src, order, activate, res, ct):
    rows_b, cols_b, vals_b, saved_feat, w, mask = res
    dz = jnp.where(mask, ct, 0.0) if activate else ct
    if order == "coag":
        s = _spmm_t_blocked(rows_b, cols_b, vals_b, dz, n_src)
        dx = jnp.einsum("nh,dh->nd", s, w)
        dw = jnp.einsum("nd,nh->dh", saved_feat, s)
    else:
        dw = jnp.einsum("nd,nh->dh", saved_feat, dz)
        dax = jnp.einsum("nh,dh->nd", dz, w)
        dx = _spmm_t_blocked(rows_b, cols_b, vals_b, dax, n_src)
    return (*zero_ct((rows_b, cols_b, vals_b)), dx, dw)


_gcn_layer_block.defvjp(_gcn_layer_block_fwd, _gcn_layer_block_bwd)


def _layer_blocked_impl(tiles, x: jnp.ndarray, w: jnp.ndarray, *,
                        order: Order = "coag", activate: bool = True
                        ) -> jnp.ndarray:
    """GCN layer whose aggregation consumes Block-Message tiles directly.

    ``tiles`` is :func:`repro.core.blockmsg.dst_tiles` output (receiver-side
    layout: block-local rows, global cols).  Forward runs the block-layout
    Pallas SpMM (:func:`repro.kernels.ops.spmm_block`); backward walks the
    same tiles column-major — transpose-free, like :func:`gcn_layer`, but
    with per-block row offsets instead of global one-hot gathers.  The
    registered ``"block"`` format (:mod:`repro.engine.formats`) is the
    supported way in.
    """
    if x.shape[0] < int(np.max(tiles.cols)) + 1:
        raise ValueError(f"x rows {x.shape[0]} too few for tile col ids")
    rows_b = jnp.asarray(tiles.rows, jnp.int32)
    cols_b = jnp.asarray(tiles.cols, jnp.int32)
    vals_b = jnp.asarray(tiles.vals, jnp.float32)
    return _gcn_layer_block(int(tiles.dst_per_core), int(x.shape[0]),
                            order, activate, rows_b, cols_b, vals_b, x, w)


# ---------------------------------------------------------------------------
# Pre-reduced ELL variant: aggregation through the EdgePlan engine.
# ---------------------------------------------------------------------------
def _layer_ell_impl(plan, x: jnp.ndarray, w: jnp.ndarray, *,
                    order: Order = "coag", activate: bool = True
                    ) -> jnp.ndarray:
    """GCN layer whose aggregation runs the pre-reduced ELL engine.

    ``plan`` is :func:`repro.kernels.edgeplan.build_plan` output (built once
    per graph, cached).  Aggregation — forward AND backward — goes through
    :func:`repro.kernels.ops.ell_aggregate`: the backward walks the plan's
    column-major tables with the same scatter-free kernel, so this layer
    inherits the transpose-free backward from the ops wrapper instead of
    re-registering its own vjp.  The registered ``"ell"`` format
    (:mod:`repro.engine.formats`) is the supported way in.
    """
    from repro.kernels.ops import ell_aggregate

    if x.shape[0] != plan.n_src:
        raise ValueError(f"x rows {x.shape[0]} != plan.n_src {plan.n_src}")
    tables = plan.device_tables()
    if order == "coag":
        z = ell_aggregate(tables, x @ w)
    elif order == "agco":
        z = ell_aggregate(tables, x) @ w
    else:
        raise ValueError(order)
    return jnp.maximum(z, 0.0) if activate else z


# ---------------------------------------------------------------------------
# Deprecated flag-era entry points (kept as warning shims for one cycle).
# ---------------------------------------------------------------------------
def gcn_layer_blocked(tiles, x: jnp.ndarray, w: jnp.ndarray, *,
                      order: Order = "coag", activate: bool = True
                      ) -> jnp.ndarray:
    """Deprecated shim — the block-tile layer now lives behind the Engine:
    ``Engine("block+pipelined").layer(coo, x, w)`` (layout built and cached
    for you), or ``get_format("block").layer(tiles, ...)`` with prebuilt
    tiles."""
    from repro.engine import get_format

    _warn_shim("gcn_layer_blocked",
               'repro.engine.Engine("block+pipelined").layer(coo, x, w)')
    return get_format("block").layer(tiles, x, w, order=order,
                                     activate=activate)


def gcn_layer_ell(plan, x: jnp.ndarray, w: jnp.ndarray, *,
                  order: Order = "coag", activate: bool = True
                  ) -> jnp.ndarray:
    """Deprecated shim — the pre-reduced ELL layer now lives behind the
    Engine: ``Engine("ell+pipelined").layer(coo, x, w)`` (plan built and
    cached for you), or ``get_format("ell").layer(plan, ...)`` with a
    prebuilt plan."""
    from repro.engine import get_format

    _warn_shim("gcn_layer_ell",
               'repro.engine.Engine("ell+pipelined").layer(coo, x, w)')
    return get_format("ell").layer(plan, x, w, order=order,
                                   activate=activate)


def residual_bytes(order: Order, n_dst: int, n_src: int, d: int, h: int,
                   dtype_bytes: int = 4) -> int:
    """Storage the 'Ours' dataflow saves for backward (per layer): the
    untransposed feature operand + 1-bit mask.  Used by tests/benchmarks to
    compare against the baseline's transposed duplicates."""
    feat = n_src * d if order == "coag" else n_dst * d
    mask_bits = n_dst * h
    return feat * dtype_bytes + mask_bits // 8
