"""Sequence estimator (paper §4.4, Table 1) — choose AgCo vs CoAg per layer.

In mini-batch training the layer adjacency A ∈ R^{n × n̄} is rectangular
(n = destination nodes of this hop, n̄ = sampled frontier), so aggregating
first can *shrink* the feature matrix exactly like combining first — the
optimal order depends on the dataset and the sampling hyper-parameters.
The system controller evaluates the full-training-step complexity of both
orders before launching and configures the pipeline accordingly.

Complexities follow Table 1 exactly (per layer, per mini-batch):

                 forward            backward           gradient     transpose
  Ours CoAg   n̄dh + eh          eh + n̄dh           n̄dh          hd (+bc once)
  Ours AgCo   ed  + ndh          ndh + ed            ndh          hd (+bc once)

with storage  CoAg: n̄d + n̄h + e | n̄h + nh   /  AgCo: n̄d + nd + e | nd + nh.
(The naive variants add the Table-1 transpose rows; kept here for the
benchmark that reproduces the Table-1/Eq.5-8 comparison.)
"""
from __future__ import annotations

import dataclasses
from typing import Literal, Tuple

Order = Literal["coag", "agco"]


@dataclasses.dataclass(frozen=True)
class LayerShape:
    """Static per-layer quantities the estimator reads from the batch plan.

    b:     mini-batch size (seed nodes; only used for the one-off E^L transpose)
    n:     destination nodes of this hop  (rows of A)
    nbar:  source nodes / sampled frontier (cols of A)
    d:     input feature dim
    h:     output feature dim
    e:     nnz of A
    c:     classes (loss width; top layer only)
    """

    b: int
    n: int
    nbar: int
    d: int
    h: int
    e: int
    c: int = 0


@dataclasses.dataclass(frozen=True)
class CostEstimate:
    order: Order
    time: float
    storage: float


def time_ours(s: LayerShape, order: Order) -> float:
    if order == "coag":
        fwd = s.nbar * s.d * s.h + s.e * s.h
        bwd = s.e * s.h + s.nbar * s.d * s.h
        grad = s.nbar * s.d * s.h
    else:
        fwd = s.e * s.d + s.n * s.d * s.h
        bwd = s.n * s.d * s.h + s.e * s.d
        grad = s.n * s.d * s.h
    transpose = s.h * s.d + s.b * s.c      # Wᵀ + (E^L)ᵀ (loss layer only)
    return float(fwd + bwd + grad + transpose)


def time_naive(s: LayerShape, order: Order) -> float:
    """Table-1 CoAg/AgCo rows (baseline dataflow with big transposes)."""
    base = time_ours(s, order) - (s.h * s.d + s.b * s.c)
    if order == "coag":
        transpose = s.nbar * s.e + s.h * s.d + s.nbar * s.d   # Aᵀ, Wᵀ, Xᵀ
    else:
        transpose = s.nbar * s.e + s.h * s.d + s.n * s.d      # Aᵀ, Wᵀ, (AX)ᵀ
    return float(base + transpose)


def storage_ours(s: LayerShape, order: Order) -> float:
    if order == "coag":
        return float(s.nbar * s.d + s.nbar * s.h + s.e + s.nbar * s.h + s.n * s.h)
    return float(s.nbar * s.d + s.n * s.d + s.e + s.n * s.d + s.n * s.h)


def storage_naive(s: LayerShape, order: Order) -> float:
    extra = s.e + (s.nbar * s.d if order == "coag" else s.n * s.d)
    return storage_ours(s, order) + float(extra)


def choose_order(s: LayerShape, dataflow: str = "ours") -> CostEstimate:
    """The estimator: evaluate both orders, return the cheaper (time first,
    storage as tie-break) — run once per (dataset, sampler, model) config at
    launch, like the paper's register-configured system controller."""
    tfn = time_ours if dataflow == "ours" else time_naive
    sfn = storage_ours if dataflow == "ours" else storage_naive
    cands = [CostEstimate(o, tfn(s, o), sfn(s, o)) for o in ("coag", "agco")]
    cands.sort(key=lambda ce: (ce.time, ce.storage))
    return cands[0]


def layer_shapes_for_batch(batch_size: int, fanouts, feat_dim: int,
                           hidden: int, n_classes: int, avg_degree: float
                           ) -> Tuple[LayerShape, ...]:
    """Build the per-layer LayerShape plan for a sampled mini-batch, using
    expected frontier sizes (what the controller knows before sampling)."""
    dims = []
    n = batch_size
    hops = [batch_size]
    for f in fanouts:
        n = int(n * (min(f, avg_degree) + 1))
        hops.append(n)
    # layer l aggregates hop l+1 -> hop l ; features flow top(input)->bottom
    shapes = []
    in_dim = feat_dim
    for l in range(len(fanouts) - 1, -1, -1):
        out_dim = n_classes if l == 0 else hidden
        e = int(hops[l] * (min(fanouts[l], avg_degree) + 1))
        shapes.append(LayerShape(b=batch_size, n=hops[l], nbar=hops[l + 1],
                                 d=in_dim, h=out_dim, e=e, c=n_classes))
        in_dim = out_dim
    return tuple(reversed(shapes))  # index by layer depth (0 = closest to output)
