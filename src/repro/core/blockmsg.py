"""Block-Message compression + staged multicast waves (paper §4.3.3, Fig. 6/7).

The accelerator never ships raw edges over the on-chip network.  Per 64×64
adjacency block it builds **Block Messages**:

  * address decode (Fig. 7): for a P·t-node subgraph, the high log₂P bits of
    a node id are the core id, the low bits the slot in that core's buffer —
    column index → (C = source core, D = neighbor slot), row index →
    (A = destination core, B = aggregate slot).
  * all edges of a block share (A, C); edges with the same aggregate slot B
    are **merged locally at the sender** (the Reduced Register File): the
    sender pre-reduces the features of all its neighbors of B and sends ONE
    message ``(B, Σ features)``.  A block therefore compresses from ``nnz``
    edges to ``N = |unique B|`` messages — the paper's ``A+C+N`` expression.

This module computes, per (stage, group), the message waves that
:mod:`repro.core.routing` routes and :mod:`repro.distributed.aggregate`
executes, plus the compression statistics behind the 2.96 TB/s §5.2 claim.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.graph.partition import BlockedCOO, anti_diagonal_stages


@dataclasses.dataclass(frozen=True)
class BlockMessage:
    """One compressed block: neighbors of ``n_msgs`` aggregate slots travel
    from ``src_core`` to ``dst_core`` (the paper's ``A + C + N``)."""

    dst_core: int           # A
    src_core: int           # C
    n_msgs: int             # N  = unique aggregate slots in the block
    nnz: int                # raw edges the N messages replace
    agg_slots: np.ndarray   # [N] int32 — the B values (sorted)
    # per-message pre-reduction plan: neighbors (D slots) merged per B
    seg_ids: np.ndarray     # [nnz] int32 — message index of each edge
    nbr_slots: np.ndarray   # [nnz] int32 — D values, seg-sorted
    weights: np.ndarray     # [nnz] float32 — Ã values, seg-sorted

    @property
    def compression(self) -> float:
        return self.nnz / max(self.n_msgs, 1)


def compress_block(local_rows: np.ndarray, local_cols: np.ndarray,
                   vals: np.ndarray, dst_core: int, src_core: int
                   ) -> BlockMessage:
    """Index Compressor: COO block → Block Message (Fig. 7).

    Edges are sorted by aggregate slot (B); each unique B becomes one wire
    message whose payload is the pre-reduced Σ w·x over its D slots.
    """
    order = np.argsort(local_rows, kind="stable")
    r = np.asarray(local_rows, np.int32)[order]
    c = np.asarray(local_cols, np.int32)[order]
    v = np.asarray(vals, np.float32)[order]
    uniq, seg = np.unique(r, return_inverse=True)
    return BlockMessage(
        dst_core=int(dst_core), src_core=int(src_core),
        n_msgs=int(len(uniq)), nnz=int(len(r)),
        agg_slots=uniq.astype(np.int32),
        seg_ids=seg.astype(np.int32), nbr_slots=c, weights=v,
    )


@dataclasses.dataclass(frozen=True)
class Wave:
    """One multicast wave = up to ``groups × P`` block messages whose
    (src, dst) vectors feed Algorithm 1 directly."""

    stage: int
    src: np.ndarray          # [m] core ids
    dst: np.ndarray          # [m] core ids
    messages: Tuple[BlockMessage, ...]

    @property
    def total_msgs(self) -> int:
        return int(sum(m.n_msgs for m in self.messages))

    @property
    def total_nnz(self) -> int:
        return int(sum(m.nnz for m in self.messages))


def build_waves(blocked: BlockedCOO, group_size: int = 4) -> List[Wave]:
    """Stage the P×P block grid into anti-diagonal waves (Fig. 6(a)).

    Each stage bundles ``group_size`` anti-diagonals; within a group every
    (dst, src) pair is unique and every core appears once as sender and once
    as receiver, so a stage is exactly one Algorithm-1 wave of ≤ 4×16
    messages with ≤4 per sender — the deadlock-free start condition of the
    Message Start Point Generator.
    """
    P = blocked.n_cores
    waves: List[Wave] = []
    for s, groups in enumerate(anti_diagonal_stages(P, group_size)):
        src, dst, msgs = [], [], []
        for group in groups:
            for (i, j) in group:
                if i == j:
                    continue  # local block: aggregated in-core, never routed
                edges = blocked.block_edges.get((i, j))
                if edges is None:
                    continue  # empty block: nothing to send
                bm = compress_block(edges[0], edges[1], edges[2],
                                    dst_core=i, src_core=j)
                msgs.append(bm)
                src.append(j)
                dst.append(i)
        if msgs:
            waves.append(Wave(stage=s, src=np.asarray(src, np.int64),
                              dst=np.asarray(dst, np.int64),
                              messages=tuple(msgs)))
    return waves


def wave_statistics(waves: Sequence[Wave]) -> Dict[str, float]:
    """Compression + traffic statistics for EXPERIMENTS/§5.2."""
    nnz = sum(w.total_nnz for w in waves)
    msgs = sum(w.total_msgs for w in waves)
    blocks = sum(len(w.messages) for w in waves)
    return {
        "waves": float(len(waves)),
        "blocks": float(blocks),
        "raw_edges": float(nnz),
        "wire_messages": float(msgs),
        "compression": nnz / max(msgs, 1.0),
    }
