"""Block-Message compression + staged multicast waves (paper §4.3.3, Fig. 6/7).

The accelerator never ships raw edges over the on-chip network.  Per 64×64
adjacency block it builds **Block Messages**:

  * address decode (Fig. 7): for a P·t-node subgraph, the high log₂P bits of
    a node id are the core id, the low bits the slot in that core's buffer —
    column index → (C = source core, D = neighbor slot), row index →
    (A = destination core, B = aggregate slot).
  * all edges of a block share (A, C); edges with the same aggregate slot B
    are **merged locally at the sender** (the Reduced Register File): the
    sender pre-reduces the features of all its neighbors of B and sends ONE
    message ``(B, Σ features)``.  A block therefore compresses from ``nnz``
    edges to ``N = |unique B|`` messages — the paper's ``A+C+N`` expression.

This module computes, per (stage, group), the message waves that
:mod:`repro.core.routing` routes and :mod:`repro.distributed.aggregate`
executes, plus the compression statistics behind the 2.96 TB/s §5.2 claim.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.graph.partition import BlockedCOO, anti_diagonal_stages


@dataclasses.dataclass(frozen=True)
class BlockMessage:
    """One compressed block: neighbors of ``n_msgs`` aggregate slots travel
    from ``src_core`` to ``dst_core`` (the paper's ``A + C + N``)."""

    dst_core: int           # A
    src_core: int           # C
    n_msgs: int             # N  = unique aggregate slots in the block
    nnz: int                # raw edges the N messages replace
    agg_slots: np.ndarray   # [N] int32 — the B values (sorted)
    # per-message pre-reduction plan: neighbors (D slots) merged per B
    seg_ids: np.ndarray     # [nnz] int32 — message index of each edge
    nbr_slots: np.ndarray   # [nnz] int32 — D values, seg-sorted
    weights: np.ndarray     # [nnz] float32 — Ã values, seg-sorted

    @property
    def compression(self) -> float:
        return self.nnz / max(self.n_msgs, 1)


def compress_block(local_rows: np.ndarray, local_cols: np.ndarray,
                   vals: np.ndarray, dst_core: int, src_core: int
                   ) -> BlockMessage:
    """Index Compressor: COO block → Block Message (Fig. 7).

    Edges are sorted by aggregate slot (B); each unique B becomes one wire
    message whose payload is the pre-reduced Σ w·x over its D slots.
    """
    order = np.argsort(local_rows, kind="stable")
    r = np.asarray(local_rows, np.int32)[order]
    c = np.asarray(local_cols, np.int32)[order]
    v = np.asarray(vals, np.float32)[order]
    uniq, seg = np.unique(r, return_inverse=True)
    return BlockMessage(
        dst_core=int(dst_core), src_core=int(src_core),
        n_msgs=int(len(uniq)), nnz=int(len(r)),
        agg_slots=uniq.astype(np.int32),
        seg_ids=seg.astype(np.int32), nbr_slots=c, weights=v,
    )


def message_rowlists(bm: BlockMessage):
    """Iterate one Block Message's merge plan: ``(B, D_slots, weights)`` per
    wire message — the neighbors the Reduced Register File pre-reduces into
    a single payload.  ``seg_ids`` is seg-sorted, so each message's edges
    are one contiguous slice."""
    bounds = np.flatnonzero(np.diff(bm.seg_ids)) + 1
    for b, d_slots, w in zip(bm.agg_slots, np.split(bm.nbr_slots, bounds),
                             np.split(bm.weights, bounds)):
        yield int(b), d_slots, w


def sender_merge_flat(blocked, src_core: int
                      ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """All of one sender's edges in pre-reduction order, global row ids.

    Runs the Index Compressor (:func:`compress_block`) on every block of
    column ``src_core`` and concatenates the merge-ordered edges with rows
    lifted to the global partial-row space (``dst_core·dpc + B``) and cols
    kept sender-local (the D slots).  This is the flat input
    :mod:`repro.kernels.edgeplan` bucketizes into the sender's ELL tables.
    """
    from repro.graph.partition import sender_blocks
    from repro.kernels.edgeplan import flat_from_compressed

    dpc = blocked.dst_per_core
    parts = [flat_from_compressed(
        compress_block(lr, lc, v, dst_core=i, src_core=src_core),
        row_offset=i * dpc)
        for i, (lr, lc, v) in sender_blocks(blocked, src_core)]
    if not parts:
        z = np.zeros(0, np.int64)
        return z, z.copy(), np.zeros(0, np.float32)
    return tuple(np.concatenate(a) for a in zip(*parts))


@dataclasses.dataclass(frozen=True)
class BlockTiles:
    """Dense padded per-destination-block COO tiles of ONE sender core.

    This is the Block-Message layout in array form: tile *i* holds the edges
    whose destinations live on core *i* (block (i, src_core) of the grid),
    with **block-local row offsets** (the B values of Fig. 7) — exactly what
    the block-layout SpMM kernel consumes, so aggregation never rebuilds a
    global one-hot over ``n_dst`` rows.  Padding entries carry ``val == 0``.
    """

    rows: np.ndarray        # [B, eb] int32 — dst slot WITHIN the dst block
    cols: np.ndarray        # [B, eb] int32 — local src slot (D values)
    vals: np.ndarray        # [B, eb] float32 (0 = padding)
    dst_per_core: int
    src_per_core: int

    @property
    def n_blocks(self) -> int:
        return int(self.rows.shape[0])

    @property
    def e_per_block(self) -> int:
        return int(self.rows.shape[1])


def _pack_tiles(stripes, eb_max: Optional[int], dpc: int, spc: int,
                what: str) -> BlockTiles:
    """Pad per-tile (rows, cols, vals) triples (None = empty) to a common
    static length — the one packing loop both tile layouts share."""
    if eb_max is None:
        eb_max = max((len(t[0]) for t in stripes if t is not None),
                     default=1)
        eb_max = max(int(eb_max), 1)
    n = len(stripes)
    rows = np.zeros((n, eb_max), np.int32)
    cols = np.zeros((n, eb_max), np.int32)
    vals = np.zeros((n, eb_max), np.float32)
    for i, t in enumerate(stripes):
        if t is None:
            continue
        lr, lc, v = t
        if len(lr) > eb_max:
            raise ValueError(
                f"{what} {i} has {len(lr)} edges > eb_max={eb_max}")
        rows[i, :len(lr)] = lr
        cols[i, :len(lc)] = lc
        vals[i, :len(v)] = v
    return BlockTiles(rows=rows, cols=cols, vals=vals,
                      dst_per_core=dpc, src_per_core=spc)


def block_tiles(blocked: BlockedCOO, src_core: int,
                eb_max: Optional[int] = None) -> BlockTiles:
    """Column ``src_core`` of the block grid as dense padded tiles.

    Edges keep :func:`repro.graph.partition.block_partition`'s (row, col)
    sort order inside every tile, so per-tile segment sums add in the same
    per-element order as a flat global segment sum — the blocked and flat
    aggregation paths stay bit-identical in fp32.
    """
    P = blocked.n_cores
    per_block = [blocked.block_edges.get((i, src_core)) for i in range(P)]
    return _pack_tiles(per_block, eb_max, blocked.dst_per_core,
                       blocked.src_per_core, f"block (·, {src_core}): tile")


def dst_tiles(blocked: BlockedCOO, eb_max: Optional[int] = None
              ) -> BlockTiles:
    """Receiver-side tiles for the single-device block-layout SpMM.

    Tile *i* holds ALL edges whose destinations live in row-stripe *i* of
    the block grid — block-local row offsets, GLOBAL column ids (the dense
    feature matrix is one address space on a single device).  This is the
    layout the ``block`` engine format feeds the kernel; the
    distributed path uses the sender-side :func:`block_tiles` instead.
    """
    P = blocked.n_cores
    spc = blocked.src_per_core
    by_stripe: List[list] = [[] for _ in range(P)]
    for (bi, j), (lr, lc, v) in sorted(blocked.block_edges.items()):
        by_stripe[bi].append((lr, lc.astype(np.int64) + j * spc, v))
    stripes = [tuple(np.concatenate(a) for a in zip(*parts)) if parts
               else None for parts in by_stripe]
    return _pack_tiles(stripes, eb_max, blocked.dst_per_core, spc, "stripe")


@dataclasses.dataclass(frozen=True)
class Wave:
    """One multicast wave = up to ``groups × P`` block messages whose
    (src, dst) vectors feed Algorithm 1 directly."""

    stage: int
    src: np.ndarray          # [m] core ids
    dst: np.ndarray          # [m] core ids
    messages: Tuple[BlockMessage, ...]

    @property
    def total_msgs(self) -> int:
        return int(sum(m.n_msgs for m in self.messages))

    @property
    def total_nnz(self) -> int:
        return int(sum(m.nnz for m in self.messages))


def build_waves(blocked: BlockedCOO, group_size: int = 4) -> List[Wave]:
    """Stage the P×P block grid into anti-diagonal waves (Fig. 6(a)).

    Each stage bundles ``group_size`` anti-diagonals; within a group every
    (dst, src) pair is unique and every core appears once as sender and once
    as receiver, so a stage is exactly one Algorithm-1 wave of ≤ 4×16
    messages with ≤4 per sender — the deadlock-free start condition of the
    Message Start Point Generator.
    """
    P = blocked.n_cores
    waves: List[Wave] = []
    for s, groups in enumerate(anti_diagonal_stages(P, group_size)):
        src, dst, msgs = [], [], []
        for group in groups:
            for (i, j) in group:
                if i == j:
                    continue  # local block: aggregated in-core, never routed
                edges = blocked.block_edges.get((i, j))
                if edges is None:
                    continue  # empty block: nothing to send
                bm = compress_block(edges[0], edges[1], edges[2],
                                    dst_core=i, src_core=j)
                msgs.append(bm)
                src.append(j)
                dst.append(i)
        if msgs:
            waves.append(Wave(stage=s, src=np.asarray(src, np.int64),
                              dst=np.asarray(dst, np.int64),
                              messages=tuple(msgs)))
    return waves


def wave_statistics(waves: Sequence[Wave]) -> Dict[str, float]:
    """Compression + traffic statistics for EXPERIMENTS/§5.2."""
    nnz = sum(w.total_nnz for w in waves)
    msgs = sum(w.total_msgs for w in waves)
    blocks = sum(len(w.messages) for w in waves)
    return {
        "waves": float(len(waves)),
        "blocks": float(blocks),
        "raw_edges": float(nnz),
        "wire_messages": float(msgs),
        "compression": nnz / max(msgs, 1.0),
    }
