"""Algorithm 1 — Parallel Multicast Routing on the 4-D hypercube (paper §4.3).

Faithful, cycle-stepped reimplementation of the Router-St control plane:

  * **XOR Array** (Alg. 1 line 1): for every in-flight message the set of
    single-step next hops toward its destination is the set of nodes obtained
    by flipping one differing bit of ``cur XOR dst``; the step length is the
    popcount (= remaining shortest-path cycles).
  * **Sorter** (line 3): messages are scheduled shortest-step-first — they
    free channels earliest; long-step messages have more alternative paths
    and can afford to wait.
  * **Routing Set Filter** (line 4, Constraint 1): a core has one input port
    per dimension, so it can accept at most ``ndim`` (=4) messages per cycle.
    Candidate targets that appear too often across the path sets are pruned,
    removing from the *richest* path sets first (dynamic priority).
  * **Routing Table Filler** (lines 8-9): pick one next hop at random from
    the filtered set (the paper's ``Rand_sel``).
  * **Routing Set Remover** (line 10, Constraint 2): a receiver never takes
    two messages from the same sender in one cycle (one physical line per
    direction per dimension) — after a fill, conflicting candidates are
    removed from the remaining path sets.
  * **Virtual channels**: a message whose path set was emptied by the
    filter/remover is marked ``x`` and stalls one cycle (buffered in the
    virtual channel), re-entering the race next cycle.

The same machine serves two roles in this repo:

  1. *Simulator* — reproduces the paper's Fig. 9 (Fuse1..Fuse4 cycle counts)
     and the 2.96 TB/s aggregate-bandwidth derivation (§5.2).
  2. *Static schedule generator* — :func:`route_messages` emits per-cycle
     (sender → receiver) assignments that
     :mod:`repro.distributed.aggregate` lowers onto TPU ICI as
     ``shard_map``/``ppermute`` rounds.

Everything here is trace-time / host-side numpy — the FPGA spends LUTs on
this, we spend microseconds of Python before the step function is jitted.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

# Sentinels in the routing table.
STALL = -1   # 'x' — parked in a virtual channel this cycle
DONE = -2    # message already delivered


def popcount(x: np.ndarray) -> np.ndarray:
    """Vectorized popcount for small non-negative ints."""
    x = np.asarray(x)
    out = np.zeros_like(x)
    v = x.copy()
    while np.any(v):
        out += v & 1
        v >>= 1
    return out


def xor_path_set(cur: int, dst: int, ndim: int) -> List[int]:
    """Single-step path set of a message at ``cur`` heading to ``dst``.

    One candidate per differing bit: flip that bit of ``cur``.  (Paper
    Fig. 8(b): negate the bit positions where the XOR result is 1.)
    """
    diff = cur ^ dst
    return [cur ^ (1 << b) for b in range(ndim) if (diff >> b) & 1]


@dataclasses.dataclass(frozen=True)
class RoutingResult:
    """Output of Algorithm 1.

    table: [cycles, p] int — next hop chosen for message i at each cycle
           (STALL = virtual channel, DONE = already arrived).
    positions: [cycles + 1, p] int — node of each message before each cycle.
    cycles: total cycles until the last message arrived.
    per_message_cycles: arrival cycle of each message (1-based).
    """

    table: np.ndarray
    positions: np.ndarray
    cycles: int
    per_message_cycles: np.ndarray

    @property
    def n_messages(self) -> int:
        return int(self.table.shape[1])


def _set_filter(path_sets: List[List[int]], active: np.ndarray,
                max_receive: int, rng: np.random.Generator) -> None:
    """Constraint 1 (Routing Set Filter), in place.

    Any candidate target appearing more than ``max_receive`` times across the
    active path sets is pruned until it fits; pruning removes from the
    path sets with the most alternatives first and never empties a set unless
    every holder is down to its last alternative (those fall through to the
    virtual channel).  The priority queue is re-evaluated after each removal
    (the paper calls this a dynamic process).
    """
    while True:
        counts: Dict[int, List[int]] = {}
        for i in np.flatnonzero(active):
            for t in path_sets[i]:
                counts.setdefault(t, []).append(i)
        over = {t: holders for t, holders in counts.items()
                if len(holders) > max_receive}
        if not over:
            return
        # prune the most-overloaded target first
        target = max(over, key=lambda t: len(over[t]))
        holders = over[target]
        # remove from the richest path set; tie-break randomly (Rand_sel spirit)
        sizes = np.array([len(path_sets[i]) for i in holders])
        rich = np.flatnonzero(sizes == sizes.max())
        victim = holders[int(rng.choice(rich))]
        if sizes.max() <= 1:
            # every holder is at its last alternative: drop from a random one —
            # it will stall in a virtual channel this cycle (paper's 'x').
            victim = holders[int(rng.integers(len(holders)))]
        path_sets[victim].remove(target)


def route_messages(src: Sequence[int], dst: Sequence[int], *, ndim: int = 4,
                   seed: int = 0, max_cycles: int = 256) -> RoutingResult:
    """Run Algorithm 1 on one wave of messages.

    ``src``/``dst`` are core ids in ``[0, 2**ndim)``; entry ``i`` is one
    message (the paper's 4 groups × 16 starting-point vector is simply a
    ``p = 64`` wave).  Returns the full routing table.
    """
    src = np.asarray(src, np.int64)
    dst = np.asarray(dst, np.int64)
    if src.shape != dst.shape:
        raise ValueError("src/dst length mismatch")
    p = len(src)
    n_nodes = 1 << ndim
    if np.any((src < 0) | (src >= n_nodes) | (dst < 0) | (dst >= n_nodes)):
        raise ValueError(f"core ids must be in [0, {n_nodes})")
    rng = np.random.default_rng(seed)

    cur = src.copy()
    arrived = cur == dst
    per_message_cycles = np.zeros(p, np.int64)
    table_rows: List[np.ndarray] = []
    position_rows: List[np.ndarray] = [cur.copy()]

    cycle = 0
    while not np.all(arrived):
        cycle += 1
        if cycle > max_cycles:
            raise RuntimeError("routing did not converge (deadlock?)")
        active = ~arrived
        # --- XOR Array: path sets + step lengths (Alg. 1 line 1 / line 17)
        path_sets: List[List[int]] = [
            xor_path_set(int(cur[i]), int(dst[i]), ndim) if active[i] else []
            for i in range(p)
        ]
        steps = np.where(active, popcount(cur ^ dst), 0)
        # --- Routing Set Filter (Constraint 1)
        _set_filter(path_sets, active, max_receive=ndim, rng=rng)
        # --- Sorter: shortest step first; stable so group order breaks ties
        order = np.argsort(steps[active], kind="stable")
        act_idx = np.flatnonzero(active)[order]

        row = np.full(p, DONE, np.int64)
        recv_count: Dict[int, int] = {}          # Constraint 1 at fill time
        used_channel: set = set()                # (sender, receiver) pairs
        for i in act_idx:
            cands = [t for t in path_sets[i]
                     if recv_count.get(t, 0) < ndim
                     and (int(cur[i]), t) not in used_channel]
            if not cands:
                row[i] = STALL                   # 'x' → virtual channel
                continue
            # Routing Table Filler: random pick among survivors
            t = int(cands[int(rng.integers(len(cands)))])
            row[i] = t
            recv_count[t] = recv_count.get(t, 0) + 1
            used_channel.add((int(cur[i]), t))
            # Routing Set Remover (Constraint 2): same-sender conflicts die
            for j in act_idx:
                if j != i and row[j] == DONE and cur[j] == cur[i]:
                    if t in path_sets[j]:
                        path_sets[j].remove(t)
        # --- commit moves
        moved = row >= 0
        cur = np.where(moved, row, cur)
        newly = moved & (cur == dst)
        per_message_cycles[newly] = cycle
        arrived |= newly
        table_rows.append(row)
        position_rows.append(cur.copy())

    return RoutingResult(
        table=np.stack(table_rows) if table_rows else np.zeros((0, p), np.int64),
        positions=np.stack(position_rows),
        cycles=cycle,
        per_message_cycles=per_message_cycles,
    )


def validate_routing(res: RoutingResult, src: Sequence[int],
                     dst: Sequence[int], ndim: int = 4) -> None:
    """Assert the hardware invariants of §4.3.2 over a routing table.

    * every hop is a hypercube edge (single bit flip),
    * Constraint 1: ≤ ``ndim`` receives per (cycle, core),
    * Constraint 2: ≤ 1 message per (cycle, sender, receiver) channel,
    * ≤ ``ndim`` sends per (cycle, core) (one output line per dimension),
    * every message ends at its destination.
    Raises AssertionError on violation (used by tests + hypothesis).
    """
    src = np.asarray(src, np.int64)
    dst = np.asarray(dst, np.int64)
    p = len(src)
    cur = src.copy()
    for c in range(res.cycles):
        row = res.table[c]
        recv: Dict[int, int] = {}
        send: Dict[int, int] = {}
        chan: set = set()
        for i in range(p):
            nxt = row[i]
            if nxt in (STALL, DONE):
                continue
            edge = int(cur[i]) ^ int(nxt)
            assert edge != 0 and (edge & (edge - 1)) == 0, \
                f"cycle {c}: msg {i} hop {cur[i]}→{nxt} is not a hypercube edge"
            key = (int(cur[i]), int(nxt))
            assert key not in chan, f"cycle {c}: channel {key} used twice"
            chan.add(key)
            recv[int(nxt)] = recv.get(int(nxt), 0) + 1
            send[int(cur[i])] = send.get(int(cur[i]), 0) + 1
            cur[i] = nxt
        for node, k in recv.items():
            assert k <= ndim, f"cycle {c}: node {node} received {k} > {ndim}"
        for node, k in send.items():
            assert k <= ndim, f"cycle {c}: node {node} sent {k} > {ndim}"
    assert np.all(cur == dst), "some messages did not arrive"


# ---------------------------------------------------------------------------
# Fig. 9 experiment harness — Fuse1..Fuse4 waves.
# ---------------------------------------------------------------------------
def make_fuse_wave(n_groups: int, rng: np.random.Generator, ndim: int = 4
                   ) -> Tuple[np.ndarray, np.ndarray]:
    """Build a FuseK wave like §5.2: each group's source vector is a random
    permutation of the 16 cores ("a random sequence from 0 to 15") and each
    column is sent to a distinct target (ascending destination ids — the
    Message Start Point Generator sorts Block Messages by destination core).
    """
    n = 1 << ndim
    srcs, dsts = [], []
    for _ in range(n_groups):
        srcs.append(rng.permutation(n))
        dsts.append(np.arange(n))
    return np.concatenate(srcs), np.concatenate(dsts)


def fuse_experiment(n_groups: int, n_trials: int = 1000, seed: int = 0,
                    ndim: int = 4) -> Dict[str, float]:
    """Reproduce one Fig. 9 series: average / max receiving cycle over random
    waves for ``FuseK = K×16`` messages."""
    rng = np.random.default_rng(seed)
    cycles = np.zeros(n_trials, np.int64)
    for t in range(n_trials):
        src, dst = make_fuse_wave(n_groups, rng, ndim)
        res = route_messages(src, dst, ndim=ndim, seed=seed * 7919 + t)
        cycles[t] = res.cycles
    return {
        "fuse": float(n_groups),
        "messages": float(n_groups * (1 << ndim)),
        "avg_cycles": float(cycles.mean()),
        "p95_cycles": float(np.percentile(cycles, 95)),
        "max_cycles": float(cycles.max()),
    }


def aggregate_bandwidth_model(avg_period_ns: float, *, line_bytes: int = 64,
                              n_cores: int = 16, fan_in: int = 4,
                              compression: float = 16.0) -> Dict[str, float]:
    """§5.2's bandwidth arithmetic, parameterized.

    effective = line_bytes × fan_in × n_cores × compression / avg_period
    raw       = same without the local pre-reduction compression factor.
    With the paper's numbers (64 B, 16 cores, fan-in 4, 16× compression,
    20.13 ns average routed-wave period) this gives 2.96 TB/s wait — the
    paper counts 64 B × 4 × 16 × 16 / 20.13 ns = 3.26e12 … their printed
    value is 2.96 TB/s from measured average period; we expose the formula
    and let the benchmark feed the measured simulator period in.
    """
    eff = line_bytes * fan_in * n_cores * compression / (avg_period_ns * 1e-9)
    raw = line_bytes * fan_in * n_cores / (avg_period_ns * 1e-9)
    return {"effective_Bps": eff, "raw_Bps": raw}
