"""Naive training dataflow — the comparison baseline (Table 1 rows CoAg/AgCo).

This is the dataflow the paper improves on (and what a mechanical port of an
inference accelerator does for training): during the forward pass it
*precomputes and stores the transposed operands* that backward will need —
``Xᵀ`` (CoAg) or ``(AX)ᵀ`` (AgCo) — and it materializes an ``Aᵀ`` edge table
for backward aggregation.  Costs relative to "Ours" (paper Eqs. 5–8):

    time:    + O(n̄(e+d))   (CoAg)   /  + O(n̄e + nd)   (AgCo)
    storage: + O(e) + O(n̄d)         — one extra edge table + one transposed
                                       feature matrix resident in HBM

Functionally it computes identical gradients (tests assert allclose vs
:mod:`repro.core.gcn`), so the delta in residual bytes / HLO transposes /
edge tables is attributable purely to the dataflow redesign.
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.graph.coo import COO
from repro.cotangents import zero_ct
from .gcn import _spmm


@partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2, 3))
def gcn_layer_naive(n_dst: int, n_src: int, order: str, activate: bool,
                    rows, cols, vals, x, w):
    if order == "coag":
        z = _spmm(rows, cols, vals, x @ w, n_dst)
    else:
        z = _spmm(rows, cols, vals, x, n_dst) @ w
    return jnp.maximum(z, 0.0) if activate else z


def _fwd(n_dst, n_src, order, activate, rows, cols, vals, x, w):
    if order == "coag":
        z = _spmm(rows, cols, vals, x @ w, n_dst)
        feat_t = x.T                       # Table 1 CoAg: store Xᵀ  (O(n̄d))
    else:
        ax = _spmm(rows, cols, vals, x, n_dst)
        z = ax @ w
        feat_t = ax.T                      # Table 1 AgCo: store (AX)ᵀ (O(nd))
    # the FPGA baseline WRITES these to HBM during forward; stop XLA from
    # optimizing the materialization away, or the baseline wouldn't pay
    # its own costs (the transpose-copy + the second edge table)
    feat_t, t_rows, t_cols, t_vals = jax.lax.optimization_barrier(
        (feat_t, cols + 0, rows + 0, vals + 0.0))
    y = jnp.maximum(z, 0.0) if activate else z
    mask = (z > 0) if activate else None
    return y, (t_rows, t_cols, t_vals, feat_t, w, mask)


def _bwd(n_dst, n_src, order, activate, res, ct):
    t_rows, t_cols, t_vals, feat_t, w, mask = res
    dz = jnp.where(mask, ct, 0.0) if activate else ct
    wt = w.T + 0.0                          # materialized Wᵀ
    if order == "coag":
        s = _spmm(t_rows, t_cols, t_vals, dz, n_src)   # Aᵀ dz via Aᵀ table
        dx = s @ wt
        dw = feat_t @ s                                 # Xᵀ · S
    else:
        dw = feat_t @ dz                                # (AX)ᵀ · dz
        dax = dz @ wt
        dx = _spmm(t_rows, t_cols, t_vals, dax, n_src)
    return (*zero_ct((t_rows, t_cols, t_vals)), dx, dw)


gcn_layer_naive.defvjp(_fwd, _bwd)


def gcn_layer_baseline(A: COO, x, w, *, order: str = "coag",
                       activate: bool = True):
    """Public baseline layer (naive transposed-residual dataflow)."""
    return gcn_layer_naive(A.n_dst, A.n_src, order, activate,
                           A.rows, A.cols, A.vals, x, w)


def residual_bytes_naive(order: str, n_dst: int, n_src: int, d: int, h: int,
                         nnz: int, dtype_bytes: int = 4) -> int:
    """Residual bytes of the naive dataflow: transposed feature copy + extra
    Aᵀ edge table (2 int32 + 1 f32 per edge) + Wᵀ copy + mask."""
    feat_t = (n_src * d if order == "coag" else n_dst * d) * dtype_bytes
    edge_table = nnz * (4 + 4 + 4)
    w_t = d * h * dtype_bytes
    mask_bits = n_dst * h
    return feat_t + edge_table + w_t + mask_bits // 8
