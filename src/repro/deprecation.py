"""The one deprecation-warning helper for the Engine-migration shims.

Kept in a single module so the warning text, category, and stacklevel stay
in lockstep with the pytest ``filterwarnings`` gate (which matches on
"use the Engine API") — the shims in ``repro.core.gcn`` and
``repro.distributed.gcn_train`` both emit through here.
"""
from __future__ import annotations

import warnings


def warn_engine_shim(old: str, new: str) -> None:
    """Emit the standard shim warning, attributed to the shim's caller."""
    warnings.warn(f"{old} is deprecated; use the Engine API instead: {new}",
                  DeprecationWarning, stacklevel=3)
