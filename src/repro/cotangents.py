"""Zero cotangents for non-differentiable residuals, shared by every
custom_vjp in the repo.

Every aggregation backward returns "no gradient" for its edge-table
operands: integer index arrays legally take a ``float0`` cotangent (JAX's
unit type for non-differentiable integer inputs), float operands (the fixed
normalized adjacency weights) take ordinary zeros.  This module is the one
implementation — ``repro.core.gcn``, ``repro.kernels.ops`` and
``repro.distributed.aggregate`` all used to carry private copies.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def zero_ct(tree):
    """Zero cotangent for a pytree (or single array) of residual operands.

    Integer leaves (edge indices) map to ``float0`` zeros — the only valid
    cotangent dtype for integer primals — and float leaves (adjacency
    weights, which are fixed, not trained) map to ``zeros_like``.
    """
    return jax.tree_util.tree_map(
        lambda a: (np.zeros(np.shape(a), jax.dtypes.float0)
                   if jnp.issubdtype(jnp.asarray(a).dtype, jnp.integer)
                   else jnp.zeros_like(a)), tree)
