from .tokens import (TokenPipeline, lm_batch_specs, make_lm_batch,
                     synthetic_frames)
from .graph_pipeline import GraphBatchPipeline

__all__ = ["TokenPipeline", "lm_batch_specs", "make_lm_batch",
           "synthetic_frames", "GraphBatchPipeline"]
