from .tokens import (TokenPipeline, lm_batch_specs, make_lm_batch,
                     synthetic_frames)
from .graph_pipeline import (GraphBatchPipeline, Prefetcher,
                             StagedPrefetcher, assemble_batch,
                             gather_features, sample_batch)

__all__ = ["TokenPipeline", "lm_batch_specs", "make_lm_batch",
           "synthetic_frames", "GraphBatchPipeline", "Prefetcher",
           "StagedPrefetcher", "assemble_batch", "gather_features",
           "sample_batch"]
