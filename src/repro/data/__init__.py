from .tokens import (TokenPipeline, lm_batch_specs, make_lm_batch,
                     synthetic_frames)
from .graph_pipeline import GraphBatchPipeline, Prefetcher, assemble_batch

__all__ = ["TokenPipeline", "lm_batch_specs", "make_lm_batch",
           "synthetic_frames", "GraphBatchPipeline", "Prefetcher",
           "assemble_batch"]
