"""Synthetic token pipeline for the LM architectures.

No network access in this container, so training data is a deterministic
PRNG stream with Zipfian token marginals (real-vocab-like frequency skew so
embedding-gradient sparsity patterns are representative).  The pipeline is
steppable and restartable: ``state = (seed, step)`` checkpoints alongside
the model so restore resumes the exact stream position (fault-tolerance
contract tested in tests/test_checkpoint.py).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ArchConfig


def _zipf_tokens(rng: np.random.Generator, shape, vocab: int,
                 alpha: float = 1.1) -> np.ndarray:
    """Zipf-distributed token ids clipped to the vocab."""
    raw = rng.zipf(alpha, size=shape)
    return np.minimum(raw - 1, vocab - 1).astype(np.int32)


def make_lm_batch(seed: int, step: int, batch: int, seq: int, vocab: int,
                  *, enc_frames: int = 0, d_model: int = 0
                  ) -> Dict[str, np.ndarray]:
    """One deterministic batch: tokens + next-token labels (+frames stub)."""
    rng = np.random.default_rng(np.random.SeedSequence([seed, step]))
    toks = _zipf_tokens(rng, (batch, seq + 1), vocab)
    out: Dict[str, np.ndarray] = {
        "tokens": toks[:, :-1],
        "labels": toks[:, 1:].astype(np.int32),
    }
    if enc_frames:
        out["frames"] = rng.standard_normal(
            (batch, enc_frames, d_model)).astype(np.float32) * 0.02
    return out


def synthetic_frames(seed: int, batch: int, frames: int, d_model: int
                     ) -> np.ndarray:
    """Modality-frontend stub output (audio frames / vision patches)."""
    rng = np.random.default_rng(seed)
    return rng.standard_normal((batch, frames, d_model)).astype(np.float32) * 0.02


@dataclasses.dataclass
class TokenPipeline:
    """Restartable synthetic stream; ``state()``/``restore()`` give the
    checkpoint contract."""

    cfg: ArchConfig
    batch: int
    seq: int
    seed: int = 0
    step: int = 0
    enc_frames: int = 0

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        b = make_lm_batch(self.seed, self.step, self.batch, self.seq,
                          self.cfg.vocab, enc_frames=self.enc_frames,
                          d_model=self.cfg.d_model)
        self.step += 1
        return b

    def state(self) -> Dict[str, int]:
        return {"seed": self.seed, "step": self.step}

    def restore(self, state: Dict[str, int]) -> None:
        self.seed = int(state["seed"])
        self.step = int(state["step"])


def lm_batch_specs(cfg: ArchConfig, batch: int, seq: int,
                   *, enc_frames: int = 0
                   ) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for the dry-run (no allocation)."""
    specs = {
        "tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
        "labels": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
    }
    if enc_frames:
        specs["frames"] = jax.ShapeDtypeStruct(
            (batch, enc_frames, cfg.d_model), jnp.float32)
    return specs
