"""Graph mini-batch pipeline: sampler → static-shaped device batches.

Wraps :class:`repro.graph.NeighborSampler` into the same restartable-stream
contract as the token pipeline: the epoch permutation is derived from
``(seed, epoch)`` so restore-from-checkpoint replays the exact remaining
batches.  Shapes are padded to the per-layer static maxima so one jit trace
serves every batch (the paper's fixed 1024-node staging serves the same
purpose in BRAM).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional, Tuple

import numpy as np

from repro.graph.datasets import GraphDataset
from repro.graph.sampler import MiniBatch, NeighborSampler


@dataclasses.dataclass
class GraphBatchPipeline:
    dataset: GraphDataset
    sampler: NeighborSampler
    batch_size: int
    seed: int = 0
    epoch: int = 0
    batch_idx: int = 0

    def _perm(self) -> np.ndarray:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, self.epoch]))
        return rng.permutation(self.dataset.graph.n_nodes)

    def __iter__(self) -> Iterator[Tuple[MiniBatch, np.ndarray, np.ndarray]]:
        return self

    def __next__(self):
        perm = self._perm()
        n_batches = len(perm) // self.batch_size
        if self.batch_idx >= n_batches:
            self.epoch += 1
            self.batch_idx = 0
            perm = self._perm()
        s = self.batch_idx * self.batch_size
        seeds = perm[s:s + self.batch_size]
        # per-batch generator keyed by (seed, epoch, batch): resume-exact
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, self.epoch, self.batch_idx]))
        self.batch_idx += 1
        mb = self.sampler.sample(seeds,
                                 nnz_pad=self.sampler.static_nnz(
                                     self.batch_size), rng=rng)
        feats = self.dataset.features[np.minimum(
            mb.input_nodes, self.dataset.graph.n_nodes - 1)]
        if self.dataset.labels.ndim == 1:
            pad = mb.layers[0].n_dst - len(seeds)
            labels = self.dataset.labels[np.pad(seeds, (0, pad))]
        else:
            pad = mb.layers[0].n_dst - len(seeds)
            labels = self.dataset.labels[np.pad(seeds, (0, pad))]
        return mb, feats, labels

    def state(self) -> Dict[str, int]:
        return {"seed": self.seed, "epoch": self.epoch,
                "batch_idx": self.batch_idx}

    def restore(self, state: Dict[str, int]) -> None:
        self.seed = int(state["seed"])
        self.epoch = int(state["epoch"])
        self.batch_idx = int(state["batch_idx"])
