"""Graph mini-batch pipeline: sampler → static-shaped device batches — plus
the background :class:`Prefetcher` that takes the host-side work off the
step critical path.

Wraps :class:`repro.graph.NeighborSampler` into the same restartable-stream
contract as the token pipeline: the epoch permutation is derived from
``(seed, epoch)`` so restore-from-checkpoint replays the exact remaining
batches.  Shapes are padded to the per-layer static maxima so one jit trace
serves every batch (the paper's fixed 1024-node staging serves the same
purpose in BRAM).

:class:`Prefetcher` is the software analogue of the paper's NUMA-aware
host-side staging (§4.2–4.3): sampling + per-batch layout building +
device placement run on a producer thread with a depth-``k`` bounded queue
(default 2 — double buffering), so batch *i+1*'s host work overlaps batch
*i*'s device step instead of stalling it.  It preserves the restartable
contract: each queue slot carries the pipeline state that regenerates the
NEXT batch, so checkpointing mid-epoch with batches in flight restores
batch-exact.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Any, Callable, Dict, Iterator, Optional, Tuple

import numpy as np

from repro.graph.datasets import GraphDataset
from repro.graph.sampler import MiniBatch, NeighborSampler


def sample_batch(dataset: GraphDataset, sampler: NeighborSampler,
                 seeds: np.ndarray, nnz_pad, rng: np.random.Generator
                 ) -> Tuple[MiniBatch, np.ndarray]:
    """The feature-free half of batch assembly: ``(mb, labels)``.

    Labels are row-fancy-indexed (single-label ``[n]`` ints and multilabel
    ``[n, c]`` rows alike) with padded seed rows zero-padded — they index
    GLOBAL node 0's label, a placeholder the consumer masks (train loss
    counts only real rows when masked; val accuracy scores only the first
    ``len(seeds)`` rows).  The staged store pipeline runs this stage alone
    and gathers features in its own stage."""
    mb = sampler.sample(seeds, nnz_pad=nnz_pad, rng=rng)
    pad = mb.layers[0].n_dst - len(seeds)
    labels = dataset.labels[np.pad(seeds, (0, pad))]
    return mb, labels


def gather_features(features, input_nodes: np.ndarray,
                    n_nodes: int) -> np.ndarray:
    """THE frontier-gather rule: clamp-index padded frontier slots to the
    last real node, then fancy-index ``features`` — a dense ndarray, a
    :class:`~repro.featurestore.FeatureStore`, or a
    :class:`~repro.featurestore.HotVertexCache` alike (all three share
    the row-fancy-indexing surface, so one rule serves every tier)."""
    return features[np.minimum(input_nodes, n_nodes - 1)]


def assemble_batch(dataset: GraphDataset, sampler: NeighborSampler,
                   seeds: np.ndarray, nnz_pad, rng: np.random.Generator
                   ) -> Tuple[MiniBatch, np.ndarray, np.ndarray]:
    """One sampled batch: ``(mb, features, labels)`` for ``seeds``.

    THE batch-assembly rule, shared by the epoch pipeline and the
    Trainer's validation path so padding/label semantics can never
    diverge: :func:`sample_batch` + :func:`gather_features` fused —
    the staged pipeline calls the two halves as separate stages."""
    mb, labels = sample_batch(dataset, sampler, seeds, nnz_pad, rng)
    feats = gather_features(dataset.features, mb.input_nodes,
                            dataset.graph.n_nodes)
    return mb, feats, labels


@dataclasses.dataclass
class GraphBatchPipeline:
    """Restartable epoch stream of sampled batches.

    ``defer_gather=False`` (default) yields ``(mb, feats, labels)`` —
    features gathered inline, the in-memory path.  ``defer_gather=True``
    yields ``(mb, labels)`` and leaves the feature gather to a downstream
    pipeline stage (the out-of-core store path: sampling must not block
    on host/disk feature traffic it could overlap)."""

    dataset: GraphDataset
    sampler: NeighborSampler
    batch_size: int
    seed: int = 0
    epoch: int = 0
    batch_idx: int = 0
    defer_gather: bool = False

    def _perm(self) -> np.ndarray:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, self.epoch]))
        return rng.permutation(self.dataset.graph.n_nodes)

    @property
    def batches_per_epoch(self) -> int:
        return self.dataset.graph.n_nodes // self.batch_size

    def __iter__(self) -> Iterator[Tuple[MiniBatch, np.ndarray, np.ndarray]]:
        return self

    def __next__(self):
        perm = self._perm()
        n_batches = len(perm) // self.batch_size
        if self.batch_idx >= n_batches:
            self.epoch += 1
            self.batch_idx = 0
            perm = self._perm()
        s = self.batch_idx * self.batch_size
        seeds = perm[s:s + self.batch_size]
        # per-batch generator keyed by (seed, epoch, batch): resume-exact
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, self.epoch, self.batch_idx]))
        self.batch_idx += 1
        nnz_pad = self.sampler.static_nnz(self.batch_size)
        if self.defer_gather:
            return sample_batch(self.dataset, self.sampler, seeds,
                                nnz_pad, rng)
        return assemble_batch(self.dataset, self.sampler, seeds,
                              nnz_pad, rng)

    def state(self) -> Dict[str, int]:
        return {"seed": self.seed, "epoch": self.epoch,
                "batch_idx": self.batch_idx}

    def restore(self, state: Dict[str, int]) -> None:
        self.seed = int(state["seed"])
        self.epoch = int(state["epoch"])
        self.batch_idx = int(state["batch_idx"])


class Prefetcher:
    """Depth-``k`` background producer over a restartable batch source.

    ``source`` is any iterator with the pipeline contract (``__next__`` +
    ``state()``/``restore()``); ``prepare`` is the per-batch host transform
    (layout build + device placement) run ON THE PRODUCER THREAD, so by the
    time the train loop calls ``next(prefetcher)`` the batch is device-ready
    and the only critical-path cost is the queue pop.

    Restart contract: every queue slot carries ``source.state()`` captured
    AFTER its batch was drawn — i.e. the state that regenerates the *next*
    batch.  ``state()`` returns the snapshot belonging to the last consumed
    batch, so checkpoint-then-restore replays exactly the batches still in
    flight (queued but unconsumed work is regenerated, never skipped or
    double-consumed).

    Stall accounting: ``stall_s`` accumulates the time ``__next__`` spent
    blocked on the queue — the host time the device step could not hide.
    A sync loop doing the same work inline would stall for the full
    sample+build+place cost every step; the difference is the overlap win
    the ``epoch_time --input-pipeline`` benchmark records.
    """

    _DONE = object()

    def __init__(self, source, prepare: Optional[Callable[..., Any]] = None,
                 depth: int = 2):
        if depth < 1:
            raise ValueError(f"prefetch depth must be >= 1, got {depth}")
        self.source = source
        self.prepare = prepare
        self.depth = depth
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()
        self._error: Optional[BaseException] = None
        self._consumed_state = source.state()
        self.stall_s = 0.0
        self.n_consumed = 0

    # -- producer -----------------------------------------------------------
    def _produce(self) -> None:
        try:
            while not self._stop.is_set():
                item = next(self.source)
                state_after = self.source.state()
                if self.prepare is not None:
                    item = self.prepare(*item) if isinstance(item, tuple) \
                        else self.prepare(item)
                # bounded put; poll the stop flag so close() never deadlocks
                # against a full queue
                while not self._stop.is_set():
                    try:
                        self._q.put((state_after, item), timeout=0.05)
                        break
                    except queue.Full:
                        continue
        except BaseException as e:  # noqa: BLE001 — re-raised on the consumer
            self._error = e
            # deliver the sentinel with the same retry-until-stop loop as a
            # normal item: the queue is usually FULL when the producer dies
            # (device step slower than host work), and dropping the
            # sentinel there would leave the consumer blocked on get()
            # forever with the original exception lost
            while not self._stop.is_set():
                try:
                    self._q.put((None, self._DONE), timeout=0.05)
                    break
                except queue.Full:
                    continue

    def _ensure_started(self) -> None:
        if self._thread is None:
            self._stop.clear()
            self._thread = threading.Thread(target=self._produce,
                                            daemon=True)
            self._thread.start()

    # -- consumer -----------------------------------------------------------
    def __iter__(self) -> "Prefetcher":
        return self

    def __next__(self):
        self._ensure_started()
        t0 = time.perf_counter()
        state_after, item = self._q.get()
        self.stall_s += time.perf_counter() - t0
        if item is self._DONE:
            err, self._error = self._error, None
            self._thread = None
            raise err if err is not None else StopIteration
        self._consumed_state = state_after
        self.n_consumed += 1
        return item

    def reset_stats(self) -> None:
        self.stall_s = 0.0
        self.n_consumed = 0

    @property
    def stall_per_step(self) -> float:
        return self.stall_s / max(self.n_consumed, 1)

    # -- restartable-stream contract ----------------------------------------
    def state(self) -> Dict[str, int]:
        """The source state as of the last CONSUMED batch — in-flight
        (prefetched but unconsumed) batches are excluded, so a restore
        regenerates them."""
        return dict(self._consumed_state)

    def restore(self, state: Dict[str, int]) -> None:
        """Drain the queue, rewind the source, restart production lazily."""
        self.close()
        self.source.restore(state)
        self._consumed_state = self.source.state()

    def close(self) -> None:
        """Stop the producer, drop any queued batches (and any pending
        producer error), and rewind the source to the last CONSUMED batch
        — dropped in-flight work is regenerated on the next ``__next__``,
        never skipped, so stop/start (or checkpoint/restore) keeps the
        stream exact.

        Idempotent and exception-safe: a double close, or a close after
        the producer died (its error is discarded — consume via
        ``__next__`` to observe it), is a no-op beyond re-asserting the
        rewound source state.  The staged store pipeline closes stages
        through cascading restores, so repeated closes are its NORMAL
        path, not an error."""
        thread, self._thread = self._thread, None
        try:
            if thread is not None:
                self._stop.set()
                while thread.is_alive():  # unblock a put-blocked producer
                    try:
                        self._q.get_nowait()
                    except queue.Empty:
                        pass
                    thread.join(timeout=0.05)
        finally:
            # queue drain + source rewind run even if the join above blew
            # up — a half-closed prefetcher must never hold stale batches
            self._error = None
            while True:                   # leave the queue empty for restart
                try:
                    self._q.get_nowait()
                except queue.Empty:
                    break
            self.source.restore(self._consumed_state)

    def __enter__(self) -> "Prefetcher":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


class StagedPrefetcher:
    """Multi-stage producer chain — the depth-2 double buffer grown into a
    pipeline of named stages, each on its own thread with its own bounded
    queue.

    ``stages`` is a sequence of ``(name, fn)``; stage ``k``'s
    :class:`Prefetcher` consumes stage ``k-1``'s output, so with the store
    pipeline's ``sample → gather → layout → place`` chain, batch *i+2*'s
    feature gather overlaps batch *i+1*'s layout build overlaps batch
    *i*'s device step — the staged analogue of the paper's host-side NUMA
    staging, with the store's gather latency hidden the same way the
    layout build already was.

    The restartable-stream contract survives the depth: every queue slot
    in every stage carries the SOURCE state that regenerates its batch
    (Prefetchers chain their ``state()``/``restore()`` verbatim), so
    :meth:`state` is the innermost source's state as of the last batch
    consumed from the LAST stage — all in-flight work in every queue is
    excluded and regenerated on restore, preserving the batch-exact
    ``(seed, epoch, batch_idx)`` checkpoint contract.

    Stall accounting: :attr:`stall_per_step` is the LAST stage's stall —
    the only host time the device step actually sees; :meth:`stage_stalls`
    breaks the hidden time down per stage for the benchmarks.
    """

    def __init__(self, source, stages, depth: int = 2):
        if not stages:
            raise ValueError("StagedPrefetcher needs at least one stage")
        self.source = source
        self.names: Tuple[str, ...] = tuple(name for name, _ in stages)
        if len(set(self.names)) != len(self.names):
            raise ValueError(f"duplicate stage names: {list(self.names)}")
        self.stages: list = []
        cur = source
        for _, fn in stages:
            cur = Prefetcher(cur, prepare=fn, depth=depth)
            self.stages.append(cur)
        self._tail: Prefetcher = cur

    # -- consumer -----------------------------------------------------------
    def __iter__(self) -> "StagedPrefetcher":
        return self

    def __next__(self):
        return next(self._tail)

    @property
    def stall_s(self) -> float:
        return self._tail.stall_s

    @property
    def n_consumed(self) -> int:
        return self._tail.n_consumed

    @property
    def stall_per_step(self) -> float:
        return self._tail.stall_per_step

    def stage_stalls(self) -> Dict[str, float]:
        """Per-stage stall seconds per consumed item (stage k's stall =
        time it spent waiting on stage k-1 — where the pipeline is
        actually bottlenecked)."""
        return {name: st.stall_per_step
                for name, st in zip(self.names, self.stages)}

    def reset_stats(self) -> None:
        for st in self.stages:
            st.reset_stats()

    # -- restartable-stream contract ----------------------------------------
    def state(self) -> Dict[str, int]:
        return self._tail.state()

    def restore(self, state: Dict[str, int]) -> None:
        """Cascades down the chain: every stage drains its queue, then the
        innermost source rewinds to ``state``."""
        self._tail.restore(state)

    def close(self) -> None:
        """Close every stage (tail first — each stage's close rewinds its
        upstream, cascading to the source; Prefetcher.close is idempotent,
        so the overlapping rewinds are safe)."""
        self._tail.close()

    def __enter__(self) -> "StagedPrefetcher":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
