"""Version-compat shims for the jax API surface this repo targets.

The codebase is written against the modern spelling (``jax.shard_map``,
``jax.set_mesh``); older jaxlib builds (e.g. the 0.4.3x CPU wheels this
container ships) only expose ``jax.experimental.shard_map.shard_map`` and
have no context-mesh setter at all.  Importing from here gives every module
and test one spelling that works on both:

  * :func:`shard_map` — ``jax.shard_map`` when present, else the
    experimental entry point wrapped so ``mesh`` may be omitted and picked
    up from the innermost :func:`set_mesh` context.
  * :func:`set_mesh` — ``jax.set_mesh`` when present, else a context
    manager that records the mesh for :func:`shard_map` and enters the
    legacy ``Mesh`` resource context (so pjit specs keep resolving).
"""
from __future__ import annotations

import contextlib
import threading
from typing import Any, Optional

import jax

_local = threading.local()


def _context_mesh() -> Optional[Any]:
    stack = getattr(_local, "mesh_stack", None)
    return stack[-1] if stack else None


if hasattr(jax, "shard_map"):
    def shard_map(f, *, mesh=None, in_specs=None, out_specs=None, **kwargs):
        if mesh is None:
            mesh = _context_mesh()
        if mesh is None:
            return jax.shard_map(f, in_specs=in_specs, out_specs=out_specs,
                                 **kwargs)
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kwargs)
else:
    from jax.experimental.shard_map import shard_map as _shard_map_exp

    def shard_map(f, *, mesh=None, in_specs=None, out_specs=None, **kwargs):
        if mesh is None:
            mesh = _context_mesh()
        if mesh is None:
            raise ValueError(
                "shard_map needs a mesh: pass mesh= or enter "
                "repro.compat.set_mesh(mesh)")
        kwargs.pop("axis_names", None)  # new-API-only knob, default is fine
        return _shard_map_exp(f, mesh, in_specs, out_specs, **kwargs)


if hasattr(jax, "set_mesh"):
    set_mesh = jax.set_mesh
else:
    @contextlib.contextmanager
    def set_mesh(mesh):
        stack = getattr(_local, "mesh_stack", None)
        if stack is None:
            stack = _local.mesh_stack = []
        stack.append(mesh)
        try:
            # legacy resource context: lets pjit resolve PartitionSpecs
            with mesh:
                yield mesh
        finally:
            stack.pop()


def axis_size(axis_name) -> int:
    """Static size of a named mesh axis from inside shard_map.

    ``jax.lax.axis_size`` where it exists; on older jax ``psum(1, axis)``
    constant-folds to a python int under shard_map, which is all callers
    need (sizes feed shapes and denominators).
    """
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)


