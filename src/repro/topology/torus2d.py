"""2-D torus topology — the paper's orthogonal row/column multicast.

Cores sit on an ``R × C`` grid (``core = r·C + c``; ``C`` takes the extra
bit when ``log₂P`` is odd) with links only along rows and columns.  The
orthogonal-topology idea (paper §4.3): the row network and the column
network are INDEPENDENT wire sets, so traffic can ride both at once.  The
exchange here realizes that by splitting the feature dimension in half and
routing the halves along orthogonal dimension orders in parallel —

  * half A folds the COLUMN dimensions first, then the rows;
  * half B folds the ROW dimensions first, then the columns —

so at every step one half occupies row links while the other occupies
column links (two-phase multicast with both phases always busy).  Each
half is a :func:`repro.topology.hypercube.fold_bits` dimension-exchange
over its bit order; total steps stay ``log₂P`` = ``log₂R + log₂C``, bytes
stay the optimal ``n_rows·(1 − 1/P)``, and fp32 results land within
reduction-order roundoff (≤1e-5 contract) of the serial oracle.
"""
from __future__ import annotations

from typing import List, Tuple

import jax.numpy as jnp

from .base import Topology
from .hypercube import fold_bits, unfold_bits


def grid_shape(n_cores: int) -> Tuple[int, int]:
    """``(R, C)`` of the torus grid; C gets the extra dimension when
    ``log₂P`` is odd (a 2-core 'torus' degenerates to one row of 2)."""
    ndim = max(n_cores.bit_length() - 1, 0)
    nr_bits = ndim // 2
    return 1 << nr_bits, 1 << (ndim - nr_bits)


def _bit_orders(n_cores: int) -> Tuple[List[int], List[int]]:
    """(cols-first, rows-first) dimension orders — the orthogonal pair."""
    ndim = max(n_cores.bit_length() - 1, 0)
    nc_bits = ndim - ndim // 2
    col_bits = list(reversed(range(nc_bits)))          # low bits: c in r·C+c
    row_bits = list(reversed(range(nc_bits, ndim)))    # high bits: r
    return col_bits + row_bits, row_bits + col_bits


class Torus2DTopology(Topology):
    """R×C torus: orthogonal row/column two-phase multicast, both link
    sets busy every step."""

    description = ("2-D torus (R x C grid): feature halves fold along "
                   "orthogonal dimension orders in parallel — row links "
                   "and column links busy simultaneously")
    # the orthogonal halves occupy disjoint row/column link sets at every
    # step, so the wire sees half the per-core bytes at a time
    link_parallelism = 2.0

    def steps(self, n_cores: int) -> int:
        return max(n_cores.bit_length() - 1, 0)

    def max_step_rows(self, n_rows: int, n_cores: int) -> int:
        # in full-feature row equivalents: each half's first round moves
        # n_rows/2 rows of d/2 features.  Past P=2 the halves ride
        # DISJOINT link classes, so the per-wire buffer is n·d/4 elements
        # (= n/4 rows); at P=2 there is only one dimension and both halves
        # share its wire (n/2 rows)
        if n_cores <= 1:
            return 0
        return n_rows // 2 if n_cores == 2 else n_rows // 4

    def _split(self, x):
        d = x.shape[-1]
        return (x[..., : d // 2], x[..., d // 2:]) if d >= 2 else (None, x)

    def reduce_scatter(self, partial, axis_name, n_cores):
        if n_cores == 1:
            return partial[0]
        order_a, order_b = _bit_orders(n_cores)
        half_a, half_b = self._split(partial)
        if half_a is None:        # single feature column: one fold
            return fold_bits(partial, axis_name, n_cores, order_a)
        return jnp.concatenate(
            [fold_bits(half_a, axis_name, n_cores, order_a),
             fold_bits(half_b, axis_name, n_cores, order_b)], axis=-1)

    def allgather(self, x, axis_name, n_cores):
        if n_cores == 1:
            return x[None]
        order_a, order_b = _bit_orders(n_cores)
        half_a, half_b = self._split(x)
        if half_a is None:
            return unfold_bits(x, axis_name, n_cores, order_a)
        return jnp.concatenate(
            [unfold_bits(half_a, axis_name, n_cores, order_a),
             unfold_bits(half_b, axis_name, n_cores, order_b)], axis=-1)
