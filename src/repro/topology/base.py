"""Topology base class — the interconnect as a first-class Engine axis.

The paper's headline contribution is the *orthogonal-topology on-chip
network*: which wires exist between cores, and in what order partial rows
travel them, is a design axis independent of the edge format and the fold
issue order.  A :class:`Topology` owns exactly that axis: the per-step
exchange plan (peer schedule, message partitioning) and the collective
primitives the distributed aggregation runs inside ``shard_map`` —

  * :meth:`Topology.reduce_scatter` — fold per-owner partial rows
    ``[P, t, ...]`` (row-blocks in core order) down to this device's fully
    reduced ``[t, ...]`` block;
  * :meth:`Topology.allgather` — the mirror: replicate ``[t, ...]`` into
    every device's ``[P, t, ...]`` in core order (the transpose-free
    backward's error-row gather rides this);
  * pipelined variants that split the feature dimension into waves
    (:func:`repro.core.schedule.feature_waves`) so wire time hides under
    MAC work, and :meth:`Topology.fold_pipelined`, the fused local-SpMM +
    exchange the pipelined schedule calls.

Module-level :func:`reduce_scatter` / :func:`allgather` / :func:`exchange`
are the *differentiable* entry points: ``custom_vjp`` mirrors make the
backward of a reduce-scatter the same topology's allgather (and vice
versa), so gradients ride the mirror schedule of whatever interconnect the
forward used — no transposed exchange schedule exists anywhere.

Topologies register via ``@repro.engine.register_topology`` (the existing
engine registry); the built-ins live in sibling modules and are registered
by :mod:`repro.topology.__init__`.  A new interconnect is a ~100-line
registration::

    from repro.engine import register_topology
    from repro.topology import Topology

    @register_topology("dragonfly")
    class Dragonfly(Topology):
        description = "two-level groups, one global hop"
        def steps(self, n_cores): ...
        def reduce_scatter(self, partial, axis_name, n_cores): ...
        def allgather(self, x, axis_name, n_cores): ...

After that ``Engine("ell+pipelined+dragonfly")`` reaches it everywhere —
train step, Trainer, benchmarks — with no other code change.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.schedule import feature_waves


def _wave_slices(x, n_chunks: int):
    waves = feature_waves(x.shape[-1], n_chunks)
    return [jax.lax.slice_in_dim(x, w.start, w.stop, axis=-1) for w in waves]


@dataclasses.dataclass(frozen=True)
class ExchangePlan:
    """One topology's per-step exchange plan for a fixed core count.

    ``steps`` is the number of serialized exchange rounds of one
    reduce-scatter (= one allgather, by mirror symmetry);
    ``bytes_per_core`` the wire bytes each core ships per reduce-scatter of
    ``n_rows`` rows × ``d`` features; ``max_step_rows`` the largest single
    message (rows) any step puts on a wire — the buffer a real NoC must
    provision.  ``link_parallelism`` is how many disjoint link sets the
    topology keeps busy simultaneously (torus2d's orthogonal row+column
    halves give 2.0 — effective wire bytes are ``bytes_per_core`` divided
    by it); ``predicted_seconds`` is the planner cost model's estimate for
    one reduce-scatter when a :class:`repro.engine.planner.CostModel` was
    handed to :meth:`Topology.plan`.  Host-side accounting only: the
    benchmarks record it, the roofline and planner consume it; no traced
    code reads a plan.
    """

    topology: str
    n_cores: int
    steps: int
    bytes_per_core: int
    max_step_rows: int
    axis: str = "model"
    link_parallelism: float = 1.0
    predicted_seconds: Optional[float] = None


class Topology:
    """Base class for registered interconnect topologies (module docstring).

    Subclasses implement :meth:`steps`, :meth:`reduce_scatter` and
    :meth:`allgather`; ``name`` is filled in by ``register_topology``.  The
    pipelined variants and :meth:`fold_pipelined` have wave-split defaults
    any topology inherits; hypercube overrides them with the fused
    double-buffered fold.  Collectives run INSIDE ``shard_map`` over the
    engine's core axis; everything else is host-side trace-time Python.
    """

    name: str = "?"
    description: str = ""
    # disjoint link sets the schedule keeps busy at once (torus2d: 2.0);
    # the cost model divides wire bytes by this
    link_parallelism: float = 1.0

    # -- plan / cost model (host side) ---------------------------------------
    def validate_cores(self, n_cores: int) -> None:
        """Raise ``ValueError`` when this topology cannot be built over
        ``n_cores`` cores.  Every built-in runs on any power of two (the
        engine's block partitioning already requires it)."""
        if n_cores < 1 or n_cores & (n_cores - 1):
            raise ValueError(
                f"the {self.name} topology needs a power-of-two core "
                f"count, got {n_cores}")

    def steps(self, n_cores: int) -> int:
        """Serialized exchange rounds per reduce-scatter."""
        raise NotImplementedError

    def bytes_per_core(self, n_rows: int, d: int, n_cores: int,
                       dtype_bytes: int = 4) -> int:
        """Wire bytes each core ships per reduce-scatter of ``n_rows``
        pre-reduced rows.  Default: the bandwidth-optimal
        ``n_rows·(1 − 1/P)`` — every built-in ships exactly the blocks that
        must leave, never raw redundant rows."""
        if n_cores <= 1:
            return 0
        return int(n_rows * (n_cores - 1) // n_cores) * d * dtype_bytes

    def max_step_rows(self, n_rows: int, n_cores: int) -> int:
        """Largest single-step message, in rows (default: one core block)."""
        return n_rows // n_cores if n_cores > 1 else 0

    def plan(self, n_rows: int, d: int, n_cores: int,
             dtype_bytes: int = 4, axis: str = "model",
             cost_model=None, wire_rows: Optional[int] = None
             ) -> ExchangePlan:
        """The per-step exchange plan (steps + wire cost) for ``n_cores``.

        ``cost_model`` (a :class:`repro.engine.planner.CostModel`, duck-typed
        on ``.predict(plan)``) fills ``predicted_seconds``; without one the
        field stays ``None`` — planning never requires a fitted model.

        ``wire_rows`` is the measured post-merge wire content of the
        exchange, in partial rows across all cores — the distinct
        (destination row, sender core) cross-core pairs the sender-side
        merge actually ships (:func:`repro.graph.partition.exchange_rows`).
        The structural default assumes every non-owned row crosses
        (``n_rows·(1 − 1/P)`` per core); a measured count rescales
        ``bytes_per_core`` by its ratio to that worst case, which is how
        partition quality (``mincom`` vs ``naive``) and redundancy merging
        become visible to the planner's cost model.
        """
        self.validate_cores(n_cores)
        bpc = self.bytes_per_core(n_rows, d, n_cores, dtype_bytes)
        if wire_rows is not None and n_cores > 1:
            # worst case: every row needed from every non-owner core
            dense_rows = n_rows * (n_cores - 1)
            bpc = int(round(bpc * min(wire_rows / max(dense_rows, 1), 1.0)))
        plan = ExchangePlan(
            topology=self.name, n_cores=n_cores,
            steps=self.steps(n_cores),
            bytes_per_core=bpc,
            max_step_rows=self.max_step_rows(n_rows, n_cores), axis=axis,
            link_parallelism=self.link_parallelism)
        if cost_model is not None:
            plan = dataclasses.replace(
                plan, predicted_seconds=float(cost_model.predict(plan)))
        return plan

    # -- collectives (inside shard_map) --------------------------------------
    def reduce_scatter(self, partial: jnp.ndarray, axis_name: str,
                       n_cores: int) -> jnp.ndarray:
        """``[P, t, ...]`` per-owner partials (core order) → this device's
        fully reduced ``[t, ...]`` block."""
        raise NotImplementedError

    def allgather(self, x: jnp.ndarray, axis_name: str,
                  n_cores: int) -> jnp.ndarray:
        """``[t, ...]`` → ``[P, t, ...]`` in core order on every device
        (the mirror of :meth:`reduce_scatter`)."""
        raise NotImplementedError

    def reduce_scatter_pipelined(self, partial, axis_name: str,
                                 n_cores: int, n_chunks: int) -> jnp.ndarray:
        """Wave-split reduce-scatter: every wave's exchange is issued
        independently so XLA can overlap wave *k*'s wire time with wave
        *k+1*'s sends.  Default = one serial fold per feature wave; the
        reduction order per element is the serial schedule's."""
        chunks = _wave_slices(partial, n_chunks)
        if len(chunks) == 1:
            return self.reduce_scatter(partial, axis_name, n_cores)
        outs = [self.reduce_scatter(c, axis_name, n_cores) for c in chunks]
        return jnp.concatenate(outs, axis=-1)

    def allgather_pipelined(self, x, axis_name: str, n_cores: int,
                            n_chunks: int) -> jnp.ndarray:
        """Wave-split mirror of :meth:`reduce_scatter_pipelined`."""
        chunks = _wave_slices(x, n_chunks)
        if len(chunks) == 1:
            return self.allgather(x, axis_name, n_cores)
        outs = [self.allgather(c, axis_name, n_cores) for c in chunks]
        return jnp.concatenate(outs, axis=-1)

    def fold_pipelined(self, axis_name: str, n_cores: int, n_chunks: int,
                       partials_fn, x_local) -> jnp.ndarray:
        """Fused local SpMM + exchange, one feature wave at a time.

        ``partials_fn(x_chunk) -> [P, t, dc]`` is the format's local
        pre-reduction for one wave.  The default computes each wave's
        partials then folds them — the waves' exchanges are independent
        dataflow, so wave *k*'s wire time hides under wave *k+1*'s SpMM.
        Hypercube overrides this with the ping-pong fold that also issues
        the first round's send before the still-owned half's SpMM runs.
        """
        waves = _wave_slices(x_local, n_chunks)
        if len(waves) == 1:
            return self.reduce_scatter(partials_fn(x_local), axis_name,
                                       n_cores)
        outs = [self.reduce_scatter(partials_fn(xc), axis_name, n_cores)
                for xc in waves]
        return jnp.concatenate(outs, axis=-1)


def _topo(name: str) -> Topology:
    # lazy: breaks the aggregate ↔ engine ↔ topology import cycle
    from repro.engine.registry import get_topology
    return get_topology(name)


# ---------------------------------------------------------------------------
# Differentiable primitives: custom_vjp mirrors, so the transpose-free
# backward rides ANY registered topology.
# ---------------------------------------------------------------------------
@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2))
def reduce_scatter(topology: str, axis_name: str, n_cores: int,
                   partial: jnp.ndarray) -> jnp.ndarray:
    """Differentiable ``[P, t, ...] → [t, ...]`` fold over ``topology``.

    The backward is the SAME topology's :func:`allgather` (reduce-scatter's
    linear transpose): error rows travel the mirror schedule of the wires
    the forward used.  Call inside ``shard_map``.
    """
    return _topo(topology).reduce_scatter(partial, axis_name, n_cores)


def _rs_fwd(topology, axis_name, n_cores, partial):
    return reduce_scatter(topology, axis_name, n_cores, partial), None


def _rs_bwd(topology, axis_name, n_cores, _, ct):
    return (_topo(topology).allgather(ct, axis_name, n_cores),)


reduce_scatter.defvjp(_rs_fwd, _rs_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2))
def allgather(topology: str, axis_name: str, n_cores: int,
              x: jnp.ndarray) -> jnp.ndarray:
    """Differentiable ``[t, ...] → [P, t, ...]`` gather over ``topology``;
    the backward is the same topology's :func:`reduce_scatter` (cotangent
    blocks fold back to their owners over the mirror wires)."""
    return _topo(topology).allgather(x, axis_name, n_cores)


def _ag_fwd(topology, axis_name, n_cores, x):
    return allgather(topology, axis_name, n_cores, x), None


def _ag_bwd(topology, axis_name, n_cores, _, ct):
    return (_topo(topology).reduce_scatter(ct, axis_name, n_cores),)


allgather.defvjp(_ag_fwd, _ag_bwd)


def exchange(x: jnp.ndarray, plan: ExchangePlan,
             op: str = "reduce_scatter") -> jnp.ndarray:
    """One differentiable exchange under ``plan`` (see :meth:`Topology.plan`).

    ``op="reduce_scatter"`` folds ``[P, t, ...]`` partials to the owned
    block; ``op="allgather"`` replicates the owned block.  Both ride the
    plan's topology with the custom_vjp mirror backward.
    """
    if op == "reduce_scatter":
        return reduce_scatter(plan.topology, plan.axis, plan.n_cores, x)
    if op == "allgather":
        return allgather(plan.topology, plan.axis, plan.n_cores, x)
    raise ValueError(f"unknown exchange op {op!r}; "
                     "expected 'reduce_scatter' or 'allgather'")
