# The interconnect as a first-class Engine axis: Topology base class,
# differentiable exchange primitives with custom_vjp mirror backwards, and
# the four built-in topologies.  Registration happens HERE (not in the
# topology modules) so the modules stay import-cycle-free: they depend only
# on jax + the exchange helpers, while this package init touches the engine
# registry once everything is defined.
from .base import (ExchangePlan, Topology, allgather, exchange,
                   reduce_scatter)
from .allpairs import AllPairsTopology
from .hypercube import HypercubeTopology
from .ring import RingTopology
from .torus2d import Torus2DTopology

from repro.engine.registry import register_topology

register_topology("hypercube")(HypercubeTopology)
register_topology("allpairs")(AllPairsTopology)
register_topology("ring")(RingTopology)
register_topology("torus2d")(Torus2DTopology)

__all__ = [
    "ExchangePlan", "Topology", "exchange", "reduce_scatter", "allgather",
    "HypercubeTopology", "AllPairsTopology", "RingTopology",
    "Torus2DTopology",
]
