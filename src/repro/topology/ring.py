"""Ring topology — bandwidth-optimal reduce-scatter / allgather.

Each core talks only to its two neighbours.  The reduce-scatter passes
running partial sums around the ring: block *b* starts at core ``b+1``,
accumulates one core's contribution per hop, and arrives fully reduced at
its owner after ``P − 1`` hops.  Every step every link carries exactly one
``n_rows/P`` block — the smallest per-step message of any topology here
(what makes rings the bandwidth-optimal choice when link count, not
latency, is the constraint).  The allgather is the mirror: each core's
block circulates ``P − 1`` hops until everyone holds all of them.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .base import Topology


def _right_perm(n_cores: int) -> list:
    return [(i, (i + 1) % n_cores) for i in range(n_cores)]


class RingTopology(Topology):
    """Neighbour-only ring: P-1 steps, one n_rows/P block per link-step."""

    description = ("bandwidth-optimal ring: P-1 neighbour hops of running "
                   "partial sums, minimum per-step message size")
    link_parallelism = 1.0    # one neighbour link direction busy per hop

    def steps(self, n_cores: int) -> int:
        return n_cores - 1

    def reduce_scatter(self, partial, axis_name, n_cores):
        if n_cores == 1:
            return partial[0]
        idx = jax.lax.axis_index(axis_name)
        perm = _right_perm(n_cores)
        # at step s this core ships the running sum for owner (idx - s);
        # what arrives is the sum for (idx - s - 1), to which this core
        # adds its own partial before the next hop
        send = jnp.take(partial, (idx - 1) % n_cores, axis=0)
        for s in range(1, n_cores):
            recv = jax.lax.ppermute(send, axis_name, perm)
            send = recv + jnp.take(partial, (idx - s - 1) % n_cores, axis=0)
        return send        # after P-1 hops: my own block, fully reduced

    def allgather(self, x, axis_name, n_cores):
        if n_cores == 1:
            return x[None]
        idx = jax.lax.axis_index(axis_name)
        perm = _right_perm(n_cores)
        blocks = [x]                          # position k ← core idx-k
        cur = x
        for _ in range(1, n_cores):
            cur = jax.lax.ppermute(cur, axis_name, perm)
            blocks.append(cur)
        stacked = jnp.stack(blocks)
        order = (idx - jnp.arange(n_cores)) % n_cores
        return jnp.take(stacked, order, axis=0)
