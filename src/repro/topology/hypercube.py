"""Hypercube topology — the paper's 4-D NoC as dimension-ordered folds.

Canonical home of the exchange loops that used to live inline in
:mod:`repro.distributed.aggregate` (which keeps thin delegating shims):
``log₂P`` rounds of pairwise ``ppermute`` along hypercube dimensions, high
bit first, plus the double-buffered (ping-pong Block-Message, §4.2) and
fused-SpMM (§4.3, Fig. 9) variants.  fp32 add order is the repo-wide
serial contract — the ``coo+serial`` oracle and the block format's
bit-exactness both ride these exact functions.

Also home of the *generalized* bit-order fold (:func:`fold_bits` /
:func:`unfold_bits`): the same dimension-exchange machinery over an
arbitrary bit sequence, which :mod:`repro.topology.torus2d` uses to route
its two feature halves along orthogonal dimension orders in parallel.
"""
from __future__ import annotations

from typing import List, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.schedule import feature_waves
from repro.distributed.overlap import double_buffered_rounds

from .base import Topology


def _dim_perm(n_cores: int, bit: int) -> list:
    return [(i, i ^ (1 << bit)) for i in range(n_cores)]


def hypercube_reduce_scatter(partial: jnp.ndarray, axis_name: str,
                             ndim: int) -> jnp.ndarray:
    """Fold per-owner partials across the hypercube, high dimension first.

    ``partial``: [P, t, ...] — row-blocks ordered by owner core id.  Returns
    [t, ...]: this device's rows, fully reduced.  Because blocks are in
    ascending core order and we process the top bit first, 'my half' is
    always a contiguous slice — each round halves the buffer (the wire bytes
    form the geometric series t·(1 − 1/P), same as a reduce-scatter).
    """
    idx = jax.lax.axis_index(axis_name)
    n_cores = 1 << ndim
    buf = partial
    for b in reversed(range(ndim)):
        half = buf.shape[0] // 2
        low, high = buf[:half], buf[half:]
        my_bit = (idx >> b) & 1
        mine = jnp.where(my_bit == 0, low, high)
        send = jnp.where(my_bit == 0, high, low)
        recv = jax.lax.ppermute(send, axis_name, _dim_perm(n_cores, b))
        buf = mine + recv
    return buf[0]


def hypercube_allgather(x: jnp.ndarray, axis_name: str, ndim: int
                        ) -> jnp.ndarray:
    """Mirror schedule (transpose of the reduce-scatter): after ``ndim``
    doubling rounds every device holds [P, t, ...] in core order."""
    idx = jax.lax.axis_index(axis_name)
    n_cores = 1 << ndim
    buf = x[None]
    for b in range(ndim):
        other = jax.lax.ppermute(buf, axis_name, _dim_perm(n_cores, b))
        my_bit = (idx >> b) & 1
        lo = jnp.concatenate([buf, other], axis=0)
        hi = jnp.concatenate([other, buf], axis=0)
        buf = jnp.where(my_bit == 0, lo, hi)
    return buf


def _fold_round(idx, axis_name: str, n_cores: int, b: int):
    """One double-buffered fold round over dimension ``b``: the
    ``(split, permute)`` factory :func:`double_buffered_rounds` consumes.
    The split halves are derived from the CURRENT buffer (the fold shrinks
    it every round); shared by the pipelined reduce-scatter and the fused
    SpMM fold so their wire schedule can never drift apart."""
    def round_fns(bufs):
        half = bufs[0].shape[0] // 2
        my_bit = (idx >> b) & 1
        perm = _dim_perm(n_cores, b)

        def split(buf, my_bit=my_bit, half=half):
            mine = jax.lax.dynamic_slice_in_dim(buf, my_bit * half,
                                                half, 0)
            send = jax.lax.dynamic_slice_in_dim(buf, (1 - my_bit) * half,
                                                half, 0)
            return mine, send

        return split, lambda s, perm=perm: jax.lax.ppermute(
            s, axis_name, perm)
    return round_fns


def hypercube_reduce_scatter_pipelined(partial: jnp.ndarray, axis_name: str,
                                       ndim: int, n_chunks: int = 2
                                       ) -> jnp.ndarray:
    """Double-buffered fold — bit-identical to the serial reduce-scatter.

    The feature dimension is split into ``n_chunks`` waves
    (:func:`repro.core.schedule.feature_waves`); within every round all
    waves' ``ppermute`` sends are issued before any wave's local add
    consumes a received half, so the wire transfer of wave *k+1* overlaps
    the MAC work of wave *k* — the paper's ping-pong Block-Message buffers
    (§4.2), expressed as dataflow for XLA's latency-hiding scheduler.  The
    round sequence is the topology's step count, driven through
    :func:`repro.distributed.overlap.double_buffered_rounds`.  Per-element
    add order matches :func:`hypercube_reduce_scatter` exactly, so fp32
    results are bit-equal.
    """
    idx = jax.lax.axis_index(axis_name)
    n_cores = 1 << ndim
    waves = feature_waves(partial.shape[-1], n_chunks)
    bufs = [jax.lax.slice_in_dim(partial, w.start, w.stop, axis=-1)
            for w in waves]
    bufs = double_buffered_rounds(
        bufs, [_fold_round(idx, axis_name, n_cores, b)
               for b in reversed(range(ndim))])
    return jnp.concatenate([b[0] for b in bufs], axis=-1)


def hypercube_allgather_pipelined(x: jnp.ndarray, axis_name: str, ndim: int,
                                  n_chunks: int = 2) -> jnp.ndarray:
    """Mirror of the pipelined fold (the backward pass's gather): the same
    feature waves, each wave one ``all_gather`` in core order.

    All waves' collectives are issued independently before any result is
    consumed, so wave *k*'s wire time hides under wave *k+1*'s — and each
    wave lowers to XLA's native all-gather, which schedules the
    dimension-ordered doubling itself instead of paying ``ndim`` rounds of
    hand-rolled concatenate+select copies (the gather moves bytes only, so
    the result is bit-identical to :func:`hypercube_allgather`).
    """
    del ndim  # the native collective derives the schedule from the mesh
    waves = feature_waves(x.shape[-1], n_chunks)
    if len(waves) == 1:
        return jax.lax.all_gather(x, axis_name)
    gathered = [jax.lax.all_gather(
        jax.lax.slice_in_dim(x, w.start, w.stop, axis=-1), axis_name)
        for w in waves]
    return jnp.concatenate(gathered, axis=-1)


def hypercube_fold_pipelined(axis_name: str, ndim: int, n_chunks: int,
                             partials_fn, x_local):
    """Fused local SpMM + double-buffered fold, layout-agnostic.

    ``partials_fn(x_chunk) -> [P, dpc, dc]`` is the local pre-reduction for
    one feature wave — the Block-Message tile scatter or the pre-reduced
    ELL gather; the fold around it is identical.  Per feature wave the SpMM
    for the half-cube this device does NOT own is computed first and its
    round-(ndim-1) ``ppermute`` issued immediately; the SpMM for the
    still-owned half then runs while that first transfer is on the wire
    (paper §4.3, Fig. 9 — message passing overlapped with MAC work).  The
    remaining rounds use the double-buffered fold.
    """
    n_cores = 1 << ndim
    if ndim == 0:
        return partials_fn(x_local)[0]
    idx = jax.lax.axis_index(axis_name)
    waves = feature_waves(x_local.shape[-1], n_chunks)
    b0 = ndim - 1                     # top bit: the first fold round
    half = n_cores // 2
    my_bit0 = (idx >> b0) & 1
    perm0 = _dim_perm(n_cores, b0)
    mines, recvs = [], []
    for w in waves:
        xc = jax.lax.slice_in_dim(x_local, w.start, w.stop, axis=-1)
        # wave k's SpMM runs while wave k-1's send (issued below, consumed
        # only after the loop) is on the wire — the ping-pong buffer
        p = partials_fn(xc)
        send = jax.lax.dynamic_slice_in_dim(p, (1 - my_bit0) * half,
                                            half, 0)
        recvs.append(jax.lax.ppermute(send, axis_name, perm0))
        mines.append(jax.lax.dynamic_slice_in_dim(p, my_bit0 * half,
                                                  half, 0))
    bufs = [m + r for m, r in zip(mines, recvs)]
    bufs = double_buffered_rounds(
        bufs, [_fold_round(idx, axis_name, n_cores, b)
               for b in reversed(range(ndim - 1))])
    return jnp.concatenate([b[0] for b in bufs], axis=-1)   # [dpc, d]


# ---------------------------------------------------------------------------
# Generalized bit-order folds (torus2d routes feature halves along
# orthogonal dimension orders through these).
# ---------------------------------------------------------------------------
def fold_bits(partial: jnp.ndarray, axis_name: str, n_cores: int,
              bit_order: Sequence[int]) -> jnp.ndarray:
    """Dimension-exchange reduce-scatter over an ARBITRARY bit sequence.

    ``bit_order`` lists which hypercube dimension each round exchanges
    (every bit of ``log₂P`` exactly once).  Before each round the buffer's
    row-blocks are reordered by a STATIC permutation so the blocks whose
    destination-id bit is 0 form the first half — the 'mine'/'send' halves
    then split contiguously exactly like the high-bit-first special case.
    ``bit_order = [ndim-1, …, 0]`` reproduces
    :func:`hypercube_reduce_scatter`'s schedule (the sort is the identity
    every round).
    """
    idx = jax.lax.axis_index(axis_name)
    buf = partial
    slots: List[int] = list(range(n_cores))
    for b in bit_order:
        order = sorted(range(len(slots)), key=lambda k: (slots[k] >> b) & 1)
        if order != list(range(len(slots))):
            buf = buf[np.asarray(order)]
            slots = [slots[k] for k in order]
        half = len(slots) // 2
        low, high = buf[:half], buf[half:]
        my_bit = (idx >> b) & 1
        mine = jnp.where(my_bit == 0, low, high)
        send = jnp.where(my_bit == 0, high, low)
        recv = jax.lax.ppermute(send, axis_name, _dim_perm(n_cores, b))
        buf = mine + recv
        # keep the bit-b = 0 representatives: low[k] and high[k] agree on
        # every remaining bit (the slot list enumerates a subcube in
        # ascending order, which the stable sort preserves)
        slots = slots[:half]
    return buf[0]


def unfold_bits(x: jnp.ndarray, axis_name: str, n_cores: int,
                bit_order: Sequence[int]) -> jnp.ndarray:
    """Mirror of :func:`fold_bits`: doubling rounds over ``reversed(
    bit_order)``, then a static reorder to ascending core order.  With the
    hypercube order the reorder is the identity and this is exactly
    :func:`hypercube_allgather`."""
    idx = jax.lax.axis_index(axis_name)
    buf = x[None]
    slots: List[int] = [0]
    for b in reversed(list(bit_order)):
        other = jax.lax.ppermute(buf, axis_name, _dim_perm(n_cores, b))
        my_bit = (idx >> b) & 1
        lo = jnp.concatenate([buf, other], axis=0)
        hi = jnp.concatenate([other, buf], axis=0)
        buf = jnp.where(my_bit == 0, lo, hi)      # bit-b = 0 blocks first
        slots = slots + [s | (1 << b) for s in slots]
    order = np.argsort(np.asarray(slots))
    if not np.array_equal(order, np.arange(len(slots))):
        buf = buf[order]
    return buf


class HypercubeTopology(Topology):
    """log₂P dimension-ordered folds — the paper's 4-D NoC, and the repo's
    fp32 oracle schedule (serial add order is THE reference order)."""

    description = ("log2(P)-step dimension-ordered pairwise exchange, high "
                   "bit first; the paper's 4-D NoC and the fp32 oracle "
                   "schedule")
    link_parallelism = 1.0    # one pairwise link set busy per round

    def steps(self, n_cores: int) -> int:
        return max(n_cores.bit_length() - 1, 0)

    def max_step_rows(self, n_rows: int, n_cores: int) -> int:
        return n_rows // 2 if n_cores > 1 else 0   # the first (top-bit) round

    def reduce_scatter(self, partial, axis_name, n_cores):
        return hypercube_reduce_scatter(partial, axis_name,
                                        self.steps(n_cores))

    def allgather(self, x, axis_name, n_cores):
        return hypercube_allgather(x, axis_name, self.steps(n_cores))

    def reduce_scatter_pipelined(self, partial, axis_name, n_cores,
                                 n_chunks):
        return hypercube_reduce_scatter_pipelined(
            partial, axis_name, self.steps(n_cores), n_chunks)

    def allgather_pipelined(self, x, axis_name, n_cores, n_chunks):
        return hypercube_allgather_pipelined(
            x, axis_name, self.steps(n_cores), n_chunks)

    def fold_pipelined(self, axis_name, n_cores, n_chunks, partials_fn,
                       x_local):
        return hypercube_fold_pipelined(axis_name, self.steps(n_cores),
                                        n_chunks, partials_fn, x_local)
