"""All-pairs topology — the dense all-to-all reference.

Every (sender, receiver) pair exchanges its block directly: no fold tree,
no partial-sum reuse on the wire.  ``P − 1`` serialized rotation rounds
(rotation *s* ships each core's block for peer ``(i+s) mod P`` straight to
that peer), each carrying one ``n_rows/P`` block — the direct realization
of "ship every message point-to-point", which is what a full crossbar
would do and what the structured topologies are benchmarked against.
Bytes per core are still the optimal ``n_rows·(1 − 1/P)`` (only owed
blocks travel); the cost is the step count: ``P − 1`` rounds versus the
hypercube's ``log₂P``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .base import Topology


def _rot_perm(n_cores: int, s: int) -> list:
    return [(i, (i + s) % n_cores) for i in range(n_cores)]


class AllPairsTopology(Topology):
    """Dense all-to-all: one direct message per (sender, receiver) pair."""

    description = ("dense all-to-all reference: P-1 rotation rounds, one "
                   "direct block per peer, no fold-tree reuse")
    link_parallelism = 1.0    # one rotation permutation busy per round

    def steps(self, n_cores: int) -> int:
        return n_cores - 1

    def reduce_scatter(self, partial, axis_name, n_cores):
        if n_cores == 1:
            return partial[0]
        idx = jax.lax.axis_index(axis_name)
        acc = jnp.take(partial, idx, axis=0)          # my own contribution
        for s in range(1, n_cores):
            # ship my block for peer (idx+s) straight to it; receive, from
            # peer (idx-s), ITS partial block for me — one pair per round
            send = jnp.take(partial, (idx + s) % n_cores, axis=0)
            acc = acc + jax.lax.ppermute(send, axis_name,
                                         _rot_perm(n_cores, s))
        return acc

    def allgather(self, x, axis_name, n_cores):
        if n_cores == 1:
            return x[None]
        idx = jax.lax.axis_index(axis_name)
        blocks = [x]                                  # position k ← core idx-k
        for s in range(1, n_cores):
            blocks.append(jax.lax.ppermute(x, axis_name,
                                           _rot_perm(n_cores, s)))
        stacked = jnp.stack(blocks)
        # stacked[k] came from core (idx - k) mod P → core order is a
        # device-dependent rotation
        order = (idx - jnp.arange(n_cores)) % n_cores
        return jnp.take(stacked, order, axis=0)
