"""Request queue + coalescer — deque admission, deduplicated micro-batches.

**Ordering contract (FIFO + deadline):** requests are served in strict
arrival order — a micro-batch is always a contiguous prefix of the queue,
never a reordering (no request can be starved by later arrivals, and a
request's queueing delay is bounded by ``max_wait`` plus one batch's
service time).  Deadlines never reorder; they only *accelerate flushing*:
when the HEAD request's deadline is within ``deadline_slack`` of now, the
batch closes immediately instead of waiting out ``max_wait``.  A batch
closes when the first of these holds:

1. ``max_batch`` requests are queued (size flush),
2. the head request has waited ``max_wait`` seconds (age flush),
3. the head request's deadline is ≤ ``deadline_slack`` away (deadline
   flush).

The head of the queue is ``popleft`` on a :class:`collections.deque` —
O(1), replacing the seed LM server's O(n) ``list.pop(0)`` admission
pattern.

Coalescing happens at batch-close: concurrent queries for the same vertex
collapse into one engine row (:attr:`MicroBatch.nodes` is the sorted unique
vertex set) and every request gets its logits scattered back.  The
cumulative ``coalesce_factor`` (requests served / unique rows computed) is
the benchmark's measure of how much concurrent demand the dedup absorbed.
"""
from __future__ import annotations

import dataclasses
import itertools
from collections import deque
from typing import Deque, Dict, List, Optional

import numpy as np

_rid = itertools.count()


@dataclasses.dataclass
class InferenceRequest:
    """One node-level query: which vertex, when it arrived, when it must
    answer.  ``result``/``t_done`` are filled by the service."""

    node: int
    t_arrival: float
    deadline: Optional[float] = None
    rid: int = dataclasses.field(default_factory=lambda: next(_rid))
    result: Optional[np.ndarray] = None
    t_done: Optional[float] = None

    @property
    def latency(self) -> Optional[float]:
        return None if self.t_done is None else self.t_done - self.t_arrival


@dataclasses.dataclass(frozen=True)
class MicroBatch:
    """A closed batch: the FIFO-prefix requests plus their deduplicated
    vertex set (sorted ascending — the engine's canonical row order)."""

    requests: List[InferenceRequest]
    nodes: np.ndarray                  # sorted unique int64 vertex ids

    @property
    def coalesce_factor(self) -> float:
        return len(self.requests) / max(len(self.nodes), 1)


class RequestQueue:
    """Deque-backed FIFO with size/age/deadline flushing (contract above)."""

    def __init__(self, *, max_batch: int = 8, max_wait: float = 0.004,
                 deadline_slack: float = 0.001):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        self.max_batch = int(max_batch)
        self.max_wait = float(max_wait)
        self.deadline_slack = float(deadline_slack)
        self._q: Deque[InferenceRequest] = deque()
        self.submitted = 0
        self.served_requests = 0
        self.served_unique = 0
        self.batches = 0
        self.flush_reasons = {"size": 0, "age": 0, "deadline": 0,
                              "drain": 0}

    def __len__(self) -> int:
        return len(self._q)

    def submit(self, req: InferenceRequest) -> InferenceRequest:
        self._q.append(req)
        self.submitted += 1
        return req

    # -- flush policy ---------------------------------------------------------
    def _flush_reason(self, now: float) -> Optional[str]:
        if not self._q:
            return None
        if len(self._q) >= self.max_batch:
            return "size"
        head = self._q[0]
        if head.deadline is not None \
                and head.deadline - now <= self.deadline_slack:
            return "deadline"
        if now - head.t_arrival >= self.max_wait:
            return "age"
        return None

    def ready(self, now: float) -> bool:
        return self._flush_reason(now) is not None

    def next_wakeup(self, now: float) -> Optional[float]:
        """Earliest future time a waiting batch will flush on its own (age
        or deadline), or ``None`` for an empty queue — the service sleeps
        until min(this, next arrival)."""
        if not self._q:
            return None
        head = self._q[0]
        t = head.t_arrival + self.max_wait
        if head.deadline is not None:
            t = min(t, head.deadline - self.deadline_slack)
        return max(t, now)

    def next_batch(self, now: float, *, force: bool = False
                   ) -> Optional[MicroBatch]:
        """Close and return the head batch if a flush condition holds
        (``force=True`` drains regardless — shutdown path)."""
        reason = self._flush_reason(now)
        if reason is None:
            if not (force and self._q):
                return None
            reason = "drain"
        reqs = [self._q.popleft()
                for _ in range(min(self.max_batch, len(self._q)))]
        nodes = np.unique(np.fromiter((r.node for r in reqs), np.int64,
                                      len(reqs)))
        self.flush_reasons[reason] += 1
        self.batches += 1
        self.served_requests += len(reqs)
        self.served_unique += len(nodes)
        return MicroBatch(requests=reqs, nodes=nodes)

    # -- metrics --------------------------------------------------------------
    @property
    def coalesce_factor(self) -> float:
        """Cumulative requests-per-computed-row across all served batches."""
        return self.served_requests / max(self.served_unique, 1)

    def stats(self) -> Dict[str, float]:
        return {"submitted": self.submitted, "batches": self.batches,
                "served_requests": self.served_requests,
                "served_unique": self.served_unique,
                "coalesce_factor": self.coalesce_factor,
                "queued": len(self._q), **{f"flush_{k}": v for k, v in
                                           self.flush_reasons.items()}}
