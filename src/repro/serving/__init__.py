"""Online GCN inference service on the Engine.

The serving subsystem: a deque-backed request queue + coalescer
(:mod:`~repro.serving.queue`), a versioned historical-embedding cache with
frontier-walk invalidation (:mod:`~repro.serving.cache`), a mutable serving
graph (:mod:`~repro.serving.graph`), the :class:`InferenceEngine` that runs
layered queries over any registered Engine spec with bit-exact incremental
reuse (:mod:`~repro.serving.engine`), the single-worker
:class:`InferenceService` loop (:mod:`~repro.serving.service`) and the
open-loop load generator (:mod:`~repro.serving.loadgen`).
"""
from .cache import EmbeddingCache
from .engine import InferenceEngine, load_checkpoint_params
from .graph import DynamicGraph
from .loadgen import Arrival, percentile, poisson_trace, summarize
from .queue import InferenceRequest, MicroBatch, RequestQueue
from .service import InferenceService

__all__ = [
    "EmbeddingCache", "InferenceEngine", "load_checkpoint_params",
    "DynamicGraph", "Arrival", "percentile", "poisson_trace", "summarize",
    "InferenceRequest", "MicroBatch", "RequestQueue", "InferenceService",
]
