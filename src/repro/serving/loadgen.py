"""Synthetic open-loop load — Poisson arrivals, zipf vertex popularity.

Open-loop means arrival times are fixed up front and never slow down when
the service lags (the load generator models independent users, not a
closed feedback loop) — queueing delay therefore shows up in the measured
latency exactly as it would in production.  Vertex popularity is zipf: a
few hub vertices absorb most queries, which is what makes both the
coalescer (concurrent duplicates) and the embedding cache (repeat
neighborhoods) earn their keep.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class Arrival:
    t: float        # seconds from trace start
    node: int


def poisson_trace(rate: float, duration: float, n_nodes: int, *,
                  zipf_a: float = 1.3, seed: int = 0) -> List[Arrival]:
    """Poisson arrivals at ``rate``/s for ``duration`` s over ``n_nodes``
    vertices with zipf(``zipf_a``) popularity.

    The popularity ranking is a seeded permutation of the vertex ids, so
    "hot" vertices are spread over the graph rather than clustered at low
    ids (low ids are also the high-degree ids in the synthetic datasets —
    without the shuffle the trace would accidentally align with the
    feature store's pinned set and overstate cache wins).
    """
    if rate <= 0 or duration <= 0:
        raise ValueError(f"rate={rate} and duration={duration} must be > 0")
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate, size=max(int(rate * duration * 2),
                                                16))
    times = np.cumsum(gaps)
    times = times[times < duration]
    ranks = np.minimum(rng.zipf(zipf_a, size=len(times)) - 1, n_nodes - 1)
    perm = rng.permutation(n_nodes)
    return [Arrival(t=float(t), node=int(perm[r]))
            for t, r in zip(times, ranks)]


def percentile(xs: Sequence[float], q: float) -> float:
    if not len(xs):
        return float("nan")
    return float(np.percentile(np.asarray(xs, np.float64), q))


def summarize(latencies_s: Sequence[float], slo_s: float,
              wall_s: float) -> Dict[str, float]:
    """Latency tail + throughput-at-SLO for one open-loop run.

    ``throughput_at_slo`` counts only requests answered within the SLO,
    over the full wall clock — a service that answers fast but drops the
    tail, or answers everything late, both score low.
    """
    lat = np.asarray(latencies_s, np.float64)
    within = int((lat <= slo_s).sum()) if len(lat) else 0
    return {
        "completed": int(len(lat)),
        "p50_ms": percentile(lat, 50) * 1e3,
        "p99_ms": percentile(lat, 99) * 1e3,
        "mean_ms": float(lat.mean() * 1e3) if len(lat) else float("nan"),
        "within_slo": within,
        "slo_ms": slo_s * 1e3,
        "wall_s": float(wall_s),
        "throughput_at_slo": within / max(wall_s, 1e-9),
    }
