"""InferenceService — the queue/coalescer wired to an InferenceEngine.

The service is the loop a deployment would run: admit requests into the
deque-backed :class:`~repro.serving.queue.RequestQueue`, close micro-batches
under the FIFO + deadline contract, run each batch's deduplicated vertex
set through one :meth:`InferenceEngine.query`, and scatter the logits back
to every coalesced request.  ``replay`` drives it under an open-loop trace
(arrival times fixed, service lag becomes queueing latency) and returns the
p50/p99/throughput-at-SLO summary the benchmarks gate on.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Sequence

import numpy as np

from .engine import InferenceEngine
from .loadgen import Arrival, summarize
from .queue import InferenceRequest, MicroBatch, RequestQueue


class InferenceService:
    """One engine + one queue; synchronous single-worker serving loop."""

    def __init__(self, engine: InferenceEngine, *, max_batch: int = 8,
                 max_wait: float = 0.004, deadline_slack: float = 0.001,
                 use_cache: bool = True):
        self.engine = engine
        self.queue = RequestQueue(max_batch=max_batch, max_wait=max_wait,
                                  deadline_slack=deadline_slack)
        self.use_cache = use_cache
        self.latencies_s: List[float] = []
        self.served = 0

    # -- request plane --------------------------------------------------------
    def submit(self, node: int, *, now: Optional[float] = None,
               deadline: Optional[float] = None) -> InferenceRequest:
        now = time.perf_counter() if now is None else now
        return self.queue.submit(InferenceRequest(node=int(node),
                                                  t_arrival=now,
                                                  deadline=deadline))

    def _serve(self, batch: MicroBatch, now_fn) -> None:
        logits = self.engine.query(batch.nodes, use_cache=self.use_cache)
        pos = np.searchsorted(batch.nodes,
                              [r.node for r in batch.requests])
        done = now_fn()
        for r, p in zip(batch.requests, pos):
            r.result = logits[p]
            r.t_done = done
            self.latencies_s.append(r.latency)
        self.served += len(batch.requests)

    def step(self, *, now: Optional[float] = None, force: bool = False
             ) -> int:
        """Serve at most one ready batch; returns requests answered."""
        t = time.perf_counter() if now is None else now
        batch = self.queue.next_batch(t, force=force)
        if batch is None:
            return 0
        before = self.served
        self._serve(batch, (lambda: now) if now is not None
                    else time.perf_counter)
        return self.served - before

    def drain(self, *, now: Optional[float] = None) -> int:
        """Flush everything queued (shutdown path)."""
        total = 0
        while len(self.queue):
            total += self.step(now=now, force=True)
        return total

    # -- open-loop replay -----------------------------------------------------
    def replay(self, trace: Sequence[Arrival], *, slo: float = 0.05,
               default_deadline: Optional[float] = None) -> Dict[str, float]:
        """Run the trace open-loop in real time and summarize latency.

        Arrivals are admitted at their scheduled offsets from the replay
        start (never earlier — the loop sleeps ahead of schedule, so a
        fast engine cannot batch the future); a request's latency is
        completion wall-time minus its SCHEDULED arrival, so backlog shows
        up as queueing delay exactly like an outside observer would see.
        """
        t0 = time.perf_counter()
        i = 0
        n = len(trace)
        while i < n or len(self.queue):
            now = time.perf_counter() - t0
            while i < n and trace[i].t <= now:
                a = trace[i]
                deadline = None if default_deadline is None \
                    else a.t + default_deadline
                self.queue.submit(InferenceRequest(
                    node=a.node, t_arrival=a.t, deadline=deadline))
                i += 1
            if self.queue.ready(now):
                batch = self.queue.next_batch(now)
                self._serve(batch, lambda: time.perf_counter() - t0)
                continue
            if i >= n:
                # nothing else arrives: drain the sub-max_wait tail
                if len(self.queue):
                    batch = self.queue.next_batch(now, force=True)
                    self._serve(batch, lambda: time.perf_counter() - t0)
                continue
            # idle: sleep to the next arrival or queue wakeup
            wake = trace[i].t
            qw = self.queue.next_wakeup(now)
            if qw is not None:
                wake = min(wake, qw)
            if wake > now:
                time.sleep(min(wake - now, 0.01))
        wall = time.perf_counter() - t0
        out = summarize(self.latencies_s, slo, wall)
        out["coalesce_factor"] = self.queue.coalesce_factor
        return out

    def stats(self) -> Dict[str, float]:
        return {"served": self.served, "use_cache": self.use_cache,
                "queue": self.queue.stats(),
                "engine": self.engine.stats()}
