"""Versioned historical-embedding cache — the incremental-aggregation core.

Entries are keyed ``(layer, vertex)`` and hold the vertex's HIDDEN
activation after that GCN layer (layers ``1..L-1``; final-layer logits are
never cached — they are cheap once the hop-(L-1) embeddings exist, and
keeping them out makes every served logit a fresh last-layer compute).

**Validity is explicit, not versioned-out:** an entry stays servable until
an :meth:`invalidate` call removes it — the InferenceEngine's
``update_edges`` / ``update_features`` frontier walk names exactly the
``(layer, vertex)`` pairs whose inputs changed, and only those are dropped.
The ``version`` counter (bumped once per update batch) is stamped on every
entry at insert time purely for *staleness accounting*: a hit on an entry
whose stamp predates the current version is a vertex legitimately served
from history (its neighborhood did not change), and
``max_staleness_served`` records how far back the cache has reached.

Eviction is LRU over all entries with a row-count ``capacity``; pinned
regions are a feature-store concern (:class:`repro.featurestore
.HotVertexCache`), not an embedding-cache one — embeddings go stale,
features do not.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Dict, Iterable, Optional, Tuple

import numpy as np

Key = Tuple[int, int]


class EmbeddingCache:
    """LRU of ``(layer, vertex) → (embedding row, version stamp)``."""

    def __init__(self, capacity: int = 4096):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = int(capacity)
        self._entries: "OrderedDict[Key, Tuple[np.ndarray, int]]" = \
            OrderedDict()
        self.version = 0            # bumped once per update_* batch
        self.hits = 0
        self.misses = 0
        self.insertions = 0
        self.evictions = 0
        self.invalidations = 0
        self.stale_hits = 0         # hits on entries stamped < version
        self.max_staleness_served = 0

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, key: Key) -> bool:
        return (int(key[0]), int(key[1])) in self._entries

    # -- read/write -----------------------------------------------------------
    def get(self, layer: int, vertex: int) -> Optional[np.ndarray]:
        ent = self._entries.get((int(layer), int(vertex)))
        if ent is None:
            self.misses += 1
            return None
        self.hits += 1
        self._entries.move_to_end((int(layer), int(vertex)))
        row, stamp = ent
        if stamp < self.version:
            self.stale_hits += 1
            self.max_staleness_served = max(self.max_staleness_served,
                                            self.version - stamp)
        return row

    def put(self, layer: int, vertex: int, row: np.ndarray) -> None:
        key = (int(layer), int(vertex))
        if key in self._entries:
            self._entries.move_to_end(key)
        elif len(self._entries) >= self.capacity:
            self._entries.popitem(last=False)
            self.evictions += 1
        self._entries[key] = (np.asarray(row), self.version)
        self.insertions += 1

    # -- invalidation ---------------------------------------------------------
    def invalidate(self, layer: int, vertices: Iterable[int]) -> int:
        """Drop the entries for ``vertices`` at ``layer``; returns how many
        actually existed (the invalidation counter counts real drops, so a
        frontier walk over mostly-uncached vertices reads as cheap)."""
        dropped = 0
        for v in vertices:
            if self._entries.pop((int(layer), int(v)), None) is not None:
                dropped += 1
        self.invalidations += dropped
        return dropped

    def bump_version(self) -> int:
        self.version += 1
        return self.version

    def clear(self) -> None:
        self.invalidations += len(self._entries)
        self._entries.clear()

    # -- metrics --------------------------------------------------------------
    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def stats(self) -> Dict[str, float]:
        return {"capacity": self.capacity, "entries": len(self._entries),
                "version": self.version, "hits": self.hits,
                "misses": self.misses, "hit_rate": self.hit_rate,
                "insertions": self.insertions, "evictions": self.evictions,
                "invalidations": self.invalidations,
                "stale_hits": self.stale_hits,
                "max_staleness_served": self.max_staleness_served}
