"""InferenceEngine — online GCN queries on any registered Engine spec.

One engine owns: a trained weight stack (directly, or restored from a
:class:`~repro.checkpoint.CheckpointManager` directory), a mutable
:class:`~repro.serving.graph.DynamicGraph`, a feature source routed through
the existing :mod:`repro.featurestore` surface (plain array, ``FeatureStore``
or ``HotVertexCache`` — all share the counted ``gather`` front door), and a
versioned :class:`~repro.serving.cache.EmbeddingCache` of historical
hop-``l`` embeddings.

``query(nodes)`` runs the L-layer GCN top-down: at each layer the engine
splits the needed vertices into cache-valid rows (reused verbatim) and
uncached rows (recursed), builds the rectangular per-layer COO in
**canonical form** — rows sorted ascending, each row's columns ascending,
row-mean ``1/|N_in(v) ∪ {v}|`` weights, shapes padded to power-of-two
buckets — and runs it through ``Engine.layer``.  Canonical construction is
what makes the incremental path *bit-equal* to a cold full recompute: for
the ``coo`` and ``ell`` formats a row's output is a row-local reduction
over its own edge segment, independent of which other rows share the
micro-batch (verified property; the ``block`` format's cross-row tiling
breaks it, so the cache auto-disables there and ``incremental_supported``
reads false in :meth:`stats`).

``update_edges`` / ``update_features`` mutate the graph/feature state and
run the invalidation frontier walk: the directly dirtied vertices
invalidate their layer-1 entries, one out-neighbor expansion per deeper
layer invalidates exactly the rows whose aggregation transitively reads a
changed input.  Everything else keeps serving from history (the cache's
staleness counters record how far back).
"""
from __future__ import annotations

import json
import os
from typing import Dict, List, Optional, Sequence, Set, Union

import jax.numpy as jnp
import numpy as np

from repro.engine import Engine, EngineConfig
from repro.graph import CSRGraph, from_edges

from .cache import EmbeddingCache
from .graph import DynamicGraph


def load_checkpoint_params(ckpt_dir: str) -> List[Dict[str, np.ndarray]]:
    """Restore the newest Trainer checkpoint's GCN weight stack.

    The Trainer saves ``params`` as ``[{"w": [d_in, d_out]}, ...]``; the
    manifest's leaf paths (``"0/w"``, ``"1/w"``, …) carry enough structure
    to rebuild the ``like`` tree without knowing the layer dims up front,
    so serving needs only the directory.
    """
    from repro.checkpoint import CheckpointManager

    mgr = CheckpointManager(ckpt_dir)
    step = mgr.latest_step()
    if step is None:
        raise FileNotFoundError(f"no checkpoint under {ckpt_dir!r}")
    path = os.path.join(ckpt_dir, f"step_{step:08d}", "manifest.json")
    with open(path) as f:
        manifest = json.load(f)
    layers: Dict[int, Dict[str, np.ndarray]] = {}
    for key, meta in manifest["leaves"].items():
        idx, _, name = key.partition("/")
        layers.setdefault(int(idx), {})[name] = np.zeros(
            meta["shape"], np.dtype(meta["dtype"]))
    like = [layers[i] for i in sorted(layers)]
    tree, _ = mgr.restore(step, like)
    return tree


def _bucket(n: int, multiple: int) -> int:
    """Pad ``n`` up to a power-of-two bucket (≥ ``multiple``) — bounded
    distinct shapes keep the per-shape layout/compile caches small."""
    n = max(int(n), 1)
    b = 1 << (n - 1).bit_length()
    return max(b, multiple)


class InferenceEngine:
    """Online GCN inference over a trained checkpoint + mutable graph.

    Parameters
    ----------
    engine: spec string (``"coo+serial"``, ``"auto"``, …),
        :class:`EngineConfig` or :class:`Engine`.  ``"auto"`` resolves
        through the planner's SERVING mode (latency-weighted over
        micro-batch sizes ``1..max_batch``, see
        :func:`repro.engine.planner.rank_specs`).
    graph: :class:`~repro.graph.CSRGraph` or
        :class:`~repro.serving.graph.DynamicGraph` — the base adjacency.
    features: ``[n, d]`` array, ``FeatureStore`` or ``HotVertexCache``.
    params: the weight stack (``[{"w": ...}, ...]``), or ``None`` with
        ``ckpt_dir`` to restore the newest checkpoint.
    cache_capacity: embedding-cache rows (0 disables incremental reuse).
    feature_cache_capacity: if > 0 and ``features`` is a bare store, wrap
        it in a degree-keyed :class:`~repro.featurestore.HotVertexCache`.
    pad_multiple: minimum shape bucket for the per-query COO padding.
    max_batch: the coalescer bound the serving-mode planner ranks for.
    """

    def __init__(self, engine: Union[str, EngineConfig, Engine],
                 graph: Union[CSRGraph, DynamicGraph], features, *,
                 params: Optional[List[Dict]] = None,
                 ckpt_dir: Optional[str] = None,
                 cache_capacity: int = 4096,
                 feature_cache_capacity: int = 0,
                 pad_multiple: int = 8, max_batch: int = 8):
        if not isinstance(engine, Engine):
            engine = Engine(engine)
        if engine.is_auto:
            from repro.engine import planner
            spec = planner.resolve_spec(n_cores=1, mode="serving",
                                        max_batch=max_batch)
            engine = Engine(engine.config.with_spec(spec))
        self.engine = engine
        self.spec = engine.spec
        self.graph = graph if isinstance(graph, DynamicGraph) \
            else DynamicGraph(graph)
        if params is None:
            if ckpt_dir is None:
                raise ValueError("pass params or ckpt_dir")
            params = load_checkpoint_params(ckpt_dir)
        self.params = params
        self.weights = [jnp.asarray(np.asarray(p["w"], np.float32))
                        for p in params]
        self.n_layers = len(self.weights)
        self.feat_dim = int(self.weights[0].shape[0])
        self.n_classes = int(self.weights[-1].shape[1])
        if feature_cache_capacity > 0 and hasattr(features, "gather") \
                and not hasattr(features, "store"):
            from repro.featurestore import HotVertexCache
            degrees = np.fromiter(
                (self.graph.in_degree(v)
                 for v in range(self.graph.n_nodes)),
                np.int64, self.graph.n_nodes)
            features = HotVertexCache(features, degrees,
                                      feature_cache_capacity)
        self.features = features
        self._overlay: Dict[int, np.ndarray] = {}
        # the block format's cross-row tiling is not per-row
        # bit-deterministic across batch compositions — incremental reuse
        # would drift from the cold path by reduction-order ULPs, so the
        # cache hard-disables rather than serve almost-right logits
        self.incremental_supported = (engine.config.format != "block"
                                      and cache_capacity > 0
                                      and self.n_layers > 1)
        self.cache = EmbeddingCache(max(cache_capacity, 1))
        self.pad_multiple = int(pad_multiple)
        self.max_batch = int(max_batch)
        self.queries = 0
        self.rows_computed = 0
        self.rows_from_cache = 0
        self.feature_updates = 0
        self.edge_updates = 0

    # -- feature plane --------------------------------------------------------
    def _gather_features(self, nodes: np.ndarray) -> np.ndarray:
        """Layer-0 rows: overlay (serving-time updates) over the sealed
        store/cache/array — overlay rows are verbatim, so updated features
        are bit-exact on both the incremental and cold paths."""
        if hasattr(self.features, "gather"):
            rows = np.asarray(self.features.gather(nodes), np.float32)
        else:
            rows = np.asarray(self.features, np.float32)[nodes]
        if self._overlay:
            for i, v in enumerate(nodes):
                ov = self._overlay.get(int(v))
                if ov is not None:
                    rows[i] = ov
        return rows

    # -- the layered recursion ------------------------------------------------
    def _embed(self, layer: int, nodes: np.ndarray,
               use_cache: bool) -> np.ndarray:
        """Embeddings of sorted-unique ``nodes`` after ``layer`` GCN
        layers (``layer=0`` → raw features)."""
        if layer == 0:
            return self._gather_features(nodes)
        d_out = int(self.weights[layer - 1].shape[1])
        out = np.empty((len(nodes), d_out), np.float32)
        todo: List[int] = []
        cacheable = use_cache and layer < self.n_layers
        if cacheable:
            for i, v in enumerate(nodes):
                row = self.cache.get(layer, int(v))
                if row is None:
                    todo.append(i)
                else:
                    out[i] = row
            self.rows_from_cache += len(nodes) - len(todo)
        else:
            todo = list(range(len(nodes)))
        if todo:
            tnodes = nodes[todo]          # sorted: todo is ascending
            fresh = self._compute_rows(layer, tnodes, use_cache)
            out[todo] = fresh
            self.rows_computed += len(todo)
            if cacheable:
                for v, row in zip(tnodes, fresh):
                    self.cache.put(layer, int(v), row)
        return out

    def _compute_rows(self, layer: int, tnodes: np.ndarray,
                      use_cache: bool) -> np.ndarray:
        """One canonical rectangular layer: rows = ``tnodes`` (sorted),
        cols = their joint 1-hop frontier (sorted), mean weights, and
        EVERY array dimension — rows, cols, and the edge count — padded to
        a power-of-two bucket.  The nnz padding matters as much as the
        shape padding: each distinct traced shape is one XLA compile, and
        online frontiers vary per query, so an unpadded edge count would
        recompile (hundreds of ms) on nearly every request.  Pad edges are
        zero-weight and live entirely in the padding row/column (buckets
        are sized on ``len + 1`` so the last row/col is never real),
        leaving every real row's reduction untouched."""
        agg = [self.graph.agg_set(int(v)) for v in tnodes]
        frontier = np.unique(np.concatenate(agg)) if agg \
            else np.empty(0, np.int64)
        h_in = self._embed(layer - 1, frontier, use_cache)
        n_dst = _bucket(len(tnodes) + 1, self.pad_multiple)
        n_src = _bucket(len(frontier) + 1, self.pad_multiple)
        nnz = sum(len(a) for a in agg)
        nnz_pad = _bucket(nnz, self.pad_multiple)
        rows = np.full(nnz_pad, n_dst - 1, np.int64)
        cols = np.full(nnz_pad, n_src - 1, np.int64)
        vals = np.zeros(nnz_pad, np.float32)
        k = 0
        for r, a in enumerate(agg):
            m = len(a)
            rows[k:k + m] = r
            cols[k:k + m] = np.searchsorted(frontier, a)
            vals[k:k + m] = 1.0 / m
            k += m
        coo = from_edges(rows, cols, vals, n_dst, n_src)
        x = np.zeros((n_src, h_in.shape[1]), np.float32)
        x[:len(frontier)] = h_in
        y = self.engine.layer(coo, jnp.asarray(x), self.weights[layer - 1],
                              activate=layer < self.n_layers)
        return np.asarray(y)[:len(tnodes)]

    # -- queries --------------------------------------------------------------
    def query(self, nodes: Sequence[int], *, use_cache: bool = True
              ) -> np.ndarray:
        """Logits ``[len(nodes), n_classes]`` in the given order
        (duplicates fine — they share one computed row).

        ``use_cache=False`` is the cold full recompute: the identical
        recursion with the cache bypassed, bit-equal to the incremental
        path by per-row determinism of the canonical layer construction.
        """
        nodes = np.asarray(nodes, np.int64)
        self.queries += 1
        uniq, inv = np.unique(nodes, return_inverse=True)
        logits = self._embed(self.n_layers, uniq,
                             use_cache and self.incremental_supported)
        return logits[inv]

    # -- updates + the invalidation frontier walk -----------------------------
    def _invalidate_from(self, level1: Set[int]) -> None:
        """Drop ``(l, v)`` for every ``v`` in frontier level ``l``, where
        level 1 is the directly-dirtied rows and each deeper level is one
        out-neighbor expansion (the rows whose aggregation transitively
        reads a changed embedding)."""
        frontier = level1
        for layer in range(1, self.n_layers):
            self.cache.invalidate(layer, frontier)
            if layer + 1 < self.n_layers:
                frontier = self.graph.expand_out(frontier)
        self.cache.bump_version()

    def update_edges(self, add: Sequence = (), remove: Sequence = ()
                     ) -> Dict[str, int]:
        """Apply edge additions/removals; invalidate the affected cache
        frontier.  A dst row's layer-1 embedding changes with its in-list
        (mean weights are row-local), so level 1 is exactly the dirty dst
        set."""
        dirty = self.graph.update_edges(add=add, remove=remove)
        self.edge_updates += 1
        if dirty:
            self._invalidate_from(dirty)
        return {"dirty_rows": len(dirty),
                "cache_version": self.cache.version}

    def update_features(self, nodes: Sequence[int], rows) -> Dict[str, int]:
        """Overwrite feature rows (overlay over the sealed store); a
        feature change at ``u`` reaches layer 1 of ``u`` and every row
        aggregating ``u``, so level 1 is ``{u} ∪ out(u)``."""
        nodes = np.asarray(nodes, np.int64)
        rows = np.asarray(rows, np.float32)
        if rows.ndim == 1:
            rows = rows[None]
        if rows.shape != (len(nodes), self.feat_dim):
            raise ValueError(f"rows shape {rows.shape} != "
                             f"({len(nodes)}, {self.feat_dim})")
        for v, row in zip(nodes, rows):
            self._overlay[int(v)] = row.copy()
        self.feature_updates += 1
        self._invalidate_from(self.graph.expand_out(nodes))
        return {"dirty_rows": len(nodes),
                "cache_version": self.cache.version}

    # -- observability --------------------------------------------------------
    def stats(self) -> Dict[str, float]:
        s = {"spec": self.spec, "n_layers": self.n_layers,
             "queries": self.queries,
             "rows_computed": self.rows_computed,
             "rows_from_cache": self.rows_from_cache,
             "feature_updates": self.feature_updates,
             "edge_updates": self.edge_updates,
             "overlay_rows": len(self._overlay),
             "incremental_supported": self.incremental_supported,
             "cache": self.cache.stats()}
        fs = getattr(self.features, "stats", None)
        if callable(fs):
            s["feature_cache"] = fs()
        return s
