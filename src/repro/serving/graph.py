"""Mutable serving-time graph — the adjacency the InferenceEngine queries.

Training samples from a frozen :class:`~repro.graph.CSRGraph`; serving has
to absorb edge updates between queries, so this wraps the same adjacency in
a per-vertex mutable form with BOTH directions indexed:

* **in-neighbors** (``u`` such that ``u → v``) drive aggregation: a GCN
  layer for row ``v`` averages over ``N_in(v) ∪ {v}`` with uniform
  ``1 / |N_in(v) ∪ {v}|`` weights (the row-mean normalization of
  :func:`repro.graph.mean_normalize` — row ``v``'s weights depend only on
  its own degree, so an edge update touches exactly its dst row's weights,
  never the whole matrix as a symmetric ``D^{-1/2} A D^{-1/2}`` norm
  would).
* **out-neighbors** (``w`` such that ``v → w``) drive invalidation: they
  are exactly the rows whose layer-(l+1) aggregation reads ``v``'s
  layer-l embedding, i.e. the next ring of the invalidation frontier walk.

Neighbor lists are kept canonically SORTED (ascending vertex id) so the
rectangular per-query COO the engine builds is identical no matter which
other rows share the micro-batch — the property the incremental cache's
bit-match guarantee rests on.
"""
from __future__ import annotations

from typing import Dict, Iterable, List, Sequence, Set, Tuple

import numpy as np

Edge = Tuple[int, int]


class DynamicGraph:
    """Mutable directed adjacency with sorted in-lists + out-sets.

    Build from a :class:`~repro.graph.CSRGraph` (whose CSR is src-major:
    ``indices[indptr[s]:indptr[s+1]]`` are the out-neighbors of ``s``;
    datasets emit both directions for undirected graphs) or from nothing
    (``DynamicGraph(n_nodes=n)``) and grow it with :meth:`update_edges`.
    """

    def __init__(self, csr=None, *, n_nodes: int = 0):
        if csr is not None:
            n_nodes = int(csr.n_nodes)
        self.n_nodes = int(n_nodes)
        self._in: List[Set[int]] = [set() for _ in range(self.n_nodes)]
        self._out: List[Set[int]] = [set() for _ in range(self.n_nodes)]
        self.edges_added = 0
        self.edges_removed = 0
        self.noop_updates = 0       # add-existing / remove-missing requests
        self._sorted_in: Dict[int, np.ndarray] = {}
        if csr is not None:
            indptr = np.asarray(csr.indptr)
            indices = np.asarray(csr.indices)
            for s in range(self.n_nodes):
                for t in indices[indptr[s]:indptr[s + 1]]:
                    t = int(t)
                    self._out[s].add(t)
                    self._in[t].add(s)

    # -- reads ----------------------------------------------------------------
    def in_neighbors(self, v: int) -> np.ndarray:
        """Sorted in-neighbors of ``v`` (cached until ``v``'s row mutates)."""
        v = int(v)
        arr = self._sorted_in.get(v)
        if arr is None:
            arr = np.fromiter(sorted(self._in[v]), np.int64,
                              len(self._in[v]))
            self._sorted_in[v] = arr
        return arr

    def agg_set(self, v: int) -> np.ndarray:
        """``N_in(v) ∪ {v}`` sorted — the rows layer ``l`` reads at l-1."""
        v = int(v)
        nbrs = self.in_neighbors(v)
        pos = np.searchsorted(nbrs, v)
        if pos < len(nbrs) and nbrs[pos] == v:
            return nbrs
        return np.insert(nbrs, pos, v)

    def out_neighbors(self, v: int) -> Set[int]:
        return self._out[int(v)]

    def in_degree(self, v: int) -> int:
        return len(self._in[int(v)])

    def expand_out(self, vertices: Iterable[int]) -> Set[int]:
        """``vertices ∪ out(vertices)`` — one ring of the invalidation
        frontier walk."""
        out: Set[int] = set(int(v) for v in vertices)
        for v in list(out):
            out |= self._out[v]
        return out

    # -- writes ---------------------------------------------------------------
    def update_edges(self, add: Sequence[Edge] = (),
                     remove: Sequence[Edge] = ()) -> Set[int]:
        """Apply ``(src, dst)`` additions/removals; returns the set of dst
        vertices whose in-list (and therefore mean-normalized row weights)
        actually changed.  Duplicate adds and missing removes are counted
        no-ops, never errors — an idempotent update stream replays safely.
        """
        dirty: Set[int] = set()
        for s, t in add:
            s, t = int(s), int(t)
            if not (0 <= s < self.n_nodes and 0 <= t < self.n_nodes):
                raise ValueError(f"edge ({s}, {t}) outside the "
                                 f"{self.n_nodes}-node graph")
            if t in self._out[s]:
                self.noop_updates += 1
                continue
            self._out[s].add(t)
            self._in[t].add(s)
            self.edges_added += 1
            dirty.add(t)
        for s, t in remove:
            s, t = int(s), int(t)
            if t not in self._out[s] if 0 <= s < self.n_nodes else True:
                self.noop_updates += 1
                continue
            self._out[s].discard(t)
            self._in[t].discard(s)
            self.edges_removed += 1
            dirty.add(t)
        for t in dirty:
            self._sorted_in.pop(t, None)
        return dirty
