"""Out-of-core feature stores — node features behind a pluggable backend.

The paper's HBM regime (and the GPU-oriented data-communication paper,
arxiv 2103.03330) splits feature traffic from compute: the full ``[n, d]``
feature matrix stays in host memory (or on disk) and only each
mini-batch's frontier rows stream to the device.  A :class:`FeatureStore`
is that backing matrix: it quacks like a read-only 2-D ndarray (``shape``,
``dtype``, fancy row indexing), so every ``dataset.features`` consumer —
:func:`repro.data.assemble_batch`, the Trainer's validation path,
``EngineBundle.prepare_batch`` — works unchanged, while every row read is
an explicit, counted ``gather`` instead of an implicit device-resident
array.

Backends live in a registry mirroring ``engine/registry.py``'s
``@register_format`` contract::

    from repro.featurestore import FeatureStore, register_store

    @register_store("redis")
    class RedisStore(FeatureStore):
        ...

after which ``Trainer(feature_store="redis")`` and
``make_dataset(features="redis")`` reach it with no other code change.
Built-ins: ``host`` (RAM-resident ndarray — the pinned-host-memory tier)
and ``mmap`` (a memory-mapped ``.npy`` file with a chunked writer, so
features far beyond RAM are generated and served without ever
materializing densely).
"""
from __future__ import annotations

import os
import tempfile
from typing import Callable, Dict, List, Optional

import numpy as np


class FeatureStore:
    """Base class for registered feature-store backends.

    Subclasses implement :meth:`_rows` (the raw row copy-out) and the
    writer half (:meth:`create` + :meth:`write_chunk`); ``name`` is filled
    in by :func:`register_store`.  The base class owns the ndarray facade
    and the gather accounting every benchmark reads: ``gather_calls`` /
    ``bytes_gathered`` count the traffic that actually hit the backing
    store (a device-side cache hit never shows up here — that is the
    point of the cache).
    """

    name: str = "?"

    def __init__(self, n_nodes: int, feat_dim: int,
                 dtype=np.float32) -> None:
        self.n_nodes = int(n_nodes)
        self.feat_dim = int(feat_dim)
        self.dtype = np.dtype(dtype)
        self.gather_calls = 0
        self.bytes_gathered = 0
        self._sealed = False

    # -- ndarray facade (what dataset.features consumers rely on) -----------
    @property
    def shape(self) -> tuple:
        return (self.n_nodes, self.feat_dim)

    @property
    def ndim(self) -> int:
        return 2

    @property
    def nbytes(self) -> int:
        return self.n_nodes * self.feat_dim * self.dtype.itemsize

    def __len__(self) -> int:
        return self.n_nodes

    def __getitem__(self, idx) -> np.ndarray:
        """Fancy row indexing == a counted gather (the assemble_batch
        clamp-index path lands here unchanged)."""
        return self.gather(idx)

    # -- reads ---------------------------------------------------------------
    def gather(self, indices) -> np.ndarray:
        """Copy the given rows out of the store: ``[len(indices), d]``.

        Every call is counted (``gather_calls``/``bytes_gathered``) — this
        is the host/disk traffic the staged pipeline overlaps and the
        hot-vertex cache exists to avoid.
        """
        idx = np.asarray(indices, dtype=np.int64)
        out = self._rows(idx)
        self.gather_calls += 1
        self.bytes_gathered += out.nbytes
        return out

    def as_array(self) -> np.ndarray:
        """The full dense matrix (tests / small stores only — defeats the
        purpose at scale)."""
        return self._rows(np.arange(self.n_nodes, dtype=np.int64))

    def _rows(self, idx: np.ndarray) -> np.ndarray:
        raise NotImplementedError

    # -- writes (chunked, for out-of-core generation) ------------------------
    @classmethod
    def create(cls, n_nodes: int, feat_dim: int, dtype=np.float32,
               **kwargs) -> "FeatureStore":
        """An empty writable store; fill with :meth:`write_chunk`, then
        :meth:`seal`."""
        raise NotImplementedError

    def write_chunk(self, start: int, rows: np.ndarray) -> None:
        """Write ``rows`` at row offset ``start``.  Chunked generation
        never holds more than one chunk in RAM."""
        raise NotImplementedError

    def seal(self) -> "FeatureStore":
        """Finish writing; the store becomes read-only.  Returns self."""
        self._sealed = True
        return self

    def _check_write(self, start: int, rows: np.ndarray) -> None:
        if self._sealed:
            raise ValueError(f"{self.name} store is sealed (read-only); "
                             "write_chunk is only valid before seal()")
        if rows.shape[1:] != (self.feat_dim,):
            raise ValueError(f"chunk width {rows.shape[1:]} != feat_dim "
                             f"({self.feat_dim},)")
        if start < 0 or start + len(rows) > self.n_nodes:
            raise ValueError(f"chunk [{start}, {start + len(rows)}) out of "
                             f"range for {self.n_nodes} rows")

    @classmethod
    def from_array(cls, features: np.ndarray, *, chunk_rows: int = 65536,
                   **kwargs) -> "FeatureStore":
        """Wrap an existing dense matrix (written through the chunked
        writer, so the mmap backend streams it to disk)."""
        features = np.asarray(features)
        store = cls.create(features.shape[0], features.shape[1],
                           dtype=features.dtype, **kwargs)
        for s in range(0, features.shape[0], chunk_rows):
            store.write_chunk(s, features[s:s + chunk_rows])
        return store.seal()

    def close(self) -> None:
        """Release backing resources (files for mmap).  Idempotent."""

    def __enter__(self) -> "FeatureStore":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


_STORES: Dict[str, type] = {}


def register_store(name: str) -> Callable:
    """Class decorator: register a :class:`FeatureStore` backend (the
    same pluggable contract as ``engine.register_format`` — stores are
    registered as classes because each instance binds one matrix)."""
    def deco(cls):
        cls.name = name
        _STORES[name] = cls
        return cls
    return deco


def get_store(name: str) -> type:
    try:
        return _STORES[name]
    except KeyError:
        raise ValueError(f"unknown feature store {name!r}; registered "
                         f"stores: {sorted(_STORES)}") from None


def available_stores() -> List[str]:
    return sorted(_STORES)


@register_store("host")
class HostStore(FeatureStore):
    """Host-RAM backend: one contiguous ndarray — the software stand-in
    for the paper's pinned host staging buffers.  Features never become a
    device array; only gathered frontier rows do."""

    def __init__(self, n_nodes: int, feat_dim: int, dtype=np.float32,
                 data: Optional[np.ndarray] = None) -> None:
        super().__init__(n_nodes, feat_dim, dtype)
        self._data = data if data is not None \
            else np.empty((self.n_nodes, self.feat_dim), self.dtype)

    @classmethod
    def create(cls, n_nodes: int, feat_dim: int, dtype=np.float32,
               **kwargs) -> "HostStore":
        return cls(n_nodes, feat_dim, dtype)

    def write_chunk(self, start: int, rows: np.ndarray) -> None:
        self._check_write(start, rows)
        self._data[start:start + len(rows)] = rows

    def _rows(self, idx: np.ndarray) -> np.ndarray:
        return self._data[idx]


@register_store("mmap")
class MmapStore(FeatureStore):
    """Memory-mapped ``.npy`` backend — features live on disk; the OS
    page cache is the only RAM they occupy.  The ``.npy`` header carries
    shape/dtype, so a store is a single self-describing file that
    ``MmapStore.open(path)`` reattaches to.

    Created without a path, the store owns a tempfile and unlinks it on
    :meth:`close`.
    """

    def __init__(self, mmap: np.memmap, path: str,
                 owns_path: bool = False) -> None:
        super().__init__(mmap.shape[0], mmap.shape[1], mmap.dtype)
        self._mmap: Optional[np.memmap] = mmap
        self.path = path
        self._owns_path = owns_path

    @classmethod
    def create(cls, n_nodes: int, feat_dim: int, dtype=np.float32,
               path: Optional[str] = None, **kwargs) -> "MmapStore":
        owns = path is None
        if owns:
            fd, path = tempfile.mkstemp(suffix=".npy",
                                        prefix="featurestore-")
            os.close(fd)
        mm = np.lib.format.open_memmap(path, mode="w+", dtype=np.dtype(dtype),
                                       shape=(int(n_nodes), int(feat_dim)))
        return cls(mm, path, owns_path=owns)

    @classmethod
    def open(cls, path: str) -> "MmapStore":
        store = cls(np.lib.format.open_memmap(path, mode="r"), path)
        store._sealed = True
        return store

    def write_chunk(self, start: int, rows: np.ndarray) -> None:
        self._check_write(start, rows)
        self._mmap[start:start + len(rows)] = rows

    def seal(self) -> "MmapStore":
        """Flush and reopen read-only — a sealed store can be shared
        across processes via its path."""
        self._mmap.flush()
        self._mmap = np.lib.format.open_memmap(self.path, mode="r")
        return super().seal()

    def _rows(self, idx: np.ndarray) -> np.ndarray:
        # fancy indexing on a memmap reads only the touched pages and
        # returns a real in-RAM ndarray — the "zero-copy gather" analogue:
        # transfer is proportional to the frontier, never to n_nodes
        return np.asarray(self._mmap[idx])

    def close(self) -> None:
        if self._mmap is not None:
            if not self._sealed:
                self._mmap.flush()
            self._mmap = None
        if self._owns_path and self.path and os.path.exists(self.path):
            os.unlink(self.path)
            self._owns_path = False

    def __del__(self):  # best-effort tempfile cleanup
        try:
            self.close()
        except Exception:  # noqa: BLE001 — interpreter teardown
            pass
