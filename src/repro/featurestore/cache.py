"""Degree-keyed hot-vertex device cache in front of a FeatureStore.

Power-law graphs concentrate frontier traffic on a few hub vertices (the
paper's Fig. 11 utilization analysis leans on exactly this skew), so a
small device-resident cache of the top-k highest-degree vertices absorbs
a large fraction of the gather volume: a frontier row that hits the cache
never touches the backing store — no host-RAM read for ``host`` stores,
no disk page for ``mmap`` stores, no host→device transfer for the row.

Two regions share the cache's ``capacity`` rows:

* **pinned** — the ``pinned`` highest-degree vertices, gathered once at
  construction and never evicted (the degree key);
* **dynamic** — the remaining slots form an LRU of recently missed
  vertices, so warm frontiers hit even below the degree cut.

``gather(ids)`` is bit-exact with ``store.gather(ids)`` (cached rows are
verbatim copies), so the cache changes traffic, never values — the
batch-exact ``(seed, epoch, batch_idx)`` resume contract is untouched.
Hit/miss/eviction counters surface in Trainer metrics and
``BENCH_feature_store.json``.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Dict, Optional

import numpy as np


class HotVertexCache:
    """``capacity`` feature rows pinned/LRU-cached in front of ``store``.

    Parameters
    ----------
    store: the backing :class:`~repro.featurestore.FeatureStore` (anything
        with ``gather``/``shape``).
    degrees: ``[n_nodes]`` vertex degrees — the pin key (ties broken by
        vertex id, deterministically).
    capacity: total cached rows.
    pinned: rows reserved for the top-degree vertices (default: half the
        capacity; the rest is the LRU region).  ``pinned=capacity`` makes
        the cache fully static.
    """

    def __init__(self, store, degrees: np.ndarray, capacity: int,
                 pinned: Optional[int] = None) -> None:
        if capacity < 1:
            raise ValueError(f"cache capacity must be >= 1, got {capacity}")
        n = store.shape[0]
        capacity = min(int(capacity), n)
        if pinned is None:
            pinned = capacity // 2 if capacity > 1 else capacity
        pinned = min(int(pinned), capacity)
        self.store = store
        self.capacity = capacity
        self.n_pinned = pinned
        d = store.shape[1]
        degrees = np.asarray(degrees)
        if degrees.shape[0] != n:
            raise ValueError(f"degrees has {degrees.shape[0]} entries for "
                             f"a {n}-row store")
        # stable sort on -degree: equal degrees pin the lower vertex id, so
        # the pinned set is deterministic across runs/platforms
        hot = np.argsort(-degrees.astype(np.int64),
                         kind="stable")[:pinned].astype(np.int64)
        self._rows = np.empty((capacity, d), store.dtype)
        if pinned:
            self._rows[:pinned] = store.gather(hot)
        self.pinned_ids = frozenset(int(v) for v in hot)
        self._slot: Dict[int, int] = {int(v): i for i, v in enumerate(hot)}
        # LRU over the dynamic region: vertex id -> slot, oldest first
        self._lru: "OrderedDict[int, int]" = OrderedDict()
        self._free = list(range(capacity - 1, pinned - 1, -1))
        self._device_rows = None
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.insertions = 0
        self.bytes_served = 0       # bytes returned to callers, total
        self.bytes_from_store = 0   # bytes that actually hit the store
        self.warm_bytes = pinned * d * store.dtype.itemsize

    # -- the gather front door ----------------------------------------------
    def gather(self, indices) -> np.ndarray:
        """``store.gather(indices)``, bit-exact, fetching only the rows the
        cache does not hold.  Counters count REQUESTED rows (duplicates
        included — a padded frontier repeats vertex 0, and every repeat is
        traffic the cache absorbed)."""
        idx = np.asarray(indices, dtype=np.int64)
        out = np.empty((len(idx),) + self.store.shape[1:], self.store.dtype)
        slots = np.fromiter((self._slot.get(int(v), -1) for v in idx),
                            np.int64, len(idx))
        hit = slots >= 0
        n_hit = int(hit.sum())
        self.hits += n_hit
        self.misses += len(idx) - n_hit
        if n_hit:
            out[hit] = self._rows[slots[hit]]
            for v in idx[hit]:
                v = int(v)
                if v in self._lru:          # refresh recency on LRU hits
                    self._lru.move_to_end(v)
        miss_pos = np.flatnonzero(~hit)
        if len(miss_pos):
            uniq, inv = np.unique(idx[miss_pos], return_inverse=True)
            fetched = self.store.gather(uniq)
            self.bytes_from_store += fetched.nbytes
            out[miss_pos] = fetched[inv]
            self._insert(uniq, fetched)
        self.bytes_served += out.nbytes
        return out

    # ndarray-facade passthroughs so the cache drops in anywhere a
    # FeatureStore (or dense matrix) is accepted
    def __getitem__(self, idx) -> np.ndarray:
        return self.gather(idx)

    def __len__(self) -> int:
        return self.store.shape[0]

    @property
    def shape(self) -> tuple:
        return self.store.shape

    @property
    def dtype(self):
        return self.store.dtype

    # -- LRU region -----------------------------------------------------------
    def _insert(self, ids: np.ndarray, rows: np.ndarray) -> None:
        """Install freshly fetched rows in the dynamic region, evicting
        least-recently-used entries.  Pinned slots are structurally
        untouchable: eviction only ever recycles LRU slots."""
        room = self.capacity - self.n_pinned
        if room <= 0:
            return
        if len(ids) > room:         # only the tail fits; keep it LRU-fresh
            ids, rows = ids[-room:], rows[-room:]
        for v, row in zip(ids, rows):
            v = int(v)
            if self._free:
                slot = self._free.pop()
            else:
                old, slot = self._lru.popitem(last=False)  # oldest out
                del self._slot[old]
                self.evictions += 1
            self._rows[slot] = row
            self._slot[v] = slot
            self._lru[v] = slot
            self.insertions += 1

    # -- metrics ---------------------------------------------------------------
    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def stats(self) -> Dict[str, float]:
        return {"capacity": self.capacity, "pinned": self.n_pinned,
                "hits": self.hits, "misses": self.misses,
                "hit_rate": self.hit_rate, "evictions": self.evictions,
                "insertions": self.insertions,
                "bytes_served": self.bytes_served,
                "bytes_from_store": self.bytes_from_store}

    def reset_stats(self) -> None:
        self.hits = self.misses = 0
        self.evictions = self.insertions = 0
        self.bytes_served = self.bytes_from_store = 0

    # -- device residency -------------------------------------------------------
    @property
    def device_rows(self):
        """The pinned block as a committed device array (built once).

        This is the block that physically lives in device memory; the host
        mirror above assembles frontiers from the same bytes (on the
        simulated CPU backend the two share RAM — the honest win the
        counters record is the STORE traffic avoided, which for ``mmap``
        is disk).  The serving path will gather from this block directly.
        """
        if self._device_rows is None:
            import jax.numpy as jnp
            self._device_rows = jnp.asarray(self._rows[:self.n_pinned])
        return self._device_rows
