# Out-of-core feature store subsystem: node features behind a pluggable
# backend registry (host RAM / mmap'd disk), streamed to the device one
# frontier at a time by the staged input pipeline
# (repro.data.StagedPrefetcher), with a degree-keyed hot-vertex device
# cache absorbing hub traffic.  See README "Feature store".
from .cache import HotVertexCache
from .store import (FeatureStore, HostStore, MmapStore, available_stores,
                    get_store, register_store)

__all__ = [
    "FeatureStore", "HostStore", "MmapStore", "HotVertexCache",
    "register_store", "get_store", "available_stores",
]
