"""stablelm-3b [dense] — 32L d_model=2560 32H (GQA kv=32) d_ff=6912
vocab=50304 (hf:stabilityai/stablelm family)."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="stablelm-3b", family="dense",
    n_layers=32, d_model=2560, n_heads=32, n_kv_heads=32, d_ff=6912,
    vocab=50304, head_dim=80, rope_theta=10_000.0,
)

SMOKE = CONFIG.scaled(n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                      d_ff=128, vocab=512, head_dim=16)
