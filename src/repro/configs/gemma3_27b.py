"""gemma3-27b [dense] — 62L d_model=5376 32H (GQA kv=16) d_ff=21504
vocab=262144; 5 local (sliding-window 1024) : 1 global attention pattern,
128k context (hf:google/gemma-3 family)."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-27b", family="dense",
    n_layers=62, d_model=5376, n_heads=32, n_kv_heads=16, d_ff=21504,
    vocab=262144, head_dim=128,
    sliding_window=1024, global_every=6, rope_theta=1_000_000.0,
)

SMOKE = CONFIG.scaled(n_layers=6, d_model=64, n_heads=4, n_kv_heads=2,
                      d_ff=128, vocab=512, head_dim=16)
