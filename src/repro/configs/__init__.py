"""Config registry: ``--arch <id>`` → ArchConfig, plus the assigned
input-shape grid and the per-cell applicability policy (DESIGN
§Arch-applicability).

40 cells = 10 archs × 4 shapes; 33 runnable + 7 documented long_500k skips
(pure full-attention archs would need a 500k² score matrix / 500k KV per
layer with no sub-quadratic structure)."""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, Optional, Tuple

from repro.models.config import ArchConfig

_ARCH_MODULES = {
    "zamba2-1.2b": "zamba2_1p2b",
    "stablelm-3b": "stablelm_3b",
    "gemma3-27b": "gemma3_27b",
    "llama3.2-1b": "llama3p2_1b",
    "yi-6b": "yi_6b",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "mamba2-1.3b": "mamba2_1p3b",
    "chameleon-34b": "chameleon_34b",
}

ARCH_NAMES = tuple(_ARCH_MODULES)


def _module(name: str):
    if name not in _ARCH_MODULES:
        raise KeyError(f"unknown arch '{name}'; available: {ARCH_NAMES}")
    return importlib.import_module(f"repro.configs.{_ARCH_MODULES[name]}")


def get_config(name: str) -> ArchConfig:
    return _module(name).CONFIG


def get_smoke(name: str) -> ArchConfig:
    return _module(name).SMOKE


# ---------------------------------------------------------------------------
# the assigned shape grid
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Shape:
    name: str
    kind: str            # 'train' | 'prefill' | 'decode'
    seq_len: int
    global_batch: int


SHAPES: Dict[str, Shape] = {
    "train_4k": Shape("train_4k", "train", 4_096, 256),
    "prefill_32k": Shape("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": Shape("decode_32k", "decode", 32_768, 128),
    "long_500k": Shape("long_500k", "decode", 524_288, 1),
}

# archs with a sub-quadratic long-context path (DESIGN §Arch-applicability):
# SSM state (mamba2), hybrid state + one shared-block KV (zamba2), and
# gemma3's 5:1 sliding-window locality (global layers are O(L)/token at
# decode, which is the runnable budget).
_LONG_OK = {"zamba2-1.2b", "mamba2-1.3b", "gemma3-27b"}


def applicable(arch: str, shape: str) -> Tuple[bool, str]:
    if shape not in SHAPES:
        raise KeyError(shape)
    if shape == "long_500k" and arch not in _LONG_OK:
        return False, ("pure full-attention stack: 500k decode has no "
                       "sub-quadratic path (KV cache + O(L) scores per "
                       "token over 524288 positions) — documented skip")
    return True, ""


def all_cells(include_skipped: bool = False):
    """Yield (arch, shape, runnable, reason) for the 40-cell grid."""
    for arch in ARCH_NAMES:
        for shape in SHAPES:
            ok, reason = applicable(arch, shape)
            if ok or include_skipped:
                yield arch, shape, ok, reason
