"""mamba2-1.3b [ssm] — SSD, attention-free (arXiv:2405.21060):
48L d_model=2048, d_inner=4096 (expand 2), ssm_state=128, head_dim=64
(64 SSM heads), vocab=50280."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=1, n_kv_heads=1, d_ff=0,
    vocab=50280,
    ssm_state=128, ssm_expand=2, ssm_head_dim=64, ssm_conv=4,
)

SMOKE = CONFIG.scaled(n_layers=3, d_model=64, vocab=512, ssm_head_dim=16)
