"""llama4-maverick-400b-a17b [moe] — 48L d_model=5120 40H (GQA kv=8)
d_ff=8192(expert) vocab=202048, MoE 128 experts top-1, dense:moe layers
interleaved 1:1, early fusion (hf:meta-llama/Llama-4 family)."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="llama4-maverick-400b-a17b", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, d_ff=8192,
    vocab=202048, head_dim=128,
    moe_experts=128, moe_topk=1, moe_interleave=2, rope_theta=500_000.0,
    modality_stub="vision",
)

SMOKE = CONFIG.scaled(n_layers=4, d_model=64, n_heads=4, n_kv_heads=2,
                      d_ff=128, vocab=512, head_dim=16, moe_experts=8)
