"""chameleon-34b [vlm] — early-fusion token backbone (arXiv:2405.09818):
48L d_model=8192 64H (GQA kv=8) d_ff=22016, fused text+VQ-image vocab
65536.  The VQ image tokenizer is a STUB: image regions arrive as
precomputed token ids inside the fused vocab."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="chameleon-34b", family="dense",
    n_layers=48, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=22016,
    vocab=65536, head_dim=128, rope_theta=10_000.0,
    modality_stub="vision",
)

SMOKE = CONFIG.scaled(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                      d_ff=128, vocab=512, head_dim=16)
