"""zamba2-1.2b [hybrid] — Mamba2 backbone + shared attention block
(arXiv:2411.15242).  38L d_model=2048 32H (GQA kv=32) d_ff=8192 vocab=32000,
ssm_state=64; the single shared transformer block is applied every 6th layer
(6 applications over 38 layers)."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32, d_ff=8192,
    vocab=32000, head_dim=64,
    ssm_state=64, ssm_expand=2, ssm_head_dim=64, ssm_conv=4,
    attn_every=6,
)

SMOKE = CONFIG.scaled(n_layers=7, d_model=64, n_heads=4, n_kv_heads=4,
                      d_ff=128, vocab=512, head_dim=16, ssm_head_dim=16)
