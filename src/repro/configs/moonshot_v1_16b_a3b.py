"""moonshot-v1-16b-a3b [moe] — kimi/moonlight-style fine-grained MoE:
48L d_model=2048 16H (kv=16) expert d_ff=1408 vocab=163840, 64 experts
top-6, all layers MoE (hf:moonshotai/Moonlight-16B-A3B)."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="moonshot-v1-16b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=16, n_kv_heads=16, d_ff=1408,
    vocab=163840, head_dim=128,
    moe_experts=64, moe_topk=6, moe_interleave=1, rope_theta=50_000.0,
)

SMOKE = CONFIG.scaled(n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                      d_ff=32, vocab=512, head_dim=16, moe_experts=8,
                      moe_topk=2)
