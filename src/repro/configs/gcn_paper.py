"""The paper's own training configs (§5.1): 2-layer GCN / GraphSAGE,
hidden 256, GraphSAGE NS fanouts (25, 10), batch 1024, on Flickr / Reddit /
Yelp / AmazonProducts."""
from repro.graph.datasets import DATASET_STATS
from repro.models.gcn_model import GCNConfig

FANOUTS = (10, 25)        # layer order: hop1 fanout 25 is the deeper sample
BATCH = 1024
HIDDEN = 256

def gcn_config(dataset: str, model: str = "gcn",
               dataflow: str = "ours") -> GCNConfig:
    st = DATASET_STATS[dataset]
    return GCNConfig(name=f"{model}-{dataset}", feat_dim=st.feat_dim,
                     hidden=HIDDEN, n_classes=st.n_classes, n_layers=2,
                     model=model, dataflow=dataflow,
                     multilabel=st.multilabel)

CONFIGS = {
    f"{m}-{d}": gcn_config(d, m)
    for d in DATASET_STATS for m in ("gcn", "sage")
}
