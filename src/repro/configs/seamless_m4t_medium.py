"""seamless-m4t-medium [audio] — encoder-decoder multimodal backbone
(arXiv:2308.11596): 12L enc + 12L dec, d_model=1024 16H (kv=16) d_ff=4096
vocab=256206.  The speech frontend is a STUB per the assignment:
input_specs() provides precomputed frame embeddings; the decoder trains
teacher-forced with dec_len = seq_len // 4 text tokens."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-medium", family="encdec",
    n_layers=12, enc_layers=12, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=4096, vocab=256206, head_dim=64, rope_theta=10_000.0,
    modality_stub="audio",
)

SMOKE = CONFIG.scaled(n_layers=2, enc_layers=2, d_model=64, n_heads=4,
                      n_kv_heads=4, d_ff=128, vocab=512, head_dim=16)
