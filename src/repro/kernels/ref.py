"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth for the per-kernel allclose sweeps in
``tests/test_kernels.py`` — no Pallas, no tiling, just the math.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def gemm_ref(x: jnp.ndarray, w: jnp.ndarray, bias: Optional[jnp.ndarray] = None,
             *, relu: bool = False) -> jnp.ndarray:
    """Combination engine oracle: ``relu(x @ w + bias)`` in fp32 accumulation."""
    out = jnp.dot(x, w, preferred_element_type=jnp.float32)
    if bias is not None:
        out = out + bias.astype(jnp.float32)
    if relu:
        out = jnp.maximum(out, 0.0)
    return out.astype(x.dtype)


def spmm_ref(rows: jnp.ndarray, cols: jnp.ndarray, vals: jnp.ndarray,
             x: jnp.ndarray, n_dst: int) -> jnp.ndarray:
    """Aggregation engine oracle: ``y[r] += v * x[c]`` via segment-sum.

    Padding edges carry ``val == 0`` so they are no-ops regardless of their
    (row, col) values.
    """
    gathered = x[cols].astype(jnp.float32) * vals.astype(jnp.float32)[:, None]
    out = jax.ops.segment_sum(gathered, rows, num_segments=n_dst)
    return out.astype(x.dtype)


def spmm_t_ref(rows: jnp.ndarray, cols: jnp.ndarray, vals: jnp.ndarray,
               e: jnp.ndarray, n_src: int) -> jnp.ndarray:
    """Backward-order aggregation oracle: ``y = Aᵀ e`` walking the same COO
    column-major (the Graph Converter contract — no Aᵀ table)."""
    gathered = e[rows].astype(jnp.float32) * vals.astype(jnp.float32)[:, None]
    out = jax.ops.segment_sum(gathered, cols, num_segments=n_src)
    return out.astype(e.dtype)


def mha_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
            *, causal: bool = True) -> jnp.ndarray:
    """Flash-attention oracle: q/k/v [bh, s, hd] → [bh, s, hd]."""
    hd = q.shape[-1]
    logits = jnp.einsum("bqd,bkd->bqk", q, k,
                        preferred_element_type=jnp.float32)
    logits = logits / jnp.sqrt(hd).astype(jnp.float32)
    if causal:
        i = jnp.arange(q.shape[1])[:, None]
        j = jnp.arange(k.shape[1])[None, :]
        logits = jnp.where(j <= i, logits, jnp.finfo(jnp.float32).min)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bqk,bkd->bqd", probs, v)
