"""Public jit'd wrappers around the Pallas kernels.

On TPU the kernels compile natively; everywhere else (this CPU container)
they run in ``interpret=True`` mode, which executes the kernel body on the
Python/numpy path — same tiling, same math, no MXU.  Callers never pass
``interpret`` themselves; they get the right backend automatically.

The wrappers also absorb tile-alignment padding so layer code can call them
on the paper's natural sizes (64-node core blocks, ragged feature dims).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from . import gemm as _gemm
from . import spmm as _spmm
from . import ref as ref  # re-export for tests/benchmarks


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(x: jnp.ndarray, axis: int, mult: int) -> jnp.ndarray:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def gemm(x: jnp.ndarray, w: jnp.ndarray, bias: Optional[jnp.ndarray] = None,
         *, relu: bool = False, bm: int = 128, bn: int = 128, bk: int = 128
         ) -> jnp.ndarray:
    """Tile-padding wrapper over :func:`repro.kernels.gemm.gemm`."""
    m, k = x.shape
    _, n = w.shape
    xp = _pad_to(_pad_to(x, 0, bm), 1, bk)
    wp = _pad_to(_pad_to(w, 0, bk), 1, bn)
    bp = _pad_to(bias, 0, bn) if bias is not None else None
    out = _gemm.gemm(xp, wp, bp, bm=bm, bn=bn, bk=bk, relu=relu,
                     interpret=not _on_tpu())
    return out[:m, :n]


def spmm(rows: jnp.ndarray, cols: jnp.ndarray, vals: jnp.ndarray,
         x: jnp.ndarray, n_dst: int, *, bd: int = 128, be: int = 256
         ) -> jnp.ndarray:
    """Tile-padding wrapper over :func:`repro.kernels.spmm.spmm`."""
    d = x.shape[1]
    rp = _pad_to(rows, 0, be)
    cp = _pad_to(cols, 0, be)
    vp = _pad_to(vals, 0, be)          # zero padding ⇒ no-op edges
    xp = _pad_to(x, 1, bd)
    out = _spmm.spmm(rp, cp, vp, xp, n_dst, bd=bd, be=be,
                     interpret=not _on_tpu())
    return out[:, :d]


def spmm_block(rows: jnp.ndarray, cols: jnp.ndarray, vals: jnp.ndarray,
               x: jnp.ndarray, dpc: int, *, bd: int = 128, be: int = 256
               ) -> jnp.ndarray:
    """Tile-padding wrapper over :func:`repro.kernels.spmm.spmm_block`.

    Arguments follow the Block-Message tile layout
    (:class:`repro.core.blockmsg.BlockTiles`): [n_blocks, e_blk] edge arrays
    with block-local row offsets; returns [n_blocks * dpc, d].
    """
    d = x.shape[1]
    rp = _pad_to(rows, 1, be)
    cp = _pad_to(cols, 1, be)
    vp = _pad_to(vals, 1, be)          # zero padding ⇒ no-op edges
    xp = _pad_to(x, 1, bd)
    out = _spmm.spmm_block(rp, cp, vp, xp, dpc, bd=bd, be=be,
                           interpret=not _on_tpu())
    return out[:, :d]
