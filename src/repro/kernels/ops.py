"""Public jit'd wrappers around the Pallas kernels.

On TPU the kernels compile natively; everywhere else (this CPU container)
the COO/ELL Pallas kernels run in ``interpret=True`` mode, which executes
the kernel body on the Python/numpy path — same tiling, same math, no MXU.
Callers never pass ``interpret`` themselves; they get the right backend
automatically.  The pre-reduced ELL apply additionally has a pure-XLA twin
(`gather + degree-axis reduction`, no scatter) used off-TPU, where an
interpreted kernel would be a correctness tool rather than a hot path.

The wrappers also absorb tile-alignment padding so layer code can call them
on the paper's natural sizes (64-node core blocks, ragged feature dims).
Padding contract: padded edge/table entries carry ``val == 0`` AND their
column index is routed AWAY from real data — COO padding points past the
source range (one-hot matches nothing, gathers nothing), ELL padding points
at the plan's dedicated zero row.  Padding must never touch real row 0.

``ell_aggregate`` is the one place the pre-reduced engine's ``custom_vjp``
is registered: forward walks the plan's dst-major tables, backward walks
the column-major tables of the SAME edges with the SAME kernel
(transpose-free, scatter-free).  The ``ell`` engine format
(:mod:`repro.engine.formats`), ``repro.distributed.aggregate`` and the
engine train step all inherit their backward from here.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

# shared zero-cotangent helper (historical local name `_zero_ct` kept for
# existing importers)
from repro.cotangents import zero_ct as _zero_ct

from . import gemm as _gemm
from . import spmm as _spmm
from . import ref as ref  # re-export for tests/benchmarks


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(x: jnp.ndarray, axis: int, mult: int,
            value: float = 0) -> jnp.ndarray:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def gemm(x: jnp.ndarray, w: jnp.ndarray, bias: Optional[jnp.ndarray] = None,
         *, relu: bool = False, bm: int = 128, bn: int = 128, bk: int = 128
         ) -> jnp.ndarray:
    """Tile-padding wrapper over :func:`repro.kernels.gemm.gemm`."""
    m, k = x.shape
    _, n = w.shape
    xp = _pad_to(_pad_to(x, 0, bm), 1, bk)
    wp = _pad_to(_pad_to(w, 0, bk), 1, bn)
    bp = _pad_to(bias, 0, bn) if bias is not None else None
    out = _gemm.gemm(xp, wp, bp, bm=bm, bn=bn, bk=bk, relu=relu,
                     interpret=not _on_tpu())
    return out[:m, :n]


def spmm(rows: jnp.ndarray, cols: jnp.ndarray, vals: jnp.ndarray,
         x: jnp.ndarray, n_dst: int, *, bd: int = 128, be: int = 256
         ) -> jnp.ndarray:
    """Tile-padding wrapper over :func:`repro.kernels.spmm.spmm`.

    Padding edges point past the source range (col = n_src): their gather
    one-hot row is all-zero, so they move no data at all — val == 0 alone
    would still gather real row 0 and zero it after the fact.
    """
    n_src, d = x.shape
    rp = _pad_to(rows, 0, be)
    cp = _pad_to(cols, 0, be, value=n_src)   # out-of-range ⇒ gathers nothing
    vp = _pad_to(vals, 0, be)                # and weight 0 ⇒ scatters nothing
    xp = _pad_to(x, 1, bd)
    out = _spmm.spmm(rp, cp, vp, xp, n_dst, bd=bd, be=be,
                     interpret=not _on_tpu())
    return out[:, :d]


def spmm_block(rows: jnp.ndarray, cols: jnp.ndarray, vals: jnp.ndarray,
               x: jnp.ndarray, dpc: int, *, bd: int = 128, be: int = 256
               ) -> jnp.ndarray:
    """Tile-padding wrapper over :func:`repro.kernels.spmm.spmm_block`.

    Arguments follow the Block-Message tile layout
    (:class:`repro.core.blockmsg.BlockTiles`): [n_blocks, e_blk] edge arrays
    with block-local row offsets; returns [n_blocks * dpc, d].  Padding
    edges are routed past the source range like :func:`spmm`'s.
    """
    n_src, d = x.shape
    rp = _pad_to(rows, 1, be)
    cp = _pad_to(cols, 1, be, value=n_src)   # out-of-range ⇒ gathers nothing
    vp = _pad_to(vals, 1, be)
    xp = _pad_to(x, 1, bd)
    out = _spmm.spmm_block(rp, cp, vp, xp, dpc, bd=bd, be=be,
                           interpret=not _on_tpu())
    return out[:, :d]


# ---------------------------------------------------------------------------
# Pre-reduced ELL engine.
# ---------------------------------------------------------------------------
def _tuned_tiles(br, bd, bs):
    if br is None or bd is None or bs is None:
        from repro.kernels.tune import get_config
        cfg = get_config()
        br = cfg["br"] if br is None else br
        bd = cfg["bd"] if bd is None else bd
        bs = cfg["bs"] if bs is None else bs
    return br, bd, bs


def spmm_ell(cols: jnp.ndarray, vals: jnp.ndarray, x: jnp.ndarray, *,
             br: Optional[int] = None, bd: Optional[int] = None,
             bs: Optional[int] = None) -> jnp.ndarray:
    """Tile-padding wrapper over :func:`repro.kernels.spmm.spmm_ell`.

    ``cols``/``vals``: one [nb, K] bucket of an
    :class:`repro.kernels.edgeplan.EllTables` whose padding entries point at
    column ``n_src`` — this wrapper appends that dedicated zero row to ``x``
    before tiling, so padding gathers zeros by construction.  Tile sizes
    default to the autotuned config (:mod:`repro.kernels.tune`).
    """
    br, bd, bs = _tuned_tiles(br, bd, bs)
    nb, _ = cols.shape
    n_src, d = x.shape
    xz = jnp.concatenate([x, jnp.zeros((1, d), x.dtype)], axis=0)
    xp = _pad_to(_pad_to(xz, 0, bs), 1, bd)
    cp = _pad_to(cols, 0, br, value=n_src)   # pad rows → all-zero-row entries
    vp = _pad_to(vals, 0, br)
    out = _spmm.spmm_ell(cp, vp, xp, br=br, bd=bd, bs=bs,
                         interpret=not _on_tpu())
    return out[:nb, :d]


def spmm_ell_t(t_cols: jnp.ndarray, t_vals: jnp.ndarray, e: jnp.ndarray, *,
               br: Optional[int] = None, bd: Optional[int] = None,
               bs: Optional[int] = None) -> jnp.ndarray:
    """Transpose walk through the same wrapper: ``Aᵀ e`` over the plan's
    column-major tables — see :func:`repro.kernels.spmm.spmm_ell_t`."""
    return spmm_ell(t_cols, t_vals, e, br=br, bd=bd, bs=bs)


def _ell_walk(cols_list, vals_list, inv, x, use_pallas: Optional[bool]):
    """One gather-accumulate pass over bucketed ELL tables.

    ``use_pallas=None`` picks the backend default (native kernel on TPU,
    pure-XLA elsewhere — same math, no scatter either way).  The XLA path
    unrolls the degree axis into K one-row gathers with a fused
    multiply-add: 1-D row gathers vectorize where a [nb, K, d] temporary
    does not (measured ~7x over segment-sum on CPU at smoke sizes), and
    ``mode="fill"`` realizes the plan's dedicated zero row — the padding
    column id ``n_src`` is out of range and gathers exact zeros, touching
    no real data.  Output row *r* is row ``inv[r]`` of the concatenated
    bucket outputs; rows with no edges have ``inv[r]`` past the end and
    fill with zeros without computing anything.
    """
    if use_pallas is None:
        use_pallas = _on_tpu()
    d = x.shape[-1]
    outs = []
    if use_pallas:
        for c, v in zip(cols_list, vals_list):
            if c.shape[0]:
                outs.append(spmm_ell(c, v, x))
    else:
        for c, v in zip(cols_list, vals_list):
            if not c.shape[0]:
                continue
            acc = jnp.take(x, c[:, 0], axis=0, mode="fill",
                           fill_value=0) * v[:, 0:1]
            for k in range(1, c.shape[1]):
                acc = acc + jnp.take(x, c[:, k], axis=0, mode="fill",
                                     fill_value=0) * v[:, k:k + 1]
            outs.append(acc)
    cat = (jnp.concatenate(outs, axis=0) if outs
           else jnp.zeros((1, d), x.dtype))
    return jnp.take(cat, inv, axis=0, mode="fill", fill_value=0)


def ell_apply(tables: Dict, x: jnp.ndarray, *, transpose: bool = False,
              use_pallas: Optional[bool] = None) -> jnp.ndarray:
    """Forward (or transpose) ELL walk WITHOUT the custom_vjp — the building
    block the distributed aggregate composes around its collectives.

    ``transpose=True`` walks the column-major tables (``Aᵀ e``).
    ``use_pallas`` forces the kernel (tests run it in interpret mode off-TPU
    to exercise the exact Pallas body); ``None`` picks the backend default.

    Redundancy-merged plans (tables carrying the ``vv_*``/``vvt_*`` keys
    from :meth:`repro.kernels.edgeplan.EdgePlan.device_tables`) add one
    small pre-pass with the SAME kernel: forward computes the virtual
    partials ``z = V x`` and walks the main tables over ``[x; z]``; the
    transpose splits the extended cotangent and routes the virtual slice
    back through ``Vᵀ`` — ``dx = gₒ + Vᵀ g_v`` — so the transpose-free
    contract survives the rewrite.  The main bucket tables are identical
    in shape either way; the kernel never learns merging happened.
    """
    merged = "vv_cols" in tables
    if transpose:
        g = _ell_walk(tables["t_cols"], tables["t_vals"], tables["t_inv"],
                      x, use_pallas)
        if not merged:
            return g
        # vvt tables have one output row per ORIGINAL source: the static
        # split point n_src is their inv length (no scalar leaves in the
        # tables pytree — shapes carry the metadata).
        n_src = tables["vvt_inv"].shape[0]
        dz = _ell_walk(tables["vvt_cols"], tables["vvt_vals"],
                       tables["vvt_inv"], g[n_src:], use_pallas)
        return g[:n_src] + dz
    if merged:
        z = _ell_walk(tables["vv_cols"], tables["vv_vals"], tables["vv_inv"],
                      x, use_pallas)
        x = jnp.concatenate([x, z.astype(x.dtype)], axis=0)
    return _ell_walk(tables["cols"], tables["vals"], tables["inv"], x,
                     use_pallas)




@jax.custom_vjp
def ell_aggregate(tables: Dict, x: jnp.ndarray) -> jnp.ndarray:
    """``y = A @ x`` through a pre-reduced ELL plan — THE custom_vjp.

    ``tables`` is :meth:`repro.kernels.edgeplan.EdgePlan.device_tables`
    output (keys ``cols``/``vals``/``inv`` forward, ``t_*`` transpose).
    Forward walks the dst-major tables; the registered backward walks the
    column-major tables of the SAME edges with the SAME kernel — no ``Aᵀ``,
    no transposed residual (aggregation is linear in ``x``: the plan itself
    is the only residual), and no segment-sum scatter anywhere.  Plans with
    a virtual-vertex tier route through :func:`ell_apply`'s pre-pass in
    both directions with the same contract.
    """
    return ell_apply(tables, x)


def _ell_aggregate_fwd(tables, x):
    return ell_aggregate(tables, x), tables


def _ell_aggregate_bwd(tables, ct):
    dx = ell_apply(tables, ct, transpose=True)
    return _zero_ct(tables), dx


ell_aggregate.defvjp(_ell_aggregate_fwd, _ell_aggregate_bwd)
