"""Blocked GEMM Pallas kernel — the paper's combination engine on the MXU.

The FPGA core does block matrix multiplication on a 2-D MAC adder tree fed
from ping-pong Feature/Output buffers (paper §4.2, 256 TF32 MACs).  The TPU
equivalent is an MXU-tiled matmul with fp32 accumulation and the epilogue
(bias + ReLU, the GCN layer's σ) fused into the last K-step so the activation
never round-trips to HBM:

  * grid = (M/bm, N/bn, K/bk), K innermost so the VMEM accumulator scratch
    carries across the K-steps of one (i, j) tile;
  * BlockSpecs stage (bm, bk) of X and (bk, bn) of W into VMEM per step —
    the ping-pong buffering is what ``pallas_call`` pipelining does natively;
  * tile dims default to 128 = MXU lane width (the hardware-aligned multiple
    the roofline wants); fp32 accumulation matches the paper's
    TF32-multiply/FP32-accumulate MACs.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gemm_kernel(x_ref, w_ref, b_ref, o_ref, acc_ref, *, n_k: int,
                 relu: bool, has_bias: bool):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...], w_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == n_k - 1)
    def _epilogue():
        acc = acc_ref[...]
        if has_bias:
            acc = acc + b_ref[...].astype(jnp.float32)
        if relu:
            acc = jnp.maximum(acc, 0.0)
        o_ref[...] = acc.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "relu",
                                             "interpret"))
def gemm(x: jnp.ndarray, w: jnp.ndarray, bias: Optional[jnp.ndarray] = None,
         *, bm: int = 128, bn: int = 128, bk: int = 128, relu: bool = False,
         interpret: bool = False) -> jnp.ndarray:
    """``relu(x @ w + bias)`` with (bm, bn, bk) VMEM tiles.

    Shapes must be tile-aligned (pad first — the layer code pads node counts
    to the core multiple anyway); ``bias`` is [n], broadcast over rows.
    """
    m, k = x.shape
    k2, n = w.shape
    if k != k2:
        raise ValueError(f"inner dims mismatch: {k} vs {k2}")
    if m % bm or n % bn or k % bk:
        raise ValueError(f"shape ({m},{k})x({k},{n}) not divisible by "
                         f"tiles ({bm},{bn},{bk})")
    has_bias = bias is not None
    if not has_bias:
        bias = jnp.zeros((n,), x.dtype)
    bias2d = bias.reshape(1, n)  # TPU wants ≥2-D operands
    grid = (m // bm, n // bn, k // bk)
    kernel = functools.partial(_gemm_kernel, n_k=grid[2], relu=relu,
                               has_bias=has_bias)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, w, bias2d)
