# The paper's two compute hot-spots, as Pallas TPU kernels:
#   gemm.py  — combination engine (2-D MAC adder tree -> MXU tiles)
#   spmm.py  — aggregation engine (COO MAC chains -> dual one-hot matmuls)
#   flash.py — flash attention (the prefill memory wall found in §Perf)
# ops.py holds the jit'd public wrappers (interpret=True off-TPU),
# ref.py the pure-jnp oracles the tests sweep against.
from .ops import gemm, spmm, spmm_block
from .flash import flash_mha
from .ref import gemm_ref, mha_ref, spmm_ref, spmm_t_ref

__all__ = ["gemm", "spmm", "spmm_block", "flash_mha", "gemm_ref", "mha_ref",
           "spmm_ref", "spmm_t_ref"]
