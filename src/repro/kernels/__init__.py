# The paper's compute hot-spots, as Pallas TPU kernels:
#   gemm.py     — combination engine (2-D MAC adder tree -> MXU tiles)
#   spmm.py     — aggregation engine: legacy COO one-hot matmuls (reference
#                 arm) + the pre-reduced, src-tiled ELL family (hot path)
#   edgeplan.py — host-side ELLPACK plan builder (Block-Message merge as a
#                 layout; degree-bucketed, cached per graph)
#   tune.py     — tile/bucket autotuner (JSON-persisted winner)
#   flash.py    — flash attention (the prefill memory wall found in §Perf)
# ops.py holds the jit'd public wrappers (interpret=True off-TPU) and the
# ell_aggregate custom_vjp every aggregation path inherits its backward
# from; ref.py the pure-jnp oracles the tests sweep against.
from .ops import (ell_aggregate, ell_apply, gemm, spmm, spmm_block, spmm_ell,
                  spmm_ell_t)
from .flash import flash_mha
from .ref import gemm_ref, mha_ref, spmm_ref, spmm_t_ref

__all__ = ["ell_aggregate", "ell_apply", "gemm", "spmm", "spmm_block",
           "spmm_ell", "spmm_ell_t", "flash_mha", "gemm_ref", "mha_ref",
           "spmm_ref", "spmm_t_ref"]
