"""Pallas flash-attention kernel — the TPU fix for the prefill memory wall.

§Perf (EXPERIMENTS.md, chameleon × prefill_32k) showed the 32k cells are
memory-bound on flash-block traffic: the pure-jnp online-softmax path still
round-trips every [q_block, k_block] score tile through HBM at XLA's fusion
boundaries.  The roofline lever is to pin the running state (m, l, acc) and
the score tile in VMEM across the KV sweep — exactly what a Pallas kernel
expresses and XLA-from-jnp cannot:

  * grid = (batch·heads, n_q_blocks, n_kv_blocks), KV innermost;
  * BlockSpecs stage [q_block, hd] of Q (held across the KV sweep) and
    [k_block, hd] of K/V per step into VMEM;
  * m/l/acc live in VMEM scratch for the whole sweep — HBM traffic is
    Q+K+V read once per sweep + O written once: O(s·d), not O(s²);
  * causal masking from grid indices (`broadcasted_iota` + program_id) —
    fully-masked tiles short-circuit via ``pl.when`` (the s²/2 saving that
    the pure-jnp pair enumeration could not express without wrecking the
    GSPMD schedule).

Validated in interpret mode against :func:`repro.kernels.ref.mha_ref`
(tests/test_kernels.py); on-TPU compilation is the deployment target.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = float(jnp.finfo(jnp.float32).min)


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  n_k: int, q_block: int, k_block: int, causal: bool,
                  scale: float):
    i = pl.program_id(1)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # causal: tiles strictly above the diagonal contribute nothing
    live = (j * k_block <= i * q_block + q_block - 1) if causal else True

    @pl.when(live)
    def _tile():
        q = q_ref[0]                                   # [qb, hd]
        k = k_ref[0]                                   # [kb, hd]
        logits = jnp.dot(q, k.T,
                         preferred_element_type=jnp.float32) * scale
        if causal:
            i_ids = i * q_block + jax.lax.broadcasted_iota(
                jnp.int32, (q_block, k_block), 0)
            j_ids = j * k_block + jax.lax.broadcasted_iota(
                jnp.int32, (q_block, k_block), 1)
            logits = jnp.where(j_ids <= i_ids, logits, NEG)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, logits.max(-1, keepdims=True))
        p = jnp.exp(logits - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jnp.dot(
            p.astype(v_ref.dtype), v_ref[0],
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(j == n_k - 1)
    def _flush():
        o_ref[0] = (acc_scr[...]
                    / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "q_block", "k_block",
                                             "interpret"))
def flash_mha(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
              causal: bool = True, q_block: int = 256, k_block: int = 256,
              interpret: bool = False) -> jnp.ndarray:
    """q/k/v: [bh, s, hd] (heads flattened into the leading dim; GQA repeat
    is the caller's reshape) → o: [bh, s, hd]."""
    bh, sq, hd = q.shape
    sk = k.shape[1]
    if sq % q_block or sk % k_block:
        raise ValueError(f"seq ({sq},{sk}) not divisible by blocks "
                         f"({q_block},{k_block})")
    grid = (bh, sq // q_block, sk // k_block)
    kernel = functools.partial(_flash_kernel, n_k=grid[2], q_block=q_block,
                               k_block=k_block, causal=causal,
                               scale=1.0 / float(hd) ** 0.5)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, q_block, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, k_block, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, k_block, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, q_block, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((q_block, 1), jnp.float32),     # running max
            pltpu.VMEM((q_block, 1), jnp.float32),     # running denom
            pltpu.VMEM((q_block, hd), jnp.float32),    # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
