"""Pre-reduced ELLPACK edge plans — the Reduced Register File as a layout.

The paper's §4.3.3 Block-Message compression hinges on the sender merging
all neighbors of an aggregate slot and shipping ONE message per slot, so
traffic scales with ``N = |unique B|`` instead of ``nnz``.
:func:`repro.core.blockmsg.compress_block` already computes that merge plan
(``seg_ids`` groups the edges of each slot, ``agg_slots`` names the slots);
this module materializes it as padded ELLPACK tables the kernels can walk
without any scatter:

  * per aggregate slot *r*, a row of up to ``K`` ``(source, weight)`` pairs —
    ``y[r] = Σ_k vals[r, k] · x[cols[r, k]]`` is a gather + a reduction over
    the degree axis, never a segment scatter (the GraphACT-style sender-side
    merge, arXiv:2001.02498);
  * rows are **degree-bucketed**: rows are grouped by the smallest capacity
    in ``caps`` that fits their (duplicate-merged) degree, so one hub row
    does not inflate the padding of every other row;
  * padding entries point at a **dedicated zero row** (column id ``n_cols``;
    the consumer appends one zero row to ``x``), never at real row 0;
  * rows that receive no edges are not stored at all — ``inv_perm`` routes
    them to a zero output row, so empty destination blocks cost nothing;
  * the **transpose plan** is the same construction on the column-major walk
    of the same edges (the Graph Converter order): backward aggregation is
    the identical gather-accumulate kernel over the mirror tables — no
    ``Aᵀ`` and no scatter in the backward either.

Plans are built ONCE per graph and cached (keyed on the identity of the COO
index/value arrays), so per-step host edge prep disappears from the
training loop.  :mod:`repro.kernels.ops` consumes the tables on device;
:mod:`repro.distributed.aggregate` stacks per-sender plans for the
hypercube schedule.
"""
from __future__ import annotations

import dataclasses
import threading
from collections import OrderedDict
from typing import Callable, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

Caps = Union[str, Sequence[int]]   # "pow2" | "single" | explicit capacities

_FLAT = Tuple[np.ndarray, np.ndarray, np.ndarray]   # (rows, cols, vals)


# ---------------------------------------------------------------------------
# Flat edge arrays in the merge order compress_block defines.
# ---------------------------------------------------------------------------
def flat_from_compressed(bm, row_offset: int = 0, col_offset: int = 0
                         ) -> _FLAT:
    """One Block Message → flat (rows, cols, vals) in pre-reduction order.

    ``bm.agg_slots[bm.seg_ids]`` rebuilds the per-edge aggregate slot from
    the merge plan — consecutive edges of a slot are exactly the neighbors
    the Reduced Register File folds into one wire message, which is the row
    grouping the ELL tables store.
    """
    rows = bm.agg_slots[bm.seg_ids].astype(np.int64) + row_offset
    cols = bm.nbr_slots.astype(np.int64) + col_offset
    return rows, cols, bm.weights.astype(np.float32)


def resolve_caps(caps: Caps, max_deg: int) -> Tuple[int, ...]:
    """Bucket capacities (ascending), last one ≥ ``max_deg``.

    ``"pow2"``: 1, 2, 4, … up to the next power of two ≥ max_deg (skewed
    rows land in their own bucket instead of padding everyone).
    ``"single"``: one bucket of exactly max_deg (classic ELLPACK).
    """
    max_deg = max(int(max_deg), 1)
    if caps == "single":
        return (max_deg,)
    if caps == "pow2":
        out = [1]
        while out[-1] < max_deg:
            out.append(out[-1] * 2)
        return tuple(out)
    caps = tuple(sorted(int(c) for c in caps))
    if not caps or any(c < 1 for c in caps):
        raise ValueError(f"invalid bucket capacities {caps!r}")
    if caps[-1] < max_deg:
        caps = caps + (max_deg,)
    return caps


def merged_degrees(rows: np.ndarray, cols: np.ndarray, vals: np.ndarray,
                   n_rows: int, n_cols: int) -> np.ndarray:
    """Per-row entry counts AFTER duplicate-(row, col) merging — the fan-in
    the ELL tables actually store.  Used to fix shared bucket capacities and
    row pads before building per-sender tables."""
    rows = np.asarray(rows, np.int64)
    cols = np.asarray(cols, np.int64)
    keep = np.asarray(vals, np.float32) != 0
    key = rows[keep] * (n_cols + 1) + cols[keep]
    uniq = np.unique(key)
    return np.bincount(uniq // (n_cols + 1), minlength=n_rows)


@dataclasses.dataclass(eq=False)
class EllTables:
    """One direction (forward or transpose) of a plan, bucketed.

    ``cols[b]``: [nb_b, caps[b]] int32 — source ids, padding = ``n_cols``
    (the dedicated zero row the consumer appends to ``x``).
    ``vals[b]``: [nb_b, caps[b]] float32 — merged weights, padding = 0.
    ``inv_perm``: [n_rows] int32 — output row *r* is row ``inv_perm[r]`` of
    ``concat(bucket outputs) + [zero row]``; rows with no edges map to the
    zero row (index ``Σ nb_b``), so they are never computed.
    """

    caps: Tuple[int, ...]
    cols: Tuple[np.ndarray, ...]
    vals: Tuple[np.ndarray, ...]
    inv_perm: np.ndarray
    n_rows: int
    n_cols: int

    @property
    def n_entries(self) -> int:
        """Real (merged) entries stored across buckets."""
        return int(sum(int((v != 0).sum()) for v in self.vals))

    @property
    def padded_entries(self) -> int:
        return int(sum(int(c.size) for c in self.cols))


def build_tables(rows: np.ndarray, cols: np.ndarray, vals: np.ndarray,
                 n_rows: int, n_cols: int, caps: Caps = "pow2",
                 nb_pad: Optional[Sequence[int]] = None,
                 merge_duplicates: bool = True) -> EllTables:
    """Flat edges → degree-bucketed ELL tables (one direction).

    Duplicate ``(row, col)`` pairs are merged by summing weights (the
    sender-side pre-reduction: one register per neighbor slot).  ``nb_pad``
    forces per-bucket row counts (the distributed builder uses it to give
    every sender identical shapes); ``caps`` may be a scheme name or the
    explicit capacities (then shared across senders too).
    """
    rows = np.asarray(rows, np.int64)
    cols64 = np.asarray(cols, np.int64)
    vals = np.asarray(vals, np.float32)
    keep = vals != 0                      # drop padding edges outright
    rows, cols64, vals = rows[keep], cols64[keep], vals[keep]
    if merge_duplicates and len(rows):
        key = rows * (n_cols + 1) + cols64
        uniq, inv = np.unique(key, return_inverse=True)
        vals = np.bincount(inv, weights=vals).astype(np.float32)
        rows = uniq // (n_cols + 1)
        cols64 = uniq % (n_cols + 1)
    elif len(rows):
        order = np.lexsort((cols64, rows))
        rows, cols64, vals = rows[order], cols64[order], vals[order]
    deg = np.bincount(rows, minlength=n_rows).astype(np.int64)
    caps_t = resolve_caps(caps, int(deg.max()) if len(rows) else 0)
    caps_arr = np.asarray(caps_t, np.int64)
    # bucket of every row with ≥1 edge: smallest capacity that fits
    listed = np.flatnonzero(deg > 0)
    bucket_of = np.searchsorted(caps_arr, deg[listed], side="left")
    n_buckets = len(caps_t)
    if nb_pad is not None and len(nb_pad) != n_buckets:
        raise ValueError(f"nb_pad has {len(nb_pad)} buckets, caps {n_buckets}")
    # entry slot within its row (entries are (row, col)-sorted)
    starts = np.zeros(n_rows + 1, np.int64)
    np.cumsum(deg, out=starts[1:])
    slot = np.arange(len(rows), dtype=np.int64) - starts[rows]

    out_cols: List[np.ndarray] = []
    out_vals: List[np.ndarray] = []
    inv_perm = np.empty(n_rows, np.int64)
    base = 0
    rank_of = np.zeros(n_rows, np.int64)      # row id -> rank inside bucket
    bucket_base = np.zeros(n_rows, np.int64)  # row id -> bucket base offset
    for b in range(n_buckets):
        rb = listed[bucket_of == b]           # ascending row ids
        nb = len(rb)
        nb_out = max(nb, int(nb_pad[b])) if nb_pad is not None else nb
        if nb_pad is not None and nb > int(nb_pad[b]):
            raise ValueError(f"bucket {b} has {nb} rows > nb_pad={nb_pad[b]}")
        K = int(caps_t[b])
        c = np.full((nb_out, K), n_cols, np.int32)   # pad → zero row
        v = np.zeros((nb_out, K), np.float32)
        rank_of[rb] = np.arange(nb)
        bucket_base[rb] = base
        out_cols.append(c)
        out_vals.append(v)
        base += nb_out
    # fill the tables: vectorized scatter per bucket
    if len(rows):
        row_bucket = np.zeros(n_rows, np.int64)
        row_bucket[listed] = bucket_of
        ebucket = row_bucket[rows]
        for b in range(n_buckets):
            sel = ebucket == b
            if not sel.any():
                continue
            out_cols[b][rank_of[rows[sel]], slot[sel]] = cols64[sel]
            out_vals[b][rank_of[rows[sel]], slot[sel]] = vals[sel]
    inv_perm[:] = base                        # default: the zero output row
    inv_perm[listed] = bucket_base[listed] + rank_of[listed]
    return EllTables(caps=caps_t, cols=tuple(out_cols), vals=tuple(out_vals),
                     inv_perm=inv_perm.astype(np.int32), n_rows=n_rows,
                     n_cols=n_cols)


# ---------------------------------------------------------------------------
# The per-graph plan: forward + transpose tables, device-array cache.
# ---------------------------------------------------------------------------
@dataclasses.dataclass(eq=False)
class EdgePlan:
    """Both walks of one graph, pre-reduced and bucketed.

    ``fwd``: dst-major tables (``y[r] = Σ v·x[c]``, r ∈ [0, n_dst)).
    ``bwd``: the transpose walk's tables over the SAME edges, column-major
    (``dx[c] = Σ v·e[r]``) — the kernel-level transpose-free backward.
    """

    n_dst: int
    n_src: int
    nnz: int
    fwd: EllTables
    bwd: EllTables
    _device: Optional[Dict] = dataclasses.field(default=None, repr=False)

    @property
    def compression(self) -> float:
        """Raw edges per stored (merged) forward entry — the A+C+N win."""
        return self.nnz / max(self.fwd.n_entries, 1)

    @property
    def padding_overhead(self) -> float:
        """Padded ELL slots per stored entry (bucketing keeps this small)."""
        return self.fwd.padded_entries / max(self.fwd.n_entries, 1)

    def device_tables(self) -> Dict:
        """jnp copies of both directions, converted once and cached."""
        if self._device is None:
            import jax.numpy as jnp
            self._device = {
                "cols": tuple(jnp.asarray(c) for c in self.fwd.cols),
                "vals": tuple(jnp.asarray(v) for v in self.fwd.vals),
                "inv": jnp.asarray(self.fwd.inv_perm),
                "t_cols": tuple(jnp.asarray(c) for c in self.bwd.cols),
                "t_vals": tuple(jnp.asarray(v) for v in self.bwd.vals),
                "t_inv": jnp.asarray(self.bwd.inv_perm),
            }
        return self._device


# Bounded plan cache.  Keys hold the id() of the source arrays; the cached
# entry keeps a strong reference to those arrays so an id can never be
# recycled while its key is alive.  The lock makes it safe for the async
# input pipeline, whose prefetch thread builds per-batch layouts while the
# main thread may be building validation ones (builds serialize; a build is
# per-batch-necessary work either way, never a duplicated one).
_CACHE_CAP = 32
_cache: "OrderedDict[tuple, Tuple[tuple, object]]" = OrderedDict()
_stats = {"hits": 0, "misses": 0}
# re-entrant on purpose: builders legitimately nest cached() calls (an
# engine aggregator's builder shards edges, whose ELL build is itself
# cached) — a plain Lock would self-deadlock there
_cache_lock = threading.RLock()


def cached(key: tuple, pins: tuple, builder: Callable[[], object]):
    """Memoize ``builder()`` under ``key``; ``pins`` are objects whose ids
    appear in the key (kept alive alongside the value)."""
    with _cache_lock:
        hit = _cache.get(key)
        if hit is not None:
            _stats["hits"] += 1
            _cache.move_to_end(key)
            return hit[1]
        _stats["misses"] += 1
        value = builder()
        _cache[key] = (pins, value)
        if len(_cache) > _CACHE_CAP:
            _cache.popitem(last=False)
        return value


def cache_stats() -> Dict[str, int]:
    """Hit/miss counters since process start — benchmarks assert 'built
    once' by checking the miss count stays flat across measured steps."""
    return dict(_stats)


def cache_clear() -> None:
    _cache.clear()


def coo_key(coo, *extra) -> tuple:
    """Identity key of a COO's arrays (plus builder parameters)."""
    return (id(coo.rows), id(coo.cols), id(coo.vals),
            int(coo.n_dst), int(coo.n_src)) + tuple(extra)


def build_plan(coo, caps: Optional[Caps] = None) -> EdgePlan:
    """COO → cached :class:`EdgePlan` (dst-major fwd + column-major bwd).

    The merge order comes from :func:`repro.core.blockmsg.compress_block`:
    the whole matrix is one block, its ``seg_ids`` group the neighbors of
    each aggregate slot, and the transpose tables run the same compressor
    on the column-major walk.  ``caps=None`` reads the autotuned bucket
    scheme (:func:`repro.kernels.tune.get_config`).
    """
    if caps is None:
        from repro.kernels.tune import get_config
        caps = get_config()["caps"]
    caps_key = caps if isinstance(caps, str) else tuple(caps)

    def _build() -> EdgePlan:
        from repro.core.blockmsg import compress_block
        rows = np.asarray(coo.rows)
        cols = np.asarray(coo.cols)
        vals = np.asarray(coo.vals, np.float32)
        keep = vals != 0
        rows, cols, vals = rows[keep], cols[keep], vals[keep]
        bm_f = compress_block(rows, cols, vals, 0, 0)
        bm_b = compress_block(cols, rows, vals, 0, 0)
        fwd = build_tables(*flat_from_compressed(bm_f), coo.n_dst, coo.n_src,
                           caps=caps)
        bwd = build_tables(*flat_from_compressed(bm_b), coo.n_src, coo.n_dst,
                           caps=caps)
        return EdgePlan(n_dst=int(coo.n_dst), n_src=int(coo.n_src),
                        nnz=int(keep.sum()), fwd=fwd, bwd=bwd)

    return cached(coo_key(coo, "plan", caps_key),
                  (coo.rows, coo.cols, coo.vals), _build)
