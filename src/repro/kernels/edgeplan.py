"""Pre-reduced ELLPACK edge plans — the Reduced Register File as a layout.

The paper's §4.3.3 Block-Message compression hinges on the sender merging
all neighbors of an aggregate slot and shipping ONE message per slot, so
traffic scales with ``N = |unique B|`` instead of ``nnz``.
:func:`repro.core.blockmsg.compress_block` already computes that merge plan
(``seg_ids`` groups the edges of each slot, ``agg_slots`` names the slots);
this module materializes it as padded ELLPACK tables the kernels can walk
without any scatter:

  * per aggregate slot *r*, a row of up to ``K`` ``(source, weight)`` pairs —
    ``y[r] = Σ_k vals[r, k] · x[cols[r, k]]`` is a gather + a reduction over
    the degree axis, never a segment scatter (the GraphACT-style sender-side
    merge, arXiv:2001.02498);
  * rows are **degree-bucketed**: rows are grouped by the smallest capacity
    in ``caps`` that fits their (duplicate-merged) degree, so one hub row
    does not inflate the padding of every other row;
  * padding entries point at a **dedicated zero row** (column id ``n_cols``;
    the consumer appends one zero row to ``x``), never at real row 0;
  * rows that receive no edges are not stored at all — ``inv_perm`` routes
    them to a zero output row, so empty destination blocks cost nothing;
  * the **transpose plan** is the same construction on the column-major walk
    of the same edges (the Graph Converter order): backward aggregation is
    the identical gather-accumulate kernel over the mirror tables — no
    ``Aᵀ`` and no scatter in the backward either.

Plans are built ONCE per graph and cached (keyed on the identity of the COO
index/value arrays), so per-step host edge prep disappears from the
training loop.  :mod:`repro.kernels.ops` consumes the tables on device;
:mod:`repro.distributed.aggregate` stacks per-sender plans for the
hypercube schedule.

Merge levels
------------
``merge="dedup"`` (default) is the sender-side merge above: duplicate
``(row, col)`` pairs collapse into one weighted entry *within* each
destination row.  ``merge="redundancy"`` adds the GraphACT-style pass
(arXiv:2001.02498 §3) on top: :func:`mine_pair_redundancy` mines neighbor
pairs shared *across* destination rows from the pair-frequency table,
greedily matches them into **virtual vertices** (``z = α·x[u] + β·x[v]``),
and rewrites the ELL tables so destination rows gather from the extended
``original ∪ virtual`` source space.  The same Pallas/XLA gather kernels
walk the rewritten tables unchanged — the only addition is one small
pre-pass walk computing the virtual partials — and the backward stays
transpose-free: the column-major tables cover the extended space, and the
virtual rows' cotangents expand through the mirror of the pair table.
"""
from __future__ import annotations

import dataclasses
import threading
from collections import OrderedDict
from typing import Callable, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

Caps = Union[str, Sequence[int]]   # "pow2" | "single" | explicit capacities

_FLAT = Tuple[np.ndarray, np.ndarray, np.ndarray]   # (rows, cols, vals)


# ---------------------------------------------------------------------------
# Flat edge arrays in the merge order compress_block defines.
# ---------------------------------------------------------------------------
def flat_from_compressed(bm, row_offset: int = 0, col_offset: int = 0
                         ) -> _FLAT:
    """One Block Message → flat (rows, cols, vals) in pre-reduction order.

    ``bm.agg_slots[bm.seg_ids]`` rebuilds the per-edge aggregate slot from
    the merge plan — consecutive edges of a slot are exactly the neighbors
    the Reduced Register File folds into one wire message, which is the row
    grouping the ELL tables store.
    """
    rows = bm.agg_slots[bm.seg_ids].astype(np.int64) + row_offset
    cols = bm.nbr_slots.astype(np.int64) + col_offset
    return rows, cols, bm.weights.astype(np.float32)


def resolve_caps(caps: Caps, max_deg: int) -> Tuple[int, ...]:
    """Bucket capacities (ascending), last one ≥ ``max_deg``.

    ``"pow2"``: 1, 2, 4, … up to the next power of two ≥ max_deg (skewed
    rows land in their own bucket instead of padding everyone).
    ``"single"``: one bucket of exactly max_deg (classic ELLPACK).
    """
    max_deg = max(int(max_deg), 1)
    if caps == "single":
        return (max_deg,)
    if caps == "pow2":
        out = [1]
        while out[-1] < max_deg:
            out.append(out[-1] * 2)
        return tuple(out)
    caps = tuple(sorted(int(c) for c in caps))
    if not caps or any(c < 1 for c in caps):
        raise ValueError(f"invalid bucket capacities {caps!r}")
    if caps[-1] < max_deg:
        caps = caps + (max_deg,)
    return caps


def merged_degrees(rows: np.ndarray, cols: np.ndarray, vals: np.ndarray,
                   n_rows: int, n_cols: int) -> np.ndarray:
    """Per-row entry counts AFTER duplicate-(row, col) merging — the fan-in
    the ELL tables actually store.  Used to fix shared bucket capacities and
    row pads before building per-sender tables."""
    rows = np.asarray(rows, np.int64)
    cols = np.asarray(cols, np.int64)
    keep = np.asarray(vals, np.float32) != 0
    key = rows[keep] * (n_cols + 1) + cols[keep]
    uniq = np.unique(key)
    return np.bincount(uniq // (n_cols + 1), minlength=n_rows)


# ---------------------------------------------------------------------------
# GraphACT-style cross-row redundancy mining (merge="redundancy").
# ---------------------------------------------------------------------------
MERGE_LEVELS = ("dedup", "redundancy")


def validate_merge(merge: str) -> str:
    if merge not in MERGE_LEVELS:
        raise ValueError(f"unknown merge level {merge!r}; "
                         f"supported: {list(MERGE_LEVELS)}")
    return merge


@dataclasses.dataclass(eq=False)
class PairMerge:
    """Rewritten flat edges + the virtual-vertex tier of one mining pass.

    ``rows``/``cols``/``vals`` are the rewritten edge list: ``cols`` index
    the EXTENDED source space ``[0, n_cols) ∪ [n_cols, n_cols + n_virtual)``
    — original sources first, then virtual vertices.  ``vv_src``/``vv_coef``
    define the tier: virtual vertex *z* is
    ``α·x[vv_src[z, 0]] + β·x[vv_src[z, 1]]`` with ``(α, β) = vv_coef[z]``.
    ``stats`` carries the accounting the benchmarks and Trainer surface.
    """

    rows: np.ndarray
    cols: np.ndarray
    vals: np.ndarray
    vv_src: np.ndarray     # [n_virtual, 2] int64, original source ids
    vv_coef: np.ndarray    # [n_virtual, 2] float32
    n_rows: int
    n_cols: int
    stats: Dict

    @property
    def n_virtual(self) -> int:
        return int(self.vv_src.shape[0])

    def vv_flat(self) -> _FLAT:
        """Virtual tier as flat edges (z, src, coef) — degree-2 rows of the
        ``V`` matrix the pre-pass walks (``z = V @ x``)."""
        z = np.repeat(np.arange(self.n_virtual, dtype=np.int64), 2)
        return z, self.vv_src.reshape(-1), self.vv_coef.reshape(-1)


def _dedup_flat(rows, cols, vals, n_cols: int) -> _FLAT:
    """Drop zero-weight padding and merge duplicate (row, col) entries —
    the within-row sender-side merge, shared with :func:`build_tables`."""
    rows = np.asarray(rows, np.int64)
    cols = np.asarray(cols, np.int64)
    vals = np.asarray(vals, np.float32)
    keep = vals != 0
    rows, cols, vals = rows[keep], cols[keep], vals[keep]
    if len(rows):
        key = rows * (n_cols + 1) + cols
        uniq, inv = np.unique(key, return_inverse=True)
        vals = np.bincount(inv, weights=vals).astype(np.float32)
        rows = uniq // (n_cols + 1)
        cols = uniq % (n_cols + 1)
    return rows, cols, vals


def mine_pair_redundancy(rows, cols, vals, n_rows: int, n_cols: int, *,
                         max_row_degree: int = 128, min_uses: int = 2,
                         ratio_tol: float = 1e-6) -> PairMerge:
    """GraphACT §3: greedy matching over the shared-neighbor pair table.

    Host-side, once per graph.  A pair ``(u, v)`` appearing in rows
    ``r1, r2, …`` factors into one virtual vertex only when every row's
    weight pair is PROPORTIONAL to the first's (``a_rv/a_ru`` constant
    within ``ratio_tol`` relative) — for symmetric GCN normalization
    ``a_ru = d_r^{-1/2} d_u^{-1/2}`` that ratio is exactly
    ``(d_u/d_v)^{1/2}`` for every row, so all structural sharing factors;
    arbitrary per-edge weights simply yield fewer (or zero) matches and the
    rewrite stays exact either way.  Occurrences are consumed greedily in
    descending pair-frequency order; each (row, neighbor) entry joins at
    most one virtual vertex, and a vertex must collect ``min_uses`` rows to
    pay for its own pre-pass FLOPs.  Rows above ``max_row_degree`` skip
    pair enumeration (hub rows would cost O(deg²) and rarely share full
    pairs).

    Weight contract: row *r*'s rewritten entry is ``w_r = a_ru/α`` with
    ``(α, β)`` the first occurrence's weights — ``w_r·α`` reproduces
    ``a_ru`` exactly and ``w_r·β`` reproduces ``a_rv`` within ``ratio_tol``
    relative (0 for the defining row), so downstream losses match the
    unmerged plan to fp32 roundoff.
    """
    rows, cols, vals = _dedup_flat(rows, cols, vals, n_cols)
    edges_before = len(rows)
    stats = {"edges_before": edges_before, "edges_after": edges_before,
             "n_virtual": 0, "pair_uses": 0, "pair_coverage": 0.0,
             "flop_reduction": 1.0}
    empty = PairMerge(rows=rows, cols=cols, vals=vals,
                      vv_src=np.zeros((0, 2), np.int64),
                      vv_coef=np.zeros((0, 2), np.float32),
                      n_rows=n_rows, n_cols=n_cols, stats=stats)
    if edges_before == 0:
        return empty
    # entries arrive (row, col)-sorted from _dedup_flat
    deg = np.bincount(rows, minlength=n_rows)
    starts = np.zeros(n_rows + 1, np.int64)
    np.cumsum(deg, out=starts[1:])
    # pair-frequency table: (u, v) -> [(edge_idx_u, edge_idx_v), ...]
    occ: Dict[Tuple[int, int], List[Tuple[int, int]]] = {}
    for r in np.flatnonzero((deg >= 2) & (deg <= max_row_degree)):
        lo, hi = int(starts[r]), int(starts[r + 1])
        for i in range(lo, hi):
            for j in range(i + 1, hi):
                occ.setdefault((int(cols[i]), int(cols[j])), []) \
                   .append((i, j))
    # greedy matching, most-shared pairs first (deterministic tie-break)
    order = sorted(occ, key=lambda p: (-len(occ[p]), p))
    used = np.zeros(edges_before, bool)
    vals64 = vals.astype(np.float64)
    vv_src: List[Tuple[int, int]] = []
    vv_coef: List[Tuple[float, float]] = []
    new_rows: List[int] = []
    new_cols: List[int] = []
    new_vals: List[float] = []
    pair_uses = 0
    for pair in order:
        hits = occ[pair]
        if len(hits) < min_uses:
            break                      # sorted by count: nothing below pays
        avail = [(i, j) for i, j in hits if not (used[i] or used[j])]
        while len(avail) >= min_uses:
            i0, j0 = avail[0]
            alpha, beta = vals64[i0], vals64[j0]
            # the cluster: occurrences whose weight pair is proportional
            # to the defining row's (a_ru·β ≈ a_rv·α)
            cluster = [(i, j) for i, j in avail
                       if abs(vals64[i] * beta - vals64[j] * alpha)
                       <= ratio_tol * abs(vals64[j] * alpha)]
            if len(cluster) < min_uses:
                avail = avail[1:]      # lone ratio class: try the next
                continue
            z = len(vv_src)
            vv_src.append(pair)
            vv_coef.append((float(alpha), float(beta)))
            for i, j in cluster:
                used[i] = used[j] = True
                new_rows.append(int(rows[i]))
                new_cols.append(n_cols + z)
                new_vals.append(float(vals64[i] / alpha))
            pair_uses += len(cluster)
            avail = [(i, j) for i, j in avail
                     if not (used[i] or used[j])]
    if not vv_src:
        return empty
    keep = ~used
    out_rows = np.concatenate([rows[keep], np.asarray(new_rows, np.int64)])
    out_cols = np.concatenate([cols[keep], np.asarray(new_cols, np.int64)])
    out_vals = np.concatenate([vals[keep],
                               np.asarray(new_vals, np.float32)])
    n_virtual = len(vv_src)
    edges_after = len(out_rows)
    stats = {
        "edges_before": edges_before,
        "edges_after": edges_after,
        "n_virtual": n_virtual,
        "pair_uses": pair_uses,
        # fraction of (deduped) edges absorbed into virtual gathers
        "pair_coverage": 2.0 * pair_uses / edges_before,
        # aggregation MACs before vs after, pre-pass included (2 per vv)
        "flop_reduction": edges_before / max(edges_after + 2 * n_virtual,
                                             1),
    }
    return PairMerge(rows=out_rows, cols=out_cols, vals=out_vals,
                     vv_src=np.asarray(vv_src, np.int64).reshape(-1, 2),
                     vv_coef=np.asarray(vv_coef,
                                        np.float32).reshape(-1, 2),
                     n_rows=n_rows, n_cols=n_cols, stats=stats)


@dataclasses.dataclass(eq=False)
class EllTables:
    """One direction (forward or transpose) of a plan, bucketed.

    ``cols[b]``: [nb_b, caps[b]] int32 — source ids, padding = ``n_cols``
    (the dedicated zero row the consumer appends to ``x``).
    ``vals[b]``: [nb_b, caps[b]] float32 — merged weights, padding = 0.
    ``inv_perm``: [n_rows] int32 — output row *r* is row ``inv_perm[r]`` of
    ``concat(bucket outputs) + [zero row]``; rows with no edges map to the
    zero row (index ``Σ nb_b``), so they are never computed.
    """

    caps: Tuple[int, ...]
    cols: Tuple[np.ndarray, ...]
    vals: Tuple[np.ndarray, ...]
    inv_perm: np.ndarray
    n_rows: int
    n_cols: int

    @property
    def n_entries(self) -> int:
        """Real (merged) entries stored across buckets."""
        return int(sum(int((v != 0).sum()) for v in self.vals))

    @property
    def padded_entries(self) -> int:
        return int(sum(int(c.size) for c in self.cols))


def build_tables(rows: np.ndarray, cols: np.ndarray, vals: np.ndarray,
                 n_rows: int, n_cols: int, caps: Caps = "pow2",
                 nb_pad: Optional[Sequence[int]] = None,
                 merge_duplicates: bool = True) -> EllTables:
    """Flat edges → degree-bucketed ELL tables (one direction).

    Duplicate ``(row, col)`` pairs are merged by summing weights (the
    sender-side pre-reduction: one register per neighbor slot).  ``nb_pad``
    forces per-bucket row counts (the distributed builder uses it to give
    every sender identical shapes); ``caps`` may be a scheme name or the
    explicit capacities (then shared across senders too).
    """
    rows = np.asarray(rows, np.int64)
    cols64 = np.asarray(cols, np.int64)
    vals = np.asarray(vals, np.float32)
    keep = vals != 0                      # drop padding edges outright
    rows, cols64, vals = rows[keep], cols64[keep], vals[keep]
    if merge_duplicates and len(rows):
        key = rows * (n_cols + 1) + cols64
        uniq, inv = np.unique(key, return_inverse=True)
        vals = np.bincount(inv, weights=vals).astype(np.float32)
        rows = uniq // (n_cols + 1)
        cols64 = uniq % (n_cols + 1)
    elif len(rows):
        order = np.lexsort((cols64, rows))
        rows, cols64, vals = rows[order], cols64[order], vals[order]
    deg = np.bincount(rows, minlength=n_rows).astype(np.int64)
    caps_t = resolve_caps(caps, int(deg.max()) if len(rows) else 0)
    caps_arr = np.asarray(caps_t, np.int64)
    # bucket of every row with ≥1 edge: smallest capacity that fits
    listed = np.flatnonzero(deg > 0)
    bucket_of = np.searchsorted(caps_arr, deg[listed], side="left")
    n_buckets = len(caps_t)
    if nb_pad is not None and len(nb_pad) != n_buckets:
        raise ValueError(f"nb_pad has {len(nb_pad)} buckets, caps {n_buckets}")
    # entry slot within its row (entries are (row, col)-sorted)
    starts = np.zeros(n_rows + 1, np.int64)
    np.cumsum(deg, out=starts[1:])
    slot = np.arange(len(rows), dtype=np.int64) - starts[rows]

    out_cols: List[np.ndarray] = []
    out_vals: List[np.ndarray] = []
    inv_perm = np.empty(n_rows, np.int64)
    base = 0
    rank_of = np.zeros(n_rows, np.int64)      # row id -> rank inside bucket
    bucket_base = np.zeros(n_rows, np.int64)  # row id -> bucket base offset
    for b in range(n_buckets):
        rb = listed[bucket_of == b]           # ascending row ids
        nb = len(rb)
        nb_out = max(nb, int(nb_pad[b])) if nb_pad is not None else nb
        if nb_pad is not None and nb > int(nb_pad[b]):
            raise ValueError(f"bucket {b} has {nb} rows > nb_pad={nb_pad[b]}")
        K = int(caps_t[b])
        c = np.full((nb_out, K), n_cols, np.int32)   # pad → zero row
        v = np.zeros((nb_out, K), np.float32)
        rank_of[rb] = np.arange(nb)
        bucket_base[rb] = base
        out_cols.append(c)
        out_vals.append(v)
        base += nb_out
    # fill the tables: vectorized scatter per bucket
    if len(rows):
        row_bucket = np.zeros(n_rows, np.int64)
        row_bucket[listed] = bucket_of
        ebucket = row_bucket[rows]
        for b in range(n_buckets):
            sel = ebucket == b
            if not sel.any():
                continue
            out_cols[b][rank_of[rows[sel]], slot[sel]] = cols64[sel]
            out_vals[b][rank_of[rows[sel]], slot[sel]] = vals[sel]
    inv_perm[:] = base                        # default: the zero output row
    inv_perm[listed] = bucket_base[listed] + rank_of[listed]
    return EllTables(caps=caps_t, cols=tuple(out_cols), vals=tuple(out_vals),
                     inv_perm=inv_perm.astype(np.int32), n_rows=n_rows,
                     n_cols=n_cols)


# ---------------------------------------------------------------------------
# The per-graph plan: forward + transpose tables, device-array cache.
# ---------------------------------------------------------------------------
@dataclasses.dataclass(eq=False)
class EdgePlan:
    """Both walks of one graph, pre-reduced and bucketed.

    ``fwd``: dst-major tables (``y[r] = Σ v·x[c]``, r ∈ [0, n_dst)).
    ``bwd``: the transpose walk's tables over the SAME edges, column-major
    (``dx[c] = Σ v·e[r]``) — the kernel-level transpose-free backward.

    Under ``merge="redundancy"`` both directions cover the EXTENDED source
    space (original ∪ virtual): ``vv`` holds the pre-pass tables computing
    the virtual partials (``z = V @ x``), ``vv_t`` their column-major
    mirror that expands virtual-row cotangents back onto original sources
    (``dx += Vᵀ g``), and ``merge_stats`` the mining accounting.
    """

    n_dst: int
    n_src: int
    nnz: int
    fwd: EllTables
    bwd: EllTables
    vv: Optional[EllTables] = None
    vv_t: Optional[EllTables] = None
    merge_stats: Dict = dataclasses.field(default_factory=dict)
    _device: Optional[Dict] = dataclasses.field(default=None, repr=False)

    @property
    def compression(self) -> float:
        """Raw edges per stored (merged) forward entry — the A+C+N win."""
        return self.nnz / max(self.fwd.n_entries, 1)

    @property
    def padding_overhead(self) -> float:
        """Padded ELL slots per stored entry (bucketing keeps this small)."""
        return self.fwd.padded_entries / max(self.fwd.n_entries, 1)

    @property
    def n_virtual(self) -> int:
        return int(self.vv.n_rows) if self.vv is not None else 0

    @property
    def pair_coverage(self) -> float:
        return float(self.merge_stats.get("pair_coverage", 0.0))

    @property
    def flop_reduction(self) -> float:
        return float(self.merge_stats.get("flop_reduction", 1.0))

    def device_tables(self) -> Dict:
        """jnp copies of both directions, converted once and cached."""
        if self._device is None:
            import jax.numpy as jnp
            self._device = {
                "cols": tuple(jnp.asarray(c) for c in self.fwd.cols),
                "vals": tuple(jnp.asarray(v) for v in self.fwd.vals),
                "inv": jnp.asarray(self.fwd.inv_perm),
                "t_cols": tuple(jnp.asarray(c) for c in self.bwd.cols),
                "t_vals": tuple(jnp.asarray(v) for v in self.bwd.vals),
                "t_inv": jnp.asarray(self.bwd.inv_perm),
            }
            if self.vv is not None:
                self._device.update({
                    "vv_cols": tuple(jnp.asarray(c) for c in self.vv.cols),
                    "vv_vals": tuple(jnp.asarray(v) for v in self.vv.vals),
                    "vv_inv": jnp.asarray(self.vv.inv_perm),
                    "vvt_cols": tuple(jnp.asarray(c)
                                      for c in self.vv_t.cols),
                    "vvt_vals": tuple(jnp.asarray(v)
                                      for v in self.vv_t.vals),
                    "vvt_inv": jnp.asarray(self.vv_t.inv_perm),
                })
        return self._device


# Bounded plan cache.  Keys hold the id() of the source arrays; the cached
# entry keeps a strong reference to those arrays so an id can never be
# recycled while its key is alive.  The lock makes it safe for the async
# input pipeline, whose prefetch thread builds per-batch layouts while the
# main thread may be building validation ones (builds serialize; a build is
# per-batch-necessary work either way, never a duplicated one).
_CACHE_CAP = 32
_cache: "OrderedDict[tuple, Tuple[tuple, object]]" = OrderedDict()
_stats = {"hits": 0, "misses": 0}
# re-entrant on purpose: builders legitimately nest cached() calls (an
# engine aggregator's builder shards edges, whose ELL build is itself
# cached) — a plain Lock would self-deadlock there
_cache_lock = threading.RLock()


def cached(key: tuple, pins: tuple, builder: Callable[[], object]):
    """Memoize ``builder()`` under ``key``; ``pins`` are objects whose ids
    appear in the key (kept alive alongside the value)."""
    with _cache_lock:
        hit = _cache.get(key)
        if hit is not None:
            _stats["hits"] += 1
            _cache.move_to_end(key)
            return hit[1]
        _stats["misses"] += 1
        value = builder()
        _cache[key] = (pins, value)
        if len(_cache) > _CACHE_CAP:
            _cache.popitem(last=False)
        return value


def cache_stats() -> Dict[str, int]:
    """Hit/miss counters since process start — benchmarks assert 'built
    once' by checking the miss count stays flat across measured steps."""
    return dict(_stats)


def cache_clear() -> None:
    _cache.clear()


def coo_key(coo, *extra) -> tuple:
    """Identity key of a COO's arrays (plus builder parameters)."""
    return (id(coo.rows), id(coo.cols), id(coo.vals),
            int(coo.n_dst), int(coo.n_src)) + tuple(extra)


def build_plan(coo, caps: Optional[Caps] = None,
               merge: str = "dedup") -> EdgePlan:
    """COO → cached :class:`EdgePlan` (dst-major fwd + column-major bwd).

    The merge order comes from :func:`repro.core.blockmsg.compress_block`:
    the whole matrix is one block, its ``seg_ids`` group the neighbors of
    each aggregate slot, and the transpose tables run the same compressor
    on the column-major walk.  ``caps=None`` reads the autotuned bucket
    scheme (:func:`repro.kernels.tune.get_config`).

    ``merge="redundancy"`` runs :func:`mine_pair_redundancy` first and
    builds both directions over the extended (original ∪ virtual) source
    space, plus the small ``vv``/``vv_t`` pre-pass tables (module
    docstring, "Merge levels").  With no minable pairs the plan degrades
    to the plain ``dedup`` tables.
    """
    validate_merge(merge)
    if caps is None:
        from repro.kernels.tune import get_config
        caps = get_config()["caps"]
    caps_key = caps if isinstance(caps, str) else tuple(caps)

    def _build() -> EdgePlan:
        from repro.core.blockmsg import compress_block
        rows = np.asarray(coo.rows)
        cols = np.asarray(coo.cols)
        vals = np.asarray(coo.vals, np.float32)
        keep = vals != 0
        rows, cols, vals = rows[keep], cols[keep], vals[keep]
        nnz = int(keep.sum())
        if merge == "redundancy":
            mine = mine_pair_redundancy(rows, cols, vals, coo.n_dst,
                                        coo.n_src)
            if mine.n_virtual:
                ext = coo.n_src + mine.n_virtual
                fwd = build_tables(mine.rows, mine.cols, mine.vals,
                                   coo.n_dst, ext, caps=caps)
                bwd = build_tables(mine.cols, mine.rows, mine.vals,
                                   ext, coo.n_dst, caps=caps)
                zr, zc, zv = mine.vv_flat()
                vv = build_tables(zr, zc, zv, mine.n_virtual, coo.n_src,
                                  caps=caps)
                vv_t = build_tables(zc, zr, zv, coo.n_src, mine.n_virtual,
                                    caps=caps)
                return EdgePlan(n_dst=int(coo.n_dst), n_src=int(coo.n_src),
                                nnz=nnz, fwd=fwd, bwd=bwd, vv=vv,
                                vv_t=vv_t, merge_stats=dict(mine.stats))
        bm_f = compress_block(rows, cols, vals, 0, 0)
        bm_b = compress_block(cols, rows, vals, 0, 0)
        fwd = build_tables(*flat_from_compressed(bm_f), coo.n_dst, coo.n_src,
                           caps=caps)
        bwd = build_tables(*flat_from_compressed(bm_b), coo.n_src, coo.n_dst,
                           caps=caps)
        return EdgePlan(n_dst=int(coo.n_dst), n_src=int(coo.n_src),
                        nnz=nnz, fwd=fwd, bwd=bwd)

    return cached(coo_key(coo, "plan", caps_key, merge),
                  (coo.rows, coo.cols, coo.vals), _build)
