"""Block-COO SpMM Pallas kernel — the paper's aggregation engine, MXU-native.

The FPGA aggregates with scalar MAC chains over COO edges streamed from the
Neighbor FIFO (paper §4.2).  A TPU has no efficient scalar scatter-add; the
hardware-codesign move is to *densify per edge-chunk*: an edge chunk of E
edges against a dst-tile of R rows and a src-tile of S rows becomes two tiny
one-hot matmuls that run on the MXU,

    G   = onehot(cols)  @ X_tile          # [E, S] @ [S, bd]  — the gather
    acc += (onehot(rows) * vals) @ G      # [R, E] @ [E, bd]  — the scatter-add

so aggregation uses exactly the same compute unit as combination — the
paper's *unified aggregation+combination engine* argument (§5.4: one engine,
no Systolic/Scatter/Gather imbalance), transplanted to the MXU.

Tiling: grid = (d/bd, e/be) with the edge dimension innermost; the fp32
accumulator tile [n_dst, bd] lives in VMEM scratch across edge chunks.  The
dst tile (paper: 64 nodes/core) is small by construction — it is one core's
Aggregate Buffer — so [n_dst, bd] fits VMEM comfortably.  Padding edges have
val == 0 ⇒ their one-hot column is zeroed ⇒ no-ops, matching ref.spmm_ref.

Index arrays arrive as [1, e] int32 (TPU wants ≥2-D); one (1, be) chunk is
staged into VMEM per grid step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _spmm_kernel(rows_ref, cols_ref, vals_ref, x_ref, o_ref, acc_ref, *,
                 n_e: int, n_dst: int, n_src: int):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    rows = rows_ref[0, :]                       # [be] int32
    cols = cols_ref[0, :]
    vals = vals_ref[0, :]                       # [be] f32 (0 = padding)
    be = rows.shape[0]
    x = x_ref[...]                              # [n_src, bd] VMEM tile

    # gather via one-hot matmul: G[e, :] = x[cols[e], :]
    src_iota = jax.lax.broadcasted_iota(jnp.int32, (be, n_src), 1)
    onehot_src = (src_iota == cols[:, None]).astype(x.dtype)
    g = jnp.dot(onehot_src, x, preferred_element_type=jnp.float32)

    # scatter-add via one-hot matmul, edge weights folded into the one-hot
    dst_iota = jax.lax.broadcasted_iota(jnp.int32, (n_dst, be), 0)
    onehot_dst = jnp.where(dst_iota == rows[None, :], vals[None, :], 0.0)
    acc_ref[...] += jnp.dot(onehot_dst.astype(jnp.float32), g,
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(1) == n_e - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _spmm_block_kernel(rows_ref, cols_ref, vals_ref, x_ref, o_ref, acc_ref, *,
                       n_e: int, dpc: int, n_src: int):
    """Block-layout variant: one grid row per destination-core tile.

    ``rows`` are BLOCK-LOCAL offsets (the Block-Message B values), so the
    scatter one-hot is [dpc, be] — one core's Aggregate Buffer — instead of
    a global [n_dst, be].  The gather side is unchanged: sources are already
    local to the sender (NUMA), the destination side is what the Block
    Message compresses.
    """
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    rows = rows_ref[0, :]                       # [be] int32, block-local
    cols = cols_ref[0, :]
    vals = vals_ref[0, :]                       # [be] f32 (0 = padding)
    be = rows.shape[0]
    x = x_ref[...]                              # [n_src, bd] VMEM tile

    src_iota = jax.lax.broadcasted_iota(jnp.int32, (be, n_src), 1)
    onehot_src = (src_iota == cols[:, None]).astype(x.dtype)
    g = jnp.dot(onehot_src, x, preferred_element_type=jnp.float32)

    # per-block row offsets: the one-hot spans one tile, not the whole graph
    dst_iota = jax.lax.broadcasted_iota(jnp.int32, (dpc, be), 0)
    onehot_dst = jnp.where(dst_iota == rows[None, :], vals[None, :], 0.0)
    acc_ref[...] += jnp.dot(onehot_dst.astype(jnp.float32), g,
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == n_e - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("dpc", "bd", "be", "interpret"))
def spmm_block(rows: jnp.ndarray, cols: jnp.ndarray, vals: jnp.ndarray,
               x: jnp.ndarray, dpc: int, *, bd: int = 128, be: int = 256,
               interpret: bool = False) -> jnp.ndarray:
    """Block-layout SpMM: ``y[b*dpc + r] += v * x[c]`` over per-destination-
    block COO tiles (:class:`repro.core.blockmsg.BlockTiles` arrays).

    ``rows``/``cols``/``vals``: [n_blocks, e_blk] with block-local row
    offsets in ``[0, dpc)``; ``x``: the sender's dense [n_src, d] feature
    shard.  Returns [n_blocks * dpc, d] — tile *b* is the partial rows this
    sender contributes to destination core *b*, ready for the hypercube
    fold.  ``e_blk`` and ``d`` must be multiples of (be, bd); pad edges with
    val=0 (:func:`repro.kernels.ops.spmm_block` absorbs the padding).
    """
    n_blocks, e_blk = rows.shape
    n_src, d = x.shape
    if e_blk % be or d % bd:
        raise ValueError(
            f"e_blk={e_blk}, d={d} not divisible by (be={be}, bd={bd})")
    grid = (n_blocks, d // bd, e_blk // be)
    kernel = functools.partial(_spmm_block_kernel, n_e=grid[2], dpc=dpc,
                               n_src=n_src)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, be), lambda i, j, k: (i, k)),
            pl.BlockSpec((1, be), lambda i, j, k: (i, k)),
            pl.BlockSpec((1, be), lambda i, j, k: (i, k)),
            pl.BlockSpec((n_src, bd), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((dpc, bd), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n_blocks * dpc, d), x.dtype),
        scratch_shapes=[pltpu.VMEM((dpc, bd), jnp.float32)],
        interpret=interpret,
    )(rows.astype(jnp.int32), cols.astype(jnp.int32),
      vals.astype(jnp.float32), x)


@functools.partial(jax.jit, static_argnames=("n_dst", "bd", "be", "interpret"))
def spmm(rows: jnp.ndarray, cols: jnp.ndarray, vals: jnp.ndarray,
         x: jnp.ndarray, n_dst: int, *, bd: int = 128, be: int = 256,
         interpret: bool = False) -> jnp.ndarray:
    """``y[r] += v * x[c]`` over a COO edge list, y: [n_dst, d].

    ``n_dst`` is one core-block's row count (the Aggregate Buffer size);
    ``x`` is the VMEM-resident dense source block.  Edge count and feature
    dim must be multiples of (be, bd) — pad edges with val=0.
    """
    e = rows.shape[0]
    n_src, d = x.shape
    if e % be or d % bd:
        raise ValueError(f"e={e}, d={d} not divisible by (be={be}, bd={bd})")
    grid = (d // bd, e // be)
    kernel = functools.partial(_spmm_kernel, n_e=grid[1], n_dst=n_dst,
                               n_src=n_src)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, be), lambda j, k: (0, k)),
            pl.BlockSpec((1, be), lambda j, k: (0, k)),
            pl.BlockSpec((1, be), lambda j, k: (0, k)),
            pl.BlockSpec((n_src, bd), lambda j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((n_dst, bd), lambda j, k: (0, j)),
        out_shape=jax.ShapeDtypeStruct((n_dst, d), x.dtype),
        scratch_shapes=[pltpu.VMEM((n_dst, bd), jnp.float32)],
        interpret=interpret,
    )(rows.reshape(1, e).astype(jnp.int32),
      cols.reshape(1, e).astype(jnp.int32),
      vals.reshape(1, e).astype(jnp.float32), x)
