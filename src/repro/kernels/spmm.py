"""SpMM Pallas kernels — the paper's aggregation engine, MXU-native.

The FPGA aggregates with scalar MAC chains over COO edges streamed from the
Neighbor FIFO (paper §4.2).  A TPU has no efficient scalar scatter-add; the
hardware-codesign move is to *densify per chunk* so aggregation uses exactly
the same compute unit as combination — the paper's unified
aggregation+combination engine argument (§5.4), transplanted to the MXU.

Two kernel families live here:

**COO (legacy reference arm)** — an edge chunk of E edges against a dst
tile of R rows becomes two one-hot matmuls:

    G   = onehot(cols)  @ X_tile          # [E, S] @ [S, bd]  — the gather
    acc += (onehot(rows) * vals) @ G      # [R, E] @ [E, bd]  — the scatter-add

Simple, bit-faithful to the segment-sum order — but the gather one-hot
spans the WHOLE source shard per edge chunk (dense FLOPs ∝ e·n_src·d) and
``x`` is staged whole-shard into VMEM, which cannot scale past toy shards.

**Pre-reduced ELL (the hot path)** — :mod:`repro.kernels.edgeplan`
materializes the Block-Message merge (§4.3.3's Reduced Register File) as
padded per-row tables of (source, weight) pairs.  The kernel walks them
with the SOURCE dimension tiled:

    S[r, s] = Σ_k  vals[r, k] · [cols[r, k] == tile_start + s]   # VPU
    acc    += S @ X_tile                  # [br, bs] @ [bs, bd]  — MXU

One matmul per (row-tile, src-tile, feat-tile): total MXU FLOPs are
n_rows·n_src·d — the dense-adjacency bound, independent of nnz AND of the
ELL padding — and the scatter one-hot is gone entirely (the reduction over
the degree axis happens in the merge matrix S).  Entries outside the
current source tile simply never match the tile-local iota, so src tiling
is free; padding entries point at the plan's dedicated zero row and carry
weight 0.  The transpose walk (:func:`spmm_ell_t`) is the SAME kernel over
the plan's column-major tables — the kernel-level transpose-free backward.

Index arrays arrive ≥2-D (TPU layout); fp32 accumulator tiles live in VMEM
scratch across the innermost grid axis.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


# ---------------------------------------------------------------------------
# COO family (legacy reference arm) — one kernel body, two grid layouts.
# ---------------------------------------------------------------------------
def _spmm_coo_kernel(rows_ref, cols_ref, vals_ref, x_ref, o_ref, acc_ref, *,
                     n_e: int, n_rows: int, n_src: int, edge_axis: int):
    """Shared COO body: dual one-hot matmuls over one edge chunk.

    ``edge_axis`` is the grid axis that walks edge chunks (the innermost
    one); ``n_rows`` is the scatter one-hot's row extent — the whole
    destination range for the flat layout, one core's Aggregate Buffer
    (``dpc`` rows, block-local offsets) for the Block-Message layout.
    """
    @pl.when(pl.program_id(edge_axis) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    rows = rows_ref[0, :]                       # [be] int32
    cols = cols_ref[0, :]
    vals = vals_ref[0, :]                       # [be] f32 (0 = padding)
    be = rows.shape[0]
    x = x_ref[...]                              # [n_src, bd] VMEM tile

    # gather via one-hot matmul: G[e, :] = x[cols[e], :]; out-of-range cols
    # (the wrappers' padding routes them past n_src) match no one-hot column
    # and gather nothing at all.
    src_iota = jax.lax.broadcasted_iota(jnp.int32, (be, n_src), 1)
    onehot_src = (src_iota == cols[:, None]).astype(x.dtype)
    g = jnp.dot(onehot_src, x, preferred_element_type=jnp.float32)

    # scatter-add via one-hot matmul, edge weights folded into the one-hot
    dst_iota = jax.lax.broadcasted_iota(jnp.int32, (n_rows, be), 0)
    onehot_dst = jnp.where(dst_iota == rows[None, :], vals[None, :], 0.0)
    acc_ref[...] += jnp.dot(onehot_dst.astype(jnp.float32), g,
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(edge_axis) == n_e - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("dpc", "bd", "be", "interpret"))
def spmm_block(rows: jnp.ndarray, cols: jnp.ndarray, vals: jnp.ndarray,
               x: jnp.ndarray, dpc: int, *, bd: int = 128, be: int = 256,
               interpret: bool = False) -> jnp.ndarray:
    """Block-layout SpMM: ``y[b*dpc + r] += v * x[c]`` over per-destination-
    block COO tiles (:class:`repro.core.blockmsg.BlockTiles` arrays).

    ``rows``/``cols``/``vals``: [n_blocks, e_blk] with block-local row
    offsets in ``[0, dpc)``; ``x``: the sender's dense [n_src, d] feature
    shard.  Returns [n_blocks * dpc, d] — tile *b* is the partial rows this
    sender contributes to destination core *b*, ready for the hypercube
    fold.  ``e_blk`` and ``d`` must be multiples of (be, bd); pad edges with
    val=0 (:func:`repro.kernels.ops.spmm_block` absorbs the padding).
    """
    n_blocks, e_blk = rows.shape
    n_src, d = x.shape
    if e_blk % be or d % bd:
        raise ValueError(
            f"e_blk={e_blk}, d={d} not divisible by (be={be}, bd={bd})")
    grid = (n_blocks, d // bd, e_blk // be)
    kernel = functools.partial(_spmm_coo_kernel, n_e=grid[2], n_rows=dpc,
                               n_src=n_src, edge_axis=2)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, be), lambda i, j, k: (i, k)),
            pl.BlockSpec((1, be), lambda i, j, k: (i, k)),
            pl.BlockSpec((1, be), lambda i, j, k: (i, k)),
            pl.BlockSpec((n_src, bd), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((dpc, bd), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n_blocks * dpc, d), x.dtype),
        scratch_shapes=[pltpu.VMEM((dpc, bd), jnp.float32)],
        interpret=interpret,
    )(rows.astype(jnp.int32), cols.astype(jnp.int32),
      vals.astype(jnp.float32), x)


@functools.partial(jax.jit, static_argnames=("n_dst", "bd", "be", "interpret"))
def spmm(rows: jnp.ndarray, cols: jnp.ndarray, vals: jnp.ndarray,
         x: jnp.ndarray, n_dst: int, *, bd: int = 128, be: int = 256,
         interpret: bool = False) -> jnp.ndarray:
    """``y[r] += v * x[c]`` over a COO edge list, y: [n_dst, d].

    ``n_dst`` is one core-block's row count (the Aggregate Buffer size);
    ``x`` is the VMEM-resident dense source block.  Edge count and feature
    dim must be multiples of (be, bd) — pad edges with val=0.
    """
    e = rows.shape[0]
    n_src, d = x.shape
    if e % be or d % bd:
        raise ValueError(f"e={e}, d={d} not divisible by (be={be}, bd={bd})")
    grid = (d // bd, e // be)
    kernel = functools.partial(_spmm_coo_kernel, n_e=grid[1], n_rows=n_dst,
                               n_src=n_src, edge_axis=1)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, be), lambda j, k: (0, k)),
            pl.BlockSpec((1, be), lambda j, k: (0, k)),
            pl.BlockSpec((1, be), lambda j, k: (0, k)),
            pl.BlockSpec((n_src, bd), lambda j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((n_dst, bd), lambda j, k: (0, j)),
        out_shape=jax.ShapeDtypeStruct((n_dst, d), x.dtype),
        scratch_shapes=[pltpu.VMEM((n_dst, bd), jnp.float32)],
        interpret=interpret,
    )(rows.reshape(1, e).astype(jnp.int32),
      cols.reshape(1, e).astype(jnp.int32),
      vals.reshape(1, e).astype(jnp.float32), x)


# ---------------------------------------------------------------------------
# Pre-reduced ELL family (the hot path): src-tiled, scatter-free.
# ---------------------------------------------------------------------------
def _spmm_ell_kernel(cols_ref, vals_ref, x_ref, o_ref, acc_ref, *,
                     n_s: int, bs: int, kc: int = 16):
    """Gather-accumulate over one ELL row tile × one source tile.

    Builds the merge matrix S[r, s] = Σ_k vals[r,k]·[cols[r,k] == s_global]
    on the VPU (the Reduced Register File fold), then a single MXU matmul
    S @ X.  Entries whose column lies outside this source tile never match
    the tile-local iota — source tiling costs nothing.  Padding entries
    carry weight 0 AND point at the plan's dedicated zero row, so they are
    no-ops twice over.

    The degree axis is folded in static chunks of ``kc`` so the one-hot
    intermediate is [br, ≤kc, bs] — hub buckets (merged degree in the
    thousands) stay a few hundred KB of VMEM instead of scaling the
    temporary with K.
    """
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    cols = cols_ref[...]                        # [br, K] int32
    vals = vals_ref[...]                        # [br, K] f32 (0 = padding)
    x = x_ref[...]                              # [bs, bd] VMEM source tile
    local = cols - pl.program_id(2) * bs        # tile-local column ids
    br, K = cols.shape
    merge = jnp.zeros((br, bs), jnp.float32)    # [br, bs] — scatter-free
    for k0 in range(0, K, kc):
        lc = local[:, k0:k0 + kc]
        lv = vals[:, k0:k0 + kc]
        s_iota = jax.lax.broadcasted_iota(
            jnp.int32, (br, lc.shape[1], bs), 2)
        merge += jnp.where(s_iota == lc[:, :, None], lv[:, :, None],
                           0.0).sum(axis=1)
    acc_ref[...] += jnp.dot(merge, x.astype(jnp.float32),
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == n_s - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("br", "bd", "bs", "interpret"))
def spmm_ell(cols: jnp.ndarray, vals: jnp.ndarray, x: jnp.ndarray, *,
             br: int = 128, bd: int = 128, bs: int = 128,
             interpret: bool = False) -> jnp.ndarray:
    """Pre-reduced ELL SpMM: ``y[r] = Σ_k vals[r, k] · x[cols[r, k]]``.

    ``cols``/``vals``: [nb, K] one degree bucket of an
    :class:`repro.kernels.edgeplan.EllTables`; ``x``: [n_src_p, d] with the
    plan's dedicated zero row included.  Grid = (nb/br, d/bd, n_src_p/bs)
    with the SOURCE axis innermost — only a [bs, bd] tile of ``x`` is
    resident per step, so the kernel scales past whole-shard VMEM staging.
    All of nb, d, n_src_p must be tile multiples
    (:func:`repro.kernels.ops.spmm_ell` absorbs padding).
    """
    nb, K = cols.shape
    n_src_p, d = x.shape
    if nb % br or d % bd or n_src_p % bs:
        raise ValueError(f"nb={nb}, d={d}, n_src={n_src_p} not divisible by "
                         f"(br={br}, bd={bd}, bs={bs})")
    grid = (nb // br, d // bd, n_src_p // bs)
    kernel = functools.partial(_spmm_ell_kernel, n_s=grid[2], bs=bs)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, K), lambda i, j, k: (i, 0)),
            pl.BlockSpec((br, K), lambda i, j, k: (i, 0)),
            pl.BlockSpec((bs, bd), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((br, bd), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((nb, d), x.dtype),
        scratch_shapes=[pltpu.VMEM((br, bd), jnp.float32)],
        interpret=interpret,
    )(cols.astype(jnp.int32), vals.astype(jnp.float32), x)


def spmm_ell_t(t_cols: jnp.ndarray, t_vals: jnp.ndarray, e: jnp.ndarray, *,
               br: int = 128, bd: int = 128, bs: int = 128,
               interpret: bool = False) -> jnp.ndarray:
    """Transpose-free backward walk: ``dx[c] = Σ_k t_vals[c, k]·e[t_cols[c, k]]``.

    The SAME gather-accumulate kernel as :func:`spmm_ell`, fed the plan's
    column-major (Graph Converter order) tables — ``Aᵀ e`` as a kernel, with
    no ``Aᵀ`` table, no transposed error copy, and no segment-sum scatter.
    """
    return spmm_ell(t_cols, t_vals, e, br=br, bd=bd, bs=bs,
                    interpret=interpret)
