"""Tile/bucket autotuner for the pre-reduced ELL aggregation engine.

A small sweep over the knobs that matter — the (br, bd, bs) kernel tiles
and the degree-bucket capacity scheme (``caps``) of
:mod:`repro.kernels.edgeplan` — timed on a synthetic skewed graph, with the
winner persisted to JSON so every later process (and every training step)
just reads the file.

    from repro.kernels import tune
    cfg = tune.get_config()        # file → env override → backend defaults
    rec = tune.autotune()          # run the sweep, persist, return record

Resolution order of :func:`get_config`:

1. in-process cache;
2. the JSON file at ``$REPRO_AUTOTUNE_PATH`` (default
   ``BENCH_autotune.json`` in the CWD — benchmarks/CI write and upload it);
3. backend defaults (no implicit sweep: tests and library imports must stay
   hermetic — benchmarks and first-use call :func:`autotune` explicitly).

The file→env→default persistence itself lives in
:class:`repro.engine.plans.RecordStore` — the same contract the spec
planner's ``BENCH_planner.json`` rides — this module keeps only the
ELL-specific parts (backend defaults, the sweep, the record schema).

The bucket-scheme arm times the real consumer (the jitted
``ell_aggregate`` forward+backward) per candidate; the tile arm only runs
where tiles matter (a native TPU backend — interpret-mode timings would
tune the numpy emulator, not the hardware).
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional

_STORE = None


def _store():
    # lazy: importing repro.engine at module load would cycle back through
    # the formats' kernel imports; by call time everything is registered
    global _STORE
    if _STORE is None:
        from repro.engine.plans import RecordStore
        _STORE = RecordStore(DEFAULT_FILENAME, ENV_PATH)
    return _STORE


DEFAULT_FILENAME = "BENCH_autotune.json"
ENV_PATH = "REPRO_AUTOTUNE_PATH"

# Safe fall-back tiles per backend; caps="pow2" keeps skewed rows from
# inflating everyone's padding even before any sweep has run.
DEFAULTS: Dict[str, Dict] = {
    "tpu": {"br": 128, "bd": 128, "bs": 128, "caps": "pow2"},
    "gpu": {"br": 128, "bd": 128, "bs": 128, "caps": "pow2"},
    "cpu": {"br": 128, "bd": 128, "bs": 128, "caps": "pow2"},
}

CAPS_CANDIDATES = ["pow2", "single", [2, 8, 32]]
TILE_CANDIDATES = [(128, 128, 128), (64, 128, 256), (256, 128, 128),
                   (128, 256, 128)]

_config: Optional[Dict] = None


def cache_path() -> str:
    return _store().path()


def _backend() -> str:
    import jax
    return jax.default_backend()


def get_config() -> Dict:
    """The tuned config (see module docstring for resolution order)."""
    global _config
    if _config is not None:
        return _config
    cfg = dict(DEFAULTS.get(_backend(), DEFAULTS["cpu"]))
    rec = _store().load()             # unreadable/corrupt cache → None
    if rec is not None and rec.get("backend") == _backend():
        try:
            cfg.update(rec.get("config", {}))
        except (ValueError, TypeError):
            pass                      # malformed config block → defaults
    _config = cfg
    return cfg


def reset() -> None:
    """Drop the in-process cache (tests; after writing a new file)."""
    global _config
    _config = None


def _bench_plan_caps(caps, n: int, deg: int, d: int, n_reps: int,
                     seed: int) -> float:
    """Seconds per fwd+bwd of the jitted ELL aggregate under one scheme."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.graph.coo import from_edges
    from repro.kernels import edgeplan
    from repro.kernels.ops import ell_aggregate

    rng = np.random.default_rng(seed)
    # skewed degrees: a few hubs + a long tail (the case bucketing targets)
    rows = np.concatenate([
        rng.integers(0, n, n * deg),
        rng.integers(0, max(n // 16, 1), n * deg // 2),   # hub rows
    ])
    e = len(rows)
    coo = from_edges(rows, rng.integers(0, n, e),
                     np.abs(rng.standard_normal(e)).astype(np.float32) + 0.1,
                     n, n)
    plan = edgeplan.build_plan(coo, caps=caps)
    tables = plan.device_tables()
    x = jnp.asarray(rng.standard_normal((n, d)), jnp.float32)
    g = jax.jit(jax.grad(lambda xx: (ell_aggregate(tables, xx) ** 2).sum()))
    jax.block_until_ready(g(x))              # compile
    t0 = time.perf_counter()
    for _ in range(n_reps):
        jax.block_until_ready(g(x))
    return (time.perf_counter() - t0) / n_reps


def _bench_tiles(br: int, bd: int, bs: int, n: int, d: int, n_reps: int,
                 seed: int) -> float:
    """Seconds per native spmm_ell call for one tile triple (TPU only)."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.kernels.ops import spmm_ell

    rng = np.random.default_rng(seed)
    K = 8
    cols = jnp.asarray(rng.integers(0, n, (n, K)), jnp.int32)
    vals = jnp.asarray(np.abs(rng.standard_normal((n, K))), jnp.float32)
    x = jnp.asarray(rng.standard_normal((n, d)), jnp.float32)
    out = spmm_ell(cols, vals, x, br=br, bd=bd, bs=bs)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(n_reps):
        jax.block_until_ready(spmm_ell(cols, vals, x, br=br, bd=bd, bs=bs))
    return (time.perf_counter() - t0) / n_reps


def autotune(path: Optional[str] = None, *, force: bool = False,
             n: int = 512, deg: int = 8, d: int = 64, n_reps: int = 5,
             seed: int = 0) -> Dict:
    """Run the sweep, persist the winner to ``path``, return the record.

    Idempotent per file: an existing record for this backend is returned
    untouched unless ``force`` — so "first use" sweeps once per machine,
    and the training loop never re-tunes.
    """
    path = path or cache_path()
    backend = _backend()
    if not force:
        rec = _store().load(path)
        if rec is not None and rec.get("backend") == backend:
            return rec

    caps_timings: List[Dict] = []
    for caps in CAPS_CANDIDATES:
        s = _bench_plan_caps(caps, n, deg, d, n_reps, seed)
        caps_timings.append({"caps": caps, "s_per_fwdbwd": s})
    best_caps = min(caps_timings, key=lambda r: r["s_per_fwdbwd"])["caps"]

    tile_timings: List[Dict] = []
    best_tiles = tuple(DEFAULTS.get(backend, DEFAULTS["cpu"])[k]
                       for k in ("br", "bd", "bs"))
    if backend == "tpu":              # interpret timings would tune numpy
        for br, bd, bs in TILE_CANDIDATES:
            s = _bench_tiles(br, bd, bs, max(n, 256), max(d, 128), n_reps,
                             seed)
            tile_timings.append({"br": br, "bd": bd, "bs": bs, "s": s})
        best = min(tile_timings, key=lambda r: r["s"])
        best_tiles = (best["br"], best["bd"], best["bs"])

    rec = {
        "backend": backend,
        "config": {"br": best_tiles[0], "bd": best_tiles[1],
                   "bs": best_tiles[2], "caps": best_caps},
        "sweep": {"caps": caps_timings, "tiles": tile_timings,
                  "n": n, "deg": deg, "d": d, "n_reps": n_reps},
    }
    _store().save(rec, path)
    reset()                           # next get_config() sees the new file
    return rec
