"""Graph Converter — row-major ⇄ column-major COO re-sorting (paper §4.1).

The accelerator stores each adjacency block exactly once (COO, diagonal
storage) and *re-sorts* it between the forward pass (row-major: aggregate
into destination rows) and the backward pass (column-major: aggregate into
source columns, i.e. multiply by A^T) instead of storing an edge table twice.
Table 3 attributes ~1 edge table of HBM savings to this.

On TPU the analogous cost model holds: a sort is O(e log e) once per graph
(host- or trace-time), while a materialized transpose of A would double HBM
residency and the segment-sum SpMM wants its segment ids sorted for locality.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

from .coo import COO, from_edges


def sort_row_major(coo: COO) -> COO:
    """Sort edges by (row, col) — forward aggregation order."""
    rows = np.asarray(coo.rows)
    cols = np.asarray(coo.cols)
    vals = np.asarray(coo.vals)
    order = np.lexsort((cols, rows))
    return from_edges(rows[order], cols[order], vals[order], coo.n_dst, coo.n_src)


def sort_col_major(coo: COO) -> COO:
    """Sort edges by (col, row) — backward aggregation order (A^T walk)."""
    rows = np.asarray(coo.rows)
    cols = np.asarray(coo.cols)
    vals = np.asarray(coo.vals)
    order = np.lexsort((rows, cols))
    return from_edges(rows[order], cols[order], vals[order], coo.n_dst, coo.n_src)


def to_backward(coo_row_major: COO) -> COO:
    """Produce the backward-order view WITHOUT transposing: same edges,
    column-major sort.  Consumers use :meth:`COO.rmatmul` on it.  This is the
    transpose-free contract: no new edge table, no (n_src × n_dst) object."""
    return sort_col_major(coo_row_major)
