"""GraphSAGE neighbor sampler (paper §5.1: fanouts 25 → 10).

Mini-batch construction for sampled GCN/GraphSAGE training.  Produces the
per-layer *rectangular* adjacencies the paper's sequence estimator reasons
about: layer l has A_l ∈ R^{n_l × n_{l+1}} where n_l are the nodes needed at
hop l (n_0 = batch) and n_{l+1} their sampled frontier.

Pure-numpy host-side pipeline (this is data loading, not device compute);
emits static-shaped, padded COO so the device step function never re-traces.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

from .coo import COO, from_edges, mean_normalize, pad_coo
from .partition import pad_to_multiple


@dataclasses.dataclass(frozen=True)
class CSRGraph:
    """Host-side full-graph container (indptr/indices CSR)."""

    indptr: np.ndarray   # [n+1] int64
    indices: np.ndarray  # [e] int32/int64, neighbor ids
    n_nodes: int

    @property
    def n_edges(self) -> int:
        return int(self.indices.shape[0])

    def degree(self, nodes: np.ndarray) -> np.ndarray:
        return self.indptr[nodes + 1] - self.indptr[nodes]


def csr_from_edges(src: np.ndarray, dst: np.ndarray, n_nodes: int) -> CSRGraph:
    """Build CSR adjacency (out-neighbors of each node), symmetrizing is the
    caller's business (datasets.py emits both directions for undirected)."""
    order = np.argsort(src, kind="stable")
    src = src[order]
    dst = dst[order]
    counts = np.bincount(src, minlength=n_nodes)
    indptr = np.zeros(n_nodes + 1, np.int64)
    np.cumsum(counts, out=indptr[1:])
    return CSRGraph(indptr=indptr, indices=dst.astype(np.int64), n_nodes=n_nodes)


@dataclasses.dataclass(frozen=True)
class MiniBatch:
    """One sampled mini-batch: per-layer adjacencies + input features/labels.

    ``layers[l]`` aggregates hop-(l+1) nodes into hop-l nodes;
    ``layers[-1]`` consumes the raw input features.  All shapes are padded to
    static sizes so a single jit trace serves the whole epoch.
    """

    layers: Tuple[COO, ...]          # rectangular, row-major sorted, padded
    input_nodes: np.ndarray          # [n_last_padded] global ids of frontier
    seed_nodes: np.ndarray           # [batch] global ids of the batch
    n_real: Tuple[int, ...]          # true (unpadded) node count per hop


class NeighborSampler:
    """Uniform neighbor sampling with replacement-free capped fanout.

    ``pad_multiple`` pads every hop's node count (and 16× the edge count) so
    shapes are stable; with the production mesh this is P=16 so each hop
    splits evenly across cores.
    """

    def __init__(self, graph: CSRGraph, fanouts: Sequence[int],
                 pad_multiple: int = 16, seed: int = 0):
        self.graph = graph
        self.fanouts = tuple(fanouts)
        self.pad_multiple = pad_multiple
        self.rng = np.random.default_rng(seed)

    def _sample_layer(self, seeds: np.ndarray, fanout: int,
                      rng: Optional[np.random.Generator] = None
                      ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Return (rows_local, frontier_nodes, cols_local): for each seed node
        (row r) up to ``fanout`` sampled neighbors; frontier includes the
        seeds themselves (self loop, GCN-style Ã = A + I)."""
        g = self.graph
        rng = rng if rng is not None else self.rng
        deg = g.degree(seeds)
        take = np.minimum(deg, fanout)
        rows = np.repeat(np.arange(len(seeds), dtype=np.int64), take)
        # vectorized per-seed choice: random offsets into each CSR row
        total = int(take.sum())
        if total:
            u = rng.random(total)
            row_start = np.repeat(g.indptr[seeds], take)
            row_deg = np.repeat(deg, take).astype(np.float64)
            offs = np.floor(u * row_deg).astype(np.int64)
            picked = g.indices[row_start + offs]
        else:
            picked = np.zeros(0, np.int64)
        # frontier = seeds ∪ picked (seeds first so hop-l nodes keep ids)
        frontier, inv = np.unique(np.concatenate([seeds, picked]),
                                  return_inverse=True)
        # remap so that seeds occupy [0, len(seeds)) in the frontier ordering
        seed_pos = inv[:len(seeds)]
        remap = np.full(len(frontier), -1, np.int64)
        remap[seed_pos] = np.arange(len(seeds))
        rest = np.flatnonzero(remap < 0)
        remap[rest] = len(seeds) + np.arange(len(rest))
        frontier_sorted = np.empty_like(frontier)
        frontier_sorted[remap] = frontier
        cols = remap[inv[len(seeds):]]
        # self loops: row r aggregates frontier slot r too
        self_rows = np.arange(len(seeds), dtype=np.int64)
        rows = np.concatenate([rows, self_rows])
        cols = np.concatenate([cols, self_rows])
        return rows, frontier_sorted, cols

    def sample(self, seeds: np.ndarray,
               nnz_pad: Optional[Sequence[int]] = None,
               rng: Optional[np.random.Generator] = None) -> MiniBatch:
        """``rng``: pass a per-batch generator for deterministic-resume
        pipelines (the stateful default is fine for one-shot sampling)."""
        seeds = np.asarray(seeds, np.int64)
        layers: List[COO] = []
        n_real = [len(seeds)]
        cur = seeds
        for l, fanout in enumerate(self.fanouts):
            rows, frontier, cols = self._sample_layer(cur, fanout, rng)
            n_dst = pad_to_multiple(len(cur), self.pad_multiple)
            n_src = pad_to_multiple(len(frontier), self.pad_multiple)
            coo = mean_normalize(rows, cols, n_dst=n_dst, n_src=n_src)
            if nnz_pad is not None:
                coo = pad_coo(coo, nnz_pad[l])
            layers.append(coo)
            n_real.append(len(frontier))
            cur = frontier
        frontier_padded = np.zeros(pad_to_multiple(len(cur), self.pad_multiple),
                                   np.int64)
        frontier_padded[:len(cur)] = cur
        return MiniBatch(layers=tuple(layers), input_nodes=frontier_padded,
                         seed_nodes=seeds, n_real=tuple(n_real))

    def static_nnz(self, batch_size: int) -> Tuple[int, ...]:
        """Worst-case padded nnz per layer (fanout+selfloop bound) so the
        device step compiles once."""
        sizes = []
        cur = batch_size
        for fanout in self.fanouts:
            sizes.append(pad_to_multiple(cur * (fanout + 1), 128))
            cur = cur * (fanout + 1)  # upper bound on frontier growth
        return tuple(sizes)


def epoch_batches(n_nodes: int, batch_size: int, rng: np.random.Generator):
    """Shuffled full-epoch seed batches (drop ragged tail, as the paper's
    fixed-1024 batches do)."""
    perm = rng.permutation(n_nodes)
    n_full = (n_nodes // batch_size) * batch_size
    for s in range(0, n_full, batch_size):
        yield perm[s:s + batch_size]
