from .coo import COO, from_edges, mean_normalize, pad_coo, sym_normalize
from .convert import sort_col_major, sort_row_major, to_backward
from .partition import (BlockedCOO, anti_diagonal_stages, block_partition,
                        core_of, diagonal_storage_mask, local_addr,
                        pad_to_multiple, partition_features,
                        sender_blocks)
from .sampler import CSRGraph, MiniBatch, NeighborSampler, csr_from_edges, epoch_batches
from .datasets import DATASET_STATS, DatasetStats, GraphDataset, make_dataset

__all__ = [
    "COO", "from_edges", "mean_normalize", "pad_coo", "sym_normalize",
    "sort_col_major", "sort_row_major", "to_backward",
    "BlockedCOO", "anti_diagonal_stages", "block_partition", "core_of",
    "diagonal_storage_mask", "local_addr", "pad_to_multiple",
    "partition_features", "sender_blocks",
    "CSRGraph", "MiniBatch", "NeighborSampler", "csr_from_edges",
    "epoch_batches",
    "DATASET_STATS", "DatasetStats", "GraphDataset", "make_dataset",
]
