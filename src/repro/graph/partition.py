"""Graph partitioning for the NUMA / multi-core layout (paper §4.1, §4.3.3).

The paper shards a 1024-node subgraph across 16 cores (64 nodes per core) and
tiles the adjacency matrix into 16×16 blocks of 64×64.  Blocks are processed
in *stages* of four anti-diagonals (64 blocks per stage, arranged into 4
"groups" of 16 conflict-free block queues) so that within a group every
(destination core, source core) pair is unique — the precondition for the
4-group parallel multicast of Algorithm 1.

Here the "core" axis generalizes to the P-way ``model`` mesh axis (P=16 for
the production mesh — exactly the paper's 4-D hypercube).  Node→core
assignment is contiguous (``node // tile``) which matches the paper's
address-decode scheme: high bits of a node id = core id, low bits = local
buffer address (Fig. 7).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

import numpy as np

from .coo import COO, from_edges


def core_of(node: np.ndarray, nodes_per_core: int) -> np.ndarray:
    """High bits = core id (paper Fig. 7 address decode)."""
    return node // nodes_per_core


def local_addr(node: np.ndarray, nodes_per_core: int) -> np.ndarray:
    """Low bits = local buffer address."""
    return node % nodes_per_core


@dataclasses.dataclass(frozen=True)
class BlockedCOO:
    """Adjacency tiled into P×P blocks with per-block local indices.

    ``block_edges[(i, j)]`` holds (local_rows, local_cols, vals) of the block
    whose destinations live on core ``i`` and sources on core ``j``.
    """

    n_cores: int
    dst_per_core: int
    src_per_core: int
    block_edges: Dict[Tuple[int, int], Tuple[np.ndarray, np.ndarray, np.ndarray]]

    def block_nnz(self) -> np.ndarray:
        out = np.zeros((self.n_cores, self.n_cores), np.int64)
        for (i, j), (r, _, _) in self.block_edges.items():
            out[i, j] = len(r)
        return out

    def nnz(self) -> int:
        return int(self.block_nnz().sum())


def block_partition(coo: COO, n_cores: int) -> BlockedCOO:
    """Tile a (padded-to-multiple) adjacency into P×P core blocks."""
    rows = np.asarray(coo.rows, np.int64)
    cols = np.asarray(coo.cols, np.int64)
    vals = np.asarray(coo.vals, np.float32)
    if coo.n_dst % n_cores or coo.n_src % n_cores:
        raise ValueError(
            f"n_dst={coo.n_dst}, n_src={coo.n_src} must be multiples of P={n_cores}; "
            "pad the graph first")
    dpc = coo.n_dst // n_cores
    spc = coo.n_src // n_cores
    keep = vals != 0  # drop padding edges
    rows, cols, vals = rows[keep], cols[keep], vals[keep]
    bi = core_of(rows, dpc)
    bj = core_of(cols, spc)
    block_edges = {}
    # single lexsort pass, then split — O(e log e) like the Graph Converter
    order = np.lexsort((cols, rows, bj, bi))
    bi, bj = bi[order], bj[order]
    rows, cols, vals = rows[order], cols[order], vals[order]
    key = bi * n_cores + bj
    boundaries = np.flatnonzero(np.diff(key)) + 1
    for seg_rows, seg_cols, seg_vals, seg_key in zip(
            np.split(rows, boundaries), np.split(cols, boundaries),
            np.split(vals, boundaries), np.split(key, boundaries)):
        if len(seg_rows) == 0:
            continue
        i, j = divmod(int(seg_key[0]), n_cores)
        block_edges[(i, j)] = (
            (seg_rows - i * dpc).astype(np.int32),
            (seg_cols - j * spc).astype(np.int32),
            seg_vals,
        )
    return BlockedCOO(n_cores=n_cores, dst_per_core=dpc, src_per_core=spc,
                      block_edges=block_edges)


def sender_blocks(blocked: BlockedCOO, src_core: int
                  ) -> List[Tuple[int, Tuple[np.ndarray, np.ndarray, np.ndarray]]]:
    """Column ``src_core`` of the block grid, ascending by destination core.

    These are the blocks one sender owns (its Block-Message buffers); the
    pre-reduced edge-plan builder compresses each and stacks the merged
    rows into the sender's ELL tables.
    """
    return [(i, blocked.block_edges[(i, src_core)])
            for i in range(blocked.n_cores)
            if (i, src_core) in blocked.block_edges]


def anti_diagonal_stages(n_cores: int, group_size: int = 4) -> List[List[List[Tuple[int, int]]]]:
    """Stage/group schedule of blocks (paper Fig. 6(a)).

    Returns ``stages[s][g] = [(i, j), ...]`` where each group ``g`` is one
    anti-diagonal ``(i - j) % P == d``: within a group all destination cores
    and all source cores are distinct, so 16 messages can start in parallel
    with unique (A, C) pairs — the paper's conflict-free block queues.  A
    stage bundles ``group_size`` consecutive anti-diagonals (4 in the paper ⇒
    64 blocks per stage ⇒ 64-message multicast rounds).
    """
    diagonals = []
    for d in range(n_cores):
        diagonals.append([(i, (i - d) % n_cores) for i in range(n_cores)])
    stages = []
    for s in range(0, n_cores, group_size):
        stages.append(diagonals[s:s + group_size])
    return stages


def diagonal_storage_mask(n_cores: int) -> np.ndarray:
    """Upper-triangle block mask — "diagonal storage" keeps one triangle of an
    undirected adjacency (paper §4.3.3); the lower triangle is regenerated by
    the Graph Converter as the column-major walk of the same blocks."""
    return np.triu(np.ones((n_cores, n_cores), dtype=bool))


def pad_to_multiple(n: int, mult: int) -> int:
    return ((n + mult - 1) // mult) * mult


def partition_features(n_nodes: int, n_cores: int) -> np.ndarray:
    """Contiguous row partition of the feature matrix: rows owned by core i
    are [i*tile, (i+1)*tile) — the NUMA placement (features of a core's nodes
    live in that core's HBM channels)."""
    if n_nodes % n_cores:
        raise ValueError("pad nodes to a multiple of the core count first")
    tile = n_nodes // n_cores
    return np.arange(n_nodes).reshape(n_cores, tile)
