"""Graph partitioning for the NUMA / multi-core layout (paper §4.1, §4.3.3).

The paper shards a 1024-node subgraph across 16 cores (64 nodes per core) and
tiles the adjacency matrix into 16×16 blocks of 64×64.  Blocks are processed
in *stages* of four anti-diagonals (64 blocks per stage, arranged into 4
"groups" of 16 conflict-free block queues) so that within a group every
(destination core, source core) pair is unique — the precondition for the
4-group parallel multicast of Algorithm 1.

Here the "core" axis generalizes to the P-way ``model`` mesh axis (P=16 for
the production mesh — exactly the paper's 4-D hypercube).  Node→core
assignment is contiguous (``node // tile``) which matches the paper's
address-decode scheme: high bits of a node id = core id, low bits = local
buffer address (Fig. 7).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

import numpy as np

from .coo import COO, from_edges


def core_of(node: np.ndarray, nodes_per_core: int) -> np.ndarray:
    """High bits = core id (paper Fig. 7 address decode)."""
    return node // nodes_per_core


def local_addr(node: np.ndarray, nodes_per_core: int) -> np.ndarray:
    """Low bits = local buffer address."""
    return node % nodes_per_core


@dataclasses.dataclass(frozen=True)
class BlockedCOO:
    """Adjacency tiled into P×P blocks with per-block local indices.

    ``block_edges[(i, j)]`` holds (local_rows, local_cols, vals) of the block
    whose destinations live on core ``i`` and sources on core ``j``.
    """

    n_cores: int
    dst_per_core: int
    src_per_core: int
    block_edges: Dict[Tuple[int, int], Tuple[np.ndarray, np.ndarray, np.ndarray]]

    def block_nnz(self) -> np.ndarray:
        out = np.zeros((self.n_cores, self.n_cores), np.int64)
        for (i, j), (r, _, _) in self.block_edges.items():
            out[i, j] = len(r)
        return out

    def nnz(self) -> int:
        return int(self.block_nnz().sum())


def block_partition(coo: COO, n_cores: int) -> BlockedCOO:
    """Tile a (padded-to-multiple) adjacency into P×P core blocks."""
    rows = np.asarray(coo.rows, np.int64)
    cols = np.asarray(coo.cols, np.int64)
    vals = np.asarray(coo.vals, np.float32)
    if coo.n_dst % n_cores or coo.n_src % n_cores:
        raise ValueError(
            f"n_dst={coo.n_dst}, n_src={coo.n_src} must be multiples of P={n_cores}; "
            "pad the graph first")
    dpc = coo.n_dst // n_cores
    spc = coo.n_src // n_cores
    keep = vals != 0  # drop padding edges
    rows, cols, vals = rows[keep], cols[keep], vals[keep]
    bi = core_of(rows, dpc)
    bj = core_of(cols, spc)
    block_edges = {}
    # single lexsort pass, then split — O(e log e) like the Graph Converter
    order = np.lexsort((cols, rows, bj, bi))
    bi, bj = bi[order], bj[order]
    rows, cols, vals = rows[order], cols[order], vals[order]
    key = bi * n_cores + bj
    boundaries = np.flatnonzero(np.diff(key)) + 1
    for seg_rows, seg_cols, seg_vals, seg_key in zip(
            np.split(rows, boundaries), np.split(cols, boundaries),
            np.split(vals, boundaries), np.split(key, boundaries)):
        if len(seg_rows) == 0:
            continue
        i, j = divmod(int(seg_key[0]), n_cores)
        block_edges[(i, j)] = (
            (seg_rows - i * dpc).astype(np.int32),
            (seg_cols - j * spc).astype(np.int32),
            seg_vals,
        )
    return BlockedCOO(n_cores=n_cores, dst_per_core=dpc, src_per_core=spc,
                      block_edges=block_edges)


def sender_blocks(blocked: BlockedCOO, src_core: int
                  ) -> List[Tuple[int, Tuple[np.ndarray, np.ndarray, np.ndarray]]]:
    """Column ``src_core`` of the block grid, ascending by destination core.

    These are the blocks one sender owns (its Block-Message buffers); the
    pre-reduced edge-plan builder compresses each and stacks the merged
    rows into the sender's ELL tables.
    """
    return [(i, blocked.block_edges[(i, src_core)])
            for i in range(blocked.n_cores)
            if (i, src_core) in blocked.block_edges]


def anti_diagonal_stages(n_cores: int, group_size: int = 4) -> List[List[List[Tuple[int, int]]]]:
    """Stage/group schedule of blocks (paper Fig. 6(a)).

    Returns ``stages[s][g] = [(i, j), ...]`` where each group ``g`` is one
    anti-diagonal ``(i - j) % P == d``: within a group all destination cores
    and all source cores are distinct, so 16 messages can start in parallel
    with unique (A, C) pairs — the paper's conflict-free block queues.  A
    stage bundles ``group_size`` consecutive anti-diagonals (4 in the paper ⇒
    64 blocks per stage ⇒ 64-message multicast rounds).
    """
    diagonals = []
    for d in range(n_cores):
        diagonals.append([(i, (i - d) % n_cores) for i in range(n_cores)])
    stages = []
    for s in range(0, n_cores, group_size):
        stages.append(diagonals[s:s + group_size])
    return stages


def diagonal_storage_mask(n_cores: int) -> np.ndarray:
    """Upper-triangle block mask — "diagonal storage" keeps one triangle of an
    undirected adjacency (paper §4.3.3); the lower triangle is regenerated by
    the Graph Converter as the column-major walk of the same blocks."""
    return np.triu(np.ones((n_cores, n_cores), dtype=bool))


def pad_to_multiple(n: int, mult: int) -> int:
    return ((n + mult - 1) // mult) * mult


def partition_features(n_nodes: int, n_cores: int) -> np.ndarray:
    """Contiguous row partition of the feature matrix: rows owned by core i
    are [i*tile, (i+1)*tile) — the NUMA placement (features of a core's nodes
    live in that core's HBM channels)."""
    if n_nodes % n_cores:
        raise ValueError("pad nodes to a multiple of the core count first")
    tile = n_nodes // n_cores
    return np.arange(n_nodes).reshape(n_cores, tile)


# ---------------------------------------------------------------------------
# Partition quality as an Engine axis (spec knob 4: "naive" | "mincom").
#
# "naive" is everything above: contiguous node//tile striping, the paper's
# address-decode placement — zero host work, but the block-grid cut (and
# therefore the exchange wire volume) is whatever the node numbering
# happens to give.  "mincom" relabels nodes with a capacity-constrained
# greedy label propagation (the communication-volume-minimizing family of
# the distributed-memory scaling literature, arXiv 2212.05009): each node
# moves to the core where most of its neighbors live, subject to exact
# per-core balance, so cross-core (dst-row, sender) pairs — the
# post-merge Block-Message wire unit — drop on community-structured
# graphs.  The result is a plain permutation: downstream layouts still
# see contiguous striping, so every format/schedule/topology runs
# unchanged on the relabeled graph.
# ---------------------------------------------------------------------------
PARTITIONS: Tuple[str, ...] = ("naive", "mincom")


def validate_partition(name: str) -> str:
    if name not in PARTITIONS:
        raise ValueError(
            f"unknown partition {name!r}; registered partitions: {PARTITIONS}")
    return name


def mincom_assignment(rows: np.ndarray, cols: np.ndarray, n_nodes: int,
                      n_cores: int, n_rounds: int = 8) -> np.ndarray:
    """Capacity-constrained greedy label propagation over ONE node space.

    Nodes start on their naive (contiguous) core.  Each round counts every
    node's neighbor votes against the previous round's full assignment,
    then re-places ALL nodes greedily by decreasing degree into their
    plurality core, falling down the vote order when a core is full (exact
    balance: ``n_nodes // n_cores`` per core, so the contiguous-stripe
    layouts keep working after relabeling).  Early-exits on a fixed point;
    8 rounds fully recovers planted communities at bench sizes.
    ``rows``/``cols`` are any edge list over the same node space
    (symmetrized internally — communication is cut edges regardless of
    direction).
    """
    if n_nodes % n_cores:
        raise ValueError("pad nodes to a multiple of the core count first")
    cap = n_nodes // n_cores
    assign = (np.arange(n_nodes) // cap).astype(np.int64)
    if n_cores == 1:
        return assign
    u = np.concatenate([rows, cols]).astype(np.int64)
    v = np.concatenate([cols, rows]).astype(np.int64)
    keep = u != v
    u, v = u[keep], v[keep]
    deg = np.bincount(u, minlength=n_nodes)
    order = np.argsort(-deg, kind="stable")
    for _ in range(max(1, int(n_rounds))):
        # votes against LAST round's full assignment, then a fresh greedy
        # placement (an in-place move rule deadlocks: every core starts at
        # capacity, so no first move is ever legal)
        votes = np.zeros((n_nodes, n_cores), np.int64)
        np.add.at(votes, (u, assign[v]), 1)
        new = np.full(n_nodes, -1, np.int64)
        fill = np.zeros(n_cores, np.int64)
        for node in order:
            pref = np.argsort(-votes[node], kind="stable") if deg[node] \
                else np.argsort(fill, kind="stable")
            for core in pref:
                if fill[core] < cap:
                    new[node] = core
                    fill[core] += 1
                    break
        if np.array_equal(new, assign):
            break
        assign = new
    return assign


def mincom_bipartite(rows_assign: np.ndarray, rows: np.ndarray,
                     cols: np.ndarray, n_src: int,
                     n_cores: int) -> np.ndarray:
    """Assign one SOURCE space given its destination space's fixed cores.

    The sampled-minibatch chain (batch ← mid ← frontier) has a distinct
    node space per hop, so the square propagation above does not apply;
    instead each space is assigned greedily against the space it feeds:
    source node *u* votes for the cores its destination rows live on and
    takes the plurality core with remaining capacity (exact balance,
    ``n_src // n_cores`` per core, nodes visited by decreasing degree).
    """
    if n_src % n_cores:
        raise ValueError("pad nodes to a multiple of the core count first")
    cap = n_src // n_cores
    naive = (np.arange(n_src) // cap).astype(np.int64)
    if n_cores == 1:
        return naive
    votes = np.zeros((n_src, n_cores), np.int64)
    np.add.at(votes, (cols.astype(np.int64),
                      rows_assign[rows.astype(np.int64)]), 1)
    deg = votes.sum(axis=1)
    assign = np.full(n_src, -1, np.int64)
    fill = np.zeros(n_cores, np.int64)
    for node in np.argsort(-deg, kind="stable"):
        placed = False
        for core in np.argsort(-votes[node], kind="stable"):
            if fill[core] < cap:
                assign[node] = core
                fill[core] += 1
                placed = True
                break
        if not placed:              # unreachable: capacities sum to n_src
            assign[node] = int(np.argmin(fill))
            fill[assign[node]] += 1
    return assign


def mincom_layer_perms(layers, n_cores: int) -> List[np.ndarray]:
    """Per-space relabeling permutations for a sampled layer chain.

    ``layers`` are per-hop COOs shallowest-first (``mb.layers`` order):
    layer *i* maps source space *i+1* → destination space *i*, space 0
    being the labeled batch rows.  Space 0 stays identity (labels, logits
    and checkpointed batch order are untouched); each deeper space is
    assigned against the space it feeds via :func:`mincom_bipartite` and
    converted to a contiguous permutation.  Returns ``len(layers) + 1``
    arrays, ``perms[s][old_id] = new_id``; apply layer *i* as
    ``(perms[i][rows], perms[i + 1][cols])`` and permute the frontier
    features with ``perms[-1]``.
    """
    perms = [np.arange(layers[0].n_dst, dtype=np.int64)]
    assign = (np.arange(layers[0].n_dst, dtype=np.int64)
              // max(layers[0].n_dst // n_cores, 1))
    for coo in layers:
        rows = np.asarray(coo.rows, np.int64)
        cols = np.asarray(coo.cols, np.int64)
        keep = np.asarray(coo.vals) != 0
        # rows are in the previous space's OLD numbering, which is exactly
        # what `assign` (old id → core) indexes — no composition needed
        assign = mincom_bipartite(assign, rows[keep], cols[keep],
                                  coo.n_src, n_cores)
        perms.append(partition_permutation(assign, n_cores))
    return perms


def partition_permutation(assign: np.ndarray, n_cores: int) -> np.ndarray:
    """Assignment → relabeling permutation ``perm[old_id] = new_id``.

    New ids are contiguous per core (core *c* owns ``[c·cap, (c+1)·cap)``)
    and preserve the old relative order within a core, so the naive
    assignment maps to the identity permutation.
    """
    order = np.argsort(assign, kind="stable")      # old ids in new order
    perm = np.empty_like(order)
    perm[order] = np.arange(len(assign))
    return perm


def exchange_rows(rows: np.ndarray, cols: np.ndarray, vals: np.ndarray,
                  n_dst: int, n_src: int, n_cores: int) -> int:
    """Post-merge wire volume of a partition, in partial rows.

    Counts distinct ``(destination row, sender core)`` pairs that cross
    cores — after the sender-side merge each such pair ships exactly one
    partial feature row, so this (× d × dtype bytes) IS the exchange's
    wire content.  Feed it to :meth:`repro.topology.base.Topology.plan`
    via ``wire_rows=`` so the cost model sees partition quality.
    """
    rows = np.asarray(rows, np.int64)
    cols = np.asarray(cols, np.int64)
    keep = np.asarray(vals) != 0
    rows, cols = rows[keep], cols[keep]
    dpc = n_dst // n_cores
    spc = n_src // n_cores
    dst_core = rows // dpc
    src_core = cols // spc
    cross = dst_core != src_core
    return int(np.unique(rows[cross] * n_cores + src_core[cross]).size)
