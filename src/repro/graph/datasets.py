"""Synthetic graph datasets with the paper's benchmark statistics.

The container has no network access, so we synthesize power-law graphs whose
(node count, edge count, feature dim, classes) match the four benchmarks the
paper trains on (Flickr / Reddit / Yelp / AmazonProducts — GraphSAINT & SAGE
papers' standard stats).  A ``scale`` knob shrinks node/edge counts for CPU
smoke tests while preserving density and degree skew; benchmarks that quote
full-size numbers use the analytical stats below, not the scaled instance.

Degree skew matters to the paper (their Fig. 10/11 utilization analysis blames
the power-law neighbor distribution), so we generate Chung–Lu style graphs
with a Pareto weight sequence rather than Erdős–Rényi.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np

from .sampler import CSRGraph, csr_from_edges


@dataclasses.dataclass(frozen=True)
class DatasetStats:
    name: str
    n_nodes: int
    n_edges: int      # undirected edge count as usually reported
    feat_dim: int
    n_classes: int
    multilabel: bool = False
    alpha: float = 1.8    # Pareto tail (lower = heavier hubs = more skew)


# Standard statistics (GraphSAINT table 1 / SAGE; what HP-GNN and the paper use)
DATASET_STATS: Dict[str, DatasetStats] = {
    # alpha encodes the relative degree skew the paper's Fig. 11 analysis
    # leans on: reddit is comparatively flat, yelp/amazon are hub-heavy
    "flickr": DatasetStats("flickr", 89_250, 899_756, 500, 7, alpha=1.8),
    "reddit": DatasetStats("reddit", 232_965, 11_606_919, 602, 41,
                           alpha=2.4),
    "yelp": DatasetStats("yelp", 716_847, 6_977_410, 300, 100,
                         multilabel=True, alpha=1.5),
    "amazonproducts": DatasetStats("amazonproducts", 1_598_960, 132_169_734,
                                   200, 107, multilabel=True, alpha=1.35),
}


@dataclasses.dataclass(frozen=True)
class GraphDataset:
    stats: DatasetStats
    graph: CSRGraph               # symmetrized CSR (both directions present)
    #: [n, d] float32 — a dense ndarray (in-memory path) or a
    #: repro.featurestore.FeatureStore (out-of-core path); both share the
    #: shape/dtype/fancy-row-indexing surface every consumer relies on
    features: object
    labels: np.ndarray            # [n] int32 or [n, c] float32 (multilabel)
    scale: float


def _chung_lu_edges(n: int, target_edges: int, alpha: float,
                    rng: np.random.Generator) -> Tuple[np.ndarray, np.ndarray]:
    """Power-law degree sequence via weighted endpoint sampling.

    Draw both endpoints of each edge from a Pareto(alpha) weight distribution;
    expected degree of node i ∝ w_i, giving the heavy-tailed neighbor counts
    the paper's utilization analysis depends on.
    """
    w = rng.pareto(alpha, n) + 1.0
    p = w / w.sum()
    m = target_edges
    src = rng.choice(n, size=m, p=p).astype(np.int64)
    dst = rng.choice(n, size=m, p=p).astype(np.int64)
    keep = src != dst
    return src[keep], dst[keep]


def make_dataset(name: str, scale: float = 1.0, seed: int = 0,
                 feat_dim: Optional[int] = None, features: str = "dense",
                 store_path: Optional[str] = None,
                 chunk_rows: int = 65536) -> GraphDataset:
    """Instantiate a synthetic stand-in for one of the paper's datasets.

    ``scale`` multiplies node and edge counts (density preserved);
    ``feat_dim`` overrides the feature width (tests use small dims).

    ``features`` picks where the feature matrix lives: ``"dense"`` (an
    in-RAM ndarray, the default), or a registered
    :mod:`repro.featurestore` backend name — ``"store"`` (alias for
    ``"host"``) or ``"mmap"`` (a memory-mapped file at ``store_path``, or
    a self-cleaning tempfile).  Store-backed features are generated in
    ``chunk_rows``-row chunks through the store's writer, so a matrix far
    beyond RAM never materializes — and because the generator stream is
    consumed element-sequentially either way, the chunked rows are
    BIT-IDENTICAL to the dense path at the same seed (test-pinned), as
    are the labels drawn after them.
    """
    stats = DATASET_STATS[name]
    rng = np.random.default_rng(seed)
    n = max(int(stats.n_nodes * scale), 64)
    e = max(int(stats.n_edges * scale), 4 * n)
    d = feat_dim if feat_dim is not None else stats.feat_dim
    src, dst = _chung_lu_edges(n, e, alpha=stats.alpha, rng=rng)
    # symmetrize (undirected)
    s2 = np.concatenate([src, dst])
    d2 = np.concatenate([dst, src])
    graph = csr_from_edges(s2, d2, n)
    if features == "dense":
        feats = rng.standard_normal((n, d), dtype=np.float32) * 0.1
    else:
        from repro.featurestore import get_store

        backend = "host" if features == "store" else features
        kwargs = {"path": store_path} if backend == "mmap" else {}
        store = get_store(backend).create(n, d, dtype=np.float32, **kwargs)
        for s in range(0, n, chunk_rows):
            c = min(chunk_rows, n - s)
            store.write_chunk(
                s, rng.standard_normal((c, d), dtype=np.float32) * 0.1)
        feats = store.seal()
    if stats.multilabel:
        labels = (rng.random((n, stats.n_classes)) < 0.05).astype(np.float32)
    else:
        labels = rng.integers(0, stats.n_classes, size=n).astype(np.int32)
    return GraphDataset(stats=stats, graph=graph, features=feats,
                        labels=labels, scale=scale)
