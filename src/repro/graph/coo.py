"""COO graph containers and adjacency normalization.

The paper stores the (sampled, rectangular) adjacency of every GCN layer in
COO format and re-sorts it between row-major (forward aggregation) and
column-major (backward aggregation) order instead of ever materializing A^T
(Section 4.1, "Graph Converter").  This module provides the containers; the
re-sorting lives in :mod:`repro.graph.convert`.

Conventions
-----------
* ``rows`` index **destination** nodes (aggregate targets), ``cols`` index
  **source** nodes (message producers):  ``y[r] += val * x[c]``.
* Rectangular adjacencies (mini-batch sampling makes ``A in R^{n_dst x n_src}``)
  are first-class citizens — the paper's C4 insight depends on them.
* All index arrays are ``int32`` (TPU-friendly), values ``float32``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class COO:
    """A (possibly rectangular) sparse matrix in COO format.

    ``nnz`` entries may include padding: padded entries carry ``val == 0`` and
    point at row/col 0, so every dense op treats them as no-ops.  Static
    shapes (``n_dst``, ``n_src``, padded ``nnz``) keep the whole structure
    jit-stable across mini-batches.
    """

    rows: jnp.ndarray  # [nnz] int32, destination ids
    cols: jnp.ndarray  # [nnz] int32, source ids
    vals: jnp.ndarray  # [nnz] float32, edge weights (0 == padding)
    n_dst: int
    n_src: int

    # -- pytree plumbing ---------------------------------------------------
    def tree_flatten(self):
        return (self.rows, self.cols, self.vals), (self.n_dst, self.n_src)

    @classmethod
    def tree_unflatten(cls, aux, children):
        rows, cols, vals = children
        return cls(rows=rows, cols=cols, vals=vals, n_dst=aux[0], n_src=aux[1])

    # -- basic ops ----------------------------------------------------------
    @property
    def nnz(self) -> int:
        return int(self.rows.shape[0])

    def todense(self) -> jnp.ndarray:
        dense = jnp.zeros((self.n_dst, self.n_src), self.vals.dtype)
        return dense.at[self.rows, self.cols].add(self.vals)

    def transpose(self) -> "COO":
        """Explicit transpose (baseline dataflow only — the paper avoids this)."""
        return COO(rows=self.cols, cols=self.rows, vals=self.vals,
                   n_dst=self.n_src, n_src=self.n_dst)

    def matmul(self, x: jnp.ndarray) -> jnp.ndarray:
        """Reference SpMM  ``y = A @ x``  via segment-sum (pure jnp oracle)."""
        gathered = x[self.cols] * self.vals[:, None]
        return jax.ops.segment_sum(gathered, self.rows, num_segments=self.n_dst)

    def rmatmul(self, e: jnp.ndarray) -> jnp.ndarray:
        """``y = A^T @ e`` *without* materializing A^T: swap index roles.

        This is the Graph Converter in one line — backward aggregation walks
        the same edge list with (row, col) roles exchanged.
        """
        gathered = e[self.rows] * self.vals[:, None]
        return jax.ops.segment_sum(gathered, self.cols, num_segments=self.n_src)


def pad_coo(coo: COO, nnz_padded: int) -> COO:
    """Pad the edge list to a static size (val=0 ⇒ no-op edges)."""
    if coo.nnz > nnz_padded:
        raise ValueError(f"nnz {coo.nnz} exceeds padded size {nnz_padded}")
    pad = nnz_padded - coo.nnz
    return COO(
        rows=jnp.pad(coo.rows, (0, pad)),
        cols=jnp.pad(coo.cols, (0, pad)),
        vals=jnp.pad(coo.vals, (0, pad)),
        n_dst=coo.n_dst,
        n_src=coo.n_src,
    )


def from_edges(rows, cols, vals, n_dst: int, n_src: int) -> COO:
    return COO(
        rows=jnp.asarray(rows, jnp.int32),
        cols=jnp.asarray(cols, jnp.int32),
        vals=jnp.asarray(vals, jnp.float32),
        n_dst=int(n_dst),
        n_src=int(n_src),
    )


def sym_normalize(rows: np.ndarray, cols: np.ndarray, n: int,
                  add_self_loops: bool = True) -> COO:
    """GCN normalization  Ã = D̃^{-1/2} (A + I) D̃^{-1/2}  (square graphs).

    Host-side (numpy) — this is data-pipeline work, done once per graph.
    """
    rows = np.asarray(rows, np.int64)
    cols = np.asarray(cols, np.int64)
    if add_self_loops:
        loop = np.arange(n, dtype=np.int64)
        rows = np.concatenate([rows, loop])
        cols = np.concatenate([cols, loop])
    deg = np.bincount(rows, minlength=n).astype(np.float64)
    # undirected symmetric normalization uses both-sided degree
    deg_c = np.bincount(cols, minlength=n).astype(np.float64)
    d_r = 1.0 / np.sqrt(np.maximum(deg, 1.0))
    d_c = 1.0 / np.sqrt(np.maximum(deg_c, 1.0))
    vals = d_r[rows] * d_c[cols]
    return from_edges(rows, cols, vals.astype(np.float32), n, n)


def mean_normalize(rows: np.ndarray, cols: np.ndarray,
                   n_dst: int, n_src: int) -> COO:
    """Row-mean normalization  D^{-1} A  — used for the rectangular sampled
    layer adjacencies of GraphSAGE-style mini-batch training."""
    rows = np.asarray(rows, np.int64)
    cols = np.asarray(cols, np.int64)
    deg = np.bincount(rows, minlength=n_dst).astype(np.float64)
    vals = (1.0 / np.maximum(deg, 1.0))[rows]
    return from_edges(rows, cols, vals.astype(np.float32), n_dst, n_src)
