"""Encoder-decoder transformer — seamless-m4t-medium's text/speech backbone.

Per the assignment, the modality frontend is a STUB: the encoder consumes
*precomputed frame embeddings* [b, s_enc, d] (what the real model's speech
frontend would emit); the decoder is a causal transformer with per-layer
cross-attention into the encoder memory.  The paper's C4 note applies here:
cross-attention is a bipartite aggregation with a rectangular adjacency
(dec positions × enc frames) — the order-selection cost model reasons about
it the same way it reasons about sampled GCN layers (DESIGN
§Arch-applicability).

Decode: self-attn KV cache per decoder layer + cross K/V computed once from
the encoder memory at prefill (they never change during decode).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ArchConfig
from .transformer import (KVCache, _norm_init, apply_rope, attend,
                          attend_auto, causal_mask, decode_attn_block,
                          gqa_project, h_params, init_attn_params,
                          init_ffn_params, maybe_sp, rmsnorm, stack_layers,
                          swiglu)

Params = Dict[str, Any]


def init_enc_layer(key, cfg: ArchConfig, dtype=jnp.bfloat16) -> Params:
    k1, k2 = jax.random.split(key)
    p = init_attn_params(k1, cfg, dtype)
    p.update(init_ffn_params(k2, cfg, dtype))
    p["ln_attn"] = jnp.zeros((cfg.d_model,), dtype)
    p["ln_ffn"] = jnp.zeros((cfg.d_model,), dtype)
    return p


def init_dec_layer(key, cfg: ArchConfig, dtype=jnp.bfloat16) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    p = init_attn_params(k1, cfg, dtype)                 # self attention
    cross = init_attn_params(k2, cfg, dtype)             # cross attention
    p.update({f"x_{k}": v for k, v in cross.items()})
    p.update(init_ffn_params(k3, cfg, dtype))
    p["ln_self"] = jnp.zeros((cfg.d_model,), dtype)
    p["ln_cross"] = jnp.zeros((cfg.d_model,), dtype)
    p["ln_ffn"] = jnp.zeros((cfg.d_model,), dtype)
    return p


def init_encdec_params(key, cfg: ArchConfig, dtype=jnp.bfloat16) -> Params:
    k_emb, k_enc, k_dec = jax.random.split(key, 3)
    return {
        "embed": _norm_init(k_emb, (cfg.vocab, cfg.d_model), 0.02, dtype),
        "enc_layers": stack_layers(k_enc, cfg.enc_layers,
                                   lambda k: init_enc_layer(k, cfg, dtype)),
        "dec_layers": stack_layers(k_dec, cfg.n_layers,
                                   lambda k: init_dec_layer(k, cfg, dtype)),
        "ln_enc": jnp.zeros((cfg.d_model,), dtype),
        "ln_final": jnp.zeros((cfg.d_model,), dtype),
    }


def _cross_params(p: Params) -> Params:
    return {k[2:]: v for k, v in p.items() if k.startswith("x_")}


def encode(params: Params, frames: jnp.ndarray, cfg: ArchConfig,
           *, remat: bool = False, sp_spec=None) -> jnp.ndarray:
    """frames: [b, s_enc, d] precomputed embeddings (stub frontend output).
    Bidirectional self-attention; RoPE positions for relative geometry."""
    frames = frames.astype(params["embed"].dtype)   # stub emits f32
    s = frames.shape[1]
    positions = jnp.arange(s)[None, :]

    def body(h, p):
        xin = rmsnorm(h, p["ln_attn"], cfg.norm_eps)
        q, k, v = gqa_project(xin, p, cfg)
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
        h = h + jnp.einsum(
            "bshk,hkd->bsd", attend_auto(q, k, v, causal=False),
            p["wo"].reshape(cfg.n_heads, cfg.hd, h.shape[-1]))
        h = h + swiglu(rmsnorm(h, p["ln_ffn"], cfg.norm_eps), h_params(p))
        return maybe_sp(h, sp_spec), ()

    if remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, maybe_sp(frames, sp_spec),
                        params["enc_layers"])
    return rmsnorm(x, params["ln_enc"], cfg.norm_eps)


def _cross_attend(h, p, cfg, memory):
    """h: [b, s_dec, d] queries; memory: [b, s_enc, d]."""
    xp = _cross_params(p)
    q = jnp.einsum("bsd,dhk->bshk", h,
                   xp["wq"].reshape(h.shape[-1], cfg.n_heads, cfg.hd))
    k = jnp.einsum("bsd,dhk->bshk", memory,
                   xp["wk"].reshape(memory.shape[-1], cfg.n_kv_heads, cfg.hd))
    v = jnp.einsum("bsd,dhk->bshk", memory,
                   xp["wv"].reshape(memory.shape[-1], cfg.n_kv_heads, cfg.hd))
    o = attend_auto(q, k, v, causal=False)
    return jnp.einsum("bshk,hkd->bsd", o,
                      xp["wo"].reshape(cfg.n_heads, cfg.hd, h.shape[-1]))


def decode_train(params: Params, memory: jnp.ndarray, tokens: jnp.ndarray,
                 cfg: ArchConfig, *, remat: bool = False,
                 sp_spec=None, last_logits: bool = False) -> jnp.ndarray:
    """Teacher-forced decoder: tokens [b, s_dec] → logits [b, s_dec, vocab]."""
    b, s = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0)
    positions = jnp.arange(s)[None, :]

    def body(h, p):
        xin = rmsnorm(h, p["ln_self"], cfg.norm_eps)
        q, k, v = gqa_project(xin, p, cfg)
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
        h = h + jnp.einsum(
            "bshk,hkd->bsd", attend_auto(q, k, v, causal=True),
            p["wo"].reshape(cfg.n_heads, cfg.hd, h.shape[-1]))
        h = h + _cross_attend(rmsnorm(h, p["ln_cross"], cfg.norm_eps),
                              p, cfg, memory)
        h = h + swiglu(rmsnorm(h, p["ln_ffn"], cfg.norm_eps), h_params(p))
        return maybe_sp(h, sp_spec), ()

    if remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, maybe_sp(x, sp_spec), params["dec_layers"])
    if last_logits:
        x = x[:, -1:]
    x = rmsnorm(x, params["ln_final"], cfg.norm_eps)
    return jnp.einsum("bsd,dv->bsv", x, params["embed"].T,
                      preferred_element_type=jnp.float32)


def encdec_forward(params: Params, frames: jnp.ndarray, tokens: jnp.ndarray,
                   cfg: ArchConfig, *, remat: bool = False,
                   sp_spec=None, last_logits: bool = False) -> jnp.ndarray:
    memory = encode(params, frames, cfg, remat=remat, sp_spec=sp_spec)
    return decode_train(params, memory, tokens, cfg, remat=remat,
                        sp_spec=None,  # dec seq (s/4) has its own length
                        last_logits=last_logits)


# ---------------------------------------------------------------------------
# decode with cache
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class EncDecCache:
    self_kv: KVCache        # [L_dec, b, S_dec, kv, hd]
    cross_k: jnp.ndarray    # [L_dec, b, S_enc, kv, hd] — precomputed
    cross_v: jnp.ndarray


jax.tree_util.register_pytree_node(
    EncDecCache, lambda c: ((c.self_kv, c.cross_k, c.cross_v), None),
    lambda _, kv: EncDecCache(self_kv=kv[0], cross_k=kv[1], cross_v=kv[2]))


def prefill_cross(params: Params, memory: jnp.ndarray, cfg: ArchConfig,
                  batch: int, max_dec: int, dtype=jnp.bfloat16
                  ) -> EncDecCache:
    """Project the encoder memory through every decoder layer's cross K/V
    once (they are decode-invariant)."""
    def body(_, p):
        xp = _cross_params(p)
        k = jnp.einsum("bsd,dhk->bshk", memory,
                       xp["wk"].reshape(memory.shape[-1], cfg.n_kv_heads,
                                        cfg.hd))
        v = jnp.einsum("bsd,dhk->bshk", memory,
                       xp["wv"].reshape(memory.shape[-1], cfg.n_kv_heads,
                                        cfg.hd))
        return (), (k, v)

    _, (ck, cv) = jax.lax.scan(body, (), params["dec_layers"])
    return EncDecCache(
        self_kv=KVCache.zeros(cfg, batch, max_dec, dtype,
                              n_layers=cfg.n_layers),
        cross_k=ck.astype(dtype), cross_v=cv.astype(dtype))


def encdec_decode_step(params: Params, cache: EncDecCache,
                       token: jnp.ndarray, pos: jnp.ndarray, cfg: ArchConfig
                       ) -> Tuple[jnp.ndarray, EncDecCache]:
    x = jnp.take(params["embed"], token, axis=0)
    always_global = jnp.ones((), bool)

    def body(h, layer):
        p, kc, vc, ck, cv = layer
        xin = rmsnorm(h, p["ln_self"], cfg.norm_eps)
        att, kc, vc = decode_attn_block(xin, p, cfg, kc, vc, pos,
                                        always_global)
        h = h + att
        # cross attention against the precomputed enc K/V (no mask)
        xin = rmsnorm(h, p["ln_cross"], cfg.norm_eps)
        xp = _cross_params(p)
        q = jnp.einsum("bsd,dhk->bshk", xin,
                       xp["wq"].reshape(h.shape[-1], cfg.n_heads, cfg.hd))
        o = attend(q, ck, cv, None)
        h = h + jnp.einsum("bshk,hkd->bsd", o,
                           xp["wo"].reshape(cfg.n_heads, cfg.hd,
                                            h.shape[-1]))
        h = h + swiglu(rmsnorm(h, p["ln_ffn"], cfg.norm_eps), h_params(p))
        return h, (kc, vc)

    x, (new_k, new_v) = jax.lax.scan(
        body, x, (params["dec_layers"], cache.self_kv.k, cache.self_kv.v,
                  cache.cross_k, cache.cross_v))
    x = rmsnorm(x, params["ln_final"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, params["embed"].T,
                        preferred_element_type=jnp.float32)
    return logits, EncDecCache(self_kv=KVCache(k=new_k, v=new_v),
                               cross_k=cache.cross_k, cross_v=cache.cross_v)
