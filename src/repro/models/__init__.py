# Model zoo: the paper's GCN/SAGE (gcn_model.py, on top of repro.core) and
# the five LM stack families serving the 10 assigned architectures
# (transformer/moe/mamba2/hybrid/encdec, unified by lm.py).
from .config import ArchConfig
from .gcn_model import (GCNConfig, accuracy, gcn_forward, gcn_loss,
                        init_gcn_params, pick_orders)
from . import lm

__all__ = ["ArchConfig", "GCNConfig", "accuracy", "gcn_forward", "gcn_loss",
           "init_gcn_params", "pick_orders", "lm"]
