"""Zamba2-style hybrid — Mamba2 backbone + ONE shared attention block.

zamba2-1.2b: 38 Mamba2 layers (d_model 2048, ssm_state 64); a single
transformer block (32H GQA kv=32, d_ff 8192) whose weights are SHARED is
applied every ``attn_every`` layers.  We realize the schedule as scanned
*segments*: ``n_seg = L // attn_every`` segments of (attn_every mamba
layers → shared block), then the remainder mamba layers — both inner and
outer loops are ``lax.scan``s, so depth stays out of the HLO.

Decode state = MambaCache over all mamba layers + a KV cache with one slot
per shared-block *application* (same weights, different activations — each
application has its own keys/values).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ArchConfig
from .mamba2 import (MambaCache, init_mamba_layer, mamba_block,
                     mamba_decode_block)
from .transformer import (KVCache, _norm_init, attn_block, causal_mask,
                          decode_attn_block, h_params, init_dense_layer,
                          maybe_sp, rmsnorm, stack_layers, swiglu)

Params = Dict[str, Any]


def _seg_counts(cfg: ArchConfig) -> Tuple[int, int, int]:
    seg = cfg.attn_every
    n_seg = cfg.n_layers // seg
    rem = cfg.n_layers - n_seg * seg
    return seg, n_seg, rem


def init_hybrid_params(key, cfg: ArchConfig, dtype=jnp.bfloat16) -> Params:
    k_emb, k_layers, k_shared = jax.random.split(key, 3)
    return {
        "embed": _norm_init(k_emb, (cfg.vocab, cfg.d_model), 0.02, dtype),
        "mamba_layers": stack_layers(
            k_layers, cfg.n_layers, lambda k: init_mamba_layer(k, cfg, dtype)),
        "shared": init_dense_layer(k_shared, cfg, dtype),   # ONE block
        "ln_final": jnp.zeros((cfg.d_model,), dtype),
    }


def _shared_block(h, p, cfg, w_eff, positions):
    a = attn_block(rmsnorm(h, p["ln_attn"], cfg.norm_eps), p, cfg,
                   w_eff, positions)
    h = h + a
    return h + swiglu(rmsnorm(h, p["ln_ffn"], cfg.norm_eps), h_params(p))


def _split_segments(layers: Params, n_seg: int, seg: int):
    body = jax.tree_util.tree_map(
        lambda a: a[:n_seg * seg].reshape(n_seg, seg, *a.shape[1:]), layers)
    rem = jax.tree_util.tree_map(lambda a: a[n_seg * seg:], layers)
    return body, rem


def hybrid_forward(params: Params, tokens: jnp.ndarray, cfg: ArchConfig, *,
                   chunk: int = 64,
                   embeddings: Optional[jnp.ndarray] = None,
                   remat: bool = False, sp_spec=None,
                   last_logits: bool = False) -> jnp.ndarray:
    b, s = tokens.shape[:2]
    x = embeddings if embeddings is not None \
        else jnp.take(params["embed"], tokens, axis=0)
    positions = jnp.arange(s)[None, :]
    seg, n_seg, rem = _seg_counts(cfg)
    seg_params, rem_params = _split_segments(params["mamba_layers"],
                                             n_seg, seg)
    shared = params["shared"]

    def mamba_body(h, p):
        return maybe_sp(h + mamba_block(h, p, cfg, chunk=chunk), sp_spec), ()

    if remat:
        mamba_body = jax.checkpoint(mamba_body)

    def seg_body(h, seg_p):
        h, _ = jax.lax.scan(mamba_body, h, seg_p)
        return maybe_sp(_shared_block(h, shared, cfg, None, positions),
                        sp_spec), ()

    x = maybe_sp(x, sp_spec)
    x, _ = jax.lax.scan(seg_body, x, seg_params)
    if rem:
        x, _ = jax.lax.scan(mamba_body, x, rem_params)
    if last_logits:
        x = x[:, -1:]
    x = rmsnorm(x, params["ln_final"], cfg.norm_eps)
    return jnp.einsum("bsd,dv->bsv", x, params["embed"].T,
                      preferred_element_type=jnp.float32)


@dataclasses.dataclass(frozen=True)
class HybridCache:
    mamba: MambaCache      # over all n_layers mamba blocks
    attn: KVCache          # [n_seg, b, S, kv, hd] — one slot per application

    @classmethod
    def zeros(cls, cfg: ArchConfig, batch: int, max_seq: int,
              dtype=jnp.bfloat16):
        _, n_seg, _ = _seg_counts(cfg)
        return cls(mamba=MambaCache.zeros(cfg, batch),
                   attn=KVCache.zeros(cfg, batch, max_seq, dtype,
                                      n_layers=n_seg))


jax.tree_util.register_pytree_node(
    HybridCache, lambda c: ((c.mamba, c.attn), None),
    lambda _, kv: HybridCache(mamba=kv[0], attn=kv[1]))


def _seg_split_tree(tree, n_seg: int, seg: int):
    body = jax.tree_util.tree_map(
        lambda a: a[:n_seg * seg].reshape(n_seg, seg, *a.shape[1:]), tree)
    rem = jax.tree_util.tree_map(lambda a: a[n_seg * seg:], tree)
    return body, rem


def hybrid_decode_step(params: Params, cache: HybridCache,
                       token: jnp.ndarray, pos: jnp.ndarray, cfg: ArchConfig
                       ) -> Tuple[jnp.ndarray, HybridCache]:
    x = jnp.take(params["embed"], token, axis=0)
    seg, n_seg, rem = _seg_counts(cfg)
    mcache = (cache.mamba.conv_x, cache.mamba.conv_B, cache.mamba.conv_C,
              cache.mamba.ssm)
    seg_cache, rem_cache = _seg_split_tree(mcache, n_seg, seg)
    seg_params, rem_params = _split_segments(params["mamba_layers"],
                                             n_seg, seg)
    shared = params["shared"]
    always_global = jnp.ones((), bool)

    def mamba_body(h, layer):
        p, cx, cb, cc, ss = layer
        out, cx, cb, cc, ss = mamba_decode_block(h, p, cfg, cx, cb, cc, ss)
        return h + out, (cx, cb, cc, ss)

    def seg_body(h, layer):
        p_seg, (cx, cb, cc, ss), kc, vc = layer
        h, new_state = jax.lax.scan(mamba_body, h, (p_seg, cx, cb, cc, ss))
        xin = rmsnorm(h, shared["ln_attn"], cfg.norm_eps)
        att, kc, vc = decode_attn_block(xin, shared, cfg, kc, vc, pos,
                                        always_global)
        h = h + att
        h = h + swiglu(rmsnorm(h, shared["ln_ffn"], cfg.norm_eps),
                       h_params(shared))
        return h, (new_state, kc, vc)

    x, (state_b, new_k, new_v) = jax.lax.scan(
        seg_body, x, (seg_params, seg_cache, cache.attn.k, cache.attn.v))
    if rem:
        x, state_r = jax.lax.scan(mamba_body, x, (rem_params,) + rem_cache)
        merged = tuple(
            jnp.concatenate([b.reshape(-1, *b.shape[2:]), r])
            for b, r in zip(state_b, state_r))
    else:
        merged = tuple(b.reshape(-1, *b.shape[2:]) for b in state_b)
    x = rmsnorm(x, params["ln_final"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, params["embed"].T,
                        preferred_element_type=jnp.float32)
    return logits, HybridCache(
        mamba=MambaCache(conv_x=merged[0], conv_B=merged[1],
                         conv_C=merged[2], ssm=merged[3]),
        attn=KVCache(k=new_k, v=new_v))
