"""Architecture config — one dataclass describes every assigned arch.

``family`` selects the block pattern:
  dense   — decoder-only transformer (stablelm, llama3.2, yi, gemma3,
            chameleon: early-fusion VLM = dense LM over a fused vocab)
  moe     — decoder-only with MoE FFN layers (llama4-maverick: dense/moe
            interleaved pairs; moonshot: all-moe)
  ssm     — Mamba2 / SSD stack (attention-free)
  hybrid  — zamba2: mamba2 backbone + ONE shared attention block re-applied
            every ``attn_every`` layers
  encdec  — seamless-m4t: bidirectional encoder over precomputed frame
            embeddings (stub frontend) + causal decoder w/ cross-attention

All stacks are homogeneous *by construction* so layers run under
``lax.scan`` with stacked params: heterogeneity is expressed as per-layer
FLAG VECTORS (gemma3's 5-local:1-global mask pattern, zamba2's shared-attn
schedule) or as scanned PAIRS (llama4's dense+moe interleave) — this keeps
HLO size O(1) in depth, which the 512-device dry-run compile needs.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | encdec
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None          # default d_model // n_heads
    # --- attention pattern ---
    sliding_window: Optional[int] = None    # local-attention window
    global_every: int = 0                   # gemma3: layer i is global iff (i+1) % k == 0
    # --- MoE ---
    moe_experts: int = 0
    moe_topk: int = 0
    moe_interleave: int = 1                 # 2 ⇒ scan (dense, moe) pairs
    # --- SSM (mamba2 / zamba2) ---
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    attn_every: int = 0                     # zamba2 shared block period
    # --- enc-dec ---
    enc_layers: int = 0
    # --- common ---
    rope_theta: float = 500_000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = True
    modality_stub: Optional[str] = None     # 'audio' | 'vision' frontend note

    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def scaled(self, *, n_layers: Optional[int] = None, d_model: Optional[int] = None,
               n_heads: Optional[int] = None, n_kv_heads: Optional[int] = None,
               d_ff: Optional[int] = None, vocab: Optional[int] = None,
               moe_experts: Optional[int] = None, head_dim: Optional[int] = None,
               enc_layers: Optional[int] = None, ssm_head_dim: Optional[int] = None,
               moe_topk: Optional[int] = None,
               ) -> "ArchConfig":
        """Reduced-config variant for CPU smoke tests (same family/pattern)."""
        return dataclasses.replace(
            self,
            n_layers=n_layers or self.n_layers,
            d_model=d_model or self.d_model,
            n_heads=n_heads or self.n_heads,
            n_kv_heads=n_kv_heads or self.n_kv_heads,
            d_ff=d_ff or self.d_ff,
            vocab=vocab or self.vocab,
            moe_experts=moe_experts if moe_experts is not None else self.moe_experts,
            moe_topk=moe_topk if moe_topk is not None else self.moe_topk,
            head_dim=head_dim if head_dim is not None else self.head_dim,
            enc_layers=enc_layers if enc_layers is not None else self.enc_layers,
            ssm_head_dim=ssm_head_dim or self.ssm_head_dim,
        )

    # --- analytic parameter/FLOP counts (roofline MODEL_FLOPS = 6·N·D) -----
    def param_count(self) -> int:
        d, hd = self.d_model, self.hd
        attn = d * hd * self.n_heads + 2 * d * hd * self.n_kv_heads \
            + hd * self.n_heads * d
        dense_ffn = 3 * d * self.d_ff
        moe_ffn = self.moe_experts * 3 * d * self.d_ff
        ssm = 0
        if self.family in ("ssm", "hybrid"):
            di, n = self.d_inner, self.ssm_state
            # in_proj (z,x,B,C,dt) + conv + out_proj (+ heads' A, D, dt_bias)
            ssm = d * (2 * di + 2 * n + self.ssm_heads) \
                + self.ssm_conv * (di + 2 * n) + di * d + 3 * self.ssm_heads
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        if self.family == "dense":
            per_layer = attn + dense_ffn
            total = self.n_layers * per_layer
        elif self.family == "moe":
            n_moe = self.n_layers // self.moe_interleave
            n_dense = self.n_layers - n_moe
            total = self.n_layers * attn + n_dense * dense_ffn \
                + n_moe * (moe_ffn + d * self.moe_experts)
        elif self.family == "ssm":
            total = self.n_layers * ssm
        elif self.family == "hybrid":
            n_attn_apps = 0 if not self.attn_every else 1  # ONE shared block
            total = self.n_layers * ssm + n_attn_apps * (attn + dense_ffn)
        elif self.family == "encdec":
            enc = self.enc_layers * (attn + dense_ffn)
            dec = self.n_layers * (2 * attn + dense_ffn)   # self + cross
            total = enc + dec
        else:
            raise ValueError(self.family)
        return total + emb

    def active_param_count(self) -> int:
        """Active params per token (MoE: top-k of E experts)."""
        if self.family != "moe":
            return self.param_count()
        d = self.d_model
        full = self.param_count()
        n_moe = self.n_layers // self.moe_interleave
        inactive = n_moe * (self.moe_experts - self.moe_topk) * 3 * d * self.d_ff
        return full - inactive
