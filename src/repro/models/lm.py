"""Unified LM wrapper — one interface over all five stack families.

Dispatch on ``cfg.family``:
    init_params / forward / loss / init_cache / decode_step

``train_step_fn`` builds the jit-able training step (loss → grads → clip →
optimizer → apply), ``prefill_fn`` the full-sequence inference forward and
``decode_fn`` the one-token serve step — these are what launch/dryrun.py
lowers for every (arch × shape) cell and what launch/train.py runs.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.optim import apply_updates, clip_by_global_norm
from .config import ArchConfig
from . import encdec as _encdec
from . import hybrid as _hybrid
from . import mamba2 as _mamba2
from . import moe as _moe
from . import transformer as _dense

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# init / forward dispatch
# ---------------------------------------------------------------------------
def init_params(key, cfg: ArchConfig, dtype=jnp.bfloat16) -> Params:
    if cfg.family == "dense":
        return _dense.init_dense_params(key, cfg, dtype)
    if cfg.family == "moe":
        return _moe.init_moe_stack_params(key, cfg, dtype)
    if cfg.family == "ssm":
        return _mamba2.init_ssm_params(key, cfg, dtype)
    if cfg.family == "hybrid":
        return _hybrid.init_hybrid_params(key, cfg, dtype)
    if cfg.family == "encdec":
        return _encdec.init_encdec_params(key, cfg, dtype)
    raise ValueError(cfg.family)


def forward(params: Params, batch: Dict[str, jnp.ndarray], cfg: ArchConfig,
            *, chunk: int = 64, remat: bool = False, sp_spec=None,
            ep_spec=None, last_logits: bool = False
            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """→ (logits f32, aux_loss scalar).  ``batch['embeddings']`` (modality
    stub) substitutes the embedding lookup when present.  ``remat``
    checkpoints each layer body; ``sp_spec`` constrains the residual stream
    (sequence parallelism)."""
    emb = batch.get("embeddings")
    zero = jnp.zeros((), jnp.float32)
    kw = dict(remat=remat, sp_spec=sp_spec, last_logits=last_logits)
    if cfg.family == "dense":
        return _dense.dense_forward(params, batch["tokens"], cfg,
                                    embeddings=emb, **kw), zero
    if cfg.family == "moe":
        return _moe.moe_forward(params, batch["tokens"], cfg,
                                embeddings=emb, ep_spec=ep_spec, **kw)
    if cfg.family == "ssm":
        return _mamba2.ssm_forward(params, batch["tokens"], cfg, chunk=chunk,
                                   embeddings=emb, **kw), zero
    if cfg.family == "hybrid":
        return _hybrid.hybrid_forward(params, batch["tokens"], cfg,
                                      chunk=chunk, embeddings=emb, **kw), zero
    if cfg.family == "encdec":
        return _encdec.encdec_forward(params, batch["frames"],
                                      batch["tokens"], cfg, **kw), zero
    raise ValueError(cfg.family)


def lm_loss(params: Params, batch: Dict[str, jnp.ndarray], cfg: ArchConfig,
            *, aux_coef: float = 0.01, chunk: int = 64, remat: bool = False,
            sp_spec=None, ep_spec=None) -> jnp.ndarray:
    """Next-token cross-entropy (labels = tokens shifted by the pipeline)."""
    logits, aux = forward(params, batch, cfg, chunk=chunk, remat=remat,
                          sp_spec=sp_spec, ep_spec=ep_spec)
    labels = batch["labels"]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    mask = batch.get("mask")
    if mask is not None:
        nll = nll * mask
        denom = jnp.maximum(mask.sum(), 1.0)
    else:
        denom = nll.size
    return nll.sum() / denom + aux_coef * aux


# ---------------------------------------------------------------------------
# train step factory
# ---------------------------------------------------------------------------
def train_step_fn(cfg: ArchConfig, optimizer, *, clip: float = 1.0,
                  chunk: int = 64, remat: bool = True,
                  sp_spec=None, ep_spec=None) -> Callable:
    """optimizer = (init_fn, update_fn) from repro.optim."""
    _, update = optimizer

    def step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            functools.partial(lm_loss, cfg=cfg, chunk=chunk, remat=remat,
                              sp_spec=sp_spec, ep_spec=ep_spec))(params, batch)
        grads, gnorm = clip_by_global_norm(grads, clip)
        updates, opt_state = update(grads, opt_state, params)
        params = apply_updates(params, updates)
        return params, opt_state, {"loss": loss, "grad_norm": gnorm}

    return step


def prefill_fn(cfg: ArchConfig, *, chunk: int = 64, sp_spec=None,
               ep_spec=None, last_logits: bool = True) -> Callable:
    """Serving prefill: by default only the LAST position's logits are
    computed (§Perf iteration — the [b, s, vocab] tensor was ~75% of
    prefill HBM bytes at 32k; generation needs one row)."""
    def prefill(params, batch):
        logits, _ = forward(params, batch, cfg, chunk=chunk, sp_spec=sp_spec,
                            ep_spec=ep_spec, last_logits=last_logits)
        return logits
    return prefill


# ---------------------------------------------------------------------------
# serve: cache init + one-token decode
# ---------------------------------------------------------------------------
def init_cache(cfg: ArchConfig, batch: int, max_seq: int,
               dtype=jnp.bfloat16, *, enc_frames: int = 0, params=None):
    if cfg.family == "dense":
        return _dense.KVCache.zeros(cfg, batch, max_seq, dtype)
    if cfg.family == "moe":
        return _dense.KVCache.zeros(cfg, batch, max_seq, dtype)
    if cfg.family == "ssm":
        return _mamba2.MambaCache.zeros(cfg, batch)
    if cfg.family == "hybrid":
        return _hybrid.HybridCache.zeros(cfg, batch, max_seq, dtype)
    if cfg.family == "encdec":
        # decode-ready cache needs the encoder memory; for shape-level work
        # (dry-run) a zeros memory of the right size is sufficient.
        memory = jnp.zeros((batch, enc_frames or max_seq, cfg.d_model), dtype)
        if params is not None:
            return _encdec.prefill_cross(params, memory, cfg, batch, max_seq,
                                         dtype)
        raise ValueError("encdec cache needs params (cross K/V projection)")
    raise ValueError(cfg.family)


def decode_fn(cfg: ArchConfig) -> Callable:
    def step(params, cache, token, pos):
        if cfg.family == "dense":
            return _dense.dense_decode_step(params, cache, token, pos, cfg)
        if cfg.family == "moe":
            return _moe.moe_decode_step(params, cache, token, pos, cfg)
        if cfg.family == "ssm":
            return _mamba2.ssm_decode_step(params, cache, token, pos, cfg)
        if cfg.family == "hybrid":
            return _hybrid.hybrid_decode_step(params, cache, token, pos, cfg)
        if cfg.family == "encdec":
            return _encdec.encdec_decode_step(params, cache, token, pos, cfg)
        raise ValueError(cfg.family)
    return step
