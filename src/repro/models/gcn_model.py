"""The paper's models: 2-layer GCN and GraphSAGE-mean for node classification.

Built on :mod:`repro.core` — each layer's execution order (CoAg/AgCo) is
chosen by the sequence estimator per the sampled-batch shape plan (paper
§4.4), and the backward runs the transpose-free "Ours" dataflow unless
``dataflow='naive'`` selects the Table-1 baseline for comparison.

The loss-layer transpose: the paper transposes the loss error E^L once
(O(b·c)) and carries backward in transposed form.  In JAX the analogue is
structural — our custom_vjp layers consume the upstream cotangent directly
and all contractions are expressed transpose-free; the only O(b·c) object is
the softmax error itself, produced by the loss below.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.baseline import gcn_layer_baseline
from repro.core.estimator import LayerShape, choose_order
from repro.graph.coo import COO

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class GCNConfig:
    name: str
    feat_dim: int
    hidden: int                     # paper §5.1: 256
    n_classes: int
    n_layers: int = 2               # paper trains 2-layer models
    model: str = "gcn"              # 'gcn' | 'sage'  (SAGE adds a root path)
    dataflow: str = "ours"          # 'ours' | 'naive' (Table-1 baseline)
    multilabel: bool = False
    engine: Optional[str] = None    # Engine spec for 'ours' layers, e.g.
    #                                 "coo+serial" (the default). Formats
    #                                 that build host-side layouts (block/
    #                                 ell) need concrete graphs and raise
    #                                 under jit — see Format.traceable.


def init_gcn_params(key, cfg: GCNConfig, dtype=jnp.float32) -> Params:
    dims = [cfg.feat_dim] + [cfg.hidden] * (cfg.n_layers - 1) + [cfg.n_classes]
    keys = jax.random.split(key, 2 * cfg.n_layers)
    params: Params = {"layers": []}
    for l in range(cfg.n_layers):
        d_in, d_out = dims[l], dims[l + 1]
        layer = {"w": (jax.random.normal(keys[2 * l], (d_in, d_out))
                       * (d_in ** -0.5)).astype(dtype)}
        if cfg.model == "sage":
            layer["w_root"] = (jax.random.normal(keys[2 * l + 1],
                                                 (d_in, d_out))
                               * (d_in ** -0.5)).astype(dtype)
        params["layers"].append(layer)
    return params


def pick_orders(cfg: GCNConfig, shapes: Sequence[LayerShape]) -> Tuple[str, ...]:
    """Sequence estimator, once per (dataset, sampler, model) at launch."""
    return tuple(choose_order(s, dataflow=cfg.dataflow).order for s in shapes)


def gcn_forward(params: Params, layers: Sequence[COO], x: jnp.ndarray,
                cfg: GCNConfig, orders: Sequence[str]) -> jnp.ndarray:
    """layers[l] aggregates hop l+1 → hop l; x is the deepest hop's features.
    Iterate deepest-first (layers reversed), matching sampler.MiniBatch."""
    if cfg.dataflow == "ours":
        # one declarative entry point for every format x schedule; the
        # default spec is the serial COO oracle (the paper's Table-1 "Ours")
        from repro.engine import Engine
        layer_fn = Engine(cfg.engine or "coo+serial").layer
    else:
        layer_fn = gcn_layer_baseline
    h = x
    n = len(params["layers"])
    for l in range(n - 1, -1, -1):
        A = layers[l]
        p = params["layers"][n - 1 - l]
        activate = l != 0                      # no ReLU on the logits layer
        out = layer_fn(A, h, p["w"], order=orders[l], activate=False)
        if cfg.model == "sage":
            # SAGE-mean: aggregate-neighbors path + root path
            root = h[:A.n_dst] @ p["w_root"]
            out = out + root
        h = jnp.maximum(out, 0.0) if activate else out
    return h


def gcn_loss(params: Params, layers: Sequence[COO], x: jnp.ndarray,
             labels: jnp.ndarray, cfg: GCNConfig, orders: Sequence[str],
             n_valid: Optional[int] = None) -> jnp.ndarray:
    """Softmax CE (single-label) or sigmoid BCE (multilabel: yelp/amazon).
    ``n_valid`` masks padded seed rows."""
    logits = gcn_forward(params, layers, x, cfg, orders)
    b = logits.shape[0]
    valid = (jnp.arange(b) < (n_valid if n_valid is not None else b))
    if cfg.multilabel:
        z = logits.astype(jnp.float32)
        per = jnp.maximum(z, 0) - z * labels + jnp.log1p(jnp.exp(-jnp.abs(z)))
        per = per.sum(-1)
    else:
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        per = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    per = jnp.where(valid, per, 0.0)
    return per.sum() / jnp.maximum(valid.sum(), 1)


def accuracy(logits: jnp.ndarray, labels: jnp.ndarray,
             n_valid: Optional[int] = None) -> jnp.ndarray:
    b = logits.shape[0]
    valid = (jnp.arange(b) < (n_valid if n_valid is not None else b))
    hit = (jnp.argmax(logits, -1) == labels) & valid
    return hit.sum() / jnp.maximum(valid.sum(), 1)
