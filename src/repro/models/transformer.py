"""Dense decoder-only transformer — GQA + RoPE + RMSNorm + SwiGLU.

Serves stablelm-3b, llama3.2-1b, yi-6b, chameleon-34b (early-fusion VLM = a
dense LM over a fused text+VQ vocab) and gemma3-27b (per-layer local/global
flag vector selects the sliding-window size under the same scanned params).

Scale discipline (the paper's two-level blocking, applied to attention):
  * layers run under ``lax.scan`` over stacked params — HLO size O(1) in
    depth; optional ``jax.checkpoint`` on the body (remat) bounds the
    backward stash to one residual per layer;
  * optional sequence-parallel sharding constraint on the residual stream
    (Megatron-SP): the per-layer stash shards over the ``model`` axis;
  * attention auto-switches to a FLASH-BLOCKED path (running-max online
    softmax over [q_block × k_block] tiles) when the KV length exceeds
    ``FLASH_THRESHOLD`` — the 32k/500k cells never materialize an [s, s]
    score matrix, exactly like the paper never materializes a dense
    adjacency;
  * the sliding window is a TRACED scalar (``w_eff``), so gemma3's 5:1
    local:global pattern is a scanned flag, not 6 program variants.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec

Params = Dict[str, Any]

FLASH_THRESHOLD = 8192     # max KV length for the materialized-mask path
Q_BLOCK = 512
K_BLOCK = 1024

from .config import ArchConfig  # noqa: E402


# ---------------------------------------------------------------------------
# primitives
# ---------------------------------------------------------------------------
def rmsnorm(x: jnp.ndarray, g: jnp.ndarray, eps: float) -> jnp.ndarray:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)).astype(x.dtype) \
        * (1.0 + g)


def rope_freqs(hd: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float
               ) -> jnp.ndarray:
    """x: [b, s, h, hd]; positions: [b, s] (or [s])."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # [hd/2]
    ang = positions[..., None].astype(jnp.float32) * freqs  # [b, s, hd/2]
    cos = jnp.cos(ang)[..., None, :]                    # [b, s, 1, hd/2]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def causal_mask(s: int) -> jnp.ndarray:
    i = jnp.arange(s)[:, None]
    j = jnp.arange(s)[None, :]
    return j <= i                                        # [s, s] bool


def sliding_mask(s: int, window: int) -> jnp.ndarray:
    i = jnp.arange(s)[:, None]
    j = jnp.arange(s)[None, :]
    return (j <= i) & (i - j < window)


def _repeat_kv(k, v, h):
    kv = k.shape[2]
    if kv != h:
        k = jnp.repeat(k, h // kv, axis=2)
        v = jnp.repeat(v, h // kv, axis=2)
    return k, v


def attend(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
           mask: Optional[jnp.ndarray]) -> jnp.ndarray:
    """Materialized-score GQA attention. q: [b, sq, h, hd]; k/v:
    [b, sk, kv, hd]; mask broadcastable to [b, h, sq, sk] (True = attend).

    GROUPED einsum, no materialized K/V repeat (§Perf iteration on the
    dense trains): ``jnp.repeat`` on the head-sharded K forced GSPMD to
    all-gather K/V to full heads and all-reduce the score gradients
    (~0.5 TB/device/step on gemma3); the reshape-grouped form contracts
    per kv-head, so head-sharded attention stays device-local."""
    b, sq, h, hd = q.shape
    kv = k.shape[2]
    if h % kv:
        k, v = _repeat_kv(k, v, h)       # ragged fallback (unused archs)
        kv = h
    g = h // kv
    if g == 1:                           # MHA: plain einsum, no group dim
        logits = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                            preferred_element_type=jnp.float32)
        logits = logits / jnp.sqrt(hd).astype(jnp.float32)
        if mask is not None:
            logits = jnp.where(mask, logits, jnp.finfo(jnp.float32).min)
        probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
        return jnp.einsum("bhqk,bkhd->bqhd", probs, v)
    q5 = q.reshape(b, sq, kv, g, hd)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", q5, k,
                        preferred_element_type=jnp.float32)
    logits = logits / jnp.sqrt(hd).astype(jnp.float32)
    if mask is not None:
        logits = jnp.where(mask[:, :, None] if mask.ndim == 4 else mask,
                           logits, jnp.finfo(jnp.float32).min)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v)
    return out.reshape(b, sq, h, hd)


def flash_attend(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                 causal: bool, w_eff: Optional[jnp.ndarray] = None,
                 q_block: int = Q_BLOCK, k_block: int = K_BLOCK
                 ) -> jnp.ndarray:
    """Online-softmax blocked attention (never materializes [sq, sk]).

    ``w_eff``: traced sliding-window size (positions i-j >= w_eff masked);
    pass None for dense attention.  Block masks are built from index
    arithmetic per [q_block, k_block] tile.
    """
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    k, v = _repeat_kv(k, v, h)
    nq = sq // q_block
    nk = sk // k_block
    assert nq * q_block == sq and nk * k_block == sk, (sq, sk)
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    qb = q.reshape(b, nq, q_block, h, hd).transpose(1, 0, 3, 2, 4)
    kb = k.reshape(b, nk, k_block, h, hd).transpose(1, 0, 3, 2, 4)
    vb = v.reshape(b, nk, k_block, h, hd).transpose(1, 0, 3, 2, 4)
    neg = jnp.finfo(jnp.float32).min

    def q_step(_, qi):
        qblk, iq = qi                               # [b, h, qb, hd], scalar
        i_ids = iq * q_block + jnp.arange(q_block)

        def kv_step(carry, kj):
            m, l, acc = carry
            kblk, vblk, jk = kj
            j_ids = jk * k_block + jnp.arange(k_block)
            logits = jnp.einsum("bhqd,bhkd->bhqk", qblk, kblk,
                                preferred_element_type=jnp.float32) * scale
            ok = jnp.ones((q_block, k_block), bool)
            if causal:
                ok = ok & (j_ids[None, :] <= i_ids[:, None])
            if w_eff is not None:
                ok = ok & (i_ids[:, None] - j_ids[None, :] < w_eff)
            logits = jnp.where(ok[None, None], logits, neg)
            m_new = jnp.maximum(m, logits.max(-1))
            p = jnp.exp(logits - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + p.sum(-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bhqk,bhkd->bhqd", p.astype(vblk.dtype), vblk,
                preferred_element_type=jnp.float32)
            return (m_new, l, acc), ()

        m0 = jnp.full((b, h, q_block), neg, jnp.float32)
        l0 = jnp.zeros((b, h, q_block), jnp.float32)
        a0 = jnp.zeros((b, h, q_block, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0), (kb, vb, jnp.arange(nk)))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return (), out.astype(q.dtype)             # [b, h, qb, hd]

    _, outs = jax.lax.scan(q_step, (), (qb, jnp.arange(nq)))
    # outs: [nq, b, h, qb, hd] → [b, sq, h, hd]
    return outs.transpose(1, 0, 3, 2, 4).reshape(b, sq, h, hd)


def flash_attend_causal_pairs(q: jnp.ndarray, k: jnp.ndarray,
                              v: jnp.ndarray, *, q_block: int = Q_BLOCK,
                              k_block: int = K_BLOCK) -> jnp.ndarray:
    """Causal flash that only visits the LOWER-TRIANGLE block pairs.

    §Perf iteration (chameleon × prefill_32k): the rectangular flash sweep
    computes (and moves) 2× the necessary score blocks for causal masks —
    half are fully masked.  Enumerating the valid (q-block, kv-block) pairs
    statically and scanning over them does exactly s²/2 block work; the
    strictly-lower pairs also skip the mask arithmetic entirely.  The
    running-max state lives in an output-sized carry, updated per pair.
    """
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    assert sq == sk, "pairs path is for self-attention prefill"
    k, v = _repeat_kv(k, v, h)
    nq, nk = sq // q_block, sk // k_block
    assert nq * q_block == sq and nk * k_block == sk
    r = q_block // k_block if q_block >= k_block else 1
    pairs = [(i, j) for i in range(nq) for j in range(nk)
             if j * k_block <= i * q_block + q_block - 1]
    pi = jnp.asarray([p[0] for p in pairs], jnp.int32)
    pj = jnp.asarray([p[1] for p in pairs], jnp.int32)
    diag = jnp.asarray([p[1] * k_block + k_block - 1 > p[0] * q_block
                        for p in pairs])   # needs masking (crosses diagonal)
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    neg = jnp.finfo(jnp.float32).min
    qb_ = q.transpose(0, 2, 1, 3)                    # [b, h, sq, hd]
    kb_ = k.transpose(0, 2, 1, 3)
    vb_ = v.transpose(0, 2, 1, 3)

    def step(carry, pij):
        m, l, acc = carry                            # [b,h,sq], ..., [...,hd]
        i, j, need_mask = pij
        qs = jax.lax.dynamic_slice_in_dim(qb_, i * q_block, q_block, 2)
        ks = jax.lax.dynamic_slice_in_dim(kb_, j * k_block, k_block, 2)
        vs = jax.lax.dynamic_slice_in_dim(vb_, j * k_block, k_block, 2)
        logits = jnp.einsum("bhqd,bhkd->bhqk", qs, ks,
                            preferred_element_type=jnp.float32) * scale
        i_ids = i * q_block + jnp.arange(q_block)
        j_ids = j * k_block + jnp.arange(k_block)
        ok = jnp.where(need_mask,
                       j_ids[None, :] <= i_ids[:, None],
                       jnp.ones((q_block, k_block), bool))
        logits = jnp.where(ok[None, None], logits, neg)
        m_blk = jax.lax.dynamic_slice_in_dim(m, i * q_block, q_block, 2)
        l_blk = jax.lax.dynamic_slice_in_dim(l, i * q_block, q_block, 2)
        a_blk = jax.lax.dynamic_slice_in_dim(acc, i * q_block, q_block, 2)
        m_new = jnp.maximum(m_blk, logits.max(-1))
        p = jnp.exp(logits - m_new[..., None])
        corr = jnp.exp(m_blk - m_new)
        l_blk = l_blk * corr + p.sum(-1)
        a_blk = a_blk * corr[..., None] + jnp.einsum(
            "bhqk,bhkd->bhqd", p.astype(vs.dtype), vs,
            preferred_element_type=jnp.float32)
        m = jax.lax.dynamic_update_slice_in_dim(m, m_new, i * q_block, 2)
        l = jax.lax.dynamic_update_slice_in_dim(l, l_blk, i * q_block, 2)
        acc = jax.lax.dynamic_update_slice_in_dim(acc, a_blk,
                                                  i * q_block, 2)
        return (m, l, acc), ()

    m0 = jnp.full((b, h, sq), neg, jnp.float32)
    l0 = jnp.zeros((b, h, sq), jnp.float32)
    a0 = jnp.zeros((b, h, sq, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), (pi, pj, diag))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.astype(q.dtype).transpose(0, 2, 1, 3)


def attend_auto(q, k, v, *, causal: bool,
                w_eff: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Dispatch: materialized mask for short KV, flash blocking beyond
    (causal pair-enumeration when the mask is statically pure-causal)."""
    sq, sk = q.shape[1], k.shape[1]
    if sk <= FLASH_THRESHOLD:
        mask = None
        if causal or w_eff is not None:
            i = jnp.arange(sq)[:, None] + (sk - sq)
            j = jnp.arange(sk)[None, :]
            ok = jnp.ones((sq, sk), bool)
            if causal:
                ok = ok & (j <= i)
            if w_eff is not None:
                ok = ok & (i - j < w_eff)
            mask = ok[None, None]
        return attend(q, k, v, mask)
    # NOTE (§Perf, chameleon×prefill_32k iteration 1 — REFUTED): dispatching
    # to flash_attend_causal_pairs here halves HLO FLOPs (3.65→2.02e15) but
    # the per-pair dynamic updates on the sharded running-state carry made
    # GSPMD emit per-step collectives (wire 4.2e11 → 1.0e14).  The
    # rectangular sweep stays; the pairs kernel remains available/tested.
    return flash_attend(q, k, v, causal=causal, w_eff=w_eff)


def gqa_project(x: jnp.ndarray, p: Params, cfg: ArchConfig
                ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    b, s, d = x.shape
    q = jnp.einsum("bsd,dhk->bshk",
                   x, p["wq"].reshape(d, cfg.n_heads, cfg.hd))
    k = jnp.einsum("bsd,dhk->bshk",
                   x, p["wk"].reshape(d, cfg.n_kv_heads, cfg.hd))
    v = jnp.einsum("bsd,dhk->bshk",
                   x, p["wv"].reshape(d, cfg.n_kv_heads, cfg.hd))
    return q, k, v


def _maybe_head_shard(t: jnp.ndarray) -> jnp.ndarray:
    """Pin [b, s, h, hd] to batch-DP × head-TP when an ambient mesh exists
    and the head dim divides — without this, an SP (sequence-sharded)
    residual makes GSPMD keep q/k/v sequence-sharded with FULL heads into
    the flash scan: 16× redundant attention per device (§Perf iteration,
    chameleon × prefill_32k)."""
    try:
        mesh = jax.sharding.get_abstract_mesh()   # trace-time ambient mesh
        if mesh is None or mesh.empty or "model" not in mesh.shape:
            return t
    except Exception:  # noqa: BLE001
        return t
    if t.shape[2] % mesh.shape["model"]:
        return t
    dp = tuple(a for a in ("pod", "data") if a in mesh.shape)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    if dp and t.shape[0] % dp_size:
        dp = ()
    return jax.lax.with_sharding_constraint(
        t, PartitionSpec(dp if dp else None, None, "model", None))


def attn_block(x: jnp.ndarray, p: Params, cfg: ArchConfig,
               w_eff: Optional[jnp.ndarray], positions: jnp.ndarray
               ) -> jnp.ndarray:
    """Full-sequence causal attention (train / prefill).  ``w_eff``: traced
    sliding-window length, or None for dense causal."""
    q, k, v = gqa_project(x, p, cfg)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    if k.shape[1] > FLASH_THRESHOLD:
        q = _maybe_head_shard(q)
        k = _maybe_head_shard(k)
        v = _maybe_head_shard(v)
    o = attend_auto(q, k, v, causal=True, w_eff=w_eff)
    return jnp.einsum("bshk,hkd->bsd",
                      o, p["wo"].reshape(cfg.n_heads, cfg.hd, x.shape[-1]))


def swiglu(x: jnp.ndarray, p: Params) -> jnp.ndarray:
    gate = jax.nn.silu(jnp.einsum("bsd,df->bsf", x, p["w_gate"]))
    up = jnp.einsum("bsd,df->bsf", x, p["w_up"])
    return jnp.einsum("bsf,fd->bsd", gate * up, p["w_down"])


def _rep_spec(sp_spec):
    """The model-replicated companion of an SP spec (AG target)."""
    if sp_spec is None:
        return None
    return PartitionSpec(sp_spec[0], *([None] * (len(sp_spec) - 1)))


def dense_block(x, p, cfg: ArchConfig, w_eff, positions, sp_spec=None):
    """§Perf note (EXPERIMENTS.md, gemma3 iterations): explicit Megatron-
    style AG(activation)→TP→RS transitions per branch were MEASURED WORSE
    here (wire 1.19→2.33 TB/dev) — at 65k tokens/device the activations
    outweigh the FFN weight shards GSPMD chooses to gather instead.  The
    residual constraint at block boundary + grouped GQA attention is the
    winning placement; leave branch placement to the partitioner."""
    h = x + attn_block(rmsnorm(x, p["ln_attn"], cfg.norm_eps), p, cfg,
                       w_eff, positions)
    h = h + swiglu(rmsnorm(h, p["ln_ffn"], cfg.norm_eps), h_params(p))
    return h


def h_params(p: Params) -> Params:
    return {k: p[k] for k in ("w_gate", "w_up", "w_down")}


def maybe_sp(h: jnp.ndarray, sp_spec: Optional[PartitionSpec]) -> jnp.ndarray:
    """Sequence-parallel residual constraint (no-op when spec is None)."""
    if sp_spec is None:
        return h
    return jax.lax.with_sharding_constraint(h, sp_spec)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def _norm_init(key, shape, scale, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def init_attn_params(key, cfg: ArchConfig, dtype=jnp.bfloat16) -> Params:
    d, hd = cfg.d_model, cfg.hd
    ks = jax.random.split(key, 4)
    s = d ** -0.5
    return {
        "wq": _norm_init(ks[0], (d, cfg.n_heads * hd), s, dtype),
        "wk": _norm_init(ks[1], (d, cfg.n_kv_heads * hd), s, dtype),
        "wv": _norm_init(ks[2], (d, cfg.n_kv_heads * hd), s, dtype),
        "wo": _norm_init(ks[3], (cfg.n_heads * hd, d),
                         (cfg.n_heads * hd) ** -0.5, dtype),
    }


def init_ffn_params(key, cfg: ArchConfig, dtype=jnp.bfloat16,
                    d_ff: Optional[int] = None) -> Params:
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "w_gate": _norm_init(ks[0], (d, f), d ** -0.5, dtype),
        "w_up": _norm_init(ks[1], (d, f), d ** -0.5, dtype),
        "w_down": _norm_init(ks[2], (f, d), f ** -0.5, dtype),
    }


def init_dense_layer(key, cfg: ArchConfig, dtype=jnp.bfloat16) -> Params:
    k1, k2 = jax.random.split(key)
    p = init_attn_params(k1, cfg, dtype)
    p.update(init_ffn_params(k2, cfg, dtype))
    p["ln_attn"] = jnp.zeros((cfg.d_model,), dtype)
    p["ln_ffn"] = jnp.zeros((cfg.d_model,), dtype)
    return p


def stack_layers(key, n: int, init_fn) -> Params:
    """Init n layers and stack each leaf along a new leading axis."""
    keys = jax.random.split(key, n)
    layers = [init_fn(k) for k in keys]
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *layers)


def init_dense_params(key, cfg: ArchConfig, dtype=jnp.bfloat16) -> Params:
    k_emb, k_layers, k_head = jax.random.split(key, 3)
    params = {
        "embed": _norm_init(k_emb, (cfg.vocab, cfg.d_model), 0.02, dtype),
        "layers": stack_layers(k_layers, cfg.n_layers,
                               lambda k: init_dense_layer(k, cfg, dtype)),
        "ln_final": jnp.zeros((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = _norm_init(k_head, (cfg.d_model, cfg.vocab),
                                       cfg.d_model ** -0.5, dtype)
    return params


def global_flags(cfg: ArchConfig) -> jnp.ndarray:
    """[L] bool — layer uses the FULL causal mask (gemma3: every k-th)."""
    if cfg.global_every:
        return (jnp.arange(cfg.n_layers) + 1) % cfg.global_every == 0
    if cfg.sliding_window:
        return jnp.zeros(cfg.n_layers, bool)
    return jnp.ones(cfg.n_layers, bool)


def layer_window(cfg: ArchConfig, s: int, is_global: jnp.ndarray
                 ) -> Optional[jnp.ndarray]:
    """Per-layer effective window (traced): s when global, else the sliding
    window; None when the arch has no sliding layers at all."""
    if not cfg.sliding_window:
        return None
    return jnp.where(is_global, s, cfg.sliding_window).astype(jnp.int32)


# ---------------------------------------------------------------------------
# forward (train / prefill) — scan over stacked layers
# ---------------------------------------------------------------------------
def dense_forward(params: Params, tokens: jnp.ndarray, cfg: ArchConfig,
                  *, embeddings: Optional[jnp.ndarray] = None,
                  remat: bool = False, last_logits: bool = False,
                  sp_spec: Optional[PartitionSpec] = None) -> jnp.ndarray:
    """tokens [b, s] → logits [b, s, vocab] f32 (or [b, 1, vocab] when
    ``last_logits`` — the serving-prefill contract: §Perf iteration 2, the
    full-vocab × full-sequence logits were ~75% of prefill HBM bytes)."""
    b, s = tokens.shape[:2]
    x = embeddings if embeddings is not None \
        else jnp.take(params["embed"], tokens, axis=0)
    positions = jnp.arange(s)[None, :]
    flags = global_flags(cfg)

    def body(h, layer):
        p, is_global = layer
        w_eff = layer_window(cfg, s, is_global)
        h = dense_block(h, p, cfg, w_eff, positions, sp_spec)
        return maybe_sp(h, sp_spec), ()

    if remat:
        body = jax.checkpoint(body)
    x = maybe_sp(x, sp_spec)
    x, _ = jax.lax.scan(body, x, (params["layers"], flags))
    if last_logits:
        x = x[:, -1:]
    x = rmsnorm(x, params["ln_final"], cfg.norm_eps)
    head = params.get("lm_head")
    if head is None:
        head = params["embed"].T
    return jnp.einsum("bsd,dv->bsv", x, head,
                      preferred_element_type=jnp.float32)


# ---------------------------------------------------------------------------
# KV-cache decode
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class KVCache:
    k: jnp.ndarray   # [L, b, S, kv, hd]
    v: jnp.ndarray   # [L, b, S, kv, hd]

    @classmethod
    def zeros(cls, cfg: ArchConfig, batch: int, max_seq: int,
              dtype=jnp.bfloat16, n_layers: Optional[int] = None):
        shape = (n_layers or cfg.n_layers, batch, max_seq,
                 cfg.n_kv_heads, cfg.hd)
        return cls(k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype))


jax.tree_util.register_pytree_node(
    KVCache, lambda c: ((c.k, c.v), None),
    lambda _, kv: KVCache(k=kv[0], v=kv[1]))


def decode_attn_block(x, p, cfg: ArchConfig, k_cache, v_cache, pos,
                      is_global: jnp.ndarray) -> Tuple[jnp.ndarray, ...]:
    """One-token attention against the cache.

    x: [b, 1, d]; k_cache/v_cache: [b, S, kv, hd]; pos: scalar int32 —
    index of the new token.  Returns (out [b,1,d], new k/v caches).
    """
    b, _, d = x.shape
    S = k_cache.shape[1]
    q, k, v = gqa_project(x, p, cfg)
    posv = jnp.full((b, 1), pos, jnp.int32)
    q = apply_rope(q, posv, cfg.rope_theta)
    k = apply_rope(k, posv, cfg.rope_theta)
    k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k, pos, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v, pos, axis=1)
    j = jnp.arange(S)
    valid = j <= pos
    if cfg.sliding_window:
        local_valid = valid & (pos - j < cfg.sliding_window)
        valid = jnp.where(is_global, valid, local_valid)
    mask = valid[None, None, None, :]            # [1,1,1,S]
    o = attend(q, k_cache, v_cache, mask)
    out = jnp.einsum("bshk,hkd->bsd", o,
                     p["wo"].reshape(cfg.n_heads, cfg.hd, d))
    return out, k_cache, v_cache


def dense_decode_step(params: Params, cache: KVCache, token: jnp.ndarray,
                      pos: jnp.ndarray, cfg: ArchConfig
                      ) -> Tuple[jnp.ndarray, KVCache]:
    """token [b, 1] int32, pos scalar → (logits [b, 1, vocab], new cache)."""
    x = jnp.take(params["embed"], token, axis=0)
    flags = global_flags(cfg)

    def body(h, layer):
        p, is_global, kc, vc = layer
        xin = rmsnorm(h, p["ln_attn"], cfg.norm_eps)
        att, kc, vc = decode_attn_block(xin, p, cfg, kc, vc, pos, is_global)
        h = h + att
        h = h + swiglu(rmsnorm(h, p["ln_ffn"], cfg.norm_eps), h_params(p))
        return h, (kc, vc)

    x, (new_k, new_v) = jax.lax.scan(
        body, x, (params["layers"], flags, cache.k, cache.v))
    x = rmsnorm(x, params["ln_final"], cfg.norm_eps)
    head = params.get("lm_head")
    if head is None:
        head = params["embed"].T
    logits = jnp.einsum("bsd,dv->bsv", x, head,
                        preferred_element_type=jnp.float32)
    return logits, KVCache(k=new_k, v=new_v)
