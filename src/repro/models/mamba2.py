"""Mamba2 / SSD (state-space duality) blocks — mamba2-1.3b and the zamba2
backbone.

The SSD chunked algorithm runs as a ``lax.scan`` over sequence chunks
carrying the [b, heads, state, head_dim] SSM state — peak memory is one
chunk's quadratic [Q, Q] block, not the sequence's (this is the same
two-level blocking discipline as the paper's 64-node core tiles: a VMEM-
sized working set + a carried state).  Decode is the O(1) single-token
recurrence on the same state, which is what makes ``long_500k`` runnable
for the SSM/hybrid archs (DESIGN §Arch-applicability).

Per block:  z/x/B/C/dt projections;  causal depthwise conv (width 4) on
x, B, C;  SSD over (x·dt, A, B, C);  gated RMSNorm by silu(z);  out_proj.
A is scalar-per-head (Mamba2's restriction), dt softplus-positive.

TP note (hardware codesign): the projections are SPLIT per component rather
than fused like the reference CUDA kernels — a fused [d, 2di+2n+nh] matrix
would be sliced along its SHARDED output dim (z|x|dt shard over ``model``,
B|C replicate), and GSPMD would insert all-gathers at every slice.  Split
projections give collective-free megatron-style TP: col-shard z/x/dt,
replicate the small B/C, row-shard out_proj with one psum.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ArchConfig
from .transformer import _norm_init, maybe_sp, rmsnorm

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# causal depthwise conv (width k): train form + streaming decode form
# ---------------------------------------------------------------------------
def causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """x: [b, l, ch]; w: [k, ch]; causal depthwise conv + silu."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(k))
    return jax.nn.silu(out + b)


def conv_step(x_t: jnp.ndarray, conv_state: jnp.ndarray, w: jnp.ndarray,
              b: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x_t: [b, ch]; conv_state: [b, k-1, ch] (previous inputs, oldest first).
    Returns (y_t [b, ch], new conv_state)."""
    window = jnp.concatenate([conv_state, x_t[:, None, :]], axis=1)  # [b,k,ch]
    y = jnp.einsum("bkc,kc->bc", window, w)
    return jax.nn.silu(y + b), window[:, 1:, :]


# ---------------------------------------------------------------------------
# SSD chunked scan
# ---------------------------------------------------------------------------
def ssd_scan(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
             B: jnp.ndarray, C: jnp.ndarray, D: jnp.ndarray, *,
             chunk: int = 64, h_init: Optional[jnp.ndarray] = None
             ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: [b, l, nh, p]; dt: [b, l, nh] (f32, >0); A: [nh] (f32, <0);
    B, C: [b, l, n] (one group, broadcast over heads); D: [nh].

    Returns (y [b, l, nh, p], final state [b, nh, n, p]).
    """
    b, l, nh, p = x.shape
    n = B.shape[-1]
    if l % chunk:
        raise ValueError(f"seq len {l} not divisible by chunk {chunk}")
    c = l // chunk
    f32 = jnp.float32
    xs = x.astype(f32).reshape(b, c, chunk, nh, p).transpose(1, 0, 2, 3, 4)
    dts = dt.astype(f32).reshape(b, c, chunk, nh).transpose(1, 0, 2, 3)
    Bs = B.astype(f32).reshape(b, c, chunk, n).transpose(1, 0, 2, 3)
    Cs = C.astype(f32).reshape(b, c, chunk, n).transpose(1, 0, 2, 3)
    causal = jnp.tril(jnp.ones((chunk, chunk), bool))

    def step(H, inp):
        xq, dtq, Bq, Cq = inp                  # [b,Q,nh,p] [b,Q,nh] [b,Q,n]
        dA = dtq * A                            # [b,Q,nh]
        cum = jnp.cumsum(dA, axis=1)
        # --- intra-chunk (diagonal block): attention-like quadratic form
        diff = cum[:, :, None, :] - cum[:, None, :, :]          # [b,i,j,nh]
        Lmat = jnp.where(causal[None, :, :, None], jnp.exp(diff), 0.0)
        scores = jnp.einsum("bin,bjn->bij", Cq, Bq)             # [b,i,j]
        w = scores[..., None] * Lmat * dtq[:, None, :, :]       # [b,i,j,nh]
        y = jnp.einsum("bijh,bjhp->bihp", w, xq)
        # --- contribution of the carried state
        y += jnp.einsum("bin,bhnp,bih->bihp", Cq, H, jnp.exp(cum))
        # --- state update for the next chunk
        decay_out = jnp.exp(cum[:, -1:, :] - cum)               # [b,Q,nh]
        S = jnp.einsum("bjn,bjh,bjhp->bhnp", Bq, dtq * decay_out, xq)
        H = jnp.exp(cum[:, -1, :])[:, :, None, None] * H + S
        return H, y

    H0 = h_init if h_init is not None else jnp.zeros((b, nh, n, p), f32)
    H_final, ys = jax.lax.scan(step, H0, (xs, dts, Bs, Cs))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, l, nh, p)
    y = y + D[None, None, :, None] * x.astype(f32)
    return y.astype(x.dtype), H_final


def ssd_step(H: jnp.ndarray, x_t: jnp.ndarray, dt_t: jnp.ndarray,
             A: jnp.ndarray, B_t: jnp.ndarray, C_t: jnp.ndarray,
             D: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Single-token recurrence.  H: [b, nh, n, p]; x_t: [b, nh, p];
    dt_t: [b, nh]; B_t, C_t: [b, n].  Returns (new H, y_t [b, nh, p])."""
    f32 = jnp.float32
    xf = x_t.astype(f32)
    decay = jnp.exp(dt_t * A)                                  # [b, nh]
    S = jnp.einsum("bn,bh,bhp->bhnp", B_t.astype(f32), dt_t, xf)
    H = decay[:, :, None, None] * H + S
    y = jnp.einsum("bn,bhnp->bhp", C_t.astype(f32), H) \
        + D[None, :, None] * xf
    return H, y.astype(x_t.dtype)


# ---------------------------------------------------------------------------
# the Mamba2 block (split projections — see TP note above)
# ---------------------------------------------------------------------------
def init_mamba_layer(key, cfg: ArchConfig, dtype=jnp.bfloat16) -> Params:
    d, di, n = cfg.d_model, cfg.d_inner, cfg.ssm_state
    nh, k = cfg.ssm_heads, cfg.ssm_conv
    ks = jax.random.split(key, 6)
    s = d ** -0.5
    return {
        "w_z": _norm_init(ks[0], (d, di), s, dtype),
        "w_x": _norm_init(ks[1], (d, di), s, dtype),
        "w_B": _norm_init(ks[2], (d, n), s, dtype),
        "w_C": _norm_init(ks[3], (d, n), s, dtype),
        "w_dt": _norm_init(ks[4], (d, nh), s, dtype),
        "conv_wx": _norm_init(ks[5], (k, di), k ** -0.5, jnp.float32),
        "conv_bx": jnp.zeros((di,), jnp.float32),
        "conv_wB": _norm_init(ks[5], (k, n), k ** -0.5, jnp.float32),
        "conv_bB": jnp.zeros((n,), jnp.float32),
        "conv_wC": _norm_init(ks[5], (k, n), k ** -0.5, jnp.float32),
        "conv_bC": jnp.zeros((n,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "A_log": jnp.zeros((nh,), jnp.float32),         # A = -exp(A_log) = -1
        "D": jnp.ones((nh,), jnp.float32),
        "norm_g": jnp.zeros((di,), dtype),
        "out_proj": _norm_init(ks[2], (di, d), di ** -0.5, dtype),
        "ln": jnp.zeros((d,), dtype),
    }


def mamba_block(x: jnp.ndarray, p: Params, cfg: ArchConfig, *,
                chunk: int = 64) -> jnp.ndarray:
    """Full-sequence Mamba2 block (pre-norm residual applied by caller)."""
    di, n, nh, hp = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    h = rmsnorm(x, p["ln"], cfg.norm_eps)
    z = jnp.einsum("bld,de->ble", h, p["w_z"])
    xin = jnp.einsum("bld,de->ble", h, p["w_x"])
    B = jnp.einsum("bld,dn->bln", h, p["w_B"])
    C = jnp.einsum("bld,dn->bln", h, p["w_C"])
    dt = jnp.einsum("bld,dh->blh", h, p["w_dt"])
    xin = causal_conv(xin.astype(jnp.float32), p["conv_wx"], p["conv_bx"])
    B = causal_conv(B.astype(jnp.float32), p["conv_wB"], p["conv_bB"])
    C = causal_conv(C.astype(jnp.float32), p["conv_wC"], p["conv_bC"])
    xs = xin.reshape(*x.shape[:2], nh, hp).astype(x.dtype)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    y, _ = ssd_scan(xs, dt, A, B, C, p["D"], chunk=chunk)
    y = y.reshape(*x.shape[:2], di)
    y = rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                p["norm_g"], cfg.norm_eps)
    return jnp.einsum("ble,ed->bld", y, p["out_proj"])


def mamba_decode_block(x_t: jnp.ndarray, p: Params, cfg: ArchConfig,
                       conv_x: jnp.ndarray, conv_B: jnp.ndarray,
                       conv_C: jnp.ndarray, ssm_state: jnp.ndarray
                       ) -> Tuple[jnp.ndarray, ...]:
    """x_t: [b, 1, d] one token.  Returns (out, conv_x', conv_B', conv_C',
    ssm')."""
    di, n, nh, hp = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    h = rmsnorm(x_t, p["ln"], cfg.norm_eps)[:, 0]
    z = jnp.einsum("bd,de->be", h, p["w_z"])
    xin = jnp.einsum("bd,de->be", h, p["w_x"])
    B = jnp.einsum("bd,dn->bn", h, p["w_B"])
    C = jnp.einsum("bd,dn->bn", h, p["w_C"])
    dt = jnp.einsum("bd,dh->bh", h, p["w_dt"])
    xin, conv_x = conv_step(xin.astype(jnp.float32), conv_x,
                            p["conv_wx"], p["conv_bx"])
    B, conv_B = conv_step(B.astype(jnp.float32), conv_B,
                          p["conv_wB"], p["conv_bB"])
    C, conv_C = conv_step(C.astype(jnp.float32), conv_C,
                          p["conv_wC"], p["conv_bC"])
    xs = xin.reshape(-1, nh, hp).astype(x_t.dtype)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    ssm_state, y = ssd_step(ssm_state, xs, dt, A, B, C, p["D"])
    y = y.reshape(-1, di)
    y = rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                p["norm_g"], cfg.norm_eps)
    out = jnp.einsum("be,ed->bd", y, p["out_proj"])
    return out[:, None, :], conv_x, conv_B, conv_C, ssm_state


# ---------------------------------------------------------------------------
# pure-SSM stack (mamba2-1.3b)
# ---------------------------------------------------------------------------
from .transformer import stack_layers  # noqa: E402


@dataclasses.dataclass(frozen=True)
class MambaCache:
    conv_x: jnp.ndarray   # [L, b, k-1, di] f32
    conv_B: jnp.ndarray   # [L, b, k-1, n] f32
    conv_C: jnp.ndarray   # [L, b, k-1, n] f32
    ssm: jnp.ndarray      # [L, b, nh, n, p] f32

    @classmethod
    def zeros(cls, cfg: ArchConfig, batch: int,
              n_layers: Optional[int] = None):
        L = n_layers or cfg.n_layers
        k1 = cfg.ssm_conv - 1
        return cls(
            conv_x=jnp.zeros((L, batch, k1, cfg.d_inner), jnp.float32),
            conv_B=jnp.zeros((L, batch, k1, cfg.ssm_state), jnp.float32),
            conv_C=jnp.zeros((L, batch, k1, cfg.ssm_state), jnp.float32),
            ssm=jnp.zeros((L, batch, cfg.ssm_heads, cfg.ssm_state,
                           cfg.ssm_head_dim), jnp.float32),
        )

    def slice_layers(self, lo: int, hi: int) -> "MambaCache":
        return MambaCache(conv_x=self.conv_x[lo:hi],
                          conv_B=self.conv_B[lo:hi],
                          conv_C=self.conv_C[lo:hi], ssm=self.ssm[lo:hi])


jax.tree_util.register_pytree_node(
    MambaCache, lambda c: ((c.conv_x, c.conv_B, c.conv_C, c.ssm), None),
    lambda _, kv: MambaCache(conv_x=kv[0], conv_B=kv[1], conv_C=kv[2],
                             ssm=kv[3]))


def init_ssm_params(key, cfg: ArchConfig, dtype=jnp.bfloat16) -> Params:
    k_emb, k_layers = jax.random.split(key)
    return {
        "embed": _norm_init(k_emb, (cfg.vocab, cfg.d_model), 0.02, dtype),
        "layers": stack_layers(k_layers, cfg.n_layers,
                               lambda k: init_mamba_layer(k, cfg, dtype)),
        "ln_final": jnp.zeros((cfg.d_model,), dtype),
    }


def ssm_forward(params: Params, tokens: jnp.ndarray, cfg: ArchConfig, *,
                chunk: int = 64,
                embeddings: Optional[jnp.ndarray] = None,
                remat: bool = False, sp_spec=None,
                last_logits: bool = False) -> jnp.ndarray:
    x = embeddings if embeddings is not None \
        else jnp.take(params["embed"], tokens, axis=0)

    def body(h, p):
        return maybe_sp(h + mamba_block(h, p, cfg, chunk=chunk), sp_spec), ()

    if remat:
        body = jax.checkpoint(body)
    x = maybe_sp(x, sp_spec)
    x, _ = jax.lax.scan(body, x, params["layers"])
    if last_logits:
        x = x[:, -1:]
    x = rmsnorm(x, params["ln_final"], cfg.norm_eps)
    return jnp.einsum("bsd,dv->bsv", x, params["embed"].T,
                      preferred_element_type=jnp.float32)


def ssm_decode_step(params: Params, cache: MambaCache, token: jnp.ndarray,
                    pos: jnp.ndarray, cfg: ArchConfig
                    ) -> Tuple[jnp.ndarray, MambaCache]:
    del pos  # state carries all history — O(1) decode, no position needed
    x = jnp.take(params["embed"], token, axis=0)

    def body(h, layer):
        p, cx, cb, cc, ss = layer
        out, cx, cb, cc, ss = mamba_decode_block(h, p, cfg, cx, cb, cc, ss)
        return h + out, (cx, cb, cc, ss)

    x, (cx, cb, cc, ssm) = jax.lax.scan(
        body, x, (params["layers"], cache.conv_x, cache.conv_B,
                  cache.conv_C, cache.ssm))
    x = rmsnorm(x, params["ln_final"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, params["embed"].T,
                        preferred_element_type=jnp.float32)
    return logits, MambaCache(conv_x=cx, conv_B=cb, conv_C=cc, ssm=ssm)
