"""Mixture-of-Experts FFN — token-choice top-k with capacity dispatch.

Serves llama4-maverick (128e top-1, dense/moe interleaved pairs) and
moonshot-v1 (64e top-6, all-moe).  Experts shard over the ``model`` axis
(EP); the scatter into the [E, C, d] expert buffer and the gather back are
the token-routing all-to-all — which is exactly the paper's graph message
passing with a rectangular (tokens × experts) adjacency, so the hypercube
schedule analysis (DESIGN §Arch-applicability) applies: tokens destined to
the same expert are *pre-combined per device before exchange* by the sort,
mirroring the Block-Message merge.

Capacity C bounds the per-expert buffer (tokens beyond C drop — standard
top-k MoE; the tests check the drop fraction stays tiny at the default
factor 1.25).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.compat import axis_size as compat_axis_size
from repro.compat import shard_map as compat_shard_map

from .config import ArchConfig
from .transformer import _norm_init

Params = Dict[str, Any]


def init_moe_params(key, cfg: ArchConfig, dtype=jnp.bfloat16) -> Params:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.moe_experts
    ks = jax.random.split(key, 4)
    return {
        "router": _norm_init(ks[0], (d, e), d ** -0.5, jnp.float32),
        "w_gate": _norm_init(ks[1], (e, d, f), d ** -0.5, dtype),
        "w_up": _norm_init(ks[2], (e, d, f), d ** -0.5, dtype),
        "w_down": _norm_init(ks[3], (e, f, d), f ** -0.5, dtype),
    }


def capacity(n_tokens: int, n_experts: int, topk: int,
             factor: float = 1.25) -> int:
    c = int(factor * n_tokens * topk / n_experts)
    return max(8, ((c + 7) // 8) * 8)          # pad to 8 for TPU layout


def _route(x, p, cfg):
    """Router + top-k + aux loss.  x: [b, s, d]."""
    b, s, d = x.shape
    e, k = cfg.moe_experts, cfg.moe_topk
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gates, eidx = jax.lax.top_k(probs, k)                 # [b, s, k]
    gates = gates / jnp.clip(gates.sum(-1, keepdims=True), 1e-9)
    me = probs.mean((0, 1))
    ce = jnp.zeros(e).at[eidx.reshape(-1)].add(1.0) / (b * s * k)
    aux = e * jnp.sum(me * ce)                            # Switch aux loss
    return gates, eidx, aux


def _positions(eidx_flat, e, cap):
    """Capacity plan: position-in-expert for every routed slot ([s*k])."""
    onehot = jax.nn.one_hot(eidx_flat, e, dtype=jnp.int32)
    pos = jnp.take_along_axis(jnp.cumsum(onehot, 0) - 1,
                              eidx_flat[:, None], 1)[:, 0]
    keep = pos < cap
    return jnp.where(keep, pos, cap - 1), keep


def moe_ffn(x: jnp.ndarray, p: Params, cfg: ArchConfig,
            capacity_factor: float = 1.25, ep_spec=None
            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: [b, s, d] → (y: [b, s, d], aux_loss scalar).

    Single-device / unsharded path: PER-SAMPLE dispatch (vmap over batch) —
    top-k, position-in-expert cumsum and the scatter into [e, cap, d] all
    stay inside one sequence.  The distributed path is
    :func:`moe_ffn_ep` (explicit shard_map message passing); lm.py selects
    it when an ``ep_spec`` is configured.
    """
    if ep_spec is not None:
        return moe_ffn_ep(x, p, cfg, capacity_factor, ep_spec)
    b, s, d = x.shape
    e, k = cfg.moe_experts, cfg.moe_topk
    gates, eidx, aux = _route(x, p, cfg)
    cap = capacity(s, e, k, capacity_factor)

    def dispatch(xt, eix):
        flat_e = eix.reshape(-1)                           # [s*k]
        safe_pos, keep = _positions(flat_e, e, cap)
        xk = jnp.repeat(xt, k, axis=0)                     # [s*k, d]
        buf = jnp.zeros((e, cap, d), xt.dtype).at[flat_e, safe_pos].add(
            jnp.where(keep[:, None], xk, 0).astype(xt.dtype))
        return buf, flat_e, safe_pos, keep

    buf, flat_e, safe_pos, keep = jax.vmap(dispatch)(x, eidx)
    gate_h = jax.nn.silu(jnp.einsum("becd,edf->becf", buf, p["w_gate"]))
    up_h = jnp.einsum("becd,edf->becf", buf, p["w_up"])
    out = jnp.einsum("becf,efd->becd", gate_h * up_h, p["w_down"])

    def combine(o, fe, sp, kp, g):
        got = jnp.where(kp[:, None], o[fe, sp], 0)         # [s*k, d]
        return (got * g.reshape(-1)[:, None].astype(got.dtype)
                ).reshape(s, k, d).sum(1)

    y = jax.vmap(combine)(out, flat_e, safe_pos, keep, gates)
    return y, aux


def moe_ffn_ep(x: jnp.ndarray, p: Params, cfg: ArchConfig,
               capacity_factor: float, ep_spec) -> Tuple[jnp.ndarray, ...]:
    """Expert-parallel MoE as EXPLICIT shard_map message passing.

    §Perf iterations 1-2 (EXPERIMENTS.md): leaving the dispatch to GSPMD
    sharding constraints made the partitioner all-reduce the full
    [b, s·k, d] expanded-token tensor (~0.9 TB/device/step on moonshot) and
    re-all-gather it under remat.  This schedule is the paper's
    message-passing architecture instead — every device:

      1. all-gathers the (sequence-sharded) residual once — senders hold
         their full messages, like the NUMA cores hold their node features;
      2. routes + capacity-plans IDENTICALLY (replicated math, no wire);
      3. scatters ONLY the tokens destined to its own experts into its
         local [b_l, e_local, cap, d] buffer (the Block-Message build:
         sender-side selection, zero dispatch traffic);
      4. runs its experts;
      5. contributes partial outputs for every token and folds them with
         ONE psum_scatter back to the sequence-sharded residual (the
         delivery + local aggregation).

    Wire per layer = one all-gather + one reduce-scatter of the [b, s, d]
    activation — independent of top-k (the paper's compression argument:
    wire carries combined messages, not per-edge traffic).
    """
    b, s, d = x.shape
    e, k = cfg.moe_experts, cfg.moe_topk
    cap = capacity(s, e, k, capacity_factor)
    dp = ep_spec[0] if isinstance(ep_spec[0], tuple) else (ep_spec[0],)
    dp = tuple(a for a in dp if a)
    from jax.sharding import PartitionSpec as P_

    def body(x_l, router, wg, wu, wd):
        # x_l: [b_l, s_l, d] sequence-sharded slice
        x_full = jax.lax.all_gather(x_l, "model", axis=1, tiled=True)
        gates, eidx, aux = _route(x_full, {"router": router}, cfg)
        n_model = compat_axis_size("model")
        e_local = e // n_model
        j = jax.lax.axis_index("model")
        lo = j * e_local

        def dispatch(xt, eix, g):
            flat_e = eix.reshape(-1)                       # [s*k]
            safe_pos, keep = _positions(flat_e, e, cap)    # GLOBAL capacity
            mine = (flat_e >= lo) & (flat_e < lo + e_local)
            keep_l = keep & mine
            fe_l = jnp.where(mine, flat_e - lo, 0)
            xk = jnp.repeat(xt, k, axis=0)
            buf = jnp.zeros((e_local, cap, d), xt.dtype) \
                .at[fe_l, safe_pos].add(
                jnp.where(keep_l[:, None], xk, 0).astype(xt.dtype))
            return buf, fe_l, safe_pos, keep_l

        buf, fe_l, safe_pos, keep_l = jax.vmap(dispatch)(x_full, eidx, gates)
        gate_h = jax.nn.silu(jnp.einsum("becd,edf->becf", buf, wg))
        up_h = jnp.einsum("becd,edf->becf", buf, wu)
        out = jnp.einsum("becf,efd->becd", gate_h * up_h, wd)

        def combine(o, fe, sp, kp, g):
            got = jnp.where(kp[:, None], o[fe, sp], 0)     # [s*k, d]
            return (got * g.reshape(-1)[:, None].astype(got.dtype)
                    ).reshape(s, k, d).sum(1)

        y_partial = jax.vmap(combine)(out, fe_l, safe_pos, keep_l, gates)
        # fold partial expert outputs + return to the s-sharded residual
        y = jax.lax.psum_scatter(y_partial, "model", scatter_dimension=1,
                                 tiled=True)
        # aux leaves the region device-varying ([1] per device) and is
        # averaged outside — a replicated (P()) output would need an in-body
        # pmean, whose transpose chokes on symbolic-Zero cotangents when aux
        # is unused by the loss (older shard_map); the mean outside is the
        # same value and differentiates on every jax we support
        return y, aux[None]

    y, aux = compat_shard_map(
        body,
        in_specs=(P_(dp, "model", None), P_(), P_("model", None, None),
                  P_("model", None, None), P_("model", None, None)),
        out_specs=(P_(dp, "model", None), P_(dp + ("model",))),
    )(x, p["router"], p["w_gate"], p["w_up"], p["w_down"])
    return y, aux.mean()


# ---------------------------------------------------------------------------
# MoE decoder stacks
# ---------------------------------------------------------------------------
from .transformer import (KVCache, attn_block, causal_mask,  # noqa: E402
                          decode_attn_block, dense_block, global_flags,
                          h_params, init_attn_params, init_dense_layer,
                          init_ffn_params, maybe_sp, rmsnorm, stack_layers,
                          swiglu)


def init_moe_layer(key, cfg: ArchConfig, dtype=jnp.bfloat16) -> Params:
    k1, k2 = jax.random.split(key)
    pl = init_attn_params(k1, cfg, dtype)
    pl.update(init_moe_params(k2, cfg, dtype))
    pl["ln_attn"] = jnp.zeros((cfg.d_model,), dtype)
    pl["ln_ffn"] = jnp.zeros((cfg.d_model,), dtype)
    return pl


def init_moe_stack_params(key, cfg: ArchConfig, dtype=jnp.bfloat16) -> Params:
    """llama4 style (interleave=2): scan over (dense, moe) PAIRS; moonshot
    style (interleave=1): scan over moe layers only."""
    k_emb, k_a, k_b, k_head = jax.random.split(key, 4)
    params: Params = {
        "embed": _norm_init(k_emb, (cfg.vocab, cfg.d_model), 0.02, dtype),
        "ln_final": jnp.zeros((cfg.d_model,), dtype),
    }
    if cfg.moe_interleave == 2:
        n_pairs = cfg.n_layers // 2
        params["dense_layers"] = stack_layers(
            k_a, n_pairs, lambda k: init_dense_layer(k, cfg, dtype))
        params["moe_layers"] = stack_layers(
            k_b, n_pairs, lambda k: init_moe_layer(k, cfg, dtype))
    else:
        params["moe_layers"] = stack_layers(
            k_a, cfg.n_layers, lambda k: init_moe_layer(k, cfg, dtype))
    if not cfg.tie_embeddings:
        params["lm_head"] = _norm_init(k_head, (cfg.d_model, cfg.vocab),
                                       cfg.d_model ** -0.5, dtype)
    return params


def _moe_block(x, p, cfg, w_eff, positions, cf=1.25, ep_spec=None):
    h = x + attn_block(rmsnorm(x, p["ln_attn"], cfg.norm_eps), p, cfg,
                       w_eff, positions)
    y, aux = moe_ffn(rmsnorm(h, p["ln_ffn"], cfg.norm_eps), p, cfg,
                     capacity_factor=cf, ep_spec=ep_spec)
    return h + y, aux


def moe_forward(params: Params, tokens: jnp.ndarray, cfg: ArchConfig,
                *, embeddings: Optional[jnp.ndarray] = None,
                capacity_factor: float = 1.25, remat: bool = False,
                sp_spec=None, ep_spec=None, last_logits: bool = False
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """→ (logits [b, s, vocab] f32, aux_loss scalar)."""
    b, s = tokens.shape[:2]
    x = embeddings if embeddings is not None \
        else jnp.take(params["embed"], tokens, axis=0)
    positions = jnp.arange(s)[None, :]
    x = maybe_sp(x, sp_spec)

    if cfg.moe_interleave == 2:
        def body(carry, layer):
            h, aux = carry
            pd, pm = layer
            h = dense_block(h, pd, cfg, None, positions, sp_spec)
            h, a = _moe_block(h, pm, cfg, None, positions, capacity_factor,
                              ep_spec)
            return (maybe_sp(h, sp_spec), aux + a), ()
        if remat:
            body = jax.checkpoint(body)
        (x, aux), _ = jax.lax.scan(
            body, (x, jnp.zeros((), jnp.float32)),
            (params["dense_layers"], params["moe_layers"]))
    else:
        def body(carry, p):
            h, aux = carry
            h, a = _moe_block(h, p, cfg, None, positions, capacity_factor,
                              ep_spec)
            return (maybe_sp(h, sp_spec), aux + a), ()
        if remat:
            body = jax.checkpoint(body)
        (x, aux), _ = jax.lax.scan(
            body, (x, jnp.zeros((), jnp.float32)), params["moe_layers"])

    if last_logits:
        x = x[:, -1:]
    x = rmsnorm(x, params["ln_final"], cfg.norm_eps)
    head = params.get("lm_head")
    if head is None:
        head = params["embed"].T
    logits = jnp.einsum("bsd,dv->bsv", x, head,
                        preferred_element_type=jnp.float32)
    return logits, aux / cfg.n_layers


def moe_decode_step(params: Params, cache: KVCache, token: jnp.ndarray,
                    pos: jnp.ndarray, cfg: ArchConfig,
                    capacity_factor: float = 1.25
                    ) -> Tuple[jnp.ndarray, KVCache]:
    """One-token decode; cache spans ALL attention layers in stack order
    (interleave=2 ⇒ cache[2i] = dense layer i, cache[2i+1] = moe layer i)."""
    x = jnp.take(params["embed"], token, axis=0)
    always_global = jnp.ones((), bool)

    def attn_then(h, p, kc, vc):
        xin = rmsnorm(h, p["ln_attn"], cfg.norm_eps)
        att, kc, vc = decode_attn_block(xin, p, cfg, kc, vc, pos,
                                        always_global)
        return h + att, kc, vc

    if cfg.moe_interleave == 2:
        n_pairs = cfg.n_layers // 2
        kd, km = cache.k[0::2], cache.k[1::2]
        vd, vm = cache.v[0::2], cache.v[1::2]

        def body(h, layer):
            pd, pm, kcd, vcd, kcm, vcm = layer
            h, kcd, vcd = attn_then(h, pd, kcd, vcd)
            h = h + swiglu(rmsnorm(h, pd["ln_ffn"], cfg.norm_eps),
                           h_params(pd))
            h, kcm, vcm = attn_then(h, pm, kcm, vcm)
            y, _ = moe_ffn(rmsnorm(h, pm["ln_ffn"], cfg.norm_eps), pm, cfg,
                           capacity_factor=capacity_factor)
            return h + y, (kcd, vcd, kcm, vcm)

        x, (nkd, nvd, nkm, nvm) = jax.lax.scan(
            body, x, (params["dense_layers"], params["moe_layers"],
                      kd, vd, km, vm))
        new_k = jnp.stack([nkd, nkm], 1).reshape(cache.k.shape)
        new_v = jnp.stack([nvd, nvm], 1).reshape(cache.v.shape)
    else:
        def body(h, layer):
            p, kc, vc = layer
            h, kc, vc = attn_then(h, p, kc, vc)
            y, _ = moe_ffn(rmsnorm(h, p["ln_ffn"], cfg.norm_eps), p, cfg,
                           capacity_factor=capacity_factor)
            return h + y, (kc, vc)
        x, (new_k, new_v) = jax.lax.scan(
            body, x, (params["moe_layers"], cache.k, cache.v))

    x = rmsnorm(x, params["ln_final"], cfg.norm_eps)
    head = params.get("lm_head")
    if head is None:
        head = params["embed"].T
    logits = jnp.einsum("bsd,dv->bsv", x, head,
                        preferred_element_type=jnp.float32)
    return logits, KVCache(k=new_k, v=new_v)
