"""Health monitoring — failure detection + straggler mitigation policy.

No real fleet exists in this container, so the monitor is the *policy
engine* a real deployment would drive from heartbeats: it consumes per-step,
per-worker timing/liveness reports (tests inject synthetic traces with
faults) and emits actions:

  * ``CHECKPOINT_NOW``  — a worker missed ``miss_limit`` heartbeats: save
    before likely loss of a host;
  * ``EVICT_AND_RESHARD`` — worker confirmed dead (or is a persistent
    straggler): shrink to the survivor mesh via checkpoint/elastic.py;
  * ``REBALANCE`` — transient straggler (> ``straggler_factor`` × median
    step time for ``patience`` consecutive steps): first response is to
    shed load (smaller per-device batch on that replica's data shard) —
    matching the paper's observation (§5.3, Fig. 10/11) that skewed
    aggregation load, not compute, drives core idling.

Deterministic and unit-testable; launch/train.py wires it into the loop.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Dict, List, Optional, Sequence


class Action(enum.Enum):
    NONE = "none"
    CHECKPOINT_NOW = "checkpoint_now"
    REBALANCE = "rebalance"
    EVICT_AND_RESHARD = "evict_and_reshard"


@dataclasses.dataclass
class WorkerState:
    worker_id: int
    alive: bool = True
    missed_heartbeats: int = 0
    slow_streak: int = 0


@dataclasses.dataclass
class HealthMonitor:
    n_workers: int
    straggler_factor: float = 1.5
    patience: int = 3
    miss_limit: int = 2

    def __post_init__(self):
        self.workers = [WorkerState(i) for i in range(self.n_workers)]
        self.log: List[Dict] = []

    # -- report ingestion ----------------------------------------------------
    def report_step(self, step: int, step_times: Sequence[Optional[float]]
                    ) -> Dict[int, Action]:
        """step_times[i] = wall seconds for worker i, or None = no heartbeat.
        Returns {worker_id: action} for every non-NONE action."""
        alive_times = [t for t in step_times if t is not None]
        median = sorted(alive_times)[len(alive_times) // 2] if alive_times \
            else 0.0
        actions: Dict[int, Action] = {}
        for w, t in zip(self.workers, step_times):
            if not w.alive:
                continue
            if t is None:
                w.missed_heartbeats += 1
                if w.missed_heartbeats == 1:
                    actions[w.worker_id] = Action.CHECKPOINT_NOW
                if w.missed_heartbeats >= self.miss_limit:
                    w.alive = False
                    actions[w.worker_id] = Action.EVICT_AND_RESHARD
                continue
            w.missed_heartbeats = 0
            if median and t > self.straggler_factor * median:
                w.slow_streak += 1
                if w.slow_streak == self.patience:
                    actions[w.worker_id] = Action.REBALANCE
                elif w.slow_streak >= 2 * self.patience:
                    w.alive = False
                    actions[w.worker_id] = Action.EVICT_AND_RESHARD
            else:
                w.slow_streak = 0
        if actions:
            self.log.append({"step": step,
                             "actions": {k: v.value
                                         for k, v in actions.items()}})
        return actions

    # -- state ----------------------------------------------------------------
    def survivors(self) -> List[int]:
        return [w.worker_id for w in self.workers if w.alive]

    def n_alive(self) -> int:
        return len(self.survivors())
