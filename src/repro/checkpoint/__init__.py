from .manager import CheckpointManager
from .elastic import (ScalePlan, gather_global, make_mesh_from_plan, reshard,
                      scale_plan, shardings_like)
from .health import Action, HealthMonitor

__all__ = ["CheckpointManager", "ScalePlan", "gather_global",
           "make_mesh_from_plan", "reshard", "scale_plan", "shardings_like",
           "Action", "HealthMonitor"]
