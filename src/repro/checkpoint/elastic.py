"""Elastic scaling — reshard a running job onto a different mesh.

Checkpoints store GLOBAL host arrays (manager.py), so elasticity reduces to
"restore with the new mesh's shardings".  This module supplies the two
pieces around that:

  * :func:`reshard` — live pytree → new mesh (no disk round-trip): gather to
    host, device_put with the target shardings.  Used when the job keeps
    running but the healthy-device set changed.
  * :func:`scale_plan` — given (old_devices, new_devices) pick the largest
    valid production-shaped mesh and report the batch/step re-scaling the
    trainer applies (global batch is preserved by rebalancing per-device
    batch — straggler-removal shrinks the mesh, recovery grows it back).

The launcher's failure path (launch/train.py + checkpoint/health.py) is:
detect → checkpoint (or reuse last) → build survivor mesh → restore with new
shardings → continue.  tests/test_elastic.py runs the full loop on subsets
of the 16 host devices.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec


def gather_global(tree: Any) -> Any:
    """Device pytree → host numpy pytree (global arrays)."""
    return jax.tree_util.tree_map(np.asarray, tree)


def reshard(tree: Any, shardings: Any) -> Any:
    """Place a (host or device) pytree onto new shardings leaf-by-leaf."""
    host = gather_global(tree)
    return jax.tree_util.tree_map(
        lambda a, s: jax.device_put(a, s), host, shardings)


@dataclasses.dataclass(frozen=True)
class ScalePlan:
    mesh_shape: Tuple[int, ...]
    axis_names: Tuple[str, ...]
    n_devices: int
    per_device_batch_scale: float   # multiply per-device batch by this


def scale_plan(n_available: int, *, model_parallel: int = 16,
               global_batch: int = 256) -> ScalePlan:
    """Largest (data, model) mesh with the fixed model-parallel degree.

    The paper's 16-core hypercube (and our TP/EP degree) is a property of
    the MODEL layout, so elasticity trades only the data axis: lose a node
    → drop one data replica, keep global batch by scaling per-device batch.
    """
    if n_available < model_parallel:
        # degrade model parallelism by powers of two (hypercube needs 2^k)
        mp = 1 << int(np.log2(max(n_available, 1)))
        data = 1
    else:
        mp = model_parallel
        data = n_available // model_parallel
    new_world = data * mp
    old_data = max(global_batch // max(global_batch // max(data, 1), 1), 1)
    return ScalePlan(
        mesh_shape=(data, mp), axis_names=("data", "model"),
        n_devices=new_world,
        per_device_batch_scale=global_batch / (data * (global_batch // max(data, 1))) if data else 1.0,
    )


def make_mesh_from_plan(plan: ScalePlan,
                        devices: Optional[Sequence] = None) -> Mesh:
    devs = list(devices if devices is not None else jax.devices())
    devs = devs[:plan.n_devices]
    arr = np.array(devs).reshape(plan.mesh_shape)
    return Mesh(arr, plan.axis_names)


def shardings_like(tree: Any, mesh: Mesh, spec_fn) -> Any:
    """Build a shardings pytree: ``spec_fn(path_free_leaf) -> PartitionSpec``
    (most callers use a constant replicated spec for params and let pjit
    re-shard activations)."""
    return jax.tree_util.tree_map(
        lambda leaf: NamedSharding(mesh, spec_fn(leaf)), tree)
