"""Checkpointing — atomic, sharded, async, reshardable.

Fault-tolerance contract for the 1000-node deployment:
  * **atomic**: a checkpoint is written to ``step_XXXX.tmp/`` and renamed
    into place only after every leaf + manifest is fsynced — a crash
    mid-write can never leave a half checkpoint that restore would pick up;
  * **sharded**: each pytree leaf is saved as its own ``.npy`` (addressed by
    tree path), so per-host writers can stripe leaves — on this container
    one process writes all of them, the layout is the multi-host one;
  * **async**: ``save_async`` snapshots to host memory synchronously (device
    buffers are never borrowed across steps) and writes on a worker thread —
    the train loop blocks only for the snapshot;
  * **reshardable**: leaves are stored as GLOBAL arrays; restore takes an
    optional sharding pytree and ``device_put``s into any mesh — elastic
    scale-up/down is restore-with-different-mesh (checkpoint/elastic.py).

Retention keeps the newest K checkpoints (crash-looped jobs don't fill the
disk).  ``latest_step`` + the data-pipeline state inside the manifest give
exact-resume (tested).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

SEP = "/"


def _flatten_with_paths(tree) -> List[Tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = SEP.join(_path_str(p) for p in path)
        out.append((key, leaf))
    return out


def _path_str(p) -> str:
    if isinstance(p, jax.tree_util.DictKey):
        return str(p.key)
    if isinstance(p, jax.tree_util.SequenceKey):
        return str(p.idx)
    if isinstance(p, jax.tree_util.GetAttrKey):
        return str(p.name)
    return str(p)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._worker: Optional[threading.Thread] = None

    # -- write ---------------------------------------------------------------
    def save(self, step: int, tree: Any,
             extra: Optional[Dict[str, Any]] = None) -> str:
        """Synchronous atomic save; returns the final path."""
        host_tree = jax.tree_util.tree_map(np.asarray, tree)
        return self._write(step, host_tree, extra or {})

    def save_async(self, step: int, tree: Any,
                   extra: Optional[Dict[str, Any]] = None) -> None:
        """Snapshot now, write in the background (joins any prior writer
        first so checkpoints land in order)."""
        self.wait()
        host_tree = jax.tree_util.tree_map(np.asarray, tree)
        self._worker = threading.Thread(
            target=self._write, args=(step, host_tree, extra or {}))
        self._worker.start()

    def wait(self) -> None:
        if self._worker is not None:
            self._worker.join()
            self._worker = None

    def _write(self, step: int, host_tree, extra: Dict[str, Any]) -> str:
        final = os.path.join(self.directory, f"step_{step:08d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        leaves = _flatten_with_paths(host_tree)
        manifest = {"step": step, "extra": extra, "leaves": {}}
        for key, leaf in leaves:
            arr = np.asarray(leaf)
            fname = key.replace(SEP, "__") + ".npy"
            np.save(os.path.join(tmp, fname), arr)
            manifest["leaves"][key] = {
                "file": fname, "shape": list(arr.shape), "dtype": str(arr.dtype)}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)          # atomicity boundary
        self._retain()
        return final

    def _retain(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)

    # -- read ----------------------------------------------------------------
    def all_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.directory):
            if name.startswith("step_") and not name.endswith(".tmp"):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore_latest(self, like: Any, shardings: Any = None
                       ) -> Optional[Tuple[Any, Dict[str, Any], int]]:
        """Restore the newest checkpoint: ``(tree, extra, step)``, or
        ``None`` when the directory holds no checkpoint yet (first launch
        with ``resume=True`` is a no-op, not an error).

        The ``extra`` dict carries whatever the saver stashed — the Trainer
        stores its progress counters and the input pipeline/prefetcher
        state there, so restore is batch-exact even when the checkpoint was
        taken mid-epoch with prefetched batches in flight (the prefetcher
        reports the state of the last CONSUMED batch; see
        ``repro.data.Prefetcher.state``)."""
        step = self.latest_step()
        if step is None:
            return None
        tree, extra = self.restore(step, like, shardings)
        return tree, extra, step

    def restore(self, step: int, like: Any,
                shardings: Any = None) -> Tuple[Any, Dict[str, Any]]:
        """Restore into the structure of ``like``; optional ``shardings``
        pytree (same structure) device_puts each leaf — pass shardings built
        on a DIFFERENT mesh to reshard elastically."""
        path = os.path.join(self.directory, f"step_{step:08d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        keys = [k for k, _ in _flatten_with_paths(like)]
        missing = [k for k in keys if k not in manifest["leaves"]]
        if missing:
            raise KeyError(f"checkpoint missing leaves: {missing[:5]}")
        arrays = {k: np.load(os.path.join(path, v["file"]))
                  for k, v in manifest["leaves"].items()}
        flat_like, tree = jax.tree_util.tree_flatten(like)
        leaves = [arrays[k] for k in keys]
        if shardings is not None:
            flat_sh = jax.tree_util.tree_leaves(
                shardings, is_leaf=lambda x: x is None or hasattr(x, "mesh"))
            leaves = [a if s is None else jax.device_put(a, s)
                      for a, s in zip(leaves, flat_sh)]
        restored = jax.tree_util.tree_unflatten(tree, leaves)
        return restored, manifest["extra"]
