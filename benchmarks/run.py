"""Benchmark driver — one section per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--fast]
    PYTHONPATH=src python -m benchmarks.run --smoke   # CI: toy sizes + JSON

``--smoke`` is the CI arm: it autotunes the ELL engine (winner persisted to
``BENCH_autotune.json``), exercises the overlap + pre-reduced-ELL
aggregation arms at toy sizes (4 simulated cores), sweeps every registered
interconnect topology on one bit-matching stream (``BENCH_topology.json``),
runs the planner's auto arm — spec autotune persisted to
``BENCH_planner.json``, then ``Engine("auto")`` raced against the best
manual spec (``BENCH_auto.json``) — measures feature residency (dense
device-resident vs the ``host``/``mmap`` feature stores under sync vs
staged-prefetch input pipelines, ``BENCH_feature_store.json``),
races the GraphACT-merged ELL engine (``merge="redundancy"`` + ``mincom``
partitioning) against the plain ELL arm on a bit-matching power-law
stream (``BENCH_redundancy.json``),
serves open-loop traffic through the online inference service — trained
checkpoint, request coalescing, incremental-aggregation cache vs cold
recompute under a latency SLO (``BENCH_serving.json``),
sanity-runs the block-layout and ELL SpMM kernels against their oracle,
diffs the fresh record against the previous ``BENCH_smoke.json``
(warn-only), and writes ``BENCH_smoke.json`` + ``BENCH_overlap.json`` for
the workflow to upload as artifacts.  The smoke FAILS if the ELL arm's
aggregation speedups drop to ≤1.0, the hypercube NoC stops beating the
dense all-pairs reference, the auto spec loses to the best manual arm by
>10% (or stops bit-matching it), the staged store pipeline stops
cutting host stall / bit-matching the dense stream / hitting its
hot-vertex cache, or the serving arm's incremental path stops
bit-matching the cold recompute / coalescing concurrent queries /
beating the cold arm on throughput-at-SLO — no regression arm ships.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time


def smoke() -> int:
    """Toy-size benchmark smoke: autotune + overlap/ELL arms + kernel
    sanity, JSON out, regression diff vs the previous record."""
    t_start = time.time()
    rec = {"mode": "smoke"}
    prev = None
    if os.path.exists("BENCH_smoke.json"):     # snapshot BEFORE overwriting
        try:
            with open("BENCH_smoke.json") as f:
                prev = json.load(f)
        except ValueError:
            prev = None

    print(f"\n{'=' * 72}\nELL autotune (bucket scheme + tiles)\n{'=' * 72}")
    from repro.kernels import tune
    tune_rec = tune.autotune(n=256, deg=6, d=32, n_reps=3)
    rec["autotune"] = {"backend": tune_rec["backend"],
                       "config": tune_rec["config"],
                       "path": tune.cache_path()}
    print(f"config: {tune_rec['config']}  (wrote {tune.cache_path()})")

    print(f"\n{'=' * 72}\nengine arms — coo+serial oracle vs "
          f"block+pipelined / ell+pipelined (toy)\n{'=' * 72}")
    from benchmarks.epoch_time import (run_auto_arm, run_feature_store_arm,
                                       run_input_pipeline_arm,
                                       run_overlap_arm, run_redundancy_arm,
                                       run_topology_arm)
    from benchmarks.serving import run_serving_arm
    rec["overlap"] = run_overlap_arm(4, smoke=True)

    print(f"\n{'=' * 72}\ntopology sweep — every registered interconnect "
          f"vs the allpairs reference (toy)\n{'=' * 72}")
    rec["topology"] = run_topology_arm(4, smoke=True)

    print(f"\n{'=' * 72}\nauto arm — planner autotune + Engine('auto') vs "
          f"the best manual spec (toy)\n{'=' * 72}")
    rec["auto"] = run_auto_arm(4, smoke=True)

    print(f"\n{'=' * 72}\ninput pipeline — Trainer host-stall/step, "
          f"sync vs prefetch (toy)\n{'=' * 72}")
    rec["input_pipeline"] = run_input_pipeline_arm(4, smoke=True)

    print(f"\n{'=' * 72}\nfeature store — device vs host vs mmap, "
          f"sync vs staged prefetch (toy)\n{'=' * 72}")
    rec["feature_store"] = run_feature_store_arm(4, smoke=True)

    print(f"\n{'=' * 72}\nredundancy — GraphACT-merged ELL + mincom "
          f"partitioning vs plain ELL (toy)\n{'=' * 72}")
    rec["redundancy"] = run_redundancy_arm(4, smoke=True)

    print(f"\n{'=' * 72}\nserving — online inference: coalescing + "
          f"incremental aggregation vs cold (toy)\n{'=' * 72}")
    rec["serving"] = run_serving_arm(4, smoke=True)

    print(f"\n{'=' * 72}\nSpMM kernels vs oracle (interpret)\n{'=' * 72}")
    import numpy as np
    import jax.numpy as jnp
    from repro.core.blockmsg import dst_tiles
    from repro.graph.coo import from_edges
    from repro.graph.partition import block_partition
    from repro.kernels import edgeplan
    from repro.kernels.ops import ell_apply, spmm_block
    from repro.kernels.ref import spmm_ref

    rng = np.random.default_rng(0)
    n_dst, n_src, d, e = 64, 64, 32, 600
    coo = from_edges(rng.integers(0, n_dst, e), rng.integers(0, n_src, e),
                     rng.standard_normal(e).astype(np.float32), n_dst, n_src)
    tiles = dst_tiles(block_partition(coo, 4))
    x = jnp.asarray(rng.standard_normal((n_src, d)), jnp.float32)
    ref = np.asarray(spmm_ref(coo.rows, coo.cols, coo.vals, x, n_dst))
    t0 = time.time()
    y = spmm_block(jnp.asarray(tiles.rows), jnp.asarray(tiles.cols),
                   jnp.asarray(tiles.vals), x, tiles.dst_per_core)
    err = float(np.abs(np.asarray(y) - ref).max())
    rec["spmm_block"] = {"max_abs_err": err, "s": time.time() - t0,
                        "n_dst": n_dst, "n_src": n_src, "d": d, "e": e}
    print(f"spmm_block max |err| = {err:.2e}  ({rec['spmm_block']['s']:.1f}s)")

    t0 = time.time()
    plan = edgeplan.build_plan(coo)
    y_ell = ell_apply(plan.device_tables(), x, use_pallas=True)
    err_ell = float(np.abs(np.asarray(y_ell) - ref).max())
    rec["spmm_ell"] = {"max_abs_err": err_ell, "s": time.time() - t0,
                       "compression": plan.compression,
                       "padding_overhead": plan.padding_overhead,
                       "caps": list(plan.fwd.caps)}
    print(f"spmm_ell   max |err| = {err_ell:.2e}  "
          f"(compression {plan.compression:.2f}x, "
          f"padding {plan.padding_overhead:.2f}x, "
          f"{rec['spmm_ell']['s']:.1f}s)")

    rec["total_s"] = time.time() - t_start
    with open("BENCH_smoke.json", "w") as f:
        json.dump(rec, f, indent=1)
    print(f"\nwrote BENCH_smoke.json ({rec['total_s']:.1f}s total)")
    if prev is not None:
        from benchmarks.compare import compare_records, print_report
        rows, regressions = compare_records(prev, rec)
        print_report(rows, regressions, 0.10)   # warn-only in CI for now
    ov = rec["overlap"]
    ip = rec["input_pipeline"]
    tp = rec["topology"]
    au = rec["auto"]
    fs = rec["feature_store"]
    rd = rec["redundancy"]
    sv = rec["serving"]
    # direct indexing on purpose: the ELL arm always runs in smoke, and a
    # renamed/missing metric must be a loud KeyError, not a silently
    # disabled gate
    ok = (err < 1e-4 and err_ell < 1e-4 and ov["loss_match"]
          and ov["loss_match_ell"]
          # the acceptance gate: no regression arm ships — the ELL engine
          # must beat the serial schedule on its own hot path
          and ov["agg_fwd_speedup_ell"] > 1.0
          and ov["agg_fwdbwd_speedup_ell"] > 1.0
          # the topology gate (4 cores): the paper's hypercube NoC must
          # beat the dense all-pairs crossbar reference on the aggregation
          # hot path, and every topology's loss must stay within 1e-5 on
          # the shared bit-matching stream
          and tp["hypercube_vs_allpairs_speedup"] >= 1.0
          and tp["loss_match"]
          # and the async input pipeline must actually overlap: prefetch
          # STRICTLY reduces per-step host stall vs the sync pipeline on
          # an identical (bit-matching) batch stream
          and ip["prefetch_reduces_stall"]
          and ip["input_loss_match"]
          # the planner gate: Engine('auto') must follow its own persisted
          # autotune winner, bit-match its losses, and never lose to the
          # best manual arm by >10% (paired median on a common-mode load)
          and au["auto_vs_best_manual_speedup"] >= 0.9
          and au["auto_loss_match"]
          and au["resolved_matches_winner"]
          # the feature-store gate: out-of-core training must bit-match
          # the dense stream, the STAGED prefetch (sample → gather →
          # layout → place) must strictly cut host stall vs synchronous
          # gather, and the hot-vertex cache must actually absorb traffic
          and fs["prefetch_reduces_stall"]
          and fs["loss_match"]
          and fs["cache_hit_rate"] > 0
          # the redundancy gate: the GraphACT merge + mincom partitioning
          # must bit-match the plain ELL stream while actually cutting
          # BOTH measured exchange bytes and aggregation FLOPs on the
          # power-law bench graph — a merge that stops finding pairs (or a
          # partitioner that stops beating the naive split) fails here
          and rd["loss_match"]
          and rd["wire_bytes_reduction"] > 1.0
          and rd["flop_reduction"] > 1.0
          # the serving gate: after a mixed stream of queries and
          # graph/feature updates every incrementally-served logit must
          # bit-match a cold full recompute, the coalescer must actually
          # merge concurrent duplicate queries, and the incremental
          # aggregation cache must BEAT the cold path on throughput at the
          # latency SLO (paired replay of one trace — load is common-mode)
          and sv["bit_match"]
          and sv["coalesce_factor"] > 1.0
          and sv["incremental_vs_cold_throughput"] > 1.0)
    print("SMOKE", "PASS" if ok else "FAIL")
    return 0 if ok else 1


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="CI arm: toy sizes, writes BENCH_*.json")
    args = ap.parse_args()

    if args.smoke:
        raise SystemExit(smoke())

    sections = [
        ("Fig. 9 — routing cycles + §5.2 bandwidth", "routing_cycles"),
        ("Table 1 — dataflow complexities (Eqs. 5-8) + measured contracts",
         "dataflow_table1"),
        ("Table 2 — epoch time, ours vs naive dataflow", "epoch_time"),
        ("Overlap — serial vs pipelined aggregation", "epoch_time:overlap"),
        ("Topology — registered interconnects vs the allpairs reference",
         "epoch_time:topologies"),
        ("Fig. 1 — access locality / NUMA-vs-UMA bytes", "hbm_access"),
        ("Fig. 10/11 — compute:comm ratio + utilization", "ctc_ratio"),
        ("§Roofline — dry-run three-term table", "roofline"),
        ("Scaling — per-device wire bytes vs core count", "scaling"),
    ]
    argv_saved = sys.argv
    sys.argv = [argv_saved[0]]    # section mains parse their own argv
    try:
        for title, mod in sections:
            print(f"\n{'=' * 72}\n{title}\n{'=' * 72}")
            t0 = time.time()
            mod, _, variant = mod.partition(":")
            try:
                m = __import__(f"benchmarks.{mod}", fromlist=["main"])
                if variant == "overlap":
                    m.run_overlap_arm(8, smoke=args.fast)
                elif variant == "topologies":
                    m.run_topology_arm(8, smoke=args.fast)
                else:
                    m.main()
                print(f"[{mod}: {time.time() - t0:.1f}s]")
            except FileNotFoundError as e:
                print(f"[{mod}: skipped — {e}; run the dry-run first]")
            except Exception as e:  # noqa: BLE001
                print(f"[{mod}: FAILED — {e!r}]")
                raise
    finally:
        sys.argv = argv_saved


if __name__ == "__main__":
    main()
