"""Benchmark driver — one section per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--fast]
    PYTHONPATH=src python -m benchmarks.run --smoke   # CI: toy sizes + JSON

``--smoke`` is the CI arm: it exercises the pipelined-aggregation overlap
path at toy sizes (4 simulated cores), sanity-runs the block-layout SpMM
kernel against its oracle, and writes ``BENCH_smoke.json`` +
``BENCH_overlap.json`` for the workflow to upload as artifacts.
"""
from __future__ import annotations

import argparse
import json
import sys
import time


def smoke() -> int:
    """Toy-size benchmark smoke: overlap arm + kernel sanity, JSON out."""
    t_start = time.time()
    rec = {"mode": "smoke"}

    print(f"\n{'=' * 72}\npipelined aggregation — overlap arm (toy)\n"
          f"{'=' * 72}")
    from benchmarks.epoch_time import run_overlap_arm
    rec["overlap"] = run_overlap_arm(4, smoke=True)

    print(f"\n{'=' * 72}\nblock-layout SpMM kernel vs oracle (interpret)\n"
          f"{'=' * 72}")
    import numpy as np
    import jax.numpy as jnp
    from repro.core.blockmsg import dst_tiles
    from repro.graph.coo import from_edges
    from repro.graph.partition import block_partition
    from repro.kernels.ops import spmm_block
    from repro.kernels.ref import spmm_ref

    rng = np.random.default_rng(0)
    n_dst, n_src, d, e = 64, 64, 32, 600
    coo = from_edges(rng.integers(0, n_dst, e), rng.integers(0, n_src, e),
                     rng.standard_normal(e).astype(np.float32), n_dst, n_src)
    tiles = dst_tiles(block_partition(coo, 4))
    x = jnp.asarray(rng.standard_normal((n_src, d)), jnp.float32)
    t0 = time.time()
    y = spmm_block(jnp.asarray(tiles.rows), jnp.asarray(tiles.cols),
                   jnp.asarray(tiles.vals), x, tiles.dst_per_core)
    err = float(np.abs(np.asarray(y)
                       - np.asarray(spmm_ref(coo.rows, coo.cols, coo.vals,
                                             x, n_dst))).max())
    rec["spmm_block"] = {"max_abs_err": err, "s": time.time() - t0,
                        "n_dst": n_dst, "n_src": n_src, "d": d, "e": e}
    print(f"max |err| = {err:.2e}  ({rec['spmm_block']['s']:.1f}s)")

    rec["total_s"] = time.time() - t_start
    with open("BENCH_smoke.json", "w") as f:
        json.dump(rec, f, indent=1)
    print(f"\nwrote BENCH_smoke.json ({rec['total_s']:.1f}s total)")
    ok = err < 1e-4 and rec["overlap"]["loss_match"]
    print("SMOKE", "PASS" if ok else "FAIL")
    return 0 if ok else 1


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="CI arm: toy sizes, writes BENCH_*.json")
    args = ap.parse_args()

    if args.smoke:
        raise SystemExit(smoke())

    sections = [
        ("Fig. 9 — routing cycles + §5.2 bandwidth", "routing_cycles"),
        ("Table 1 — dataflow complexities (Eqs. 5-8) + measured contracts",
         "dataflow_table1"),
        ("Table 2 — epoch time, ours vs naive dataflow", "epoch_time"),
        ("Overlap — serial vs pipelined aggregation", "epoch_time:overlap"),
        ("Fig. 1 — access locality / NUMA-vs-UMA bytes", "hbm_access"),
        ("Fig. 10/11 — compute:comm ratio + utilization", "ctc_ratio"),
        ("§Roofline — dry-run three-term table", "roofline"),
        ("Scaling — per-device wire bytes vs core count", "scaling"),
    ]
    argv_saved = sys.argv
    sys.argv = [argv_saved[0]]    # section mains parse their own argv
    try:
        for title, mod in sections:
            print(f"\n{'=' * 72}\n{title}\n{'=' * 72}")
            t0 = time.time()
            mod, _, variant = mod.partition(":")
            try:
                m = __import__(f"benchmarks.{mod}", fromlist=["main"])
                if variant == "overlap":
                    m.run_overlap_arm(8, smoke=args.fast)
                else:
                    m.main()
                print(f"[{mod}: {time.time() - t0:.1f}s]")
            except FileNotFoundError as e:
                print(f"[{mod}: skipped — {e}; run the dry-run first]")
            except Exception as e:  # noqa: BLE001
                print(f"[{mod}: FAILED — {e!r}]")
                raise
    finally:
        sys.argv = argv_saved


if __name__ == "__main__":
    main()
