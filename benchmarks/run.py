"""Benchmark driver — one section per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--fast]
"""
from __future__ import annotations

import sys
import time


def main() -> None:
    sections = [
        ("Fig. 9 — routing cycles + §5.2 bandwidth", "routing_cycles"),
        ("Table 1 — dataflow complexities (Eqs. 5-8) + measured contracts",
         "dataflow_table1"),
        ("Table 2 — epoch time, ours vs naive dataflow", "epoch_time"),
        ("Fig. 1 — access locality / NUMA-vs-UMA bytes", "hbm_access"),
        ("Fig. 10/11 — compute:comm ratio + utilization", "ctc_ratio"),
        ("§Roofline — dry-run three-term table", "roofline"),
        ("Scaling — per-device wire bytes vs core count", "scaling"),
    ]
    for title, mod in sections:
        print(f"\n{'=' * 72}\n{title}\n{'=' * 72}")
        t0 = time.time()
        try:
            m = __import__(f"benchmarks.{mod}", fromlist=["main"])
            m.main()
            print(f"[{mod}: {time.time() - t0:.1f}s]")
        except FileNotFoundError as e:
            print(f"[{mod}: skipped — {e}; run the dry-run first]")
        except Exception as e:  # noqa: BLE001
            print(f"[{mod}: FAILED — {e!r}]")
            raise


if __name__ == "__main__":
    main()
