"""Fig. 9 reproduction — Fuse1..Fuse4 routing cycles + the §5.2 bandwidth
derivation.

Paper claims checked:
  * +~1 cycle per extra group from Fuse2→Fuse4,
  * fastest full 64-message wave = 4 cycles,
  * avg routed-wave period ≈ 20.13 ns at 250 MHz (≈ 5.03 cycles) ⇒
    2.96 TB/s effective aggregate bandwidth with 16× local compression,
    189.4 GB/s raw.
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from repro.core.routing import aggregate_bandwidth_model, fuse_experiment

CLOCK_NS = 4.0     # 250 MHz


def run(n_trials: int = 300, seed: int = 0) -> List[Dict]:
    rows = []
    for g in (1, 2, 3, 4):
        stats = fuse_experiment(g, n_trials=n_trials, seed=seed)
        period_ns = stats["avg_cycles"] * CLOCK_NS
        bw = aggregate_bandwidth_model(period_ns)
        rows.append({
            "fuse": g,
            "messages": int(stats["messages"]),
            "avg_cycles": round(stats["avg_cycles"], 3),
            "p95_cycles": stats["p95_cycles"],
            "max_cycles": stats["max_cycles"],
            "avg_period_ns": round(period_ns, 2),
            "effective_TBps": round(bw["effective_Bps"] / 1e12, 3),
            "raw_GBps": round(bw["raw_Bps"] / 1e9, 1),
        })
    return rows


def main() -> None:
    rows = run()
    print("fuse,messages,avg_cycles,p95,max,period_ns,eff_TB/s,raw_GB/s")
    for r in rows:
        print(f"{r['fuse']},{r['messages']},{r['avg_cycles']},"
              f"{r['p95_cycles']},{r['max_cycles']},{r['avg_period_ns']},"
              f"{r['effective_TBps']},{r['raw_GBps']}")
    f4 = rows[-1]
    print(f"# paper: Fuse4 ≈ 5.03 cycles (20.13 ns) → 2.96 TB/s eff, "
          f"189.4 GB/s raw; ours: {f4['avg_cycles']} cycles "
          f"({f4['avg_period_ns']} ns) → {f4['effective_TBps']} TB/s, "
          f"{f4['raw_GBps']} GB/s")


if __name__ == "__main__":
    main()
