"""Benchmark regression diff — old vs new ``BENCH_*.json``.

    PYTHONPATH=src python -m benchmarks.compare OLD.json NEW.json [--strict]

Flags tracked keys that moved >10% in the bad direction (warn-only by
default: CI prints the table and keeps going; ``--strict`` exits 1 on any
regression so the gate can be tightened later).  Keys are dotted paths into
the JSON record; direction says which way is better.  Missing keys (old
records predate a metric, or an arm was skipped) are reported as untracked,
never as failures — a fresh metric cannot regress.
"""
from __future__ import annotations

import argparse
import json
from typing import Dict, List, Optional, Tuple

# dotted path -> "higher" | "lower" (which direction is better).  Only
# load-robust metrics belong here: the paired-median speedups and the
# kernel error bounds.  Absolute per-step wall times are deliberately NOT
# tracked — on shared CI hosts they swing 2-3x with background load (see
# epoch_time.measured_overlap's methodology note), so a 10% gate on them
# would fail chronically on noise once --strict is enabled.
TRACKED: Dict[str, str] = {
    "overlap.speedup": "higher",
    "overlap.speedup_ell": "higher",
    "overlap.agg_fwd_speedup": "higher",
    "overlap.agg_fwdbwd_speedup": "higher",
    "overlap.agg_fwd_speedup_ell": "higher",
    "overlap.agg_fwdbwd_speedup_ell": "higher",
    "spmm_block.max_abs_err": "lower",
    "spmm_ell.max_abs_err": "lower",
    # sync-stall / prefetch-stall per step (Trainer input pipeline): a
    # ratio of two same-host, same-run stall times, so common-mode load
    # cancels like the paired speedups above
    "input_pipeline.stall_reduction": "higher",
    # the paper's NoC vs the dense all-pairs crossbar reference on the
    # aggregation hot path (paired median — load-robust); the topology
    # smoke gates it > 1, this tracks that it doesn't erode
    "topology.hypercube_vs_allpairs_speedup": "higher",
    # Engine('auto') vs the best manual arm (paired median); the smoke
    # gates it >= 0.9, this tracks that the planner's pick doesn't erode
    "auto.auto_vs_best_manual_speedup": "higher",
    # sync-stall / staged-prefetch-stall for the mmap feature store (same
    # same-host ratio construction as input_pipeline.stall_reduction), and
    # the hot-vertex cache's absorbed fraction of frontier traffic
    "feature_store.stall_reduction": "higher",
    "feature_store.cache_hit_rate": "higher",
    # redundancy-merged ELL vs plain ELL (paired median, same stream):
    # the smoke gates wire_bytes_reduction > 1 and loss bit-match; this
    # tracks that the merged plan's step win doesn't erode
    "redundancy.step_speedup": "higher",
    # the serving arms: throughput-at-SLO and p99 latency of the
    # incremental-aggregation path under the shared open-loop trace.
    # These are the issue-mandated SLO metrics; unlike the paired ratios
    # above they carry some host-load sensitivity (absolute wall times),
    # so they stay warn-only — the hard gate is the load-robust
    # incremental_vs_cold_throughput ratio in run.py --smoke
    "serving.throughput_at_slo": "higher",
    "serving.p99_ms": "lower",
}

# every BENCH_*.json a current benchmark produces — the ownership registry
# behind warn_unowned_records().  Grows with each new arm; a record on disk
# that no entry claims is an orphan (its producer was deleted or renamed)
# and should be pruned or re-owned, not silently uploaded forever.
KNOWN_RECORDS = {
    "BENCH_smoke.json":          "benchmarks/run.py --smoke",
    "BENCH_overlap.json":        "benchmarks/epoch_time.py",
    "BENCH_input_pipeline.json": "benchmarks/epoch_time.py --input-pipeline",
    "BENCH_feature_store.json":  "benchmarks/epoch_time.py --feature-store",
    "BENCH_redundancy.json":     "benchmarks/epoch_time.py --redundancy",
    "BENCH_serving.json":        "benchmarks/serving.py",
    "BENCH_topology.json":       "benchmarks/epoch_time.py --topology",
    "BENCH_auto.json":           "benchmarks/epoch_time.py --auto",
    "BENCH_autotune.json":       "repro.kernels.tune (ELL autotuner)",
    "BENCH_planner.json":        "repro.engine.planner.autotune",
}

_warned_unowned = False


def warn_unowned_records(directory: str = ".") -> List[str]:
    """Names of ``BENCH_*.json`` files in ``directory`` no current
    benchmark owns (per :data:`KNOWN_RECORDS`); prints one warning total
    per process — the orphan list, once, not one line per run per file."""
    global _warned_unowned
    import glob
    import os
    orphans = sorted(
        os.path.basename(p)
        for p in glob.glob(os.path.join(directory, "BENCH_*.json"))
        if os.path.basename(p) not in KNOWN_RECORDS)
    if orphans and not _warned_unowned:
        _warned_unowned = True
        print(f"# WARNING: {len(orphans)} BENCH record(s) with no current "
              f"producing benchmark: {', '.join(orphans)} — prune them or "
              "re-add a producer (see compare.KNOWN_RECORDS)")
    return orphans


def get_path(rec: Dict, path: str) -> Optional[float]:
    cur = rec
    for part in path.split("."):
        if not isinstance(cur, dict) or part not in cur:
            return None
        cur = cur[part]
    return float(cur) if isinstance(cur, (int, float)) else None


def compare_records(old: Dict, new: Dict, threshold: float = 0.10
                    ) -> Tuple[List[Dict], List[Dict]]:
    """Returns (rows, regressions); every row has old/new/delta/status."""
    rows, regressions = [], []
    for key, direction in TRACKED.items():
        o, n = get_path(old, key), get_path(new, key)
        if o is None or n is None:
            rows.append({"key": key, "old": o, "new": n, "delta": None,
                         "status": "untracked"})
            continue
        if o == 0:
            # a zero baseline is meaningful (e.g. a bit-exact kernel's
            # max_abs_err): ANY nonzero drift in the bad direction is a
            # regression, never delta=0%
            delta = 0.0 if n == 0 else float("inf") * (1 if n > o else -1)
            bad = n > 0 if direction == "lower" else n < 0
        else:
            delta = (n - o) / abs(o)
            bad = delta < -threshold if direction == "higher" \
                else delta > threshold
        status = "REGRESSION" if bad else "ok"
        row = {"key": key, "old": o, "new": n, "delta": delta,
               "status": status, "better": direction}
        rows.append(row)
        if bad:
            regressions.append(row)
    return rows, regressions


def print_report(rows: List[Dict], regressions: List[Dict],
                 threshold: float) -> None:
    print(f"## benchmark diff (threshold ±{threshold:.0%}, warn-only "
          "unless --strict)")
    print("key,old,new,delta,status")
    for r in rows:
        if r["delta"] is None:
            print(f"{r['key']},{r['old']},{r['new']},-,{r['status']}")
        else:
            print(f"{r['key']},{r['old']:.4g},{r['new']:.4g},"
                  f"{r['delta']:+.1%},{r['status']}")
    if regressions:
        print(f"# {len(regressions)} regression(s) >"
              f"{threshold:.0%} on tracked keys:")
        for r in regressions:
            print(f"#   {r['key']}: {r['old']:.4g} -> {r['new']:.4g} "
                  f"({r['delta']:+.1%}, better={r['better']})")
    else:
        print("# no regressions on tracked keys")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("old", help="previous BENCH_*.json")
    ap.add_argument("new", help="freshly produced BENCH_*.json")
    ap.add_argument("--threshold", type=float, default=0.10)
    ap.add_argument("--strict", action="store_true",
                    help="exit 1 on any regression (CI default: warn only)")
    args = ap.parse_args()
    warn_unowned_records()
    with open(args.old) as f:
        old = json.load(f)
    with open(args.new) as f:
        new = json.load(f)
    rows, regressions = compare_records(old, new, args.threshold)
    print_report(rows, regressions, args.threshold)
    if args.strict and regressions:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
