"""Table 2 reproduction — per-epoch training time, ours vs the naive
(HP-GNN-style) dataflow.

The FPGA cannot be timed here, so the reproduction has two layers:

  1. **Analytic model at the paper's scale**: per-epoch op counts from the
     Table-1 cost model at the paper's setup (batch 1024, NS (25, 10),
     hidden 256), for the naive dataflow vs ours.  The paper's headline is
     1.03×–1.81× over HP-GNN; our model isolates the DATAFLOW component of
     that gap (the NoC/NUMA component shows up in the ctc benchmark).
  2. **Measured at reduced scale**: wall-clock s/epoch of the actual jitted
     training step on the synthetic datasets, ours vs naive, same seeds.
"""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.estimator import LayerShape, time_naive, time_ours
from repro.graph import NeighborSampler, make_dataset
from repro.graph.datasets import DATASET_STATS
from repro.models.gcn_model import GCNConfig, gcn_loss, init_gcn_params
from repro.optim import apply_updates, sgd

from .dataflow_table1 import BATCH, FANOUTS, HIDDEN, paper_layer_shapes


def _time_naive_realistic(s: LayerShape, order: str) -> float:
    """Implementation-realistic baseline transpose costs: the Aᵀ table is an
    O(e log e) COO re-sort (not Table 1's literal O(n̄e) bound) and the
    feature transpose an O(n̄d) copy — what a software HP-GNN-style port
    would actually pay.  Keeps the Table-2 comparison honest."""
    import math
    base = time_ours(s, order) - (s.h * s.d + s.b * s.c)
    resort = s.e * max(math.log2(max(s.e, 2)), 1.0)
    feat_t = (s.nbar if order == "coag" else s.n) * s.d
    return float(base + resort + feat_t + s.h * s.d)


def analytic_epoch_ratio() -> List[Dict]:
    rows = []
    for name, st in DATASET_STATS.items():
        shapes = paper_layer_shapes(name)
        batches = st.n_nodes // BATCH
        naive_lit = sum(min(time_naive(s, "coag"), time_naive(s, "agco"))
                        for s in shapes) * batches
        naive_real = sum(min(_time_naive_realistic(s, "coag"),
                             _time_naive_realistic(s, "agco"))
                         for s in shapes) * batches
        ours = sum(min(time_ours(s, "coag"), time_ours(s, "agco"))
                   for s in shapes) * batches
        rows.append({"dataset": name, "ops_naive": naive_lit,
                     "ops_naive_realistic": naive_real, "ops_ours": ours,
                     "speedup_paper_literal": naive_lit / ours,
                     "speedup": naive_real / ours})
    return rows


def measured_epoch(name: str, scale: float = 0.01, batch: int = 64,
                   n_batches: int = 8, seed: int = 0) -> Dict:
    ds = make_dataset(name, scale=scale, feat_dim=64)
    sampler = NeighborSampler(ds.graph, fanouts=FANOUTS, pad_multiple=16,
                              seed=seed)
    out = {}
    rng = np.random.default_rng(seed)
    seeds_list = [rng.permutation(ds.graph.n_nodes)[:batch]
                  for _ in range(n_batches)]
    nnz_pad = sampler.static_nnz(batch)
    batches = []
    for sd in seeds_list:
        mb = sampler.sample(sd, nnz_pad=nnz_pad,
                            rng=np.random.default_rng(0))
        x = jnp.asarray(ds.features[np.minimum(mb.input_nodes,
                                               ds.graph.n_nodes - 1)])
        pad = mb.layers[0].n_dst - len(sd)
        lab = ds.labels[np.pad(sd, (0, pad))]
        if lab.ndim > 1:
            lab = lab.argmax(-1).astype(np.int32)
        batches.append((mb.layers, x, jnp.asarray(lab)))
    for dataflow in ("ours", "naive"):
        cfg = GCNConfig(name=name, feat_dim=64, hidden=HIDDEN,
                        n_classes=ds.stats.n_classes, dataflow=dataflow)
        params = init_gcn_params(jax.random.PRNGKey(seed), cfg)
        init, update = sgd(0.05)
        opt = init(params)
        orders = ("agco", "agco")

        @jax.jit
        def step(params, opt, layers, x, lab):
            loss, g = jax.value_and_grad(gcn_loss)(params, layers, x, lab,
                                                   cfg, orders,
                                                   n_valid=batch)
            upd, opt = update(g, opt, params)
            return apply_updates(params, upd), opt, loss

        # warmup compile
        params, opt, _ = step(params, opt, *batches[0])
        t0 = time.perf_counter()
        for layers, x, lab in batches:
            params, opt, loss = step(params, opt, layers, x, lab)
        jax.block_until_ready(loss)
        out[dataflow] = (time.perf_counter() - t0) / n_batches
    out["speedup"] = out["naive"] / out["ours"]
    return out


def main() -> None:
    print("## analytic (paper scale, dataflow component of Table 2)")
    print("dataset,ops_naive_tab1,ops_naive_realistic,ops_ours,"
          "speedup_tab1,speedup_realistic")
    for r in analytic_epoch_ratio():
        print(f"{r['dataset']},{r['ops_naive']:.4g},"
              f"{r['ops_naive_realistic']:.4g},{r['ops_ours']:.4g},"
              f"{r['speedup_paper_literal']:.2f},{r['speedup']:.3f}")
    print("# paper Table 2 overall speedup vs HP-GNN: 1.03x-1.81x "
          "(dataflow + NoC components combined)")
    print("## measured (reduced scale, s/batch on CPU)")
    print("dataset,s_naive,s_ours,speedup")
    for name in ("flickr", "reddit"):
        m = measured_epoch(name)
        print(f"{name},{m['naive']:.4f},{m['ours']:.4f},{m['speedup']:.3f}")


if __name__ == "__main__":
    main()
